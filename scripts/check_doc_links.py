#!/usr/bin/env python3
"""Dependency-free markdown link checker for this repository.

Walks every tracked ``*.md`` file and verifies that

* relative markdown links ``[text](path)`` point at files or directories
  that exist (query strings are rejected, ``#anchors`` are split off),
* intra-document and cross-document ``#anchor`` fragments resolve to a
  heading in the target file (GitHub slug rules: lowercase, spaces to
  dashes, punctuation dropped),

and exits nonzero listing every broken link. External links
(``http://``, ``https://``, ``mailto:``) and code spans are ignored.
Used by CI (see ``.github/workflows/ci.yml``) so stale cross-references
in README/docs fail the build instead of rotting silently.
"""

import os
import re
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

SKIP_DIRS = {".git", "target", "node_modules", ".github"}

# [text](target) — but not images' alt brackets (images are links too,
# same rules apply) and not footnote refs.
LINK_RE = re.compile(r"\[[^\]^\[]*\]\(([^)\s]+)(?:\s+\"[^\"]*\")?\)")
HEADING_RE = re.compile(r"^(#{1,6})\s+(.*)$")
CODE_FENCE_RE = re.compile(r"^(```|~~~)")


def github_slug(heading: str) -> str:
    """GitHub's anchor slug: strip markup, lowercase, spaces to dashes."""
    text = re.sub(r"`([^`]*)`", r"\1", heading)  # inline code
    text = re.sub(r"\[([^\]]*)\]\([^)]*\)", r"\1", text)  # links
    text = text.strip().lower()
    text = re.sub(r"[^\w\- ]", "", text)  # drop punctuation
    return text.replace(" ", "-")


def md_files():
    for dirpath, dirnames, filenames in os.walk(ROOT):
        dirnames[:] = [d for d in dirnames if d not in SKIP_DIRS]
        for f in filenames:
            if f.endswith(".md"):
                yield os.path.join(dirpath, f)


def headings_of(path: str):
    """Set of anchor slugs in a markdown file (fenced code excluded)."""
    slugs: dict = {}
    in_fence = False
    with open(path, encoding="utf-8") as fh:
        for line in fh:
            if CODE_FENCE_RE.match(line.strip()):
                in_fence = not in_fence
                continue
            if in_fence:
                continue
            m = HEADING_RE.match(line)
            if not m:
                continue
            slug = github_slug(m.group(2))
            # Duplicate headings get -1, -2... suffixes on GitHub.
            n = slugs.get(slug, 0)
            slugs[slug] = n + 1
    out = set()
    for slug, count in slugs.items():
        out.add(slug)
        for i in range(1, count):
            out.add(f"{slug}-{i}")
    return out


def links_of(path: str):
    """(line_no, target) for every markdown link, fenced code excluded."""
    out = []
    in_fence = False
    with open(path, encoding="utf-8") as fh:
        for ln, line in enumerate(fh, 1):
            if CODE_FENCE_RE.match(line.strip()):
                in_fence = not in_fence
                continue
            if in_fence:
                continue
            # Drop inline code spans before matching links.
            stripped = re.sub(r"`[^`]*`", "", line)
            for m in LINK_RE.finditer(stripped):
                out.append((ln, m.group(1)))
    return out


def main() -> int:
    heading_cache = {}
    problems = []
    for md in md_files():
        rel_md = os.path.relpath(md, ROOT)
        for ln, target in links_of(md):
            if target.startswith(("http://", "https://", "mailto:")):
                continue
            if target.startswith("#"):
                dest, frag = md, target[1:]
            else:
                path_part, _, frag = target.partition("#")
                dest = os.path.normpath(os.path.join(os.path.dirname(md), path_part))
                if not os.path.exists(dest):
                    problems.append(f"{rel_md}:{ln}: broken link -> {target}")
                    continue
                if frag and os.path.isdir(dest):
                    problems.append(f"{rel_md}:{ln}: anchor on a directory -> {target}")
                    continue
            if frag and dest.endswith(".md"):
                if dest not in heading_cache:
                    heading_cache[dest] = headings_of(dest)
                if frag.lower() not in heading_cache[dest]:
                    problems.append(f"{rel_md}:{ln}: missing anchor -> {target}")
    if problems:
        print(f"{len(problems)} broken markdown link(s):")
        for p in problems:
            print(f"  {p}")
        return 1
    print("all markdown links resolve")
    return 0


if __name__ == "__main__":
    sys.exit(main())
