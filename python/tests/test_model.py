"""L2 correctness: the transformer over flat params — shapes, pallas-vs-ref
equivalence of the full network, gradient sanity, training-step behavior,
and manifest consistency (the Rust-side ABI)."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import archs as A
from compile import model as M

TINY = A.ARCHS["tx-tiny"]


def rand_batch(seed=0, b=A.BATCH, t=A.MAX_SEQ):
    rng = np.random.default_rng(seed)
    tokens = jnp.asarray(rng.integers(0, 254, (b, t)), jnp.int32)
    cls_labels = jnp.asarray(rng.integers(0, A.NUM_CLASSES, (b,)), jnp.int32)
    mlm_labels = np.full((b, t), M.IGNORE_LABEL, np.int32)
    mask = rng.random((b, t)) < 0.15
    mlm_labels[mask] = rng.integers(0, 254, mask.sum())
    return tokens, cls_labels, jnp.asarray(mlm_labels)


def test_param_count_matches_layout():
    for arch in A.ARCHS.values():
        total = sum(e["size"] for e in arch.layout())
        assert arch.param_count() == total
        # offsets are contiguous
        off = 0
        for e in arch.layout():
            assert e["offset"] == off
            off += e["size"]


def test_flatten_unflatten_roundtrip():
    flat = M.init_params(TINY, 0)
    params = M.unflatten(TINY, flat)
    assert set(params) == {name for name, _ in TINY.param_spec()}
    back = M.flatten(TINY, params)
    np.testing.assert_array_equal(np.asarray(flat), np.asarray(back))


def test_forward_shapes():
    flat = M.init_params(TINY, 0)
    tokens, _, _ = rand_batch()
    h = M.encode(TINY, flat, tokens)
    assert h.shape == (A.BATCH, A.MAX_SEQ, TINY.d_model)
    assert M.mlm_logits(TINY, flat, tokens).shape == (A.BATCH, A.MAX_SEQ, A.VOCAB)
    assert M.cls_logits(TINY, flat, tokens).shape == (A.BATCH, A.NUM_CLASSES)


def test_pallas_and_ref_paths_agree():
    flat = M.init_params(TINY, 1)
    tokens, cls_labels, mlm_labels = rand_batch(1)
    lp, ap = M.cls_loss_acc(TINY, flat, tokens, cls_labels, use_pallas=True)
    lr, ar = M.cls_loss_acc(TINY, flat, tokens, cls_labels, use_pallas=False)
    np.testing.assert_allclose(float(lp), float(lr), rtol=1e-4)
    assert float(ap) == float(ar)
    lp, _ = M.mlm_loss_acc(TINY, flat, tokens, mlm_labels, use_pallas=True)
    lr, _ = M.mlm_loss_acc(TINY, flat, tokens, mlm_labels, use_pallas=False)
    np.testing.assert_allclose(float(lp), float(lr), rtol=1e-4)


def test_gradients_flow_to_all_params_cls():
    """Every tensor except the unused MLM head gets gradient signal."""
    flat = M.init_params(TINY, 2)
    tokens, cls_labels, _ = rand_batch(2)
    g = jax.grad(lambda f: M.cls_loss_acc(TINY, f, tokens, cls_labels)[0])(flat)
    gp = M.unflatten(TINY, g)
    for name, _ in TINY.param_spec():
        norm = float(jnp.linalg.norm(gp[name]))
        if name.startswith("mlm_head"):
            assert norm == 0.0, f"{name} should be untouched by cls loss"
        else:
            assert norm > 0.0, f"no gradient reaches {name}"


def test_train_step_reduces_loss():
    step = jax.jit(M.make_train_step(TINY, "cls"))
    flat = M.init_params(TINY, 3)
    mom = jnp.zeros_like(flat)
    tokens, labels, _ = rand_batch(3)
    losses = []
    for _ in range(20):
        flat, mom, loss = step(flat, mom, tokens, labels, jnp.float32(0.1))
        losses.append(float(loss))
    assert losses[-1] < losses[0]
    assert all(np.isfinite(losses))


def test_eval_step_accuracy_range():
    ev = jax.jit(M.make_eval_step(TINY, "cls"))
    flat = M.init_params(TINY, 4)
    tokens, labels, _ = rand_batch(4)
    loss, acc = ev(flat, tokens, labels)
    assert 0.0 <= float(acc) <= 1.0
    assert float(loss) > 0.0


def test_mlm_ignore_labels_respected():
    flat = M.init_params(TINY, 5)
    tokens, _, _ = rand_batch(5)
    all_ignored = jnp.full((A.BATCH, A.MAX_SEQ), M.IGNORE_LABEL, jnp.int32)
    loss, acc = M.mlm_loss_acc(TINY, flat, tokens, all_ignored)
    assert float(loss) == 0.0
    assert float(acc) == 0.0


def test_manifest_schema():
    m = A.manifest()
    assert m["abi_version"] == 1
    for name, arch in m["archs"].items():
        assert arch["param_count"] > 0
        dag = arch["dag"]
        ids = {n["id"] for n in dag["nodes"]}
        assert len(ids) == len(dag["nodes"]), f"duplicate layer ids in {name}"
        for src, dst in dag["edges"]:
            assert src in ids and dst in ids
        # every layout tensor is owned by exactly one dag node
        owned = [p for n in dag["nodes"] for p in n["params"]]
        assert sorted(owned) == sorted(e["name"] for e in arch["layout"])
        # init kinds sane
        for e in arch["layout"]:
            assert e["init"] in ("normal", "ones", "zeros")


@pytest.mark.skipif(
    not os.path.exists(os.path.join(os.path.dirname(__file__), "../../artifacts/manifest.json")),
    reason="artifacts not built",
)
def test_written_manifest_matches_source():
    path = os.path.join(os.path.dirname(__file__), "../../artifacts/manifest.json")
    with open(path) as f:
        written = json.load(f)
    assert written == A.manifest()


def test_init_params_layout_matches_manifest_init():
    flat = np.asarray(M.init_params(TINY, 0))
    for e in TINY.layout():
        sl = flat[e["offset"]:e["offset"] + e["size"]]
        if e["init"] == "ones":
            assert (sl == 1.0).all(), e["name"]
        elif e["init"] == "zeros":
            assert (sl == 0.0).all(), e["name"]
        else:
            assert sl.std() > 0.001, e["name"]
