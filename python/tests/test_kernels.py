"""L1 correctness: every Pallas kernel vs its pure-jnp oracle, across
hypothesis-driven shape/value sweeps. This is the core correctness signal
for the compiled artifacts (interpret=True lowers to the same HLO the
Rust runtime executes)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import kernels
from compile.kernels import ref

settings.register_profile("ci", max_examples=25, deadline=None)
settings.load_profile("ci")


# ---------------------------------------------------------------------------
# delta quant / dequant
# ---------------------------------------------------------------------------
@given(
    n=st.integers(min_value=1, max_value=5000),
    eps=st.sampled_from([1e-5, 1e-4, 1e-3]),
    scale=st.floats(min_value=1e-5, max_value=0.1),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_delta_quant_matches_ref(n, eps, scale, seed):
    rng = np.random.default_rng(seed)
    a = jnp.asarray(rng.standard_normal(n), jnp.float32)
    b = a + jnp.asarray(scale * rng.standard_normal(n), jnp.float32)
    e = jnp.asarray([eps], jnp.float32)
    q = np.asarray(kernels.delta_quant(a, b, e))
    qr = np.asarray(ref.delta_quant_ref(a, b, e))
    # XLA may compile x/s as x*(1/s); allow off-by-one on a <0.1% sliver of
    # elements sitting exactly on quantization-bucket boundaries.
    diff = np.abs(q - qr)
    assert diff.max() <= 1
    assert (diff != 0).sum() <= max(1, n // 500)
    q = jnp.asarray(q)
    back = kernels.delta_dequant(a, q, e)
    np.testing.assert_allclose(
        np.asarray(back),
        np.asarray(ref.delta_dequant_ref(a, q, e)),
        rtol=1e-5,
        atol=1e-6,  # fma vs mul+sub fusion differences, ~1 ulp
    )


@given(
    n=st.integers(min_value=8, max_value=4096),
    eps=st.sampled_from([1e-5, 1e-4, 1e-3]),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_delta_error_bound(n, eps, seed):
    """|b − dequant(quant(a,b))| <= ln(1+eps): Algorithm 1's guarantee."""
    rng = np.random.default_rng(seed)
    a = jnp.asarray(rng.standard_normal(n), jnp.float32)
    b = a + jnp.asarray(1e-3 * rng.standard_normal(n), jnp.float32)
    e = jnp.asarray([eps], jnp.float32)
    q = kernels.delta_quant(a, b, e)
    rec = kernels.delta_dequant(a, q, e)
    bound = float(np.log1p(eps)) * (1 + 1e-2)  # f32 divide/multiply slack
    assert float(jnp.max(jnp.abs(rec - b))) <= bound


def test_delta_quant_block_boundaries():
    """Exercise block sizes around the BlockSpec tiling edges."""
    e = jnp.asarray([1e-4], jnp.float32)
    for n in [1, 7, 8192, 8193, 16384]:
        a = jnp.arange(n, dtype=jnp.float32) / max(n, 1)
        b = a + 0.001
        q = kernels.delta_quant(a, b, e)
        qr = ref.delta_quant_ref(a, b, e)
        np.testing.assert_array_equal(np.asarray(q), np.asarray(qr))


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------
@given(
    b=st.integers(min_value=1, max_value=4),
    h=st.integers(min_value=1, max_value=4),
    t=st.sampled_from([4, 8, 16, 32]),
    dh=st.sampled_from([8, 16, 32]),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_attention_matches_ref(b, h, t, dh, seed):
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.standard_normal((b, h, t, dh)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((b, h, t, dh)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, h, t, dh)), jnp.float32)
    out = kernels.attention(q, k, v)
    want = ref.attention_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=2e-5, rtol=2e-5)


def test_attention_gradients_match_ref():
    """The custom_vjp backward (Pallas) vs jax.grad through the oracle."""
    rng = np.random.default_rng(0)
    shape = (2, 2, 8, 16)
    q = jnp.asarray(rng.standard_normal(shape), jnp.float32)
    k = jnp.asarray(rng.standard_normal(shape), jnp.float32)
    v = jnp.asarray(rng.standard_normal(shape), jnp.float32)

    def loss_k(f):
        return lambda q, k, v: jnp.sum(jnp.sin(f(q, k, v)))

    g_kernel = jax.grad(loss_k(kernels.attention), argnums=(0, 1, 2))(q, k, v)
    g_ref = jax.grad(loss_k(ref.attention_ref), argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g_kernel, g_ref):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-4, rtol=2e-4)


# ---------------------------------------------------------------------------
# layernorm
# ---------------------------------------------------------------------------
@given(
    b=st.integers(min_value=1, max_value=4),
    t=st.sampled_from([2, 8, 32]),
    d=st.sampled_from([8, 64, 96]),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_layernorm_matches_ref(b, t, d, seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal((b, t, d)), jnp.float32)
    g = jnp.asarray(rng.standard_normal(d), jnp.float32)
    bb = jnp.asarray(rng.standard_normal(d), jnp.float32)
    out = kernels.layernorm(x, g, bb)
    want = ref.layernorm_ref(x, g, bb)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=1e-5, rtol=1e-5)


def test_layernorm_gradients_match_ref():
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.standard_normal((3, 4, 16)), jnp.float32)
    g = jnp.asarray(1.0 + 0.1 * rng.standard_normal(16), jnp.float32)
    b = jnp.asarray(0.1 * rng.standard_normal(16), jnp.float32)

    def loss(f):
        return lambda x, g, b: jnp.sum(f(x, g, b) ** 2)

    got = jax.grad(loss(kernels.layernorm), argnums=(0, 1, 2))(x, g, b)
    want = jax.grad(loss(ref.layernorm_ref), argnums=(0, 1, 2))(x, g, b)
    for a, w in zip(got, want):
        np.testing.assert_allclose(np.asarray(a), np.asarray(w), atol=2e-4, rtol=2e-4)


def test_layernorm_output_is_normalized():
    rng = np.random.default_rng(2)
    x = jnp.asarray(5.0 + 3.0 * rng.standard_normal((2, 4, 64)), jnp.float32)
    y = kernels.layernorm(x, jnp.ones(64), jnp.zeros(64))
    mu = np.asarray(jnp.mean(y, axis=-1))
    sd = np.asarray(jnp.std(y, axis=-1))
    np.testing.assert_allclose(mu, 0.0, atol=1e-5)
    np.testing.assert_allclose(sd, 1.0, atol=1e-2)
