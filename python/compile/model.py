"""L2: the transformer-encoder family (fwd/bwd) over a flat parameter vector.

This is the JAX compute graph that MGit's creation functions (finetune,
MLM pretrain, FL local steps, MTL, prune-recovery) and test functions
(accuracy evaluation) execute. It calls the L1 Pallas kernels
(attention, layernorm) so they lower into the same HLO artifact.

ABI (all artifacts; see aot.py):
    <arch>_mlm_train : (params f32[N], mom f32[N], tokens i32[B,T],
                        labels i32[B,T], lr f32[])
                       -> (params' f32[N], mom' f32[N], loss f32[])
    <arch>_cls_train : same but labels i32[B]
    <arch>_mlm_eval  : (params, tokens, labels[B,T]) -> (loss, acc)
    <arch>_cls_eval  : (params, tokens, labels[B])   -> (loss, acc)

The flat vector layout is defined by ``archs.Arch.param_spec`` and is the
same for MLM and CLS objectives (both heads always present), so parent and
child models in a lineage share layouts exactly. MLM labels use -100 as
the ignore marker (only masked positions contribute to loss/accuracy).

Optimizer: SGD with momentum 0.9 (stateless apart from the caller-held
momentum vector, which keeps the ABI to plain arrays).
"""

from typing import Dict

import jax
import jax.numpy as jnp

from . import kernels
from .archs import Arch
from .kernels import ref

MOMENTUM = 0.9
IGNORE_LABEL = -100


# ---------------------------------------------------------------------------
# Flat vector <-> named parameters
# ---------------------------------------------------------------------------
def unflatten(arch: Arch, flat: jnp.ndarray) -> Dict[str, jnp.ndarray]:
    """Slice the flat f32[N] vector into named tensors (static offsets)."""
    params = {}
    off = 0
    for name, shape in arch.param_spec():
        n = 1
        for s in shape:
            n *= s
        params[name] = flat[off:off + n].reshape(shape)
        off += n
    return params


def flatten(arch: Arch, params: Dict[str, jnp.ndarray]) -> jnp.ndarray:
    return jnp.concatenate(
        [params[name].reshape(-1) for name, _ in arch.param_spec()]
    )


def init_params(arch: Arch, seed: int = 0) -> jnp.ndarray:
    """Reference initializer (the Rust side mirrors this from the manifest)."""
    key = jax.random.PRNGKey(seed)
    chunks = []
    for entry in arch.layout():
        n, init = entry["size"], entry["init"]
        if init == "ones":
            chunks.append(jnp.ones((n,), jnp.float32))
        elif init == "zeros":
            chunks.append(jnp.zeros((n,), jnp.float32))
        else:
            key, sub = jax.random.split(key)
            chunks.append(0.02 * jax.random.normal(sub, (n,), jnp.float32))
    return jnp.concatenate(chunks)


# ---------------------------------------------------------------------------
# Forward pass
# ---------------------------------------------------------------------------
def _attn_block(arch: Arch, p: Dict, prefix: str, x, use_pallas: bool):
    b, t, d = x.shape
    h, dh = arch.n_heads, arch.d_head

    def proj(w, bias):
        y = jnp.einsum("btd,de->bte", x, w) + bias
        return y.reshape(b, t, h, dh).transpose(0, 2, 1, 3)  # (B,H,T,Dh)

    q = proj(p[prefix + "attn.wq"], p[prefix + "attn.bq"])
    k = proj(p[prefix + "attn.wk"], p[prefix + "attn.bk"])
    v = proj(p[prefix + "attn.wv"], p[prefix + "attn.bv"])
    attn = kernels.attention if use_pallas else ref.attention_ref
    o = attn(q, k, v)                                        # (B,H,T,Dh)
    o = o.transpose(0, 2, 1, 3).reshape(b, t, d)
    return jnp.einsum("btd,de->bte", o, p[prefix + "attn.wo"]) \
        + p[prefix + "attn.bo"]


def encode(arch: Arch, flat, tokens, use_pallas: bool = True):
    """tokens i32[B,T] -> final hidden states f32[B,T,D]."""
    p = unflatten(arch, flat)
    ln = kernels.layernorm if use_pallas else ref.layernorm_ref
    x = p["embed.tok"][tokens] + p["embed.pos"][None, :, :]
    for i in range(arch.n_layers):
        pref = f"block{i}."
        hx = ln(x, p[pref + "ln1.g"], p[pref + "ln1.b"])
        x = x + _attn_block(arch, p, pref, hx, use_pallas)
        hx = ln(x, p[pref + "ln2.g"], p[pref + "ln2.b"])
        hx = jnp.einsum("btd,df->btf", hx, p[pref + "ff.w1"]) + p[pref + "ff.b1"]
        hx = jax.nn.gelu(hx)
        hx = jnp.einsum("btf,fd->btd", hx, p[pref + "ff.w2"]) + p[pref + "ff.b2"]
        x = x + hx
    return ln(x, p["final_ln.g"], p["final_ln.b"])


def mlm_logits(arch: Arch, flat, tokens, use_pallas: bool = True):
    h = encode(arch, flat, tokens, use_pallas)
    p = unflatten(arch, flat)
    return jnp.einsum("btd,dv->btv", h, p["mlm_head.w"]) + p["mlm_head.b"]


def cls_logits(arch: Arch, flat, tokens, use_pallas: bool = True):
    h = encode(arch, flat, tokens, use_pallas)
    p = unflatten(arch, flat)
    pooled = jnp.mean(h, axis=1)                             # (B, D)
    return pooled @ p["cls_head.w"] + p["cls_head.b"]


# ---------------------------------------------------------------------------
# Losses / metrics
# ---------------------------------------------------------------------------
def _ce(logits, labels):
    logp = jax.nn.log_softmax(logits, axis=-1)
    return -jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]


def mlm_loss_acc(arch: Arch, flat, tokens, labels, use_pallas: bool = True):
    """Masked-LM loss/accuracy; positions with label == -100 are ignored."""
    logits = mlm_logits(arch, flat, tokens, use_pallas)      # (B,T,V)
    valid = labels != IGNORE_LABEL
    safe = jnp.where(valid, labels, 0)
    ce = _ce(logits, safe)
    count = jnp.maximum(jnp.sum(valid), 1)
    loss = jnp.sum(jnp.where(valid, ce, 0.0)) / count
    pred = jnp.argmax(logits, axis=-1)
    acc = jnp.sum(jnp.where(valid, pred == safe, False)) / count
    return loss, acc.astype(jnp.float32)


def cls_loss_acc(arch: Arch, flat, tokens, labels, use_pallas: bool = True):
    logits = cls_logits(arch, flat, tokens, use_pallas)      # (B,C)
    loss = jnp.mean(_ce(logits, labels))
    acc = jnp.mean(jnp.argmax(logits, axis=-1) == labels)
    return loss, acc.astype(jnp.float32)


# ---------------------------------------------------------------------------
# Train / eval steps (the AOT entry points)
# ---------------------------------------------------------------------------
def _sgd(flat, mom, grad, lr):
    mom = MOMENTUM * mom + grad
    return flat - lr * mom, mom


def make_train_step(arch: Arch, objective: str, use_pallas: bool = True):
    loss_fn = mlm_loss_acc if objective == "mlm" else cls_loss_acc

    def step(flat, mom, tokens, labels, lr):
        loss, grad = jax.value_and_grad(
            lambda f: loss_fn(arch, f, tokens, labels, use_pallas)[0]
        )(flat)
        flat2, mom2 = _sgd(flat, mom, grad, lr)
        return flat2, mom2, loss

    return step


def make_eval_step(arch: Arch, objective: str, use_pallas: bool = True):
    loss_fn = mlm_loss_acc if objective == "mlm" else cls_loss_acc

    def step(flat, tokens, labels):
        loss, acc = loss_fn(arch, flat, tokens, labels, use_pallas)
        return loss, acc

    return step
