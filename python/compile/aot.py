"""AOT compile path: lower every (arch × objective × step) + delta kernels
to HLO **text** artifacts, and write the manifest the Rust runtime loads.

HLO text — not ``lowered.compile().serialize()`` and not the serialized
HloModuleProto — is the interchange format: jax >= 0.5 emits protos with
64-bit instruction ids that the pinned xla_extension 0.5.1 rejects
(``proto.id() <= INT_MAX``); the text parser reassigns ids and round-trips
cleanly (see /opt/xla-example/README.md).

Run once via ``make artifacts``; Python never appears on the request path.
"""

import argparse
import json
import os
import sys

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import archs as A
from . import model as M
from . import kernels


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _write(out_dir: str, fname: str, text: str) -> None:
    path = os.path.join(out_dir, fname)
    with open(path, "w") as f:
        f.write(text)
    print(f"  wrote {fname} ({len(text) / 1e6:.2f} MB)", flush=True)


def lower_arch(arch: A.Arch, out_dir: str) -> None:
    n = arch.param_count()
    b, t = A.BATCH, arch.max_seq
    flat = jax.ShapeDtypeStruct((n,), jnp.float32)
    tokens = jax.ShapeDtypeStruct((b, t), jnp.int32)
    mlm_labels = jax.ShapeDtypeStruct((b, t), jnp.int32)
    cls_labels = jax.ShapeDtypeStruct((b,), jnp.int32)
    lr = jax.ShapeDtypeStruct((), jnp.float32)

    for obj, labels in (("mlm", mlm_labels), ("cls", cls_labels)):
        train = M.make_train_step(arch, obj)
        # donate_argnums lets XLA alias the params/momentum buffers so the
        # training loop updates in place instead of copying N floats/step.
        lowered = jax.jit(train, donate_argnums=(0, 1)).lower(
            flat, flat, tokens, labels, lr
        )
        _write(out_dir, f"{arch.name}_{obj}_train.hlo.txt", to_hlo_text(lowered))

        ev = M.make_eval_step(arch, obj)
        lowered = jax.jit(ev).lower(flat, tokens, labels)
        _write(out_dir, f"{arch.name}_{obj}_eval.hlo.txt", to_hlo_text(lowered))


def lower_delta_kernels(out_dir: str) -> None:
    c = A.DELTA_CHUNK
    fa = jax.ShapeDtypeStruct((c,), jnp.float32)
    qi = jax.ShapeDtypeStruct((c,), jnp.int32)
    eps = jax.ShapeDtypeStruct((1,), jnp.float32)

    lowered = jax.jit(lambda a, b, e: kernels.delta_quant(a, b, e)).lower(
        fa, fa, eps
    )
    _write(out_dir, f"delta_quant_c{c}.hlo.txt", to_hlo_text(lowered))

    lowered = jax.jit(lambda a, q, e: kernels.delta_dequant(a, q, e)).lower(
        fa, qi, eps
    )
    _write(out_dir, f"delta_dequant_c{c}.hlo.txt", to_hlo_text(lowered))


def main() -> None:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--out", default="../artifacts", help="output directory")
    p.add_argument(
        "--arch", default=None, help="only lower one architecture (debugging)"
    )
    args = p.parse_args()
    os.makedirs(args.out, exist_ok=True)

    names = [args.arch] if args.arch else list(A.ARCHS)
    for name in names:
        arch = A.ARCHS[name]
        print(f"lowering {name} ({arch.param_count():,} params)", flush=True)
        lower_arch(arch, args.out)
    print("lowering delta kernels", flush=True)
    lower_delta_kernels(args.out)

    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(A.manifest(), f, indent=1)
    print("wrote manifest.json", flush=True)


if __name__ == "__main__":
    main()
