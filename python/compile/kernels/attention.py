"""Fused scaled-dot-product attention as a Pallas kernel (training hot-spot).

One grid cell per (batch, head); the whole (T, d_head) tile lives in VMEM
(T=32, d_head<=32 => q/k/v tiles + the TxT score matrix total ~28 KiB,
far under the ~16 MiB TPU VMEM budget — see DESIGN.md §7). Sequences are
fixed-length and unpadded, so no mask is needed.

``pallas_call`` has no automatic differentiation rule, so the kernel is
wrapped in ``jax.custom_vjp`` with the backward pass *also* written as a
Pallas kernel (recomputing the softmax probabilities from the saved
q, k, v residuals — the flash-attention-style recompute strategy).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _fwd_kernel(q_ref, k_ref, v_ref, o_ref, *, scale):
    q = q_ref[0, 0]  # (T, Dh)
    k = k_ref[0, 0]
    v = v_ref[0, 0]
    s = jnp.dot(q, k.T) * scale                        # (T, T)
    s = s - jnp.max(s, axis=-1, keepdims=True)
    p = jnp.exp(s)
    p = p / jnp.sum(p, axis=-1, keepdims=True)
    o_ref[0, 0] = jnp.dot(p, v)


def _bwd_kernel(q_ref, k_ref, v_ref, do_ref, dq_ref, dk_ref, dv_ref, *, scale):
    q = q_ref[0, 0]
    k = k_ref[0, 0]
    v = v_ref[0, 0]
    do = do_ref[0, 0]
    s = jnp.dot(q, k.T) * scale
    s = s - jnp.max(s, axis=-1, keepdims=True)
    p = jnp.exp(s)
    p = p / jnp.sum(p, axis=-1, keepdims=True)          # (T, T)
    dv = jnp.dot(p.T, do)                               # (T, Dh)
    dp = jnp.dot(do, v.T)                               # (T, T)
    ds = p * (dp - jnp.sum(dp * p, axis=-1, keepdims=True))
    dq_ref[0, 0] = jnp.dot(ds, k) * scale
    dk_ref[0, 0] = jnp.dot(ds.T, q) * scale
    dv_ref[0, 0] = dv


def _tile_spec(t, dh):
    return pl.BlockSpec((1, 1, t, dh), lambda b, h: (b, h, 0, 0))


def _attention_fwd_impl(q, k, v):
    b, h, t, dh = q.shape
    scale = 1.0 / (dh ** 0.5)
    return pl.pallas_call(
        functools.partial(_fwd_kernel, scale=scale),
        grid=(b, h),
        in_specs=[_tile_spec(t, dh)] * 3,
        out_specs=_tile_spec(t, dh),
        out_shape=jax.ShapeDtypeStruct((b, h, t, dh), q.dtype),
        interpret=True,
    )(q, k, v)


def _attention_bwd_impl(q, k, v, do):
    b, h, t, dh = q.shape
    scale = 1.0 / (dh ** 0.5)
    shape = jax.ShapeDtypeStruct((b, h, t, dh), q.dtype)
    return pl.pallas_call(
        functools.partial(_bwd_kernel, scale=scale),
        grid=(b, h),
        in_specs=[_tile_spec(t, dh)] * 4,
        out_specs=[_tile_spec(t, dh)] * 3,
        out_shape=[shape, shape, shape],
        interpret=True,
    )(q, k, v, do)


@jax.custom_vjp
def attention(q, k, v):
    """softmax(q kᵀ / sqrt(d_head)) v over (B, H, T, d_head) tensors."""
    return _attention_fwd_impl(q, k, v)


def _attention_fwd(q, k, v):
    return _attention_fwd_impl(q, k, v), (q, k, v)


def _attention_bwd(res, do):
    q, k, v = res
    return tuple(_attention_bwd_impl(q, k, v, do))


attention.defvjp(_attention_fwd, _attention_bwd)
