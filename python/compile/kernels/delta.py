"""Pallas kernels for MGit's delta quantization (Algorithm 1 hot-spot).

The storage path computes, for every pair of matched parameter tensors
(p_parent, p_child), the error-bounded quantized delta

    q = floor((p_parent - p_child) / (2 * ln(1 + eps)) + 0.5)        (i32)

and its inverse

    p_child' = p_parent - q * (2 * ln(1 + eps))

These are bandwidth-bound elementwise kernels over flat f32 vectors. They
are tiled with a 1-D grid so each block (BLOCK elements, 256 KiB per f32
operand at the default) fits comfortably in TPU VMEM; on CPU they run via
``interpret=True`` (Mosaic custom-calls are not executable on the CPU PJRT
plugin — see DESIGN.md §Hardware-Adaptation).

The quantizer guarantees |delta - dequant(quant(delta))| <= ln(1+eps),
which is what MGit's accept/reject accuracy check relies on.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK = 8192


def _quant_kernel(eps_ref, a_ref, b_ref, q_ref):
    step = 2.0 * jnp.log1p(eps_ref[0])
    d = (a_ref[...] - b_ref[...]) / step
    q_ref[...] = jnp.floor(d + 0.5).astype(jnp.int32)


def _dequant_kernel(eps_ref, a_ref, q_ref, b_ref):
    step = 2.0 * jnp.log1p(eps_ref[0])
    b_ref[...] = a_ref[...] - q_ref[...].astype(jnp.float32) * step


def _pick_block(n: int, block: int) -> int:
    """Largest power-of-two block <= ``block`` that divides ``n``.

    Falls back to n itself for small/odd sizes so arbitrary test shapes work.
    """
    b = min(block, n)
    while b > 1 and n % b != 0:
        b //= 2
    return max(b, 1)


@functools.partial(jax.jit, static_argnames=("block",))
def delta_quant(a, b, eps, block: int = DEFAULT_BLOCK):
    """Quantize the delta ``a - b`` into i32 steps of ``2*ln(1+eps)``.

    a, b: f32[N] (same shape); eps: f32[1]. Returns i32[N].
    """
    (n,) = a.shape
    blk = _pick_block(n, block)
    grid = (n // blk,)
    return pl.pallas_call(
        _quant_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((blk,), lambda i: (i,)),
            pl.BlockSpec((blk,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((blk,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.int32),
        interpret=True,
    )(eps, a, b)


@functools.partial(jax.jit, static_argnames=("block",))
def delta_dequant(a, q, eps, block: int = DEFAULT_BLOCK):
    """Reconstruct ``b' = a - q * 2*ln(1+eps)`` from the quantized delta.

    a: f32[N]; q: i32[N]; eps: f32[1]. Returns f32[N].
    """
    (n,) = a.shape
    blk = _pick_block(n, block)
    grid = (n // blk,)
    return pl.pallas_call(
        _dequant_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((blk,), lambda i: (i,)),
            pl.BlockSpec((blk,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((blk,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.float32),
        interpret=True,
    )(eps, a, q)
