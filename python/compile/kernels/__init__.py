"""L1 Pallas kernels: MGit's compute hot-spots (see DESIGN.md §1)."""

from .attention import attention
from .delta import delta_dequant, delta_quant
from .layernorm import layernorm

__all__ = ["attention", "delta_quant", "delta_dequant", "layernorm"]
