"""Fused LayerNorm as a Pallas kernel.

Forward and the input-gradient backward are Pallas kernels gridded over the
batch dimension (one (T, D) tile per cell — a few KiB, VMEM-resident).
The tiny parameter gradients (dg, db: reductions over B*T rows) are plain
jnp reductions; they are O(D) outputs and not a hot-spot.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

EPS = 1e-5


def _fwd_kernel(x_ref, g_ref, b_ref, y_ref):
    x = x_ref[0]                                   # (T, D)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)
    xhat = (x - mu) * jax.lax.rsqrt(var + EPS)
    y_ref[0] = xhat * g_ref[...] + b_ref[...]


def _bwd_dx_kernel(x_ref, g_ref, dy_ref, dx_ref):
    x = x_ref[0]
    dy = dy_ref[0]
    g = g_ref[...]
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)
    rstd = jax.lax.rsqrt(var + EPS)
    xhat = (x - mu) * rstd
    dyg = dy * g
    m1 = jnp.mean(dyg, axis=-1, keepdims=True)
    m2 = jnp.mean(dyg * xhat, axis=-1, keepdims=True)
    dx_ref[0] = (dyg - m1 - xhat * m2) * rstd


def _x_spec(t, d):
    return pl.BlockSpec((1, t, d), lambda i: (i, 0, 0))


def _p_spec(d):
    return pl.BlockSpec((d,), lambda i: (0,))


def _layernorm_fwd_impl(x, g, b):
    bs, t, d = x.shape
    return pl.pallas_call(
        _fwd_kernel,
        grid=(bs,),
        in_specs=[_x_spec(t, d), _p_spec(d), _p_spec(d)],
        out_specs=_x_spec(t, d),
        out_shape=jax.ShapeDtypeStruct((bs, t, d), x.dtype),
        interpret=True,
    )(x, g, b)


def _layernorm_bwd_dx(x, g, dy):
    bs, t, d = x.shape
    return pl.pallas_call(
        _bwd_dx_kernel,
        grid=(bs,),
        in_specs=[_x_spec(t, d), _p_spec(d), _x_spec(t, d)],
        out_specs=_x_spec(t, d),
        out_shape=jax.ShapeDtypeStruct((bs, t, d), x.dtype),
        interpret=True,
    )(x, g, dy)


@jax.custom_vjp
def layernorm(x, g, b):
    """LayerNorm over the last dim of x:(B,T,D) with affine (g, b):(D,)."""
    return _layernorm_fwd_impl(x, g, b)


def _fwd(x, g, b):
    return _layernorm_fwd_impl(x, g, b), (x, g)


def _bwd(res, dy):
    x, g = res
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)
    xhat = (x - mu) * jax.lax.rsqrt(var + EPS)
    dg = jnp.sum(dy * xhat, axis=(0, 1))
    db = jnp.sum(dy, axis=(0, 1))
    dx = _layernorm_bwd_dx(x, g, dy)
    return dx, dg, db


layernorm.defvjp(_fwd, _bwd)
