"""Pure-jnp oracles for every Pallas kernel (the correctness ground truth).

The pytest suite (python/tests/) asserts `assert_allclose` between each
kernel and its oracle across hypothesis-driven shape/value sweeps, and the
L2 model can be built entirely on these references (``use_pallas=False``)
to cross-check the whole network.
"""

import jax
import jax.numpy as jnp

LN_EPS = 1e-5


def delta_quant_ref(a, b, eps):
    step = 2.0 * jnp.log1p(eps[0])
    return jnp.floor((a - b) / step + 0.5).astype(jnp.int32)


def delta_dequant_ref(a, q, eps):
    step = 2.0 * jnp.log1p(eps[0])
    return a - q.astype(jnp.float32) * step


def attention_ref(q, k, v):
    dh = q.shape[-1]
    s = jnp.einsum("bhtd,bhsd->bhts", q, k) / jnp.sqrt(jnp.float32(dh))
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhts,bhsd->bhtd", p, v)


def layernorm_ref(x, g, b):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)
    xhat = (x - mu) * jax.lax.rsqrt(var + LN_EPS)
    return xhat * g + b
