"""Architecture descriptors for the MGit transformer zoo.

This file is the single source of truth for:
  * the model family's hyperparameters (the BERT/RoBERTa/... analog zoo),
  * the *flat parameter layout* — the ordered list of named tensors that is
    packed into one f32 vector, which is the ABI between the AOT-compiled
    HLO artifacts and the Rust runtime,
  * the layer DAG used by MGit's structural `diff` (Algorithm 3).

Everything here is mirrored into `artifacts/manifest.json` by `aot.py`; the
Rust side never re-derives layouts on its own.
"""

from dataclasses import dataclass, field
from typing import Dict, List, Tuple

VOCAB = 256          # token ids 0..253 data, 254 = CLS, 255 = MASK
MAX_SEQ = 32         # fixed sequence length (synthetic data is unpadded)
NUM_CLASSES = 4      # classification head width shared by all tasks
BATCH = 32           # fixed train/eval batch size
DELTA_CHUNK = 65536  # element count per delta_quant/dequant kernel call


@dataclass(frozen=True)
class Arch:
    """A transformer-encoder architecture in the zoo."""

    name: str
    d_model: int
    n_layers: int
    n_heads: int
    d_ff: int
    vocab: int = VOCAB
    max_seq: int = MAX_SEQ
    n_classes: int = NUM_CLASSES

    @property
    def d_head(self) -> int:
        assert self.d_model % self.n_heads == 0
        return self.d_model // self.n_heads

    # ------------------------------------------------------------------
    # Flat parameter layout
    # ------------------------------------------------------------------
    def param_spec(self) -> List[Tuple[str, Tuple[int, ...]]]:
        """Ordered (name, shape) list defining the flat f32 vector layout.

        Both heads (MLM and classification) are always present so that a
        fine-tuned child shares its parent's layout exactly — this is what
        makes LCS delta matching between parent and child trivial for
        same-arch pairs and keeps a single ABI per architecture.
        """
        d, ff = self.d_model, self.d_ff
        spec: List[Tuple[str, Tuple[int, ...]]] = [
            ("embed.tok", (self.vocab, d)),
            ("embed.pos", (self.max_seq, d)),
        ]
        for i in range(self.n_layers):
            p = f"block{i}."
            spec += [
                (p + "ln1.g", (d,)),
                (p + "ln1.b", (d,)),
                (p + "attn.wq", (d, d)),
                (p + "attn.bq", (d,)),
                (p + "attn.wk", (d, d)),
                (p + "attn.bk", (d,)),
                (p + "attn.wv", (d, d)),
                (p + "attn.bv", (d,)),
                (p + "attn.wo", (d, d)),
                (p + "attn.bo", (d,)),
                (p + "ln2.g", (d,)),
                (p + "ln2.b", (d,)),
                (p + "ff.w1", (d, ff)),
                (p + "ff.b1", (ff,)),
                (p + "ff.w2", (ff, d)),
                (p + "ff.b2", (d,)),
            ]
        spec += [
            ("final_ln.g", (d,)),
            ("final_ln.b", (d,)),
            ("mlm_head.w", (d, self.vocab)),
            ("mlm_head.b", (self.vocab,)),
            ("cls_head.w", (d, self.n_classes)),
            ("cls_head.b", (self.n_classes,)),
        ]
        return spec

    def param_count(self) -> int:
        total = 0
        for _, shape in self.param_spec():
            n = 1
            for s in shape:
                n *= s
            total += n
        return total

    def layout(self) -> List[Dict]:
        """Manifest entries: name, shape, offset, size, init kind.

        The init kind tells the Rust side how to initialize fresh models
        (it never calls back into Python): 'normal' = N(0, 0.02²),
        'ones' = layernorm gains, 'zeros' = biases / layernorm shifts.
        """
        out, off = [], 0
        for name, shape in self.param_spec():
            n = 1
            for s in shape:
                n *= s
            if name.endswith(".g"):
                init = "ones"
            elif name.endswith((".b", ".bq", ".bk", ".bv", ".bo", ".b1", ".b2")):
                init = "zeros"
            else:
                init = "normal"
            out.append(
                {
                    "name": name,
                    "shape": list(shape),
                    "offset": off,
                    "size": n,
                    "init": init,
                }
            )
            off += n
        return out

    # ------------------------------------------------------------------
    # Layer DAG (for MGit's structural diff)
    # ------------------------------------------------------------------
    def layer_dag(self) -> Dict:
        """Explicit dataflow DAG over *layers* (not tensors).

        Node attrs: id, op type, attribute string (shape signature), list of
        parameter tensor names owned by the layer. Edges are dataflow.
        This substitutes for the paper's torch.fx capture.
        """
        nodes: List[Dict] = []
        edges: List[List[str]] = []

        def node(nid: str, op: str, attrs: str, params: List[str]):
            nodes.append({"id": nid, "op": op, "attrs": attrs, "params": params})

        d, ff = self.d_model, self.d_ff
        node("embed.tok", "embedding", f"{self.vocab}x{d}", ["embed.tok"])
        node("embed.pos", "pos_embedding", f"{self.max_seq}x{d}", ["embed.pos"])
        node("embed.add", "add", f"{d}", [])
        edges += [["embed.tok", "embed.add"], ["embed.pos", "embed.add"]]
        prev = "embed.add"
        for i in range(self.n_layers):
            p = f"block{i}."
            node(p + "ln1", "layernorm", f"{d}", [p + "ln1.g", p + "ln1.b"])
            node(
                p + "attn",
                "attention",
                f"h{self.n_heads}x{self.d_head}",
                [
                    p + "attn.wq", p + "attn.bq", p + "attn.wk", p + "attn.bk",
                    p + "attn.wv", p + "attn.bv", p + "attn.wo", p + "attn.bo",
                ],
            )
            node(p + "add1", "add", f"{d}", [])
            node(p + "ln2", "layernorm", f"{d}", [p + "ln2.g", p + "ln2.b"])
            node(p + "ff1", "linear", f"{d}x{ff}", [p + "ff.w1", p + "ff.b1"])
            node(p + "gelu", "gelu", f"{ff}", [])
            node(p + "ff2", "linear", f"{ff}x{d}", [p + "ff.w2", p + "ff.b2"])
            node(p + "add2", "add", f"{d}", [])
            edges += [
                [prev, p + "ln1"],
                [p + "ln1", p + "attn"],
                [p + "attn", p + "add1"],
                [prev, p + "add1"],
                [p + "add1", p + "ln2"],
                [p + "ln2", p + "ff1"],
                [p + "ff1", p + "gelu"],
                [p + "gelu", p + "ff2"],
                [p + "ff2", p + "add2"],
                [p + "add1", p + "add2"],
            ]
            prev = p + "add2"
        node("final_ln", "layernorm", f"{d}", ["final_ln.g", "final_ln.b"])
        edges.append([prev, "final_ln"])
        node("mlm_head", "linear", f"{d}x{self.vocab}", ["mlm_head.w", "mlm_head.b"])
        node("cls_pool", "mean_pool", f"{d}", [])
        node("cls_head", "linear", f"{d}x{self.n_classes}",
             ["cls_head.w", "cls_head.b"])
        edges += [
            ["final_ln", "mlm_head"],
            ["final_ln", "cls_pool"],
            ["cls_pool", "cls_head"],
        ]
        return {"nodes": nodes, "edges": edges}


# The zoo. tx-tiny / tx-small / tx-base stand in for the small / base /
# large model families of the paper's G1 (see DESIGN.md §2 substitutions).
ARCHS: Dict[str, Arch] = {
    a.name: a
    for a in [
        Arch("tx-tiny", d_model=64, n_layers=2, n_heads=2, d_ff=128),
        Arch("tx-small", d_model=96, n_layers=4, n_heads=3, d_ff=192),
        Arch("tx-base", d_model=192, n_layers=6, n_heads=6, d_ff=384),
    ]
}


def manifest() -> Dict:
    """The full manifest mirrored to artifacts/manifest.json."""
    return {
        "abi_version": 1,
        "vocab": VOCAB,
        "max_seq": MAX_SEQ,
        "n_classes": NUM_CLASSES,
        "batch": BATCH,
        "delta_chunk": DELTA_CHUNK,
        "special_tokens": {"cls": 254, "mask": 255, "ignore_label": -100},
        "archs": {
            name: {
                "d_model": a.d_model,
                "n_layers": a.n_layers,
                "n_heads": a.n_heads,
                "d_ff": a.d_ff,
                "param_count": a.param_count(),
                "layout": a.layout(),
                "dag": a.layer_dag(),
            }
            for name, a in ARCHS.items()
        },
        "artifacts": {
            name: {
                "mlm_train": f"{name}_mlm_train.hlo.txt",
                "mlm_eval": f"{name}_mlm_eval.hlo.txt",
                "cls_train": f"{name}_cls_train.hlo.txt",
                "cls_eval": f"{name}_cls_eval.hlo.txt",
            }
            for name in ARCHS
        },
        "delta_kernels": {
            "quant": f"delta_quant_c{DELTA_CHUNK}.hlo.txt",
            "dequant": f"delta_dequant_c{DELTA_CHUNK}.hlo.txt",
        },
    }


if __name__ == "__main__":
    for name, a in ARCHS.items():
        print(f"{name}: {a.param_count():,} params")
