//! Integration: the `mgit` CLI surface against a temp repository.

use std::path::PathBuf;

fn run(args: &[&str]) -> anyhow::Result<()> {
    mgit::cli::run(args.iter().map(|s| s.to_string()).collect())
}

fn tmp_repo(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("mgit-cli-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

fn artifacts() -> String {
    PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("artifacts")
        .to_string_lossy()
        .into_owned()
}

#[test]
fn init_log_fsck_stats_gc() {
    let dir = tmp_repo("basic");
    let d = dir.to_str().unwrap();
    run(&["init", "--dir", d]).unwrap();
    // double init fails
    assert!(run(&["init", "--dir", d]).is_err());
    run(&["log", "--dir", d]).unwrap();
    run(&["fsck", "--dir", d]).unwrap();
    run(&["stats", "--dir", d]).unwrap();
    run(&["gc", "--dir", d]).unwrap();
    assert!(run(&["nonsense", "--dir", d]).is_err());
    run(&["help"]).unwrap();
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn build_compress_test_cascade_flow() {
    let dir = tmp_repo("flow");
    let d = dir.to_str().unwrap();
    let a = artifacts();
    run(&["init", "--dir", d]).unwrap();
    // Build a small G5 (fast) and G3.
    run(&["build", "g5", "--dir", d, "--artifacts", &a, "--small"]).unwrap();
    run(&["fsck", "--dir", d]).unwrap();
    run(&["log", "--dir", d]).unwrap();
    // show a node
    run(&["show", "g5/base-mlm", "--dir", d]).unwrap();
    assert!(run(&["show", "missing-node", "--dir", d]).is_err());
    // diff two nodes
    run(&["diff", "g5/mtl-task1", "g5/mtl-task2", "--dir", d, "--artifacts", &a]).unwrap();
    // compress everything with deltas
    run(&["compress", "--dir", d, "--artifacts", &a, "--codec", "lzma"]).unwrap();
    run(&["fsck", "--dir", d]).unwrap();
    // cascade on the MLM root
    run(&["cascade", "g5/base-mlm", "--dir", d, "--artifacts", &a, "--steps", "3"]).unwrap();
    run(&["fsck", "--dir", d]).unwrap();
    // the cascade created a @v2 of the root
    let repo = mgit::cli::Repo::open(&dir).unwrap();
    assert!(repo.graph.idx("g5/base-mlm@v2").is_ok());
    std::fs::remove_dir_all(&dir).unwrap();
}
