//! Integration: the `mgit` CLI surface against a temp repository.

use std::path::PathBuf;

fn run(args: &[&str]) -> anyhow::Result<()> {
    mgit::cli::run(args.iter().map(|s| s.to_string()).collect())
}

fn tmp_repo(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("mgit-cli-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

fn artifacts() -> String {
    PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("artifacts")
        .to_string_lossy()
        .into_owned()
}

/// The build/compress/cascade flow trains real models, so it needs the
/// PJRT backend and the AOT artifacts; skip cleanly otherwise.
fn can_train() -> bool {
    if !mgit::runtime::HAS_PJRT {
        eprintln!("skipping: built without the `pjrt` feature");
        return false;
    }
    if !PathBuf::from(artifacts()).join("manifest.json").exists() {
        eprintln!("skipping: no artifacts (run `make artifacts`)");
        return false;
    }
    true
}

#[test]
fn init_log_fsck_stats_gc() {
    let dir = tmp_repo("basic");
    let d = dir.to_str().unwrap();
    run(&["init", "--dir", d]).unwrap();
    // double init fails
    assert!(run(&["init", "--dir", d]).is_err());
    run(&["log", "--dir", d]).unwrap();
    run(&["fsck", "--dir", d]).unwrap();
    run(&["stats", "--dir", d]).unwrap();
    run(&["gc", "--dir", d]).unwrap();
    assert!(run(&["nonsense", "--dir", d]).is_err());
    run(&["help"]).unwrap();
    std::fs::remove_dir_all(&dir).unwrap();
}

/// End-to-end pack flow with no runtime dependency: craft a repo with a
/// 6-deep delta chain through the library, then drive `repack`,
/// `verify-pack`, `stats`, `fsck` and `gc` through the CLI and confirm
/// every model still loads bit-exactly from the pack.
#[test]
fn repack_verify_stats_fsck_flow() {
    use mgit::checkpoint::{Checkpoint, ModelZoo};
    use mgit::delta::{self, CompressConfig, NativeKernel};
    use mgit::util::rng::Rng;

    const MANIFEST: &str = r#"{
      "vocab": 16, "max_seq": 4, "n_classes": 2, "batch": 2,
      "delta_chunk": 1024,
      "special_tokens": {"cls": 14, "mask": 15, "ignore_label": -100},
      "archs": {"t": {
          "d_model": 4, "n_layers": 1, "n_heads": 1, "d_ff": 8,
          "param_count": 4096,
          "layout": [
            {"name":"w.a","shape":[4096],"offset":0,"size":4096,"init":"normal"}
          ],
          "dag": {"nodes": [], "edges": []}
      }},
      "artifacts": {"t": {}},
      "delta_kernels": {"quant": "q", "dequant": "d"}
    }"#;

    let dir = tmp_repo("pack");
    let d = dir.to_str().unwrap();
    run(&["init", "--dir", d]).unwrap();

    let zoo = ModelZoo::from_json(&mgit::util::json::parse(MANIFEST).unwrap()).unwrap();
    let spec = zoo.arch("t").unwrap();
    let mut expected: Vec<(String, Checkpoint)> = Vec::new();
    {
        let mut repo = mgit::cli::Repo::open(&dir).unwrap();
        let root_ck = Checkpoint::init(spec, 1);
        let (sm, _) = delta::store_raw(&repo.store, spec, &root_ck).unwrap();
        let idx = repo.graph.add_node("m/v1", "t").unwrap();
        repo.graph.node_mut(idx).stored = Some(sm.clone());
        expected.push(("m/v1".into(), root_ck.clone()));
        let mut prev = (root_ck, sm);
        let mut prev_idx = idx;
        for v in 0..6u64 {
            let mut rng = Rng::new(v + 10);
            let child = Checkpoint {
                arch: prev.0.arch.clone(),
                flat: prev.0.flat.iter().map(|&x| x + rng.normal_f32(0.0, 3e-4)).collect(),
            };
            let cand = delta::prepare_delta(
                &repo.store,
                spec,
                &child,
                spec,
                &prev.0,
                &prev.1,
                CompressConfig::default(),
                &NativeKernel,
            )
            .unwrap();
            delta::commit(&repo.store, &cand).unwrap();
            let name = format!("m/v{}", v + 2);
            let n = repo.graph.add_node(&name, "t").unwrap();
            repo.graph.node_mut(n).stored = Some(cand.model.clone());
            repo.graph.add_version_edge(prev_idx, n).unwrap();
            expected.push((name, cand.checkpoint.clone()));
            prev = (cand.checkpoint, cand.model);
            prev_idx = n;
        }
        repo.save().unwrap();
    }

    run(&["fsck", "--dir", d]).unwrap();
    run(&["stats", "--dir", d]).unwrap();
    run(&["repack", "--dir", d, "--max-chain-depth", "2"]).unwrap();
    run(&["verify-pack", "--dir", d]).unwrap();
    run(&["fsck", "--dir", d]).unwrap();
    run(&["stats", "--dir", d]).unwrap();
    run(&["gc", "--dir", d]).unwrap();

    // Everything previously readable loose is byte-identically readable
    // via the packed store, and chains respect the cap.
    let repo = mgit::cli::Repo::open(&dir).unwrap();
    let ps = repo.store.as_packed().unwrap();
    assert_eq!(ps.packs().len(), 1);
    let (loose, packed) = ps.counts().unwrap();
    assert_eq!(loose, 0, "loose dir must be demoted to staging");
    assert!(packed >= expected.len());
    for (name, want) in &expected {
        let node = repo.graph.by_name(name).unwrap();
        let loaded =
            delta::load(&repo.store, &zoo, node.stored.as_ref().unwrap(), &NativeKernel)
                .unwrap();
        for (x, y) in loaded.flat.iter().zip(&want.flat) {
            assert_eq!(x.to_bits(), y.to_bits(), "{name} changed across repack");
        }
    }
    let depths = mgit::store::pack::chain_depths(&repo.store).unwrap();
    assert!(depths.values().all(|&dep| dep <= 2));

    // A second repack (now pack-to-pack) with pruning also round-trips.
    run(&["repack", "--dir", d, "--prune"]).unwrap();
    run(&["verify-pack", "--dir", d]).unwrap();
    run(&["fsck", "--dir", d]).unwrap();
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn build_compress_test_cascade_flow() {
    if !can_train() {
        return;
    }
    let dir = tmp_repo("flow");
    let d = dir.to_str().unwrap();
    let a = artifacts();
    run(&["init", "--dir", d]).unwrap();
    // Build a small G5 (fast) and G3.
    run(&["build", "g5", "--dir", d, "--artifacts", &a, "--small"]).unwrap();
    run(&["fsck", "--dir", d]).unwrap();
    run(&["log", "--dir", d]).unwrap();
    // show a node
    run(&["show", "g5/base-mlm", "--dir", d]).unwrap();
    assert!(run(&["show", "missing-node", "--dir", d]).is_err());
    // diff two nodes
    run(&["diff", "g5/mtl-task1", "g5/mtl-task2", "--dir", d, "--artifacts", &a]).unwrap();
    // compress everything with deltas
    run(&["compress", "--dir", d, "--artifacts", &a, "--codec", "lzma"]).unwrap();
    run(&["fsck", "--dir", d]).unwrap();
    // cascade on the MLM root
    run(&["cascade", "g5/base-mlm", "--dir", d, "--artifacts", &a, "--steps", "3"]).unwrap();
    run(&["fsck", "--dir", d]).unwrap();
    // the cascade created a @v2 of the root
    let repo = mgit::cli::Repo::open(&dir).unwrap();
    assert!(repo.graph.idx("g5/base-mlm@v2").is_ok());
    std::fs::remove_dir_all(&dir).unwrap();
}
