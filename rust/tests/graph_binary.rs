//! The binary graph tier (MGGI v1), end to end: the committed
//! `tests/fixtures/graph_v1/graph.bin` fixture must stay readable
//! forever (the pack-v1 fixture contract), a binary repo must be
//! byte-identical to its JSON twin through `log`/`show`, pagination
//! must chain to exactly the full log without materializing the mapped
//! node set, and a torn segment tail must recover its durable prefix
//! and surface in fsck.
//!
//! The fixture was written by `gen_fixture.py` (same directory), which
//! mirrors the v1 byte layout frozen in `rust/src/lineage/binfmt.rs`;
//! `fixture_matches_current_encoder` pins the encoder to those bytes.

use std::path::PathBuf;

use mgit::lineage::binfmt::{self, AdjBlock, MappedGraph};
use mgit::lineage::LineageGraph;
use mgit::ops::{self, Repo, Report};
use mgit::util::json::Json;

fn fixture_path() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("tests/fixtures/graph_v1/graph.bin")
}

fn tmp_root(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("mgit-graphbin-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// The graph the fixture encodes (see gen_fixture.py):
/// base --prov--> a --ver--> a2, base --prov--> b.
fn fixture_graph() -> LineageGraph {
    let mut g = LineageGraph::new();
    let base = g.add_node("base", "tx").unwrap();
    let a = g.add_node("a", "tx").unwrap();
    let a2 = g.add_node("a2", "tx").unwrap();
    let b = g.add_node("b", "tx").unwrap();
    g.nodes[a].metadata = Json::obj().set("note", "hello");
    g.add_edge(base, a).unwrap();
    g.add_edge(base, b).unwrap();
    g.add_version_edge(a, a2).unwrap();
    g
}

/// A deterministic mixed-shape graph: provenance tree + version edges
/// every fourth node, two model types, per-node metadata.
fn sample_graph(n: usize) -> LineageGraph {
    let mut g = LineageGraph::new();
    for i in 0..n {
        let ty = if i % 4 == 0 { "cnn" } else { "tx" };
        let idx = g.add_node(&format!("m{i:04}"), ty).unwrap();
        g.nodes[idx].metadata = Json::obj().set("step", i);
        if i > 0 {
            g.add_edge((i - 1) / 2, idx).unwrap();
        }
        if i % 4 == 2 {
            g.add_version_edge(idx - 1, idx).unwrap();
        }
    }
    g
}

/// Init a repo whose graph is persisted as v0 `graph.json`.
fn json_repo(tag: &str, g: &LineageGraph) -> PathBuf {
    let root = tmp_root(tag);
    Repo::init(&root).unwrap();
    g.save(&Repo::graph_path(&root)).unwrap();
    root
}

/// Init a repo whose graph is persisted as a binary `graph.bin`
/// (authoritative over the empty `graph.json` that init wrote).
fn bin_repo(tag: &str, g: &LineageGraph) -> PathBuf {
    let root = tmp_root(tag);
    Repo::init(&root).unwrap();
    binfmt::write_binary(g, &Repo::graph_bin_path(&root)).unwrap();
    root
}

// ---------------------------------------------------------------------------
// Committed fixture: forever-readability + encoder stability
// ---------------------------------------------------------------------------

#[test]
fn fixture_v1_is_forever_readable() {
    let m = MappedGraph::open(&fixture_path()).unwrap();
    assert_eq!(m.node_count(), 4);
    assert_eq!(m.edge_counts(), (2, 1));
    assert_eq!(m.tail_ops.len(), 1, "fixture carries one tail commit");
    assert!(m.tail_torn.is_none());

    // Lazy reads against the frozen bytes.
    assert_eq!(m.idx("base").unwrap(), Some(0));
    assert_eq!(m.idx("a").unwrap(), Some(1));
    assert_eq!(m.idx("b").unwrap(), Some(3));
    assert_eq!(m.idx("missing").unwrap(), None);
    assert_eq!(m.name_of(2).unwrap(), "a2");
    assert_eq!(m.adjacency(AdjBlock::ProvChildren, 0).unwrap(), vec![1, 3]);
    assert_eq!(m.adjacency(AdjBlock::VerParents, 2).unwrap(), vec![1]);
    assert_eq!(
        m.body(1).unwrap().get("metadata").unwrap().to_string_compact(),
        r#"{"note":"hello"}"#
    );

    // Materialization applies the tail commit (node `c`, child of `b`).
    let g = m.materialize().unwrap();
    assert_eq!(g.len(), 5);
    let c = g.by_name("c").unwrap();
    assert_eq!(c.prov_parents, vec![3]);
    g.integrity_check().unwrap();
}

#[test]
fn fixture_matches_current_encoder() {
    let encoded = binfmt::encode(&fixture_graph()).unwrap();
    let committed = std::fs::read(fixture_path()).unwrap();
    let base = MappedGraph::open(&fixture_path()).unwrap().base_len() as usize;
    assert_eq!(
        encoded,
        &committed[..base],
        "encoder output drifted from the committed v1 fixture — that is a \
         format break; bump GRAPH_VERSION instead of changing v1"
    );
}

#[test]
fn fixture_repo_opens_with_tail_applied() {
    let root = tmp_root("fixture-open");
    Repo::init(&root).unwrap();
    std::fs::copy(fixture_path(), Repo::graph_bin_path(&root)).unwrap();
    let repo = Repo::open(&root).unwrap();
    assert_eq!(repo.graph.format(), "binary");
    // A non-empty tail is folded into the session image at open.
    assert_eq!(repo.graph.len(), 5);
    assert_eq!(repo.graph.node_by_name("c").unwrap().prov_parents, vec![3]);
    let _ = std::fs::remove_dir_all(&root);
}

// ---------------------------------------------------------------------------
// JSON <-> binary output parity
// ---------------------------------------------------------------------------

#[test]
fn json_and_binary_reports_are_byte_identical() {
    let g = sample_graph(40);
    let jroot = json_repo("parity-json", &g);
    let broot = bin_repo("parity-bin", &g);
    let jrepo = Repo::open(&jroot).unwrap();
    let brepo = Repo::open(&broot).unwrap();
    assert_eq!(jrepo.graph.format(), "json");
    assert_eq!(brepo.graph.format(), "binary");

    // Lazy-path reports first: paged log + show decode only the visited
    // nodes and must leave the mapped graph unmaterialized.
    let page = ops::LogPageRequest {
        limit: 7,
        after: Some("m0012".to_string()),
        model_type: None,
    };
    let (jp, bp) = (page.run(&jrepo).unwrap(), page.run(&brepo).unwrap());
    assert_eq!(
        jp.to_json().to_string_compact(),
        bp.to_json().to_string_compact()
    );
    assert_eq!(jp.to_string(), bp.to_string());

    let show = ops::ShowRequest { node: "m0017".to_string() };
    let (js, bs) = (show.run(&jrepo).unwrap(), show.run(&brepo).unwrap());
    assert_eq!(
        js.to_json().to_string_compact(),
        bs.to_json().to_string_compact()
    );
    assert_eq!(js.to_string(), bs.to_string());
    assert!(
        !brepo.graph.is_materialized(),
        "paged log + show must not materialize the mapped graph"
    );

    // Full log (whole-graph path, materializes via auto-deref).
    let (jl, bl) = (
        ops::LogRequest.run(&jrepo).unwrap(),
        ops::LogRequest.run(&brepo).unwrap(),
    );
    assert_eq!(
        jl.to_json().to_string_compact(),
        bl.to_json().to_string_compact()
    );
    assert_eq!(jl.to_string(), bl.to_string());
    assert!(brepo.graph.is_materialized());

    let _ = std::fs::remove_dir_all(&jroot);
    let _ = std::fs::remove_dir_all(&broot);
}

#[test]
fn paged_log_chains_to_exactly_the_full_log() {
    let g = sample_graph(40);
    let root = bin_repo("paging", &g);
    let repo = Repo::open(&root).unwrap();

    let chain = |model_type: Option<&str>| {
        let mut names = Vec::new();
        let mut after: Option<String> = None;
        let mut pages = 0;
        loop {
            let req = ops::LogPageRequest {
                limit: 7,
                after: after.clone(),
                model_type: model_type.map(String::from),
            };
            let page = req.run(&repo).unwrap();
            assert_eq!(page.total, 40, "total is unfiltered");
            assert!(page.nodes.len() <= 7);
            names.extend(page.nodes.iter().map(|n| n.name.clone()));
            pages += 1;
            match page.next_after {
                Some(cursor) => after = Some(cursor),
                None => break,
            }
        }
        (names, pages)
    };

    let (all, pages) = chain(None);
    let want: Vec<String> = (0..40).map(|i| format!("m{i:04}")).collect();
    assert_eq!(all, want);
    assert_eq!(pages, 40usize.div_ceil(7));

    let (cnn, _) = chain(Some("cnn"));
    let want_cnn: Vec<String> = (0..40)
        .filter(|i| i % 4 == 0)
        .map(|i| format!("m{i:04}"))
        .collect();
    assert_eq!(cnn, want_cnn);

    // Pagination never needs the full node set.
    assert!(!repo.graph.is_materialized());

    // A bogus cursor is an error, not an empty page.
    let bad = ops::LogPageRequest {
        limit: 7,
        after: Some("no-such-node".to_string()),
        model_type: None,
    };
    assert!(bad.run(&repo).is_err());

    let _ = std::fs::remove_dir_all(&root);
}

// ---------------------------------------------------------------------------
// Torn tail: durable prefix + fsck + compaction
// ---------------------------------------------------------------------------

#[test]
fn torn_tail_recovers_prefix_and_surfaces_in_fsck() {
    let root = tmp_root("torn");
    Repo::init(&root).unwrap();
    std::fs::copy(fixture_path(), Repo::graph_bin_path(&root)).unwrap();
    // Crash mid-append: a record header with no body after the valid
    // tail record.
    let bin = Repo::graph_bin_path(&root);
    let mut bytes = std::fs::read(&bin).unwrap();
    bytes.extend_from_slice(&[9, 0, 0, 0, 0xde, 0xad]);
    std::fs::write(&bin, &bytes).unwrap();

    // The durable prefix (base + 1 valid tail commit) still serves.
    let repo = Repo::open(&root).unwrap();
    assert_eq!(repo.graph.len(), 5);
    let (offset, _) = repo.graph.tail_status().expect("torn tail must be reported");
    assert_eq!(offset as usize, bytes.len() - 6);

    // fsck names it.
    let fsck = ops::FsckRequest.run(&repo).unwrap();
    assert!(
        fsck.problems.iter().any(|p| p.kind == "TORN_GRAPH_TAIL"),
        "{:?}",
        fsck.problems.iter().map(|p| p.kind).collect::<Vec<_>>()
    );

    // Persisting compacts: tail folded into the base image, torn bytes
    // discarded, fsck clean again.
    repo.save().unwrap();
    let m = MappedGraph::open(&bin).unwrap();
    assert_eq!(m.node_count(), 5);
    assert!(m.tail_ops.is_empty() && m.tail_torn.is_none());
    assert_eq!(m.base_len(), m.file_len());
    let repo = Repo::open(&root).unwrap();
    assert!(repo.graph.tail_status().is_none());
    assert!(!ops::FsckRequest.run(&repo).unwrap().problems.iter().any(|p| p.kind
        == "TORN_GRAPH_TAIL"));

    let _ = std::fs::remove_dir_all(&root);
}

// ---------------------------------------------------------------------------
// v0 repos are untouched
// ---------------------------------------------------------------------------

#[test]
fn v0_json_repo_stays_json() {
    let g = sample_graph(12);
    let root = json_repo("v0", &g);
    let repo = Repo::open(&root).unwrap();
    assert_eq!(repo.graph.format(), "json");
    assert_eq!(repo.graph.len(), 12);
    repo.save().unwrap();
    assert!(
        !Repo::graph_bin_path(&root).exists(),
        "a v0 repo must never grow a graph.bin behind the user's back"
    );
    assert!(Repo::graph_path(&root).exists());
    let _ = std::fs::remove_dir_all(&root);
}

// ---------------------------------------------------------------------------
// synth-graph: the scale harness entry point
// ---------------------------------------------------------------------------

#[test]
fn synth_graph_builds_openable_repos() {
    for (shape, format) in [("chain", "bin"), ("tree", "json"), ("mtl", "bin")] {
        let root = tmp_root(&format!("synth-{shape}-{format}"));
        let report = ops::SynthGraphRequest {
            nodes: 300,
            shape: shape.to_string(),
            format: format.to_string(),
        }
        .run(&root)
        .unwrap();
        assert_eq!(report.nodes, 300);
        let repo = Repo::open(&root).unwrap();
        assert_eq!(repo.graph.len(), 300);
        assert_eq!(
            repo.graph.format(),
            if format == "bin" { "binary" } else { "json" }
        );
        assert_eq!(
            repo.graph.edge_counts(),
            (report.prov_edges, report.ver_edges)
        );
        repo.graph.integrity_check().unwrap();
        let _ = std::fs::remove_dir_all(&root);
    }
}
