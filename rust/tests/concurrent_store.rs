//! Concurrency stress: N reader threads cold-reconstruct delta chains
//! from one shared `PackedStore` while a writer stages loose objects.
//! Readers must see bit-exact tensors throughout, nothing may deadlock,
//! and an incremental repack afterwards must absorb the writer's objects
//! without disturbing the sealed pack.

use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::atomic::{AtomicUsize, Ordering};

use mgit::delta::{self, Codec, DeltaKernel, NativeKernel, ResolveCache};
use mgit::store::format::TensorObject;
use mgit::store::pack::{repack, RepackConfig, RepackMode};
use mgit::store::{hash_tensor, ObjectId, Store};
use mgit::tensor::{f32_to_bytes, i32_to_bytes, DType};
use mgit::util::rng::Rng;

fn tmp_dir(tag: &str) -> PathBuf {
    let dir =
        std::env::temp_dir().join(format!("mgit-conc-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// Build a delta chain of `n` links over a raw base (real quantized
/// deltas, so chains resolve through the kernel). Returns ids base-first.
fn build_chain(store: &Store, n: usize, seed: u64, len: usize) -> Vec<ObjectId> {
    let mut rng = Rng::new(seed);
    let eps = 1e-4f32;
    let codec = Codec::Deflate;
    let base: Vec<f32> = (0..len).map(|_| rng.normal_f32(0.0, 1.0)).collect();
    let base_payload = f32_to_bytes(&base);
    let base_id = hash_tensor(DType::F32, &[len], &base_payload);
    store
        .put(
            base_id,
            &TensorObject::Raw { dtype: DType::F32, shape: vec![len], payload: base_payload }
                .encode(),
        )
        .unwrap();
    let mut ids = vec![base_id];
    let mut prev = base;
    let mut prev_id = base_id;
    for _ in 0..n {
        let child: Vec<f32> = prev.iter().map(|&p| p + rng.normal_f32(0.0, 3e-4)).collect();
        let q = NativeKernel.quantize(&prev, &child, eps).unwrap();
        let rec = NativeKernel.dequantize(&prev, &q, eps).unwrap();
        let payload = f32_to_bytes(&rec);
        let id = hash_tensor(DType::F32, &[len], &payload);
        let obj = TensorObject::Delta {
            dtype: DType::F32,
            shape: vec![len],
            parent: prev_id,
            eps,
            codec: codec.code(),
            n_quant: len,
            grid: false,
            payload: codec.compress(&i32_to_bytes(&q)).unwrap(),
        };
        store.put(id, &obj.encode()).unwrap();
        ids.push(id);
        prev = rec;
        prev_id = id;
    }
    ids
}

#[test]
fn concurrent_readers_with_live_writer() {
    const N_CHAINS: usize = 4;
    const CHAIN_LEN: usize = 6;
    const N_READERS: usize = 4;
    const ROUNDS: usize = 8;

    let dir = tmp_dir("rw");
    let mut store = Store::open_packed(&dir).unwrap();

    // Seal N delta chains into one pack.
    let chains: Vec<Vec<ObjectId>> = (0..N_CHAINS)
        .map(|i| build_chain(&store, CHAIN_LEN, 100 + i as u64, 256))
        .collect();
    let tips: Vec<ObjectId> = chains.iter().map(|c| *c.last().unwrap()).collect();
    let cfg = RepackConfig {
        max_chain_depth: 8,
        prune: false,
        mode: RepackMode::Full,
        ..RepackConfig::default()
    };
    let report = repack(&mut store, &tips, &cfg, &NativeKernel).unwrap();
    assert!(report.pack_path.is_some());

    // Reference values for every chain link, resolved single-threaded.
    let reference: Vec<Vec<Vec<f32>>> = chains
        .iter()
        .map(|chain| {
            let mut cache = HashMap::new();
            chain
                .iter()
                .map(|id| {
                    delta::resolve_tensor(&store, *id, &NativeKernel, &mut cache, 0)
                        .unwrap()
                })
                .collect()
        })
        .collect();

    // Readers hammer cold chain reconstruction (fresh local cache per
    // round, plus a shared bounded cache) while the writer stages new
    // loose objects into the same store.
    let shared_cache = ResolveCache::new(64);
    let mismatch_count = AtomicUsize::new(0);
    let writer_ids: Vec<ObjectId> = std::thread::scope(|s| {
        let mismatches = &mismatch_count;
        for r in 0..N_READERS {
            let (store, chains, reference, shared_cache) =
                (&store, &chains, &reference, &shared_cache);
            s.spawn(move || {
                for round in 0..ROUNDS {
                    let (ci, li) = ((r + round) % N_CHAINS, round % (CHAIN_LEN + 1));
                    let id = chains[ci][li];
                    // Cold walk: nothing memoized between iterations.
                    let mut local = HashMap::new();
                    let cold =
                        delta::resolve_tensor(store, id, &NativeKernel, &mut local, 0)
                            .unwrap();
                    // Shared-cache walk: memoized across threads.
                    let shared = delta::resolve_tensor_shared(
                        store,
                        id,
                        &NativeKernel,
                        shared_cache,
                        0,
                    )
                    .unwrap();
                    let want = &reference[ci][li];
                    let exact = cold.len() == want.len()
                        && shared.len() == want.len()
                        && cold
                            .iter()
                            .zip(shared.iter())
                            .zip(want)
                            .all(|((a, b), w)| {
                                a.to_bits() == w.to_bits() && b.to_bits() == w.to_bits()
                            });
                    if !exact {
                        mismatches.fetch_add(1, Ordering::Relaxed);
                    }
                }
            });
        }
        // Writer: stage fresh loose objects (new raw tensors) while the
        // readers run. `put` is loose + atomic, so readers never observe
        // partial objects.
        let writer = s.spawn(|| {
            let mut rng = Rng::new(999);
            let mut ids = Vec::new();
            for _ in 0..32 {
                let vals: Vec<f32> = (0..64).map(|_| rng.normal_f32(0.0, 1.0)).collect();
                let payload = f32_to_bytes(&vals);
                let id = hash_tensor(DType::F32, &[64], &payload);
                store
                    .put(
                        id,
                        &TensorObject::Raw {
                            dtype: DType::F32,
                            shape: vec![64],
                            payload,
                        }
                        .encode(),
                    )
                    .unwrap();
                ids.push(id);
            }
            ids
        });
        writer.join().unwrap()
    });
    // All readers have joined here (scope exit); check their verdict.
    assert_eq!(
        mismatch_count.load(Ordering::Relaxed),
        0,
        "concurrent readers saw non-bit-exact tensors"
    );

    // Writer's objects all landed and are readable.
    for id in &writer_ids {
        assert!(store.has(id));
        store.get(id).unwrap();
    }
    let (hits, misses) = shared_cache.counters();
    assert!(hits + misses > 0);

    // Incremental repack absorbs the staged objects as a new generation
    // without touching the sealed pack.
    let first_pack = report.pack_path.clone().unwrap();
    let mut roots = tips.clone();
    roots.extend(writer_ids.iter().copied());
    let inc = RepackConfig {
        max_chain_depth: 8,
        prune: false,
        mode: RepackMode::Incremental,
        ..RepackConfig::default()
    };
    let r2 = repack(&mut store, &roots, &inc, &NativeKernel).unwrap();
    assert_eq!(r2.packed, writer_ids.len());
    assert!(first_pack.exists());
    assert_eq!(r2.packs_after, 2);

    // Every chain still resolves bit-exactly from the multi-pack store.
    let store2 = Store::open_packed(&dir).unwrap();
    for (chain, want_chain) in chains.iter().zip(&reference) {
        let mut cache = HashMap::new();
        for (id, want) in chain.iter().zip(want_chain) {
            let got =
                delta::resolve_tensor(&store2, *id, &NativeKernel, &mut cache, 0).unwrap();
            assert_eq!(got.len(), want.len());
            for (a, b) in got.iter().zip(want) {
                assert_eq!(a.to_bits(), b.to_bits());
            }
        }
    }
    std::fs::remove_dir_all(&dir).unwrap();
}
