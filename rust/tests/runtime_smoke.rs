//! Integration: the PJRT runtime executes the AOT artifacts correctly —
//! training reduces loss and improves accuracy, evaluation is
//! deterministic, and the compiled Pallas delta kernels agree with the
//! native oracle. Requires `make artifacts`.

use std::path::PathBuf;

use mgit::checkpoint::Checkpoint;
use mgit::data;
use mgit::delta::quant::{DeltaKernel, NativeKernel};
use mgit::registry::Objective;
use mgit::runtime::Runtime;
use mgit::util::rng::Rng;

fn artifacts_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

/// `None` (skip) when the AOT artifacts are absent or this build lacks
/// the PJRT backend — both are expected in the offline build; the tests
/// below exercise real HLO execution and need `make artifacts` plus
/// `--features pjrt`.
fn runtime() -> Option<Runtime> {
    if !mgit::runtime::HAS_PJRT {
        eprintln!("skipping: built without the `pjrt` feature");
        return None;
    }
    if !artifacts_dir().join("manifest.json").exists() {
        eprintln!("skipping: no artifacts (run `make artifacts`)");
        return None;
    }
    Some(Runtime::new(&artifacts_dir()).expect("runtime init failed"))
}

#[test]
fn training_reduces_loss_and_learns() {
    let Some(rt) = runtime() else { return };
    let spec = rt.zoo().arch("tx-tiny").unwrap();
    let ck = Checkpoint::init(spec, 7);
    let mut params = ck.flat.clone();
    let mut mom = vec![0f32; params.len()];

    let (_, acc_before) = rt
        .eval_many("tx-tiny", Objective::Cls, &params, "task4", 0, 4)
        .unwrap();

    let mut first_losses = 0.0;
    let mut last_losses = 0.0;
    let steps = 80;
    for step in 0..steps {
        let batch =
            data::cls_batch("task4", rt.zoo().batch, rt.zoo().max_seq, 0, step as u64, None)
                .unwrap();
        let loss = rt
            .train_step("tx-tiny", Objective::Cls, &mut params, &mut mom, &batch, 0.02)
            .unwrap();
        assert!(loss.is_finite(), "loss diverged at step {step}");
        if step < 10 {
            first_losses += loss;
        }
        if step >= steps - 10 {
            last_losses += loss;
        }
    }
    assert!(
        last_losses < first_losses,
        "loss did not decrease: first {first_losses}, last {last_losses}"
    );

    let (_, acc_after) = rt
        .eval_many("tx-tiny", Objective::Cls, &params, "task4", 0, 4)
        .unwrap();
    assert!(
        acc_after > acc_before + 0.1,
        "no learning: before {acc_before}, after {acc_after}"
    );
}

#[test]
fn mlm_objective_trains() {
    let Some(rt) = runtime() else { return };
    let spec = rt.zoo().arch("tx-tiny").unwrap();
    let mut params = Checkpoint::init(spec, 3).flat;
    let mut mom = vec![0f32; params.len()];
    let mut losses = Vec::new();
    for step in 0..120 {
        let batch =
            data::mlm_batch(1, rt.zoo().batch, rt.zoo().max_seq, step as u64, None).unwrap();
        let loss = rt
            .train_step("tx-tiny", Objective::Mlm, &mut params, &mut mom, &batch, 0.05)
            .unwrap();
        losses.push(loss);
    }
    assert!(losses.last().unwrap() < losses.first().unwrap());
    // MLM accuracy above the ~1/254 chance level after a few steps.
    let (_, acc) = rt
        .eval_many("tx-tiny", Objective::Mlm, &params, "corpus", 1, 2)
        .unwrap();
    assert!(acc > 0.008, "mlm acc {acc}"); // ≥2× the 1/254 chance level
}

#[test]
fn eval_is_deterministic() {
    let Some(rt) = runtime() else { return };
    let spec = rt.zoo().arch("tx-tiny").unwrap();
    let params = Checkpoint::init(spec, 5).flat;
    let a = rt.eval_many("tx-tiny", Objective::Cls, &params, "task1", 9, 3).unwrap();
    let b = rt.eval_many("tx-tiny", Objective::Cls, &params, "task1", 9, 3).unwrap();
    assert_eq!(a, b);
}

#[test]
fn pjrt_delta_kernels_match_native_oracle() {
    let Some(rt) = runtime() else { return };
    let mut rng = Rng::new(11);
    // Cover: shorter than one chunk, exact chunk, chunk + tail.
    let chunk = rt.zoo().delta_chunk;
    for n in [1000usize, chunk, chunk + 1234] {
        let parent: Vec<f32> = (0..n).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let child: Vec<f32> =
            parent.iter().map(|&p| p + rng.normal_f32(0.0, 3e-4)).collect();
        let eps = 1e-4f32;
        let q_pjrt = rt.quantize(&parent, &child, eps).unwrap();
        let q_native = NativeKernel.quantize(&parent, &child, eps).unwrap();
        let same = q_pjrt
            .iter()
            .zip(&q_native)
            .filter(|(a, b)| a == b)
            .count();
        // f32 rounding at bucket boundaries may differ on a few elements.
        assert!(
            same as f64 / n as f64 > 0.999,
            "n={n}: only {same}/{n} quantized values agree"
        );
        let d_pjrt = rt.dequantize(&parent, &q_pjrt, eps).unwrap();
        let d_native = NativeKernel.dequantize(&parent, &q_pjrt, eps).unwrap();
        for (a, b) in d_pjrt.iter().zip(&d_native) {
            assert!((a - b).abs() < 1e-6);
        }
        // Error bound vs the original child.
        let bound = mgit::runtime::quant_step(eps) * 1.001;
        for (r, c) in d_pjrt.iter().zip(&child) {
            assert!((r - c).abs() <= bound, "bound violated: {}", (r - c).abs());
        }
    }
}

#[test]
fn batch_shape_validation() {
    let Some(rt) = runtime() else { return };
    let spec = rt.zoo().arch("tx-tiny").unwrap();
    let mut params = Checkpoint::init(spec, 0).flat;
    let mut mom = vec![0f32; params.len()];
    let bad = data::Batch { tokens: vec![0; 8], labels: vec![0; 2], batch: 2, seq: 4 };
    assert!(rt
        .train_step("tx-tiny", Objective::Cls, &mut params, &mut mom, &bad, 0.1)
        .is_err());
    // Wrong param length.
    let mut short = vec![0f32; 10];
    let mut short_m = vec![0f32; 10];
    let good =
        data::cls_batch("task1", rt.zoo().batch, rt.zoo().max_seq, 0, 0, None).unwrap();
    assert!(rt
        .train_step("tx-tiny", Objective::Cls, &mut short, &mut short_m, &good, 0.1)
        .is_err());
}
