//! Golden tests for the typed operations API: `--json` reports must be
//! byte-stable across runs, carry the documented fields, and map
//! problems to nonzero CLI exits.

use std::path::{Path, PathBuf};

use mgit::checkpoint::{Checkpoint, ModelZoo};
use mgit::delta::{self, CompressConfig, NativeKernel};
use mgit::ops::{self, Report};
use mgit::util::rng::Rng;

const MANIFEST: &str = r#"{
  "vocab": 16, "max_seq": 4, "n_classes": 2, "batch": 2,
  "delta_chunk": 1024,
  "special_tokens": {"cls": 14, "mask": 15, "ignore_label": -100},
  "archs": {"t": {
      "d_model": 4, "n_layers": 1, "n_heads": 1, "d_ff": 8,
      "param_count": 4096,
      "layout": [
        {"name":"w.a","shape":[4096],"offset":0,"size":4096,"init":"normal"}
      ],
      "dag": {"nodes": [], "edges": []}
  }},
  "artifacts": {"t": {}},
  "delta_kernels": {"quant": "q", "dequant": "d"}
}"#;

fn tmp_repo(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("mgit-ops-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

fn zoo() -> ModelZoo {
    ModelZoo::from_json(&mgit::util::json::parse(MANIFEST).unwrap()).unwrap()
}

/// Build `m/v1 … m/v{versions}` as a delta chain through the library.
fn build_chain(dir: &Path, zoo: &ModelZoo, versions: usize) {
    let spec = zoo.arch("t").unwrap();
    let mut repo = ops::Repo::open(dir).unwrap();
    let root_ck = Checkpoint::init(spec, 1);
    let (sm, _) = delta::store_raw(&repo.store, spec, &root_ck).unwrap();
    let idx = repo.graph.add_node("m/v1", "t").unwrap();
    repo.graph.node_mut(idx).stored = Some(sm.clone());
    let mut prev = (root_ck, sm);
    let mut prev_idx = idx;
    for v in 1..versions as u64 {
        let mut rng = Rng::new(v + 10);
        let child = Checkpoint {
            arch: prev.0.arch.clone(),
            flat: prev.0.flat.iter().map(|&x| x + rng.normal_f32(0.0, 3e-4)).collect(),
        };
        let cand = delta::prepare_delta(
            &repo.store,
            spec,
            &child,
            spec,
            &prev.0,
            &prev.1,
            CompressConfig::default(),
            &NativeKernel,
        )
        .unwrap();
        delta::commit(&repo.store, &cand).unwrap();
        let name = format!("m/v{}", v + 1);
        let n = repo.graph.add_node(&name, "t").unwrap();
        repo.graph.node_mut(n).stored = Some(cand.model.clone());
        repo.graph.add_version_edge(prev_idx, n).unwrap();
        prev = (cand.checkpoint, cand.model);
        prev_idx = n;
    }
    repo.save().unwrap();
}

fn cli(args: &[&str]) -> anyhow::Result<()> {
    mgit::cli::run(args.iter().map(|s| s.to_string()).collect())
}

#[test]
fn log_stats_fsck_json_byte_stable() {
    let dir = tmp_repo("golden");
    let z = zoo();
    ops::Repo::init(&dir).unwrap();
    build_chain(&dir, &z, 5);
    // Repack so stats exercises the pack-generation listing too.
    ops::RepackRequest::default().run(&mut ops::Repo::open(&dir).unwrap()).unwrap();

    let snapshot = |what: &str| -> String {
        let repo = ops::Repo::open(&dir).unwrap();
        match what {
            "log" => ops::LogRequest.run(&repo).unwrap().to_json().to_string_pretty(),
            "stats" => ops::StatsRequest.run(&repo).unwrap().to_json().to_string_pretty(),
            "fsck" => ops::FsckRequest.run(&repo).unwrap().to_json().to_string_pretty(),
            _ => unreachable!(),
        }
    };
    for what in ["log", "stats", "fsck"] {
        let a = snapshot(what);
        let b = snapshot(what);
        assert_eq!(a, b, "{what} --json must be byte-stable across runs");
    }

    // Golden structure: the documented fields are present and sane.
    let log = mgit::util::json::parse(&snapshot("log")).unwrap();
    assert_eq!(log.req_arr("nodes").unwrap().len(), 5);
    assert_eq!(log.req_usize("ver_edges").unwrap(), 4);
    assert_eq!(log.req_usize("prov_edges").unwrap(), 0);
    let first = &log.req_arr("nodes").unwrap()[0];
    assert_eq!(first.req_str("name").unwrap(), "m/v1");
    assert_eq!(first.get("stored").unwrap().as_bool(), Some(true));

    let stats = mgit::util::json::parse(&snapshot("stats")).unwrap();
    assert_eq!(stats.req_usize("objects").unwrap(), 5);
    assert!(stats.req_usize("delta_objects").unwrap() >= 1);
    assert!(!stats.req_arr("packs").unwrap().is_empty());
    assert!(stats.req_f64("compression_ratio").unwrap() > 0.0);
    // v2 pack metadata surfaces per generation: format version, outer
    // framing, and the index-recorded max chain depth.
    let gen0 = &stats.req_arr("packs").unwrap()[0];
    assert_eq!(gen0.req_usize("version").unwrap(), 2);
    assert_eq!(gen0.req_str("framing").unwrap(), "raw");
    assert!(gen0.req_usize("max_depth").unwrap() >= 1);

    let fsck = mgit::util::json::parse(&snapshot("fsck")).unwrap();
    assert_eq!(fsck.get("ok").unwrap().as_bool(), Some(true));
    assert!(fsck.req_arr("problems").unwrap().is_empty());
    assert_eq!(fsck.req_usize("nodes").unwrap(), 5);

    std::fs::remove_dir_all(&dir).unwrap();
}

/// Golden shape of the paged log: `{nodes, total, next_after}` is the
/// documented `/log?limit` response (docs/API.md) and must not drift.
#[test]
fn log_page_json_golden() {
    let dir = tmp_repo("logpage");
    let z = zoo();
    ops::Repo::init(&dir).unwrap();
    build_chain(&dir, &z, 5);
    let repo = ops::Repo::open(&dir).unwrap();

    let page = ops::LogPageRequest { limit: 2, after: None, model_type: None }
        .run(&repo)
        .unwrap();
    let j = page.to_json();
    assert_eq!(j.req_arr("nodes").unwrap().len(), 2);
    assert_eq!(j.req_usize("total").unwrap(), 5);
    assert_eq!(j.get("next_after").unwrap().as_str(), Some("m/v2"));

    // Resuming after the cursor continues exactly where the page ended;
    // the final page carries a null cursor.
    let last = ops::LogPageRequest {
        limit: 10,
        after: Some("m/v2".into()),
        model_type: None,
    }
    .run(&repo)
    .unwrap();
    assert_eq!(last.nodes.len(), 3);
    assert_eq!(last.nodes[0].name, "m/v3");
    assert!(matches!(
        last.to_json().get("next_after"),
        Some(mgit::util::json::Json::Null)
    ));

    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn diff_json_byte_stable() {
    let dir = tmp_repo("diff");
    let z = zoo();
    ops::Repo::init(&dir).unwrap();
    build_chain(&dir, &z, 3);
    let req = ops::DiffRequest { a: "m/v1".into(), b: "m/v2".into() };
    let run = || {
        let repo = ops::Repo::open(&dir).unwrap();
        req.run(&repo, &z, &NativeKernel).unwrap().to_json().to_string_pretty()
    };
    let (a, b) = (run(), run());
    assert_eq!(a, b, "diff --json must be byte-stable across runs");
    let j = mgit::util::json::parse(&a).unwrap();
    assert_eq!(j.req_str("a").unwrap(), "m/v1");
    assert!(j.req_f64("value_distance").unwrap() >= 0.0);
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn show_json_full_ids() {
    let dir = tmp_repo("show");
    let z = zoo();
    ops::Repo::init(&dir).unwrap();
    build_chain(&dir, &z, 2);
    let repo = ops::Repo::open(&dir).unwrap();
    let report = ops::ShowRequest { node: "m/v2".into() }.run(&repo).unwrap();
    assert_eq!(report.name, "m/v2");
    assert_eq!(report.params.len(), 1);
    assert_eq!(report.params[0].1.len(), 64, "JSON carries full content ids");
    assert!(ops::ShowRequest { node: "nope".into() }.run(&repo).is_err());
    std::fs::remove_dir_all(&dir).unwrap();
}

/// Satellite: fsck with corruption must exit nonzero from the CLI (with
/// and without `--json`), and the typed report must carry the problems.
#[test]
fn fsck_corruption_exits_nonzero() {
    let dir = tmp_repo("fsck-exit");
    let d = dir.to_str().unwrap();
    let z = zoo();
    ops::Repo::init(&dir).unwrap();
    build_chain(&dir, &z, 3);

    // Destroy the chain base: the loose object file of m/v1's parameter.
    let repo = ops::Repo::open(&dir).unwrap();
    let id = repo.graph.by_name("m/v1").unwrap().stored.as_ref().unwrap().params[0].1;
    let hex = id.hex();
    let path = dir.join(".mgit/objects").join(&hex[..2]).join(&hex[2..]);
    std::fs::remove_file(&path).unwrap();

    let report = ops::FsckRequest.run(&ops::Repo::open(&dir).unwrap()).unwrap();
    assert!(!report.problems.is_empty());
    assert!(report.failure().unwrap().contains("fsck problems"));
    assert!(report.problems.iter().any(|p| p.kind == "MISSING"));
    assert!(report.problems.iter().any(|p| p.kind == "DANGLING"));

    assert!(cli(&["fsck", "--dir", d]).is_err());
    assert!(cli(&["fsck", "--dir", d, "--json"]).is_err());
    std::fs::remove_dir_all(&dir).unwrap();
}

/// Satellite: a corrupt `stats.json` is preserved as `stats.json.corrupt`
/// instead of being silently reset.
#[test]
fn corrupt_stats_preserved() {
    let dir = tmp_repo("stats-corrupt");
    let z = zoo();
    ops::Repo::init(&dir).unwrap();
    build_chain(&dir, &z, 2);
    let stats_path = dir.join(".mgit/stats.json");
    assert!(stats_path.exists(), "build must have persisted counters");
    std::fs::write(&stats_path, "{not json").unwrap();

    assert_eq!(ops::Repo::load_stats(&dir), (0, 0, 0));
    assert!(
        dir.join(".mgit/stats.json.corrupt").exists(),
        "corrupt stats must be preserved for inspection"
    );
    assert!(!stats_path.exists(), "the corrupt file was moved aside");
    // A fresh load is a clean zero (no file), and stats still runs.
    assert_eq!(ops::Repo::load_stats(&dir), (0, 0, 0));
    ops::StatsRequest.run(&ops::Repo::open(&dir).unwrap()).unwrap();
    std::fs::remove_dir_all(&dir).unwrap();
}

/// Satellite: failing tests / bad packs surface through `Report::failure`
/// so the CLI exits nonzero while still emitting the full typed report.
#[test]
fn report_failure_contracts() {
    let passing = ops::TestReport { results: vec![], ran: 3, failed: 0 };
    assert!(passing.failure().is_none());
    let failing = ops::TestReport { results: vec![], ran: 3, failed: 2 };
    assert_eq!(failing.failure().unwrap(), "2 test failures");

    let bad_pack = ops::VerifyPackReport {
        packs: vec![ops::PackCheck {
            path: "p.pack".into(),
            objects: 1,
            version: 2,
            framing: "raw",
            structure_ok: false,
            error: Some("checksum mismatch".into()),
        }],
        object_problems: vec![],
        total_objects: 0,
        checked: 0,
        opaque: 0,
    };
    assert!(bad_pack.failure().unwrap().contains("1 problems"));
    // JSON still renders the failing state.
    let j = bad_pack.to_json();
    assert_eq!(j.get("ok").unwrap().as_bool(), Some(false));
}

/// Golden shape of `ServeReport` with the write-tier fields: the JSON
/// carries `writable`/`commits`/`snapshot_swaps`, is byte-stable, and
/// the human rendering mentions the write tier only when it was on.
#[test]
fn serve_report_write_fields_golden() {
    let ro = ops::serve::ServeReport {
        requests: 7,
        errors: 1,
        pool: 2,
        writable: false,
        commits: 0,
        snapshot_swaps: 0,
    };
    assert_eq!(
        ro.to_json().to_string_compact(),
        r#"{"requests":7,"errors":1,"pool":2,"writable":false,"commits":0,"snapshot_swaps":0}"#
    );
    let text = format!("{ro}");
    assert!(text.contains("7 requests"), "got {text}");
    assert!(!text.contains("writable"), "read-only report must not mention writes: {text}");

    let rw = ops::serve::ServeReport {
        requests: 120,
        errors: 0,
        pool: 8,
        writable: true,
        commits: 101,
        snapshot_swaps: 102,
    };
    assert_eq!(
        rw.to_json().to_string_compact(),
        r#"{"requests":120,"errors":0,"pool":8,"writable":true,"commits":101,"snapshot_swaps":102}"#
    );
    assert_eq!(rw.to_json().to_string_compact(), rw.to_json().to_string_compact());
    let text = format!("{rw}");
    assert!(text.contains("writable: 101 commits, 102 snapshot swaps"), "got {text}");
    assert!(rw.failure().is_none());
}

/// `--json` through the CLI surface: machine-readable output parses and
/// the command still succeeds.
#[test]
fn cli_json_flag_smoke() {
    let dir = tmp_repo("cli-json");
    let d = dir.to_str().unwrap();
    cli(&["init", "--dir", d, "--json"]).unwrap();
    let z = zoo();
    build_chain(&dir, &z, 2);
    cli(&["log", "--dir", d, "--json"]).unwrap();
    cli(&["log", "--dir", d, "--json", "--limit", "1", "--type", "t"]).unwrap();
    cli(&["stats", "--dir", d, "--json"]).unwrap();
    cli(&["fsck", "--dir", d, "--json"]).unwrap();
    cli(&["gc", "--dir", d, "--json"]).unwrap();
    cli(&["repack", "--dir", d, "--json"]).unwrap();
    cli(&["verify-pack", "--dir", d, "--json"]).unwrap();
    std::fs::remove_dir_all(&dir).unwrap();
}
