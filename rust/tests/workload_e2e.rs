//! Integration: workload builders + persistence + cascade over the real
//! runtime at CI scale. This is the compressed version of the
//! `adaptation_cascade` end-to-end driver, asserting the invariants the
//! examples only print.

use std::path::PathBuf;

use mgit::delta::{self, Codec, CompressConfig, NativeKernel};
use mgit::registry::CreationSpec;
use mgit::runtime::Runtime;
use mgit::store::Store;
use mgit::train::{CasCheckpointStore, Trainer};
use mgit::update::{self, CheckpointStore, CreationExecutor};
use mgit::workloads::{self, PersistMode, Scale};

/// `None` (skip) without AOT artifacts or the PJRT backend — the
/// workload builders train real models through compiled HLO.
fn runtime() -> Option<Runtime> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !mgit::runtime::HAS_PJRT {
        eprintln!("skipping: built without the `pjrt` feature");
        return None;
    }
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: no artifacts (run `make artifacts`)");
        return None;
    }
    Some(Runtime::new(&dir).expect("runtime init failed"))
}

#[test]
fn g2_build_persist_load_cascade() {
    let Some(rt) = runtime() else { return };
    let zoo = rt.zoo().clone();
    let scale = Scale::small();
    let store = Store::in_memory();

    let mut wl = workloads::build_g2(&rt, &scale).unwrap();
    wl.graph.integrity_check().unwrap();
    let expected_nodes = 1 + scale.n_tasks * (1 + scale.versions_per_task);
    assert_eq!(wl.graph.len(), expected_nodes);
    let (prov, ver) = wl.graph.edge_counts();
    assert_eq!(prov, scale.n_tasks * (1 + scale.versions_per_task));
    assert_eq!(ver, scale.n_tasks * scale.versions_per_task);

    // Persist with delta compression; everything must load back within
    // the quantization error bound.
    let report = workloads::persist(
        &mut wl,
        &store,
        &zoo,
        &rt,
        PersistMode::Delta(CompressConfig::default()),
        |_, _| Ok(true),
    )
    .unwrap();
    assert_eq!(report.n_models, expected_nodes);
    assert!(report.ratio() > 1.5, "ratio {}", report.ratio());
    for node in &wl.graph.nodes {
        let sm = node.stored.as_ref().expect("all nodes stored");
        let loaded = delta::load(&store, &zoo, sm, &rt).unwrap();
        let orig = wl.ck(&node.name).unwrap();
        // Chain error bound: depth * step.
        let bound = 16.0 * mgit::runtime::quant_step(1e-4);
        for (a, b) in loaded.flat.iter().zip(&orig.flat) {
            assert!((a - b).abs() <= bound);
        }
    }

    // Cascade from the root (through the shared resolve cache, as the
    // CLI does).
    let trainer = Trainer::new(&rt);
    let cache = delta::ResolveCache::new(64);
    let ckstore = CasCheckpointStore {
        store: &store,
        zoo: &zoo,
        kernel: &NativeKernel,
        compress: Some(CompressConfig::default()),
        cache: Some(&cache),
    };
    let m = wl.graph.idx("g2/base-mlm").unwrap();
    let base_ck = wl.ck("g2/base-mlm").unwrap().clone();
    let new_ck = trainer
        .execute(
            &CreationSpec::Pretrain { corpus_seed: 77, steps: 5, lr: 0.02 },
            "tx-tiny",
            &[base_ck],
        )
        .unwrap();
    let sm = ckstore.save(&new_ck, None).unwrap();
    let m_new = wl.graph.add_node("g2/base-mlm@v2", "tx-tiny").unwrap();
    wl.graph.node_mut(m_new).stored = Some(sm);
    wl.graph.add_version_edge(m, m_new).unwrap();
    let before = wl.graph.len();
    let report = update::run_update_cascade(
        &mut wl.graph,
        &ckstore,
        &trainer,
        m,
        m_new,
        |_, _| false,
        |_, _| false,
    )
    .unwrap();
    // Every descendant had a creation function -> all get new versions.
    assert_eq!(report.new_versions.len(), before - 1 - 1); // minus root & m_new
    assert!(report.skipped_no_cr.is_empty());
    wl.graph.integrity_check().unwrap();
    // New versions all have checkpoints.
    for (_, new) in &report.new_versions {
        assert!(wl.graph.node(*new).stored.is_some());
    }
}

#[test]
fn g4_prune_chain_preserves_sparsity_through_storage() {
    let Some(rt) = runtime() else { return };
    let zoo = rt.zoo().clone();
    let mut scale = Scale::small();
    scale.sparsities = vec![0.6];
    // Keep this test tiny: only the tiny arch chain matters here, but the
    // builder trains all three — shrink steps hard.
    scale.task_steps = 3;
    scale.prune_recover_steps = 2;
    let mut wl = workloads::build_g4(&rt, &scale).unwrap();

    for node in &wl.graph.nodes {
        if node.name.contains("sparse") {
            let ck = wl.ck(&node.name).unwrap();
            assert!(ck.sparsity() > 0.4, "{}: {}", node.name, ck.sparsity());
        }
    }

    let store = Store::in_memory();
    let cfg = CompressConfig { eps: 1e-4, codec: Codec::Deflate, prequantize: true };
    workloads::persist(&mut wl, &store, &zoo, &rt, PersistMode::Delta(cfg), |_, _| Ok(true))
        .unwrap();
    for node in &wl.graph.nodes {
        if !node.name.contains("sparse") {
            continue;
        }
        let sm = node.stored.as_ref().unwrap();
        let loaded = delta::load(&store, &zoo, sm, &rt).unwrap();
        let want = wl.ck(&node.name).unwrap().sparsity();
        assert!(
            loaded.sparsity() >= want - 1e-9,
            "{}: sparsity {} -> {}",
            node.name,
            want,
            loaded.sparsity()
        );
    }
}

#[test]
fn g5_mtl_members_share_backbone() {
    let Some(rt) = runtime() else { return };
    let scale = Scale::small();
    let wl = workloads::build_g5(&rt, &scale).unwrap();
    let names: Vec<String> = wl
        .graph
        .nodes
        .iter()
        .filter(|n| n.name.contains("mtl"))
        .map(|n| n.name.clone())
        .collect();
    assert_eq!(names.len(), scale.n_tasks);
    let a = wl.ck(&names[0]).unwrap();
    let b = wl.ck(&names[1]).unwrap();
    let shared = a.flat.iter().zip(&b.flat).filter(|(x, y)| x == y).count();
    let frac = shared as f64 / a.flat.len() as f64;
    assert!(frac > 0.9, "only {frac} of params shared");
    assert_ne!(a.flat, b.flat, "heads must differ");

    // Hash-only persistence exploits the sharing (ratio > 1.5 with >= 3
    // members sharing a backbone).
    let store = Store::in_memory();
    let zoo = rt.zoo().clone();
    let mut wl = wl;
    let report =
        workloads::persist(&mut wl, &store, &zoo, &rt, PersistMode::HashOnly, |_, _| Ok(true))
            .unwrap();
    assert!(report.ratio() > 1.5, "hash-only ratio {}", report.ratio());
}

#[test]
fn g3_federated_improves_and_tracks_lineage() {
    let Some(rt) = runtime() else { return };
    let scale = Scale::small();
    let wl = workloads::build_g3(&rt, &scale).unwrap();
    wl.graph.integrity_check().unwrap();
    // nodes: 1 initial global + rounds * (workers + 1 global)
    let expect =
        1 + scale.fl.rounds * (scale.fl.workers_per_round + 1);
    assert_eq!(wl.graph.len(), expect);
    // FedAvg nodes have the FedAvg creation spec.
    let fedavg_nodes = wl
        .graph
        .nodes
        .iter()
        .filter(|n| matches!(n.creation, Some(CreationSpec::FedAvg)))
        .count();
    assert_eq!(fedavg_nodes, scale.fl.rounds);
}

#[test]
fn g1_auto_construction_mostly_correct() {
    let Some(rt) = runtime() else { return };
    let mut scale = Scale::small();
    scale.pretrain_steps = 4;
    scale.g1_child_steps = 4;
    let wl = workloads::build_g1(&rt, &scale).unwrap();
    let gold = workloads::g1_gold();
    let order: Vec<_> = gold
        .iter()
        .map(|(n, a, p)| (n.to_string(), a.to_string(), p.map(String::from)))
        .collect();
    let store = Store::in_memory();
    let (g, correct, _) = workloads::auto_construct(
        &rt,
        &store,
        &order,
        &wl.checkpoints,
        &mgit::autoconstruct::AutoConfig::default(),
    )
    .unwrap();
    g.integrity_check().unwrap();
    // Paper: 22/23, reproduced at paper scale by `cargo bench --bench
    // table3_graphs`. At CI scale (4 training steps) unrelated roots have
    // barely diverged, so insertion is much harder; require well above
    // chance only.
    assert!(correct >= 13, "only {correct}/23 correct");
}
