#!/usr/bin/env python3
"""Generator for the committed MGGI v1 graph fixture (graph.bin).

Mirrors rust/src/lineage/binfmt.rs byte-for-byte — the base image this
writes must stay identical to what `binfmt::encode` produces for the
same graph (tests/graph_binary.rs asserts exactly that), and the v1
reader must keep opening this file forever, the same contract as the
pack v1 fixture under tests/fixtures/v1/.

Graph (4 nodes + 1 tail commit):

    base --prov--> a --ver--> a2
    base --prov--> b
    tail: {"name":"c","model_type":"tx","prov_parents":["b"]}

Run from this directory: python3 gen_fixture.py
"""

import struct
import zlib

HEADER_LEN = 96
MAGIC = b"MGGI"
VERSION = 1


def fnv64(data: bytes) -> int:
    h = 0xCBF29CE484222325
    for b in data:
        h ^= b
        h = (h * 0x100000001B3) & 0xFFFFFFFFFFFFFFFF
    return h


# (name, body JSON) in node-index order. Compact serialization matches
# Json::to_string_compact: no whitespace, insertion key order.
BODIES = [
    ("base", '{"name":"base","model_type":"tx","metadata":{}}'),
    ("a", '{"name":"a","model_type":"tx","metadata":{"note":"hello"}}'),
    ("a2", '{"name":"a2","model_type":"tx","metadata":{}}'),
    ("b", '{"name":"b","model_type":"tx","metadata":{}}'),
]

# The four CSR blocks in on-disk order, one adjacency list per node.
PROV_PARENTS = [[], [0], [], [0]]
PROV_CHILDREN = [[1, 3], [], [], []]
VER_PARENTS = [[], [], [1], []]
VER_CHILDREN = [[], [2], [], []]

TESTS = b"[]"

TAIL_OPS = [b'{"name":"c","model_type":"tx","prov_parents":["b"]}']


def csr_block(lists):
    out = bytearray()
    off = 0
    for lst in lists:
        out += struct.pack("<Q", off)
        off += len(lst)
    out += struct.pack("<Q", off)
    for lst in lists:
        for t in lst:
            out += struct.pack("<I", t)
    return bytes(out)


def main():
    n = len(BODIES)
    prov = sum(len(l) for l in PROV_PARENTS)
    ver = sum(len(l) for l in VER_PARENTS)
    assert prov == sum(len(l) for l in PROV_CHILDREN)
    assert ver == sum(len(l) for l in VER_CHILDREN)

    names = sorted((fnv64(name.encode()), i) for i, (name, _) in enumerate(BODIES))
    name_idx = b"".join(struct.pack("<QI", h, i) for h, i in names)

    adj = b"".join(
        csr_block(b) for b in (PROV_PARENTS, PROV_CHILDREN, VER_PARENTS, VER_CHILDREN)
    )

    bodies = b""
    bodies_idx = b""
    for _, body in BODIES:
        raw = body.encode()
        bodies_idx += struct.pack("<QI", len(bodies), len(raw))
        bodies += raw

    name_idx_off = HEADER_LEN
    adj_off = name_idx_off + len(name_idx)
    bodies_idx_off = adj_off + len(adj)
    bodies_off = bodies_idx_off + len(bodies_idx)
    tests_off = bodies_off + len(bodies)
    base_len = tests_off + len(TESTS)

    header = MAGIC + struct.pack(
        "<IQQQQQQQQQQQ",
        VERSION,
        n,
        prov,
        ver,
        name_idx_off,
        adj_off,
        bodies_idx_off,
        bodies_off,
        tests_off,
        len(TESTS),
        base_len,
        0,
    )
    assert len(header) == HEADER_LEN

    image = header + name_idx + adj + bodies_idx + bodies + TESTS
    assert len(image) == base_len

    tail = b""
    for payload in TAIL_OPS:
        tail += struct.pack("<II", len(payload), zlib.crc32(payload)) + payload

    with open("graph.bin", "wb") as f:
        f.write(image + tail)
    print(f"graph.bin: {base_len} base bytes + {len(tail)} tail bytes")


if __name__ == "__main__":
    main()
