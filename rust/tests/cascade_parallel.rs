//! Integration: the wavefront cascade engine — serial/parallel parity,
//! Phase-A determinism, MTL groups crossed by skip/terminate predicates,
//! and journaled resume after a partial failure. Everything runs against
//! mock executors/stores, so no runtime artifacts are needed.

use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::Mutex;

use anyhow::{anyhow, Result};
use mgit::cascade::{self, CascadeOptions};
use mgit::checkpoint::Checkpoint;
use mgit::delta::StoredModel;
use mgit::lineage::{LineageGraph, NodeIdx};
use mgit::registry::{CreationSpec, FreezeSpec, Objective};
use mgit::update::{next_version_name, CheckpointStore, CreationExecutor};

// ---------------------------------------------------------------------------
// Mocks (thread-safe: the traits are `&self + Send + Sync`)
// ---------------------------------------------------------------------------

fn spec_label(spec: &CreationSpec) -> String {
    match spec {
        CreationSpec::Finetune { task, .. } => task.clone(),
        CreationSpec::Mtl { task, .. } => task.clone(),
        other => other.kind().to_string(),
    }
}

/// Deterministic executor: child = parents[0] + 1.0; records labels and
/// optionally fails on one task label (failure injection for resume).
struct MockExec {
    calls: Mutex<Vec<String>>,
    fail_on: Option<String>,
}

impl MockExec {
    fn new() -> MockExec {
        MockExec { calls: Mutex::new(Vec::new()), fail_on: None }
    }

    fn failing_on(label: &str) -> MockExec {
        MockExec { calls: Mutex::new(Vec::new()), fail_on: Some(label.to_string()) }
    }

    fn calls(&self) -> Vec<String> {
        self.calls.lock().unwrap().clone()
    }
}

impl CreationExecutor for MockExec {
    fn execute(
        &self,
        spec: &CreationSpec,
        _arch: &str,
        parents: &[Checkpoint],
    ) -> Result<Checkpoint> {
        let label = spec_label(spec);
        if self.fail_on.as_deref() == Some(label.as_str()) {
            return Err(anyhow!("injected failure on `{label}`"));
        }
        self.calls.lock().unwrap().push(label);
        let mut ck = parents[0].clone();
        for x in ck.flat.iter_mut() {
            *x += 1.0;
        }
        Ok(ck)
    }

    fn execute_mtl_group(
        &self,
        specs: &[&CreationSpec],
        _arch: &str,
        parents: &[Checkpoint],
    ) -> Result<Vec<Checkpoint>> {
        self.calls.lock().unwrap().push(format!("mtl x{}", specs.len()));
        Ok(specs.iter().map(|_| parents[0].clone()).collect())
    }
}

/// Content-addressed in-memory store: the key is a hash of the values,
/// so stored pointers are identical whatever order workers finish in —
/// exactly like the real CAS.
struct MockStore {
    saved: Mutex<HashMap<String, Checkpoint>>,
}

impl MockStore {
    fn new() -> MockStore {
        MockStore { saved: Mutex::new(HashMap::new()) }
    }
}

fn content_key(ck: &Checkpoint) -> String {
    let mut h: u64 = 0xcbf29ce484222325;
    for x in &ck.flat {
        for b in x.to_bits().to_le_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x100000001b3);
        }
    }
    format!("{}#{h:016x}", ck.arch)
}

impl CheckpointStore for MockStore {
    fn load(&self, stored: &StoredModel) -> Result<Checkpoint> {
        self.saved
            .lock()
            .unwrap()
            .get(&stored.arch)
            .cloned()
            .ok_or_else(|| anyhow!("no stored checkpoint under key {}", stored.arch))
    }

    fn save(
        &self,
        ck: &Checkpoint,
        _prev: Option<(&StoredModel, &Checkpoint)>,
    ) -> Result<StoredModel> {
        let key = content_key(ck);
        self.saved.lock().unwrap().insert(key.clone(), ck.clone());
        Ok(StoredModel { arch: key, params: vec![] })
    }
}

// ---------------------------------------------------------------------------
// Graph builders
// ---------------------------------------------------------------------------

fn ck(v: f32) -> Checkpoint {
    Checkpoint { arch: "t".into(), flat: vec![v; 8] }
}

fn finetune(task: &str) -> CreationSpec {
    CreationSpec::Finetune {
        task: task.into(),
        objective: Objective::Cls,
        steps: 1,
        lr: 0.1,
        seed: 0,
        freeze: FreezeSpec::None,
        perturb: None,
    }
}

fn put(g: &mut LineageGraph, st: &MockStore, idx: NodeIdx, v: f32) {
    let sm = st.save(&ck(v), None).unwrap();
    g.node_mut(idx).stored = Some(sm);
}

/// Register a stored next version of `m` (what the CLI does up front).
fn register_update(g: &mut LineageGraph, st: &MockStore, m: NodeIdx) -> NodeIdx {
    let name = next_version_name(g, &g.node(m).name);
    let mt = g.node(m).model_type.clone();
    let m2 = g.add_node(&name, &mt).unwrap();
    let sm = st.save(&ck(100.0), None).unwrap();
    g.node_mut(m2).stored = Some(sm);
    g.add_version_edge(m, m2).unwrap();
    m2
}

/// m fans out into `width` independent children, each with one
/// grandchild: the shape wavefront scheduling exists for.
fn wide_graph(width: usize) -> (LineageGraph, MockStore) {
    let mut g = LineageGraph::new();
    let st = MockStore::new();
    let m = g.add_node("m", "t").unwrap();
    put(&mut g, &st, m, 0.0);
    for i in 0..width {
        let c = g.add_node(&format!("c{i}"), "t").unwrap();
        g.add_edge(m, c).unwrap();
        g.register_creation_function(c, finetune(&format!("c{i}"))).unwrap();
        put(&mut g, &st, c, 1.0 + i as f32);
        let gc = g.add_node(&format!("g{i}"), "t").unwrap();
        g.add_edge(c, gc).unwrap();
        g.register_creation_function(gc, finetune(&format!("g{i}"))).unwrap();
        put(&mut g, &st, gc, 100.0 + i as f32);
    }
    (g, st)
}

fn run_wide(width: usize, jobs: usize) -> (LineageGraph, MockStore, usize) {
    let (mut g, st) = wide_graph(width);
    let m = g.idx("m").unwrap();
    let m2 = register_update(&mut g, &st, m);
    let exec = MockExec::new();
    let report = cascade::run(
        &mut g,
        &st,
        &exec,
        m,
        m2,
        |_, _| false,
        |_, _| false,
        &CascadeOptions { jobs, journal: None },
    )
    .unwrap();
    (g, st, report.new_versions.len())
}

// ---------------------------------------------------------------------------
// Tests
// ---------------------------------------------------------------------------

/// `--jobs 4` must produce results bit-identical to the serial path:
/// same graph JSON, same stored checkpoints.
#[test]
fn parallel_jobs_match_serial_bit_exactly() {
    let (g1, st1, n1) = run_wide(12, 1);
    let (g4, st4, n4) = run_wide(12, 4);
    assert_eq!(n1, 24);
    assert_eq!(n4, 24);
    assert_eq!(
        g1.to_json().to_string_pretty(),
        g4.to_json().to_string_pretty(),
        "graph JSON must not depend on the worker count"
    );
    for i in 0..12 {
        for name in [format!("c{i}@v2"), format!("g{i}@v2")] {
            let a = st1.load(g1.by_name(&name).unwrap().stored.as_ref().unwrap()).unwrap();
            let b = st4.load(g4.by_name(&name).unwrap().stored.as_ref().unwrap()).unwrap();
            assert_eq!(a.flat, b.flat, "{name} differs across job counts");
        }
    }
    // Values flow: child = m2+1 = 101, grandchild = 102.
    let c0 = st1.load(g1.by_name("c0@v2").unwrap().stored.as_ref().unwrap()).unwrap();
    assert_eq!(c0.flat[0], 101.0);
    let g0 = st1.load(g1.by_name("g0@v2").unwrap().stored.as_ref().unwrap()).unwrap();
    assert_eq!(g0.flat[0], 102.0);
    g1.integrity_check().unwrap();
    g4.integrity_check().unwrap();
}

/// Phase-A determinism regression (the old implementation wired
/// provenance edges in HashMap order): two identical cascades must
/// serialize to byte-identical graph JSON.
#[test]
fn identical_cascades_produce_identical_graph_json() {
    let (ga, _, _) = run_wide(9, 1);
    let (gb, _, _) = run_wide(9, 1);
    assert_eq!(
        ga.to_json().to_string_pretty(),
        gb.to_json().to_string_pretty(),
        "cascade graph mutation must be deterministic run to run"
    );
}

/// An MTL group crossed by skip/terminate predicates: the skipped member
/// stays at its old version, the group retrains as a smaller barrier
/// task, and terminate cuts the cascade below a member.
#[test]
fn mtl_group_crossed_by_skip_and_terminate() {
    let mut g = LineageGraph::new();
    let st = MockStore::new();
    let m = g.add_node("m", "t").unwrap();
    put(&mut g, &st, m, 0.0);
    let mtl = |task: &str| CreationSpec::Mtl {
        task: task.into(),
        group: vec!["t1".into(), "t2".into(), "t3".into()],
        steps: 1,
        lr: 0.1,
        seed: 0,
    };
    for name in ["t1", "t2", "t3"] {
        let n = g.add_node(name, "t").unwrap();
        g.add_edge(m, n).unwrap();
        g.register_creation_function(n, mtl(name)).unwrap();
        put(&mut g, &st, n, 1.0);
    }
    // A descendant below t1 that terminate will cut off.
    let t1 = g.idx("t1").unwrap();
    let d = g.add_node("d", "t").unwrap();
    g.add_edge(t1, d).unwrap();
    g.register_creation_function(d, finetune("d")).unwrap();
    put(&mut g, &st, d, 2.0);

    let m2 = register_update(&mut g, &st, m);
    let exec = MockExec::new();
    let skip = |g2: &LineageGraph, i: NodeIdx| g2.node(i).name == "t2";
    let terminate = |g2: &LineageGraph, i: NodeIdx| g2.node(i).name == "t1";
    let report = cascade::run(
        &mut g,
        &st,
        &exec,
        m,
        m2,
        skip,
        terminate,
        &CascadeOptions::default(),
    )
    .unwrap();

    // t1 and t3 get new versions; t2 was skipped; d was cut by terminate.
    assert_eq!(report.new_versions.len(), 2);
    assert!(g.idx("t1@v2").is_ok());
    assert!(g.idx("t3@v2").is_ok());
    assert!(g.idx("t2@v2").is_err());
    assert!(g.idx("d@v2").is_err());
    // The shrunken group still trained once, jointly.
    assert_eq!(exec.calls(), vec!["mtl x2"]);
    g.integrity_check().unwrap();
}

/// Kill a cascade mid-flight, then resume from the journal: only the
/// unfinished suffix re-executes, and the final state matches a clean
/// run.
#[test]
fn resume_replays_exactly_the_unfinished_suffix() {
    let jdir = std::env::temp_dir()
        .join(format!("mgit-cascade-journal-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&jdir);

    // m -> a -> b -> c (chain) plus m -> s1, m -> s2 (siblings).
    let mut g = LineageGraph::new();
    let st = MockStore::new();
    let m = g.add_node("m", "t").unwrap();
    put(&mut g, &st, m, 0.0);
    let mut prev = m;
    for name in ["a", "b", "c"] {
        let n = g.add_node(name, "t").unwrap();
        g.add_edge(prev, n).unwrap();
        g.register_creation_function(n, finetune(name)).unwrap();
        put(&mut g, &st, n, 1.0);
        prev = n;
    }
    for name in ["s1", "s2"] {
        let n = g.add_node(name, "t").unwrap();
        g.add_edge(m, n).unwrap();
        g.register_creation_function(n, finetune(name)).unwrap();
        put(&mut g, &st, n, 2.0);
    }
    let m2 = register_update(&mut g, &st, m);

    // First attempt: `b` fails. With one worker the FIFO order is
    // a, s1, s2, then b (fails) — c never becomes ready.
    let plan = cascade::plan_cascade(&mut g, m, m2, |_, _| false, |_, _| false).unwrap();
    let journal = cascade::CascadeJournal::create(&jdir, &plan, &g).unwrap();
    let exec1 = MockExec::failing_on("b");
    let opts = CascadeOptions { jobs: 1, journal: Some(&journal) };
    let err = cascade::execute_and_apply(
        &mut g,
        &plan,
        &st,
        &exec1,
        &opts,
        &cascade::DoneTasks::new(),
    );
    assert!(err.is_err(), "injected failure must surface");
    assert_eq!(exec1.calls(), vec!["a", "s1", "s2"]);
    drop(journal);

    // Resume: the journaled prefix (a, s1, s2) is replayed, not
    // re-executed; only b and c run.
    let exec2 = MockExec::new();
    let report = cascade::resume(&mut g, &st, &exec2, &jdir, 1).unwrap();
    assert_eq!(report.resumed_tasks, 3);
    assert_eq!(report.new_versions.len(), 5);
    assert_eq!(exec2.calls(), vec!["b", "c"]);

    // Final state matches an uninterrupted cascade: m2=100 flows down
    // the chain (a=101, b=102, c=103) and across the siblings (101).
    for (name, want) in
        [("a@v2", 101.0), ("b@v2", 102.0), ("c@v2", 103.0), ("s1@v2", 101.0), ("s2@v2", 101.0)]
    {
        let node = g.by_name(name).unwrap();
        let loaded = st.load(node.stored.as_ref().unwrap()).unwrap();
        assert_eq!(loaded.flat[0], want, "{name}");
    }
    g.integrity_check().unwrap();
    std::fs::remove_dir_all(&jdir).unwrap();
}

/// The journal refuses double-creation, reports existence correctly,
/// and cleans up.
#[test]
fn journal_lifecycle() {
    let jdir: PathBuf = std::env::temp_dir()
        .join(format!("mgit-cascade-journal-life-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&jdir);
    assert!(!cascade::journal_exists(&jdir));

    let (mut g, st) = wide_graph(2);
    let m = g.idx("m").unwrap();
    let m2 = register_update(&mut g, &st, m);
    let plan = cascade::plan_cascade(&mut g, m, m2, |_, _| false, |_, _| false).unwrap();
    let journal = cascade::CascadeJournal::create(&jdir, &plan, &g).unwrap();
    assert!(cascade::journal_exists(&jdir));
    assert!(
        cascade::CascadeJournal::create(&jdir, &plan, &g).is_err(),
        "double-create must be refused"
    );
    drop(journal);

    // A full run against the journal leaves a replayable record.
    let exec = MockExec::new();
    let journal = cascade::CascadeJournal::reopen(&jdir).unwrap();
    let opts = CascadeOptions { jobs: 2, journal: Some(&journal) };
    cascade::execute_and_apply(&mut g, &plan, &st, &exec, &opts, &cascade::DoneTasks::new())
        .unwrap();
    drop(journal);
    let (loaded_plan, done) = cascade::load_journal(&jdir, &g).unwrap();
    assert_eq!(loaded_plan.tasks.len(), plan.tasks.len());
    assert_eq!(done.len(), plan.tasks.len(), "every task journaled");

    cascade::remove_journal(&jdir).unwrap();
    assert!(!cascade::journal_exists(&jdir));
}
