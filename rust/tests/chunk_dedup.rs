//! Integration: similarity-driven repack + content-defined chunk dedup
//! (`mgit repack --similarity`, pack v3, `MGCR` recipes).
//!
//! Two model lineages whose checkpoints share most of their bytes but
//! none of their object ids (every tensor is perturbed, so CAS dedup
//! never fires) are repacked twice: once with the classic lineage-only
//! pass and once with `--similarity`/chunk dedup. The chunked pack must
//! be strictly smaller, every checkpoint must stay bit-exact — including
//! when read back through `mgit serve` — and `verify-pack` must accept
//! the v3 pack. A later default incremental repack writes a v2 pack next
//! to the v3 one, pinning mixed-generation readability.

use std::collections::HashMap;
use std::io::{Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::path::{Path, PathBuf};

use mgit::checkpoint::{Checkpoint, ModelZoo};
use mgit::delta::{self, CompressConfig, NativeKernel};
use mgit::ops::serve::Server;
use mgit::ops::{self, Repo};
use mgit::store::pack::RepackMode;
use mgit::tensor::f32_to_bytes;
use mgit::util::rng::Rng;

const MANIFEST: &str = r#"{
  "vocab": 16, "max_seq": 4, "n_classes": 2, "batch": 2,
  "delta_chunk": 1024,
  "special_tokens": {"cls": 14, "mask": 15, "ignore_label": -100},
  "archs": {"t": {
      "d_model": 4, "n_layers": 1, "n_heads": 1, "d_ff": 8,
      "param_count": 4096,
      "layout": [
        {"name":"w.a","shape":[4096],"offset":0,"size":4096,"init":"normal"}
      ],
      "dag": {"nodes": [], "edges": []}
  }},
  "artifacts": {"t": {}},
  "delta_kernels": {"quant": "q", "dequant": "d"}
}"#;

const VERSIONS: usize = 3;

fn tmp_repo(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("mgit-cdedup-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

fn zoo() -> ModelZoo {
    ModelZoo::from_json(&mgit::util::json::parse(MANIFEST).unwrap()).unwrap()
}

/// Append a lineage `<family>/v1..vN` rooted at `root_ck` (stored raw,
/// deltas for the rest).
fn add_lineage(repo: &mut Repo, zoo: &ModelZoo, family: &str, root_ck: Checkpoint, seed: u64) {
    let spec = zoo.arch("t").unwrap();
    let (sm, _) = delta::store_raw(&repo.store, spec, &root_ck).unwrap();
    let idx = repo.graph.add_node(&format!("{family}/v1"), "t").unwrap();
    repo.graph.node_mut(idx).stored = Some(sm.clone());
    let mut prev = (root_ck, sm);
    let mut prev_idx = idx;
    for v in 1..VERSIONS as u64 {
        let mut rng = Rng::new(seed + v);
        let child = Checkpoint {
            arch: prev.0.arch.clone(),
            flat: prev.0.flat.iter().map(|&x| x + rng.normal_f32(0.0, 3e-4)).collect(),
        };
        let cand = delta::prepare_delta(
            &repo.store,
            spec,
            &child,
            spec,
            &prev.0,
            &prev.1,
            CompressConfig::default(),
            &NativeKernel,
        )
        .unwrap();
        delta::commit(&repo.store, &cand).unwrap();
        let n = repo.graph.add_node(&format!("{family}/v{}", v + 1), "t").unwrap();
        repo.graph.node_mut(n).stored = Some(cand.model.clone());
        repo.graph.add_version_edge(prev_idx, n).unwrap();
        prev = (cand.checkpoint, cand.model);
        prev_idx = n;
    }
}

/// Two lineages with heavy cross-lineage byte sharing but no identical
/// objects: lineage `b`'s root is lineage `a`'s root with a sparse
/// perturbation touching every 1024-element storage chunk.
fn build_repo(dir: &Path, zoo: &ModelZoo) {
    let spec = zoo.arch("t").unwrap();
    Repo::init(dir).unwrap();
    let mut repo = Repo::open(dir).unwrap();
    let a_root = Checkpoint::init(spec, 1);
    let mut b_flat = a_root.flat.clone();
    for i in (0..b_flat.len()).step_by(512) {
        b_flat[i] += 0.25;
    }
    let b_root = Checkpoint { arch: a_root.arch.clone(), flat: b_flat };
    add_lineage(&mut repo, zoo, "a", a_root, 100);
    add_lineage(&mut repo, zoo, "b", b_root, 200);
    repo.save().unwrap();
}

/// Every node's resolved flat checkpoint, as bytes.
fn checkpoints(dir: &Path, zoo: &ModelZoo) -> HashMap<String, Vec<u8>> {
    let repo = Repo::open(dir).unwrap();
    let mut out = HashMap::new();
    for node in &repo.graph.nodes {
        let ck =
            delta::load(&repo.store, zoo, node.stored.as_ref().unwrap(), &NativeKernel).unwrap();
        out.insert(node.name.clone(), f32_to_bytes(&ck.flat));
    }
    out
}

fn full_repack(dir: &Path, similarity: Option<f64>) -> ops::RepackReport {
    let req = ops::RepackRequest {
        mode: RepackMode::Full,
        similarity,
        chunk_dedup: similarity.is_some(),
        ..Default::default()
    };
    req.run(&mut Repo::open(dir).unwrap()).unwrap()
}

fn http_get(addr: SocketAddr, path: &str) -> (u16, Vec<u8>) {
    let mut s = TcpStream::connect(addr).unwrap();
    s.write_all(
        format!("GET {path} HTTP/1.1\r\nHost: localhost\r\nConnection: close\r\n\r\n")
            .as_bytes(),
    )
    .unwrap();
    s.flush().unwrap();
    let mut buf = Vec::new();
    s.read_to_end(&mut buf).unwrap();
    let head_end =
        buf.windows(4).position(|w| w == b"\r\n\r\n").expect("malformed response") + 4;
    let head = String::from_utf8_lossy(&buf[..head_end]).into_owned();
    let status: u16 =
        head.split_whitespace().nth(1).and_then(|c| c.parse().ok()).expect("bad status line");
    (status, buf[head_end..].to_vec())
}

/// The tentpole acceptance check: on cross-lineage shared tensors a
/// `--similarity` repack packs strictly fewer bytes than the classic
/// lineage-only pass, while every checkpoint stays bit-exact and the v3
/// pack verifies.
#[test]
fn chunked_repack_reduces_packed_bytes_bit_exactly() {
    let zoo = zoo();
    let dir = tmp_repo("size");
    build_repo(&dir, &zoo);
    let want = checkpoints(&dir, &zoo);

    // Classic lineage-only full repack first.
    let r1 = full_repack(&dir, None);
    let size_plain = std::fs::metadata(r1.pack.pack_path.as_ref().unwrap()).unwrap().len();
    assert_eq!(r1.pack.recipes, 0, "plain repack must not write recipes");

    // Similarity + chunk-dedup full rewrite of the same object set.
    let r2 = full_repack(&dir, Some(0.5));
    let size_chunked = std::fs::metadata(r2.pack.pack_path.as_ref().unwrap()).unwrap().len();
    assert!(r2.pack.recipes > 0, "cross-lineage sharing must produce recipes: {:?}", {
        (r2.pack.recipes, r2.pack.chunks_shared)
    });
    assert!(r2.pack.chunks_shared > 0);
    assert!(r2.pack.chunk_bytes_saved > 0);
    assert!(
        size_chunked < size_plain,
        "chunk dedup must shrink the pack: {size_chunked} >= {size_plain}"
    );

    // Bit-exact content after both rewrites.
    let got = checkpoints(&dir, &zoo);
    assert_eq!(got.len(), want.len());
    for (name, bytes) in &want {
        assert_eq!(&got[name], bytes, "checkpoint {name} changed");
    }

    // verify-pack accepts the v3 pack end-to-end.
    let repo = Repo::open(&dir).unwrap();
    let vp = ops::VerifyPackRequest.run(&repo).unwrap();
    assert!(vp.packs.iter().all(|p| p.structure_ok), "{:?}", vp.object_problems);
    assert!(vp.object_problems.is_empty(), "{:?}", vp.object_problems);
    assert!(vp.packs.iter().any(|p| p.version == 3), "expected a v3 pack");
    std::fs::remove_dir_all(&dir).unwrap();
}

/// `mgit serve` reads recipe-backed objects transparently: every
/// `/checkpoint/<node>` stream off a chunked pack is byte-identical to
/// the library reconstruction, and `/object/<id>` to `Store::get`.
#[test]
fn chunked_pack_serves_checkpoints_bit_exactly() {
    let zoo = zoo();
    let dir = tmp_repo("serve");
    build_repo(&dir, &zoo);
    let want = checkpoints(&dir, &zoo);
    let r = full_repack(&dir, Some(0.5));
    assert!(r.pack.recipes > 0, "serve test needs actual recipes in the pack");

    let repo = Repo::open(&dir).unwrap();
    let object_id = repo.graph.by_name("b/v1").unwrap().stored.as_ref().unwrap().params[0].1;
    let object_bytes = repo.store.get(&object_id).unwrap();

    let server = Server::bind(Repo::open(&dir).unwrap(), Some(zoo.clone()), 0, 2).unwrap();
    let addr = server.local_addr().unwrap();
    let handle = server.handle().unwrap();
    let srv = std::thread::spawn(move || server.serve().unwrap());

    for (name, bytes) in &want {
        let (code, body) = http_get(addr, &format!("/checkpoint/{name}"));
        assert_eq!(code, 200, "checkpoint {name}");
        assert_eq!(&body, bytes, "checkpoint {name} not bit-exact over HTTP");
    }
    let (code, body) = http_get(addr, &format!("/object/{}", object_id.hex()));
    assert_eq!(code, 200);
    assert_eq!(body, object_bytes, "/object body differs from Store::get");

    handle.shutdown();
    srv.join().unwrap();
    std::fs::remove_dir_all(&dir).unwrap();
}

/// A default incremental repack after a chunked full rewrite seals new
/// objects into a v2 pack next to the v3 pack; both generations stay
/// readable and verifiable.
#[test]
fn mixed_pack_generations_stay_readable() {
    let zoo = zoo();
    let dir = tmp_repo("mixed");
    build_repo(&dir, &zoo);
    full_repack(&dir, Some(0.5));

    // Grow lineage `a` past the chunked pack, then pack the new loose
    // objects with the default (plain v2) incremental repack.
    {
        let spec = zoo.arch("t").unwrap();
        let mut repo = Repo::open(&dir).unwrap();
        let tip_name = format!("a/v{VERSIONS}");
        let tip = repo.graph.by_name(&tip_name).unwrap().clone();
        let tip_ck =
            delta::load(&repo.store, &zoo, tip.stored.as_ref().unwrap(), &NativeKernel).unwrap();
        let mut rng = Rng::new(77);
        let child = Checkpoint {
            arch: tip_ck.arch.clone(),
            flat: tip_ck.flat.iter().map(|&x| x + rng.normal_f32(0.0, 3e-4)).collect(),
        };
        let cand = delta::prepare_delta(
            &repo.store,
            spec,
            &child,
            spec,
            &tip_ck,
            tip.stored.as_ref().unwrap(),
            CompressConfig::default(),
            &NativeKernel,
        )
        .unwrap();
        delta::commit(&repo.store, &cand).unwrap();
        let tip_idx = repo.graph.idx(&tip_name).unwrap();
        let n = repo.graph.add_node(&format!("a/v{}", VERSIONS + 1), "t").unwrap();
        repo.graph.node_mut(n).stored = Some(cand.model.clone());
        repo.graph.add_version_edge(tip_idx, n).unwrap();
        repo.save().unwrap();
        ops::RepackRequest::default().run(&mut repo).unwrap();
    }

    let repo = Repo::open(&dir).unwrap();
    let vp = ops::VerifyPackRequest.run(&repo).unwrap();
    assert!(vp.packs.len() >= 2, "expected v3 + v2 pack generations");
    assert!(vp.packs.iter().any(|p| p.version == 3));
    assert!(vp.packs.iter().any(|p| p.version == 2));
    assert!(vp.packs.iter().all(|p| p.structure_ok));
    assert!(vp.object_problems.is_empty(), "{:?}", vp.object_problems);

    // Every checkpoint — across both pack generations — still resolves.
    let all = checkpoints(&dir, &zoo);
    assert_eq!(all.len(), 2 * VERSIONS + 1);
    for (name, bytes) in &all {
        assert_eq!(bytes.len(), 4096 * 4, "checkpoint {name} has wrong size");
    }
    std::fs::remove_dir_all(&dir).unwrap();
}
