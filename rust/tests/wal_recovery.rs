//! Deterministic crash-injection suite for the write-ahead log.
//!
//! Builds a 50-commit WAL (each commit = one staged object + one commit
//! record, exactly what the writable serving tier appends), then
//! simulates a crash at *every* interesting byte position:
//!
//! * truncation at every record boundary (a crash between appends),
//! * truncation inside every record's header and payload (a crash
//!   mid-append),
//! * a bit flip inside the length, checksum, and payload of sampled
//!   records (storage corruption).
//!
//! After each injected crash the repository is reopened cold and must
//! recover to **exactly the last durable commit**: the `/log`-equivalent
//! JSON is byte-identical to a never-crashed oracle holding the same
//! prefix of commits, and every recovered node's checkpoint reads back
//! bit-exact. Damage past a record boundary must additionally be
//! diagnosed: `scan` reports the torn tail, `fsck` emits a `TORN_WAL`
//! problem whose `failure()` maps to a nonzero process exit, and
//! reopening the log for append truncates the tail so new records only
//! ever land after a validated prefix.

use std::collections::HashSet;
use std::fs;
use std::path::PathBuf;

use mgit::checkpoint::{Checkpoint, ModelZoo};
use mgit::delta::{self, NativeKernel};
use mgit::lineage::LineageGraph;
use mgit::ops::{self, Repo, Report};
use mgit::store::wal::{self, Wal, WalRecord, WAL_HEADER_LEN};
use mgit::store::Store;
use mgit::tensor::f32_to_bytes;
use mgit::util::json::{self, Json};

const MANIFEST: &str = r#"{
  "vocab": 16, "max_seq": 4, "n_classes": 2, "batch": 2,
  "delta_chunk": 1024,
  "special_tokens": {"cls": 14, "mask": 15, "ignore_label": -100},
  "archs": {"t": {
      "d_model": 4, "n_layers": 1, "n_heads": 1, "d_ff": 8,
      "param_count": 1024,
      "layout": [
        {"name":"w.a","shape":[1024],"offset":0,"size":1024,"init":"normal"}
      ],
      "dag": {"nodes": [], "edges": []}
  }},
  "artifacts": {"t": {}},
  "delta_kernels": {"quant": "q", "dequant": "d"}
}"#;

const COMMITS: usize = 50;

/// Unique per call: the `#[test]`s here run in parallel threads of one
/// process, so a pid-only suffix would collide.
fn tmp_dir(tag: &str) -> PathBuf {
    use std::sync::atomic::{AtomicUsize, Ordering};
    static SEQ: AtomicUsize = AtomicUsize::new(0);
    let dir = std::env::temp_dir().join(format!(
        "mgit-walrec-{tag}-{}-{}",
        std::process::id(),
        SEQ.fetch_add(1, Ordering::Relaxed)
    ));
    let _ = fs::remove_dir_all(&dir);
    fs::create_dir_all(&dir).unwrap();
    dir
}

/// The canonical rendering of a graph, as `/log` and `mgit log --json`
/// serve it. Byte-compare these strings for "bit-exact log".
fn log_json(graph: &LineageGraph) -> String {
    ops::LogRequest.run_graph(graph).unwrap().to_json().to_string_compact()
}

/// The template WAL plus everything needed to judge a recovery.
struct Fixture {
    zoo: ModelZoo,
    /// Full, never-crashed WAL bytes (header included).
    full: Vec<u8>,
    /// Byte offset where each record starts, in append order.
    rec_starts: Vec<u64>,
    /// Commits fully contained *before* each record (same indexing),
    /// plus one trailing entry for "all records".
    commits_before: Vec<usize>,
    /// `log_json` of the oracle graph after d commits, for d in 0..=50.
    oracle_logs: Vec<String>,
    /// Bit-exact flat checkpoint bytes of commit k (index k-1).
    ck_bytes: Vec<Vec<u8>>,
}

fn build_fixture() -> Fixture {
    let zoo = ModelZoo::from_json(&json::parse(MANIFEST).unwrap()).unwrap();
    let spec = zoo.arch("t").unwrap();
    let template = tmp_dir("template");
    Repo::init(&template).unwrap();
    let mut wal = Wal::open_append(&template).unwrap();

    let mut rec_starts = Vec::new();
    let mut commits_before = Vec::new();
    let mut oracle_logs = Vec::with_capacity(COMMITS + 1);
    let mut ck_bytes = Vec::with_capacity(COMMITS);
    let mut oracle = LineageGraph::new();
    oracle_logs.push(log_json(&oracle));
    let mut seen_ids = HashSet::new();
    let mut commits = 0usize;
    for k in 1..=COMMITS {
        let ck = Checkpoint::init(spec, 7000 + k as u64);
        // Encode exactly as the serving tier does: into a scratch store,
        // then ship the objects as Put records ahead of the commit.
        let mem = Store::in_memory();
        let (sm, _) = delta::store_raw(&mem, spec, &ck).unwrap();
        for (_, id) in &sm.params {
            if seen_ids.insert(*id) {
                rec_starts.push(wal.len().unwrap());
                commits_before.push(commits);
                wal.append(&WalRecord::Put { id: *id, bytes: mem.get(id).unwrap() })
                    .unwrap();
            }
        }
        let mut op = Json::obj()
            .set("name", format!("c/{k}"))
            .set("model_type", "t")
            .set("stored", sm.to_json());
        if k > 1 {
            op = op.set("ver_parent", format!("c/{}", k - 1));
        }
        rec_starts.push(wal.len().unwrap());
        commits_before.push(commits);
        wal.append(&WalRecord::Commit { op: op.clone() }).unwrap();
        commits += 1;
        assert!(oracle.apply_commit(&op).unwrap());
        oracle_logs.push(log_json(&oracle));
        ck_bytes.push(f32_to_bytes(&ck.flat));
    }
    wal.sync().unwrap();
    commits_before.push(commits);
    let full = fs::read(wal::wal_path(&template)).unwrap();
    fs::remove_dir_all(&template).unwrap();
    Fixture { zoo, full, rec_starts, commits_before, oracle_logs, ck_bytes }
}

/// Durable commits in a prefix of the template log that ends at byte
/// `len` (or whose first damaged record starts at `len`).
fn durable_commits(fx: &Fixture, len: u64) -> usize {
    for (i, start) in fx.rec_starts.iter().enumerate() {
        if *start >= len {
            return fx.commits_before[i];
        }
    }
    *fx.commits_before.last().unwrap()
}

/// Plant `wal_bytes` in a fresh repository, reopen cold, and assert
/// recovery to exactly `expect_commits` durable commits — bit-exact log
/// JSON and checkpoint bytes against the oracle — plus the torn-tail
/// diagnosis when `expect_torn`.
fn assert_recovers(fx: &Fixture, wal_bytes: &[u8], expect_commits: usize, expect_torn: bool) {
    let dir = tmp_dir("case");
    Repo::init(&dir).unwrap();
    fs::create_dir_all(wal::wal_dir(&dir)).unwrap();
    let path = wal::wal_path(&dir);
    fs::write(&path, wal_bytes).unwrap();

    // The scan itself agrees on durability and damage.
    let scan = wal::scan(&path).unwrap();
    assert_eq!(scan.commits, expect_commits, "scan commits at len {}", wal_bytes.len());
    assert_eq!(
        scan.torn.is_some(),
        expect_torn,
        "torn detection at len {}",
        wal_bytes.len()
    );

    // Cold reopen replays the durable prefix; the graph must equal the
    // never-crashed oracle with the same number of commits, byte for
    // byte in its canonical JSON rendering.
    let repo = Repo::open(&dir).unwrap();
    assert_eq!(
        log_json(&repo.graph),
        fx.oracle_logs[expect_commits],
        "log mismatch at len {} ({expect_commits} commits)",
        wal_bytes.len()
    );
    // Every recovered checkpoint reads back bit-exact.
    for k in 1..=expect_commits {
        let n = repo.graph.by_name(&format!("c/{k}")).unwrap();
        let ck =
            delta::load(&repo.store, &fx.zoo, n.stored.as_ref().unwrap(), &NativeKernel)
                .unwrap();
        assert_eq!(
            f32_to_bytes(&ck.flat),
            fx.ck_bytes[k - 1],
            "checkpoint c/{k} at len {}",
            wal_bytes.len()
        );
    }

    // fsck: clean prefixes pass; damage is a TORN_WAL problem that maps
    // to a nonzero exit via `failure()`.
    let fsck = ops::FsckRequest.run(&repo).unwrap();
    if expect_torn {
        assert!(
            fsck.problems.iter().any(|p| p.kind == "TORN_WAL"),
            "fsck must flag the torn tail at len {}",
            wal_bytes.len()
        );
        assert!(fsck.failure().is_some(), "torn WAL must fail fsck");
    } else {
        assert!(
            fsck.failure().is_none(),
            "clean recovery must pass fsck at len {}: {:?}",
            wal_bytes.len(),
            fsck.problems.iter().map(|p| format!("{}: {}", p.kind, p.detail)).collect::<Vec<_>>()
        );
    }

    // A writer reopening the log truncates the tail back to the durable
    // prefix — appends only ever land after validated bytes.
    let expect_len = wal::scan(&path).unwrap().valid_len;
    drop(Wal::open_append(&dir).unwrap());
    assert_eq!(fs::metadata(&path).unwrap().len(), expect_len);
    let rescan = wal::scan(&path).unwrap();
    assert!(rescan.torn.is_none(), "open_append must leave a clean log");
    assert_eq!(rescan.commits, expect_commits);

    fs::remove_dir_all(&dir).unwrap();
}

/// Crash at every record boundary: the file ends exactly between
/// records, so recovery is clean (no torn tail) and lands on the last
/// commit whose record made it in.
#[test]
fn truncation_at_every_record_boundary() {
    let fx = build_fixture();
    // Boundaries: after the header, after every record, and the full
    // file (never crashed).
    let mut boundaries: Vec<u64> = vec![WAL_HEADER_LEN];
    boundaries.extend(fx.rec_starts.iter().skip(1).copied());
    boundaries.push(fx.full.len() as u64);
    assert_eq!(boundaries.len(), fx.rec_starts.len() + 1);
    for &b in &boundaries {
        let d = durable_commits(&fx, b);
        assert_recovers(&fx, &fx.full[..b as usize], d, false);
    }
    // Sanity: the suite really covered the whole range.
    assert_eq!(durable_commits(&fx, WAL_HEADER_LEN), 0);
    assert_eq!(durable_commits(&fx, fx.full.len() as u64), COMMITS);
}

/// Crash inside every record: cut one byte into the frame header and
/// halfway through the payload. Both leave a torn tail; recovery stops
/// at the record's start.
#[test]
fn truncation_inside_every_record() {
    let fx = build_fixture();
    let n = fx.rec_starts.len();
    for i in 0..n {
        let start = fx.rec_starts[i] as usize;
        let end = if i + 1 < n { fx.rec_starts[i + 1] as usize } else { fx.full.len() };
        let d = fx.commits_before[i];
        // One byte into the 8-byte frame header: partial header.
        assert_recovers(&fx, &fx.full[..start + 1], d, true);
        // Mid-payload: the length field promises more bytes than exist.
        let mid = start + 8 + (end - start - 8) / 2;
        assert_recovers(&fx, &fx.full[..mid], d, true);
    }
}

/// Storage corruption: flip one bit in the length, checksum, and payload
/// of sampled records. The scan must stop at the damaged record — never
/// resynchronizing past it, even though later records are intact — and
/// recover everything before it.
#[test]
fn bit_flip_inside_records() {
    let fx = build_fixture();
    let n = fx.rec_starts.len();
    for i in (0..n).step_by(7).chain([n - 1]) {
        let start = fx.rec_starts[i] as usize;
        let end = if i + 1 < n { fx.rec_starts[i + 1] as usize } else { fx.full.len() };
        let d = fx.commits_before[i];
        // Length, checksum, and payload byte positions within the frame.
        for delta_off in [0usize, 4, 8 + (end - start - 8) / 3] {
            let mut data = fx.full.clone();
            data[start + delta_off] ^= 0x40;
            assert_recovers(&fx, &data, d, true);
        }
    }
}

/// The binary graph's append-only segment tail honors the same crash
/// contract as the WAL: a cut at a record boundary recovers clean, a
/// cut or bit flip inside a record keeps exactly the durable prefix
/// (bit-exact log JSON against a never-crashed oracle) and is
/// diagnosed as a `TORN_GRAPH_TAIL` fsck failure.
#[test]
fn graph_tail_crash_injection() {
    use mgit::lineage::binfmt;

    const TAIL: usize = 12;
    let mut oracle = LineageGraph::new();
    oracle.add_node("root", "t").unwrap();

    // Template: a compact base image plus TAIL appended commit records,
    // with every record's start offset and the oracle log after each.
    let template = tmp_dir("graph-template");
    Repo::init(&template).unwrap();
    let bin = Repo::graph_bin_path(&template);
    binfmt::write_binary(&oracle, &bin).unwrap();
    let mut rec_starts = Vec::with_capacity(TAIL);
    let mut oracle_logs = vec![log_json(&oracle)];
    for k in 1..=TAIL {
        let parent = if k == 1 { "root".to_string() } else { format!("g/{}", k - 1) };
        let op = Json::obj()
            .set("name", format!("g/{k}"))
            .set("model_type", "t")
            .set("prov_parents", Json::Arr(vec![Json::from(parent.as_str())]));
        rec_starts.push(fs::metadata(&bin).unwrap().len() as usize);
        binfmt::append_commits(&bin, &[op.clone()]).unwrap();
        assert!(oracle.apply_commit(&op).unwrap());
        oracle_logs.push(log_json(&oracle));
    }
    let full = fs::read(&bin).unwrap();
    fs::remove_dir_all(&template).unwrap();

    let assert_case = |bytes: &[u8], durable: usize, torn: bool| {
        let dir = tmp_dir("graph-case");
        Repo::init(&dir).unwrap();
        fs::write(Repo::graph_bin_path(&dir), bytes).unwrap();
        let repo = Repo::open(&dir).unwrap();
        assert_eq!(
            log_json(&repo.graph),
            oracle_logs[durable],
            "graph tail recovery at len {} ({durable} durable commits)",
            bytes.len()
        );
        assert_eq!(repo.graph.tail_status().is_some(), torn, "at len {}", bytes.len());
        let fsck = ops::FsckRequest.run(&repo).unwrap();
        assert_eq!(
            fsck.problems.iter().any(|p| p.kind == "TORN_GRAPH_TAIL"),
            torn,
            "fsck at len {}: {:?}",
            bytes.len(),
            fsck.problems.iter().map(|p| p.kind).collect::<Vec<_>>()
        );
        assert_eq!(fsck.failure().is_some(), torn, "exit status at len {}", bytes.len());
        fs::remove_dir_all(&dir).unwrap();
    };

    // Every record boundary, including the bare base image and the
    // never-crashed file: clean.
    for (i, &start) in rec_starts.iter().enumerate() {
        assert_case(&full[..start], i, false);
    }
    assert_case(&full, TAIL, false);
    // Inside every record — mid-header and mid-payload: torn.
    for i in 0..TAIL {
        let start = rec_starts[i];
        let end = if i + 1 < TAIL { rec_starts[i + 1] } else { full.len() };
        assert_case(&full[..start + 1], i, true);
        assert_case(&full[..start + 8 + (end - start - 8) / 2], i, true);
    }
    // Bit flips in the length, checksum, and payload of sampled records:
    // the scan must stop there, never resynchronizing past damage.
    for i in [0, TAIL / 2, TAIL - 1] {
        for off in [0usize, 4, 9] {
            let mut data = full.clone();
            data[rec_starts[i] + off] ^= 0x40;
            assert_case(&data, i, true);
        }
    }
}

/// After a torn-tail recovery the log keeps working: reopening for
/// append truncates the damage, new commits land after the validated
/// prefix, and the next cold open sees old + new.
#[test]
fn append_after_torn_tail_recovery() {
    let fx = build_fixture();
    // Cut mid-way through the final commit record: 49 durable commits.
    let last_start = *fx.rec_starts.last().unwrap() as usize;
    let cut = last_start + 8 + (fx.full.len() - last_start - 8) / 2;

    let dir = tmp_dir("resume");
    Repo::init(&dir).unwrap();
    fs::create_dir_all(wal::wal_dir(&dir)).unwrap();
    fs::write(wal::wal_path(&dir), &fx.full[..cut]).unwrap();

    let mut wal = Wal::open_append(&dir).unwrap();
    assert_eq!(wal.len().unwrap(), last_start as u64, "tail must be truncated");
    wal.append(&WalRecord::Commit {
        op: Json::obj()
            .set("name", "resumed/1")
            .set("model_type", "t")
            .set("prov_parents", Json::Arr(vec![Json::from("c/1")])),
    })
    .unwrap();
    wal.sync().unwrap();
    drop(wal);

    let repo = Repo::open(&dir).unwrap();
    assert_eq!(repo.graph.len(), COMMITS); // 49 recovered + 1 resumed
    assert!(repo.graph.by_name("resumed/1").is_ok());
    assert!(repo.graph.by_name(&format!("c/{COMMITS}")).is_err(), "torn commit must be gone");
    let fsck = ops::FsckRequest.run(&repo).unwrap();
    assert!(fsck.failure().is_none(), "resumed log must be clean");
    fs::remove_dir_all(&dir).unwrap();
}
