//! Integration: the `mgit serve` HTTP front-end under concurrent load.
//!
//! Starts a server on an ephemeral port over a packed repository and
//! hammers it from N concurrent clients: `/log` JSON plus
//! `/checkpoint/<node>` tensor streams that must be bit-exact with what
//! `delta::load` reconstructs, and `/object/<id>` bodies byte-identical
//! to `Store::get`.

use std::collections::HashMap;
use std::io::{Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::path::{Path, PathBuf};

use mgit::checkpoint::{Checkpoint, ModelZoo};
use mgit::delta::{self, CompressConfig, NativeKernel};
use mgit::ops::serve::Server;
use mgit::ops::{self, Repo};
use mgit::tensor::f32_to_bytes;
use mgit::util::rng::Rng;

const MANIFEST: &str = r#"{
  "vocab": 16, "max_seq": 4, "n_classes": 2, "batch": 2,
  "delta_chunk": 1024,
  "special_tokens": {"cls": 14, "mask": 15, "ignore_label": -100},
  "archs": {"t": {
      "d_model": 4, "n_layers": 1, "n_heads": 1, "d_ff": 8,
      "param_count": 4096,
      "layout": [
        {"name":"w.a","shape":[4096],"offset":0,"size":4096,"init":"normal"}
      ],
      "dag": {"nodes": [], "edges": []}
  }},
  "artifacts": {"t": {}},
  "delta_kernels": {"quant": "q", "dequant": "d"}
}"#;

const VERSIONS: usize = 6;
const CLIENTS: usize = 8;

fn tmp_repo(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("mgit-serve-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

fn build_chain(dir: &Path, zoo: &ModelZoo) {
    let spec = zoo.arch("t").unwrap();
    let mut repo = Repo::open(dir).unwrap();
    let root_ck = Checkpoint::init(spec, 1);
    let (sm, _) = delta::store_raw(&repo.store, spec, &root_ck).unwrap();
    let idx = repo.graph.add_node("m/v1", "t").unwrap();
    repo.graph.node_mut(idx).stored = Some(sm.clone());
    let mut prev = (root_ck, sm);
    let mut prev_idx = idx;
    for v in 1..VERSIONS as u64 {
        let mut rng = Rng::new(v + 30);
        let child = Checkpoint {
            arch: prev.0.arch.clone(),
            flat: prev.0.flat.iter().map(|&x| x + rng.normal_f32(0.0, 3e-4)).collect(),
        };
        let cand = delta::prepare_delta(
            &repo.store,
            spec,
            &child,
            spec,
            &prev.0,
            &prev.1,
            CompressConfig::default(),
            &NativeKernel,
        )
        .unwrap();
        delta::commit(&repo.store, &cand).unwrap();
        let name = format!("m/v{}", v + 1);
        let n = repo.graph.add_node(&name, "t").unwrap();
        repo.graph.node_mut(n).stored = Some(cand.model.clone());
        repo.graph.add_version_edge(prev_idx, n).unwrap();
        prev = (cand.checkpoint, cand.model);
        prev_idx = n;
    }
    repo.save().unwrap();
}

/// Minimal HTTP/1.1 GET: returns (status code, body bytes).
fn http_get(addr: SocketAddr, path: &str) -> (u16, Vec<u8>) {
    let mut s = TcpStream::connect(addr).unwrap();
    write!(s, "GET {path} HTTP/1.1\r\nHost: localhost\r\nConnection: close\r\n\r\n").unwrap();
    s.flush().unwrap();
    let mut buf = Vec::new();
    s.read_to_end(&mut buf).unwrap();
    let head_end =
        buf.windows(4).position(|w| w == b"\r\n\r\n").expect("malformed response") + 4;
    let head = String::from_utf8_lossy(&buf[..head_end]).into_owned();
    let status: u16 = head
        .split_whitespace()
        .nth(1)
        .and_then(|c| c.parse().ok())
        .expect("bad status line");
    (status, buf[head_end..].to_vec())
}

#[test]
fn serve_concurrent_bit_exact() {
    let dir = tmp_repo("conc");
    let zoo = ModelZoo::from_json(&mgit::util::json::parse(MANIFEST).unwrap()).unwrap();
    Repo::init(&dir).unwrap();
    build_chain(&dir, &zoo);
    // Repack so the server reads through the pack/mmap tier, not loose
    // files.
    ops::RepackRequest::default().run(&mut Repo::open(&dir).unwrap()).unwrap();

    // Library-side ground truth: every node's resolved flat checkpoint.
    let repo = Repo::open(&dir).unwrap();
    let mut expected: HashMap<String, Vec<u8>> = HashMap::new();
    for node in &repo.graph.nodes {
        let ck = delta::load(
            &repo.store,
            &zoo,
            node.stored.as_ref().unwrap(),
            &NativeKernel,
        )
        .unwrap();
        expected.insert(node.name.clone(), f32_to_bytes(&ck.flat));
    }
    let object_id = repo.graph.by_name("m/v1").unwrap().stored.as_ref().unwrap().params[0].1;
    let object_bytes = repo.store.get(&object_id).unwrap();

    let server = Server::bind(Repo::open(&dir).unwrap(), Some(zoo.clone()), 0, CLIENTS)
        .unwrap();
    let addr = server.local_addr().unwrap();
    let handle = server.handle().unwrap();
    let srv = std::thread::spawn(move || server.serve().unwrap());

    // ≥ 8 concurrent readers, each fetching /log and every node's
    // checkpoint twice; every byte must match the library reconstruction.
    let mut clients = Vec::new();
    for _ in 0..CLIENTS {
        let expected = expected.clone();
        clients.push(std::thread::spawn(move || {
            for _round in 0..2 {
                let (code, body) = http_get(addr, "/log");
                assert_eq!(code, 200);
                let log = mgit::util::json::parse(&String::from_utf8(body).unwrap()).unwrap();
                assert_eq!(log.req_arr("nodes").unwrap().len(), VERSIONS);
                for (name, want) in &expected {
                    let (code, body) = http_get(addr, &format!("/checkpoint/{name}"));
                    assert_eq!(code, 200, "checkpoint {name}");
                    assert_eq!(&body, want, "checkpoint {name} not bit-exact");
                }
            }
        }));
    }
    for c in clients {
        c.join().unwrap();
    }

    // /object/<id> is byte-identical to Store::get.
    let (code, body) = http_get(addr, &format!("/object/{}", object_id.hex()));
    assert_eq!(code, 200);
    assert_eq!(body, object_bytes);

    // JSON endpoints + routing edges.
    let (code, body) = http_get(addr, "/stats");
    assert_eq!(code, 200);
    let stats = mgit::util::json::parse(&String::from_utf8(body).unwrap()).unwrap();
    assert_eq!(stats.req_usize("objects").unwrap(), VERSIONS);

    let (code, body) = http_get(addr, "/show/m%2Fv1");
    assert_eq!(code, 200);
    let show = mgit::util::json::parse(&String::from_utf8(body).unwrap()).unwrap();
    assert_eq!(show.req_str("name").unwrap(), "m/v1");
    // Unencoded slashes also reach single-name endpoints.
    let (code, _) = http_get(addr, "/show/m/v1");
    assert_eq!(code, 200);

    let (code, body) = http_get(addr, "/diff/m%2Fv1/m%2Fv2");
    assert_eq!(code, 200);
    let diff = mgit::util::json::parse(&String::from_utf8(body).unwrap()).unwrap();
    assert!(diff.req_f64("value_distance").unwrap() >= 0.0);

    let (code, _) = http_get(addr, "/healthz");
    assert_eq!(code, 200);
    let (code, _) = http_get(addr, "/no-such-route");
    assert_eq!(code, 404);
    let (code, _) = http_get(addr, "/checkpoint/ghost");
    assert_eq!(code, 404);
    let (code, _) = http_get(addr, "/object/zzzz");
    assert_eq!(code, 400);
    let (code, _) = http_get(addr, "/diff/only-one");
    assert_eq!(code, 400);

    handle.shutdown();
    let report = srv.join().unwrap();
    let min = (CLIENTS * 2 * (VERSIONS + 1)) as u64;
    assert!(report.requests >= min, "served {} < {min}", report.requests);
    std::fs::remove_dir_all(&dir).unwrap();
}

/// Without a manifest the graph/store endpoints still work; the
/// arch-dependent ones answer 503.
#[test]
fn serve_without_manifest_degrades() {
    let dir = tmp_repo("nozoo");
    let zoo = ModelZoo::from_json(&mgit::util::json::parse(MANIFEST).unwrap()).unwrap();
    Repo::init(&dir).unwrap();
    build_chain(&dir, &zoo);

    let server = Server::bind(Repo::open(&dir).unwrap(), None, 0, 2).unwrap();
    let addr = server.local_addr().unwrap();
    let handle = server.handle().unwrap();
    let srv = std::thread::spawn(move || server.serve().unwrap());

    let (code, _) = http_get(addr, "/log");
    assert_eq!(code, 200);
    let (code, _) = http_get(addr, "/checkpoint/m%2Fv1");
    assert_eq!(code, 503);
    let (code, _) = http_get(addr, "/diff/m%2Fv1/m%2Fv2");
    assert_eq!(code, 503);

    handle.shutdown();
    srv.join().unwrap();
    std::fs::remove_dir_all(&dir).unwrap();
}
