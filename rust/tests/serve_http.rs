//! Integration: the `mgit serve` HTTP front-end under concurrent load.
//!
//! Starts a server on an ephemeral port over a packed repository and
//! hammers it from N concurrent clients: `/log` JSON plus
//! `/checkpoint/<node>` tensor streams that must be bit-exact with what
//! `delta::load` reconstructs, and `/object/<id>` bodies byte-identical
//! to `Store::get`. The `/metrics` endpoint must account for that
//! traffic *exactly* (requests are recorded before their first response
//! byte), in both JSON and Prometheus text renderings, and keep-alive
//! connections must carry multiple requests.
//!
//! The write tier is exercised end-to-end as well: route-aware method
//! dispatch (405/403/401/429 gating), `POST /object` + `POST /commit` +
//! `POST /checkpoint` round trips, `Range:` reads, a live
//! `POST /admin/repack`, and a ≥8-reader × ≥100-commit concurrent
//! stress run that pins down snapshot-swap atomicity (no torn reads)
//! and exact metrics settling.

use std::collections::HashMap;
use std::io::{BufRead, BufReader, Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

use mgit::checkpoint::{Checkpoint, ModelZoo};
use mgit::delta::{self, CompressConfig, NativeKernel};
use mgit::ops::serve::{Server, WriteConfig};
use mgit::ops::{self, Repo};
use mgit::store::{wal, Store};
use mgit::tensor::f32_to_bytes;
use mgit::util::json::{self, Json};
use mgit::util::rng::Rng;

const MANIFEST: &str = r#"{
  "vocab": 16, "max_seq": 4, "n_classes": 2, "batch": 2,
  "delta_chunk": 1024,
  "special_tokens": {"cls": 14, "mask": 15, "ignore_label": -100},
  "archs": {"t": {
      "d_model": 4, "n_layers": 1, "n_heads": 1, "d_ff": 8,
      "param_count": 4096,
      "layout": [
        {"name":"w.a","shape":[4096],"offset":0,"size":4096,"init":"normal"}
      ],
      "dag": {"nodes": [], "edges": []}
  }},
  "artifacts": {"t": {}},
  "delta_kernels": {"quant": "q", "dequant": "d"}
}"#;

const VERSIONS: usize = 6;
const CLIENTS: usize = 8;

fn tmp_repo(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("mgit-serve-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

fn build_chain(dir: &Path, zoo: &ModelZoo) {
    let spec = zoo.arch("t").unwrap();
    let mut repo = Repo::open(dir).unwrap();
    let root_ck = Checkpoint::init(spec, 1);
    let (sm, _) = delta::store_raw(&repo.store, spec, &root_ck).unwrap();
    let idx = repo.graph.add_node("m/v1", "t").unwrap();
    repo.graph.node_mut(idx).stored = Some(sm.clone());
    let mut prev = (root_ck, sm);
    let mut prev_idx = idx;
    for v in 1..VERSIONS as u64 {
        let mut rng = Rng::new(v + 30);
        let child = Checkpoint {
            arch: prev.0.arch.clone(),
            flat: prev.0.flat.iter().map(|&x| x + rng.normal_f32(0.0, 3e-4)).collect(),
        };
        let cand = delta::prepare_delta(
            &repo.store,
            spec,
            &child,
            spec,
            &prev.0,
            &prev.1,
            CompressConfig::default(),
            &NativeKernel,
        )
        .unwrap();
        delta::commit(&repo.store, &cand).unwrap();
        let name = format!("m/v{}", v + 1);
        let n = repo.graph.add_node(&name, "t").unwrap();
        repo.graph.node_mut(n).stored = Some(cand.model.clone());
        repo.graph.add_version_edge(prev_idx, n).unwrap();
        prev = (cand.checkpoint, cand.model);
        prev_idx = n;
    }
    repo.save().unwrap();
}

/// Read one `Connection: close` response off a stream: returns
/// (status code, head text, body).
fn read_response(mut s: TcpStream) -> (u16, String, Vec<u8>) {
    let mut buf = Vec::new();
    s.read_to_end(&mut buf).unwrap();
    let head_end =
        buf.windows(4).position(|w| w == b"\r\n\r\n").expect("malformed response") + 4;
    let head = String::from_utf8_lossy(&buf[..head_end]).into_owned();
    let status: u16 = head
        .split_whitespace()
        .nth(1)
        .and_then(|c| c.parse().ok())
        .expect("bad status line");
    (status, head, buf[head_end..].to_vec())
}

/// Raw one-shot HTTP exchange: returns (status code, head text, body).
fn http_request(addr: SocketAddr, request: &str) -> (u16, String, Vec<u8>) {
    let mut s = TcpStream::connect(addr).unwrap();
    s.write_all(request.as_bytes()).unwrap();
    s.flush().unwrap();
    read_response(s)
}

/// Minimal HTTP/1.1 GET: returns (status code, body bytes).
fn http_get(addr: SocketAddr, path: &str) -> (u16, Vec<u8>) {
    let (status, _head, body) = http_request(
        addr,
        &format!("GET {path} HTTP/1.1\r\nHost: localhost\r\nConnection: close\r\n\r\n"),
    );
    (status, body)
}

/// One-shot GET carrying extra request headers (e.g. `Range:`).
fn http_get_with(
    addr: SocketAddr,
    path: &str,
    headers: &[(&str, &str)],
) -> (u16, String, Vec<u8>) {
    let mut req = format!("GET {path} HTTP/1.1\r\nHost: localhost\r\nConnection: close\r\n");
    for (k, v) in headers {
        req.push_str(&format!("{k}: {v}\r\n"));
    }
    req.push_str("\r\n");
    http_request(addr, &req)
}

/// One-shot POST with a binary body: returns (status, head text, body).
fn http_post(
    addr: SocketAddr,
    path: &str,
    headers: &[(&str, &str)],
    body: &[u8],
) -> (u16, String, Vec<u8>) {
    let mut s = TcpStream::connect(addr).unwrap();
    let mut head = format!(
        "POST {path} HTTP/1.1\r\nHost: localhost\r\nConnection: close\r\nContent-Length: {}\r\n",
        body.len()
    );
    for (k, v) in headers {
        head.push_str(&format!("{k}: {v}\r\n"));
    }
    head.push_str("\r\n");
    s.write_all(head.as_bytes()).unwrap();
    s.write_all(body).unwrap();
    s.flush().unwrap();
    read_response(s)
}

fn parse_json(body: &[u8]) -> Json {
    json::parse(std::str::from_utf8(body).unwrap()).unwrap()
}

/// A persistent (keep-alive) client connection: responses are framed by
/// `Content-Length`, so one TCP stream carries many requests.
struct KeepAliveConn {
    reader: BufReader<TcpStream>,
}

impl KeepAliveConn {
    fn connect(addr: SocketAddr) -> KeepAliveConn {
        KeepAliveConn { reader: BufReader::new(TcpStream::connect(addr).unwrap()) }
    }

    fn get(&mut self, path: &str) -> (u16, Vec<u8>) {
        write!(self.reader.get_mut(), "GET {path} HTTP/1.1\r\nHost: localhost\r\n\r\n")
            .unwrap();
        let mut line = String::new();
        self.reader.read_line(&mut line).unwrap();
        let status: u16 =
            line.split_whitespace().nth(1).and_then(|c| c.parse().ok()).unwrap();
        let mut content_len = 0usize;
        loop {
            let mut h = String::new();
            self.reader.read_line(&mut h).unwrap();
            if h == "\r\n" || h == "\n" || h.is_empty() {
                break;
            }
            if let Some(v) = h.to_ascii_lowercase().strip_prefix("content-length:") {
                content_len = v.trim().parse().unwrap();
            }
        }
        let mut body = vec![0u8; content_len];
        self.reader.read_exact(&mut body).unwrap();
        (status, body)
    }
}

#[test]
fn serve_concurrent_bit_exact() {
    let dir = tmp_repo("conc");
    let zoo = ModelZoo::from_json(&mgit::util::json::parse(MANIFEST).unwrap()).unwrap();
    Repo::init(&dir).unwrap();
    build_chain(&dir, &zoo);
    // Repack so the server reads through the pack/mmap tier, not loose
    // files.
    ops::RepackRequest::default().run(&mut Repo::open(&dir).unwrap()).unwrap();

    // Library-side ground truth: every node's resolved flat checkpoint.
    let repo = Repo::open(&dir).unwrap();
    let mut expected: HashMap<String, Vec<u8>> = HashMap::new();
    for node in &repo.graph.nodes {
        let ck = delta::load(
            &repo.store,
            &zoo,
            node.stored.as_ref().unwrap(),
            &NativeKernel,
        )
        .unwrap();
        expected.insert(node.name.clone(), f32_to_bytes(&ck.flat));
    }
    let object_id = repo.graph.by_name("m/v1").unwrap().stored.as_ref().unwrap().params[0].1;
    let object_bytes = repo.store.get(&object_id).unwrap();

    let server = Server::bind(Repo::open(&dir).unwrap(), Some(zoo.clone()), 0, CLIENTS)
        .unwrap();
    let addr = server.local_addr().unwrap();
    let handle = server.handle().unwrap();
    let srv = std::thread::spawn(move || server.serve().unwrap());

    // ≥ 8 concurrent readers, each fetching /log and every node's
    // checkpoint twice; every byte must match the library reconstruction.
    let mut clients = Vec::new();
    for _ in 0..CLIENTS {
        let expected = expected.clone();
        clients.push(std::thread::spawn(move || {
            for _round in 0..2 {
                let (code, body) = http_get(addr, "/log");
                assert_eq!(code, 200);
                let log = mgit::util::json::parse(&String::from_utf8(body).unwrap()).unwrap();
                assert_eq!(log.req_arr("nodes").unwrap().len(), VERSIONS);
                for (name, want) in &expected {
                    let (code, body) = http_get(addr, &format!("/checkpoint/{name}"));
                    assert_eq!(code, 200, "checkpoint {name}");
                    assert_eq!(&body, want, "checkpoint {name} not bit-exact");
                }
            }
        }));
    }
    for c in clients {
        c.join().unwrap();
    }

    // /object/<id> is byte-identical to Store::get.
    let (code, body) = http_get(addr, &format!("/object/{}", object_id.hex()));
    assert_eq!(code, 200);
    assert_eq!(body, object_bytes);

    // JSON endpoints + routing edges.
    let (code, body) = http_get(addr, "/stats");
    assert_eq!(code, 200);
    let stats = mgit::util::json::parse(&String::from_utf8(body).unwrap()).unwrap();
    assert_eq!(stats.req_usize("objects").unwrap(), VERSIONS);

    let (code, body) = http_get(addr, "/show/m%2Fv1");
    assert_eq!(code, 200);
    let show = mgit::util::json::parse(&String::from_utf8(body).unwrap()).unwrap();
    assert_eq!(show.req_str("name").unwrap(), "m/v1");
    // Unencoded slashes also reach single-name endpoints.
    let (code, _) = http_get(addr, "/show/m/v1");
    assert_eq!(code, 200);

    let (code, body) = http_get(addr, "/diff/m%2Fv1/m%2Fv2");
    assert_eq!(code, 200);
    let diff = mgit::util::json::parse(&String::from_utf8(body).unwrap()).unwrap();
    assert!(diff.req_f64("value_distance").unwrap() >= 0.0);

    let (code, _) = http_get(addr, "/healthz");
    assert_eq!(code, 200);
    let (code, _) = http_get(addr, "/no-such-route");
    assert_eq!(code, 404);
    let (code, _) = http_get(addr, "/checkpoint/ghost");
    assert_eq!(code, 404);
    let (code, _) = http_get(addr, "/object/zzzz");
    assert_eq!(code, 400);
    let (code, _) = http_get(addr, "/diff/only-one");
    assert_eq!(code, 400);

    // ------------------------------------------------------------------
    // /metrics accounts for everything above *exactly*: metrics are
    // recorded before a response's first byte, every response above was
    // fully read, and a /metrics snapshot excludes its own request.
    // ------------------------------------------------------------------
    // 112 concurrent (8 clients × 2 rounds × (1 /log + 6 checkpoints))
    // + 10 sequential probes above.
    let settled = 112 + 10;
    let (code, body) = http_get(addr, "/metrics");
    assert_eq!(code, 200);
    let snap = mgit::util::json::parse(&String::from_utf8(body).unwrap()).unwrap();
    let server_reg = snap.get("server").expect("per-server registry section");
    snap.get("process").expect("process-global registry section");
    let counters = server_reg.get("counters").unwrap();
    assert_eq!(counters.req_usize("requests_total").unwrap(), settled);
    assert_eq!(counters.req_usize("status.200").unwrap(), settled - 4);
    assert_eq!(counters.req_usize("status.404").unwrap(), 2);
    assert_eq!(counters.req_usize("status.400").unwrap(), 2);
    assert_eq!(counters.req_usize("endpoint.log").unwrap(), 16);
    assert_eq!(counters.req_usize("endpoint.checkpoint").unwrap(), 97);
    // Concurrent chain walks share ancestors through the server's
    // ResolveCache; its mirror counters must show that.
    assert!(counters.req_usize("cache.hits").unwrap() > 0, "no cache hits mirrored");
    let hist = server_reg.get("histograms").unwrap().get("request_micros").unwrap();
    assert_eq!(
        hist.req_usize("count").unwrap(),
        settled,
        "latency histogram count must equal settled requests"
    );
    assert!(hist.req_usize("p99").unwrap() >= hist.req_usize("p50").unwrap());
    assert!(!hist.req_arr("buckets").unwrap().is_empty());

    // Counters are monotonic, and the next scrape counts the previous
    // one: +1 exactly.
    let (_, body) = http_get(addr, "/metrics");
    let snap2 = mgit::util::json::parse(&String::from_utf8(body).unwrap()).unwrap();
    let counters2 = snap2.get("server").unwrap().get("counters").unwrap();
    assert_eq!(counters2.req_usize("requests_total").unwrap(), settled + 1);

    // Prometheus text rendering: typed series, cumulative buckets, and
    // the process registry (prefixed `mgit_`) alongside the server's.
    let (code, body) = http_get(addr, "/metrics?format=prom");
    assert_eq!(code, 200);
    let text = String::from_utf8(body).unwrap();
    assert!(text.contains("# TYPE mgit_serve_requests_total counter"));
    assert!(text.contains(&format!("mgit_serve_requests_total {}", settled + 2)));
    assert!(text.contains("# TYPE mgit_serve_request_micros histogram"));
    assert!(text.contains("mgit_serve_request_micros_bucket{le=\""));
    assert!(text.contains("mgit_serve_request_micros_bucket{le=\"+Inf\"}"));
    assert!(text.contains(&format!("mgit_serve_request_micros_count {}", settled + 2)));
    assert!(text.contains("mgit_store_pack_reads"), "process registry missing");

    // Non-GET methods: 405 with an explicit Allow header, JSON body.
    let (code, head, body) = http_request(
        addr,
        "POST /log HTTP/1.1\r\nHost: localhost\r\nConnection: close\r\n\r\n",
    );
    assert_eq!(code, 405);
    assert!(head.contains("Allow: GET"), "405 must carry Allow: GET, got {head}");
    let err = mgit::util::json::parse(&String::from_utf8(body).unwrap()).unwrap();
    assert!(err.req_str("error").unwrap().contains("GET"));

    handle.shutdown();
    let report = srv.join().unwrap();
    let min = (CLIENTS * 2 * (VERSIONS + 1)) as u64;
    assert!(report.requests >= min, "served {} < {min}", report.requests);
    std::fs::remove_dir_all(&dir).unwrap();
}

/// Golden shape of `/metrics` after a known sequential request mix on a
/// fresh server: per-endpoint and per-status counters, the in-flight
/// gauge (which must read 1 — the `/metrics` request itself), and
/// connection accounting.
#[test]
fn serve_metrics_golden_shape() {
    let dir = tmp_repo("metrics");
    Repo::init(&dir).unwrap();
    let server = Server::bind(Repo::open(&dir).unwrap(), None, 0, 2).unwrap();
    let addr = server.local_addr().unwrap();
    let handle = server.handle().unwrap();
    let srv = std::thread::spawn(move || server.serve().unwrap());

    let (code, _) = http_get(addr, "/healthz");
    assert_eq!(code, 200);
    let (code, _) = http_get(addr, "/log");
    assert_eq!(code, 200);
    let (code, _) = http_get(addr, "/nope");
    assert_eq!(code, 404);
    let (code, _, _) = http_request(
        addr,
        "DELETE /log HTTP/1.1\r\nHost: localhost\r\nConnection: close\r\n\r\n",
    );
    assert_eq!(code, 405);

    let (code, body) = http_get(addr, "/metrics");
    assert_eq!(code, 200);
    let snap = mgit::util::json::parse(&String::from_utf8(body).unwrap()).unwrap();
    let server_reg = snap.get("server").unwrap();
    let counters = server_reg.get("counters").unwrap();
    assert_eq!(counters.req_usize("requests_total").unwrap(), 4);
    assert_eq!(counters.req_usize("endpoint.healthz").unwrap(), 1);
    // Route-aware dispatch: the 405'd DELETE still resolves to the /log
    // endpoint label; only the 404'd unknown route lands in `other`.
    assert_eq!(counters.req_usize("endpoint.log").unwrap(), 2);
    assert_eq!(counters.req_usize("endpoint.other").unwrap(), 1);
    assert_eq!(counters.req_usize("status.200").unwrap(), 2);
    assert_eq!(counters.req_usize("status.404").unwrap(), 1);
    assert_eq!(counters.req_usize("status.405").unwrap(), 1);
    assert!(counters.req_usize("bytes_sent_total").unwrap() > 0);
    // 4 one-shot connections + the one carrying this /metrics request.
    assert_eq!(counters.req_usize("connections_total").unwrap(), 5);
    let gauges = server_reg.get("gauges").unwrap();
    assert_eq!(
        gauges.req_usize("inflight").unwrap(),
        1,
        "the in-flight request is the /metrics fetch itself"
    );
    let hist = server_reg.get("histograms").unwrap().get("request_micros").unwrap();
    assert_eq!(hist.req_usize("count").unwrap(), 4);
    assert!(hist.req_usize("sum").unwrap() > 0);

    handle.shutdown();
    srv.join().unwrap();
    std::fs::remove_dir_all(&dir).unwrap();
}

/// HTTP/1.1 keep-alive: one TCP connection carries several requests,
/// and the server's connection/request accounting proves it.
#[test]
fn serve_keep_alive_reuses_connection() {
    let dir = tmp_repo("keepalive");
    Repo::init(&dir).unwrap();
    let server = Server::bind(Repo::open(&dir).unwrap(), None, 0, 2).unwrap();
    let addr = server.local_addr().unwrap();
    let handle = server.handle().unwrap();
    let srv = std::thread::spawn(move || server.serve().unwrap());

    let mut conn = KeepAliveConn::connect(addr);
    for _ in 0..2 {
        let (code, body) = conn.get("/healthz");
        assert_eq!(code, 200);
        let ok = mgit::util::json::parse(&String::from_utf8(body).unwrap()).unwrap();
        assert_eq!(ok.get("ok"), Some(&mgit::util::json::Json::Bool(true)));
    }
    // Same connection, third request: the server saw exactly one
    // connection and has settled exactly the two /healthz requests.
    let (code, body) = conn.get("/metrics");
    assert_eq!(code, 200);
    let snap = mgit::util::json::parse(&String::from_utf8(body).unwrap()).unwrap();
    let counters = snap.get("server").unwrap().get("counters").unwrap();
    assert_eq!(counters.req_usize("connections_total").unwrap(), 1);
    assert_eq!(counters.req_usize("requests_total").unwrap(), 2);
    drop(conn);

    handle.shutdown();
    let report = srv.join().unwrap();
    assert_eq!(report.requests, 3);
    assert_eq!(report.errors, 0);
    std::fs::remove_dir_all(&dir).unwrap();
}

/// Without a manifest the graph/store endpoints still work; the
/// arch-dependent ones answer 503.
#[test]
fn serve_without_manifest_degrades() {
    let dir = tmp_repo("nozoo");
    let zoo = ModelZoo::from_json(&mgit::util::json::parse(MANIFEST).unwrap()).unwrap();
    Repo::init(&dir).unwrap();
    build_chain(&dir, &zoo);

    let server = Server::bind(Repo::open(&dir).unwrap(), None, 0, 2).unwrap();
    let addr = server.local_addr().unwrap();
    let handle = server.handle().unwrap();
    let srv = std::thread::spawn(move || server.serve().unwrap());

    let (code, _) = http_get(addr, "/log");
    assert_eq!(code, 200);
    let (code, _) = http_get(addr, "/checkpoint/m%2Fv1");
    assert_eq!(code, 503);
    let (code, _) = http_get(addr, "/diff/m%2Fv1/m%2Fv2");
    assert_eq!(code, 503);

    handle.shutdown();
    srv.join().unwrap();
    std::fs::remove_dir_all(&dir).unwrap();
}

/// `/log?limit&after&type`: chained pages reproduce exactly the bare
/// `/log` node list (whose bytes are pinned elsewhere and must not
/// change), cursors percent-decode, and bad parameters get typed 4xx.
#[test]
fn serve_log_pagination() {
    let dir = tmp_repo("logpage");
    let zoo = ModelZoo::from_json(&mgit::util::json::parse(MANIFEST).unwrap()).unwrap();
    Repo::init(&dir).unwrap();
    build_chain(&dir, &zoo);
    let server = Server::bind(Repo::open(&dir).unwrap(), None, 0, 2).unwrap();
    let addr = server.local_addr().unwrap();
    let handle = server.handle().unwrap();
    let srv = std::thread::spawn(move || server.serve().unwrap());

    // Ground truth: the bare (unpaged) listing.
    let (code, body) = http_get(addr, "/log");
    assert_eq!(code, 200);
    let names = |nodes: &[Json]| -> Vec<String> {
        nodes.iter().map(|n| n.req_str("name").unwrap().to_string()).collect()
    };
    let want = names(parse_json(&body).req_arr("nodes").unwrap());
    assert_eq!(want.len(), VERSIONS);

    // Chain pages of 2 until the cursor runs out.
    let mut got = Vec::new();
    let mut cursor: Option<String> = None;
    let mut pages = 0;
    loop {
        let path = match &cursor {
            // Cursors are node names — percent-encode the slash.
            Some(c) => format!("/log?limit=2&after={}", c.replace('/', "%2F")),
            None => "/log?limit=2".to_string(),
        };
        let (code, body) = http_get(addr, &path);
        assert_eq!(code, 200, "{path}");
        let page = parse_json(&body);
        assert_eq!(page.req_usize("total").unwrap(), VERSIONS);
        let nodes = page.req_arr("nodes").unwrap();
        assert!(nodes.len() <= 2);
        got.extend(names(nodes));
        pages += 1;
        match page.get("next_after") {
            Some(Json::Str(c)) => cursor = Some(c.clone()),
            _ => break,
        }
    }
    assert_eq!(got, want, "pages must chain to exactly the full log");
    assert_eq!(pages, VERSIONS.div_ceil(2));

    // Type filtering rides the same query.
    let (code, body) = http_get(addr, &format!("/log?limit={VERSIONS}&type=t"));
    assert_eq!(code, 200);
    assert_eq!(names(parse_json(&body).req_arr("nodes").unwrap()), want);
    let (code, body) = http_get(addr, &format!("/log?limit={VERSIONS}&type=ghost"));
    assert_eq!(code, 200);
    assert!(parse_json(&body).req_arr("nodes").unwrap().is_empty());

    // Typed failures: bad limit and unknown params are 400s, a bogus
    // cursor is a 404.
    for bad in ["/log?limit=0", "/log?limit=x", "/log?after=m%2Fv1", "/log?limit=2&bogus=1"] {
        let (code, _) = http_get(addr, bad);
        assert_eq!(code, 400, "{bad}");
    }
    let (code, _) = http_get(addr, "/log?limit=2&after=ghost");
    assert_eq!(code, 404);

    handle.shutdown();
    srv.join().unwrap();
    std::fs::remove_dir_all(&dir).unwrap();
}

// ---------------------------------------------------------------------------
// Write tier
// ---------------------------------------------------------------------------

/// Route-aware method dispatch on a read-only server: wrong methods get
/// a 405 with the route's own `Allow:` set, POSTs to write routes get a
/// 403 pointing at `--writable`, and unknown routes stay 404 regardless
/// of method.
#[test]
fn serve_write_dispatch_read_only() {
    let dir = tmp_repo("dispatch");
    Repo::init(&dir).unwrap();
    let server = Server::bind(Repo::open(&dir).unwrap(), None, 0, 2).unwrap();
    let addr = server.local_addr().unwrap();
    let handle = server.handle().unwrap();
    let srv = std::thread::spawn(move || server.serve().unwrap());

    // POST-only routes reject GET and say what they do accept.
    let (code, head, body) = http_request(
        addr,
        "GET /commit HTTP/1.1\r\nHost: localhost\r\nConnection: close\r\n\r\n",
    );
    assert_eq!(code, 405);
    assert!(head.contains("Allow: POST"), "got {head}");
    assert!(parse_json(&body).req_str("error").unwrap().contains("POST"));
    let (code, head, _) = http_request(
        addr,
        "GET /admin/repack HTTP/1.1\r\nHost: localhost\r\nConnection: close\r\n\r\n",
    );
    assert_eq!(code, 405);
    assert!(head.contains("Allow: POST"), "got {head}");

    // Dual-method routes advertise both verbs on a 405.
    let (code, head, _) = http_request(
        addr,
        "DELETE /object/aa HTTP/1.1\r\nHost: localhost\r\nConnection: close\r\n\r\n",
    );
    assert_eq!(code, 405);
    assert!(head.contains("Allow: GET, POST"), "got {head}");

    // A well-formed POST to a write route on a read-only server: 403.
    let (code, _, body) = http_post(addr, "/commit", &[], b"{}");
    assert_eq!(code, 403);
    assert!(parse_json(&body).req_str("error").unwrap().contains("read-only"));
    let (code, _, _) = http_post(addr, "/admin/repack", &[], b"");
    assert_eq!(code, 403);

    // Unknown routes are 404 before any method/capability gating.
    let (code, _, _) = http_post(addr, "/nope", &[], b"");
    assert_eq!(code, 404);

    handle.shutdown();
    let report = srv.join().unwrap();
    assert!(!report.writable);
    assert_eq!(report.commits, 0);
    std::fs::remove_dir_all(&dir).unwrap();
}

/// Bearer-token auth plus the full `POST /object` → `POST /commit` →
/// live `GET /checkpoint` lifecycle: unauthenticated writes get a 401
/// challenge while reads stay open, staged objects commit into a node
/// that is served bit-exact without a restart, and duplicate/invalid
/// commits are rejected with typed errors.
#[test]
fn serve_write_auth_and_commit_lifecycle() {
    let dir = tmp_repo("auth");
    let zoo = ModelZoo::from_json(&json::parse(MANIFEST).unwrap()).unwrap();
    Repo::init(&dir).unwrap();
    let server = Server::bind_writable(
        Repo::open(&dir).unwrap(),
        Some(zoo.clone()),
        0,
        4,
        WriteConfig {
            auth_token: Some("sekrit".to_string()),
            rate_per_sec: None,
            fold_every: ops::serve::CHECKPOINT_EVERY,
        },
    )
    .unwrap();
    let addr = server.local_addr().unwrap();
    let handle = server.handle().unwrap();
    let srv = std::thread::spawn(move || server.serve().unwrap());
    let auth = ("Authorization", "Bearer sekrit");
    let op_a1 = br#"{"name":"a/1","model_type":"t"}"#;

    // No token / wrong token: 401 with a challenge header and a JSON
    // error body; reads need no auth.
    let (code, head, body) = http_post(addr, "/commit", &[], op_a1);
    assert_eq!(code, 401);
    assert!(head.contains("WWW-Authenticate: Bearer"), "got {head}");
    assert!(parse_json(&body).req_str("error").unwrap().contains("bearer"));
    let (code, _, _) = http_post(addr, "/commit", &[("Authorization", "Bearer wrong")], op_a1);
    assert_eq!(code, 401);
    let (code, _) = http_get(addr, "/log");
    assert_eq!(code, 200);

    // Malformed bodies: 400, not 500.
    let (code, _, _) = http_post(addr, "/commit", &[auth], b"not json");
    assert_eq!(code, 400);
    let (code, _, _) = http_post(addr, "/commit", &[auth], br#"{"model_type":"t"}"#);
    assert_eq!(code, 400);

    // A metadata-only commit lands and bumps the epoch.
    let (code, _, body) = http_post(addr, "/commit", &[auth], op_a1);
    assert_eq!(code, 200, "{}", String::from_utf8_lossy(&body));
    let done = parse_json(&body);
    assert_eq!(done.get("committed"), Some(&Json::Bool(true)));
    assert_eq!(done.req_usize("epoch").unwrap(), 2);
    assert_eq!(done.req_usize("nodes").unwrap(), 1);

    // Same name again: 409.
    let (code, _, body) = http_post(addr, "/commit", &[auth], op_a1);
    assert_eq!(code, 409);
    assert!(parse_json(&body).req_str("error").unwrap().contains("already exists"));

    // Unknown provenance parent: 400.
    let (code, _, _) = http_post(
        addr,
        "/commit",
        &[auth],
        br#"{"name":"a/2","model_type":"t","prov_parents":["ghost"]}"#,
    );
    assert_eq!(code, 400);

    // A stored model whose objects were never uploaded: 409 telling the
    // client to stage them first.
    let fake = "ab".repeat(32);
    let dangling = format!(
        r#"{{"name":"a/2","model_type":"t","stored":{{"arch":"t","params":[{{"name":"w.a","id":"{fake}"}}]}}}}"#
    );
    let (code, _, body) = http_post(addr, "/commit", &[auth], dangling.as_bytes());
    assert_eq!(code, 409);
    assert!(parse_json(&body).req_str("error").unwrap().contains("POST /object"));

    // Stage the real objects (idempotently), then commit the model.
    let spec = zoo.arch("t").unwrap();
    let ck = Checkpoint::init(spec, 7);
    let mem = Store::in_memory();
    let (sm, _) = delta::store_raw(&mem, spec, &ck).unwrap();
    let mut seen = std::collections::HashSet::new();
    for (_, id) in &sm.params {
        if !seen.insert(*id) {
            continue;
        }
        let bytes = mem.get(id).unwrap();
        let (code, _, body) = http_post(addr, &format!("/object/{}", id.hex()), &[auth], &bytes);
        assert_eq!(code, 200);
        assert_eq!(parse_json(&body).get("new"), Some(&Json::Bool(true)));
        let (code, _, body) = http_post(addr, &format!("/object/{}", id.hex()), &[auth], &bytes);
        assert_eq!(code, 200);
        assert_eq!(parse_json(&body).get("new"), Some(&Json::Bool(false)), "not idempotent");
    }
    let op = Json::obj()
        .set("name", "a/2")
        .set("model_type", "t")
        .set("stored", sm.to_json())
        .to_string_compact();
    let (code, _, body) = http_post(addr, "/commit", &[auth], op.as_bytes());
    assert_eq!(code, 200, "{}", String::from_utf8_lossy(&body));

    // The committed node is live immediately — no restart — and its
    // checkpoint streams bit-exact.
    let (code, body) = http_get(addr, "/log");
    assert_eq!(code, 200);
    assert_eq!(parse_json(&body).req_arr("nodes").unwrap().len(), 2);
    let (code, body) = http_get(addr, "/checkpoint/a%2F2");
    assert_eq!(code, 200);
    assert_eq!(body, f32_to_bytes(&ck.flat));

    handle.shutdown();
    let report = srv.join().unwrap();
    assert!(report.writable);
    assert_eq!(report.commits, 2);
    assert_eq!(report.snapshot_swaps, 2);
    assert_eq!(report.errors, 0);
    std::fs::remove_dir_all(&dir).unwrap();
}

/// The write-rate token bucket: rapid-fire commits trip a 429, and a
/// 429'd request never reaches the writer (no node is created for it).
#[test]
fn serve_write_rate_limit() {
    let dir = tmp_repo("rate");
    Repo::init(&dir).unwrap();
    let server = Server::bind_writable(
        Repo::open(&dir).unwrap(),
        None,
        0,
        2,
        WriteConfig {
            auth_token: None,
            rate_per_sec: Some(1),
            fold_every: ops::serve::CHECKPOINT_EVERY,
        },
    )
    .unwrap();
    let addr = server.local_addr().unwrap();
    let handle = server.handle().unwrap();
    let srv = std::thread::spawn(move || server.serve().unwrap());

    let mut ok = 0usize;
    let mut limited = 0usize;
    for i in 0..4 {
        let op = format!(r#"{{"name":"r/{i}","model_type":"t"}}"#);
        let (code, _, body) = http_post(addr, "/commit", &[], op.as_bytes());
        match code {
            200 => ok += 1,
            429 => {
                assert!(parse_json(&body).req_str("error").unwrap().contains("rate"));
                limited += 1;
            }
            c => panic!("unexpected status {c}"),
        }
    }
    // The bucket holds a 1-token burst: at least the first succeeds, and
    // four back-to-back posts cannot all refill in time.
    assert!(ok >= 1, "no commit made it through");
    assert!(limited >= 1, "rate limit never tripped");
    assert_eq!(ok + limited, 4);

    let (code, body) = http_get(addr, "/log");
    assert_eq!(code, 200);
    assert_eq!(parse_json(&body).req_arr("nodes").unwrap().len(), ok);

    handle.shutdown();
    let report = srv.join().unwrap();
    assert_eq!(report.commits, ok as u64);
    std::fs::remove_dir_all(&dir).unwrap();
}

/// `POST /checkpoint` raw and delta forms, then `Range:` reads over the
/// result: 206 windows are byte-exact slices with `Content-Range`,
/// unsatisfiable ranges answer 416, and malformed/multi ranges fall back
/// to a full 200.
#[test]
fn serve_checkpoint_post_delta_and_range() {
    let dir = tmp_repo("ckrange");
    let zoo = ModelZoo::from_json(&json::parse(MANIFEST).unwrap()).unwrap();
    Repo::init(&dir).unwrap();
    let server = Server::bind_writable(
        Repo::open(&dir).unwrap(),
        Some(zoo.clone()),
        0,
        2,
        WriteConfig {
            auth_token: None,
            rate_per_sec: None,
            fold_every: ops::serve::CHECKPOINT_EVERY,
        },
    )
    .unwrap();
    let addr = server.local_addr().unwrap();
    let handle = server.handle().unwrap();
    let srv = std::thread::spawn(move || server.serve().unwrap());

    let spec = zoo.arch("t").unwrap();
    let v1 = Checkpoint::init(spec, 11);
    let v1_bytes = f32_to_bytes(&v1.flat);
    let total = v1_bytes.len(); // 4096 params × 4 bytes

    // Parameter validation.
    let (code, _, _) = http_post(addr, "/checkpoint/d%2Fv1", &[], &v1_bytes);
    assert_eq!(code, 400); // arch is required
    let (code, _, body) = http_post(addr, "/checkpoint/d%2Fv1?arch=zzz", &[], &v1_bytes);
    assert_eq!(code, 400);
    assert!(parse_json(&body).req_str("error").unwrap().contains("zzz"));
    let (code, _, body) = http_post(addr, "/checkpoint/d%2Fv1?arch=t", &[], &v1_bytes[..8]);
    assert_eq!(code, 400);
    assert!(parse_json(&body).req_str("error").unwrap().contains("16384"));

    // Raw upload commits and reads back bit-exact.
    let (code, _, body) = http_post(addr, "/checkpoint/d%2Fv1?arch=t", &[], &v1_bytes);
    assert_eq!(code, 200, "{}", String::from_utf8_lossy(&body));
    let resp = parse_json(&body);
    assert_eq!(resp.req_str("node").unwrap(), "d/v1");
    assert_eq!(resp.req_usize("delta_params").unwrap(), 0);
    assert_eq!(resp.req_usize("epoch").unwrap(), 2);
    assert!(resp.req_usize("new_objects").unwrap() >= 1);
    let (code, head, body) = http_get_with(addr, "/checkpoint/d%2Fv1", &[]);
    assert_eq!(code, 200);
    assert!(head.contains("Accept-Ranges: bytes"), "got {head}");
    assert_eq!(body, v1_bytes);

    // Delta upload against it; unknown prev is a 400.
    let mut rng = Rng::new(99);
    let v2 = Checkpoint {
        arch: v1.arch.clone(),
        flat: v1.flat.iter().map(|&x| x + rng.normal_f32(0.0, 3e-4)).collect(),
    };
    let v2_bytes = f32_to_bytes(&v2.flat);
    let (code, _, _) = http_post(addr, "/checkpoint/d%2Fv3?arch=t&prev=ghost", &[], &v2_bytes);
    assert_eq!(code, 400);
    let (code, _, body) =
        http_post(addr, "/checkpoint/d%2Fv2?arch=t&prev=d%2Fv1", &[], &v2_bytes);
    assert_eq!(code, 200, "{}", String::from_utf8_lossy(&body));
    assert!(parse_json(&body).req_usize("delta_params").unwrap() > 0);
    // Delta encoding quantizes (lossy), so don't demand bit-equality
    // with the posted body — demand a stable server-side reconstruction
    // of the right size.
    let (code, b1) = http_get(addr, "/checkpoint/d%2Fv2");
    assert_eq!(code, 200);
    assert_eq!(b1.len(), total);
    let (_, b2) = http_get(addr, "/checkpoint/d%2Fv2");
    assert_eq!(b1, b2);

    // Range reads over d/v1.
    let (code, head, body) = http_get_with(addr, "/checkpoint/d%2Fv1", &[("Range", "bytes=0-15")]);
    assert_eq!(code, 206);
    assert!(head.contains(&format!("Content-Range: bytes 0-15/{total}")), "got {head}");
    assert_eq!(body, &v1_bytes[..16]);
    let (code, head, body) =
        http_get_with(addr, "/checkpoint/d%2Fv1", &[("Range", "bytes=16376-")]);
    assert_eq!(code, 206);
    assert!(head.contains(&format!("Content-Range: bytes 16376-16383/{total}")), "got {head}");
    assert_eq!(body, &v1_bytes[16376..]);
    let (code, _, body) = http_get_with(addr, "/checkpoint/d%2Fv1", &[("Range", "bytes=-8")]);
    assert_eq!(code, 206);
    assert_eq!(body, &v1_bytes[total - 8..]);
    // Unaligned to the f32 grid still slices exact bytes.
    let (code, _, body) = http_get_with(addr, "/checkpoint/d%2Fv1", &[("Range", "bytes=3-9")]);
    assert_eq!(code, 206);
    assert_eq!(body, &v1_bytes[3..10]);
    // Past the end: 416 with the total advertised.
    let (code, head, _) =
        http_get_with(addr, "/checkpoint/d%2Fv1", &[("Range", "bytes=999999-1000000")]);
    assert_eq!(code, 416);
    assert!(head.contains(&format!("Content-Range: bytes */{total}")), "got {head}");
    // Malformed and multi-range specs fall back to a full 200.
    let (code, _, body) = http_get_with(addr, "/checkpoint/d%2Fv1", &[("Range", "bytes=9-2")]);
    assert_eq!(code, 200);
    assert_eq!(body, v1_bytes);
    let (code, _, body) =
        http_get_with(addr, "/checkpoint/d%2Fv1", &[("Range", "bytes=0-1,4-5")]);
    assert_eq!(code, 200);
    assert_eq!(body.len(), total);

    handle.shutdown();
    let report = srv.join().unwrap();
    assert_eq!(report.commits, 2);
    assert_eq!(report.errors, 0);
    std::fs::remove_dir_all(&dir).unwrap();
}

/// The headline concurrency run: 8 keep-alive readers hammer `/log`,
/// `/show`, `/checkpoint`, and `/metrics` while a writer commits 101
/// nodes (51 raw checkpoints + 50 metadata commits, crossing the WAL
/// auto-checkpoint threshold). Readers must never observe a torn graph:
/// `/log` never shrinks, every listed node is servable, and a pinned
/// checkpoint stays bit-exact throughout. Afterwards a live
/// `POST /admin/repack` swaps in a repacked store with the same bytes,
/// metrics settle exactly, a clean shutdown leaves an empty WAL, and a
/// cold reopen agrees with everything the server served.
#[test]
fn serve_writable_concurrent_stress() {
    const RAW: usize = 51; // raw checkpoint uploads w/v1..w/v51
    const COMMITS: usize = 2 * RAW - 1; // + meta/1..meta/50
    let dir = tmp_repo("stress");
    let zoo = ModelZoo::from_json(&json::parse(MANIFEST).unwrap()).unwrap();
    Repo::init(&dir).unwrap();
    let spec = zoo.arch("t").unwrap();
    let server = Server::bind_writable(
        Repo::open(&dir).unwrap(),
        Some(zoo.clone()),
        0,
        CLIENTS + 2,
        WriteConfig {
            auth_token: None,
            rate_per_sec: None,
            fold_every: ops::serve::CHECKPOINT_EVERY,
        },
    )
    .unwrap();
    let addr = server.local_addr().unwrap();
    let handle = server.handle().unwrap();
    let srv = std::thread::spawn(move || server.serve().unwrap());

    // Deterministic oracle for every raw checkpoint this test uploads.
    let mut oracle: HashMap<String, Vec<u8>> = HashMap::new();
    for i in 1..=RAW {
        let ck = Checkpoint::init(spec, 1000 + i as u64);
        oracle.insert(format!("w/v{i}"), f32_to_bytes(&ck.flat));
    }

    // Land w/v1 before the readers start so the checkpoint they pin
    // always exists.
    let (code, _, body) = http_post(addr, "/checkpoint/w%2Fv1?arch=t", &[], &oracle["w/v1"]);
    assert_eq!(code, 200, "{}", String::from_utf8_lossy(&body));
    let mut last_epoch = parse_json(&body).req_usize("epoch").unwrap();
    assert_eq!(last_epoch, 2);

    let done = Arc::new(AtomicBool::new(false));
    let mut readers = Vec::new();
    for r in 0..CLIENTS {
        let done = Arc::clone(&done);
        let v1 = oracle["w/v1"].clone();
        readers.push(std::thread::spawn(move || {
            let mut seen = 1usize;
            let mut iters = 0usize;
            while !done.load(Ordering::SeqCst) {
                // Fresh connection per block keeps each well under the
                // server's per-connection request cap.
                let mut conn = KeepAliveConn::connect(addr);
                for _ in 0..50 {
                    let (code, body) = conn.get("/log");
                    assert_eq!(code, 200, "reader {r}");
                    let log = parse_json(&body);
                    let nodes = log.req_arr("nodes").unwrap();
                    // Torn-read check #1: snapshots only move forward.
                    assert!(
                        nodes.len() >= seen,
                        "reader {r}: /log went backwards ({} < {seen})",
                        nodes.len()
                    );
                    seen = nodes.len();
                    // Torn-read check #2: anything a snapshot lists is
                    // fully servable from the same server.
                    let last = nodes.last().unwrap().req_str("name").unwrap();
                    let (code, _) = conn.get(&format!("/show/{}", last.replace('/', "%2F")));
                    assert_eq!(code, 200, "reader {r}: listed `{last}` not showable");
                    // Torn-read check #3: a pinned checkpoint never
                    // changes underneath a reader.
                    let (code, body) = conn.get("/checkpoint/w%2Fv1");
                    assert_eq!(code, 200, "reader {r}");
                    assert_eq!(body, v1, "reader {r}: torn checkpoint bytes");
                    let (code, _) = conn.get("/metrics");
                    assert_eq!(code, 200, "reader {r}");
                    iters += 1;
                    if done.load(Ordering::SeqCst) {
                        break;
                    }
                }
            }
            assert!(iters > 0, "reader {r} never completed an iteration");
        }));
    }

    // The writer: alternate raw checkpoint uploads and metadata-only
    // commits; every response's epoch is exactly the previous plus one
    // (single writer, no lost swaps).
    for i in 2..=RAW {
        let name = format!("w/v{i}");
        let (code, _, body) = http_post(
            addr,
            &format!("/checkpoint/{}?arch=t", name.replace('/', "%2F")),
            &[],
            &oracle[&name],
        );
        assert_eq!(code, 200, "{name}: {}", String::from_utf8_lossy(&body));
        let epoch = parse_json(&body).req_usize("epoch").unwrap();
        assert_eq!(epoch, last_epoch + 1, "{name}");
        last_epoch = epoch;
        let op = format!(r#"{{"name":"meta/{}","model_type":"t","prov_parents":["w/v1"]}}"#, i - 1);
        let (code, _, body) = http_post(addr, "/commit", &[], op.as_bytes());
        assert_eq!(code, 200, "meta/{}: {}", i - 1, String::from_utf8_lossy(&body));
        let epoch = parse_json(&body).req_usize("epoch").unwrap();
        assert_eq!(epoch, last_epoch + 1);
        last_epoch = epoch;
    }
    assert_eq!(last_epoch, COMMITS + 1);
    done.store(true, Ordering::SeqCst);
    for t in readers {
        t.join().unwrap();
    }

    // Final /log shows every commit.
    let (code, body) = http_get(addr, "/log");
    assert_eq!(code, 200);
    let log = parse_json(&body);
    let nodes = log.req_arr("nodes").unwrap();
    assert_eq!(nodes.len(), COMMITS);
    let names: std::collections::HashSet<String> =
        nodes.iter().map(|n| n.req_str("name").unwrap().to_string()).collect();
    for i in 1..=RAW {
        assert!(names.contains(&format!("w/v{i}")), "missing w/v{i}");
    }
    for i in 1..RAW {
        assert!(names.contains(&format!("meta/{i}")), "missing meta/{i}");
    }

    // Every raw checkpoint is bit-exact after the dust settles.
    for (name, want) in &oracle {
        let (code, body) = http_get(addr, &format!("/checkpoint/{}", name.replace('/', "%2F")));
        assert_eq!(code, 200, "{name}");
        assert_eq!(&body, want, "{name} not bit-exact");
    }

    // Metrics settle exactly once traffic stops.
    let (_, body) = http_get(addr, "/metrics");
    let m1 = parse_json(&body);
    let server_reg = m1.get("server").unwrap();
    let c1 = server_reg.get("counters").unwrap();
    assert_eq!(c1.req_usize("snapshot.swaps").unwrap(), COMMITS);
    assert_eq!(c1.req_usize("endpoint.commit").unwrap(), RAW - 1);
    assert_eq!(c1.req_usize("endpoint.admin").unwrap(), 0);
    assert_eq!(c1.req_usize("status.200").unwrap(), c1.req_usize("requests_total").unwrap());
    let wh = server_reg.get("histograms").unwrap().get("write_micros").unwrap();
    assert_eq!(wh.req_usize("count").unwrap(), COMMITS, "one write-latency sample per commit");
    // The next scrape counts the previous one: +1 exactly.
    let (_, body) = http_get(addr, "/metrics");
    let c2 = parse_json(&body);
    let c2 = c2.get("server").unwrap().get("counters").unwrap();
    assert_eq!(
        c2.req_usize("requests_total").unwrap(),
        c1.req_usize("requests_total").unwrap() + 1
    );

    // Live repack: the loose objects the write tier spilled migrate into
    // a pack, a new snapshot is published over the repacked store, and
    // every checkpoint still reads back bit-exact.
    let (code, _, body) = http_post(addr, "/admin/repack", &[], b"");
    assert_eq!(code, 200, "{}", String::from_utf8_lossy(&body));
    let rep = parse_json(&body);
    assert!(rep.req_usize("packs_after").unwrap() >= 1);
    assert_eq!(rep.req_usize("epoch").unwrap(), COMMITS + 2);
    for (name, want) in &oracle {
        let (code, body) = http_get(addr, &format!("/checkpoint/{}", name.replace('/', "%2F")));
        assert_eq!(code, 200, "{name} after repack");
        assert_eq!(&body, want, "{name} not bit-exact after repack");
    }

    handle.shutdown();
    let report = srv.join().unwrap();
    assert!(report.writable);
    assert_eq!(report.commits, COMMITS as u64);
    assert_eq!(report.snapshot_swaps, COMMITS as u64 + 1); // + the repack swap
    assert_eq!(report.errors, 0);

    // Clean shutdown folded the WAL into graph.json: only the file
    // header remains.
    let wal_len = std::fs::metadata(wal::wal_path(&dir)).unwrap().len();
    assert_eq!(wal_len, wal::WAL_HEADER_LEN);

    // A cold reopen agrees with everything the server served.
    let repo = Repo::open(&dir).unwrap();
    assert_eq!(repo.graph.len(), COMMITS);
    let n = repo.graph.by_name("w/v51").unwrap();
    let ck = delta::load(&repo.store, &zoo, n.stored.as_ref().unwrap(), &NativeKernel).unwrap();
    assert_eq!(f32_to_bytes(&ck.flat), oracle["w/v51"]);
    std::fs::remove_dir_all(&dir).unwrap();
}
