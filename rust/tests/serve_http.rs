//! Integration: the `mgit serve` HTTP front-end under concurrent load.
//!
//! Starts a server on an ephemeral port over a packed repository and
//! hammers it from N concurrent clients: `/log` JSON plus
//! `/checkpoint/<node>` tensor streams that must be bit-exact with what
//! `delta::load` reconstructs, and `/object/<id>` bodies byte-identical
//! to `Store::get`. The `/metrics` endpoint must account for that
//! traffic *exactly* (requests are recorded before their first response
//! byte), in both JSON and Prometheus text renderings, and keep-alive
//! connections must carry multiple requests.

use std::collections::HashMap;
use std::io::{BufRead, BufReader, Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::path::{Path, PathBuf};

use mgit::checkpoint::{Checkpoint, ModelZoo};
use mgit::delta::{self, CompressConfig, NativeKernel};
use mgit::ops::serve::Server;
use mgit::ops::{self, Repo};
use mgit::tensor::f32_to_bytes;
use mgit::util::rng::Rng;

const MANIFEST: &str = r#"{
  "vocab": 16, "max_seq": 4, "n_classes": 2, "batch": 2,
  "delta_chunk": 1024,
  "special_tokens": {"cls": 14, "mask": 15, "ignore_label": -100},
  "archs": {"t": {
      "d_model": 4, "n_layers": 1, "n_heads": 1, "d_ff": 8,
      "param_count": 4096,
      "layout": [
        {"name":"w.a","shape":[4096],"offset":0,"size":4096,"init":"normal"}
      ],
      "dag": {"nodes": [], "edges": []}
  }},
  "artifacts": {"t": {}},
  "delta_kernels": {"quant": "q", "dequant": "d"}
}"#;

const VERSIONS: usize = 6;
const CLIENTS: usize = 8;

fn tmp_repo(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("mgit-serve-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

fn build_chain(dir: &Path, zoo: &ModelZoo) {
    let spec = zoo.arch("t").unwrap();
    let mut repo = Repo::open(dir).unwrap();
    let root_ck = Checkpoint::init(spec, 1);
    let (sm, _) = delta::store_raw(&repo.store, spec, &root_ck).unwrap();
    let idx = repo.graph.add_node("m/v1", "t").unwrap();
    repo.graph.node_mut(idx).stored = Some(sm.clone());
    let mut prev = (root_ck, sm);
    let mut prev_idx = idx;
    for v in 1..VERSIONS as u64 {
        let mut rng = Rng::new(v + 30);
        let child = Checkpoint {
            arch: prev.0.arch.clone(),
            flat: prev.0.flat.iter().map(|&x| x + rng.normal_f32(0.0, 3e-4)).collect(),
        };
        let cand = delta::prepare_delta(
            &repo.store,
            spec,
            &child,
            spec,
            &prev.0,
            &prev.1,
            CompressConfig::default(),
            &NativeKernel,
        )
        .unwrap();
        delta::commit(&repo.store, &cand).unwrap();
        let name = format!("m/v{}", v + 1);
        let n = repo.graph.add_node(&name, "t").unwrap();
        repo.graph.node_mut(n).stored = Some(cand.model.clone());
        repo.graph.add_version_edge(prev_idx, n).unwrap();
        prev = (cand.checkpoint, cand.model);
        prev_idx = n;
    }
    repo.save().unwrap();
}

/// Raw one-shot HTTP exchange: returns (status code, head text, body).
fn http_request(addr: SocketAddr, request: &str) -> (u16, String, Vec<u8>) {
    let mut s = TcpStream::connect(addr).unwrap();
    s.write_all(request.as_bytes()).unwrap();
    s.flush().unwrap();
    let mut buf = Vec::new();
    s.read_to_end(&mut buf).unwrap();
    let head_end =
        buf.windows(4).position(|w| w == b"\r\n\r\n").expect("malformed response") + 4;
    let head = String::from_utf8_lossy(&buf[..head_end]).into_owned();
    let status: u16 = head
        .split_whitespace()
        .nth(1)
        .and_then(|c| c.parse().ok())
        .expect("bad status line");
    (status, head, buf[head_end..].to_vec())
}

/// Minimal HTTP/1.1 GET: returns (status code, body bytes).
fn http_get(addr: SocketAddr, path: &str) -> (u16, Vec<u8>) {
    let (status, _head, body) = http_request(
        addr,
        &format!("GET {path} HTTP/1.1\r\nHost: localhost\r\nConnection: close\r\n\r\n"),
    );
    (status, body)
}

/// A persistent (keep-alive) client connection: responses are framed by
/// `Content-Length`, so one TCP stream carries many requests.
struct KeepAliveConn {
    reader: BufReader<TcpStream>,
}

impl KeepAliveConn {
    fn connect(addr: SocketAddr) -> KeepAliveConn {
        KeepAliveConn { reader: BufReader::new(TcpStream::connect(addr).unwrap()) }
    }

    fn get(&mut self, path: &str) -> (u16, Vec<u8>) {
        write!(self.reader.get_mut(), "GET {path} HTTP/1.1\r\nHost: localhost\r\n\r\n")
            .unwrap();
        let mut line = String::new();
        self.reader.read_line(&mut line).unwrap();
        let status: u16 =
            line.split_whitespace().nth(1).and_then(|c| c.parse().ok()).unwrap();
        let mut content_len = 0usize;
        loop {
            let mut h = String::new();
            self.reader.read_line(&mut h).unwrap();
            if h == "\r\n" || h == "\n" || h.is_empty() {
                break;
            }
            if let Some(v) = h.to_ascii_lowercase().strip_prefix("content-length:") {
                content_len = v.trim().parse().unwrap();
            }
        }
        let mut body = vec![0u8; content_len];
        self.reader.read_exact(&mut body).unwrap();
        (status, body)
    }
}

#[test]
fn serve_concurrent_bit_exact() {
    let dir = tmp_repo("conc");
    let zoo = ModelZoo::from_json(&mgit::util::json::parse(MANIFEST).unwrap()).unwrap();
    Repo::init(&dir).unwrap();
    build_chain(&dir, &zoo);
    // Repack so the server reads through the pack/mmap tier, not loose
    // files.
    ops::RepackRequest::default().run(&mut Repo::open(&dir).unwrap()).unwrap();

    // Library-side ground truth: every node's resolved flat checkpoint.
    let repo = Repo::open(&dir).unwrap();
    let mut expected: HashMap<String, Vec<u8>> = HashMap::new();
    for node in &repo.graph.nodes {
        let ck = delta::load(
            &repo.store,
            &zoo,
            node.stored.as_ref().unwrap(),
            &NativeKernel,
        )
        .unwrap();
        expected.insert(node.name.clone(), f32_to_bytes(&ck.flat));
    }
    let object_id = repo.graph.by_name("m/v1").unwrap().stored.as_ref().unwrap().params[0].1;
    let object_bytes = repo.store.get(&object_id).unwrap();

    let server = Server::bind(Repo::open(&dir).unwrap(), Some(zoo.clone()), 0, CLIENTS)
        .unwrap();
    let addr = server.local_addr().unwrap();
    let handle = server.handle().unwrap();
    let srv = std::thread::spawn(move || server.serve().unwrap());

    // ≥ 8 concurrent readers, each fetching /log and every node's
    // checkpoint twice; every byte must match the library reconstruction.
    let mut clients = Vec::new();
    for _ in 0..CLIENTS {
        let expected = expected.clone();
        clients.push(std::thread::spawn(move || {
            for _round in 0..2 {
                let (code, body) = http_get(addr, "/log");
                assert_eq!(code, 200);
                let log = mgit::util::json::parse(&String::from_utf8(body).unwrap()).unwrap();
                assert_eq!(log.req_arr("nodes").unwrap().len(), VERSIONS);
                for (name, want) in &expected {
                    let (code, body) = http_get(addr, &format!("/checkpoint/{name}"));
                    assert_eq!(code, 200, "checkpoint {name}");
                    assert_eq!(&body, want, "checkpoint {name} not bit-exact");
                }
            }
        }));
    }
    for c in clients {
        c.join().unwrap();
    }

    // /object/<id> is byte-identical to Store::get.
    let (code, body) = http_get(addr, &format!("/object/{}", object_id.hex()));
    assert_eq!(code, 200);
    assert_eq!(body, object_bytes);

    // JSON endpoints + routing edges.
    let (code, body) = http_get(addr, "/stats");
    assert_eq!(code, 200);
    let stats = mgit::util::json::parse(&String::from_utf8(body).unwrap()).unwrap();
    assert_eq!(stats.req_usize("objects").unwrap(), VERSIONS);

    let (code, body) = http_get(addr, "/show/m%2Fv1");
    assert_eq!(code, 200);
    let show = mgit::util::json::parse(&String::from_utf8(body).unwrap()).unwrap();
    assert_eq!(show.req_str("name").unwrap(), "m/v1");
    // Unencoded slashes also reach single-name endpoints.
    let (code, _) = http_get(addr, "/show/m/v1");
    assert_eq!(code, 200);

    let (code, body) = http_get(addr, "/diff/m%2Fv1/m%2Fv2");
    assert_eq!(code, 200);
    let diff = mgit::util::json::parse(&String::from_utf8(body).unwrap()).unwrap();
    assert!(diff.req_f64("value_distance").unwrap() >= 0.0);

    let (code, _) = http_get(addr, "/healthz");
    assert_eq!(code, 200);
    let (code, _) = http_get(addr, "/no-such-route");
    assert_eq!(code, 404);
    let (code, _) = http_get(addr, "/checkpoint/ghost");
    assert_eq!(code, 404);
    let (code, _) = http_get(addr, "/object/zzzz");
    assert_eq!(code, 400);
    let (code, _) = http_get(addr, "/diff/only-one");
    assert_eq!(code, 400);

    // ------------------------------------------------------------------
    // /metrics accounts for everything above *exactly*: metrics are
    // recorded before a response's first byte, every response above was
    // fully read, and a /metrics snapshot excludes its own request.
    // ------------------------------------------------------------------
    // 112 concurrent (8 clients × 2 rounds × (1 /log + 6 checkpoints))
    // + 10 sequential probes above.
    let settled = 112 + 10;
    let (code, body) = http_get(addr, "/metrics");
    assert_eq!(code, 200);
    let snap = mgit::util::json::parse(&String::from_utf8(body).unwrap()).unwrap();
    let server_reg = snap.get("server").expect("per-server registry section");
    snap.get("process").expect("process-global registry section");
    let counters = server_reg.get("counters").unwrap();
    assert_eq!(counters.req_usize("requests_total").unwrap(), settled);
    assert_eq!(counters.req_usize("status.200").unwrap(), settled - 4);
    assert_eq!(counters.req_usize("status.404").unwrap(), 2);
    assert_eq!(counters.req_usize("status.400").unwrap(), 2);
    assert_eq!(counters.req_usize("endpoint.log").unwrap(), 16);
    assert_eq!(counters.req_usize("endpoint.checkpoint").unwrap(), 97);
    // Concurrent chain walks share ancestors through the server's
    // ResolveCache; its mirror counters must show that.
    assert!(counters.req_usize("cache.hits").unwrap() > 0, "no cache hits mirrored");
    let hist = server_reg.get("histograms").unwrap().get("request_micros").unwrap();
    assert_eq!(
        hist.req_usize("count").unwrap(),
        settled,
        "latency histogram count must equal settled requests"
    );
    assert!(hist.req_usize("p99").unwrap() >= hist.req_usize("p50").unwrap());
    assert!(!hist.req_arr("buckets").unwrap().is_empty());

    // Counters are monotonic, and the next scrape counts the previous
    // one: +1 exactly.
    let (_, body) = http_get(addr, "/metrics");
    let snap2 = mgit::util::json::parse(&String::from_utf8(body).unwrap()).unwrap();
    let counters2 = snap2.get("server").unwrap().get("counters").unwrap();
    assert_eq!(counters2.req_usize("requests_total").unwrap(), settled + 1);

    // Prometheus text rendering: typed series, cumulative buckets, and
    // the process registry (prefixed `mgit_`) alongside the server's.
    let (code, body) = http_get(addr, "/metrics?format=prom");
    assert_eq!(code, 200);
    let text = String::from_utf8(body).unwrap();
    assert!(text.contains("# TYPE mgit_serve_requests_total counter"));
    assert!(text.contains(&format!("mgit_serve_requests_total {}", settled + 2)));
    assert!(text.contains("# TYPE mgit_serve_request_micros histogram"));
    assert!(text.contains("mgit_serve_request_micros_bucket{le=\""));
    assert!(text.contains("mgit_serve_request_micros_bucket{le=\"+Inf\"}"));
    assert!(text.contains(&format!("mgit_serve_request_micros_count {}", settled + 2)));
    assert!(text.contains("mgit_store_pack_reads"), "process registry missing");

    // Non-GET methods: 405 with an explicit Allow header, JSON body.
    let (code, head, body) = http_request(
        addr,
        "POST /log HTTP/1.1\r\nHost: localhost\r\nConnection: close\r\n\r\n",
    );
    assert_eq!(code, 405);
    assert!(head.contains("Allow: GET"), "405 must carry Allow: GET, got {head}");
    let err = mgit::util::json::parse(&String::from_utf8(body).unwrap()).unwrap();
    assert!(err.req_str("error").unwrap().contains("GET"));

    handle.shutdown();
    let report = srv.join().unwrap();
    let min = (CLIENTS * 2 * (VERSIONS + 1)) as u64;
    assert!(report.requests >= min, "served {} < {min}", report.requests);
    std::fs::remove_dir_all(&dir).unwrap();
}

/// Golden shape of `/metrics` after a known sequential request mix on a
/// fresh server: per-endpoint and per-status counters, the in-flight
/// gauge (which must read 1 — the `/metrics` request itself), and
/// connection accounting.
#[test]
fn serve_metrics_golden_shape() {
    let dir = tmp_repo("metrics");
    Repo::init(&dir).unwrap();
    let server = Server::bind(Repo::open(&dir).unwrap(), None, 0, 2).unwrap();
    let addr = server.local_addr().unwrap();
    let handle = server.handle().unwrap();
    let srv = std::thread::spawn(move || server.serve().unwrap());

    let (code, _) = http_get(addr, "/healthz");
    assert_eq!(code, 200);
    let (code, _) = http_get(addr, "/log");
    assert_eq!(code, 200);
    let (code, _) = http_get(addr, "/nope");
    assert_eq!(code, 404);
    let (code, _, _) = http_request(
        addr,
        "DELETE /log HTTP/1.1\r\nHost: localhost\r\nConnection: close\r\n\r\n",
    );
    assert_eq!(code, 405);

    let (code, body) = http_get(addr, "/metrics");
    assert_eq!(code, 200);
    let snap = mgit::util::json::parse(&String::from_utf8(body).unwrap()).unwrap();
    let server_reg = snap.get("server").unwrap();
    let counters = server_reg.get("counters").unwrap();
    assert_eq!(counters.req_usize("requests_total").unwrap(), 4);
    assert_eq!(counters.req_usize("endpoint.healthz").unwrap(), 1);
    assert_eq!(counters.req_usize("endpoint.log").unwrap(), 1);
    // The 404'd unknown route and the 405'd DELETE both land in `other`.
    assert_eq!(counters.req_usize("endpoint.other").unwrap(), 2);
    assert_eq!(counters.req_usize("status.200").unwrap(), 2);
    assert_eq!(counters.req_usize("status.404").unwrap(), 1);
    assert_eq!(counters.req_usize("status.405").unwrap(), 1);
    assert!(counters.req_usize("bytes_sent_total").unwrap() > 0);
    // 4 one-shot connections + the one carrying this /metrics request.
    assert_eq!(counters.req_usize("connections_total").unwrap(), 5);
    let gauges = server_reg.get("gauges").unwrap();
    assert_eq!(
        gauges.req_usize("inflight").unwrap(),
        1,
        "the in-flight request is the /metrics fetch itself"
    );
    let hist = server_reg.get("histograms").unwrap().get("request_micros").unwrap();
    assert_eq!(hist.req_usize("count").unwrap(), 4);
    assert!(hist.req_usize("sum").unwrap() > 0);

    handle.shutdown();
    srv.join().unwrap();
    std::fs::remove_dir_all(&dir).unwrap();
}

/// HTTP/1.1 keep-alive: one TCP connection carries several requests,
/// and the server's connection/request accounting proves it.
#[test]
fn serve_keep_alive_reuses_connection() {
    let dir = tmp_repo("keepalive");
    Repo::init(&dir).unwrap();
    let server = Server::bind(Repo::open(&dir).unwrap(), None, 0, 2).unwrap();
    let addr = server.local_addr().unwrap();
    let handle = server.handle().unwrap();
    let srv = std::thread::spawn(move || server.serve().unwrap());

    let mut conn = KeepAliveConn::connect(addr);
    for _ in 0..2 {
        let (code, body) = conn.get("/healthz");
        assert_eq!(code, 200);
        let ok = mgit::util::json::parse(&String::from_utf8(body).unwrap()).unwrap();
        assert_eq!(ok.get("ok"), Some(&mgit::util::json::Json::Bool(true)));
    }
    // Same connection, third request: the server saw exactly one
    // connection and has settled exactly the two /healthz requests.
    let (code, body) = conn.get("/metrics");
    assert_eq!(code, 200);
    let snap = mgit::util::json::parse(&String::from_utf8(body).unwrap()).unwrap();
    let counters = snap.get("server").unwrap().get("counters").unwrap();
    assert_eq!(counters.req_usize("connections_total").unwrap(), 1);
    assert_eq!(counters.req_usize("requests_total").unwrap(), 2);
    drop(conn);

    handle.shutdown();
    let report = srv.join().unwrap();
    assert_eq!(report.requests, 3);
    assert_eq!(report.errors, 0);
    std::fs::remove_dir_all(&dir).unwrap();
}

/// Without a manifest the graph/store endpoints still work; the
/// arch-dependent ones answer 503.
#[test]
fn serve_without_manifest_degrades() {
    let dir = tmp_repo("nozoo");
    let zoo = ModelZoo::from_json(&mgit::util::json::parse(MANIFEST).unwrap()).unwrap();
    Repo::init(&dir).unwrap();
    build_chain(&dir, &zoo);

    let server = Server::bind(Repo::open(&dir).unwrap(), None, 0, 2).unwrap();
    let addr = server.local_addr().unwrap();
    let handle = server.handle().unwrap();
    let srv = std::thread::spawn(move || server.serve().unwrap());

    let (code, _) = http_get(addr, "/log");
    assert_eq!(code, 200);
    let (code, _) = http_get(addr, "/checkpoint/m%2Fv1");
    assert_eq!(code, 503);
    let (code, _) = http_get(addr, "/diff/m%2Fv1/m%2Fv2");
    assert_eq!(code, 503);

    handle.shutdown();
    srv.join().unwrap();
    std::fs::remove_dir_all(&dir).unwrap();
}
