//! Pack format v2 integration tests: backward compatibility against a
//! committed v1 fixture pack, the `repack --full` v1→v2 upgrade path,
//! decode-free metadata walks (repack mark + fsck orphan scan,
//! counter-asserted), and outer zstd framing round-trips.
//!
//! The fixture under `tests/fixtures/v1/` was written by the v1 pack
//! writer (byte layout frozen in `docs/STORAGE.md`); `fixture_objects`
//! mirrors its exact contents so reads can be asserted bit-for-bit.

use std::path::PathBuf;

use mgit::delta::NativeKernel;
use mgit::store::format::{payload_decodes, ObjectKind, TensorObject};
use mgit::store::pack::{
    chain_depths, repack, PackFraming, RepackConfig, RepackMode, IDX_VERSION, VERSION,
    VERSION_1,
};
use mgit::store::{hash_bytes, hash_tensor, ObjectId, Store};
use mgit::tensor::{f32_to_bytes, DType};

fn fixture_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("tests/fixtures/v1")
}

/// Copy the committed v1 pack + idx into a fresh store root.
fn install_fixture(tag: &str) -> PathBuf {
    let root =
        std::env::temp_dir().join(format!("mgit-v1fix-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&root);
    let pack_dir = root.join("pack");
    std::fs::create_dir_all(&pack_dir).unwrap();
    let mut copied = 0;
    for entry in std::fs::read_dir(fixture_dir()).unwrap() {
        let p = entry.unwrap().path();
        std::fs::copy(&p, pack_dir.join(p.file_name().unwrap())).unwrap();
        copied += 1;
    }
    assert_eq!(copied, 2, "fixture must hold exactly one .pack + .idx pair");
    root
}

/// The fixture's four objects, byte-for-byte (mirrors the generator
/// that produced the committed pack): two raw tensors, one delta child
/// of the first, one opaque blob.
fn fixture_objects() -> (ObjectId, Vec<(ObjectId, Vec<u8>)>) {
    let a_payload = f32_to_bytes(&[0.0, 1.0, 2.0, 3.0]);
    let a_id = hash_tensor(DType::F32, &[4], &a_payload);
    let a = TensorObject::Raw { dtype: DType::F32, shape: vec![4], payload: a_payload }
        .encode();
    let b_payload = f32_to_bytes(&[1.5, -2.5, 3.5, -4.5]);
    let b_id = hash_tensor(DType::F32, &[2, 2], &b_payload);
    let b = TensorObject::Raw { dtype: DType::F32, shape: vec![2, 2], payload: b_payload }
        .encode();
    let d_id = hash_bytes(b"mgit-fixture-delta");
    let d = TensorObject::Delta {
        dtype: DType::F32,
        shape: vec![4],
        parent: a_id,
        eps: 1e-4,
        codec: 1,
        n_quant: 4,
        grid: false,
        payload: vec![9u8; 10],
    }
    .encode();
    let o = b"mgit fixture opaque blob v1".to_vec();
    let o_id = hash_bytes(&o);
    (a_id, vec![(a_id, a), (b_id, b), (d_id, d), (o_id, o)])
}

#[test]
fn v1_fixture_reads_bit_exactly() {
    let root = install_fixture("read");
    let store = Store::open_packed(&root).unwrap();
    let (a_id, objects) = fixture_objects();
    let ps = store.as_packed().unwrap();
    assert_eq!(ps.packs().len(), 1);
    let pack = &ps.packs()[0];
    assert_eq!(pack.version, VERSION_1);
    assert_eq!(pack.framing, PackFraming::Raw);
    assert_eq!(pack.index.version, VERSION_1);
    assert_eq!(pack.object_count(), 4);
    pack.verify().expect("v1 structural verification must pass");
    for e in &pack.index.entries {
        assert_eq!(e.meta, None, "v1 index entries carry no metadata");
    }
    for (id, bytes) in &objects {
        assert_eq!(
            &store.get(id).unwrap(),
            bytes,
            "v2 code must read v1-packed object {} bit-exactly",
            id.short()
        );
    }
    // Chain metadata still works via the header-parse fallback.
    let d_id = objects[2].0;
    let meta = store.object_meta(&d_id).unwrap();
    assert_eq!(meta.kind, ObjectKind::Delta);
    assert_eq!(meta.parent, Some(a_id));
    assert!(meta.shape.is_some(), "v1 pack answers need a byte read");
    std::fs::remove_dir_all(&root).unwrap();
}

#[test]
fn repack_full_upgrades_v1_to_v2() {
    let root = install_fixture("upgrade");
    let mut store = Store::open_packed(&root).unwrap();
    let (a_id, objects) = fixture_objects();
    let (b_id, d_id, o_id) = (objects[1].0, objects[2].0, objects[3].0);
    let v1_path = store.as_packed().unwrap().packs()[0].path.clone();

    let cfg = RepackConfig {
        max_chain_depth: 8,
        mode: RepackMode::Full,
        ..RepackConfig::default()
    };
    let report = repack(&mut store, &[d_id, b_id, o_id], &cfg, &NativeKernel).unwrap();
    assert_eq!(report.packed, 4, "the delta pulls its parent live");
    assert_eq!(report.packs_after, 1);
    // Even over a v1 pack, marking parses headers — never payloads.
    assert_eq!(report.mark_payload_decodes, 0);
    assert_eq!(report.mark_meta_fallback, 4, "all four live objects are v1-packed");
    assert!(!v1_path.exists(), "the v1 pack must be replaced by the rewrite");

    // The rewritten pack is v2 with exact metadata, and its freshly
    // written index carries the v3 per-entry numel column.
    let store = Store::open_packed(&root).unwrap();
    let pack = &store.as_packed().unwrap().packs()[0];
    assert_eq!(pack.version, VERSION);
    assert_eq!(pack.framing, PackFraming::Raw);
    assert_eq!(pack.index.version, IDX_VERSION);
    pack.verify().unwrap();
    let meta = |id: &ObjectId| pack.index.entry(id).unwrap().meta.unwrap();
    assert_eq!(meta(&a_id).kind, ObjectKind::Raw);
    assert_eq!(meta(&a_id).depth, 0);
    assert_eq!(meta(&a_id).numel, Some(4), "v3 index persists tensor numel");
    assert_eq!(meta(&d_id).kind, ObjectKind::Delta);
    assert_eq!(meta(&d_id).parent, Some(a_id));
    assert_eq!(meta(&d_id).depth, 1);
    assert_eq!(meta(&d_id).numel, Some(4));
    assert_eq!(meta(&o_id).kind, ObjectKind::Opaque);
    assert_eq!(meta(&o_id).numel, Some(0), "opaque blobs have no tensor elements");

    // Bit-exact content survived the upgrade.
    for (id, bytes) in &objects {
        assert_eq!(&store.get(id).unwrap(), bytes, "upgrade changed {}", id.short());
    }

    // Chain discovery over the upgraded store is fully decode-free.
    let before = payload_decodes();
    let depths = chain_depths(&store).unwrap();
    assert_eq!(payload_decodes(), before, "v2 chain walk must not decode");
    assert_eq!(depths[&d_id], 1);
    assert_eq!(depths[&a_id], 0);

    // And a follow-up incremental mark needs no byte reads at all.
    let mut store = store;
    let inc = RepackConfig { mode: RepackMode::Incremental, ..cfg };
    let r = repack(&mut store, &[d_id, b_id, o_id], &inc, &NativeKernel).unwrap();
    assert_eq!(r.packed, 0);
    assert_eq!(r.mark_payload_decodes, 0);
    assert_eq!(r.mark_meta_fallback, 0);
    std::fs::remove_dir_all(&root).unwrap();
}

/// fsck's orphaned-parent scan over a fully v2-packed store walks pure
/// index metadata: zero payload decodes, counter-asserted — while a
/// loose delta with a missing parent is still caught via the header
/// fallback.
#[test]
fn fsck_orphan_scan_is_decode_free_on_v2() {
    use mgit::ops::{self, Report};

    let root =
        std::env::temp_dir().join(format!("mgit-fsck-meta-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&root);
    ops::Repo::init(&root).unwrap();
    let mut repo = ops::Repo::open(&root).unwrap();

    // A 3-link MGTF chain (fabricated ids — fsck checks presence and
    // parent edges, not content hashes).
    let mk_delta = |parent: ObjectId, tag: &[u8]| {
        (
            hash_bytes(tag),
            TensorObject::Delta {
                dtype: DType::F32,
                shape: vec![2],
                parent,
                eps: 1e-4,
                codec: 1,
                n_quant: 2,
                grid: false,
                payload: vec![1, 2, 3],
            }
            .encode(),
        )
    };
    let raw_payload = f32_to_bytes(&[0.5, -0.5]);
    let raw_id = hash_tensor(DType::F32, &[2], &raw_payload);
    let raw =
        TensorObject::Raw { dtype: DType::F32, shape: vec![2], payload: raw_payload }
            .encode();
    let (d1_id, d1) = mk_delta(raw_id, b"fsck-d1");
    let (d2_id, d2) = mk_delta(d1_id, b"fsck-d2");
    repo.store.put(raw_id, &raw).unwrap();
    repo.store.put(d1_id, &d1).unwrap();
    repo.store.put(d2_id, &d2).unwrap();
    repo.save().unwrap();

    // Seal everything into a v2 pack.
    let cfg = RepackConfig {
        max_chain_depth: 8,
        mode: RepackMode::Full,
        ..RepackConfig::default()
    };
    repack(&mut repo.store, &[d2_id], &cfg, &NativeKernel).unwrap();

    let repo = ops::Repo::open(&root).unwrap();
    let before = payload_decodes();
    let report = ops::FsckRequest.run(&repo).unwrap();
    assert_eq!(payload_decodes(), before, "fsck scan must not decode payloads");
    assert!(report.problems.is_empty(), "clean store: {:?}", report.failure());
    assert_eq!(report.meta_scanned, 3, "all three objects answered from the index");
    assert_eq!(report.byte_scanned, 0);

    // A loose delta pointing at a missing parent is still detected
    // (header-fallback path), and the scan stays payload-decode-free.
    let (dx_id, dx) = mk_delta(hash_bytes(b"no-such-parent"), b"fsck-dx");
    repo.store.put(dx_id, &dx).unwrap();
    let before = payload_decodes();
    let report = ops::FsckRequest.run(&repo).unwrap();
    assert_eq!(payload_decodes(), before);
    assert_eq!(report.byte_scanned, 1, "the loose delta needs a header read");
    assert!(
        report.problems.iter().any(|p| p.kind == "DANGLING"),
        "missing parent must be reported"
    );
    assert_eq!(report.orphaned.len(), 1);
    assert!(report.failure().is_some(), "fsck with problems must map to exit != 0");
    std::fs::remove_dir_all(&root).unwrap();
}

/// `mgit stats` over a fully v3-packed store answers entirely from pack
/// index metadata: zero payload decodes and zero header-read fallbacks
/// (`meta_fallback == 0`), with the logical byte accounting computed
/// from the persisted per-entry numel.
#[test]
fn stats_walks_pure_index_metadata_on_v3() {
    use mgit::ops;

    let root =
        std::env::temp_dir().join(format!("mgit-stats-meta-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&root);
    ops::Repo::init(&root).unwrap();
    let mut repo = ops::Repo::open(&root).unwrap();

    // raw → d1 → d2, same fabricated chain shape as the fsck test.
    let mk_delta = |parent: ObjectId, tag: &[u8]| {
        (
            hash_bytes(tag),
            TensorObject::Delta {
                dtype: DType::F32,
                shape: vec![2],
                parent,
                eps: 1e-4,
                codec: 1,
                n_quant: 2,
                grid: false,
                payload: vec![1, 2, 3],
            }
            .encode(),
        )
    };
    let raw_payload = f32_to_bytes(&[0.5, -0.5]);
    let raw_id = hash_tensor(DType::F32, &[2], &raw_payload);
    let raw =
        TensorObject::Raw { dtype: DType::F32, shape: vec![2], payload: raw_payload }
            .encode();
    let (d1_id, d1) = mk_delta(raw_id, b"stats-d1");
    let (d2_id, d2) = mk_delta(d1_id, b"stats-d2");
    repo.store.put(raw_id, &raw).unwrap();
    repo.store.put(d1_id, &d1).unwrap();
    repo.store.put(d2_id, &d2).unwrap();
    repo.save().unwrap();

    // Loose store: every object needs a header read for its metadata.
    let report = ops::StatsRequest.run(&repo).unwrap();
    assert_eq!(report.meta_fallback, 3, "loose objects always fall back");

    // Seal everything into a v3-indexed pack.
    let cfg = RepackConfig {
        max_chain_depth: 8,
        mode: RepackMode::Full,
        ..RepackConfig::default()
    };
    repack(&mut repo.store, &[d2_id], &cfg, &NativeKernel).unwrap();

    let repo = ops::Repo::open(&root).unwrap();
    assert_eq!(repo.store.as_packed().unwrap().packs()[0].index.version, IDX_VERSION);
    let before = payload_decodes();
    let report = ops::StatsRequest.run(&repo).unwrap();
    assert_eq!(payload_decodes(), before, "stats over v3 must not decode payloads");
    assert_eq!(report.meta_fallback, 0, "stats over v3 must not read object bytes");
    assert_eq!(report.objects, 3);
    assert_eq!(report.delta_objects, 2);
    // 3 tensors × 2 elements × 4 bytes, straight from index numel.
    assert_eq!(report.logical_bytes, 24);
    assert_eq!(report.chain_max, 2);
    std::fs::remove_dir_all(&root).unwrap();
}

/// Outer zstd framing end-to-end: `repack --full --framing zstd`
/// produces a framed pack that reads bit-exactly (through the owned
/// decoded buffer), verifies, survives a store re-open, and can be
/// re-framed back to raw.
#[cfg(feature = "zstd")]
#[test]
fn zstd_framing_repack_roundtrip() {
    use mgit::store::pack::PackFile;

    let root = install_fixture("zstd");
    let mut store = Store::open_packed(&root).unwrap();
    let (_, objects) = fixture_objects();
    let (b_id, d_id, o_id) = (objects[1].0, objects[2].0, objects[3].0);
    let roots = [d_id, b_id, o_id];

    let zstd_cfg = RepackConfig {
        max_chain_depth: 8,
        mode: RepackMode::Full,
        framing: PackFraming::Zstd,
        ..RepackConfig::default()
    };
    let report = repack(&mut store, &roots, &zstd_cfg, &NativeKernel).unwrap();
    assert_eq!(report.framing, PackFraming::Zstd);
    let pack_path = report.pack_path.unwrap();

    // Fresh handle from disk: framed pack decodes transparently.
    let store = Store::open_packed(&root).unwrap();
    let pack = &store.as_packed().unwrap().packs()[0];
    assert_eq!(pack.framing, PackFraming::Zstd);
    assert_eq!(pack.version, VERSION);
    assert_eq!(pack.reader_kind(), "owned");
    pack.verify().unwrap();
    for (id, bytes) in &objects {
        assert_eq!(&store.get(id).unwrap(), bytes, "zstd framing changed content");
    }

    // Corrupting the compressed body must be caught by verify.
    let mut bytes = std::fs::read(&pack_path).unwrap();
    let mid = bytes.len() / 2;
    bytes[mid] ^= 0xff;
    let broken = root.join("pack").join("broken.pack");
    std::fs::write(&broken, &bytes).unwrap();
    std::fs::copy(PackFile::idx_path(&pack_path), PackFile::idx_path(&broken)).unwrap();
    assert!(
        PackFile::open(&broken).is_err() || PackFile::open(&broken).unwrap().verify().is_err(),
        "corrupt zstd body must not pass verification"
    );
    std::fs::remove_file(&broken).unwrap();
    std::fs::remove_file(PackFile::idx_path(&broken)).unwrap();

    // Re-frame back to raw: identical content, mmap-class reader again.
    let mut store = Store::open_packed(&root).unwrap();
    let raw_cfg = RepackConfig { framing: PackFraming::Raw, ..zstd_cfg };
    repack(&mut store, &roots, &raw_cfg, &NativeKernel).unwrap();
    let store = Store::open_packed(&root).unwrap();
    let pack = &store.as_packed().unwrap().packs()[0];
    assert_eq!(pack.framing, PackFraming::Raw);
    assert_ne!(pack.reader_kind(), "owned");
    for (id, bytes) in &objects {
        assert_eq!(&store.get(id).unwrap(), bytes);
    }
    std::fs::remove_dir_all(&root).unwrap();
}
