//! Integration: the tiered remote store against a loopback origin.
//!
//! Spins up real `mgit serve` origins on ephemeral ports and drives the
//! remote/tiered stack end-to-end: a *fresh* repo with only
//! `.mgit/remote` configured fetches a node, pins its delta chain hot,
//! and then serves it bit-exactly **offline** (the acceptance scenario);
//! LRU eviction under a byte budget; the negative-lookup cache; bounded
//! retry with backoff against an origin that drops connections; 429
//! rate-limit backoff against a token-bucketed writable origin; `mgit
//! push` closure upload + commit (with the ver-parent 400 fallback and
//! the typed 403/401 errors); `HEAD` + `Range:` on `/object/<id>`; and
//! `mgit graph pack`.
//!
//! Origin-side request counts are asserted through each server's
//! *private* `/metrics` registry, so concurrently running tests never
//! bleed into each other; process-global tier counters are only ever
//! asserted as deltas that other tests can't decrease.

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::path::{Path, PathBuf};

use mgit::checkpoint::{Checkpoint, ModelZoo};
use mgit::delta::{self, CompressConfig, NativeKernel};
use mgit::obs;
use mgit::ops::serve::{Server, ServerHandle, WriteConfig};
use mgit::ops::{self, Repo, Report};
use mgit::store::remote::{RemoteConfig, RemoteError, RemoteStore};
use mgit::store::tiered::TieredStore;
use mgit::store::{hash_bytes, ObjectId};
use mgit::tensor::f32_to_bytes;
use mgit::util::json;
use mgit::util::rng::Rng;

const MANIFEST: &str = r#"{
  "vocab": 16, "max_seq": 4, "n_classes": 2, "batch": 2,
  "delta_chunk": 1024,
  "special_tokens": {"cls": 14, "mask": 15, "ignore_label": -100},
  "archs": {"t": {
      "d_model": 4, "n_layers": 1, "n_heads": 1, "d_ff": 8,
      "param_count": 4096,
      "layout": [
        {"name":"w.a","shape":[4096],"offset":0,"size":4096,"init":"normal"}
      ],
      "dag": {"nodes": [], "edges": []}
  }},
  "artifacts": {"t": {}},
  "delta_kernels": {"quant": "q", "dequant": "d"}
}"#;

const VERSIONS: usize = 4;

fn zoo() -> ModelZoo {
    ModelZoo::from_json(&json::parse(MANIFEST).unwrap()).unwrap()
}

fn tmp_repo(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("mgit-rtier-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// `m/v1 -> m/v2 -> ...` delta chain (version edges), like the serve
/// tests build.
fn build_chain(dir: &Path, zoo: &ModelZoo) {
    let spec = zoo.arch("t").unwrap();
    let mut repo = Repo::open(dir).unwrap();
    let root_ck = Checkpoint::init(spec, 1);
    let (sm, _) = delta::store_raw(&repo.store, spec, &root_ck).unwrap();
    let idx = repo.graph.add_node("m/v1", "t").unwrap();
    repo.graph.node_mut(idx).stored = Some(sm.clone());
    let mut prev = (root_ck, sm);
    let mut prev_idx = idx;
    for v in 1..VERSIONS as u64 {
        let mut rng = Rng::new(v + 70);
        let child = Checkpoint {
            arch: prev.0.arch.clone(),
            flat: prev.0.flat.iter().map(|&x| x + rng.normal_f32(0.0, 3e-4)).collect(),
        };
        let cand = delta::prepare_delta(
            &repo.store,
            spec,
            &child,
            spec,
            &prev.0,
            &prev.1,
            CompressConfig::default(),
            &NativeKernel,
        )
        .unwrap();
        delta::commit(&repo.store, &cand).unwrap();
        let name = format!("m/v{}", v + 1);
        let n = repo.graph.add_node(&name, "t").unwrap();
        repo.graph.node_mut(n).stored = Some(cand.model.clone());
        repo.graph.add_version_edge(prev_idx, n).unwrap();
        prev = (cand.checkpoint, cand.model);
        prev_idx = n;
    }
    repo.save().unwrap();
}

/// N independent raw-stored nodes (`r1`, `r2`, …) — every stored object
/// is the same size, which the eviction test leans on.
fn build_raw_nodes(dir: &Path, zoo: &ModelZoo, n: usize) -> Vec<ObjectId> {
    let spec = zoo.arch("t").unwrap();
    let mut repo = Repo::open(dir).unwrap();
    let mut ids = Vec::new();
    for i in 0..n {
        let ck = Checkpoint::init(spec, 100 + i as u64);
        let (sm, _) = delta::store_raw(&repo.store, spec, &ck).unwrap();
        ids.push(sm.params[0].1);
        let idx = repo.graph.add_node(&format!("r{}", i + 1), "t").unwrap();
        repo.graph.node_mut(idx).stored = Some(sm);
    }
    repo.save().unwrap();
    ids
}

fn start_origin(
    dir: &Path,
    zoo: Option<ModelZoo>,
) -> (SocketAddr, ServerHandle, std::thread::JoinHandle<()>) {
    let server = Server::bind(Repo::open(dir).unwrap(), zoo, 0, 4).unwrap();
    let addr = server.local_addr().unwrap();
    let handle = server.handle().unwrap();
    let join = std::thread::spawn(move || {
        server.serve().unwrap();
    });
    (addr, handle, join)
}

fn start_writable_origin(
    dir: &Path,
    zoo: Option<ModelZoo>,
    cfg: WriteConfig,
) -> (SocketAddr, ServerHandle, std::thread::JoinHandle<()>) {
    let server = Server::bind_writable(Repo::open(dir).unwrap(), zoo, 0, 4, cfg).unwrap();
    let addr = server.local_addr().unwrap();
    let handle = server.handle().unwrap();
    let join = std::thread::spawn(move || {
        server.serve().unwrap();
    });
    (addr, handle, join)
}

fn url_of(addr: SocketAddr) -> String {
    format!("http://127.0.0.1:{}", addr.port())
}

/// Raw one-shot HTTP exchange (`Connection: close` framing): returns
/// (status code, head text, body).
fn http_request(addr: SocketAddr, request: &str) -> (u16, String, Vec<u8>) {
    let mut s = TcpStream::connect(addr).unwrap();
    s.write_all(request.as_bytes()).unwrap();
    s.flush().unwrap();
    let mut buf = Vec::new();
    s.read_to_end(&mut buf).unwrap();
    let head_end =
        buf.windows(4).position(|w| w == b"\r\n\r\n").expect("malformed response") + 4;
    let head = String::from_utf8_lossy(&buf[..head_end]).into_owned();
    let status: u16 = head
        .split_whitespace()
        .nth(1)
        .and_then(|c| c.parse().ok())
        .expect("bad status line");
    (status, head, buf[head_end..].to_vec())
}

fn http_get(addr: SocketAddr, path: &str) -> (u16, Vec<u8>) {
    let (status, _head, body) = http_request(
        addr,
        &format!("GET {path} HTTP/1.1\r\nHost: localhost\r\nConnection: close\r\n\r\n"),
    );
    (status, body)
}

fn http_get_with(
    addr: SocketAddr,
    path: &str,
    headers: &[(&str, &str)],
) -> (u16, String, Vec<u8>) {
    let mut req = format!("GET {path} HTTP/1.1\r\nHost: localhost\r\nConnection: close\r\n");
    for (k, v) in headers {
        req.push_str(&format!("{k}: {v}\r\n"));
    }
    req.push_str("\r\n");
    http_request(addr, &req)
}

fn http_head(addr: SocketAddr, path: &str) -> (u16, String, Vec<u8>) {
    http_request(
        addr,
        &format!("HEAD {path} HTTP/1.1\r\nHost: localhost\r\nConnection: close\r\n\r\n"),
    )
}

/// This origin's private `requests_total` — the isolation-safe way to
/// prove "no wire traffic happened" (the scrape itself is excluded from
/// its own count, so consecutive scrapes with nothing in between differ
/// by exactly 1: the previous scrape).
fn origin_requests(addr: SocketAddr) -> u64 {
    let (status, body) = http_get(addr, "/metrics");
    assert_eq!(status, 200);
    let j = json::parse(std::str::from_utf8(&body).unwrap()).unwrap();
    j.req("server")
        .unwrap()
        .req("counters")
        .unwrap()
        .req_usize("requests_total")
        .unwrap() as u64
}

fn set_remote(dir: &Path, addr: SocketAddr) {
    ops::RemoteSetRequest {
        url: url_of(addr),
        auth_token: None,
        hot_bytes: None,
        prefetch: true,
    }
    .run(dir)
    .unwrap();
}

// ---------------------------------------------------------------------------
// Acceptance: fetch on a fresh repo, then serve everything offline
// ---------------------------------------------------------------------------

#[test]
fn fetch_pins_chain_then_serves_offline() {
    let zoo = zoo();
    let origin_dir = tmp_repo("accept-origin");
    Repo::init(&origin_dir).unwrap();
    build_chain(&origin_dir, &zoo);

    // Library-side ground truth from the origin repo.
    let origin_repo = Repo::open(&origin_dir).unwrap();
    let mut expected = Vec::new();
    for node in &origin_repo.graph.nodes {
        let ck = delta::load(
            &origin_repo.store,
            &zoo,
            node.stored.as_ref().unwrap(),
            &NativeKernel,
        )
        .unwrap();
        expected.push((node.name.clone(), f32_to_bytes(&ck.flat)));
    }
    drop(origin_repo);
    let (addr, handle, join) = start_origin(&origin_dir, Some(zoo.clone()));

    // A fresh repo that has never seen these models: only `.mgit/remote`.
    let local_dir = tmp_repo("accept-local");
    Repo::init(&local_dir).unwrap();
    let before_get = ops::RemoteGetRequest.run(&local_dir).unwrap();
    assert!(before_get.url.is_none());
    set_remote(&local_dir, addr);
    let after_get = ops::RemoteGetRequest.run(&local_dir).unwrap();
    assert_eq!(after_get.url.as_deref(), Some(url_of(addr).as_str()));
    assert!(!after_get.auth);

    let cold_fills = obs::global().counter("tier.cold_fills");
    let hot_hits = obs::global().counter("tier.hot_hits");
    let fills_before = cold_fills.get();

    // Fetch the tip: node metadata comes from origin /show, and the
    // whole delta chain under it is pinned hot.
    let mut repo = Repo::open(&local_dir).unwrap();
    let report =
        ops::FetchRequest { node: format!("m/v{VERSIONS}") }.run(&mut repo).unwrap();
    assert!(report.created_node);
    assert_eq!(report.params, 1);
    assert_eq!(report.objects_fetched, VERSIONS, "tip chain = 1 delta per version + raw root");
    assert!(report.bytes_fetched > 0);
    assert!(cold_fills.get() >= fills_before + VERSIONS as u64);

    // Fetch every other node: their chains are suffixes of the tip's,
    // so everything is already hot.
    for v in 1..VERSIONS {
        let mut repo = Repo::open(&local_dir).unwrap();
        let r = ops::FetchRequest { node: format!("m/v{v}") }.run(&mut repo).unwrap();
        assert!(r.created_node);
        assert_eq!(r.objects_fetched, 0, "m/v{v} chain was pinned by the tip fetch");
        assert!(r.already_hot > 0);
    }

    // Second read is pure hot tier: the origin sees zero object
    // requests between these two scrapes.
    let r0 = origin_requests(addr);
    {
        let repo = Repo::open(&local_dir).unwrap();
        let hits_before = hot_hits.get();
        for (name, want) in &expected {
            let node = repo.graph.node_by_name(name).unwrap();
            let ck =
                delta::load(&repo.store, &zoo, node.stored.as_ref().unwrap(), &NativeKernel)
                    .unwrap();
            assert_eq!(&f32_to_bytes(&ck.flat), want, "{name} not bit-exact");
        }
        assert!(hot_hits.get() > hits_before);
    }
    let r1 = origin_requests(addr);
    assert_eq!(r1 - r0, 1, "only the previous /metrics scrape, no object traffic");

    // Stats surfaces the tier and stays offline-safe.
    handle.shutdown();
    join.join().unwrap();
    {
        let repo = Repo::open(&local_dir).unwrap();
        let stats = ops::StatsRequest.run(&repo).unwrap();
        let tier = stats.tier.as_ref().expect("tiered repo reports its tier");
        assert_eq!(tier.url, url_of(addr));
        assert!(tier.prefetch);
        assert!(stats.to_json().req("tier").unwrap().req_str("url").is_ok());

        // Everything fetched still loads bit-exactly with the origin gone.
        for (name, want) in &expected {
            let node = repo.graph.node_by_name(name).unwrap();
            let ck =
                delta::load(&repo.store, &zoo, node.stored.as_ref().unwrap(), &NativeKernel)
                    .unwrap();
            assert_eq!(&f32_to_bytes(&ck.flat), want, "{name} offline load");
        }
        let fsck = ops::FsckRequest.run(&repo).unwrap();
        assert!(fsck.failure().is_none(), "offline fsck must stay green");
    }

    // A cold miss with the origin down fails descriptively, fast.
    {
        let cfg = RemoteConfig::new(&url_of(addr));
        let mut ts =
            TieredStore::open(&local_dir.join(".mgit").join("objects"), &cfg).unwrap();
        ts.remote_mut().set_max_retries(0);
        let missing = hash_bytes(b"never stored anywhere");
        let err = mgit::store::ObjectStore::get(&ts, &missing).unwrap_err();
        assert!(
            err.to_string().contains("unreachable"),
            "offline cold miss should name the origin problem, got: {err:#}"
        );
    }
}

// ---------------------------------------------------------------------------
// Eviction order + negative cache (direct TieredStore)
// ---------------------------------------------------------------------------

#[test]
fn budget_evicts_lru_fills_and_negative_cache_suppresses_misses() {
    let zoo = zoo();
    let origin_dir = tmp_repo("evict-origin");
    Repo::init(&origin_dir).unwrap();
    let ids = build_raw_nodes(&origin_dir, &zoo, 3);
    let (addr, handle, join) = start_origin(&origin_dir, None);

    // Phase 1: measure one fill's size with an unbounded scratch tier.
    let mut cfg = RemoteConfig::new(&url_of(addr));
    cfg.prefetch = false;
    let scratch = tmp_repo("evict-scratch");
    let one = {
        let ts = TieredStore::open(&scratch.join("objects"), &cfg).unwrap();
        mgit::store::ObjectStore::get(&ts, &ids[0]).unwrap();
        ts.fill_resident_bytes()
    };
    assert!(one > 0);

    // Phase 2: budget = exactly two fills. Raw objects of the same arch
    // are the same size, so the arithmetic below is exact.
    cfg.hot_bytes = Some(2 * one);
    let dir = tmp_repo("evict-hot");
    let ts = TieredStore::open(&dir.join("objects"), &cfg).unwrap();
    use mgit::store::ObjectStore;
    ts.get(&ids[0]).unwrap();
    ts.get(&ids[1]).unwrap();
    assert_eq!(ts.fill_resident_bytes(), 2 * one, "two fills fit the budget");
    // Re-reading ids[0] warms it: ids[1] is now the LRU victim.
    ts.get(&ids[0]).unwrap();
    ts.get(&ids[2]).unwrap();
    assert!(ts.hot().contains(&ids[0]), "touched fill survives");
    assert!(!ts.hot().contains(&ids[1]), "coldest fill evicted");
    assert!(ts.hot().contains(&ids[2]), "a fill is never its own victim");
    assert_eq!(ts.fill_resident_bytes(), 2 * one);

    // Negative cache: the first miss asks the origin, the second does
    // not touch the wire at all.
    let missing = hash_bytes(b"no such object");
    let e1 = ts.get(&missing).unwrap_err();
    assert!(e1.to_string().contains("not found"), "first miss is the origin's 404: {e1:#}");
    let r0 = origin_requests(addr);
    let e2 = ts.get(&missing).unwrap_err();
    assert!(
        e2.to_string().contains("negative cache"),
        "second miss answered locally: {e2:#}"
    );
    assert!(!ts.contains(&missing), "contains consults the negative cache");
    let r1 = origin_requests(addr);
    assert_eq!(r1 - r0, 1, "only the previous scrape; the repeat miss sent nothing");

    // A local put supersedes the negative entry.
    let payload = b"locally authored".to_vec();
    let new_id = hash_bytes(&payload);
    // (different id than `missing`, so insert a negative entry for it first)
    assert!(!ts.contains(&new_id));
    assert!(ts.put(new_id, &payload).unwrap());
    assert!(ts.contains(&new_id));
    assert_eq!(ts.get(&new_id).unwrap(), payload);

    handle.shutdown();
    join.join().unwrap();
}

// ---------------------------------------------------------------------------
// Retry / backoff against a flaky origin
// ---------------------------------------------------------------------------

/// A raw TCP origin that closes the first `drop_first` connections
/// without answering, then serves one canned 200 and exits.
fn flaky_origin(
    drop_first: usize,
    payload: Vec<u8>,
) -> (SocketAddr, std::thread::JoinHandle<()>) {
    let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let join = std::thread::spawn(move || {
        let mut dropped = 0usize;
        loop {
            let Ok((mut s, _)) = listener.accept() else { return };
            if dropped < drop_first {
                dropped += 1;
                drop(s);
                continue;
            }
            let mut buf = [0u8; 4096];
            let mut head = Vec::new();
            loop {
                match s.read(&mut buf) {
                    Ok(0) | Err(_) => break,
                    Ok(n) => {
                        head.extend_from_slice(&buf[..n]);
                        if head.windows(4).any(|w| w == b"\r\n\r\n") {
                            break;
                        }
                    }
                }
            }
            let resp = format!(
                "HTTP/1.1 200 OK\r\nContent-Type: application/octet-stream\r\n\
                 Content-Length: {}\r\nConnection: close\r\n\r\n",
                payload.len()
            );
            let _ = s.write_all(resp.as_bytes());
            let _ = s.write_all(&payload);
            return;
        }
    });
    (addr, join)
}

#[test]
fn retry_survives_dropped_connections_and_reports_exhaustion() {
    let payload = b"the object bytes".to_vec();
    let (addr, join) = flaky_origin(2, payload.clone());
    let retries = obs::global().counter("remote.retries");
    let retries_before = retries.get();
    let remote = RemoteStore::connect(&RemoteConfig::new(&url_of(addr))).unwrap();
    let id = hash_bytes(&payload);
    let got = remote.fetch(&id).unwrap();
    assert_eq!(got, payload, "third attempt served the bytes");
    assert!(retries.get() >= retries_before + 2, "two dropped connections = two retries");
    join.join().unwrap();

    // Exhaustion: nothing listening at all.
    let dead = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    let dead_addr = dead.local_addr().unwrap();
    drop(dead);
    let mut remote = RemoteStore::connect(&RemoteConfig::new(&url_of(dead_addr))).unwrap();
    remote.set_max_retries(1);
    match remote.fetch(&id) {
        Err(RemoteError::Unreachable { attempts, .. }) => assert_eq!(attempts, 2),
        other => panic!("expected Unreachable, got {other:?}"),
    }
}

// ---------------------------------------------------------------------------
// 429 backoff, push closure + commit, typed 403/401
// ---------------------------------------------------------------------------

#[test]
fn rate_limited_put_backs_off_until_a_token_refills() {
    let origin_dir = tmp_repo("rate-origin");
    Repo::init(&origin_dir).unwrap();
    let (addr, handle, join) = start_writable_origin(
        &origin_dir,
        None,
        WriteConfig { auth_token: None, rate_per_sec: Some(2), fold_every: 64 },
    );
    let remote = RemoteStore::connect(&RemoteConfig::new(&url_of(addr))).unwrap();
    // Drain the 2-token burst, then the third put must ride the backoff
    // loop until the bucket refills (min cumulative backoff by the 4th
    // retry comfortably covers the 0.5 s refill).
    let payloads: Vec<Vec<u8>> = (0..3).map(|i| vec![i as u8 + 1; 64]).collect();
    for p in &payloads {
        assert!(remote.put_remote(hash_bytes(p), p).unwrap(), "put of {} bytes", p.len());
    }
    handle.shutdown();
    join.join().unwrap();
}

#[test]
fn push_uploads_closure_then_commits_with_lineage() {
    let zoo = zoo();
    // Local repo with a delta chain, origin starts empty + writable.
    let local_dir = tmp_repo("push-local");
    Repo::init(&local_dir).unwrap();
    build_chain(&local_dir, &zoo);
    let origin_dir = tmp_repo("push-origin");
    Repo::init(&origin_dir).unwrap();
    let (addr, handle, join) = start_writable_origin(
        &origin_dir,
        Some(zoo.clone()),
        WriteConfig {
            auth_token: Some("sekrit".to_string()),
            rate_per_sec: None,
            fold_every: 64,
        },
    );
    ops::RemoteSetRequest {
        url: url_of(addr),
        auth_token: Some("sekrit".to_string()),
        hot_bytes: None,
        prefetch: true,
    }
    .run(&local_dir)
    .unwrap();

    let repo = Repo::open(&local_dir).unwrap();
    let r1 = ops::PushRequest { node: "m/v1".to_string() }.run(&repo).unwrap();
    assert!(r1.committed);
    assert_eq!(r1.objects_pushed, 1, "v1 is one raw object");
    assert_eq!(r1.ver_parent, None);

    // v2's closure shares v1's base object — dedup on the origin.
    let r2 = ops::PushRequest { node: "m/v2".to_string() }.run(&repo).unwrap();
    assert!(r2.committed);
    assert_eq!(r2.objects_pushed, 1, "only the delta is new");
    assert_eq!(r2.already_remote, 1, "the shared base was already there");
    assert_eq!(r2.ver_parent.as_deref(), Some("m/v1"), "origin knew the parent");

    // Idempotent re-push: everything deduped, commit answers 409.
    let r2b = ops::PushRequest { node: "m/v2".to_string() }.run(&repo).unwrap();
    assert!(!r2b.committed);
    assert_eq!(r2b.objects_pushed, 0);
    assert_eq!(r2b.already_remote, 2);

    // The origin now serves v2 bit-exactly.
    let want = {
        let node = repo.graph.node_by_name("m/v2").unwrap();
        let ck =
            delta::load(&repo.store, &zoo, node.stored.as_ref().unwrap(), &NativeKernel)
                .unwrap();
        f32_to_bytes(&ck.flat)
    };
    let (status, body) = http_get(addr, "/checkpoint/m%2Fv2");
    assert_eq!(status, 200);
    assert_eq!(body, want, "pushed checkpoint not bit-exact on the origin");

    // Wrong token → typed Unauthorized.
    let bad = RemoteStore::connect(&RemoteConfig::new(&url_of(addr))).unwrap();
    match bad.put_remote(hash_bytes(b"x"), b"x") {
        Err(RemoteError::Unauthorized { .. }) => {}
        other => panic!("expected Unauthorized, got {other:?}"),
    }
    handle.shutdown();
    join.join().unwrap();

    // Pushing to an origin that does not know the ver parent: the 400
    // answer falls back to a commit without lineage.
    let bare_dir = tmp_repo("push-bare-origin");
    Repo::init(&bare_dir).unwrap();
    let (addr2, handle2, join2) = start_writable_origin(
        &bare_dir,
        None,
        WriteConfig { auth_token: None, rate_per_sec: None, fold_every: 64 },
    );
    ops::RemoteSetRequest {
        url: url_of(addr2),
        auth_token: None,
        hot_bytes: None,
        prefetch: true,
    }
    .run(&local_dir)
    .unwrap();
    let repo = Repo::open(&local_dir).unwrap();
    let r = ops::PushRequest { node: "m/v2".to_string() }.run(&repo).unwrap();
    assert!(r.committed);
    assert_eq!(r.ver_parent, None, "unknown parent on the origin → no lineage sent");
    assert_eq!(r.objects_pushed, 2, "full closure: delta + base");
    handle2.shutdown();
    join2.join().unwrap();

    // A read-only origin refuses the object upload with the server's own
    // message in the typed error.
    let ro_dir = tmp_repo("push-ro-origin");
    Repo::init(&ro_dir).unwrap();
    let (addr3, handle3, join3) = start_origin(&ro_dir, None);
    let ro = RemoteStore::connect(&RemoteConfig::new(&url_of(addr3))).unwrap();
    match ro.put_remote(hash_bytes(b"y"), b"y") {
        Err(RemoteError::ReadOnly { server, .. }) => {
            assert!(server.contains("read-only"), "server body surfaced: {server}");
        }
        other => panic!("expected ReadOnly, got {other:?}"),
    }
    handle3.shutdown();
    join3.join().unwrap();
}

// ---------------------------------------------------------------------------
// HEAD + Range on /object (satellite)
// ---------------------------------------------------------------------------

#[test]
fn object_endpoint_head_and_range() {
    let zoo = zoo();
    let origin_dir = tmp_repo("headrange-origin");
    Repo::init(&origin_dir).unwrap();
    let ids = build_raw_nodes(&origin_dir, &zoo, 1);
    let repo = Repo::open(&origin_dir).unwrap();
    let bytes = repo.store.get(&ids[0]).unwrap();
    drop(repo);
    let (addr, handle, join) = start_origin(&origin_dir, None);
    let path = format!("/object/{}", ids[0].hex());

    // HEAD known object: full head, zero body bytes.
    let (status, head, body) = http_head(addr, &path);
    assert_eq!(status, 200);
    assert!(body.is_empty(), "HEAD must not carry a body");
    assert!(
        head.to_ascii_lowercase().contains(&format!("content-length: {}", bytes.len())),
        "HEAD advertises the full length:\n{head}"
    );

    // HEAD unknown object: 404, still no body.
    let missing = hash_bytes(b"absent");
    let (status, _head, body) = http_head(addr, &format!("/object/{}", missing.hex()));
    assert_eq!(status, 404);
    assert!(body.is_empty());

    // HEAD elsewhere stays 405 with the route's own Allow set.
    let (status, head, body) = http_head(addr, "/log");
    assert_eq!(status, 405);
    assert!(body.is_empty());
    assert!(head.contains("Allow: GET"), "Allow header present:\n{head}");

    // Range: a 4-byte window, with Content-Range bookkeeping.
    let (status, head, body) = http_get_with(addr, &path, &[("Range", "bytes=0-3")]);
    assert_eq!(status, 206);
    assert_eq!(body, &bytes[..4]);
    assert!(head.contains(&format!("Content-Range: bytes 0-3/{}", bytes.len())), "{head}");

    // Out-of-range → 416 with the total.
    let spec = format!("bytes={}-", bytes.len());
    let (status, head, _body) = http_get_with(addr, &path, &[("Range", spec.as_str())]);
    assert_eq!(status, 416);
    assert!(head.contains(&format!("Content-Range: bytes */{}", bytes.len())), "{head}");

    // Plain GET still advertises range support.
    let (status, head, body) = http_get_with(addr, &path, &[]);
    assert_eq!(status, 200);
    assert_eq!(body, bytes);
    assert!(head.contains("Accept-Ranges: bytes"), "{head}");

    handle.shutdown();
    join.join().unwrap();
}

// ---------------------------------------------------------------------------
// graph pack (satellite)
// ---------------------------------------------------------------------------

#[test]
fn graph_pack_converts_json_repo_to_binary() {
    let zoo = zoo();
    let dir = tmp_repo("graphpack");
    Repo::init(&dir).unwrap();
    build_chain(&dir, &zoo);
    let bin = Repo::graph_bin_path(&dir);
    assert!(!bin.exists());

    let report = ops::GraphPackRequest.run(&Repo::open(&dir).unwrap()).unwrap();
    assert!(!report.already_binary);
    assert_eq!(report.nodes, VERSIONS);
    assert_eq!(report.ver_edges, VERSIONS - 1);
    assert!(report.bytes > 0);
    assert!(bin.exists());
    assert_eq!(report.to_json().req_usize("nodes").unwrap(), VERSIONS);

    // The repo reopens through the binary index with everything intact.
    let repo = Repo::open(&dir).unwrap();
    assert_eq!(repo.graph.format(), "binary");
    assert!(repo.graph.node_by_name(&format!("m/v{VERSIONS}")).unwrap().stored.is_some());

    // Second run is a reported no-op.
    let again = ops::GraphPackRequest.run(&repo).unwrap();
    assert!(again.already_binary);
    assert_eq!(again.nodes, VERSIONS);
}
