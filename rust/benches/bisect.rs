//! §6.4 test bisection: finding the first failing version in a chain via
//! binary search vs linear scan (paper: up to 1.5× faster, growing with
//! chain depth).
//!
//! Chains of perturbed model versions are built without training (the
//! test cost is what matters); the "test" is a real accuracy evaluation
//! through the PJRT runtime, failing from a planted regression point on.

mod common;

use mgit::checkpoint::Checkpoint;
use mgit::lineage::{traversal, LineageGraph};
use mgit::registry::Objective;
use mgit::util::human_secs;
use mgit::util::rng::Rng;
use mgit::util::timing::Timer;

fn main() -> anyhow::Result<()> {
    let rt = common::runtime();
    let spec = rt.zoo().arch("tx-tiny")?;
    let lengths: Vec<usize> = match std::env::var("MGIT_SCALE").as_deref() {
        Ok("small") => vec![8],
        _ => vec![8, 16, 32, 64],
    };

    println!("§6.4 — test bisection vs linear scan over version chains");
    common::hr();
    println!(
        "{:>6} {:>10} {:>10} {:>12} {:>12} {:>8}",
        "chain", "bisect-ev", "scan-ev", "bisect-time", "scan-time", "speedup"
    );

    for &len in &lengths {
        // Build a version chain; versions after the regression point get
        // parameters that fail the test (scrambled head).
        let mut g = LineageGraph::new();
        let mut cks: Vec<Checkpoint> = Vec::new();
        let mut rng = Rng::new(5);
        let base = Checkpoint::init(spec, 5);
        let regression_at = len / 2 + 1;
        let mut prev = None;
        for v in 0..len {
            let idx = g.add_node(&format!("m@v{}", v + 1), "tx-tiny")?;
            let mut ck = base.clone();
            for x in ck.flat.iter_mut() {
                *x += rng.normal_f32(0.0, 1e-4);
            }
            if v >= regression_at {
                // The planted bug: NaN-free but broken parameters.
                let e = spec.entry("cls_head.w")?;
                for x in ck.flat[e.offset..e.offset + e.size].iter_mut() {
                    *x = 10.0;
                }
            }
            cks.push(ck);
            if let Some(p) = prev {
                g.add_version_edge(p, idx)?;
            }
            prev = Some(idx);
        }
        let chain = traversal::version_chain(&g, 0);

        // The failing test: param-norm explosion detector (a real MGit
        // test spec evaluated against real checkpoints; eval-based tests
        // behave identically — cost per test is what matters).
        let norm_limit = base.l2_norm() + 1.0;
        let fails = |i: usize| {
            // also run one real eval batch so the test cost is realistic
            let _ = rt.eval_many("tx-tiny", Objective::Cls, &cks[i].flat, "task1", 0, 1);
            cks[i].l2_norm() > norm_limit
        };

        // Warm the executable cache so compile time doesn't pollute the
        // first timed evaluation.
        let _ = rt.eval_many("tx-tiny", Objective::Cls, &cks[0].flat, "task1", 0, 1);

        let t = Timer::start();
        let (found_b, evals_b) = traversal::bisect_first_failure(&chain, fails);
        let tb = t.elapsed_secs();
        let t = Timer::start();
        let (found_s, evals_s) = traversal::scan_first_failure(&chain, fails);
        let ts = t.elapsed_secs();
        assert_eq!(found_b, found_s);
        assert_eq!(found_b, Some(regression_at));
        println!(
            "{:>6} {:>10} {:>10} {:>12} {:>12} {:>7.2}x",
            len,
            evals_b,
            evals_s,
            human_secs(tb),
            human_secs(ts),
            ts / tb
        );
    }
    println!("\n(speedup grows with chain depth — asymptotically n/log n)");
    Ok(())
}
