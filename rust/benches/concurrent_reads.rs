//! Concurrent pack-read bench: multi-threaded cold chain reconstruction
//! against one shared `PackedStore`, versus an emulated serialized
//! baseline (every model load behind one global mutex — the shape of the
//! pre-mmap `Mutex<File>` pack reader).
//!
//! No runtime/artifacts needed: the lineage graph is synthesized inline
//! (4 pretrained roots × 8 delta-compressed versions), fully repacked,
//! then read back cold by 1/2/4/8 reader threads splitting the model
//! list. "Cold" means a fresh `Store` handle per iteration (indexes
//! re-load, every chain re-resolves); the OS page cache stays warm, so
//! the numbers isolate read-path concurrency, which is what the mmap
//! tier changes. A final section shows the shared bounded
//! `ResolveCache` absorbing repeated ancestor materializations.

mod common;

use std::path::PathBuf;
use std::sync::Mutex;

use mgit::checkpoint::{Checkpoint, ModelZoo};
use mgit::delta::{self, CompressConfig, NativeKernel, ResolveCache, StoredModel};
use mgit::store::pack::{repack, RepackConfig, RepackMode};
use mgit::store::{ObjectId, Store};
use mgit::util::json;
use mgit::util::rng::Rng;
use mgit::util::timing::BenchStats;
use mgit::util::{human_bytes, human_secs};

/// 8 × 16 Ki-f32 tensors = 512 KiB of parameters per model.
fn manifest() -> String {
    let n_tensors = 8usize;
    let size = 16 * 1024usize;
    let layout: Vec<String> = (0..n_tensors)
        .map(|i| {
            format!(
                r#"{{"name":"w.t{i}","shape":[{size}],"offset":{},"size":{size},"init":"normal"}}"#,
                i * size
            )
        })
        .collect();
    format!(
        r#"{{
          "vocab": 16, "max_seq": 4, "n_classes": 2, "batch": 2,
          "delta_chunk": 4096,
          "special_tokens": {{"cls": 14, "mask": 15, "ignore_label": -100}},
          "archs": {{"bench": {{
              "d_model": 8, "n_layers": 1, "n_heads": 1, "d_ff": 16,
              "param_count": {},
              "layout": [{}],
              "dag": {{"nodes": [], "edges": []}}
          }}}},
          "artifacts": {{"bench": {{}}}},
          "delta_kernels": {{"quant": "q", "dequant": "d"}}
        }}"#,
        n_tensors * size,
        layout.join(",")
    )
}

fn perturbed(ck: &Checkpoint, seed: u64) -> Checkpoint {
    let mut rng = Rng::new(seed);
    let flat = ck.flat.iter().map(|&x| x + rng.normal_f32(0.0, 3e-4)).collect();
    Checkpoint { arch: ck.arch.clone(), flat }
}

/// Cold-load every model, the list split over `threads` reader threads
/// sharing one fresh `Store`. Returns total elements loaded (sanity).
fn load_concurrent(
    dir: &PathBuf,
    zoo: &ModelZoo,
    models: &[StoredModel],
    threads: usize,
    serialize: Option<&Mutex<()>>,
) -> usize {
    let store = Store::open_packed(dir).expect("open store");
    let chunk = (models.len() + threads - 1) / threads;
    std::thread::scope(|s| {
        let handles: Vec<_> = models
            .chunks(chunk)
            .map(|slab| {
                let store = &store;
                s.spawn(move || {
                    let mut elems = 0usize;
                    for m in slab {
                        let _guard = serialize.map(|l| l.lock().unwrap());
                        let ck = delta::load(store, zoo, m, &NativeKernel)
                            .expect("load model");
                        elems += ck.flat.len();
                    }
                    elems
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).sum()
    })
}

fn main() -> anyhow::Result<()> {
    let zoo = ModelZoo::from_json(&json::parse(&manifest())?)?;
    let spec = zoo.arch("bench")?;
    let dir =
        std::env::temp_dir().join(format!("mgit-bench-conc-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let store = Store::open_packed(&dir)?;

    // ------------------------------------------------------------------
    // Build the lineage graph and seal it into one pack.
    // ------------------------------------------------------------------
    let (n_roots, n_versions) = (4usize, 8usize);
    let cfg = CompressConfig::default();
    let mut models: Vec<StoredModel> = Vec::new();
    for r in 0..n_roots {
        let root = Checkpoint::init(spec, r as u64);
        let (sm, _) = delta::store_raw(&store, spec, &root)?;
        let mut prev = (root, sm.clone());
        models.push(sm);
        for v in 0..n_versions {
            let child = perturbed(&prev.0, (r * 1000 + v) as u64 + 7);
            let cand = delta::prepare_delta(
                &store, spec, &child, spec, &prev.0, &prev.1, cfg, &NativeKernel,
            )?;
            delta::commit(&store, &cand)?;
            prev = (cand.checkpoint, cand.model.clone());
            models.push(cand.model);
        }
    }
    let roots: Vec<ObjectId> = models.iter().flat_map(|m| m.refs()).collect();
    let rcfg = RepackConfig {
        max_chain_depth: 8,
        prune: true,
        mode: RepackMode::Full,
        ..RepackConfig::default()
    };
    let mut store = store;
    let report = repack(&mut store, &roots, &rcfg, &NativeKernel)?;
    let reader_kind =
        store.as_packed().unwrap().packs().first().map(|p| p.reader_kind()).unwrap_or("?");
    println!(
        "graph: {} models, {} packed objects ({}), pack reader: {reader_kind}",
        models.len(),
        report.packed,
        human_bytes(report.bytes_after),
    );
    drop(store);

    // Correctness first: every thread count reproduces identical bits.
    let reference: Vec<Checkpoint> = {
        let store = Store::open_packed(&dir)?;
        models
            .iter()
            .map(|m| delta::load(&store, &zoo, m, &NativeKernel).unwrap())
            .collect()
    };
    let expected_elems: usize = reference.iter().map(|c| c.flat.len()).sum();

    // ------------------------------------------------------------------
    // Scaling: 1/2/4/8 reader threads, lock-free pack reads.
    // ------------------------------------------------------------------
    common::hr();
    let mut results: Vec<(usize, BenchStats)> = Vec::new();
    for &threads in &[1usize, 2, 4, 8] {
        assert_eq!(load_concurrent(&dir, &zoo, &models, threads, None), expected_elems);
        let stats = BenchStats::measure(
            &format!("cold load, {threads} reader thread(s)"),
            1,
            3,
            || {
                let _ = load_concurrent(&dir, &zoo, &models, threads, None);
            },
        );
        println!("{}", stats.report());
        results.push((threads, stats));
    }

    // ------------------------------------------------------------------
    // Serialized baseline: one global lock around every model load.
    // This is *stricter* than the old per-pack Mutex<File> (which only
    // serialized the seek+read, not decompression/dequantization), so
    // read it as an upper bound on what full serialization costs, not
    // as an exact reproduction of the PR 1 reader.
    // ------------------------------------------------------------------
    common::hr();
    let big_lock = Mutex::new(());
    assert_eq!(
        load_concurrent(&dir, &zoo, &models, 8, Some(&big_lock)),
        expected_elems
    );
    let serialized = BenchStats::measure(
        "cold load, 8 threads, fully serialized (upper bound)",
        1,
        3,
        || {
            let _ = load_concurrent(&dir, &zoo, &models, 8, Some(&big_lock));
        },
    );
    println!("{}", serialized.report());

    common::hr();
    let base = results[0].1.mean();
    println!("scaling vs 1 thread (lock-free pack reads):");
    for (threads, stats) in &results {
        println!(
            "  {threads} thread(s): {:>10}  speedup {:.2}x",
            human_secs(stats.mean()),
            base / stats.mean()
        );
    }
    let best = results
        .iter()
        .map(|(_, s)| s.mean())
        .fold(f64::INFINITY, f64::min);
    println!(
        "fully-serialized upper bound: {} ({:.2}x slower than best concurrent)",
        human_secs(serialized.mean()),
        serialized.mean() / best
    );

    // ------------------------------------------------------------------
    // Shared decoded-base cache: concurrent tip loads re-use ancestors.
    // ------------------------------------------------------------------
    common::hr();
    let tips: Vec<&StoredModel> =
        models.chunks(n_versions + 1).filter_map(|c| c.last()).collect();
    let store = Store::open_packed(&dir)?;
    let cache = ResolveCache::new(512);
    std::thread::scope(|s| {
        for tip in &tips {
            let (store, zoo, cache) = (&store, &zoo, &cache);
            s.spawn(move || {
                let ck = delta::load_with_cache(store, zoo, tip, &NativeKernel, cache)
                    .expect("cached load");
                assert_eq!(ck.flat.len(), spec.param_count);
            });
        }
    });
    let (hits, misses) = cache.counters();
    println!(
        "shared ResolveCache over {} concurrent tip loads: {} hits / {} misses \
         ({:.0}% hit rate), {} tensors cached",
        tips.len(),
        hits,
        misses,
        cache.hit_rate() * 100.0,
        cache.len()
    );

    std::fs::remove_dir_all(&dir)?;
    Ok(())
}
