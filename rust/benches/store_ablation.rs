//! Ablations behind Table 4: codec ratio/throughput on realistic delta
//! payloads, the ε sweep (error bound vs compression ratio), and SHA-256
//! hashing throughput (the content-addressing cost).

mod common;

use mgit::delta::quant::{DeltaKernel, NativeKernel};
use mgit::delta::Codec;
use mgit::store::hash_bytes;
use mgit::tensor::i32_to_bytes;
use mgit::util::rng::Rng;
use mgit::util::timing::BenchStats;
use mgit::util::{human_bytes, human_secs};

fn main() -> anyhow::Result<()> {
    let n = 1 << 20; // 4 MiB of f32 — a mid-sized model's worth of deltas
    let mut rng = Rng::new(1);
    let parent: Vec<f32> = (0..n).map(|_| rng.normal_f32(0.0, 1.0)).collect();
    // Finetune-like child: small, sparse-ish drift.
    let child: Vec<f32> = parent
        .iter()
        .map(|&p| if rng.bool_with(0.3) { p + rng.normal_f32(0.0, 3e-4) } else { p })
        .collect();

    println!("Codec ablation on quantized finetune deltas ({} elements)", n);
    common::hr();
    println!(
        "{:<10} {:>9} {:>14} {:>14}",
        "codec", "ratio", "compress", "decompress"
    );
    let q = NativeKernel.quantize(&parent, &child, 1e-4)?;
    let payload = i32_to_bytes(&q);
    #[cfg(feature = "zstd")]
    let codecs = [Codec::Rle, Codec::Deflate, Codec::Zstd];
    #[cfg(not(feature = "zstd"))]
    let codecs = [Codec::Rle, Codec::Deflate];
    #[cfg(not(feature = "zstd"))]
    println!("(zstd codec skipped: rebuild with --features zstd)");
    for codec in codecs {
        let enc = codec.compress(&payload)?;
        let cs = BenchStats::measure("c", 1, 5, || {
            let _ = codec.compress(&payload).unwrap();
        });
        let ds = BenchStats::measure("d", 1, 5, || {
            let _ = codec.decompress(&enc, payload.len()).unwrap();
        });
        println!(
            "{:<10} {:>8.2}x {:>11}/s {:>11}/s",
            codec.name(),
            payload.len() as f64 / enc.len() as f64,
            human_bytes((payload.len() as f64 / cs.mean()) as u64),
            human_bytes((payload.len() as f64 / ds.mean()) as u64),
        );
    }

    println!("\nε sweep (ratio vs error bound; paper default ε=1e-4)");
    common::hr();
    println!("{:<10} {:>9} {:>14} {:>12}", "eps", "ratio", "max |err|", "zeros");
    for eps in [1e-5f32, 1e-4, 1e-3, 1e-2] {
        let q = NativeKernel.quantize(&parent, &child, eps)?;
        let rec = NativeKernel.dequantize(&parent, &q, eps)?;
        let max_err = rec
            .iter()
            .zip(&child)
            .map(|(r, c)| (r - c).abs())
            .fold(0f32, f32::max);
        let zeros = q.iter().filter(|&&x| x == 0).count();
        let enc = Codec::Deflate.compress(&i32_to_bytes(&q))?;
        println!(
            "{:<10.0e} {:>8.2}x {:>14.3e} {:>11.1}%",
            eps,
            payload.len() as f64 / enc.len() as f64,
            max_err,
            100.0 * zeros as f64 / q.len() as f64
        );
    }

    println!("\nSHA-256 content hashing throughput (the 'Hash' config's cost)");
    common::hr();
    let bytes = mgit::tensor::f32_to_bytes(&parent);
    let hs = BenchStats::measure("hash", 1, 5, || {
        let _ = hash_bytes(&bytes);
    });
    println!(
        "hash {:>10} per 4 MiB tensor  ({}/s)",
        human_secs(hs.mean()),
        human_bytes((bytes.len() as f64 / hs.mean()) as u64)
    );

    println!("\nquantize/dequantize kernel throughput (native oracle)");
    common::hr();
    let qs = BenchStats::measure("q", 1, 5, || {
        let _ = NativeKernel.quantize(&parent, &child, 1e-4).unwrap();
    });
    let dsv = BenchStats::measure("dq", 1, 5, || {
        let _ = NativeKernel.dequantize(&parent, &q, 1e-4).unwrap();
    });
    println!(
        "quantize   {:>10}  ({} elem/s)",
        human_secs(qs.mean()),
        human_bytes((n as f64 / qs.mean()) as u64)
    );
    println!(
        "dequantize {:>10}  ({} elem/s)",
        human_secs(dsv.mean()),
        human_bytes((n as f64 / dsv.mean()) as u64)
    );
    Ok(())
}
