//! Figure 3: average per-model auto-insertion time vs lineage-graph size.
//!
//! Insertion is pairwise comparison against every present model, so the
//! per-model cost grows linearly with graph size. As in the paper, larger
//! pools are made by replicating the G2 model pool ×{1,2,4,8}; models are
//! synthesized (root + finetune-like perturbations) rather than trained —
//! auto-insertion only reads parameters, so training is irrelevant here.

mod common;

use std::collections::HashMap;

use mgit::checkpoint::Checkpoint;
use mgit::store::Store;
use mgit::util::human_secs;
use mgit::util::rng::Rng;
use mgit::workloads;

fn main() -> anyhow::Result<()> {
    let rt = common::runtime();
    let zoo = rt.zoo();
    let arch = "tx-tiny";
    let spec = zoo.arch(arch)?;

    let replications: Vec<usize> = match std::env::var("MGIT_SCALE").as_deref() {
        Ok("small") => vec![1, 2],
        _ => vec![1, 2, 4, 8],
    };
    println!("Figure 3 — avg per-model insertion time vs graph size (linear growth expected)");
    common::hr();

    for &k in &replications {
        // Synthesize a G2-shaped pool replicated k times: per replica, one
        // root + 9 task children + 2 versions each (perturbed copies).
        let mut order: Vec<(String, String, Option<String>)> = Vec::new();
        let mut cks: HashMap<String, Checkpoint> = HashMap::new();
        for rep in 0..k {
            let mut rng = Rng::new(900 + rep as u64);
            let root_name = format!("r{rep}/base");
            let root = Checkpoint::init(spec, 900 + rep as u64);
            cks.insert(root_name.clone(), root.clone());
            order.push((root_name.clone(), arch.into(), None));
            for t in 0..9 {
                let child_name = format!("r{rep}/task{t}");
                let mut ck = root.clone();
                for x in ck.flat.iter_mut() {
                    *x += rng.normal_f32(0.0, 0.003);
                }
                cks.insert(child_name.clone(), ck.clone());
                order.push((child_name.clone(), arch.into(), Some(root_name.clone())));
                let mut prev_name = child_name;
                let mut prev = ck;
                for v in 0..2 {
                    let vname = format!("r{rep}/task{t}@v{}", v + 2);
                    let mut vck = prev.clone();
                    for x in vck.flat.iter_mut() {
                        *x += rng.normal_f32(0.0, 0.001);
                    }
                    cks.insert(vname.clone(), vck.clone());
                    order.push((vname.clone(), arch.into(), Some(prev_name.clone())));
                    prev_name = vname;
                    prev = vck;
                }
            }
        }
        let store = Store::in_memory();
        let (_g, correct, times) = workloads::auto_construct(
            &rt,
            &store,
            &order,
            &cks,
            &mgit::autoconstruct::AutoConfig::default(),
        )?;
        let avg = times.iter().sum::<f64>() / times.len() as f64;
        let last10: f64 = times[times.len().saturating_sub(10)..].iter().sum::<f64>()
            / 10f64.min(times.len() as f64);
        println!(
            "{:>4} models: avg insert {:>10}   tail-10 avg {:>10}   parents correct {}/{}",
            order.len(),
            human_secs(avg),
            human_secs(last10),
            correct,
            order.len()
        );
    }
    println!("\n(per-model time should grow ~linearly with pool size — pairwise diffs)");
    Ok(())
}
