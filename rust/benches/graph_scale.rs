//! Graph-scale bench: `Repo::open`, paged log, and an ancestor walk
//! over synthetic lineage graphs, JSON (`graph.json`) vs the binary
//! MGGI index (`graph.bin`).
//!
//! The numbers this exists to pin down (ISSUE: graph tier):
//! - `open bin` must beat `open json` by ≥10x at the largest size —
//!   opening a mapped binary repo is a header parse, not an O(N) JSON
//!   materialization;
//! - `log page` (limit 100, cursor in the middle of the graph) and
//!   `traverse` (1000-step version-ancestor walk) must be flat across
//!   sizes — they touch O(page) of the file, never the node set. Both
//!   run against the *unmaterialized* mapped graph and assert it stays
//!   that way.
//!
//! `MGIT_SCALE=small` (CI bench-smoke) runs 2k/10k; the full ladder is
//! 10k/100k/1M. Rows land in `$MGIT_BENCH_JSON`.

mod common;

use std::path::Path;
use std::time::Instant;

use mgit::lineage::store::GRAPH_RESIDENT_BYTES;
use mgit::ops::{LogPageRequest, Repo, SynthGraphRequest};
use mgit::util::human_bytes;

/// Best-of-`iters` wall time in microseconds.
fn best_micros<T>(iters: usize, mut f: impl FnMut() -> T) -> f64 {
    let mut best = f64::INFINITY;
    for _ in 0..iters {
        let t = Instant::now();
        let v = f();
        std::hint::black_box(&v);
        best = best.min(t.elapsed().as_secs_f64() * 1e6);
    }
    best
}

fn synth(root: &Path, nodes: usize, format: &str) {
    std::fs::create_dir_all(root).expect("bench tmp dir");
    SynthGraphRequest { nodes, shape: "chain".to_string(), format: format.to_string() }
        .run(root)
        .expect("synth-graph");
}

fn main() -> anyhow::Result<()> {
    let sizes: Vec<usize> = match std::env::var("MGIT_SCALE").as_deref() {
        Ok("small") => vec![2_000, 10_000],
        _ => vec![10_000, 100_000, 1_000_000],
    };
    println!("graph scale: Repo::open / paged log / ancestor walk, JSON vs MGGI binary");
    common::hr();
    println!(
        "{:>9}  {:>12} {:>12} {:>8}  {:>11} {:>11}  {:>10}",
        "nodes", "open json", "open bin", "speedup", "log page", "traverse", "resident"
    );
    let base = std::env::temp_dir().join(format!("mgit-graph-scale-{}", std::process::id()));
    for &n in &sizes {
        let json_root = base.join(format!("json-{n}"));
        let bin_root = base.join(format!("bin-{n}"));
        synth(&json_root, n, "json");
        synth(&bin_root, n, "bin");
        // Fewer repeats at the big end: the JSON side alone is seconds.
        let iters = if n >= 500_000 { 2 } else { 3 };

        // Opening a JSON repo parses and validates every node; the
        // deref below forces the same work the old eager path always
        // did, so the two columns compare like for like.
        let open_json = best_micros(iters, || {
            let repo = Repo::open(&json_root).expect("open json repo");
            repo.graph.len()
        });
        let open_bin = best_micros(iters, || {
            let repo = Repo::open(&bin_root).expect("open bin repo");
            repo.graph.len()
        });
        let speedup = open_json / open_bin.max(1e-9);

        let repo = Repo::open(&bin_root)?;
        let resident = GRAPH_RESIDENT_BYTES.get().max(0) as u64;

        // One 100-row page with its cursor in the middle of the graph:
        // cost must not depend on n.
        let mid = format!("n{:07}", n / 2);
        let page = LogPageRequest {
            limit: 100,
            after: Some(mid),
            model_type: None,
        };
        let logpage = best_micros(3, || {
            let report = page.run(&repo).expect("log page");
            assert_eq!(report.total, n);
            report.nodes.len()
        });

        // 1000-step walk up the version chain from the newest node:
        // O(steps) node decodes on the mapped graph.
        let steps_want = 1_000.min(n.saturating_sub(1));
        let start = format!("n{:07}", n - 1);
        let traverse = best_micros(3, || {
            let mut idx = repo.graph.idx(&start).expect("tail node");
            let mut steps = 0usize;
            while steps < steps_want {
                let node = repo.graph.node_owned(idx).expect("node decode");
                match node.ver_parents.first() {
                    Some(&p) => {
                        idx = p;
                        steps += 1;
                    }
                    None => break,
                }
            }
            assert_eq!(steps, steps_want);
            steps
        });
        assert!(
            !repo.graph.is_materialized(),
            "paged log + traversal must not materialize the mapped graph"
        );

        println!(
            "{:>9}  {:>11.0}u {:>11.0}u {:>7.1}x  {:>10.0}u {:>10.0}u  {:>10}",
            n,
            open_json,
            open_bin,
            speedup,
            logpage,
            traverse,
            human_bytes(resident)
        );
        let bench = format!("graph_scale/{n}");
        common::bench_json(&bench, "open_json_micros", open_json);
        common::bench_json(&bench, "open_bin_micros", open_bin);
        common::bench_json(&bench, "open_speedup", speedup);
        common::bench_json(&bench, "logpage100_micros", logpage);
        common::bench_json(&bench, "traverse1k_micros", traverse);
        common::bench_json(&bench, "resident_bytes", resident as f64);
    }
    common::hr();
    let _ = std::fs::remove_dir_all(&base);
    Ok(())
}
