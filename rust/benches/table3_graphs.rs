//! Table 3: the five lineage graphs — node/edge counts, build times, plus
//! the §6.1 G1 auto-insertion accuracy and the §6.4 G5 parameter-sharing
//! fraction.

mod common;

use mgit::autoconstruct::AutoConfig;
use mgit::store::Store;
use mgit::util::human_secs;
use mgit::util::timing::Timer;
use mgit::workloads::{self, Workload};

fn row(name: &str, desc: &str, wl: &Workload, secs: f64) {
    let (prov, ver) = wl.graph.edge_counts();
    println!(
        "{:<4} {:<28} {:>5} nodes / {:>5} edges ({} prov + {} ver)   built in {}",
        name,
        desc,
        wl.graph.len(),
        prov + ver,
        prov,
        ver,
        human_secs(secs)
    );
    wl.graph.integrity_check().expect("graph invariants");
}

fn main() -> anyhow::Result<()> {
    let rt = common::runtime();
    let scale = common::scale();
    println!("Table 3 — lineage graphs (paper: G1 23/21, G2 91/171, G3 60/95, G5 10/9)");
    common::hr();

    if common::graph_enabled("g1") {
        let t = Timer::start();
        let wl = workloads::build_g1(&rt, &scale)?;
        row("G1", "HuggingFace-zoo analog", &wl, t.elapsed_secs());

        // §6.1: auto-construction vs gold (paper: 22/23 correct).
        let gold = workloads::g1_gold();
        let order: Vec<_> = gold
            .iter()
            .map(|(n, a, p)| (n.to_string(), a.to_string(), p.map(String::from)))
            .collect();
        let store = Store::in_memory();
        let (_, correct, times) = workloads::auto_construct(
            &rt,
            &store,
            &order,
            &wl.checkpoints,
            &AutoConfig::default(),
        )?;
        let avg = times.iter().sum::<f64>() / times.len() as f64;
        println!(
            "     auto-insertion: {}/{} parents correct (paper 22/23); avg insert {}",
            correct,
            gold.len(),
            human_secs(avg)
        );
    }
    if common::graph_enabled("g2") {
        let t = Timer::start();
        let wl = workloads::build_g2(&rt, &scale)?;
        row("G2", "adaptation + versions", &wl, t.elapsed_secs());
    }
    if common::graph_enabled("g3") {
        let t = Timer::start();
        let wl = workloads::build_g3(&rt, &scale)?;
        row("G3", "federated learning", &wl, t.elapsed_secs());
    }
    if common::graph_enabled("g4") {
        let t = Timer::start();
        let wl = workloads::build_g4(&rt, &scale)?;
        row("G4", "edge pruning", &wl, t.elapsed_secs());
        for node in &wl.graph.nodes {
            let ck = wl.ck(&node.name)?;
            println!("     {:<32} sparsity {:>5.1}%", node.name, ck.sparsity() * 100.0);
        }
    }
    if common::graph_enabled("g5") {
        let t = Timer::start();
        let wl = workloads::build_g5(&rt, &scale)?;
        row("G5", "multi-task learning", &wl, t.elapsed_secs());

        // §6.4: fraction of parameters shared across MTL siblings
        // (paper: 98%, only head parameters are task-local).
        let names: Vec<&String> = wl.checkpoints.keys().filter(|n| n.contains("mtl")).collect();
        if names.len() >= 2 {
            let a = wl.ck(names[0])?;
            let b = wl.ck(names[1])?;
            let shared = a
                .flat
                .iter()
                .zip(&b.flat)
                .filter(|(x, y)| x == y)
                .count();
            println!(
                "     MTL parameter sharing: {:.1}% identical across siblings (paper: 98%)",
                100.0 * shared as f64 / a.flat.len() as f64
            );
        }
    }
    Ok(())
}
