//! Figure 4 + cascade-engine scaling.
//!
//! **Part 1 (always runs, no artifacts needed):** wall-clock scaling of
//! the wavefront cascade scheduler over 1/2/4/8 jobs on a cascade of
//! independent sibling models driven by a deterministic CPU-bound mock
//! executor. Reports the per-job-count speedup (the `--jobs 4` ≥ 2×
//! acceptance bar is read off this table; wall-clock is not asserted —
//! CI machines are too noisy for that) and *asserts* the other half of
//! the bar: results are bit-identical across job counts.
//!
//! **Part 2 (PJRT + artifacts):** accuracy difference between cascaded
//! children (m1'…mN') and their originals per (task × perturbation) —
//! the paper's Figure 4. Protocol (§6.4): the base MLM model m is
//! re-pretrained on a *perturbed* corpus → m'; the cascade regenerates
//! children whose creation functions never see perturbed data —
//! robustness must be inherited from m'.

mod common;

use std::collections::HashMap;
use std::sync::Mutex;

use anyhow::{anyhow, Result};
use mgit::cascade::{self, CascadeOptions};
use mgit::checkpoint::Checkpoint;
use mgit::delta::{NativeKernel, StoredModel};
use mgit::lineage::LineageGraph;
use mgit::registry::{CreationSpec, FreezeSpec, Objective};
use mgit::store::Store;
use mgit::train::{CasCheckpointStore, Trainer};
use mgit::update::{self, CheckpointStore, CreationExecutor};
use mgit::workloads::{self, PersistMode, Scale};

// ---------------------------------------------------------------------------
// Part 1: scheduler scaling (synthetic, deterministic)
// ---------------------------------------------------------------------------

/// CPU-bound deterministic executor: `work` rounds of fused
/// multiply-adds over the parent checkpoint stand in for a real
/// finetune. Identical inputs produce identical outputs regardless of
/// scheduling, so job counts can be compared bit-for-bit.
struct BusyExec {
    work: usize,
}

impl CreationExecutor for BusyExec {
    fn execute(
        &self,
        _spec: &CreationSpec,
        _arch: &str,
        parents: &[Checkpoint],
    ) -> Result<Checkpoint> {
        let mut ck = parents[0].clone();
        for _ in 0..self.work {
            for x in ck.flat.iter_mut() {
                *x = x.mul_add(1.000_000_1, 1.0e-7);
            }
        }
        std::hint::black_box(&ck.flat);
        Ok(ck)
    }

    fn execute_mtl_group(
        &self,
        specs: &[&CreationSpec],
        arch: &str,
        parents: &[Checkpoint],
    ) -> Result<Vec<Checkpoint>> {
        let one = self.execute(specs[0], arch, parents)?;
        Ok(vec![one; specs.len()])
    }
}

/// Content-keyed in-memory store (order-independent pointers).
struct MemCkStore {
    saved: Mutex<HashMap<String, Checkpoint>>,
}

impl CheckpointStore for MemCkStore {
    fn load(&self, stored: &StoredModel) -> Result<Checkpoint> {
        self.saved
            .lock()
            .unwrap()
            .get(&stored.arch)
            .cloned()
            .ok_or_else(|| anyhow!("missing {}", stored.arch))
    }

    fn save(
        &self,
        ck: &Checkpoint,
        _prev: Option<(&StoredModel, &Checkpoint)>,
    ) -> Result<StoredModel> {
        let mut h: u64 = 0xcbf29ce484222325;
        for x in &ck.flat {
            h ^= x.to_bits() as u64;
            h = h.wrapping_mul(0x100000001b3);
        }
        let key = format!("{}#{h:016x}", ck.arch);
        self.saved.lock().unwrap().insert(key.clone(), ck.clone());
        Ok(StoredModel { arch: key, params: vec![] })
    }
}

fn sibling_graph(width: usize, st: &MemCkStore) -> (LineageGraph, usize, usize) {
    let mut g = LineageGraph::new();
    let m = g.add_node("m", "t").unwrap();
    let base = Checkpoint { arch: "t".into(), flat: vec![0.5; 1 << 15] };
    g.node_mut(m).stored = Some(st.save(&base, None).unwrap());
    for i in 0..width {
        let c = g.add_node(&format!("c{i}"), "t").unwrap();
        g.add_edge(m, c).unwrap();
        g.register_creation_function(
            c,
            CreationSpec::Finetune {
                task: format!("task{i}"),
                objective: Objective::Cls,
                steps: 1,
                lr: 0.1,
                seed: i as u64,
                freeze: FreezeSpec::None,
                perturb: None,
            },
        )
        .unwrap();
        g.node_mut(c).stored = Some(st.save(&base, None).unwrap());
    }
    let m2 = g.add_node("m@v2", "t").unwrap();
    let updated = Checkpoint { arch: "t".into(), flat: vec![0.75; 1 << 15] };
    g.node_mut(m2).stored = Some(st.save(&updated, None).unwrap());
    g.add_version_edge(m, m2).unwrap();
    (g, m, m2)
}

fn scheduler_scaling() -> Result<()> {
    const WIDTH: usize = 16;
    const WORK: usize = 400;
    println!(
        "wavefront scheduler scaling: {WIDTH} independent siblings, \
         synthetic CPU-bound creations"
    );
    common::hr();
    println!("{:>6} {:>12} {:>9}", "jobs", "wall-clock", "speedup");
    let mut base_secs = 0.0f64;
    let mut reference: Option<String> = None;
    for &jobs in &[1usize, 2, 4, 8] {
        let st = MemCkStore { saved: Mutex::new(HashMap::new()) };
        let (mut g, m, m2) = sibling_graph(WIDTH, &st);
        let exec = BusyExec { work: WORK };
        let t = mgit::util::timing::Timer::start();
        let report = cascade::run(
            &mut g,
            &st,
            &exec,
            m,
            m2,
            |_, _| false,
            |_, _| false,
            &CascadeOptions { jobs, journal: None },
        )?;
        let secs = t.elapsed_secs();
        assert_eq!(report.new_versions.len(), WIDTH);
        let fingerprint = g.to_json().to_string_pretty();
        match &reference {
            None => {
                base_secs = secs;
                reference = Some(fingerprint);
            }
            Some(want) => assert_eq!(
                want, &fingerprint,
                "jobs={jobs} diverged from the serial result"
            ),
        }
        println!(
            "{:>6} {:>10.1}ms {:>8.2}x",
            jobs,
            secs * 1e3,
            if secs > 0.0 { base_secs / secs } else { 0.0 }
        );
    }
    println!("results bit-identical across job counts: yes");
    Ok(())
}

// ---------------------------------------------------------------------------
// Part 2: the paper's Figure 4 (needs PJRT + artifacts)
// ---------------------------------------------------------------------------

fn figure4() -> Result<()> {
    let rt = common::runtime();
    let zoo = rt.zoo().clone();
    let small = matches!(std::env::var("MGIT_SCALE").as_deref(), Ok("small"));
    let scale = if small {
        Scale::small()
    } else {
        Scale { n_tasks: 4, versions_per_task: 2, ..Scale::paper() }
    };
    let perturbations: &[&str] = if small {
        &["swap", "uniform_noise"]
    } else {
        &["swap", "drop", "remap", "uniform_noise", "shift"]
    };

    // Build + persist G2.
    let store = Store::in_memory();
    let mut wl = workloads::build_g2(&rt, &scale)?;
    workloads::persist(&mut wl, &store, &zoo, &rt, PersistMode::Delta(Default::default()), |_, _| {
        Ok(true)
    })?;

    // Old children's perturbed-eval accuracies.
    let tasks: Vec<String> = (0..scale.n_tasks).map(|t| format!("task{}", t + 1)).collect();
    let mut old_acc = vec![vec![0f32; perturbations.len()]; tasks.len()];
    for (ti, task) in tasks.iter().enumerate() {
        let node = wl.graph.idx(&format!("g2/{task}"))?;
        let latest = wl.graph.latest_version(node);
        let ck = wl.ck(&wl.graph.node(latest).name.clone())?;
        for (pi, p) in perturbations.iter().enumerate() {
            old_acc[ti][pi] = rt
                .eval_many_perturbed(
                    "tx-tiny",
                    Objective::Cls,
                    &ck.flat,
                    task,
                    0,
                    3,
                    Some((p, 0.3)),
                )?
                .1;
        }
    }

    // Update the root on perturbed corpus, cascade.
    let trainer = Trainer::new(&rt);
    let ckstore = CasCheckpointStore {
        store: &store,
        zoo: &zoo,
        kernel: &NativeKernel,
        compress: Some(Default::default()),
        cache: None,
    };
    let m = wl.graph.idx("g2/base-mlm")?;
    let base_ck = wl.ck("g2/base-mlm")?.clone();
    let new_ck = trainer.execute(
        &CreationSpec::Pretrain { corpus_seed: 999, steps: scale.pretrain_steps * 2, lr: scale.lr },
        "tx-tiny",
        &[base_ck],
    )?;
    let sm = ckstore.save(&new_ck, None)?;
    let m_new = wl.graph.add_node("g2/base-mlm@v2", "tx-tiny")?;
    wl.graph.node_mut(m_new).stored = Some(sm);
    wl.graph.add_version_edge(m, m_new)?;
    let report = update::run_update_cascade(
        &mut wl.graph,
        &ckstore,
        &trainer,
        m,
        m_new,
        |_, _| false,
        |_, _| false,
    )?;
    println!(
        "cascade regenerated {} children (skipped {} without cr)\n",
        report.new_versions.len(),
        report.skipped_no_cr.len()
    );

    // New children's accuracies; print the Figure-4 matrix.
    print!("{:<8}", "task");
    for p in perturbations {
        print!(" {:>14}", p);
    }
    println!();
    common::hr();
    let mut positive = 0;
    let mut total = 0;
    for (ti, task) in tasks.iter().enumerate() {
        let node = wl.graph.idx(&format!("g2/{task}"))?;
        let latest = wl.graph.latest_version(node);
        let sm = wl.graph.node(latest).stored.clone().unwrap();
        let ck = ckstore.load(&sm)?;
        print!("{:<8}", task);
        for (pi, p) in perturbations.iter().enumerate() {
            let acc = rt
                .eval_many_perturbed(
                    "tx-tiny",
                    Objective::Cls,
                    &ck.flat,
                    task,
                    0,
                    3,
                    Some((p, 0.3)),
                )?
                .1;
            let d = acc - old_acc[ti][pi];
            if d >= 0.0 {
                positive += 1;
            }
            total += 1;
            print!(" {:>+14.3}", d);
        }
        println!();
    }
    common::hr();
    println!(
        "Δacc ≥ 0 in {positive}/{total} (task, perturbation) cells \
         (paper: positive for most perturbations and tasks)"
    );
    Ok(())
}

fn main() -> Result<()> {
    scheduler_scaling()?;
    println!();
    if !mgit::runtime::HAS_PJRT {
        println!(
            "skipping Figure-4 accuracy matrix: built without the `pjrt` feature \
             (rebuild with --features pjrt after `make artifacts`)"
        );
        return Ok(());
    }
    figure4()
}
