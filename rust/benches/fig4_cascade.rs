//! Figure 4: accuracy difference between cascaded children (m1'…mN') and
//! their originals (m1…mN) per (task × perturbation).
//!
//! Protocol (paper §6.4): the base MLM model m is re-pretrained on a
//! *perturbed* corpus → m'; `run_update_cascade` regenerates children
//! whose creation functions never see perturbed data — robustness must be
//! inherited from m'. Positive Δacc on perturbed eval sets = the paper's
//! "superior performance (accuracy difference > 0) for most
//! perturbations".

mod common;

use mgit::delta::NativeKernel;
use mgit::registry::{CreationSpec, Objective};
use mgit::store::Store;
use mgit::train::{CasCheckpointStore, Trainer};
use mgit::update::{self, CheckpointStore, CreationExecutor};
use mgit::workloads::{self, PersistMode, Scale};

fn main() -> anyhow::Result<()> {
    let rt = common::runtime();
    let zoo = rt.zoo().clone();
    let small = matches!(std::env::var("MGIT_SCALE").as_deref(), Ok("small"));
    let scale = if small {
        Scale::small()
    } else {
        Scale { n_tasks: 4, versions_per_task: 2, ..Scale::paper() }
    };
    let perturbations: &[&str] = if small {
        &["swap", "uniform_noise"]
    } else {
        &["swap", "drop", "remap", "uniform_noise", "shift"]
    };

    // Build + persist G2.
    let store = Store::in_memory();
    let mut wl = workloads::build_g2(&rt, &scale)?;
    workloads::persist(&mut wl, &store, &zoo, &rt, PersistMode::Delta(Default::default()), |_, _| {
        Ok(true)
    })?;

    // Old children's perturbed-eval accuracies.
    let tasks: Vec<String> = (0..scale.n_tasks).map(|t| format!("task{}", t + 1)).collect();
    let mut old_acc = vec![vec![0f32; perturbations.len()]; tasks.len()];
    for (ti, task) in tasks.iter().enumerate() {
        let node = wl.graph.idx(&format!("g2/{task}"))?;
        let latest = wl.graph.latest_version(node);
        let ck = wl.ck(&wl.graph.node(latest).name.clone())?;
        for (pi, p) in perturbations.iter().enumerate() {
            old_acc[ti][pi] = rt
                .eval_many_perturbed("tx-tiny", Objective::Cls, &ck.flat, task, 0, 3, Some((p, 0.3)))?
                .1;
        }
    }

    // Update the root on perturbed corpus, cascade.
    let mut trainer = Trainer::new(&rt);
    let mut ckstore = CasCheckpointStore {
        store: &store,
        zoo: &zoo,
        kernel: &NativeKernel,
        compress: Some(Default::default()),
    };
    let m = wl.graph.idx("g2/base-mlm")?;
    let base_ck = wl.ck("g2/base-mlm")?.clone();
    let new_ck = trainer.execute(
        &CreationSpec::Pretrain { corpus_seed: 999, steps: scale.pretrain_steps * 2, lr: scale.lr },
        "tx-tiny",
        &[base_ck],
    )?;
    let sm = ckstore.save(&new_ck, None)?;
    let m_new = wl.graph.add_node("g2/base-mlm@v2", "tx-tiny")?;
    wl.graph.node_mut(m_new).stored = Some(sm);
    wl.graph.add_version_edge(m, m_new)?;
    let report = update::run_update_cascade(
        &mut wl.graph,
        &mut ckstore,
        &mut trainer,
        m,
        m_new,
        |_, _| false,
        |_, _| false,
    )?;
    println!(
        "cascade regenerated {} children (skipped {} without cr)\n",
        report.new_versions.len(),
        report.skipped_no_cr.len()
    );

    // New children's accuracies; print the Figure-4 matrix.
    print!("{:<8}", "task");
    for p in perturbations {
        print!(" {:>14}", p);
    }
    println!();
    common::hr();
    let mut positive = 0;
    let mut total = 0;
    for (ti, task) in tasks.iter().enumerate() {
        let node = wl.graph.idx(&format!("g2/{task}"))?;
        let latest = wl.graph.latest_version(node);
        let sm = wl.graph.node(latest).stored.clone().unwrap();
        let ck = ckstore.load(&sm)?;
        print!("{:<8}", task);
        for (pi, p) in perturbations.iter().enumerate() {
            let acc = rt
                .eval_many_perturbed("tx-tiny", Objective::Cls, &ck.flat, task, 0, 3, Some((p, 0.3)))?
                .1;
            let d = acc - old_acc[ti][pi];
            if d >= 0.0 {
                positive += 1;
            }
            total += 1;
            print!(" {:>+14.3}", d);
        }
        println!();
    }
    common::hr();
    println!(
        "Δacc ≥ 0 in {positive}/{total} (task, perturbation) cells \
         (paper: positive for most perturbations and tasks)"
    );
    Ok(())
}
