//! Runtime hot-path latencies (the §Perf baseline of EXPERIMENTS.md):
//! train/eval step per architecture, and the PJRT Pallas delta kernels vs
//! the native oracle.

mod common;

use mgit::checkpoint::Checkpoint;
use mgit::data;
use mgit::delta::quant::{DeltaKernel, NativeKernel};
use mgit::registry::Objective;
use mgit::util::human_secs;
use mgit::util::rng::Rng;
use mgit::util::timing::BenchStats;

fn main() -> anyhow::Result<()> {
    let rt = common::runtime();
    let zoo = rt.zoo().clone();
    let small = matches!(std::env::var("MGIT_SCALE").as_deref(), Ok("small"));
    let iters = if small { 5 } else { 20 };

    println!("L3/L2 hot path: train & eval step latency per architecture");
    common::hr();
    let archs: Vec<&str> =
        if small { vec!["tx-tiny"] } else { vec!["tx-tiny", "tx-small", "tx-base"] };
    for arch in archs {
        let spec = zoo.arch(arch)?;
        let mut params = Checkpoint::init(spec, 1).flat;
        let mut mom = vec![0f32; params.len()];
        let batch = data::cls_batch("task1", zoo.batch, zoo.max_seq, 0, 0, None)?;
        let ts = BenchStats::measure(&format!("{arch} train"), 2, iters, || {
            rt.train_step(arch, Objective::Cls, &mut params, &mut mom, &batch, 0.01)
                .unwrap();
        });
        let es = BenchStats::measure(&format!("{arch} eval"), 2, iters, || {
            rt.eval_step(arch, Objective::Cls, &params, &batch).unwrap();
        });
        println!("{}", ts.report());
        println!("{}", es.report());
        println!(
            "   ({} params; train moves {:.1} MB of params per step host<->device)",
            spec.param_count,
            2.0 * 2.0 * spec.param_count as f64 * 4.0 / 1e6
        );
    }

    println!("\nL1 hot path: delta kernels, PJRT (AOT Pallas) vs native");
    common::hr();
    let n = 1 << 20;
    let mut rng = Rng::new(2);
    let parent: Vec<f32> = (0..n).map(|_| rng.normal_f32(0.0, 1.0)).collect();
    let child: Vec<f32> = parent.iter().map(|&p| p + rng.normal_f32(0.0, 3e-4)).collect();
    let q = NativeKernel.quantize(&parent, &child, 1e-4)?;

    let s = BenchStats::measure("quantize   pjrt", 1, iters.min(10), || {
        let _ = rt.quantize(&parent, &child, 1e-4).unwrap();
    });
    println!("{}   ({:.0} M elem/s)", s.report(), n as f64 / s.mean() / 1e6);
    let s = BenchStats::measure("quantize   native", 1, iters.min(10), || {
        let _ = NativeKernel.quantize(&parent, &child, 1e-4).unwrap();
    });
    println!("{}   ({:.0} M elem/s)", s.report(), n as f64 / s.mean() / 1e6);
    let s = BenchStats::measure("dequantize pjrt", 1, iters.min(10), || {
        let _ = rt.dequantize(&parent, &q, 1e-4).unwrap();
    });
    println!("{}   ({:.0} M elem/s)", s.report(), n as f64 / s.mean() / 1e6);
    let s = BenchStats::measure("dequantize native", 1, iters.min(10), || {
        let _ = NativeKernel.dequantize(&parent, &q, 1e-4).unwrap();
    });
    println!("{}   ({:.0} M elem/s)", s.report(), n as f64 / s.mean() / 1e6);

    println!("\nexecutable cache: {} compiles for all of the above",
        rt.stats.compile_count.load(std::sync::atomic::Ordering::Relaxed));
    Ok(())
}
