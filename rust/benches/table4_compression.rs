//! Table 4: compression ratio, max/avg accuracy delta and per-model
//! runtime for every compression configuration × lineage graph — plus
//! an artifact-free **pack framing** section comparing Raw vs Zstd
//! outer (whole-pack) compression on a synthetic delta-chain store.
//!
//! Configurations (paper names; DEFLATE substitutes LZMA — DESIGN.md §2):
//!   MGit (LZMA + Hash)      delta-compressed, dictionary codec
//!   MGit (RLE + Hash)       delta-compressed, run-length codec
//!   MGit (Hash)             content hashing only (lossless)
//!   Full                    quantize whole model + dictionary codec
//!   Full w/o quantization   dictionary codec on raw parameters
//!
//! The framing section always runs (no artifacts needed; zstd numbers
//! need `--features zstd`); the per-graph table needs the AOT artifacts
//! manifest and skips cleanly without it.

mod common;

use std::collections::HashMap;

use mgit::checkpoint::Checkpoint;
use mgit::delta::{self, Codec, CompressConfig, DeltaKernel, NativeKernel};
use mgit::registry::{CreationSpec, Objective};
use mgit::runtime::Runtime;
use mgit::store::pack::{repack, PackFraming, RepackConfig, RepackMode};
use mgit::store::Store;
use mgit::util::human_bytes;
use mgit::util::timing::Timer;
use mgit::workloads::{self, PersistMode, Scale, Workload};

/// The task a node is evaluated on (from its creation spec).
fn eval_task(wl: &Workload, name: &str) -> Option<(String, Objective)> {
    let node = wl.graph.by_name(name).ok()?;
    match node.creation.as_ref()? {
        CreationSpec::Finetune { task, objective, .. } => Some((task.clone(), *objective)),
        CreationSpec::Prune { task, .. } => Some((task.clone(), Objective::Cls)),
        CreationSpec::Mtl { task, .. } => Some((task.clone(), Objective::Cls)),
        CreationSpec::Pretrain { corpus_seed, .. } => {
            Some((format!("{corpus_seed}"), Objective::Mlm))
        }
        _ => None,
    }
}

fn accuracy(rt: &Runtime, ck: &Checkpoint, task: &str, obj: Objective) -> anyhow::Result<f32> {
    let (seed, name): (u64, &str) = match obj {
        Objective::Mlm => (task.parse().unwrap_or(0), "corpus"),
        Objective::Cls => (0, task),
    };
    Ok(rt.eval_many(&ck.arch, obj, &ck.flat, name, seed, 2)?.1)
}

struct ConfigRow {
    label: &'static str,
    mode: Mode,
}

enum Mode {
    Delta(CompressConfig),
    HashOnly,
    Full { quantize: bool },
}

/// Raw-vs-Zstd pack framing on a synthetic store: a raw f32 base plus a
/// chain of deflate-compressed quantized deltas, repacked `--full` once
/// per framing. Reports on-disk pack sizes and the size ratio.
fn pack_framing_section() -> anyhow::Result<()> {
    use mgit::store::format::TensorObject;
    use mgit::store::hash_tensor;
    use mgit::tensor::{f32_to_bytes, i32_to_bytes, DType};
    use mgit::util::rng::Rng;

    println!("Pack framing — outer whole-pack compression (Raw vs Zstd)");
    common::hr();
    let dir = std::env::temp_dir().join(format!("mgit-t4-framing-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let store = Store::open_packed(&dir)?;

    // A 24-link chain over a 16 Ki-f32 base (same object shapes the
    // storage paper sections use).
    let mut rng = Rng::new(42);
    let len = 16 * 1024usize;
    let eps = 1e-4f32;
    let codec = Codec::Deflate;
    let base: Vec<f32> = (0..len).map(|_| rng.normal_f32(0.0, 1.0)).collect();
    let base_payload = f32_to_bytes(&base);
    let base_id = hash_tensor(DType::F32, &[len], &base_payload);
    store.put(
        base_id,
        &TensorObject::Raw { dtype: DType::F32, shape: vec![len], payload: base_payload }
            .encode(),
    )?;
    let (mut prev, mut prev_id) = (base, base_id);
    let mut tip = base_id;
    for _ in 0..24 {
        let child: Vec<f32> = prev.iter().map(|&p| p + rng.normal_f32(0.0, 3e-4)).collect();
        let q = NativeKernel.quantize(&prev, &child, eps)?;
        let rec = NativeKernel.dequantize(&prev, &q, eps)?;
        let payload = f32_to_bytes(&rec);
        let id = hash_tensor(DType::F32, &[len], &payload);
        let obj = TensorObject::Delta {
            dtype: DType::F32,
            shape: vec![len],
            parent: prev_id,
            eps,
            codec: codec.code(),
            n_quant: len,
            grid: false,
            payload: codec.compress(&i32_to_bytes(&q))?,
        };
        store.put(id, &obj.encode())?;
        (prev, prev_id) = (rec, id);
        tip = id;
    }
    drop(store);

    let mut sizes: Vec<(PackFraming, u64)> = Vec::new();
    for framing in [PackFraming::Raw, PackFraming::Zstd] {
        if framing == PackFraming::Zstd && !cfg!(feature = "zstd") {
            println!("zstd framing skipped (rebuild with --features zstd)");
            continue;
        }
        let mut store = Store::open_packed(&dir)?;
        let cfg = RepackConfig {
            max_chain_depth: 32,
            mode: RepackMode::Full,
            framing,
            ..RepackConfig::default()
        };
        let t = Timer::start();
        let report = repack(&mut store, &[tip], &cfg, &NativeKernel)?;
        let size = std::fs::metadata(report.pack_path.as_ref().unwrap())?.len();
        println!(
            "{:<5} framing: pack {:>10} on disk ({} objects, repack {})",
            framing.name(),
            human_bytes(size),
            report.packed + report.retained_packed,
            mgit::util::human_secs(t.elapsed_secs()),
        );
        common::bench_json(
            "table4_compression",
            &format!("pack_size_{}_bytes", framing.name()),
            size as f64,
        );
        sizes.push((framing, size));
    }
    if let (Some((_, raw)), Some((_, zstd))) = (
        sizes.iter().find(|(f, _)| *f == PackFraming::Raw),
        sizes.iter().find(|(f, _)| *f == PackFraming::Zstd),
    ) {
        let ratio = *raw as f64 / (*zstd).max(1) as f64;
        println!("raw/zstd pack-size ratio: {ratio:.3}x");
        common::bench_json("table4_compression", "raw_vs_zstd_pack_ratio", ratio);
    }
    common::hr();
    std::fs::remove_dir_all(&dir)?;
    Ok(())
}

/// Chunk dedup — on-disk footprint with and without `--similarity` on
/// synthetic *cross-lineage* shared tensors: eight raw tensors that
/// share most of their bytes but none of their ids (each carries a
/// sparse per-tensor perturbation, so CAS never collapses them and no
/// lineage edge links them). The lineage-only repack stores every byte
/// eight times; the chunked repack stores shared ranges once and must
/// come out strictly smaller.
fn chunk_dedup_section() -> anyhow::Result<()> {
    use mgit::store::format::TensorObject;
    use mgit::store::hash_tensor;
    use mgit::store::ObjectId;
    use mgit::tensor::{f32_to_bytes, DType};
    use mgit::util::rng::Rng;

    println!("Chunk dedup — cross-lineage shared tensors (repack --similarity)");
    common::hr();
    let dir = std::env::temp_dir().join(format!("mgit-t4-cdedup-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let store = Store::open_packed(&dir)?;

    let mut rng = Rng::new(7);
    let len = 16 * 1024usize;
    let base: Vec<f32> = (0..len).map(|_| rng.normal_f32(0.0, 1.0)).collect();
    let mut roots: Vec<ObjectId> = Vec::new();
    for i in 0..8u32 {
        let mut vals = base.clone();
        for v in vals.iter_mut().step_by(512) {
            *v += 0.5 + i as f32;
        }
        let payload = f32_to_bytes(&vals);
        let id = hash_tensor(DType::F32, &[len], &payload);
        store.put(
            id,
            &TensorObject::Raw { dtype: DType::F32, shape: vec![len], payload }.encode(),
        )?;
        roots.push(id);
    }
    drop(store);

    let mut sizes: Vec<u64> = Vec::new();
    for chunked in [false, true] {
        let mut store = Store::open_packed(&dir)?;
        let cfg = RepackConfig {
            mode: RepackMode::Full,
            similarity: if chunked { Some(0.5) } else { None },
            chunk_dedup: chunked,
            ..RepackConfig::default()
        };
        let t = Timer::start();
        let report = repack(&mut store, &roots, &cfg, &NativeKernel)?;
        let size = std::fs::metadata(report.pack_path.as_ref().unwrap())?.len();
        let label = if chunked { "chunked (v3)" } else { "plain (v2)" };
        println!(
            "{:<12}: pack {:>10} on disk ({} objects, {} recipes, {} chunks shared, \
             repack {})",
            label,
            human_bytes(size),
            report.packed,
            report.recipes,
            report.chunks_shared,
            mgit::util::human_secs(t.elapsed_secs()),
        );
        common::bench_json(
            "table4_compression",
            if chunked { "chunk_dedup_on_bytes" } else { "chunk_dedup_off_bytes" },
            size as f64,
        );
        if chunked {
            common::bench_json("table4_compression", "chunk_recipes", report.recipes as f64);
            assert!(
                report.recipes > 0,
                "cross-lineage shared tensors must produce chunk recipes"
            );
        }
        sizes.push(size);
    }
    let ratio = sizes[0] as f64 / sizes[1].max(1) as f64;
    println!("plain/chunked pack-size ratio: {ratio:.3}x");
    common::bench_json("table4_compression", "chunk_dedup_ratio", ratio);
    assert!(
        sizes[1] < sizes[0],
        "chunk dedup must shrink the pack ({} >= {})",
        sizes[1],
        sizes[0]
    );
    common::hr();
    std::fs::remove_dir_all(&dir)?;
    Ok(())
}

fn main() -> anyhow::Result<()> {
    pack_framing_section()?;
    chunk_dedup_section()?;

    let Some(rt) = common::runtime_opt() else {
        println!(
            "Table 4 skipped: no AOT artifacts manifest (run `make artifacts` \
             to enable the per-graph compression table)"
        );
        return Ok(());
    };
    let scale = common::scale();
    let zoo = rt.zoo().clone();

    println!("Table 4 — compression ratio / accuracy Δ / per-model runtime");
    println!("(dictionary codec = DEFLATE standing in for LZMA; see DESIGN.md §2)");
    common::hr();
    println!(
        "{:<6} {:<26} {:>8} {:>9} {:>9} {:>12}",
        "graph", "technique", "ratio", "maxΔacc", "avgΔacc", "per-model"
    );
    common::hr();

    let builders: Vec<(&str, Box<dyn Fn() -> anyhow::Result<Workload>>)> = vec![
        ("G1", Box::new(|| workloads::build_g1(&rt, &scale))),
        ("G2", Box::new(|| workloads::build_g2(&rt, &scale))),
        ("G3", Box::new(|| workloads::build_g3(&rt, &scale))),
        ("G4", Box::new(|| workloads::build_g4(&rt, &scale))),
        ("G5", Box::new(|| workloads::build_g5(&rt, &scale))),
    ];

    for (gname, build) in builders {
        if !common::graph_enabled(gname) {
            continue;
        }
        let wl0 = build()?;
        // G4 uses pre-quantized deltas (sparsity preservation, paper §6.3).
        let preq = gname == "G4";
        // Baseline accuracies.
        let mut base_acc: HashMap<String, f32> = HashMap::new();
        for name in wl0.checkpoints.keys() {
            if let Some((task, obj)) = eval_task(&wl0, name) {
                base_acc.insert(name.clone(), accuracy(&rt, wl0.ck(name)?, &task, obj)?);
            }
        }

        let configs = vec![
            ConfigRow {
                label: "MGit (LZMA* + Hash)",
                mode: Mode::Delta(CompressConfig {
                    eps: 1e-4,
                    codec: Codec::Deflate,
                    prequantize: preq,
                }),
            },
            ConfigRow {
                label: "MGit (RLE + Hash)",
                mode: Mode::Delta(CompressConfig {
                    eps: 1e-4,
                    codec: Codec::Rle,
                    prequantize: preq,
                }),
            },
            ConfigRow { label: "MGit (Hash)", mode: Mode::HashOnly },
            ConfigRow { label: "Full", mode: Mode::Full { quantize: true } },
            ConfigRow {
                label: "Full w/o quantization",
                mode: Mode::Full { quantize: false },
            },
        ];

        for cfg in configs {
            let t = Timer::start();
            let (ratio, max_d, avg_d, n_models) = match cfg.mode {
                Mode::Delta(c) => {
                    let mut wl = clone_workload(&wl0);
                    let store = Store::in_memory();
                    let report = workloads::persist(
                        &mut wl,
                        &store,
                        &zoo,
                        &rt,
                        PersistMode::Delta(c),
                        |_, _| Ok(true),
                    )?;
                    // Accuracy of reconstructed models.
                    let (mut max_d, mut sum_d, mut n) = (0f32, 0f32, 0usize);
                    for node in &wl.graph.nodes {
                        let Some(base) = base_acc.get(&node.name) else { continue };
                        let sm = node.stored.as_ref().unwrap();
                        let ck = delta::load(&store, &zoo, sm, &rt)?;
                        let (task, obj) = eval_task(&wl, &node.name).unwrap();
                        let acc = accuracy(&rt, &ck, &task, obj)?;
                        let d = (base - acc).max(0.0);
                        max_d = max_d.max(d);
                        sum_d += d;
                        n += 1;
                    }
                    (report.ratio(), max_d, sum_d / n.max(1) as f32, report.n_models)
                }
                Mode::HashOnly => {
                    let mut wl = clone_workload(&wl0);
                    let store = Store::in_memory();
                    let report = workloads::persist(
                        &mut wl,
                        &store,
                        &zoo,
                        &rt,
                        PersistMode::HashOnly,
                        |_, _| Ok(true),
                    )?;
                    (report.ratio(), 0.0, 0.0, report.n_models)
                }
                Mode::Full { quantize } => {
                    // Paper baseline: each model compressed independently.
                    let (mut raw, mut stored) = (0u64, 0u64);
                    let (mut max_d, mut sum_d, mut n) = (0f32, 0f32, 0usize);
                    for (name, ck) in &wl0.checkpoints {
                        raw += (ck.flat.len() * 4) as u64;
                        let (size, rec) = delta::full_model_compressed_size(
                            ck,
                            Codec::Deflate,
                            1e-4,
                            quantize,
                        )?;
                        stored += size as u64;
                        if quantize {
                            if let Some(base) = base_acc.get(name) {
                                let (task, obj) = eval_task(&wl0, name).unwrap();
                                let acc = accuracy(&rt, &rec, &task, obj)?;
                                let d = (base - acc).max(0.0);
                                max_d = max_d.max(d);
                                sum_d += d;
                                n += 1;
                            }
                        }
                    }
                    (
                        raw as f64 / stored.max(1) as f64,
                        max_d,
                        sum_d / n.max(1) as f32,
                        wl0.checkpoints.len(),
                    )
                }
            };
            let per_model = t.elapsed_secs() / n_models.max(1) as f64;
            println!(
                "{:<6} {:<26} {:>7.2}x {:>9.3} {:>9.3} {:>12}",
                gname,
                cfg.label,
                ratio,
                max_d,
                avg_d,
                mgit::util::human_secs(per_model)
            );
        }
        common::hr();
    }
    Ok(())
}

fn clone_workload(wl: &Workload) -> Workload {
    Workload {
        name: wl.name.clone(),
        graph: wl.graph.clone(),
        checkpoints: wl.checkpoints.clone(),
    }
}
