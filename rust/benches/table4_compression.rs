//! Table 4: compression ratio, max/avg accuracy delta and per-model
//! runtime for every compression configuration × lineage graph.
//!
//! Configurations (paper names; DEFLATE substitutes LZMA — DESIGN.md §2):
//!   MGit (LZMA + Hash)      delta-compressed, dictionary codec
//!   MGit (RLE + Hash)       delta-compressed, run-length codec
//!   MGit (Hash)             content hashing only (lossless)
//!   Full                    quantize whole model + dictionary codec
//!   Full w/o quantization   dictionary codec on raw parameters

mod common;

use std::collections::HashMap;

use mgit::checkpoint::Checkpoint;
use mgit::delta::{self, Codec, CompressConfig};
use mgit::registry::{CreationSpec, Objective};
use mgit::runtime::Runtime;
use mgit::store::Store;
use mgit::util::timing::Timer;
use mgit::workloads::{self, PersistMode, Scale, Workload};

/// The task a node is evaluated on (from its creation spec).
fn eval_task(wl: &Workload, name: &str) -> Option<(String, Objective)> {
    let node = wl.graph.by_name(name).ok()?;
    match node.creation.as_ref()? {
        CreationSpec::Finetune { task, objective, .. } => Some((task.clone(), *objective)),
        CreationSpec::Prune { task, .. } => Some((task.clone(), Objective::Cls)),
        CreationSpec::Mtl { task, .. } => Some((task.clone(), Objective::Cls)),
        CreationSpec::Pretrain { corpus_seed, .. } => {
            Some((format!("{corpus_seed}"), Objective::Mlm))
        }
        _ => None,
    }
}

fn accuracy(rt: &Runtime, ck: &Checkpoint, task: &str, obj: Objective) -> anyhow::Result<f32> {
    let (seed, name): (u64, &str) = match obj {
        Objective::Mlm => (task.parse().unwrap_or(0), "corpus"),
        Objective::Cls => (0, task),
    };
    Ok(rt.eval_many(&ck.arch, obj, &ck.flat, name, seed, 2)?.1)
}

struct ConfigRow {
    label: &'static str,
    mode: Mode,
}

enum Mode {
    Delta(CompressConfig),
    HashOnly,
    Full { quantize: bool },
}

fn main() -> anyhow::Result<()> {
    let rt = common::runtime();
    let scale = common::scale();
    let zoo = rt.zoo().clone();

    println!("Table 4 — compression ratio / accuracy Δ / per-model runtime");
    println!("(dictionary codec = DEFLATE standing in for LZMA; see DESIGN.md §2)");
    common::hr();
    println!(
        "{:<6} {:<26} {:>8} {:>9} {:>9} {:>12}",
        "graph", "technique", "ratio", "maxΔacc", "avgΔacc", "per-model"
    );
    common::hr();

    let builders: Vec<(&str, Box<dyn Fn() -> anyhow::Result<Workload>>)> = vec![
        ("G1", Box::new(|| workloads::build_g1(&rt, &scale))),
        ("G2", Box::new(|| workloads::build_g2(&rt, &scale))),
        ("G3", Box::new(|| workloads::build_g3(&rt, &scale))),
        ("G4", Box::new(|| workloads::build_g4(&rt, &scale))),
        ("G5", Box::new(|| workloads::build_g5(&rt, &scale))),
    ];

    for (gname, build) in builders {
        if !common::graph_enabled(gname) {
            continue;
        }
        let wl0 = build()?;
        // G4 uses pre-quantized deltas (sparsity preservation, paper §6.3).
        let preq = gname == "G4";
        // Baseline accuracies.
        let mut base_acc: HashMap<String, f32> = HashMap::new();
        for name in wl0.checkpoints.keys() {
            if let Some((task, obj)) = eval_task(&wl0, name) {
                base_acc.insert(name.clone(), accuracy(&rt, wl0.ck(name)?, &task, obj)?);
            }
        }

        let configs = vec![
            ConfigRow {
                label: "MGit (LZMA* + Hash)",
                mode: Mode::Delta(CompressConfig {
                    eps: 1e-4,
                    codec: Codec::Deflate,
                    prequantize: preq,
                }),
            },
            ConfigRow {
                label: "MGit (RLE + Hash)",
                mode: Mode::Delta(CompressConfig {
                    eps: 1e-4,
                    codec: Codec::Rle,
                    prequantize: preq,
                }),
            },
            ConfigRow { label: "MGit (Hash)", mode: Mode::HashOnly },
            ConfigRow { label: "Full", mode: Mode::Full { quantize: true } },
            ConfigRow {
                label: "Full w/o quantization",
                mode: Mode::Full { quantize: false },
            },
        ];

        for cfg in configs {
            let t = Timer::start();
            let (ratio, max_d, avg_d, n_models) = match cfg.mode {
                Mode::Delta(c) => {
                    let mut wl = clone_workload(&wl0);
                    let store = Store::in_memory();
                    let report = workloads::persist(
                        &mut wl,
                        &store,
                        &zoo,
                        &rt,
                        PersistMode::Delta(c),
                        |_, _| Ok(true),
                    )?;
                    // Accuracy of reconstructed models.
                    let (mut max_d, mut sum_d, mut n) = (0f32, 0f32, 0usize);
                    for node in &wl.graph.nodes {
                        let Some(base) = base_acc.get(&node.name) else { continue };
                        let sm = node.stored.as_ref().unwrap();
                        let ck = delta::load(&store, &zoo, sm, &rt)?;
                        let (task, obj) = eval_task(&wl, &node.name).unwrap();
                        let acc = accuracy(&rt, &ck, &task, obj)?;
                        let d = (base - acc).max(0.0);
                        max_d = max_d.max(d);
                        sum_d += d;
                        n += 1;
                    }
                    (report.ratio(), max_d, sum_d / n.max(1) as f32, report.n_models)
                }
                Mode::HashOnly => {
                    let mut wl = clone_workload(&wl0);
                    let store = Store::in_memory();
                    let report = workloads::persist(
                        &mut wl,
                        &store,
                        &zoo,
                        &rt,
                        PersistMode::HashOnly,
                        |_, _| Ok(true),
                    )?;
                    (report.ratio(), 0.0, 0.0, report.n_models)
                }
                Mode::Full { quantize } => {
                    // Paper baseline: each model compressed independently.
                    let (mut raw, mut stored) = (0u64, 0u64);
                    let (mut max_d, mut sum_d, mut n) = (0f32, 0f32, 0usize);
                    for (name, ck) in &wl0.checkpoints {
                        raw += (ck.flat.len() * 4) as u64;
                        let (size, rec) = delta::full_model_compressed_size(
                            ck,
                            Codec::Deflate,
                            1e-4,
                            quantize,
                        )?;
                        stored += size as u64;
                        if quantize {
                            if let Some(base) = base_acc.get(name) {
                                let (task, obj) = eval_task(&wl0, name).unwrap();
                                let acc = accuracy(&rt, &rec, &task, obj)?;
                                let d = (base - acc).max(0.0);
                                max_d = max_d.max(d);
                                sum_d += d;
                                n += 1;
                            }
                        }
                    }
                    (
                        raw as f64 / stored.max(1) as f64,
                        max_d,
                        sum_d / n.max(1) as f32,
                        wl0.checkpoints.len(),
                    )
                }
            };
            let per_model = t.elapsed_secs() / n_models.max(1) as f64;
            println!(
                "{:<6} {:<26} {:>7.2}x {:>9.3} {:>9.3} {:>12}",
                gname,
                cfg.label,
                ratio,
                max_d,
                avg_d,
                mgit::util::human_secs(per_model)
            );
        }
        common::hr();
    }
    Ok(())
}

fn clone_workload(wl: &Workload) -> Workload {
    Workload {
        name: wl.name.clone(),
        graph: wl.graph.clone(),
        checkpoints: wl.checkpoints.clone(),
    }
}
