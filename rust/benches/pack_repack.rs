//! Packfile bench: loose vs packed cold-load of a full lineage graph,
//! and repack throughput (including chain re-basing).
//!
//! No runtime/artifacts needed: the lineage graph is synthesized from an
//! inline manifest — 4 pretrained roots, each with a 15-deep chain of
//! delta-compressed versions (64 models, ~512 tensor objects) — exactly
//! the shape `mgit repack` is built for. "Cold" here means fresh store
//! handles and full file reads each iteration (the OS page cache stays
//! warm, so the numbers isolate per-object open/seek overhead, which is
//! what packs eliminate).

mod common;

use std::collections::HashMap;
use std::path::PathBuf;

use mgit::checkpoint::{Checkpoint, ModelZoo};
use mgit::delta::{self, CompressConfig, NativeKernel, StoredModel};
use mgit::store::pack::{chain_depths, repack, RepackConfig, RepackMode};
use mgit::store::{ObjectId, Store};
use mgit::util::json;
use mgit::util::rng::Rng;
use mgit::util::timing::BenchStats;
use mgit::util::{human_bytes, human_secs};

/// 8 × 16 Ki-f32 tensors = 512 KiB of parameters per model.
fn manifest() -> String {
    let n_tensors = 8usize;
    let size = 16 * 1024usize;
    let layout: Vec<String> = (0..n_tensors)
        .map(|i| {
            format!(
                r#"{{"name":"w.t{i}","shape":[{size}],"offset":{},"size":{size},"init":"normal"}}"#,
                i * size
            )
        })
        .collect();
    format!(
        r#"{{
          "vocab": 16, "max_seq": 4, "n_classes": 2, "batch": 2,
          "delta_chunk": 4096,
          "special_tokens": {{"cls": 14, "mask": 15, "ignore_label": -100}},
          "archs": {{"bench": {{
              "d_model": 8, "n_layers": 1, "n_heads": 1, "d_ff": 16,
              "param_count": {},
              "layout": [{}],
              "dag": {{"nodes": [], "edges": []}}
          }}}},
          "artifacts": {{"bench": {{}}}},
          "delta_kernels": {{"quant": "q", "dequant": "d"}}
        }}"#,
        n_tensors * size,
        layout.join(",")
    )
}

fn perturbed(ck: &Checkpoint, seed: u64) -> Checkpoint {
    let mut rng = Rng::new(seed);
    let flat = ck.flat.iter().map(|&x| x + rng.normal_f32(0.0, 3e-4)).collect();
    Checkpoint { arch: ck.arch.clone(), flat }
}

fn load_all(dir: &PathBuf, zoo: &ModelZoo, models: &[StoredModel]) -> Vec<Checkpoint> {
    // Fresh handle each time: indexes re-load, every object re-reads.
    let store = Store::open_packed(dir).expect("open store");
    models
        .iter()
        .map(|m| delta::load(&store, zoo, m, &NativeKernel).expect("load model"))
        .collect()
}

fn main() -> anyhow::Result<()> {
    let zoo = ModelZoo::from_json(&json::parse(&manifest())?)?;
    let spec = zoo.arch("bench")?;
    let dir = std::env::temp_dir().join(format!("mgit-bench-pack-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let store = Store::open_packed(&dir)?;

    // ------------------------------------------------------------------
    // Build the lineage graph: 4 roots × (1 + 15 versions).
    // ------------------------------------------------------------------
    let (n_roots, n_versions) = (4usize, 15usize);
    let cfg = CompressConfig::default();
    let mut models: Vec<StoredModel> = Vec::new();
    let t_build = mgit::util::timing::Timer::start();
    for r in 0..n_roots {
        let root = Checkpoint::init(spec, r as u64);
        let (sm, _) = delta::store_raw(&store, spec, &root)?;
        let mut prev = (root, sm.clone());
        models.push(sm);
        for v in 0..n_versions {
            let child = perturbed(&prev.0, (r * 1000 + v) as u64 + 7);
            let cand = delta::prepare_delta(
                &store, spec, &child, spec, &prev.0, &prev.1, cfg, &NativeKernel,
            )?;
            delta::commit(&store, &cand)?;
            prev = (cand.checkpoint, cand.model.clone());
            models.push(cand.model);
        }
    }
    let n_objects = store.list()?.len();
    let loose_bytes = store.stored_bytes()?;
    println!(
        "lineage graph: {} models / {} objects / {} loose, built in {}",
        models.len(),
        n_objects,
        human_bytes(loose_bytes),
        human_secs(t_build.elapsed_secs())
    );
    let depths = chain_depths(&store)?;
    let max_before = depths.values().copied().max().unwrap_or(0);
    drop(store);

    // ------------------------------------------------------------------
    // Loose cold-load baseline.
    // ------------------------------------------------------------------
    common::hr();
    let reference = load_all(&dir, &zoo, &models);
    let loose = BenchStats::measure("loose cold-load (full graph)", 1, 5, || {
        let _ = load_all(&dir, &zoo, &models);
    });
    println!("{}", loose.report());

    // ------------------------------------------------------------------
    // Repack (with chain re-basing) — throughput.
    // ------------------------------------------------------------------
    common::hr();
    let roots: Vec<ObjectId> = models.iter().flat_map(|m| m.refs()).collect();
    let rcfg = RepackConfig {
        max_chain_depth: 8,
        prune: true,
        mode: RepackMode::Full,
        ..RepackConfig::default()
    };
    let mut store = Store::open_packed(&dir)?;
    let t_repack = mgit::util::timing::Timer::start();
    let report = repack(&mut store, &roots, &rcfg, &NativeKernel)?;
    let secs = t_repack.elapsed_secs();
    println!(
        "repack: {} objects in {}  ({:.0} obj/s, {}/s)",
        report.packed,
        human_secs(secs),
        report.packed as f64 / secs,
        human_bytes((report.bytes_before as f64 / secs) as u64)
    );
    println!(
        "chains: max depth {} -> {} ({} re-based, {} new bases); bytes {} -> {}",
        report.max_depth_before,
        report.max_depth_after,
        report.rebased_delta,
        report.new_bases,
        human_bytes(report.bytes_before),
        human_bytes(report.bytes_after)
    );
    assert_eq!(max_before, report.max_depth_before);
    assert!(report.max_depth_after <= rcfg.max_chain_depth);
    drop(store);

    // ------------------------------------------------------------------
    // Packed cold-load + integrity.
    // ------------------------------------------------------------------
    common::hr();
    let packed_loaded = load_all(&dir, &zoo, &models);
    for (a, b) in reference.iter().zip(&packed_loaded) {
        assert_eq!(a.flat.len(), b.flat.len());
        for (x, y) in a.flat.iter().zip(&b.flat) {
            assert_eq!(x.to_bits(), y.to_bits(), "repack changed model content");
        }
    }
    let packed = BenchStats::measure("packed cold-load (full graph)", 1, 5, || {
        let _ = load_all(&dir, &zoo, &models);
    });
    println!("{}", packed.report());
    common::hr();
    let speedup = loose.mean() / packed.mean();
    println!(
        "packed cold-load is {speedup:.2}x {} than loose ({} vs {})",
        if speedup >= 1.0 { "faster" } else { "slower" },
        human_secs(packed.mean()),
        human_secs(loose.mean())
    );

    // ------------------------------------------------------------------
    // Incremental mark phase over the sealed v2 store: pure index
    // metadata, zero payload decodes (asserted), no byte reads.
    // ------------------------------------------------------------------
    common::hr();
    let mut store = Store::open_packed(&dir)?;
    let icfg = RepackConfig {
        max_chain_depth: 8,
        prune: false,
        mode: RepackMode::Incremental,
        ..RepackConfig::default()
    };
    let t_mark = mgit::util::timing::Timer::start();
    let ir = repack(&mut store, &roots, &icfg, &NativeKernel)?;
    let mark_secs = t_mark.elapsed_secs();
    assert_eq!(ir.packed, 0, "no-op incremental must pack nothing");
    assert_eq!(ir.mark_payload_decodes, 0, "v2 mark must be decode-free");
    println!(
        "incremental mark over {} sealed objects: {} ({} payload decodes, \
         {} byte-read fallbacks)",
        n_objects,
        human_secs(mark_secs),
        ir.mark_payload_decodes,
        ir.mark_meta_fallback
    );
    drop(store);

    common::bench_json("pack_repack", "loose_cold_load_secs", loose.mean());
    common::bench_json("pack_repack", "packed_cold_load_secs", packed.mean());
    common::bench_json("pack_repack", "packed_speedup", speedup);
    common::bench_json("pack_repack", "repack_obj_per_sec", report.packed as f64 / secs);
    common::bench_json("pack_repack", "incremental_mark_secs", mark_secs);
    common::bench_json(
        "pack_repack",
        "mark_payload_decodes",
        ir.mark_payload_decodes as f64,
    );

    std::fs::remove_dir_all(&dir)?;
    Ok(())
}
