//! Concurrent load harness for `mgit serve`: latency percentiles and
//! throughput under keep-alive client fleets, cross-checked against the
//! server's own `/metrics` histogram.
//!
//! No runtime/artifacts needed: a synthetic lineage (12 delta-compressed
//! versions of a 512 KiB model) is built inline and fully repacked.
//! Each level spins N ∈ {8, 64, 256} client threads; every client holds
//! one persistent HTTP/1.1 connection (parsing `Content-Length` framed
//! responses) and works through a fixed quota of requests over a mixed
//! `/log` + `/stats` + `/checkpoint/<node>` workload. Rows report
//! client-observed p50/p99 latency and aggregate requests/second, and
//! land in `$MGIT_BENCH_JSON` via `common::bench_json`.
//!
//! The final section fetches `GET /metrics` and asserts the server's
//! `request_micros` histogram counted *exactly* the requests the clients
//! completed — the deterministic record-before-first-byte contract the
//! serving tier guarantees (see `rust/src/ops/serve.rs`).
//!
//! A `serve_write` section then restarts the repo writable and measures
//! the mixed read/write regime: a single writer streams WAL-backed
//! `POST /commit`s (each fsync'd and snapshot-swapped) while a fleet of
//! keep-alive readers keeps hammering `/log` + `/checkpoint`; rows
//! report commit throughput, client-observed write latency, and read
//! p99 under write load.
//!
//! `MGIT_SCALE=small` shrinks the per-client quota for CI smoke runs.

mod common;

use std::io::{BufRead, BufReader, Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::path::Path;
use std::time::Instant;

use mgit::checkpoint::{Checkpoint, ModelZoo};
use mgit::delta::{self, CompressConfig, NativeKernel};
use mgit::ops::serve::{Server, MAX_REQUESTS_PER_CONN};
use mgit::ops::{self, Repo};
use mgit::util::json;
use mgit::util::rng::Rng;
use mgit::util::timing::Timer;

const N_TENSORS: usize = 8;
const TENSOR_SIZE: usize = 16 * 1024;
const VERSIONS: usize = 12;
const POOL: usize = 8;
const LEVELS: [usize; 3] = [8, 64, 256];

fn quota() -> usize {
    match std::env::var("MGIT_SCALE").as_deref() {
        Ok("small") => 6,
        _ => 32,
    }
}

fn manifest() -> String {
    let layout: Vec<String> = (0..N_TENSORS)
        .map(|i| {
            format!(
                r#"{{"name":"w.t{i}","shape":[{TENSOR_SIZE}],"offset":{},"size":{TENSOR_SIZE},"init":"normal"}}"#,
                i * TENSOR_SIZE
            )
        })
        .collect();
    format!(
        r#"{{
          "vocab": 16, "max_seq": 4, "n_classes": 2, "batch": 2,
          "delta_chunk": 4096,
          "special_tokens": {{"cls": 14, "mask": 15, "ignore_label": -100}},
          "archs": {{"bench": {{
              "d_model": 8, "n_layers": 1, "n_heads": 1, "d_ff": 16,
              "param_count": {},
              "layout": [{}],
              "dag": {{"nodes": [], "edges": []}}
          }}}},
          "artifacts": {{"bench": {{}}}},
          "delta_kernels": {{"quant": "q", "dequant": "d"}}
        }}"#,
        N_TENSORS * TENSOR_SIZE,
        layout.join(",")
    )
}

fn build_repo(dir: &Path, zoo: &ModelZoo) -> Vec<String> {
    let spec = zoo.arch("bench").unwrap();
    Repo::init(dir).unwrap();
    let mut repo = Repo::open(dir).unwrap();
    let root = Checkpoint::init(spec, 7);
    let (sm, _) = delta::store_raw(&repo.store, spec, &root).unwrap();
    let idx = repo.graph.add_node("bench/v1", "bench").unwrap();
    repo.graph.node_mut(idx).stored = Some(sm.clone());
    let mut names = vec!["bench/v1".to_string()];
    let mut prev = (root, sm);
    let mut prev_idx = idx;
    for v in 1..VERSIONS as u64 {
        let mut rng = Rng::new(v + 500);
        let child = Checkpoint {
            arch: prev.0.arch.clone(),
            flat: prev.0.flat.iter().map(|&x| x + rng.normal_f32(0.0, 1e-4)).collect(),
        };
        let cand = delta::prepare_delta(
            &repo.store,
            spec,
            &child,
            spec,
            &prev.0,
            &prev.1,
            CompressConfig::default(),
            &NativeKernel,
        )
        .unwrap();
        delta::commit(&repo.store, &cand).unwrap();
        let name = format!("bench/v{}", v + 1);
        let n = repo.graph.add_node(&name, "bench").unwrap();
        repo.graph.node_mut(n).stored = Some(cand.model.clone());
        repo.graph.add_version_edge(prev_idx, n).unwrap();
        names.push(name);
        prev = (cand.checkpoint, cand.model);
        prev_idx = n;
    }
    repo.save().unwrap();
    ops::RepackRequest::default().run(&mut Repo::open(dir).unwrap()).unwrap();
    names
}

/// One persistent HTTP/1.1 connection: requests are written without
/// `Connection: close`, responses are framed by `Content-Length`, so a
/// single TCP stream carries the client's whole quota.
struct KeepAliveClient {
    reader: BufReader<TcpStream>,
}

impl KeepAliveClient {
    fn connect(addr: SocketAddr) -> KeepAliveClient {
        let stream = TcpStream::connect(addr).unwrap();
        let _ = stream.set_nodelay(true);
        KeepAliveClient { reader: BufReader::new(stream) }
    }

    fn get(&mut self, path: &str) -> (u16, Vec<u8>) {
        write!(self.reader.get_mut(), "GET {path} HTTP/1.1\r\nHost: bench\r\n\r\n")
            .unwrap();
        let mut line = String::new();
        self.reader.read_line(&mut line).unwrap();
        let status: u16 = line
            .split_whitespace()
            .nth(1)
            .unwrap_or_else(|| panic!("bad status line {line:?} for {path}"))
            .parse()
            .unwrap();
        let mut content_len = 0usize;
        loop {
            let mut h = String::new();
            self.reader.read_line(&mut h).unwrap();
            if h == "\r\n" || h == "\n" || h.is_empty() {
                break;
            }
            if let Some(v) = h.to_ascii_lowercase().strip_prefix("content-length:") {
                content_len = v.trim().parse().unwrap();
            }
        }
        let mut body = vec![0u8; content_len];
        self.reader.read_exact(&mut body).unwrap();
        (status, body)
    }
}

/// Drive `clients` concurrent keep-alive clients through `per_client`
/// requests each; returns (wall seconds, all per-request latencies µs).
fn drive(
    addr: SocketAddr,
    clients: usize,
    per_client: usize,
    paths: &[String],
) -> (f64, Vec<u64>) {
    let t = Timer::start();
    let mut all = Vec::with_capacity(clients * per_client);
    std::thread::scope(|scope| {
        let mut handles = Vec::new();
        for c in 0..clients {
            handles.push(scope.spawn(move || {
                let mut client = KeepAliveClient::connect(addr);
                let mut lat = Vec::with_capacity(per_client);
                for i in 0..per_client {
                    let path = &paths[(c + i) % paths.len()];
                    let t0 = Instant::now();
                    let (status, _body) = client.get(path);
                    assert_eq!(status, 200, "non-200 for {path}");
                    lat.push(t0.elapsed().as_micros() as u64);
                }
                lat
            }));
        }
        for h in handles {
            all.extend(h.join().unwrap());
        }
    });
    (t.elapsed_secs(), all)
}

/// The `q`-quantile of an already-sorted latency list (nearest-rank).
fn pctile(sorted: &[u64], q: f64) -> u64 {
    if sorted.is_empty() {
        return 0;
    }
    let rank = ((q * sorted.len() as f64).ceil() as usize).max(1);
    sorted[rank.min(sorted.len()) - 1]
}

fn main() {
    let per_client = quota();
    assert!(
        (per_client as u64) < MAX_REQUESTS_PER_CONN,
        "client quota must fit one keep-alive connection"
    );
    let dir = std::env::temp_dir().join(format!("mgit-serve-load-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    let zoo = ModelZoo::from_json(&json::parse(&manifest()).unwrap()).unwrap();
    let names = build_repo(&dir, &zoo);

    let server = Server::bind(Repo::open(&dir).unwrap(), Some(zoo), 0, POOL).unwrap();
    let addr = server.local_addr().unwrap();
    let handle = server.handle().unwrap();
    let srv = std::thread::spawn(move || server.serve().unwrap());

    // Mixed workload: cheap JSON endpoints interleaved with 512 KiB
    // checkpoint streams over every version of the chain.
    let mut paths = vec!["/log".to_string(), "/stats".to_string()];
    paths.extend(names.iter().map(|n| format!("/checkpoint/{n}")));

    println!(
        "serve load: pool {POOL}, {per_client} requests/client, {} mixed paths",
        paths.len()
    );
    println!(
        "  {:>8} {:>10} {:>12} {:>12} {:>12}",
        "clients", "wall", "req/s", "p50", "p99"
    );
    let mut issued = 0u64;
    for clients in LEVELS {
        let (secs, mut lat) = drive(addr, clients, per_client, &paths);
        lat.sort_unstable();
        issued += lat.len() as u64;
        let req_s = lat.len() as f64 / secs;
        let (p50, p99) = (pctile(&lat, 0.50), pctile(&lat, 0.99));
        println!(
            "  {clients:>8} {secs:>9.2}s {req_s:>12.0} {p50:>10}µs {p99:>10}µs"
        );
        common::bench_json("serve_load", &format!("req_per_s_c{clients}"), req_s);
        common::bench_json("serve_load", &format!("p50_micros_c{clients}"), p50 as f64);
        common::bench_json("serve_load", &format!("p99_micros_c{clients}"), p99 as f64);
    }

    // Cross-check: the server's own request histogram must have counted
    // exactly the requests our clients completed (metrics are recorded
    // before the first response byte; `/metrics` excludes itself).
    let mut probe = KeepAliveClient::connect(addr);
    let (status, body) = probe.get("/metrics");
    assert_eq!(status, 200);
    let snap = json::parse(std::str::from_utf8(&body).unwrap()).unwrap();
    let hist = snap
        .get("server")
        .unwrap()
        .get("histograms")
        .unwrap()
        .get("request_micros")
        .unwrap();
    let server_count = hist.req_usize("count").unwrap() as u64;
    assert_eq!(
        server_count, issued,
        "server histogram disagrees with client-side request count"
    );
    let (sp50, sp99) =
        (hist.req_usize("p50").unwrap(), hist.req_usize("p99").unwrap());
    println!(
        "cross-check: /metrics histogram count {server_count} == {issued} issued; \
         server-side p50 {sp50}µs p99 {sp99}µs (log2-bucket upper bounds)"
    );
    common::bench_json("serve_load", "server_hist_count", server_count as f64);

    handle.shutdown();
    let report = srv.join().unwrap();
    println!("total: {} requests, {} errors", report.requests, report.errors);
    assert_eq!(report.errors, 0, "load run must be error-free");

    serve_write_section(&dir);

    let _ = std::fs::remove_dir_all(&dir);
}

/// One-shot `POST` (Connection: close); returns the status code.
fn http_post(addr: SocketAddr, path: &str, body: &[u8]) -> u16 {
    let mut s = TcpStream::connect(addr).unwrap();
    let _ = s.set_nodelay(true);
    write!(
        s,
        "POST {path} HTTP/1.1\r\nHost: bench\r\nConnection: close\r\nContent-Length: {}\r\n\r\n",
        body.len()
    )
    .unwrap();
    s.write_all(body).unwrap();
    s.flush().unwrap();
    let mut buf = Vec::new();
    s.read_to_end(&mut buf).unwrap();
    let head_end = buf.windows(4).position(|w| w == b"\r\n\r\n").expect("malformed response");
    let head = String::from_utf8_lossy(&buf[..head_end]);
    head.split_whitespace().nth(1).and_then(|c| c.parse().ok()).expect("bad status line")
}

/// Mixed read/write: restart the repo writable, stream WAL-backed
/// commits from one writer while `WRITE_READERS` keep-alive readers keep
/// pulling `/log` + `/checkpoint`, and report both sides' latencies.
fn serve_write_section(dir: &Path) {
    use std::sync::atomic::{AtomicBool, Ordering};
    use std::sync::Arc;
    use mgit::ops::serve::WriteConfig;

    const WRITE_READERS: usize = 8;
    let commits = match std::env::var("MGIT_SCALE").as_deref() {
        Ok("small") => 40usize,
        _ => 200,
    };

    let zoo = ModelZoo::from_json(&json::parse(&manifest()).unwrap()).unwrap();
    let server = Server::bind_writable(
        Repo::open(dir).unwrap(),
        Some(zoo),
        0,
        WRITE_READERS + 2,
        WriteConfig {
            auth_token: None,
            rate_per_sec: None,
            fold_every: mgit::ops::serve::CHECKPOINT_EVERY,
        },
    )
    .unwrap();
    let addr = server.local_addr().unwrap();
    let handle = server.handle().unwrap();
    let srv = std::thread::spawn(move || server.serve().unwrap());

    let done = Arc::new(AtomicBool::new(false));
    let mut write_lat = Vec::with_capacity(commits);
    let mut read_lat: Vec<u64> = Vec::new();
    let t = Timer::start();
    std::thread::scope(|scope| {
        let mut readers = Vec::new();
        for c in 0..WRITE_READERS {
            let done = Arc::clone(&done);
            readers.push(scope.spawn(move || {
                let paths = ["/log", "/checkpoint/bench%2Fv1", "/stats"];
                let mut lat = Vec::new();
                while !done.load(Ordering::SeqCst) {
                    // Reconnect per block to stay inside the server's
                    // per-connection request cap.
                    let mut client = KeepAliveClient::connect(addr);
                    for i in 0..200usize {
                        let t0 = Instant::now();
                        let (status, _) = client.get(paths[(c + i) % paths.len()]);
                        assert_eq!(status, 200, "reader under write load");
                        lat.push(t0.elapsed().as_micros() as u64);
                        if done.load(Ordering::SeqCst) {
                            break;
                        }
                    }
                }
                lat
            }));
        }
        // The single writer: metadata-only commits, each one a durable
        // WAL append + fsync + snapshot swap.
        for i in 0..commits {
            let op = format!(r#"{{"name":"live/{i}","model_type":"bench"}}"#);
            let t0 = Instant::now();
            let status = http_post(addr, "/commit", op.as_bytes());
            assert_eq!(status, 200, "commit live/{i}");
            write_lat.push(t0.elapsed().as_micros() as u64);
        }
        done.store(true, Ordering::SeqCst);
        for r in readers {
            read_lat.extend(r.join().unwrap());
        }
    });
    let secs = t.elapsed_secs();

    write_lat.sort_unstable();
    read_lat.sort_unstable();
    let commits_per_s = commits as f64 / secs;
    let (wp50, wp99) = (pctile(&write_lat, 0.50), pctile(&write_lat, 0.99));
    let rp99 = pctile(&read_lat, 0.99);
    println!(
        "serve write: {commits} commits in {secs:.2}s ({commits_per_s:.0}/s), \
         write p50 {wp50}µs p99 {wp99}µs; {} reads, read p99 {rp99}µs",
        read_lat.len()
    );
    common::bench_json("serve_write", "commits_per_s", commits_per_s);
    common::bench_json("serve_write", "write_p50_micros", wp50 as f64);
    common::bench_json("serve_write", "write_p99_micros", wp99 as f64);
    common::bench_json("serve_write", "read_p99_micros_under_write", rp99 as f64);

    handle.shutdown();
    let report = srv.join().unwrap();
    assert!(report.writable);
    assert_eq!(report.commits, commits as u64, "every commit must have landed");
    assert_eq!(report.errors, 0, "write run must be error-free");
    println!(
        "serve write: {} snapshot swaps, {} total requests",
        report.snapshot_swaps, report.requests
    );
}
