//! Shared bench plumbing (criterion is unavailable offline; benches are
//! `harness = false` binaries printing the paper's table/figure rows).

use std::path::PathBuf;

use mgit::runtime::Runtime;
use mgit::workloads::Scale;

pub fn runtime() -> Runtime {
    runtime_opt().expect("run `make artifacts` first")
}

/// Like [`runtime`], but `None` when the AOT artifacts manifest is
/// absent — benches with artifact-free sections use this to skip their
/// runtime-dependent parts cleanly (CI runs without artifacts).
pub fn runtime_opt() -> Option<Runtime> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    Runtime::new(&dir).ok()
}

/// Append one measurement row (`{"bench":…,"metric":…,"value":…}` per
/// line) to the file named by `$MGIT_BENCH_JSON`. No-op when the
/// variable is unset. CI's bench-smoke job points it at `BENCH_pr.json`
/// and uploads the file, so every PR leaves a perf datapoint.
pub fn bench_json(bench: &str, metric: &str, value: f64) {
    let Ok(path) = std::env::var("MGIT_BENCH_JSON") else { return };
    if path.is_empty() {
        return;
    }
    if !value.is_finite() {
        // inf/NaN would render as invalid JSON and break artifact
        // consumers; a degenerate measurement is better dropped.
        eprintln!("bench_json: skipping non-finite {bench}/{metric}");
        return;
    }
    use std::io::Write;
    let file = std::fs::OpenOptions::new().create(true).append(true).open(&path);
    if let Ok(mut f) = file {
        let _ = writeln!(f, "{{\"bench\":\"{bench}\",\"metric\":\"{metric}\",\"value\":{value}}}");
    }
}

/// MGIT_SCALE=small shrinks every workload (CI); default is paper shape.
pub fn scale() -> Scale {
    match std::env::var("MGIT_SCALE").as_deref() {
        Ok("small") => Scale::small(),
        _ => Scale::paper(),
    }
}

/// Graph filter: MGIT_GRAPHS=g2,g5 restricts the per-graph benches.
pub fn graph_enabled(name: &str) -> bool {
    match std::env::var("MGIT_GRAPHS") {
        Ok(list) if !list.is_empty() => {
            list.split(',').any(|g| g.eq_ignore_ascii_case(name))
        }
        _ => true,
    }
}

pub fn hr() {
    println!("{}", "-".repeat(86));
}
