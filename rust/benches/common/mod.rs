//! Shared bench plumbing (criterion is unavailable offline; benches are
//! `harness = false` binaries printing the paper's table/figure rows).

use std::path::PathBuf;

use mgit::runtime::Runtime;
use mgit::workloads::Scale;

pub fn runtime() -> Runtime {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    Runtime::new(&dir).expect("run `make artifacts` first")
}

/// MGIT_SCALE=small shrinks every workload (CI); default is paper shape.
pub fn scale() -> Scale {
    match std::env::var("MGIT_SCALE").as_deref() {
        Ok("small") => Scale::small(),
        _ => Scale::paper(),
    }
}

/// Graph filter: MGIT_GRAPHS=g2,g5 restricts the per-graph benches.
pub fn graph_enabled(name: &str) -> bool {
    match std::env::var("MGIT_GRAPHS") {
        Ok(list) if !list.is_empty() => {
            list.split(',').any(|g| g.eq_ignore_ascii_case(name))
        }
        _ => true,
    }
}

pub fn hr() {
    println!("{}", "-".repeat(86));
}
