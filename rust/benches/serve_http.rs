//! HTTP serving-tier bench: requests/second through `mgit serve`'s
//! bounded worker pool, versus client concurrency.
//!
//! No runtime/artifacts needed: a synthetic lineage (1 root × 15
//! delta-compressed versions of a 512 KiB model) is built inline and
//! fully repacked, so `/checkpoint` responses stream through the mmap
//! pack tier and the shared `ResolveCache`. Sections:
//!
//! 1. `/log` (pure JSON, no tensor work) at 1/2/4/8 concurrent clients;
//! 2. `/checkpoint/<node>` (chain resolution + 512 KiB body) at 1/2/4/8
//!    concurrent clients, pool fixed at 8.
//!
//! Each client performs a fixed request quota; rows report wall clock,
//! requests/s and aggregate MiB/s.

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::path::Path;

use mgit::checkpoint::{Checkpoint, ModelZoo};
use mgit::delta::{self, CompressConfig, NativeKernel};
use mgit::ops::serve::Server;
use mgit::ops::{self, Repo};
use mgit::util::rng::Rng;
use mgit::util::timing::Timer;
use mgit::util::{human_bytes, json};

const N_TENSORS: usize = 8;
const TENSOR_SIZE: usize = 16 * 1024;
const VERSIONS: usize = 15;
const POOL: usize = 8;
const REQUESTS_PER_CLIENT: usize = 40;

fn manifest() -> String {
    let layout: Vec<String> = (0..N_TENSORS)
        .map(|i| {
            format!(
                r#"{{"name":"w.t{i}","shape":[{TENSOR_SIZE}],"offset":{},"size":{TENSOR_SIZE},"init":"normal"}}"#,
                i * TENSOR_SIZE
            )
        })
        .collect();
    format!(
        r#"{{
          "vocab": 16, "max_seq": 4, "n_classes": 2, "batch": 2,
          "delta_chunk": 4096,
          "special_tokens": {{"cls": 14, "mask": 15, "ignore_label": -100}},
          "archs": {{"bench": {{
              "d_model": 8, "n_layers": 1, "n_heads": 1, "d_ff": 16,
              "param_count": {},
              "layout": [{}],
              "dag": {{"nodes": [], "edges": []}}
          }}}},
          "artifacts": {{"bench": {{}}}},
          "delta_kernels": {{"quant": "q", "dequant": "d"}}
        }}"#,
        N_TENSORS * TENSOR_SIZE,
        layout.join(",")
    )
}

fn build_repo(dir: &Path, zoo: &ModelZoo) -> Vec<String> {
    let spec = zoo.arch("bench").unwrap();
    Repo::init(dir).unwrap();
    let mut repo = Repo::open(dir).unwrap();
    let root = Checkpoint::init(spec, 7);
    let (sm, _) = delta::store_raw(&repo.store, spec, &root).unwrap();
    let idx = repo.graph.add_node("bench/v1", "bench").unwrap();
    repo.graph.node_mut(idx).stored = Some(sm.clone());
    let mut names = vec!["bench/v1".to_string()];
    let mut prev = (root, sm);
    let mut prev_idx = idx;
    for v in 1..VERSIONS as u64 {
        let mut rng = Rng::new(v + 100);
        let child = Checkpoint {
            arch: prev.0.arch.clone(),
            flat: prev.0.flat.iter().map(|&x| x + rng.normal_f32(0.0, 1e-4)).collect(),
        };
        let cand = delta::prepare_delta(
            &repo.store,
            spec,
            &child,
            spec,
            &prev.0,
            &prev.1,
            CompressConfig::default(),
            &NativeKernel,
        )
        .unwrap();
        delta::commit(&repo.store, &cand).unwrap();
        let name = format!("bench/v{}", v + 1);
        let n = repo.graph.add_node(&name, "bench").unwrap();
        repo.graph.node_mut(n).stored = Some(cand.model.clone());
        repo.graph.add_version_edge(prev_idx, n).unwrap();
        names.push(name);
        prev = (cand.checkpoint, cand.model);
        prev_idx = n;
    }
    repo.save().unwrap();
    ops::RepackRequest::default().run(&mut Repo::open(dir).unwrap()).unwrap();
    names
}

fn http_get_len(addr: SocketAddr, path: &str) -> usize {
    let mut s = TcpStream::connect(addr).unwrap();
    write!(s, "GET {path} HTTP/1.1\r\nHost: bench\r\nConnection: close\r\n\r\n").unwrap();
    let mut buf = Vec::new();
    s.read_to_end(&mut buf).unwrap();
    assert!(buf.starts_with(b"HTTP/1.1 200"), "non-200 for {path}");
    buf.len()
}

fn drive(addr: SocketAddr, clients: usize, paths: &[String]) -> (f64, u64) {
    let t = Timer::start();
    let mut total = 0u64;
    std::thread::scope(|scope| {
        let mut handles = Vec::new();
        for c in 0..clients {
            let paths = paths.to_vec();
            handles.push(scope.spawn(move || {
                let mut bytes = 0u64;
                for i in 0..REQUESTS_PER_CLIENT {
                    let path = &paths[(c + i) % paths.len()];
                    bytes += http_get_len(addr, path) as u64;
                }
                bytes
            }));
        }
        for h in handles {
            total += h.join().unwrap();
        }
    });
    (t.elapsed_secs(), total)
}

fn section(addr: SocketAddr, label: &str, paths: &[String]) {
    println!("{label} (pool {POOL}, {REQUESTS_PER_CLIENT} requests/client)");
    println!(
        "  {:>8} {:>10} {:>12} {:>12}",
        "clients", "wall", "req/s", "aggregate"
    );
    for clients in [1usize, 2, 4, 8] {
        let (secs, bytes) = drive(addr, clients, paths);
        let reqs = (clients * REQUESTS_PER_CLIENT) as f64;
        println!(
            "  {:>8} {:>9.2}s {:>12.0} {:>10}/s",
            clients,
            secs,
            reqs / secs,
            human_bytes((bytes as f64 / secs) as u64)
        );
    }
}

fn main() {
    let dir = std::env::temp_dir().join(format!("mgit-serve-bench-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    let zoo = ModelZoo::from_json(&json::parse(&manifest()).unwrap()).unwrap();
    let names = build_repo(&dir, &zoo);

    let server = Server::bind(Repo::open(&dir).unwrap(), Some(zoo), 0, POOL).unwrap();
    let addr = server.local_addr().unwrap();
    let handle = server.handle().unwrap();
    let srv = std::thread::spawn(move || server.serve().unwrap());

    println!(
        "serve bench: {} versions of a {} model, packed, pool {POOL}",
        VERSIONS,
        human_bytes((N_TENSORS * TENSOR_SIZE * 4) as u64)
    );
    section(addr, "GET /log", &["/log".to_string()]);
    let ck_paths: Vec<String> =
        names.iter().map(|n| format!("/checkpoint/{n}")).collect();
    section(addr, "GET /checkpoint/<node>", &ck_paths);

    handle.shutdown();
    let report = srv.join().unwrap();
    println!("total: {} requests, {} errors", report.requests, report.errors);

    let _ = std::fs::remove_dir_all(&dir);
}
