//! Remote-tier bench: cold-fill and hot-hit latency of the tiered
//! store, bulk fetch throughput, and chain materialization wall-clock
//! with delta-parent prefetch on vs off.
//!
//! No runtime/artifacts needed: a synthetic lineage (delta-compressed
//! versions of a 512 KiB model) is built inline and served read-only by
//! an in-process `mgit serve` on a loopback ephemeral port. A fresh
//! tiered store then pulls every object cold (per-object latency +
//! aggregate MiB/s), re-reads them hot, and finally two more fresh
//! stores each reconstruct the tip checkpoint end-to-end — one with
//! prefetch disabled (every delta parent is a demand-driven round
//! trip), one with prefetch enabled (the first fill warms the whole
//! chain over the same pooled connection).
//!
//! Rows land in `$MGIT_BENCH_JSON` via `common::bench_json`;
//! `MGIT_SCALE=small` shrinks the chain for CI smoke runs.

mod common;

use std::net::SocketAddr;
use std::path::{Path, PathBuf};
use std::time::Instant;

use mgit::checkpoint::{Checkpoint, ModelZoo};
use mgit::delta::{self, CompressConfig, NativeKernel};
use mgit::ops::serve::Server;
use mgit::ops::{self, Repo};
use mgit::store::remote::RemoteConfig;
use mgit::store::tiered::TieredStore;
use mgit::store::{ObjectStore, Store};
use mgit::tensor::f32_to_bytes;
use mgit::util::json;
use mgit::util::rng::Rng;
use mgit::util::timing::Timer;

const N_TENSORS: usize = 4;
const TENSOR_SIZE: usize = 32 * 1024;
const POOL: usize = 4;

fn versions() -> usize {
    match std::env::var("MGIT_SCALE").as_deref() {
        Ok("small") => 6,
        _ => 12,
    }
}

fn manifest() -> String {
    let layout: Vec<String> = (0..N_TENSORS)
        .map(|i| {
            format!(
                r#"{{"name":"w.t{i}","shape":[{TENSOR_SIZE}],"offset":{},"size":{TENSOR_SIZE},"init":"normal"}}"#,
                i * TENSOR_SIZE
            )
        })
        .collect();
    format!(
        r#"{{
          "vocab": 16, "max_seq": 4, "n_classes": 2, "batch": 2,
          "delta_chunk": 4096,
          "special_tokens": {{"cls": 14, "mask": 15, "ignore_label": -100}},
          "archs": {{"bench": {{
              "d_model": 8, "n_layers": 1, "n_heads": 1, "d_ff": 16,
              "param_count": {},
              "layout": [{}],
              "dag": {{"nodes": [], "edges": []}}
          }}}},
          "artifacts": {{"bench": {{}}}},
          "delta_kernels": {{"quant": "q", "dequant": "d"}}
        }}"#,
        N_TENSORS * TENSOR_SIZE,
        layout.join(",")
    )
}

fn build_origin(dir: &Path, zoo: &ModelZoo, versions: usize) -> String {
    let spec = zoo.arch("bench").unwrap();
    Repo::init(dir).unwrap();
    let mut repo = Repo::open(dir).unwrap();
    let root = Checkpoint::init(spec, 7);
    let (sm, _) = delta::store_raw(&repo.store, spec, &root).unwrap();
    let idx = repo.graph.add_node("bench/v1", "bench").unwrap();
    repo.graph.node_mut(idx).stored = Some(sm.clone());
    let mut prev = (root, sm);
    let mut prev_idx = idx;
    let mut tip = "bench/v1".to_string();
    for v in 1..versions as u64 {
        let mut rng = Rng::new(v + 900);
        let child = Checkpoint {
            arch: prev.0.arch.clone(),
            flat: prev.0.flat.iter().map(|&x| x + rng.normal_f32(0.0, 1e-4)).collect(),
        };
        let cand = delta::prepare_delta(
            &repo.store,
            spec,
            &child,
            spec,
            &prev.0,
            &prev.1,
            CompressConfig::default(),
            &NativeKernel,
        )
        .unwrap();
        delta::commit(&repo.store, &cand).unwrap();
        tip = format!("bench/v{}", v + 1);
        let n = repo.graph.add_node(&tip, "bench").unwrap();
        repo.graph.node_mut(n).stored = Some(cand.model.clone());
        repo.graph.add_version_edge(prev_idx, n).unwrap();
        prev = (cand.checkpoint, cand.model);
        prev_idx = n;
    }
    repo.save().unwrap();
    ops::RepackRequest::default().run(&mut Repo::open(dir).unwrap()).unwrap();
    tip
}

fn tmp_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("mgit-rtier-bench-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

fn cfg_for(addr: SocketAddr, prefetch: bool) -> RemoteConfig {
    let mut cfg = RemoteConfig::new(&format!("http://127.0.0.1:{}", addr.port()));
    cfg.prefetch = prefetch;
    cfg
}

/// The `q`-quantile of an already-sorted latency list (nearest-rank).
fn pctile(sorted: &[u64], q: f64) -> u64 {
    if sorted.is_empty() {
        return 0;
    }
    let rank = ((q * sorted.len() as f64).ceil() as usize).max(1);
    sorted[rank.min(sorted.len()) - 1]
}

fn main() {
    let versions = versions();
    let origin_dir = tmp_dir("origin");
    let zoo = ModelZoo::from_json(&json::parse(&manifest()).unwrap()).unwrap();
    let tip = build_origin(&origin_dir, &zoo, versions);

    let origin = Repo::open(&origin_dir).unwrap();
    let ids = origin.store.list().unwrap();
    let tip_model = origin.graph.node_by_name(&tip).unwrap().stored.clone().unwrap();
    let want = f32_to_bytes(
        &delta::load(&origin.store, &zoo, &tip_model, &NativeKernel).unwrap().flat,
    );
    drop(origin);

    let server =
        Server::bind(Repo::open(&origin_dir).unwrap(), Some(zoo.clone()), 0, POOL).unwrap();
    let addr = server.local_addr().unwrap();
    let handle = server.handle().unwrap();
    let srv = std::thread::spawn(move || server.serve().unwrap());

    println!(
        "remote tier: {} objects ({} versions x {N_TENSORS} tensors) over loopback origin {addr}",
        ids.len(),
        versions
    );

    // --- Cold fills: every object pulled over the wire, one get each. ---
    let dir = tmp_dir("cold");
    let ts = TieredStore::open(&dir.join("objects"), &cfg_for(addr, false)).unwrap();
    let mut cold = Vec::with_capacity(ids.len());
    let mut bytes = 0u64;
    let t = Timer::start();
    for id in &ids {
        let t0 = Instant::now();
        bytes += ts.get(id).unwrap().len() as u64;
        cold.push(t0.elapsed().as_micros() as u64);
    }
    let cold_secs = t.elapsed_secs();
    cold.sort_unstable();
    let mib_s = bytes as f64 / (1024.0 * 1024.0) / cold_secs;
    let (cp50, cp99) = (pctile(&cold, 0.50), pctile(&cold, 0.99));
    println!(
        "  cold: {} fills, {bytes} bytes in {cold_secs:.3}s ({mib_s:.1} MiB/s), \
         p50 {cp50}µs p99 {cp99}µs",
        ids.len()
    );
    common::bench_json("remote_tier", "cold_fetch_p50_micros", cp50 as f64);
    common::bench_json("remote_tier", "cold_fetch_p99_micros", cp99 as f64);
    common::bench_json("remote_tier", "cold_fetch_mib_per_s", mib_s);

    // --- Hot hits: same objects again, now local pack/loose reads. ---
    let mut warm = Vec::with_capacity(ids.len());
    for id in &ids {
        let t0 = Instant::now();
        ts.get(id).unwrap();
        warm.push(t0.elapsed().as_micros() as u64);
    }
    warm.sort_unstable();
    let (wp50, wp99) = (pctile(&warm, 0.50), pctile(&warm, 0.99));
    println!("  warm: p50 {wp50}µs p99 {wp99}µs (hot-tier hits, no wire)");
    common::bench_json("remote_tier", "warm_hit_p50_micros", wp50 as f64);
    common::bench_json("remote_tier", "warm_hit_p99_micros", wp99 as f64);

    // --- Chain materialization: tip checkpoint from nothing, demand
    //     path only vs delta-parent prefetch. ---
    for (label, prefetch) in [("prefetch_off", false), ("prefetch_on", true)] {
        let dir = tmp_dir(label);
        let store = Store::open_tiered(&dir.join("objects"), &cfg_for(addr, prefetch)).unwrap();
        let t = Timer::start();
        let ck = delta::load(&store, &zoo, &tip_model, &NativeKernel).unwrap();
        let secs = t.elapsed_secs();
        assert_eq!(f32_to_bytes(&ck.flat), want, "remote chain load must be bit-exact");
        println!("  chain ({label}): tip `{tip}` materialized in {secs:.3}s");
        common::bench_json("remote_tier", &format!("chain_cold_secs_{label}"), secs);
        let _ = std::fs::remove_dir_all(&dir);
    }

    handle.shutdown();
    let report = srv.join().unwrap();
    assert_eq!(report.errors, 0, "bench run must be error-free");
    println!("origin served {} requests, 0 errors", report.requests);

    let _ = std::fs::remove_dir_all(&dir);
    let _ = std::fs::remove_dir_all(&origin_dir);
}
