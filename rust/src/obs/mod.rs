//! Process-wide observability: atomic counters, gauges and log2 latency
//! histograms behind a named [`Registry`].
//!
//! The design constraint is the storage tier's concurrency contract
//! (`docs/STORAGE.md`): pack reads and `ResolveCache` hits are lock-free
//! today, and instrumenting them must not add a lock. Every metric is
//! therefore plain atomics:
//!
//! * [`Counter`] — monotonic `AtomicU64` (`inc`/`add` are single
//!   `fetch_add`s).
//! * [`Gauge`] — signed `AtomicI64` level (in-flight requests, queue
//!   depth, resident bytes).
//! * [`Histogram`] — fixed array of power-of-two buckets: `observe(v)`
//!   is three relaxed `fetch_add`s (bucket, count, sum), and
//!   p50/p90/p99 are *derived* from the bucket counts at read time
//!   ([`Histogram::quantile`]), so the hot path never sorts or
//!   allocates. Bucket `i` holds values `v ≤ 2^i`; quantiles report the
//!   bucket upper bound (≤ 2× the true value — plenty for tail-latency
//!   dashboards).
//!
//! A [`Registry`] is a name → metric map. Registration (`counter`/
//! `gauge`/`histogram`) takes a short mutex and hands back an
//! `Arc`-shared handle; callers resolve once and keep the handle, so
//! the lock is never on a per-event path. Two registries matter in
//! practice:
//!
//! * [`global()`] — the process-wide registry. Layer-level telemetry
//!   (store reads, payload decodes, cascade scheduling) lands here via
//!   the [`LazyCounter`]/[`LazyGauge`]/[`LazyHistogram`] statics, which
//!   resolve their handle once under a `OnceLock` and are lock-free
//!   afterwards.
//! * Per-instance registries — `mgit serve` gives each server its own
//!   ([`crate::ops::serve`]), so concurrent servers in one process
//!   (tests!) don't bleed request counts into each other. `GET
//!   /metrics` renders both.
//!
//! Rendering: [`Registry::snapshot`] → [`crate::util::json::Json`] and
//! [`Registry::render_prometheus`] → the text exposition format
//! (`# TYPE` lines, cumulative `_bucket{le="..."}` histograms).
//! Snapshots are taken metric-by-metric with relaxed loads: a snapshot
//! racing live traffic can be off by in-flight events, which is the
//! usual (and documented) contract for scrape-based metrics.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicI64, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

use crate::util::json::Json;

/// Number of histogram buckets: bucket `i` covers values up to `2^i`,
/// so 48 buckets span `1 µs .. ~8.9 years` in microseconds — any
/// latency this codebase can produce lands in a real bucket, and the
/// whole histogram is 48 atomics (384 bytes).
pub const HIST_BUCKETS: usize = 48;

// ---------------------------------------------------------------------------
// Metric primitives
// ---------------------------------------------------------------------------

/// Monotonic event counter.
#[derive(Debug, Default)]
pub struct Counter(AtomicU64);

impl Counter {
    pub const fn new() -> Counter {
        Counter(AtomicU64::new(0))
    }

    pub fn inc(&self) {
        self.add(1);
    }

    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    /// Overwrite the value — for counters *mirrored* from another
    /// subsystem's own atomics (e.g. `ResolveCache` hit counts pulled
    /// into a registry at scrape time), never for live counting.
    pub fn store(&self, v: u64) {
        self.0.store(v, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// A level that can go up and down (in-flight requests, queue depth).
#[derive(Debug, Default)]
pub struct Gauge(AtomicI64);

impl Gauge {
    pub const fn new() -> Gauge {
        Gauge(AtomicI64::new(0))
    }

    pub fn inc(&self) {
        self.add(1);
    }

    pub fn dec(&self) {
        self.add(-1);
    }

    pub fn add(&self, n: i64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    pub fn set(&self, v: i64) {
        self.0.store(v, Ordering::Relaxed);
    }

    pub fn get(&self) -> i64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Fixed-bucket log2 histogram; see the module docs for the layout.
#[derive(Debug)]
pub struct Histogram {
    buckets: [AtomicU64; HIST_BUCKETS],
    count: AtomicU64,
    sum: AtomicU64,
}

impl Default for Histogram {
    fn default() -> Histogram {
        Histogram::new()
    }
}

impl Histogram {
    pub fn new() -> Histogram {
        Histogram {
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
            count: AtomicU64::new(0),
            sum: AtomicU64::new(0),
        }
    }

    /// The bucket index for `v`: the smallest `i` with `v ≤ 2^i`
    /// (clamped into the last bucket).
    fn bucket_index(v: u64) -> usize {
        if v <= 1 {
            return 0;
        }
        // ceil(log2(v)) for v ≥ 2.
        let i = 64 - (v - 1).leading_zeros() as usize;
        i.min(HIST_BUCKETS - 1)
    }

    /// Upper bound (`le`) of bucket `i`.
    pub fn bucket_bound(i: usize) -> u64 {
        1u64 << i.min(63)
    }

    /// Record one observation — three relaxed `fetch_add`s, lock-free.
    pub fn observe(&self, v: u64) {
        self.buckets[Self::bucket_index(v)].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum.fetch_add(v, Ordering::Relaxed);
    }

    /// Total number of observations.
    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    /// Sum of all observed values.
    pub fn sum(&self) -> u64 {
        self.sum.load(Ordering::Relaxed)
    }

    /// Point-in-time copy of the per-bucket counts.
    pub fn bucket_counts(&self) -> [u64; HIST_BUCKETS] {
        std::array::from_fn(|i| self.buckets[i].load(Ordering::Relaxed))
    }

    /// The `q`-quantile (`0.0 ..= 1.0`) derived from bucket counts:
    /// returns the upper bound of the bucket holding the `ceil(q·n)`-th
    /// observation (0 when empty). An upper bound, within 2× of the
    /// true value by construction.
    pub fn quantile(&self, q: f64) -> u64 {
        let counts = self.bucket_counts();
        let total: u64 = counts.iter().sum();
        if total == 0 {
            return 0;
        }
        let rank = ((q.clamp(0.0, 1.0) * total as f64).ceil() as u64).max(1);
        let mut seen = 0u64;
        for (i, c) in counts.iter().enumerate() {
            seen += c;
            if seen >= rank {
                return Self::bucket_bound(i);
            }
        }
        Self::bucket_bound(HIST_BUCKETS - 1)
    }
}

// ---------------------------------------------------------------------------
// Registry
// ---------------------------------------------------------------------------

#[derive(Clone)]
enum Metric {
    Counter(Arc<Counter>),
    Gauge(Arc<Gauge>),
    Histogram(Arc<Histogram>),
}

/// A named collection of metrics.
///
/// `counter`/`gauge`/`histogram` are create-or-get: the first call for
/// a name registers it, later calls return the same `Arc`. The map
/// mutex is held only during registration and snapshots — callers keep
/// the returned handle, so incrementing never touches the registry.
#[derive(Default)]
pub struct Registry {
    metrics: Mutex<BTreeMap<String, Metric>>,
}

impl Registry {
    pub fn new() -> Registry {
        Registry::default()
    }

    /// Create-or-get the counter `name`. Panics if `name` is already
    /// registered as a different metric kind (a programming error — the
    /// name space is static).
    pub fn counter(&self, name: &str) -> Arc<Counter> {
        let mut m = self.metrics.lock().unwrap();
        let metric = m
            .entry(name.to_string())
            .or_insert_with(|| Metric::Counter(Arc::new(Counter::new())));
        match metric {
            Metric::Counter(c) => Arc::clone(c),
            _ => panic!("metric `{name}` is not a counter"),
        }
    }

    /// Create-or-get the gauge `name` (same contract as `counter`).
    pub fn gauge(&self, name: &str) -> Arc<Gauge> {
        let mut m = self.metrics.lock().unwrap();
        let metric = m
            .entry(name.to_string())
            .or_insert_with(|| Metric::Gauge(Arc::new(Gauge::new())));
        match metric {
            Metric::Gauge(g) => Arc::clone(g),
            _ => panic!("metric `{name}` is not a gauge"),
        }
    }

    /// Create-or-get the histogram `name` (same contract as `counter`).
    pub fn histogram(&self, name: &str) -> Arc<Histogram> {
        let mut m = self.metrics.lock().unwrap();
        let metric = m
            .entry(name.to_string())
            .or_insert_with(|| Metric::Histogram(Arc::new(Histogram::new())));
        match metric {
            Metric::Histogram(h) => Arc::clone(h),
            _ => panic!("metric `{name}` is not a histogram"),
        }
    }

    /// JSON snapshot, grouped by kind and sorted by name:
    ///
    /// ```text
    /// {"counters": {name: value, …},
    ///  "gauges":   {name: value, …},
    ///  "histograms": {name: {count, sum, p50, p90, p99,
    ///                        buckets: [{le, count}, …]}, …}}
    /// ```
    ///
    /// Histogram `buckets` lists non-empty buckets only
    /// (non-cumulative counts; `le` is the bucket's upper bound).
    pub fn snapshot(&self) -> Json {
        let metrics = self.metrics.lock().unwrap().clone();
        let mut counters = Json::obj();
        let mut gauges = Json::obj();
        let mut histograms = Json::obj();
        for (name, metric) in &metrics {
            match metric {
                Metric::Counter(c) => counters = counters.set(name.as_str(), c.get()),
                Metric::Gauge(g) => gauges = gauges.set(name.as_str(), g.get()),
                Metric::Histogram(h) => {
                    let counts = h.bucket_counts();
                    let buckets: Vec<Json> = counts
                        .iter()
                        .enumerate()
                        .filter(|(_, &c)| c > 0)
                        .map(|(i, &c)| {
                            Json::obj()
                                .set("le", Histogram::bucket_bound(i))
                                .set("count", c)
                        })
                        .collect();
                    histograms = histograms.set(
                        name.as_str(),
                        Json::obj()
                            .set("count", h.count())
                            .set("sum", h.sum())
                            .set("p50", h.quantile(0.50))
                            .set("p90", h.quantile(0.90))
                            .set("p99", h.quantile(0.99))
                            .set("buckets", Json::Arr(buckets)),
                    );
                }
            }
        }
        Json::obj()
            .set("counters", counters)
            .set("gauges", gauges)
            .set("histograms", histograms)
    }

    /// Append the Prometheus text exposition of every metric to `out`,
    /// each name mangled to `<prefix><name>` with `.`/`-`/`/` → `_`.
    /// Histograms render the conventional cumulative
    /// `_bucket{le="…"}` series plus `_sum`/`_count`.
    pub fn render_prometheus(&self, prefix: &str, out: &mut String) {
        use std::fmt::Write;
        let metrics = self.metrics.lock().unwrap().clone();
        for (name, metric) in &metrics {
            let pname = prom_name(prefix, name);
            match metric {
                Metric::Counter(c) => {
                    let _ = writeln!(out, "# TYPE {pname} counter");
                    let _ = writeln!(out, "{pname} {}", c.get());
                }
                Metric::Gauge(g) => {
                    let _ = writeln!(out, "# TYPE {pname} gauge");
                    let _ = writeln!(out, "{pname} {}", g.get());
                }
                Metric::Histogram(h) => {
                    let counts = h.bucket_counts();
                    let last = counts.iter().rposition(|&c| c > 0);
                    let _ = writeln!(out, "# TYPE {pname} histogram");
                    let mut cum = 0u64;
                    if let Some(last) = last {
                        for (i, &c) in counts.iter().take(last + 1).enumerate() {
                            cum += c;
                            let _ = writeln!(
                                out,
                                "{pname}_bucket{{le=\"{}\"}} {cum}",
                                Histogram::bucket_bound(i)
                            );
                        }
                    }
                    let _ = writeln!(out, "{pname}_bucket{{le=\"+Inf\"}} {cum}");
                    let _ = writeln!(out, "{pname}_sum {}", h.sum());
                    let _ = writeln!(out, "{pname}_count {}", h.count());
                }
            }
        }
    }
}

fn prom_name(prefix: &str, name: &str) -> String {
    let mut out = String::with_capacity(prefix.len() + name.len());
    out.push_str(prefix);
    for ch in name.chars() {
        out.push(if ch.is_ascii_alphanumeric() { ch } else { '_' });
    }
    out
}

/// The process-global registry: layer-level telemetry (store, delta,
/// cascade) registers here. Servers keep per-instance registries for
/// request-level metrics; `GET /metrics` renders both.
pub fn global() -> &'static Registry {
    static GLOBAL: OnceLock<Registry> = OnceLock::new();
    GLOBAL.get_or_init(Registry::new)
}

// ---------------------------------------------------------------------------
// Lazily resolved global metrics (for hot-path statics)
// ---------------------------------------------------------------------------

/// A global-registry counter resolved once and cached: after the first
/// call, `inc`/`add` are an atomic `OnceLock` load plus one `fetch_add`
/// — no registry lock on any subsequent event.
pub struct LazyCounter {
    name: &'static str,
    slot: OnceLock<Arc<Counter>>,
}

impl LazyCounter {
    pub const fn new(name: &'static str) -> LazyCounter {
        LazyCounter { name, slot: OnceLock::new() }
    }

    pub fn handle(&self) -> &Counter {
        self.slot.get_or_init(|| global().counter(self.name))
    }

    pub fn inc(&self) {
        self.handle().inc();
    }

    pub fn add(&self, n: u64) {
        self.handle().add(n);
    }

    pub fn get(&self) -> u64 {
        self.handle().get()
    }
}

/// [`LazyCounter`], for gauges.
pub struct LazyGauge {
    name: &'static str,
    slot: OnceLock<Arc<Gauge>>,
}

impl LazyGauge {
    pub const fn new(name: &'static str) -> LazyGauge {
        LazyGauge { name, slot: OnceLock::new() }
    }

    pub fn handle(&self) -> &Gauge {
        self.slot.get_or_init(|| global().gauge(self.name))
    }

    pub fn set(&self, v: i64) {
        self.handle().set(v);
    }

    pub fn get(&self) -> i64 {
        self.handle().get()
    }
}

/// [`LazyCounter`], for histograms.
pub struct LazyHistogram {
    name: &'static str,
    slot: OnceLock<Arc<Histogram>>,
}

impl LazyHistogram {
    pub const fn new(name: &'static str) -> LazyHistogram {
        LazyHistogram { name, slot: OnceLock::new() }
    }

    pub fn handle(&self) -> &Histogram {
        self.slot.get_or_init(|| global().histogram(self.name))
    }

    pub fn observe(&self, v: u64) {
        self.handle().observe(v);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_and_gauge_basics() {
        let r = Registry::new();
        let c = r.counter("a.count");
        c.inc();
        c.add(4);
        assert_eq!(c.get(), 5);
        // Create-or-get returns the same underlying atomic.
        assert_eq!(r.counter("a.count").get(), 5);
        let g = r.gauge("a.level");
        g.inc();
        g.inc();
        g.dec();
        assert_eq!(g.get(), 1);
        g.set(-3);
        assert_eq!(g.get(), -3);
    }

    #[test]
    fn histogram_buckets_and_quantiles() {
        let h = Histogram::new();
        assert_eq!(h.quantile(0.5), 0, "empty histogram");
        // 0 and 1 land in bucket 0; 2^i lands exactly in bucket i.
        assert_eq!(Histogram::bucket_index(0), 0);
        assert_eq!(Histogram::bucket_index(1), 0);
        assert_eq!(Histogram::bucket_index(2), 1);
        assert_eq!(Histogram::bucket_index(3), 2);
        assert_eq!(Histogram::bucket_index(4), 2);
        assert_eq!(Histogram::bucket_index(1024), 10);
        assert_eq!(Histogram::bucket_index(u64::MAX), HIST_BUCKETS - 1);
        // 90 fast observations and 10 slow ones: p50 stays in the fast
        // bucket, p99 reports (an upper bound of) the slow one.
        for _ in 0..90 {
            h.observe(100);
        }
        for _ in 0..10 {
            h.observe(90_000);
        }
        assert_eq!(h.count(), 100);
        assert_eq!(h.sum(), 90 * 100 + 10 * 90_000);
        assert_eq!(h.quantile(0.50), 128);
        assert_eq!(h.quantile(0.90), 128);
        assert_eq!(h.quantile(0.99), 131072);
        // The bucket counts sum to the total count.
        assert_eq!(h.bucket_counts().iter().sum::<u64>(), h.count());
    }

    #[test]
    fn snapshot_shape() {
        let r = Registry::new();
        r.counter("reqs").add(7);
        r.gauge("inflight").set(2);
        r.histogram("lat").observe(5);
        let snap = r.snapshot();
        assert_eq!(snap.get("counters").unwrap().req_usize("reqs").unwrap(), 7);
        assert_eq!(snap.get("gauges").unwrap().req_usize("inflight").unwrap(), 2);
        let h = snap.get("histograms").unwrap().get("lat").unwrap();
        assert_eq!(h.req_usize("count").unwrap(), 1);
        assert_eq!(h.req_usize("sum").unwrap(), 5);
        assert_eq!(h.req_usize("p50").unwrap(), 8);
        assert_eq!(h.req_arr("buckets").unwrap().len(), 1);
    }

    #[test]
    fn prometheus_rendering() {
        let r = Registry::new();
        r.counter("store.reads").add(3);
        r.histogram("req-micros").observe(3);
        r.histogram("req-micros").observe(700);
        let mut out = String::new();
        r.render_prometheus("mgit_", &mut out);
        assert!(out.contains("# TYPE mgit_store_reads counter"));
        assert!(out.contains("mgit_store_reads 3"));
        assert!(out.contains("# TYPE mgit_req_micros histogram"));
        // Cumulative buckets: the 2-value histogram ends at 2 by +Inf.
        assert!(out.contains("mgit_req_micros_bucket{le=\"4\"} 1"));
        assert!(out.contains("mgit_req_micros_bucket{le=\"1024\"} 2"));
        assert!(out.contains("mgit_req_micros_bucket{le=\"+Inf\"} 2"));
        assert!(out.contains("mgit_req_micros_count 2"));
        assert!(out.contains("mgit_req_micros_sum 703"));
    }

    #[test]
    fn lazy_statics_resolve_against_global() {
        static C: LazyCounter = LazyCounter::new("obs.test.lazy_counter");
        C.inc();
        C.add(2);
        assert_eq!(global().counter("obs.test.lazy_counter").get(), C.get());
        assert!(C.get() >= 3);
    }
}
