//! The lineage graph (paper §3): nodes are models, edges are provenance
//! ("derived from") or versioning ("next version of") relations, stored as
//! adjacency lists. Nodes carry optional creation functions (declarative
//! [`CreationSpec`]s), a [`StoredModel`] pointer into the CAS, a model
//! type, and free-form metadata.
//!
//! Matching the paper's design, "changes to metadata are serialized to
//! disk at the end of every operation, and de-serialized at the start of
//! every operation" — [`LineageGraph::save`]/[`LineageGraph::load`]
//! round-trip the whole graph (including the test registry) as JSON at
//! `.mgit/graph.json`; the repository wrapper in [`crate::cli`] does the
//! per-operation save/load.
//!
//! Large repositories can instead keep the graph in the indexed binary
//! MGGI format ([`binfmt`]): mmap-able, opened in O(page) time behind
//! the lazy [`GraphStore`] seam ([`store`]). `graph.json` stays the v0
//! fallback — repos without a `graph.bin` are read exactly as before.

pub mod binfmt;
pub mod store;
pub mod traversal;

pub use store::GraphStore;

use std::collections::HashMap;
use std::path::Path;

use anyhow::{anyhow, bail, Context, Result};

use crate::delta::StoredModel;
use crate::registry::{CreationSpec, TestRegistry};
use crate::util::json::{self, Json};

/// Index of a node inside a [`LineageGraph`].
pub type NodeIdx = usize;

/// Which edge relation (paper Table 1).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EdgeType {
    /// "Derived from": fine-tuning, distillation, adaptation…
    Provenance,
    /// "Next version of": same model re-trained / updated over time.
    Versioning,
}

/// A model node.
#[derive(Debug, Clone)]
pub struct Node {
    /// Unique name (paper: nodes have unique names).
    pub name: String,
    /// Model type — we use the architecture name + optional role, e.g.
    /// `tx-tiny`; type-scoped tests match on this.
    pub model_type: String,
    /// Pointer to the model's parameters in the CAS (None while a cascade
    /// has created the node but not yet trained it).
    pub stored: Option<StoredModel>,
    /// Optional creation function.
    pub creation: Option<CreationSpec>,
    /// Free-form metadata (task name, seeds, notes…).
    pub metadata: Json,
    pub prov_parents: Vec<NodeIdx>,
    pub prov_children: Vec<NodeIdx>,
    pub ver_parents: Vec<NodeIdx>,
    pub ver_children: Vec<NodeIdx>,
}

impl Node {
    fn new(name: &str, model_type: &str) -> Node {
        Node {
            name: name.to_string(),
            model_type: model_type.to_string(),
            stored: None,
            creation: None,
            metadata: Json::obj(),
            prov_parents: Vec::new(),
            prov_children: Vec::new(),
            ver_parents: Vec::new(),
            ver_children: Vec::new(),
        }
    }
}

/// The lineage graph: models as nodes, provenance + versioning edges as
/// adjacency lists, plus the test registry (everything `.mgit/graph.json`
/// round-trips).
///
/// # Examples
///
/// ```
/// use mgit::lineage::LineageGraph;
///
/// let mut g = LineageGraph::new();
/// let base = g.add_node("bert-base", "tx").unwrap();
/// let ft = g.add_node("bert-sst2", "tx").unwrap();
/// g.add_edge(base, ft).unwrap(); // provenance: derived-from
/// let ft2 = g.add_node("bert-sst2@v2", "tx").unwrap();
/// g.add_version_edge(ft, ft2).unwrap(); // versioning: next-version-of
/// assert_eq!(g.next_version(ft), Some(ft2));
/// assert!(g.is_provenance_ancestor(base, ft));
/// g.integrity_check().unwrap();
/// ```
#[derive(Debug, Clone, Default)]
pub struct LineageGraph {
    /// All nodes, index-addressed ([`NodeIdx`]); order is insertion order.
    pub nodes: Vec<Node>,
    by_name: HashMap<String, NodeIdx>,
    /// Registered test functions (serialized with the graph).
    pub tests: TestRegistry,
}

impl LineageGraph {
    /// An empty graph.
    pub fn new() -> LineageGraph {
        LineageGraph::default()
    }

    // ------------------------------------------------------------------
    // Node / edge addition (paper API: add_node, add_edge,
    // add_version_edge, register_creation_function)
    // ------------------------------------------------------------------
    /// Add a node with a unique `name`; errors on a duplicate.
    pub fn add_node(&mut self, name: &str, model_type: &str) -> Result<NodeIdx> {
        if self.by_name.contains_key(name) {
            bail!("node `{name}` already exists");
        }
        let idx = self.nodes.len();
        self.nodes.push(Node::new(name, model_type));
        self.by_name.insert(name.to_string(), idx);
        Ok(idx)
    }

    /// add_node if missing; returns the index either way (paper's add_edge
    /// "calls add_node if nodes do not already exist").
    pub fn ensure_node(&mut self, name: &str, model_type: &str) -> NodeIdx {
        match self.by_name.get(name) {
            Some(&i) => i,
            None => self.add_node(name, model_type).expect("checked missing"),
        }
    }

    /// Index of the node named `name` (error if absent).
    pub fn idx(&self, name: &str) -> Result<NodeIdx> {
        self.by_name
            .get(name)
            .copied()
            .ok_or_else(|| anyhow!("no node named `{name}`"))
    }

    /// The node at `idx` (panics on an out-of-range index).
    pub fn node(&self, idx: NodeIdx) -> &Node {
        &self.nodes[idx]
    }

    /// Mutable access to the node at `idx`.
    pub fn node_mut(&mut self, idx: NodeIdx) -> &mut Node {
        &mut self.nodes[idx]
    }

    /// The node named `name` (error if absent).
    pub fn by_name(&self, name: &str) -> Result<&Node> {
        Ok(&self.nodes[self.idx(name)?])
    }

    /// Number of nodes.
    pub fn len(&self) -> usize {
        self.nodes.len()
    }

    /// Whether the graph has no nodes.
    pub fn is_empty(&self) -> bool {
        self.nodes.is_empty()
    }

    /// Add a provenance edge `parent -> child`.
    pub fn add_edge(&mut self, parent: NodeIdx, child: NodeIdx) -> Result<()> {
        self.check_idx(parent)?;
        self.check_idx(child)?;
        if parent == child {
            bail!("self-provenance is not allowed");
        }
        if self.nodes[parent].prov_children.contains(&child) {
            bail!(
                "provenance edge {} -> {} already exists",
                self.nodes[parent].name,
                self.nodes[child].name
            );
        }
        // Reject cycles: child must not already be an ancestor of parent.
        if self.is_provenance_ancestor(child, parent) {
            bail!(
                "adding {} -> {} would create a provenance cycle",
                self.nodes[parent].name,
                self.nodes[child].name
            );
        }
        self.nodes[parent].prov_children.push(child);
        self.nodes[child].prov_parents.push(parent);
        Ok(())
    }

    /// Add a versioning edge `old -> new`. Both nodes must have the same
    /// model type (paper API). A node has at most one *previous* version,
    /// but may grow several next versions over time (e.g. a manual update
    /// plus an Algorithm-2 cascade): versions form a tree, and
    /// [`LineageGraph::next_version`] returns the most recent branch.
    pub fn add_version_edge(&mut self, old: NodeIdx, new: NodeIdx) -> Result<()> {
        self.check_idx(old)?;
        self.check_idx(new)?;
        if old == new {
            bail!("self-version is not allowed");
        }
        if self.nodes[old].model_type != self.nodes[new].model_type {
            bail!(
                "version edge requires same model type ({} vs {})",
                self.nodes[old].model_type,
                self.nodes[new].model_type
            );
        }
        if !self.nodes[new].ver_parents.is_empty() {
            bail!("{} already has a previous version", self.nodes[new].name);
        }
        if self.version_chain_contains(new, old) {
            bail!("version edge would create a cycle");
        }
        self.nodes[old].ver_children.push(new);
        self.nodes[new].ver_parents.push(old);
        Ok(())
    }

    /// Attach the declarative creation function that (re-)produces this
    /// node from its parents (paper API; cascades re-execute it).
    pub fn register_creation_function(&mut self, idx: NodeIdx, cr: CreationSpec) -> Result<()> {
        self.check_idx(idx)?;
        self.nodes[idx].creation = Some(cr);
        Ok(())
    }

    // ------------------------------------------------------------------
    // Incremental append (the WAL / serving-tier commit operation)
    // ------------------------------------------------------------------
    /// Apply one serialized commit operation — the unit the writable
    /// serving tier appends to its write-ahead log:
    ///
    /// ```json
    /// {"name": "m/v2", "model_type": "t",
    ///  "stored": {…StoredModel…} | null,
    ///  "prov_parents": ["m/base"], "ver_parent": "m/v1" | null,
    ///  "metadata": {…}}
    /// ```
    ///
    /// Idempotent: a commit whose `name` already exists is a no-op
    /// returning `Ok(false)` — WAL replay after a crash between
    /// `graph.json` checkpoint and log truncation re-applies cleanly.
    /// Parent names are resolved before the node is added, so an
    /// unknown parent leaves the graph untouched.
    pub fn apply_commit(&mut self, op: &Json) -> Result<bool> {
        let name = op.req_str("name")?;
        if self.by_name.contains_key(name) {
            return Ok(false);
        }
        let model_type = op.req_str("model_type")?.to_string();
        let stored = match op.get("stored") {
            None | Some(Json::Null) => None,
            Some(j) => Some(StoredModel::from_json(j)?),
        };
        let mut prov = Vec::new();
        if let Some(parents) = op.get("prov_parents") {
            for p in parents
                .as_arr()
                .ok_or_else(|| anyhow!("prov_parents must be an array"))?
            {
                let pname = p
                    .as_str()
                    .ok_or_else(|| anyhow!("prov_parents entries must be strings"))?;
                prov.push(self.idx(pname)?);
            }
        }
        let ver = match op.get("ver_parent") {
            None | Some(Json::Null) => None,
            Some(v) => {
                let vname = v
                    .as_str()
                    .ok_or_else(|| anyhow!("ver_parent must be a string"))?;
                let vidx = self.idx(vname)?;
                if self.nodes[vidx].model_type != model_type {
                    bail!(
                        "version edge requires same model type ({} vs {})",
                        self.nodes[vidx].model_type,
                        model_type
                    );
                }
                Some(vidx)
            }
        };
        let name = name.to_string();
        let idx = self.add_node(&name, &model_type)?;
        self.nodes[idx].stored = stored;
        if let Some(md) = op.get("metadata") {
            self.nodes[idx].metadata = md.clone();
        }
        for p in prov {
            self.add_edge(p, idx)?;
        }
        if let Some(v) = ver {
            self.add_version_edge(v, idx)?;
        }
        Ok(true)
    }

    // ------------------------------------------------------------------
    // Removal (paper API: remove_edge, remove_node)
    // ------------------------------------------------------------------
    /// Remove the `ty` edge `parent -> child` (error if no such edge).
    pub fn remove_edge(&mut self, parent: NodeIdx, child: NodeIdx, ty: EdgeType) -> Result<()> {
        self.check_idx(parent)?;
        self.check_idx(child)?;
        // Edges never self-loop (enforced at insertion).
        if parent == child {
            bail!("no such edge");
        }
        let removed = match ty {
            EdgeType::Provenance => {
                let pc = &mut self.nodes[parent].prov_children;
                let before = pc.len();
                pc.retain(|&i| i != child);
                let removed = pc.len() != before;
                self.nodes[child].prov_parents.retain(|&i| i != parent);
                removed
            }
            EdgeType::Versioning => {
                let pc = &mut self.nodes[parent].ver_children;
                let before = pc.len();
                pc.retain(|&i| i != child);
                let removed = pc.len() != before;
                self.nodes[child].ver_parents.retain(|&i| i != parent);
                removed
            }
        };
        if !removed {
            bail!("no such edge");
        }
        Ok(())
    }

    /// Remove `idx` and its provenance sub-tree (paper: "removes node x
    /// and its sub-tree"). Returns the names of removed nodes.
    pub fn remove_node(&mut self, idx: NodeIdx) -> Result<Vec<String>> {
        self.check_idx(idx)?;
        // Collect the provenance-descendant closure of idx.
        let mut doomed = vec![false; self.nodes.len()];
        let mut stack = vec![idx];
        while let Some(i) = stack.pop() {
            if doomed[i] {
                continue;
            }
            doomed[i] = true;
            stack.extend(self.nodes[i].prov_children.iter().copied());
            // Versions of a doomed model are doomed too.
            stack.extend(self.nodes[i].ver_children.iter().copied());
        }
        let removed: Vec<String> = self
            .nodes
            .iter()
            .enumerate()
            .filter(|(i, _)| doomed[*i])
            .map(|(_, n)| n.name.clone())
            .collect();
        // Rebuild with surviving nodes, remapping indices.
        let mut remap = vec![usize::MAX; self.nodes.len()];
        let mut kept = Vec::new();
        for (i, node) in self.nodes.drain(..).enumerate() {
            if !doomed[i] {
                remap[i] = kept.len();
                kept.push(node);
            }
        }
        for node in &mut kept {
            let fix = |v: &mut Vec<NodeIdx>| {
                v.retain(|&i| remap[i] != usize::MAX);
                for i in v.iter_mut() {
                    *i = remap[*i];
                }
            };
            fix(&mut node.prov_parents);
            fix(&mut node.prov_children);
            fix(&mut node.ver_parents);
            fix(&mut node.ver_children);
        }
        self.nodes = kept;
        self.by_name = self
            .nodes
            .iter()
            .enumerate()
            .map(|(i, n)| (n.name.clone(), i))
            .collect();
        Ok(removed)
    }

    // ------------------------------------------------------------------
    // Queries
    // ------------------------------------------------------------------
    /// Every CAS object directly referenced by a stored model anywhere in
    /// the graph: the root set for GC marking and for pack repacking
    /// (delta-parent references are then walked transitively by the
    /// store layer).
    pub fn object_roots(&self) -> Vec<crate::store::ObjectId> {
        let mut out = Vec::new();
        for n in &self.nodes {
            if let Some(sm) = &n.stored {
                out.extend(sm.refs());
            }
        }
        out
    }

    /// Nodes with no provenance parents.
    pub fn roots(&self) -> Vec<NodeIdx> {
        (0..self.nodes.len())
            .filter(|&i| {
                self.nodes[i].prov_parents.is_empty() && self.nodes[i].ver_parents.is_empty()
            })
            .collect()
    }

    /// get_next_version(x) (paper API). With branching versions, the most
    /// recently added branch is "the" next version.
    pub fn next_version(&self, idx: NodeIdx) -> Option<NodeIdx> {
        self.nodes[idx].ver_children.last().copied()
    }

    /// get_prev_version(x): the node this one is the next version of.
    pub fn prev_version(&self, idx: NodeIdx) -> Option<NodeIdx> {
        self.nodes[idx].ver_parents.first().copied()
    }

    /// Latest version reachable from `idx` along versioning edges.
    pub fn latest_version(&self, idx: NodeIdx) -> NodeIdx {
        let mut cur = idx;
        while let Some(next) = self.next_version(cur) {
            cur = next;
        }
        cur
    }

    fn version_chain_contains(&self, start: NodeIdx, needle: NodeIdx) -> bool {
        let mut cur = Some(start);
        while let Some(i) = cur {
            if i == needle {
                return true;
            }
            cur = self.next_version(i);
        }
        false
    }

    /// Whether `anc` is reachable from `of` walking provenance edges up.
    pub fn is_provenance_ancestor(&self, anc: NodeIdx, of: NodeIdx) -> bool {
        let mut stack = vec![of];
        let mut seen = vec![false; self.nodes.len()];
        while let Some(i) = stack.pop() {
            if i == anc {
                return true;
            }
            if seen[i] {
                continue;
            }
            seen[i] = true;
            stack.extend(self.nodes[i].prov_parents.iter().copied());
        }
        false
    }

    /// Closest common provenance ancestor of two nodes (used by `merge`).
    pub fn common_ancestor(&self, a: NodeIdx, b: NodeIdx) -> Option<NodeIdx> {
        // BFS ancestor sets with depth; pick the common one minimizing
        // max(depth_a, depth_b).
        let depths = |start: NodeIdx| {
            let mut d: HashMap<NodeIdx, usize> = HashMap::new();
            let mut queue = std::collections::VecDeque::from([(start, 0usize)]);
            while let Some((i, dep)) = queue.pop_front() {
                if d.contains_key(&i) {
                    continue;
                }
                d.insert(i, dep);
                for &p in &self.nodes[i].prov_parents {
                    queue.push_back((p, dep + 1));
                }
            }
            d
        };
        let da = depths(a);
        let db = depths(b);
        da.iter()
            .filter_map(|(i, &x)| db.get(i).map(|&y| (*i, x.max(y))))
            .min_by_key(|&(_, d)| d)
            .map(|(i, _)| i)
    }

    fn check_idx(&self, idx: NodeIdx) -> Result<()> {
        if idx >= self.nodes.len() {
            bail!("node index {idx} out of range");
        }
        Ok(())
    }

    /// Count edges of each type (Table 3 reporting).
    pub fn edge_counts(&self) -> (usize, usize) {
        let prov = self.nodes.iter().map(|n| n.prov_children.len()).sum();
        let ver = self.nodes.iter().map(|n| n.ver_children.len()).sum();
        (prov, ver)
    }

    // ------------------------------------------------------------------
    // Integrity
    // ------------------------------------------------------------------
    /// Verify structural invariants; returns an error describing the first
    /// violation. Run by `mgit fsck` and by property tests.
    pub fn integrity_check(&self) -> Result<()> {
        if self.by_name.len() != self.nodes.len() {
            bail!("name index size mismatch");
        }
        for (name, &i) in &self.by_name {
            if self.nodes.get(i).map(|n| &n.name) != Some(name) {
                bail!("name index points to wrong node for `{name}`");
            }
        }
        for (i, n) in self.nodes.iter().enumerate() {
            for &c in &n.prov_children {
                if !self.nodes[c].prov_parents.contains(&i) {
                    bail!("asymmetric provenance edge {} -> {}", n.name, self.nodes[c].name);
                }
            }
            for &p in &n.prov_parents {
                if !self.nodes[p].prov_children.contains(&i) {
                    bail!("asymmetric provenance back-edge at {}", n.name);
                }
            }
            for &c in &n.ver_children {
                if !self.nodes[c].ver_parents.contains(&i) {
                    bail!("asymmetric version edge at {}", n.name);
                }
                if self.nodes[c].model_type != n.model_type {
                    bail!("version edge across model types at {}", n.name);
                }
            }
            if n.ver_parents.len() > 1 {
                bail!("node {} has multiple previous versions", n.name);
            }
        }
        // Provenance acyclicity via Kahn's algorithm.
        let mut indeg: Vec<usize> = self.nodes.iter().map(|n| n.prov_parents.len()).collect();
        let mut queue: Vec<NodeIdx> =
            (0..self.nodes.len()).filter(|&i| indeg[i] == 0).collect();
        let mut seen = 0;
        while let Some(i) = queue.pop() {
            seen += 1;
            for &c in &self.nodes[i].prov_children {
                indeg[c] -= 1;
                if indeg[c] == 0 {
                    queue.push(c);
                }
            }
        }
        if seen != self.nodes.len() {
            bail!("provenance cycle detected");
        }
        Ok(())
    }

    // ------------------------------------------------------------------
    // Serialization
    // ------------------------------------------------------------------
    /// Serialize the whole graph (nodes, edges, stored-model pointers,
    /// creation specs, metadata, test registry) to JSON.
    pub fn to_json(&self) -> Json {
        let nodes: Vec<Json> = self
            .nodes
            .iter()
            .map(|n| {
                let mut j = Json::obj()
                    .set("name", n.name.as_str())
                    .set("model_type", n.model_type.as_str())
                    .set(
                        "prov_parents",
                        Json::Arr(n.prov_parents.iter().map(|&i| Json::from(i)).collect()),
                    )
                    .set(
                        "ver_parents",
                        Json::Arr(n.ver_parents.iter().map(|&i| Json::from(i)).collect()),
                    )
                    .set("metadata", n.metadata.clone());
                if let Some(s) = &n.stored {
                    j = j.set("stored", s.to_json());
                }
                if let Some(c) = &n.creation {
                    j = j.set("creation", c.to_json());
                }
                j
            })
            .collect();
        Json::obj()
            .set("version", 1usize)
            .set("nodes", Json::Arr(nodes))
            .set("tests", self.tests.to_json())
    }

    /// Rebuild a graph from [`LineageGraph::to_json`] output, re-running
    /// the integrity check.
    pub fn from_json(j: &Json) -> Result<LineageGraph> {
        let mut g = LineageGraph::new();
        let nodes = j.req_arr("nodes")?;
        // First pass: create nodes.
        for nj in nodes {
            let idx = g.add_node(nj.req_str("name")?, nj.req_str("model_type")?)?;
            let node = &mut g.nodes[idx];
            node.metadata = nj.get("metadata").cloned().unwrap_or_else(Json::obj);
            if let Some(s) = nj.get("stored") {
                node.stored = Some(StoredModel::from_json(s)?);
            }
            if let Some(c) = nj.get("creation") {
                node.creation = Some(CreationSpec::from_json(c)?);
            }
        }
        // Second pass: edges (parent lists drive both directions).
        for (child, nj) in nodes.iter().enumerate() {
            for p in nj.req_arr("prov_parents")? {
                let p = p.as_usize().ok_or_else(|| anyhow!("bad parent index"))?;
                g.add_edge(p, child)?;
            }
            for p in nj.req_arr("ver_parents")? {
                let p = p.as_usize().ok_or_else(|| anyhow!("bad version parent index"))?;
                g.add_version_edge(p, child)?;
            }
        }
        if let Some(t) = j.get("tests") {
            g.tests = TestRegistry::from_json(t)?;
        }
        g.integrity_check()?;
        Ok(g)
    }

    /// Serialize to `path` atomically (write-to-temp + rename).
    pub fn save(&self, path: &Path) -> Result<()> {
        let text = self.to_json().to_string_pretty();
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        let tmp = path.with_extension("json.tmp");
        std::fs::write(&tmp, text)?;
        std::fs::rename(&tmp, path)?;
        Ok(())
    }

    /// Load a graph previously [`LineageGraph::save`]d.
    pub fn load(path: &Path) -> Result<LineageGraph> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading lineage graph {}", path.display()))?;
        Self::from_json(&json::parse(&text)?)
    }
}

#[cfg(test)]
pub mod testutil {
    use super::*;

    /// a -> b -> c, a -> d; b has versions b, b2.
    pub fn diamondish() -> LineageGraph {
        let mut g = LineageGraph::new();
        let a = g.add_node("a", "tx").unwrap();
        let b = g.add_node("b", "tx").unwrap();
        let c = g.add_node("c", "tx").unwrap();
        let d = g.add_node("d", "tx").unwrap();
        let b2 = g.add_node("b2", "tx").unwrap();
        g.add_edge(a, b).unwrap();
        g.add_edge(b, c).unwrap();
        g.add_edge(a, d).unwrap();
        g.add_version_edge(b, b2).unwrap();
        g
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn build_and_query() {
        let g = testutil::diamondish();
        assert_eq!(g.len(), 5);
        let (prov, ver) = g.edge_counts();
        assert_eq!((prov, ver), (3, 1));
        let a = g.idx("a").unwrap();
        let b = g.idx("b").unwrap();
        assert_eq!(g.roots(), vec![a]);
        assert_eq!(g.next_version(b), Some(g.idx("b2").unwrap()));
        assert_eq!(g.latest_version(b), g.idx("b2").unwrap());
        assert!(g.is_provenance_ancestor(a, g.idx("c").unwrap()));
        assert!(!g.is_provenance_ancestor(g.idx("c").unwrap(), a));
        g.integrity_check().unwrap();
    }

    #[test]
    fn duplicate_names_rejected() {
        let mut g = LineageGraph::new();
        g.add_node("x", "t").unwrap();
        assert!(g.add_node("x", "t").is_err());
        assert_eq!(g.ensure_node("x", "t"), 0);
    }

    #[test]
    fn cycles_rejected() {
        let mut g = LineageGraph::new();
        let a = g.add_node("a", "t").unwrap();
        let b = g.add_node("b", "t").unwrap();
        let c = g.add_node("c", "t").unwrap();
        g.add_edge(a, b).unwrap();
        g.add_edge(b, c).unwrap();
        assert!(g.add_edge(c, a).is_err());
        assert!(g.add_edge(a, a).is_err());
        assert!(g.add_edge(a, b).is_err()); // duplicate
    }

    #[test]
    fn version_chain_constraints() {
        let mut g = LineageGraph::new();
        let v1 = g.add_node("m_v1", "tx").unwrap();
        let v2 = g.add_node("m_v2", "tx").unwrap();
        let v3 = g.add_node("m_v3", "tx").unwrap();
        let other = g.add_node("o", "resnet").unwrap();
        g.add_version_edge(v1, v2).unwrap();
        g.add_version_edge(v2, v3).unwrap();
        assert!(g.add_version_edge(v2, v3).is_err()); // v3 already has prev
        assert!(g.add_version_edge(v3, other).is_err()); // type mismatch
        assert!(g.add_version_edge(v3, v1).is_err()); // cycle
        assert_eq!(g.latest_version(v1), v3);
        // Branching: v1 may grow a second next version (cascade + manual);
        // next_version picks the most recent branch.
        let v2b = g.add_node("m_v2b", "tx").unwrap();
        g.add_version_edge(v1, v2b).unwrap();
        assert_eq!(g.next_version(v1), Some(v2b));
        g.integrity_check().unwrap();
    }

    #[test]
    fn remove_edge_and_subtree() {
        let mut g = testutil::diamondish();
        let a = g.idx("a").unwrap();
        let d = g.idx("d").unwrap();
        g.remove_edge(a, d, EdgeType::Provenance).unwrap();
        assert!(g.remove_edge(a, d, EdgeType::Provenance).is_err());
        g.integrity_check().unwrap();

        // Removing b takes its subtree (c) and its versions (b2) with it.
        let b = g.idx("b").unwrap();
        let mut removed = g.remove_node(b).unwrap();
        removed.sort();
        assert_eq!(removed, vec!["b", "b2", "c"]);
        assert_eq!(g.len(), 2);
        assert!(g.idx("a").is_ok() && g.idx("d").is_ok());
        g.integrity_check().unwrap();
    }

    #[test]
    fn common_ancestor_diamond() {
        let mut g = LineageGraph::new();
        let root = g.add_node("root", "t").unwrap();
        let l = g.add_node("l", "t").unwrap();
        let r = g.add_node("r", "t").unwrap();
        let ll = g.add_node("ll", "t").unwrap();
        g.add_edge(root, l).unwrap();
        g.add_edge(root, r).unwrap();
        g.add_edge(l, ll).unwrap();
        assert_eq!(g.common_ancestor(ll, r), Some(root));
        assert_eq!(g.common_ancestor(ll, l), Some(l));
        let lone = g.add_node("lone", "t").unwrap();
        assert_eq!(g.common_ancestor(ll, lone), None);
    }

    #[test]
    fn json_roundtrip_with_payloads() {
        let mut g = testutil::diamondish();
        let b = g.idx("b").unwrap();
        g.register_creation_function(
            b,
            CreationSpec::Finetune {
                task: "task1".into(),
                objective: crate::registry::Objective::Cls,
                steps: 10,
                lr: 0.1,
                seed: 1,
                freeze: crate::registry::FreezeSpec::None,
                perturb: None,
            },
        )
        .unwrap();
        g.nodes[b].metadata = Json::obj().set("note", "hello");
        g.tests
            .register(
                "finite",
                crate::registry::TestScope::ModelType("tx".into()),
                crate::registry::TestSpec::FiniteParams,
            )
            .unwrap();
        let j = g.to_json();
        let back = LineageGraph::from_json(&j).unwrap();
        assert_eq!(back.len(), g.len());
        assert_eq!(back.by_name("b").unwrap().creation, g.nodes[b].creation);
        assert_eq!(back.by_name("b").unwrap().metadata.req_str("note").unwrap(), "hello");
        assert_eq!(back.tests.tests.len(), 1);
        assert_eq!(back.edge_counts(), g.edge_counts());
    }

    #[test]
    fn save_load_disk() {
        let g = testutil::diamondish();
        let path = std::env::temp_dir().join(format!("mgit-graph-{}.json", std::process::id()));
        g.save(&path).unwrap();
        let back = LineageGraph::load(&path).unwrap();
        assert_eq!(back.len(), g.len());
        std::fs::remove_file(&path).unwrap();
    }
}
