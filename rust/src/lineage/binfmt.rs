//! MGGI — the indexed binary lineage graph format (graph format v1).
//!
//! `graph.json` (the v0 format) is parsed in full on every `Repo::open`;
//! at millions of nodes that parse is the startup and memory wall. MGGI
//! is the graph counterpart of the pack v2/v3 index work: one
//! memory-mappable file whose header, name index, and CSR adjacency
//! sections answer `idx`/`len`/`parents-of` queries with O(page) reads,
//! while node bodies stay compact JSON decoded one node at a time.
//!
//! ## Byte format (all integers little-endian)
//!
//! ```text
//! header (96 bytes)
//!   [0..4)    magic  "MGGI"
//!   [4..8)    u32    format version (1)
//!   [8..16)   u64    node count N
//!   [16..24)  u64    provenance edge count P
//!   [24..32)  u64    version edge count V
//!   [32..40)  u64    name index offset   (== 96)
//!   [40..48)  u64    adjacency offset
//!   [48..56)  u64    bodies index offset
//!   [56..64)  u64    bodies offset
//!   [64..72)  u64    tests offset
//!   [72..80)  u64    tests length
//!   [80..88)  u64    base length (tail records start here)
//!   [88..96)  u64    reserved (zero)
//!
//! name index: N x 12 bytes  { fnv1a64(name) u64, node index u32 },
//!   sorted by (hash, index); lookups binary-search the hash then
//!   confirm against the body (collisions are adjacent entries).
//!
//! adjacency: four CSR blocks, in order
//!   [prov_parents, prov_children, ver_parents, ver_children];
//!   each block is (N+1) x u64 prefix offsets followed by E x u32
//!   target node indices (E = P for the two prov blocks, V for ver).
//!
//! bodies index: N x 12 bytes { body offset u64 (relative to bodies
//!   offset), body length u32 }.
//!
//! bodies: per-node compact JSON
//!   {"name","model_type","metadata"[,"stored"][,"creation"]}
//!   (adjacency lives in the CSR blocks, not in the body).
//!
//! tests: the [`TestRegistry`] as compact JSON.
//!
//! tail (after base length): zero or more append-only records
//!   [len u32][crc32 u32][payload], payload = one serialized commit
//!   operation (the [`LineageGraph::apply_commit`] JSON shape, exactly
//!   what the serving tier's WAL carries). Readers keep the longest
//!   valid prefix and report anything after it as torn — the same
//!   contract as the WAL itself.
//! ```
//!
//! Version dispatch follows the pack v1 -> v2 -> v3 precedent: the
//! version field is read before anything else, unknown versions fail
//! loudly, and a committed fixture (`tests/fixtures/graph_v1/`) pins
//! v1 readability forever. Repos without a `graph.bin` keep using
//! `graph.json` unchanged.

use std::io::Write;
use std::path::Path;

use anyhow::{anyhow, bail, Context, Result};

use crate::delta::StoredModel;
use crate::registry::{CreationSpec, TestRegistry};
use crate::store::pack::PackMmap;
use crate::store::wal::crc32;
use crate::util::json::{self, Json};

use super::{LineageGraph, Node, NodeIdx};

/// File magic, the graph analogue of `MGPK`/`MGPI`/`MGWL`.
pub const GRAPH_MAGIC: &[u8; 4] = b"MGGI";
/// Current (and only) binary graph format version.
pub const GRAPH_VERSION: u32 = 1;
/// Fixed header length.
pub const HEADER_LEN: u64 = 96;
/// Upper bound on one tail record's payload; anything larger is
/// treated as tail corruption rather than an allocation request.
pub const MAX_TAIL_RECORD: u32 = 1 << 26;

/// FNV-1a 64-bit — the name-index hash. Stable by definition; part of
/// the on-disk format.
pub fn fnv64(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

fn u32le(b: &[u8]) -> u32 {
    u32::from_le_bytes([b[0], b[1], b[2], b[3]])
}

fn u64le(b: &[u8]) -> u64 {
    u64::from_le_bytes([b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7]])
}

/// Serialize one node's body (adjacency excluded — that lives in the
/// CSR section). Key order is fixed; it is part of the format.
fn body_json(n: &Node) -> Json {
    let mut j = Json::obj()
        .set("name", n.name.as_str())
        .set("model_type", n.model_type.as_str())
        .set("metadata", n.metadata.clone());
    if let Some(s) = &n.stored {
        j = j.set("stored", s.to_json());
    }
    if let Some(c) = &n.creation {
        j = j.set("creation", c.to_json());
    }
    j
}

/// Encode a full graph as one MGGI v1 image (no tail records).
pub fn encode(g: &LineageGraph) -> Result<Vec<u8>> {
    let n = g.nodes.len();
    if n > u32::MAX as usize - 1 {
        bail!("graph too large for MGGI v1 ({n} nodes)");
    }
    let (prov, ver) = g.edge_counts();

    // Name index, sorted by (hash, idx).
    let mut names: Vec<(u64, u32)> = g
        .nodes
        .iter()
        .enumerate()
        .map(|(i, node)| (fnv64(node.name.as_bytes()), i as u32))
        .collect();
    names.sort_unstable();

    // Bodies + bodies index.
    let mut bodies = Vec::new();
    let mut bodies_idx: Vec<(u64, u32)> = Vec::with_capacity(n);
    for node in &g.nodes {
        let text = body_json(node).to_string_compact();
        let bytes = text.as_bytes();
        if bytes.len() > u32::MAX as usize {
            bail!("node body `{}` too large for MGGI v1", node.name);
        }
        bodies_idx.push((bodies.len() as u64, bytes.len() as u32));
        bodies.extend_from_slice(bytes);
    }

    // Four CSR adjacency blocks.
    fn push_block(adj: &mut Vec<u8>, nodes: &[Node], list: fn(&Node) -> &[NodeIdx]) {
        let mut off = 0u64;
        for node in nodes {
            adj.extend_from_slice(&off.to_le_bytes());
            off += list(node).len() as u64;
        }
        adj.extend_from_slice(&off.to_le_bytes());
        for node in nodes {
            for &t in list(node) {
                adj.extend_from_slice(&(t as u32).to_le_bytes());
            }
        }
    }
    let mut adj = Vec::new();
    push_block(&mut adj, &g.nodes, |n| &n.prov_parents);
    push_block(&mut adj, &g.nodes, |n| &n.prov_children);
    push_block(&mut adj, &g.nodes, |n| &n.ver_parents);
    push_block(&mut adj, &g.nodes, |n| &n.ver_children);

    let tests = g.tests.to_json().to_string_compact();
    let name_idx_off = HEADER_LEN;
    let adj_off = name_idx_off + 12 * n as u64;
    let bodies_idx_off = adj_off + adj.len() as u64;
    let bodies_off = bodies_idx_off + 12 * n as u64;
    let tests_off = bodies_off + bodies.len() as u64;
    let tests_len = tests.len() as u64;
    let base_len = tests_off + tests_len;

    let mut out = Vec::with_capacity(base_len as usize);
    out.extend_from_slice(GRAPH_MAGIC);
    out.extend_from_slice(&GRAPH_VERSION.to_le_bytes());
    out.extend_from_slice(&(n as u64).to_le_bytes());
    out.extend_from_slice(&(prov as u64).to_le_bytes());
    out.extend_from_slice(&(ver as u64).to_le_bytes());
    out.extend_from_slice(&name_idx_off.to_le_bytes());
    out.extend_from_slice(&adj_off.to_le_bytes());
    out.extend_from_slice(&bodies_idx_off.to_le_bytes());
    out.extend_from_slice(&bodies_off.to_le_bytes());
    out.extend_from_slice(&tests_off.to_le_bytes());
    out.extend_from_slice(&tests_len.to_le_bytes());
    out.extend_from_slice(&base_len.to_le_bytes());
    out.extend_from_slice(&0u64.to_le_bytes());
    for (h, i) in &names {
        out.extend_from_slice(&h.to_le_bytes());
        out.extend_from_slice(&i.to_le_bytes());
    }
    out.extend_from_slice(&adj);
    for (off, len) in &bodies_idx {
        out.extend_from_slice(&off.to_le_bytes());
        out.extend_from_slice(&len.to_le_bytes());
    }
    out.extend_from_slice(&bodies);
    out.extend_from_slice(tests.as_bytes());
    debug_assert_eq!(out.len() as u64, base_len);
    Ok(out)
}

/// Write a compact (tail-free) MGGI image atomically (temp + rename,
/// fsynced before the rename so a fold is durable once it returns).
pub fn write_binary(g: &LineageGraph, path: &Path) -> Result<()> {
    let bytes = encode(g)?;
    if let Some(parent) = path.parent() {
        std::fs::create_dir_all(parent)?;
    }
    let tmp = path.with_extension("bin.tmp");
    let mut f = std::fs::File::create(&tmp)
        .with_context(|| format!("creating {}", tmp.display()))?;
    f.write_all(&bytes)?;
    f.sync_all()?;
    std::fs::rename(&tmp, path)?;
    Ok(())
}

/// Append commit operations as tail records (the incremental fold: one
/// fixed-framing record per commit instead of a full-image rewrite).
/// Fsyncs before returning — callers may truncate the WAL afterwards.
pub fn append_commits(path: &Path, ops: &[Json]) -> Result<()> {
    if ops.is_empty() {
        return Ok(());
    }
    let mut f = std::fs::OpenOptions::new()
        .append(true)
        .open(path)
        .with_context(|| format!("opening {} for tail append", path.display()))?;
    let mut buf = Vec::new();
    for op in ops {
        let payload = op.to_string_compact();
        let payload = payload.as_bytes();
        if payload.len() > MAX_TAIL_RECORD as usize {
            bail!("commit operation too large for a graph tail record");
        }
        buf.extend_from_slice(&(payload.len() as u32).to_le_bytes());
        buf.extend_from_slice(&crc32(payload).to_le_bytes());
        buf.extend_from_slice(payload);
    }
    f.write_all(&buf)?;
    f.sync_all()?;
    Ok(())
}

/// A tail that stops being valid partway through (crash mid-append).
#[derive(Debug, Clone)]
pub struct TailTorn {
    /// Byte offset of the first invalid record.
    pub offset: u64,
    pub reason: String,
}

/// A memory-mapped MGGI file: O(page) open, on-demand node decode.
///
/// Reads go through [`PackMmap`], so the `--no-default-features`
/// (no-mmap) build transparently falls back to positional reads — same
/// API, same results.
pub struct MappedGraph {
    map: PackMmap,
    node_count: u64,
    prov_edges: u64,
    ver_edges: u64,
    name_idx_off: u64,
    adj_off: u64,
    bodies_idx_off: u64,
    bodies_off: u64,
    tests_off: u64,
    tests_len: u64,
    base_len: u64,
    /// Commit operations recovered from the valid tail prefix, in
    /// append order. Applied on [`MappedGraph::materialize`].
    pub tail_ops: Vec<Json>,
    /// Set when bytes past the valid tail prefix exist but do not form
    /// a valid record (torn append). The durable prefix above is still
    /// served; fsck surfaces this as `TORN_GRAPH_TAIL`.
    pub tail_torn: Option<TailTorn>,
}

/// The four CSR adjacency blocks, in on-disk order.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AdjBlock {
    ProvParents = 0,
    ProvChildren = 1,
    VerParents = 2,
    VerChildren = 3,
}

impl MappedGraph {
    /// Map `path` and validate the header + section layout. Node bodies
    /// and adjacency are *not* read; the tail is scanned (it is the
    /// only variable-validity region).
    pub fn open(path: &Path) -> Result<MappedGraph> {
        let map = PackMmap::open(path)
            .with_context(|| format!("mapping graph index {}", path.display()))?;
        Self::parse(map).with_context(|| format!("reading graph index {}", path.display()))
    }

    fn parse(map: PackMmap) -> Result<MappedGraph> {
        if map.len() < HEADER_LEN {
            bail!("file shorter than an MGGI header");
        }
        let h = map.read_at(0, HEADER_LEN as usize)?;
        if &h[0..4] != GRAPH_MAGIC {
            bail!("bad magic (not an MGGI graph index)");
        }
        let version = u32le(&h[4..8]);
        if version != GRAPH_VERSION {
            bail!("unsupported graph format version {version} (this build reads v1)");
        }
        let node_count = u64le(&h[8..16]);
        let prov_edges = u64le(&h[16..24]);
        let ver_edges = u64le(&h[24..32]);
        let name_idx_off = u64le(&h[32..40]);
        let adj_off = u64le(&h[40..48]);
        let bodies_idx_off = u64le(&h[48..56]);
        let bodies_off = u64le(&h[56..64]);
        let tests_off = u64le(&h[64..72]);
        let tests_len = u64le(&h[72..80]);
        let base_len = u64le(&h[80..88]);
        if node_count >= u32::MAX as u64 {
            bail!("implausible node count {node_count}");
        }
        if prov_edges > map.len() || ver_edges > map.len() {
            bail!("implausible edge counts");
        }
        // The v1 layout is fully determined by the counts; recompute and
        // demand exact agreement so a malformed writer can't smuggle
        // overlapping sections past the bounds checks below.
        let adj_len = 4 * (node_count + 1) * 8 + (2 * prov_edges + 2 * ver_edges) * 4;
        if name_idx_off != HEADER_LEN
            || adj_off != name_idx_off + 12 * node_count
            || bodies_idx_off != adj_off + adj_len
            || bodies_off != bodies_idx_off + 12 * node_count
            || tests_off < bodies_off
            || tests_off.checked_add(tests_len) != Some(base_len)
            || base_len > map.len()
        {
            bail!("section table is inconsistent with the v1 layout");
        }
        let (tail_ops, tail_torn) = Self::scan_tail(&map, base_len)?;
        Ok(MappedGraph {
            map,
            node_count,
            prov_edges,
            ver_edges,
            name_idx_off,
            adj_off,
            bodies_idx_off,
            bodies_off,
            tests_off,
            tests_len,
            base_len,
            tail_ops,
            tail_torn,
        })
    }

    fn scan_tail(map: &PackMmap, base_len: u64) -> Result<(Vec<Json>, Option<TailTorn>)> {
        let mut ops = Vec::new();
        let mut off = base_len;
        while off < map.len() {
            let torn = |reason: &str| {
                Some(TailTorn { offset: off, reason: reason.to_string() })
            };
            if off + 8 > map.len() {
                return Ok((ops, torn("truncated record header")));
            }
            let hdr = map.read_at(off, 8)?;
            let len = u32le(&hdr[0..4]);
            let crc = u32le(&hdr[4..8]);
            if len == 0 || len > MAX_TAIL_RECORD {
                return Ok((ops, torn("implausible record length")));
            }
            if off + 8 + len as u64 > map.len() {
                return Ok((ops, torn("truncated record body")));
            }
            let payload = map.read_at(off + 8, len as usize)?;
            if crc32(&payload) != crc {
                return Ok((ops, torn("checksum mismatch")));
            }
            let text = match std::str::from_utf8(&payload) {
                Ok(t) => t,
                Err(_) => return Ok((ops, torn("payload is not UTF-8"))),
            };
            match json::parse(text) {
                Ok(op) => ops.push(op),
                Err(_) => return Ok((ops, torn("payload is not valid JSON"))),
            }
            off += 8 + len as u64;
        }
        Ok((ops, None))
    }

    /// Node count of the base image (tail commits not included).
    pub fn node_count(&self) -> usize {
        self.node_count as usize
    }

    /// (provenance, versioning) edge counts of the base image — O(1),
    /// straight from the header.
    pub fn edge_counts(&self) -> (usize, usize) {
        (self.prov_edges as usize, self.ver_edges as usize)
    }

    /// End of the base image / start of the tail.
    pub fn base_len(&self) -> u64 {
        self.base_len
    }

    /// Total mapped length (base + tail).
    pub fn file_len(&self) -> u64 {
        self.map.len()
    }

    fn name_entry(&self, pos: usize) -> Result<(u64, usize)> {
        let e = self.map.read_at(self.name_idx_off + 12 * pos as u64, 12)?;
        Ok((u64le(&e[0..8]), u32le(&e[8..12]) as usize))
    }

    /// Name -> index through the fanout index: binary search on the
    /// hash, confirm against the body (hash collisions are adjacent
    /// entries). `Ok(None)` when absent.
    pub fn idx(&self, name: &str) -> Result<Option<NodeIdx>> {
        let target = fnv64(name.as_bytes());
        let n = self.node_count();
        let (mut lo, mut hi) = (0usize, n);
        while lo < hi {
            let mid = lo + (hi - lo) / 2;
            if self.name_entry(mid)?.0 < target {
                lo = mid + 1;
            } else {
                hi = mid;
            }
        }
        while lo < n {
            let (h, idx) = self.name_entry(lo)?;
            if h != target {
                break;
            }
            if self.name_of(idx)? == name {
                return Ok(Some(idx));
            }
            lo += 1;
        }
        Ok(None)
    }

    /// Decode one node body (compact JSON) without touching adjacency.
    pub fn body(&self, idx: NodeIdx) -> Result<Json> {
        if idx >= self.node_count() {
            bail!("node index {idx} out of range");
        }
        let e = self.map.read_at(self.bodies_idx_off + 12 * idx as u64, 12)?;
        let off = u64le(&e[0..8]);
        let len = u32le(&e[8..12]) as u64;
        let end = self.bodies_off.checked_add(off).and_then(|v| v.checked_add(len));
        if !matches!(end, Some(e) if e <= self.tests_off) {
            bail!("body entry {idx} escapes the bodies section");
        }
        let bytes = self.map.read_at(self.bodies_off + off, len as usize)?;
        let text = std::str::from_utf8(&bytes)
            .map_err(|_| anyhow!("body {idx} is not UTF-8"))?;
        json::parse(text).with_context(|| format!("parsing body of node {idx}"))
    }

    /// The name of node `idx` (one body decode).
    pub fn name_of(&self, idx: NodeIdx) -> Result<String> {
        Ok(self.body(idx)?.req_str("name")?.to_string())
    }

    /// One CSR adjacency list: two offset reads + the target range.
    pub fn adjacency(&self, block: AdjBlock, idx: NodeIdx) -> Result<Vec<NodeIdx>> {
        let n = self.node_count as u64;
        if idx as u64 >= n {
            bail!("node index {idx} out of range");
        }
        let edges = |b: AdjBlock| match b {
            AdjBlock::ProvParents | AdjBlock::ProvChildren => self.prov_edges,
            AdjBlock::VerParents | AdjBlock::VerChildren => self.ver_edges,
        };
        let mut block_off = self.adj_off;
        for b in [AdjBlock::ProvParents, AdjBlock::ProvChildren, AdjBlock::VerParents] {
            if b as usize >= block as usize {
                break;
            }
            block_off += (n + 1) * 8 + edges(b) * 4;
        }
        let offs = self.map.read_at(block_off + 8 * idx as u64, 16)?;
        let (start, end) = (u64le(&offs[0..8]), u64le(&offs[8..16]));
        if start > end || end > edges(block) {
            bail!("corrupt CSR offsets for node {idx}");
        }
        let targets_off = block_off + (n + 1) * 8;
        let bytes = self.map.read_at(targets_off + 4 * start, (end - start) as usize * 4)?;
        Ok(bytes.chunks_exact(4).map(|c| u32le(c) as NodeIdx).collect())
    }

    /// Decode one full [`Node`] (body + its four adjacency lists).
    pub fn node(&self, idx: NodeIdx) -> Result<Node> {
        let body = self.body(idx)?;
        let stored = match body.get("stored") {
            None | Some(Json::Null) => None,
            Some(s) => Some(StoredModel::from_json(s)?),
        };
        let creation = match body.get("creation") {
            None | Some(Json::Null) => None,
            Some(c) => Some(CreationSpec::from_json(c)?),
        };
        Ok(Node {
            name: body.req_str("name")?.to_string(),
            model_type: body.req_str("model_type")?.to_string(),
            stored,
            creation,
            metadata: body.get("metadata").cloned().unwrap_or_else(Json::obj),
            prov_parents: self.adjacency(AdjBlock::ProvParents, idx)?,
            prov_children: self.adjacency(AdjBlock::ProvChildren, idx)?,
            ver_parents: self.adjacency(AdjBlock::VerParents, idx)?,
            ver_children: self.adjacency(AdjBlock::VerChildren, idx)?,
        })
    }

    /// The test registry blob.
    pub fn tests(&self) -> Result<TestRegistry> {
        let bytes = self.map.read_at(self.tests_off, self.tests_len as usize)?;
        let text = std::str::from_utf8(&bytes)
            .map_err(|_| anyhow!("tests section is not UTF-8"))?;
        TestRegistry::from_json(&json::parse(text)?)
    }

    /// Rebuild the full in-memory [`LineageGraph`]: decode every body,
    /// wire edges from the CSR parents, re-run the integrity check,
    /// then apply the recovered tail commits (idempotently, exactly
    /// like WAL replay).
    pub fn materialize(&self) -> Result<LineageGraph> {
        let n = self.node_count();
        let mut nodes = Vec::with_capacity(n);
        for i in 0..n {
            let body = self
                .body(i)?
                .set(
                    "prov_parents",
                    Json::Arr(
                        self.adjacency(AdjBlock::ProvParents, i)?
                            .into_iter()
                            .map(Json::from)
                            .collect(),
                    ),
                )
                .set(
                    "ver_parents",
                    Json::Arr(
                        self.adjacency(AdjBlock::VerParents, i)?
                            .into_iter()
                            .map(Json::from)
                            .collect(),
                    ),
                );
            nodes.push(body);
        }
        let doc = Json::obj()
            .set("version", 1usize)
            .set("nodes", Json::Arr(nodes))
            .set("tests", self.tests()?.to_json());
        let mut g = LineageGraph::from_json(&doc)?;
        for op in &self.tail_ops {
            g.apply_commit(op)
                .with_context(|| "applying graph tail commit".to_string())?;
        }
        Ok(g)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lineage::testutil;

    fn tmpfile(tag: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("mgit-binfmt-{tag}-{}.bin", std::process::id()))
    }

    #[test]
    fn fnv64_known_vector() {
        // FNV-1a 64 of "a" (published test vector).
        assert_eq!(fnv64(b"a"), 0xaf63_dc4c_8601_ec8c);
        assert_eq!(fnv64(b""), 0xcbf2_9ce4_8422_2325);
    }

    #[test]
    fn roundtrip_diamondish() {
        let mut g = testutil::diamondish();
        let b = g.idx("b").unwrap();
        g.nodes[b].metadata = Json::obj().set("note", "hello");
        let path = tmpfile("roundtrip");
        write_binary(&g, &path).unwrap();
        let m = MappedGraph::open(&path).unwrap();
        assert_eq!(m.node_count(), g.len());
        assert_eq!(m.edge_counts(), g.edge_counts());
        assert!(m.tail_ops.is_empty() && m.tail_torn.is_none());
        // Lazy lookups agree with the in-memory graph.
        for (i, node) in g.nodes.iter().enumerate() {
            assert_eq!(m.idx(&node.name).unwrap(), Some(i));
            assert_eq!(m.name_of(i).unwrap(), node.name);
            assert_eq!(m.adjacency(AdjBlock::ProvParents, i).unwrap(), node.prov_parents);
            assert_eq!(m.adjacency(AdjBlock::VerChildren, i).unwrap(), node.ver_children);
        }
        assert_eq!(m.idx("nope").unwrap(), None);
        // Full materialization is byte-identical at the JSON level.
        let back = m.materialize().unwrap();
        assert_eq!(
            back.to_json().to_string_compact(),
            g.to_json().to_string_compact()
        );
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn empty_graph_roundtrip() {
        let g = LineageGraph::new();
        let path = tmpfile("empty");
        write_binary(&g, &path).unwrap();
        let m = MappedGraph::open(&path).unwrap();
        assert_eq!(m.node_count(), 0);
        assert_eq!(m.idx("x").unwrap(), None);
        assert!(m.materialize().unwrap().is_empty());
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn tail_append_and_recovery() {
        let g = testutil::diamondish();
        let path = tmpfile("tail");
        write_binary(&g, &path).unwrap();
        let op = Json::obj()
            .set("name", "e")
            .set("model_type", "tx")
            .set("prov_parents", Json::Arr(vec![Json::from("a")]));
        append_commits(&path, &[op.clone()]).unwrap();
        let m = MappedGraph::open(&path).unwrap();
        assert_eq!(m.tail_ops.len(), 1);
        assert!(m.tail_torn.is_none());
        let back = m.materialize().unwrap();
        assert_eq!(back.len(), g.len() + 1);
        assert!(back.idx("e").is_ok());

        // A torn second record: the durable prefix survives, the torn
        // bytes are reported.
        let mut bytes = std::fs::read(&path).unwrap();
        let torn_at = bytes.len() as u64;
        bytes.extend_from_slice(&[7, 0, 0, 0, 0xde, 0xad]); // truncated mid-record
        std::fs::write(&path, &bytes).unwrap();
        let m = MappedGraph::open(&path).unwrap();
        assert_eq!(m.tail_ops.len(), 1);
        let torn = m.tail_torn.as_ref().expect("tail must be reported torn");
        assert_eq!(torn.offset, torn_at);
        assert_eq!(m.materialize().unwrap().len(), g.len() + 1);

        // A corrupted checksum is torn too.
        let mut bytes = std::fs::read(&path).unwrap();
        let base = MappedGraph::open(&path).unwrap().base_len() as usize;
        bytes[base + 4] ^= 0xff; // flip a crc byte of the first record
        std::fs::write(&path, &bytes).unwrap();
        let m = MappedGraph::open(&path).unwrap();
        assert!(m.tail_ops.is_empty());
        assert!(m.tail_torn.is_some());
        assert_eq!(m.materialize().unwrap().len(), g.len());
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn unknown_version_rejected() {
        let g = LineageGraph::new();
        let path = tmpfile("version");
        write_binary(&g, &path).unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        bytes[4] = 99;
        std::fs::write(&path, &bytes).unwrap();
        let err = MappedGraph::open(&path).unwrap_err();
        assert!(format!("{err:#}").contains("version 99"), "{err:#}");
        std::fs::remove_file(&path).unwrap();
    }
}
