//! The graph access seam: eager JSON vs lazily-mapped binary.
//!
//! [`GraphStore`] is what a [`crate::ops::Repo`] session (and a serve
//! snapshot) holds instead of a bare [`LineageGraph`]. Two backends:
//!
//! * **EagerJson** — the v0 path: `graph.json` parsed in full at open,
//!   exactly as before. Every repo without a `graph.bin` uses it.
//! * **MappedBinary** — an MGGI index ([`super::binfmt`]) mapped at
//!   open: O(page) startup, name lookups and node decodes on demand.
//!
//! `Deref<Target = LineageGraph>` materializes the full in-memory
//! graph on first whole-graph access (mutation, cascade planning,
//! merge…), so the ~40 existing `repo.graph.…` call sites keep working
//! unchanged; the paginated/filtered read paths use the inherent lazy
//! methods below and never materialize. Inherent methods deliberately
//! shadow their `LineageGraph` namesakes (`len`, `idx`,
//! `edge_counts`, `integrity_check`) with lazy equivalents.

use std::fmt;
use std::ops::{Deref, DerefMut};
use std::path::Path;
use std::sync::OnceLock;

use anyhow::{anyhow, bail, Context, Result};

use crate::obs::{LazyCounter, LazyGauge, LazyHistogram};

use super::binfmt::{self, AdjBlock, MappedGraph};
use super::{LineageGraph, Node, NodeIdx};

/// Time to open the graph (map the index or parse the JSON), µs.
pub static GRAPH_OPEN_MICROS: LazyHistogram = LazyHistogram::new("graph.open_micros");
/// Writable-serve folds of WAL commits into the graph image.
pub static GRAPH_FOLDS: LazyCounter = LazyCounter::new("graph.folds");
/// Time per fold (tail append or compact rewrite), µs.
pub static GRAPH_FOLD_MICROS: LazyHistogram = LazyHistogram::new("graph.fold_micros");
/// Node count of the most recently opened graph.
pub static GRAPH_NODES: LazyGauge = LazyGauge::new("graph.nodes");
/// Graph bytes resident after open: the full file for the eager JSON
/// path, header + tail only for the mapped binary path.
pub static GRAPH_RESIDENT_BYTES: LazyGauge = LazyGauge::new("graph.resident_bytes");

enum Backend {
    /// v0 `graph.json`, parsed eagerly (`full` is pre-set).
    Eager,
    /// MGGI `graph.bin`, mapped lazily.
    Mapped(MappedGraph),
}

/// A lineage graph behind a lazy-materialization seam. See the module
/// docs for the backend split.
pub struct GraphStore {
    backend: Backend,
    full: OnceLock<LineageGraph>,
}

impl GraphStore {
    /// Wrap an in-memory graph (eager backend, already materialized).
    pub fn from_graph(g: LineageGraph) -> GraphStore {
        let full = OnceLock::new();
        let _ = full.set(g);
        GraphStore { backend: Backend::Eager, full }
    }

    /// Open the graph under `.mgit/`: `graph.bin` (mapped, lazy) when
    /// present, else `graph.json` (eager). A binary graph with a
    /// non-empty append tail is materialized immediately so every
    /// accessor sees the tail commits; a quiescent (compacted) one
    /// stays O(page) until a whole-graph access. Records open metrics.
    pub fn open(mgit_dir: &Path) -> Result<GraphStore> {
        let t = std::time::Instant::now();
        let bin = mgit_dir.join("graph.bin");
        let store = if bin.exists() {
            let mapped = MappedGraph::open(&bin)?;
            if let Some(torn) = &mapped.tail_torn {
                eprintln!(
                    "warning: {} has a torn append tail at byte {} ({}); \
                     keeping the {} durable tail commit(s) before it",
                    bin.display(),
                    torn.offset,
                    torn.reason,
                    mapped.tail_ops.len()
                );
            }
            GRAPH_NODES.set(mapped.node_count() as i64);
            GRAPH_RESIDENT_BYTES
                .set((binfmt::HEADER_LEN + (mapped.file_len() - mapped.base_len())) as i64);
            let has_tail = !mapped.tail_ops.is_empty();
            let store = GraphStore { backend: Backend::Mapped(mapped), full: OnceLock::new() };
            if has_tail {
                store.full()?;
            }
            store
        } else {
            let path = mgit_dir.join("graph.json");
            let g = LineageGraph::load(&path)?;
            GRAPH_NODES.set(g.len() as i64);
            GRAPH_RESIDENT_BYTES
                .set(std::fs::metadata(&path).map(|m| m.len()).unwrap_or(0) as i64);
            GraphStore::from_graph(g)
        };
        GRAPH_OPEN_MICROS.observe(t.elapsed().as_micros() as u64);
        Ok(store)
    }

    fn mapped(&self) -> Option<&MappedGraph> {
        match &self.backend {
            Backend::Mapped(m) => Some(m),
            Backend::Eager => None,
        }
    }

    /// `"json"` or `"binary"` — which on-disk format backs this store.
    pub fn format(&self) -> &'static str {
        match self.backend {
            Backend::Eager => "json",
            Backend::Mapped(_) => "binary",
        }
    }

    /// Whether the full in-memory graph has been built (always true
    /// for the eager backend).
    pub fn is_materialized(&self) -> bool {
        self.full.get().is_some()
    }

    /// The full in-memory graph, materializing it on first call.
    pub fn full(&self) -> Result<&LineageGraph> {
        if let Some(g) = self.full.get() {
            return Ok(g);
        }
        let g = match &self.backend {
            Backend::Eager => unreachable!("eager backend is always pre-materialized"),
            Backend::Mapped(m) => m
                .materialize()
                .context("materializing binary lineage graph")?,
        };
        let _ = self.full.set(g);
        if let Some(m) = self.mapped() {
            GRAPH_RESIDENT_BYTES.set(m.file_len() as i64);
        }
        Ok(self.full.get().expect("just set"))
    }

    /// Mutable access to the full graph (materializes first).
    pub fn full_mut(&mut self) -> Result<&mut LineageGraph> {
        self.full()?;
        Ok(self.full.get_mut().expect("materialized above"))
    }

    /// An owned clone of the full graph (the writable serving tier's
    /// working copy).
    pub fn clone_full(&self) -> Result<LineageGraph> {
        Ok(self.full()?.clone())
    }

    // ------------------------------------------------------------------
    // Lazy accessors: O(page) on the mapped backend, trivial delegation
    // once materialized. These shadow the `LineageGraph` namesakes.
    // ------------------------------------------------------------------

    /// Number of nodes (tail commits included — a tailed graph is
    /// materialized at open).
    pub fn len(&self) -> usize {
        match (self.full.get(), self.mapped()) {
            (Some(g), _) => g.len(),
            (None, Some(m)) => m.node_count(),
            (None, None) => unreachable!("eager backend is always pre-materialized"),
        }
    }

    /// Whether the graph has no nodes.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Index of the node named `name` (error if absent) — a fanout
    /// binary search on the mapped backend, no materialization.
    pub fn idx(&self, name: &str) -> Result<NodeIdx> {
        match (self.full.get(), self.mapped()) {
            (Some(g), _) => g.idx(name),
            (None, Some(m)) => {
                m.idx(name)?.ok_or_else(|| anyhow!("no node named `{name}`"))
            }
            (None, None) => unreachable!("eager backend is always pre-materialized"),
        }
    }

    /// (provenance, versioning) edge counts — O(1) from the header on
    /// the mapped backend.
    pub fn edge_counts(&self) -> (usize, usize) {
        match (self.full.get(), self.mapped()) {
            (Some(g), _) => g.edge_counts(),
            (None, Some(m)) => m.edge_counts(),
            (None, None) => unreachable!("eager backend is always pre-materialized"),
        }
    }

    /// Decode one node (owned): body + adjacency for the mapped
    /// backend, a clone otherwise.
    pub fn node_owned(&self, idx: NodeIdx) -> Result<Node> {
        match (self.full.get(), self.mapped()) {
            (Some(g), _) => {
                if idx >= g.len() {
                    bail!("node index {idx} out of range");
                }
                Ok(g.node(idx).clone())
            }
            (None, Some(m)) => m.node(idx),
            (None, None) => unreachable!("eager backend is always pre-materialized"),
        }
    }

    /// The name of node `idx` (one body decode on the mapped backend).
    pub fn name_of(&self, idx: NodeIdx) -> Result<String> {
        match (self.full.get(), self.mapped()) {
            (Some(g), _) => {
                if idx >= g.len() {
                    bail!("node index {idx} out of range");
                }
                Ok(g.node(idx).name.clone())
            }
            (None, Some(m)) => m.name_of(idx),
            (None, None) => unreachable!("eager backend is always pre-materialized"),
        }
    }

    /// Look a node up by name and decode it (owned).
    pub fn node_by_name(&self, name: &str) -> Result<Node> {
        self.node_owned(self.idx(name)?)
    }

    /// Visit every node in index order, decoding one at a time — the
    /// streaming walk fsck and pagination use (O(one node) resident on
    /// the mapped backend, never the whole set).
    pub fn each_node(&self, f: &mut dyn FnMut(NodeIdx, &Node) -> Result<()>) -> Result<()> {
        match (self.full.get(), self.mapped()) {
            (Some(g), _) => {
                for (i, n) in g.nodes.iter().enumerate() {
                    f(i, n)?;
                }
                Ok(())
            }
            (None, Some(m)) => {
                for i in 0..m.node_count() {
                    f(i, &m.node(i)?)?;
                }
                Ok(())
            }
            (None, None) => unreachable!("eager backend is always pre-materialized"),
        }
    }

    /// Torn-tail status of the mapped backend, for fsck: byte offset +
    /// reason of the first invalid tail record, if any.
    pub fn tail_status(&self) -> Option<(u64, &str)> {
        self.mapped()
            .and_then(|m| m.tail_torn.as_ref())
            .map(|t| (t.offset, t.reason.as_str()))
    }

    /// Structural integrity check through the seam. Materialized or
    /// eager graphs delegate to [`LineageGraph::integrity_check`]; an
    /// unmaterialized mapped graph is verified by streaming node
    /// decodes against the name index and CSR blocks (O(nodes) index
    /// memory, one node body resident at a time).
    pub fn integrity_check(&self) -> Result<()> {
        if let Some(g) = self.full.get() {
            return g.integrity_check();
        }
        let m = self.mapped().expect("eager backend is always pre-materialized");
        let n = m.node_count();
        let mut indeg = vec![0usize; n];
        let mut prov_children: Vec<Vec<NodeIdx>> = vec![Vec::new(); n];
        for i in 0..n {
            let node = m.node(i)?;
            if m.idx(&node.name)? != Some(i) {
                bail!("name index points to wrong node for `{}`", node.name);
            }
            for &c in &node.prov_children {
                if c >= n || !m.adjacency(AdjBlock::ProvParents, c)?.contains(&i) {
                    bail!("asymmetric provenance edge at {}", node.name);
                }
            }
            for &p in &node.prov_parents {
                if p >= n || !m.adjacency(AdjBlock::ProvChildren, p)?.contains(&i) {
                    bail!("asymmetric provenance back-edge at {}", node.name);
                }
            }
            for &c in &node.ver_children {
                if c >= n || !m.adjacency(AdjBlock::VerParents, c)?.contains(&i) {
                    bail!("asymmetric version edge at {}", node.name);
                }
                if m.body(c)?.req_str("model_type")? != node.model_type {
                    bail!("version edge across model types at {}", node.name);
                }
            }
            if node.ver_parents.len() > 1 {
                bail!("node {} has multiple previous versions", node.name);
            }
            indeg[i] = node.prov_parents.len();
            prov_children[i] = node.prov_children;
        }
        // Provenance acyclicity (Kahn) over the CSR image.
        let mut queue: Vec<NodeIdx> = (0..n).filter(|&i| indeg[i] == 0).collect();
        let mut seen = 0;
        while let Some(i) = queue.pop() {
            seen += 1;
            for &c in &prov_children[i] {
                indeg[c] -= 1;
                if indeg[c] == 0 {
                    queue.push(c);
                }
            }
        }
        if seen != n {
            bail!("provenance cycle detected");
        }
        Ok(())
    }

    /// Persist the graph in its own format: eager repos rewrite
    /// `graph.json` (the v0 behavior, byte-for-byte), binary repos
    /// rewrite `graph.bin` as a compact image — which folds any append
    /// tail in. A mapped graph that was never materialized cannot have
    /// been mutated, so nothing is written.
    pub fn persist(&self, mgit_dir: &Path) -> Result<()> {
        match &self.backend {
            Backend::Eager => self.full()?.save(&mgit_dir.join("graph.json")),
            Backend::Mapped(_) => match self.full.get() {
                Some(g) => binfmt::write_binary(g, &mgit_dir.join("graph.bin")),
                None => Ok(()),
            },
        }
    }
}

impl Deref for GraphStore {
    type Target = LineageGraph;

    /// Whole-graph access: materializes on first use. Materialization
    /// only fails on a corrupt body/CSR section *past* the validated
    /// header — at that point there is no graph to return, so this
    /// panics (the same contract as `LineageGraph::node` on a bad
    /// index). Open-time validation and fsck exist to catch it first.
    fn deref(&self) -> &LineageGraph {
        self.full()
            .unwrap_or_else(|e| panic!("lineage graph materialization failed: {e:#}"))
    }
}

impl DerefMut for GraphStore {
    fn deref_mut(&mut self) -> &mut LineageGraph {
        if let Err(e) = self.full() {
            panic!("lineage graph materialization failed: {e:#}");
        }
        self.full.get_mut().expect("materialized above")
    }
}

impl fmt::Debug for GraphStore {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("GraphStore")
            .field("format", &self.format())
            .field("materialized", &self.is_materialized())
            .field("len", &self.len())
            .finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lineage::testutil;

    fn tmpdir(tag: &str) -> std::path::PathBuf {
        let d = std::env::temp_dir()
            .join(format!("mgit-graphstore-{tag}-{}", std::process::id()));
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn mapped_lazy_accessors_then_materialize() {
        let g = testutil::diamondish();
        let dir = tmpdir("lazy");
        binfmt::write_binary(&g, &dir.join("graph.bin")).unwrap();
        let gs = GraphStore::open(&dir).unwrap();
        assert_eq!(gs.format(), "binary");
        assert!(!gs.is_materialized());
        assert_eq!(gs.len(), 5);
        assert_eq!(gs.edge_counts(), (3, 1));
        let b = gs.idx("b").unwrap();
        assert_eq!(gs.name_of(b).unwrap(), "b");
        assert_eq!(gs.node_by_name("b2").unwrap().ver_parents, vec![b]);
        gs.integrity_check().unwrap();
        assert!(!gs.is_materialized(), "lazy reads must not materialize");
        // Deref kicks in for whole-graph APIs.
        assert_eq!(gs.roots().len(), 1);
        assert!(gs.is_materialized());
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn json_fallback_unchanged() {
        let g = testutil::diamondish();
        let dir = tmpdir("json");
        g.save(&dir.join("graph.json")).unwrap();
        let gs = GraphStore::open(&dir).unwrap();
        assert_eq!(gs.format(), "json");
        assert!(gs.is_materialized());
        assert_eq!(gs.len(), 5);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn tailed_graph_materializes_at_open() {
        let g = testutil::diamondish();
        let dir = tmpdir("tail");
        let bin = dir.join("graph.bin");
        binfmt::write_binary(&g, &bin).unwrap();
        let op = crate::util::json::Json::obj()
            .set("name", "e")
            .set("model_type", "tx");
        binfmt::append_commits(&bin, &[op]).unwrap();
        let gs = GraphStore::open(&dir).unwrap();
        assert!(gs.is_materialized(), "tail commits must be folded in at open");
        assert_eq!(gs.len(), 6);
        assert!(gs.idx("e").is_ok());
        // Persist compacts: reopening is lazy again with the tail folded.
        gs.persist(&dir).unwrap();
        let gs = GraphStore::open(&dir).unwrap();
        assert!(!gs.is_materialized());
        assert_eq!(gs.len(), 6);
        std::fs::remove_dir_all(&dir).unwrap();
    }
}
