//! Traversals over the lineage graph (paper §3.1.4).
//!
//! Traversals are iterators over node indices. They take edge-type
//! filters plus the `skip_fn` / `terminate_fn` hooks of the paper's
//! `run_update_cascade` API: a *skipped* node is not yielded (but its
//! edges are still followed); a *terminated* node cuts traversal below it.
//!
//! `all_parents_first` is the modified BFS of Algorithm 2 — a node is
//! yielded only once **all** of its in-scope provenance parents have been
//! yielded. `bisect` implements the §6.4 test-bisection over a version
//! chain.

use super::{EdgeType, LineageGraph, NodeIdx};

/// Which outgoing edges a traversal follows.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EdgeFilter {
    Provenance,
    Versioning,
    Both,
}

impl EdgeFilter {
    fn children<'a>(&self, g: &'a LineageGraph, i: NodeIdx) -> Vec<NodeIdx> {
        let n = &g.nodes[i];
        match self {
            EdgeFilter::Provenance => n.prov_children.clone(),
            EdgeFilter::Versioning => n.ver_children.clone(),
            EdgeFilter::Both => {
                let mut v = n.prov_children.clone();
                v.extend_from_slice(&n.ver_children);
                v
            }
        }
    }
}

impl From<EdgeType> for EdgeFilter {
    fn from(t: EdgeType) -> EdgeFilter {
        match t {
            EdgeType::Provenance => EdgeFilter::Provenance,
            EdgeType::Versioning => EdgeFilter::Versioning,
        }
    }
}

/// Breadth-first traversal from `start` (yields `start` unless skipped).
pub fn bfs(
    g: &LineageGraph,
    start: NodeIdx,
    filter: EdgeFilter,
    skip: impl Fn(&LineageGraph, NodeIdx) -> bool,
    terminate: impl Fn(&LineageGraph, NodeIdx) -> bool,
) -> Vec<NodeIdx> {
    let mut out = Vec::new();
    let mut seen = vec![false; g.len()];
    let mut queue = std::collections::VecDeque::from([start]);
    while let Some(i) = queue.pop_front() {
        if seen[i] {
            continue;
        }
        seen[i] = true;
        if !skip(g, i) {
            out.push(i);
        }
        if terminate(g, i) {
            continue;
        }
        for c in filter.children(g, i) {
            if !seen[c] {
                queue.push_back(c);
            }
        }
    }
    out
}

/// Depth-first (pre-order) traversal from `start`.
pub fn dfs(
    g: &LineageGraph,
    start: NodeIdx,
    filter: EdgeFilter,
    skip: impl Fn(&LineageGraph, NodeIdx) -> bool,
    terminate: impl Fn(&LineageGraph, NodeIdx) -> bool,
) -> Vec<NodeIdx> {
    let mut out = Vec::new();
    let mut seen = vec![false; g.len()];
    let mut stack = vec![start];
    while let Some(i) = stack.pop() {
        if seen[i] {
            continue;
        }
        seen[i] = true;
        if !skip(g, i) {
            out.push(i);
        }
        if terminate(g, i) {
            continue;
        }
        let mut kids = filter.children(g, i);
        kids.reverse(); // keep natural child order in pre-order output
        for c in kids {
            if !seen[c] {
                stack.push(c);
            }
        }
    }
    out
}

/// The full version chain containing `idx`, from first to last version.
pub fn version_chain(g: &LineageGraph, idx: NodeIdx) -> Vec<NodeIdx> {
    let mut first = idx;
    while let Some(p) = g.prev_version(first) {
        first = p;
    }
    let mut out = vec![first];
    let mut cur = first;
    while let Some(n) = g.next_version(cur) {
        out.push(n);
        cur = n;
    }
    out
}

/// Modified BFS of Algorithm 2: yield provenance descendants of `start`
/// (excluding `start` itself) such that a node appears only after all of
/// its in-scope provenance parents. Parents outside the descendant set of
/// `start` are treated as already satisfied (they are not being updated).
pub fn all_parents_first(
    g: &LineageGraph,
    start: NodeIdx,
    skip: impl Fn(&LineageGraph, NodeIdx) -> bool,
    terminate: impl Fn(&LineageGraph, NodeIdx) -> bool,
) -> Vec<NodeIdx> {
    // Scope = provenance descendants of start (minus terminated subtrees).
    let mut in_scope = vec![false; g.len()];
    let mut stack = vec![start];
    while let Some(i) = stack.pop() {
        if in_scope[i] {
            continue;
        }
        in_scope[i] = true;
        if terminate(g, i) {
            continue;
        }
        stack.extend(g.nodes[i].prov_children.iter().copied());
    }
    // Kahn over the induced sub-DAG.
    let mut indeg = vec![0usize; g.len()];
    for i in 0..g.len() {
        if !in_scope[i] || i == start {
            continue;
        }
        indeg[i] = g.nodes[i]
            .prov_parents
            .iter()
            .filter(|&&p| in_scope[p] && p != start)
            .count();
    }
    let mut queue: std::collections::VecDeque<NodeIdx> = (0..g.len())
        .filter(|&i| in_scope[i] && i != start && indeg[i] == 0)
        .collect();
    let mut out = Vec::new();
    while let Some(i) = queue.pop_front() {
        if !skip(g, i) {
            out.push(i);
        }
        for &c in &g.nodes[i].prov_children {
            if in_scope[c] && c != start {
                indeg[c] -= 1;
                if indeg[c] == 0 {
                    queue.push_back(c);
                }
            }
        }
    }
    out
}

/// Test bisection over a version chain (§6.4): assuming versions go
/// good → … → bad monotonically under `fails`, find the *first failing*
/// version with O(log n) test evaluations. Returns `(index_into_chain,
/// number_of_test_evaluations)`, or None if no version fails.
pub fn bisect_first_failure(
    chain: &[NodeIdx],
    mut fails: impl FnMut(NodeIdx) -> bool,
) -> (Option<usize>, usize) {
    if chain.is_empty() {
        return (None, 0);
    }
    let mut evals = 0;
    // Check the last version first: if it passes, nothing fails.
    evals += 1;
    if !fails(chain[chain.len() - 1]) {
        return (None, evals);
    }
    let (mut lo, mut hi) = (0usize, chain.len() - 1); // hi is known-failing
    while lo < hi {
        let mid = (lo + hi) / 2;
        evals += 1;
        if fails(chain[mid]) {
            hi = mid;
        } else {
            lo = mid + 1;
        }
    }
    (Some(hi), evals)
}

/// Linear scan baseline for the bisection comparison.
pub fn scan_first_failure(
    chain: &[NodeIdx],
    mut fails: impl FnMut(NodeIdx) -> bool,
) -> (Option<usize>, usize) {
    let mut evals = 0;
    for (k, &n) in chain.iter().enumerate() {
        evals += 1;
        if fails(n) {
            return (Some(k), evals);
        }
    }
    (None, evals)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lineage::testutil::diamondish;
    use crate::lineage::LineageGraph;

    fn no_skip(_: &LineageGraph, _: NodeIdx) -> bool {
        false
    }

    #[test]
    fn bfs_visits_descendants_once() {
        let g = diamondish();
        let a = g.idx("a").unwrap();
        let names: Vec<_> = bfs(&g, a, EdgeFilter::Provenance, no_skip, no_skip)
            .into_iter()
            .map(|i| g.nodes[i].name.clone())
            .collect();
        assert_eq!(names, vec!["a", "b", "d", "c"]);
    }

    #[test]
    fn bfs_both_follows_versions() {
        let g = diamondish();
        let a = g.idx("a").unwrap();
        let visited = bfs(&g, a, EdgeFilter::Both, no_skip, no_skip);
        assert!(visited.contains(&g.idx("b2").unwrap()));
    }

    #[test]
    fn dfs_preorder() {
        let g = diamondish();
        let a = g.idx("a").unwrap();
        let names: Vec<_> = dfs(&g, a, EdgeFilter::Provenance, no_skip, no_skip)
            .into_iter()
            .map(|i| g.nodes[i].name.clone())
            .collect();
        assert_eq!(names, vec!["a", "b", "c", "d"]);
    }

    #[test]
    fn skip_and_terminate() {
        let g = diamondish();
        let a = g.idx("a").unwrap();
        let b = g.idx("b").unwrap();
        // Skip b: not yielded but children still traversed.
        let names: Vec<_> =
            bfs(&g, a, EdgeFilter::Provenance, |_, i| i == b, no_skip)
                .into_iter()
                .map(|i| g.nodes[i].name.clone())
                .collect();
        assert_eq!(names, vec!["a", "d", "c"]);
        // Terminate at b: c not reached.
        let names: Vec<_> =
            bfs(&g, a, EdgeFilter::Provenance, no_skip, |_, i| i == b)
                .into_iter()
                .map(|i| g.nodes[i].name.clone())
                .collect();
        assert_eq!(names, vec!["a", "b", "d"]);
    }

    #[test]
    fn version_chain_from_middle() {
        let mut g = LineageGraph::new();
        let v1 = g.add_node("v1", "t").unwrap();
        let v2 = g.add_node("v2", "t").unwrap();
        let v3 = g.add_node("v3", "t").unwrap();
        g.add_version_edge(v1, v2).unwrap();
        g.add_version_edge(v2, v3).unwrap();
        assert_eq!(version_chain(&g, v2), vec![v1, v2, v3]);
        assert_eq!(version_chain(&g, v1), vec![v1, v2, v3]);
        assert_eq!(version_chain(&g, v3), vec![v1, v2, v3]);
    }

    #[test]
    fn all_parents_first_respects_diamond() {
        // root -> l, root -> r, l -> sink, r -> sink
        let mut g = LineageGraph::new();
        let root = g.add_node("root", "t").unwrap();
        let l = g.add_node("l", "t").unwrap();
        let r = g.add_node("r", "t").unwrap();
        let sink = g.add_node("sink", "t").unwrap();
        g.add_edge(root, l).unwrap();
        g.add_edge(root, r).unwrap();
        g.add_edge(l, sink).unwrap();
        g.add_edge(r, sink).unwrap();
        let order = all_parents_first(&g, root, |_, _| false, |_, _| false);
        let pos = |n: NodeIdx| order.iter().position(|&x| x == n).unwrap();
        assert_eq!(order.len(), 3); // root excluded
        assert!(pos(sink) > pos(l) && pos(sink) > pos(r));
    }

    #[test]
    fn all_parents_first_external_parents_dont_block() {
        // start -> child, but child also has an unrelated parent outside
        // the start's descendant scope — it must still be yielded.
        let mut g = LineageGraph::new();
        let start = g.add_node("start", "t").unwrap();
        let outside = g.add_node("outside", "t").unwrap();
        let child = g.add_node("child", "t").unwrap();
        g.add_edge(start, child).unwrap();
        g.add_edge(outside, child).unwrap();
        let order = all_parents_first(&g, start, |_, _| false, |_, _| false);
        assert_eq!(order, vec![child]);
    }

    #[test]
    fn bisect_matches_scan_and_is_cheaper() {
        let chain: Vec<NodeIdx> = (0..32).collect();
        for first_bad in 0..32 {
            let fails = |i: NodeIdx| i >= first_bad;
            let (b, be) = bisect_first_failure(&chain, fails);
            let (s, _se) = scan_first_failure(&chain, fails);
            assert_eq!(b, s, "first_bad={first_bad}");
            assert!(be <= 7, "bisect used {be} evals"); // 1 + ceil(log2 32)
        }
        // No failure at all.
        let (b, be) = bisect_first_failure(&chain, |_| false);
        assert_eq!(b, None);
        assert_eq!(be, 1);
        let (s, se) = scan_first_failure(&chain, |_| false);
        assert_eq!(s, None);
        assert_eq!(se, 32);
    }

    #[test]
    fn bisect_empty_chain() {
        let (r, e) = bisect_first_failure(&[], |_| true);
        assert_eq!((r, e), (None, 0));
    }
}
