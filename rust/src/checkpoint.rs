//! Model checkpoints: architecture specs (from the AOT manifest) and flat
//! parameter vectors.
//!
//! The L2/L1 Python layer fixes a *flat f32 layout* per architecture (see
//! `python/compile/archs.py`); `artifacts/manifest.json` mirrors it here.
//! A [`Checkpoint`] is one model's parameters as that flat vector; named
//! per-layer tensors are views sliced out by the [`ArchSpec`] layout —
//! these per-tensor slices are what the content-addressed store hashes.

use std::collections::HashMap;
use std::path::Path;

use anyhow::{anyhow, bail, Context, Result};

use crate::tensor::Tensor;
use crate::util::json::{self, Json};
use crate::util::rng::Rng;

/// How a parameter tensor is initialized for a fresh model.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum InitKind {
    /// N(0, 0.02²) weights.
    Normal,
    /// All-ones (layer-norm gains).
    Ones,
    /// All-zeros (biases).
    Zeros,
}

/// One named parameter tensor inside the flat layout.
#[derive(Debug, Clone)]
pub struct ParamEntry {
    /// Parameter name (e.g. `layers.0.attn.wq`).
    pub name: String,
    /// Logical tensor shape.
    pub shape: Vec<usize>,
    /// Start offset inside the flat f32 vector.
    pub offset: usize,
    /// Element count (product of `shape`).
    pub size: usize,
    /// Fresh-model initialization for this tensor.
    pub init: InitKind,
}

/// One architecture of the model zoo.
#[derive(Debug, Clone)]
pub struct ArchSpec {
    /// Architecture name (the `model_type` lineage nodes carry).
    pub name: String,
    /// Transformer width.
    pub d_model: usize,
    /// Transformer depth.
    pub n_layers: usize,
    /// Attention heads per layer.
    pub n_heads: usize,
    /// Feed-forward hidden width.
    pub d_ff: usize,
    /// Total f32 parameter count (the flat vector's length).
    pub param_count: usize,
    /// Named tensors in flat-vector order.
    pub layout: Vec<ParamEntry>,
    by_name: HashMap<String, usize>,
    /// Raw layer DAG JSON (consumed by `modeldag`).
    pub dag: Json,
}

impl ArchSpec {
    fn from_json(name: &str, j: &Json) -> Result<ArchSpec> {
        let mut layout = Vec::new();
        for entry in j.req_arr("layout")? {
            let shape = entry
                .req_arr("shape")?
                .iter()
                .map(|d| d.as_usize().ok_or_else(|| anyhow!("bad shape dim")))
                .collect::<Result<Vec<_>>>()?;
            let init = match entry.req_str("init")? {
                "normal" => InitKind::Normal,
                "ones" => InitKind::Ones,
                "zeros" => InitKind::Zeros,
                other => bail!("unknown init kind `{other}`"),
            };
            layout.push(ParamEntry {
                name: entry.req_str("name")?.to_string(),
                shape,
                offset: entry.req_usize("offset")?,
                size: entry.req_usize("size")?,
                init,
            });
        }
        let by_name = layout
            .iter()
            .enumerate()
            .map(|(i, e)| (e.name.clone(), i))
            .collect();
        Ok(ArchSpec {
            name: name.to_string(),
            d_model: j.req_usize("d_model")?,
            n_layers: j.req_usize("n_layers")?,
            n_heads: j.req_usize("n_heads")?,
            d_ff: j.req_usize("d_ff")?,
            param_count: j.req_usize("param_count")?,
            layout,
            by_name,
            dag: j.req("dag")?.clone(),
        })
    }

    /// Layout entry for the parameter named `name` (error if absent).
    pub fn entry(&self, name: &str) -> Result<&ParamEntry> {
        self.by_name
            .get(name)
            .map(|&i| &self.layout[i])
            .ok_or_else(|| anyhow!("arch {} has no parameter `{name}`", self.name))
    }

    /// All parameter names, in layout order.
    pub fn param_names(&self) -> impl Iterator<Item = &str> {
        self.layout.iter().map(|e| e.name.as_str())
    }
}

/// The whole manifest: globals + every architecture.
#[derive(Debug, Clone)]
pub struct ModelZoo {
    /// Token vocabulary size shared by all archs.
    pub vocab: usize,
    /// Maximum sequence length.
    pub max_seq: usize,
    /// Classification head width.
    pub n_classes: usize,
    /// Batch size the AOT artifacts were compiled for.
    pub batch: usize,
    /// Chunk size the delta kernels process per call.
    pub delta_chunk: usize,
    /// MLM mask token id.
    pub mask_token: i32,
    /// Loss-ignored label id.
    pub ignore_label: i32,
    /// Every architecture by name.
    pub archs: HashMap<String, ArchSpec>,
    /// artifact file names: arch -> kind -> file
    pub artifacts: HashMap<String, HashMap<String, String>>,
    /// Artifact file for the quantize kernel.
    pub delta_quant_artifact: String,
    /// Artifact file for the dequantize kernel.
    pub delta_dequant_artifact: String,
}

impl ModelZoo {
    /// Load `manifest.json` from disk (see `python/compile/archs.py`).
    pub fn load(manifest_path: &Path) -> Result<ModelZoo> {
        let text = std::fs::read_to_string(manifest_path)
            .with_context(|| format!("reading {}", manifest_path.display()))?;
        Self::from_json(&json::parse(&text)?)
    }

    /// Parse a manifest from its JSON form.
    pub fn from_json(j: &Json) -> Result<ModelZoo> {
        let mut archs = HashMap::new();
        for (name, aj) in j.req("archs")?.as_obj().unwrap_or(&[]) {
            archs.insert(name.clone(), ArchSpec::from_json(name, aj)?);
        }
        let mut artifacts = HashMap::new();
        for (name, aj) in j.req("artifacts")?.as_obj().unwrap_or(&[]) {
            let mut kinds = HashMap::new();
            for (kind, file) in aj.as_obj().unwrap_or(&[]) {
                kinds.insert(kind.clone(), file.as_str().unwrap_or_default().to_string());
            }
            artifacts.insert(name.clone(), kinds);
        }
        let special = j.req("special_tokens")?;
        let dk = j.req("delta_kernels")?;
        Ok(ModelZoo {
            vocab: j.req_usize("vocab")?,
            max_seq: j.req_usize("max_seq")?,
            n_classes: j.req_usize("n_classes")?,
            batch: j.req_usize("batch")?,
            delta_chunk: j.req_usize("delta_chunk")?,
            mask_token: special.req_f64("mask")? as i32,
            ignore_label: special.req_f64("ignore_label")? as i32,
            archs,
            artifacts,
            delta_quant_artifact: dk.req_str("quant")?.to_string(),
            delta_dequant_artifact: dk.req_str("dequant")?.to_string(),
        })
    }

    /// The architecture named `name` (error if absent).
    pub fn arch(&self, name: &str) -> Result<&ArchSpec> {
        self.archs
            .get(name)
            .ok_or_else(|| anyhow!("unknown architecture `{name}`"))
    }
}

/// A model's parameters as one flat f32 vector in the arch's layout.
#[derive(Debug, Clone, PartialEq)]
pub struct Checkpoint {
    /// Architecture name (must match an [`ArchSpec`]).
    pub arch: String,
    /// All parameters, concatenated in layout order.
    pub flat: Vec<f32>,
}

impl Checkpoint {
    /// Fresh initialization per the manifest init kinds (N(0, 0.02²) for
    /// weights, ones for LN gains, zeros for biases). Each tensor gets its
    /// own RNG stream so layouts with equal prefixes share prefixes of
    /// randomness (useful for tests), keyed by (seed, tensor index).
    pub fn init(spec: &ArchSpec, seed: u64) -> Checkpoint {
        let mut flat = vec![0f32; spec.param_count];
        let mut root = Rng::new(seed);
        for (i, e) in spec.layout.iter().enumerate() {
            let dst = &mut flat[e.offset..e.offset + e.size];
            match e.init {
                InitKind::Zeros => {}
                InitKind::Ones => dst.fill(1.0),
                InitKind::Normal => {
                    let mut rng = root.split(i as u64);
                    for x in dst {
                        *x = rng.normal_f32(0.0, 0.02);
                    }
                }
            }
        }
        Checkpoint { arch: spec.name.clone(), flat }
    }

    /// Validate that this checkpoint matches `spec` (name + length).
    pub fn check_arch(&self, spec: &ArchSpec) -> Result<()> {
        if self.arch != spec.name {
            bail!("checkpoint arch {} != spec {}", self.arch, spec.name);
        }
        if self.flat.len() != spec.param_count {
            bail!(
                "checkpoint has {} params, arch {} wants {}",
                self.flat.len(),
                spec.name,
                spec.param_count
            );
        }
        Ok(())
    }

    /// View one named tensor as a slice of the flat vector.
    pub fn param(&self, spec: &ArchSpec, name: &str) -> Result<&[f32]> {
        let e = spec.entry(name)?;
        Ok(&self.flat[e.offset..e.offset + e.size])
    }

    /// Mutable view of one named tensor.
    pub fn param_mut(&mut self, spec: &ArchSpec, name: &str) -> Result<&mut [f32]> {
        let e = spec.entry(name)?;
        Ok(&mut self.flat[e.offset..e.offset + e.size])
    }

    /// Materialize one named tensor (copying the slice).
    pub fn tensor(&self, spec: &ArchSpec, name: &str) -> Result<Tensor> {
        let e = spec.entry(name)?;
        Ok(Tensor::f32(
            e.shape.clone(),
            self.flat[e.offset..e.offset + e.size].to_vec(),
        ))
    }

    /// Iterate (entry, slice) pairs in layout order.
    pub fn iter_params<'a>(
        &'a self,
        spec: &'a ArchSpec,
    ) -> impl Iterator<Item = (&'a ParamEntry, &'a [f32])> {
        spec.layout
            .iter()
            .map(move |e| (e, &self.flat[e.offset..e.offset + e.size]))
    }

    /// Overall fraction of zero parameters (pruning diagnostics).
    pub fn sparsity(&self) -> f64 {
        if self.flat.is_empty() {
            return 0.0;
        }
        self.flat.iter().filter(|&&x| x == 0.0).count() as f64 / self.flat.len() as f64
    }

    /// Euclidean norm over all parameters (drift diagnostics).
    pub fn l2_norm(&self) -> f64 {
        self.flat.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>().sqrt()
    }
}

#[cfg(test)]
pub mod testutil {
    //! Tiny in-code manifests so unit tests don't depend on artifacts/.
    use super::*;

    /// All-`normal` init zoo (no deterministic ones/zeros tensors): the
    /// realistic case for diff/autoconstruct tests — trained models never
    /// share exactly-equal LN/bias tensors by accident, and deterministic
    /// inits would otherwise hash-collide across unrelated fresh models.
    pub fn normal_zoo() -> ModelZoo {
        let text = r#"{
          "vocab": 16, "max_seq": 4, "n_classes": 2, "batch": 2,
          "delta_chunk": 8,
          "special_tokens": {"cls": 14, "mask": 15, "ignore_label": -100},
          "archs": {"n0": {
              "d_model": 4, "n_layers": 2, "n_heads": 1, "d_ff": 8,
              "param_count": 160,
              "layout": [
                {"name":"w.emb","shape":[16,4],"offset":0,"size":64,"init":"normal"},
                {"name":"w.mid","shape":[4,16],"offset":64,"size":64,"init":"normal"},
                {"name":"w.head","shape":[16,2],"offset":128,"size":32,"init":"normal"}
              ],
              "dag": {"nodes": [
                  {"id":"emb","op":"embedding","attrs":"16x4","params":["w.emb"]},
                  {"id":"mid","op":"linear","attrs":"4x16","params":["w.mid"]},
                  {"id":"head","op":"linear","attrs":"16x2","params":["w.head"]}
                ], "edges": [["emb","mid"],["mid","head"]]}
          }},
          "artifacts": {"n0": {}},
          "delta_kernels": {"quant": "q", "dequant": "d"}
        }"#;
        ModelZoo::from_json(&json::parse(text).unwrap()).unwrap()
    }

    pub fn tiny_zoo() -> ModelZoo {
        let text = r#"{
          "vocab": 16, "max_seq": 4, "n_classes": 2, "batch": 2,
          "delta_chunk": 8,
          "special_tokens": {"cls": 14, "mask": 15, "ignore_label": -100},
          "archs": {
            "t0": {
              "d_model": 2, "n_layers": 1, "n_heads": 1, "d_ff": 4,
              "param_count": 14,
              "layout": [
                {"name":"w.a","shape":[2,3],"offset":0,"size":6,"init":"normal"},
                {"name":"w.b","shape":[4],"offset":6,"size":4,"init":"zeros"},
                {"name":"w.g","shape":[4],"offset":10,"size":4,"init":"ones"}
              ],
              "dag": {"nodes": [
                  {"id":"a","op":"linear","attrs":"2x3","params":["w.a"]},
                  {"id":"b","op":"bias","attrs":"4","params":["w.b","w.g"]}
                ], "edges": [["a","b"]]}
            },
            "t1": {
              "d_model": 2, "n_layers": 2, "n_heads": 1, "d_ff": 4,
              "param_count": 12,
              "layout": [
                {"name":"w.a","shape":[2,3],"offset":0,"size":6,"init":"normal"},
                {"name":"w.c","shape":[6],"offset":6,"size":6,"init":"normal"}
              ],
              "dag": {"nodes": [
                  {"id":"a","op":"linear","attrs":"2x3","params":["w.a"]},
                  {"id":"c","op":"linear","attrs":"6","params":["w.c"]}
                ], "edges": [["a","c"]]}
            }
          },
          "artifacts": {"t0": {}, "t1": {}},
          "delta_kernels": {"quant": "q.hlo.txt", "dequant": "d.hlo.txt"}
        }"#;
        ModelZoo::from_json(&json::parse(text).unwrap()).unwrap()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zoo_parses() {
        let zoo = testutil::tiny_zoo();
        assert_eq!(zoo.vocab, 16);
        let t0 = zoo.arch("t0").unwrap();
        assert_eq!(t0.param_count, 14);
        assert_eq!(t0.layout.len(), 3);
        assert!(zoo.arch("nope").is_err());
    }

    #[test]
    fn init_respects_kinds() {
        let zoo = testutil::tiny_zoo();
        let spec = zoo.arch("t0").unwrap();
        let ck = Checkpoint::init(spec, 1);
        assert_eq!(ck.flat.len(), 14);
        assert!(ck.param(spec, "w.a").unwrap().iter().any(|&x| x != 0.0));
        assert!(ck.param(spec, "w.b").unwrap().iter().all(|&x| x == 0.0));
        assert!(ck.param(spec, "w.g").unwrap().iter().all(|&x| x == 1.0));
    }

    #[test]
    fn init_deterministic_and_seed_sensitive() {
        let zoo = testutil::tiny_zoo();
        let spec = zoo.arch("t0").unwrap();
        assert_eq!(Checkpoint::init(spec, 5).flat, Checkpoint::init(spec, 5).flat);
        assert_ne!(Checkpoint::init(spec, 5).flat, Checkpoint::init(spec, 6).flat);
    }

    #[test]
    fn tensor_views() {
        let zoo = testutil::tiny_zoo();
        let spec = zoo.arch("t0").unwrap();
        let mut ck = Checkpoint::init(spec, 0);
        ck.param_mut(spec, "w.b").unwrap()[2] = 9.0;
        let t = ck.tensor(spec, "w.b").unwrap();
        assert_eq!(t.shape, vec![4]);
        assert_eq!(t.as_f32().unwrap()[2], 9.0);
        assert!(ck.tensor(spec, "missing").is_err());
    }

    #[test]
    fn arch_mismatch_detected() {
        let zoo = testutil::tiny_zoo();
        let t0 = zoo.arch("t0").unwrap();
        let t1 = zoo.arch("t1").unwrap();
        let ck = Checkpoint::init(t0, 0);
        assert!(ck.check_arch(t1).is_err());
        assert!(ck.check_arch(t0).is_ok());
    }
}
