//! The `merge` primitive (paper §5, Figure 2): combine two concurrent
//! edits of the same model.
//!
//! Given the closest common ancestor `base` and the two edited models
//! `m1`, `m2` (same architecture — they are edits of one model):
//!
//! * **Conflict** — some layer changed in both edits → manual resolution;
//! * **Possible conflict** — disjoint changed layers, but a dataflow
//!   dependency exists between a layer changed by one user and a layer
//!   changed by the other (directly or through a common downstream
//!   consumer) → merge is produced but must be verified by tests;
//! * **No conflict** — disjoint and independent → auto-merge.
//!
//! The merged checkpoint starts from `base` and applies each side's
//! changed layers.

use std::collections::BTreeSet;

use anyhow::Result;

use crate::checkpoint::{ArchSpec, Checkpoint};
use crate::modeldag::ModelDag;

/// Merge verdict + artifacts.
#[derive(Debug)]
pub enum MergeOutcome {
    /// Same layer edited on both sides; manual intervention required.
    Conflict { overlapping: Vec<String> },
    /// Disjoint edits with a dependency — run tests before accepting.
    PossibleConflict {
        merged: Checkpoint,
        dependent_pairs: Vec<(String, String)>,
    },
    /// Independent edits — merged automatically.
    Clean { merged: Checkpoint },
}

impl MergeOutcome {
    pub fn verdict(&self) -> &'static str {
        match self {
            MergeOutcome::Conflict { .. } => "conflict",
            MergeOutcome::PossibleConflict { .. } => "possible-conflict",
            MergeOutcome::Clean { .. } => "no-conflict",
        }
    }

    pub fn merged(&self) -> Option<&Checkpoint> {
        match self {
            MergeOutcome::Conflict { .. } => None,
            MergeOutcome::PossibleConflict { merged, .. }
            | MergeOutcome::Clean { merged } => Some(merged),
        }
    }
}

/// Layers (dag indices) whose parameters differ between `base` and `m`.
fn changed_layer_indices(
    dag: &ModelDag,
    spec: &ArchSpec,
    base: &Checkpoint,
    m: &Checkpoint,
) -> Result<Vec<usize>> {
    let mut out = Vec::new();
    for (li, layer) in dag.layers.iter().enumerate() {
        let mut changed = false;
        for p in &layer.params {
            let e = spec.entry(p)?;
            if base.flat[e.offset..e.offset + e.size] != m.flat[e.offset..e.offset + e.size] {
                changed = true;
                break;
            }
        }
        if changed {
            out.push(li);
        }
    }
    Ok(out)
}

/// Apply `src`'s parameters for the given layers onto `dst`.
fn apply_layers(
    dag: &ModelDag,
    spec: &ArchSpec,
    dst: &mut Checkpoint,
    src: &Checkpoint,
    layers: &[usize],
) -> Result<()> {
    for &li in layers {
        for p in &dag.layers[li].params {
            let e = spec.entry(p)?;
            dst.flat[e.offset..e.offset + e.size]
                .copy_from_slice(&src.flat[e.offset..e.offset + e.size]);
        }
    }
    Ok(())
}

/// Figure-2 decision tree.
pub fn merge(
    spec: &ArchSpec,
    dag: &ModelDag,
    base: &Checkpoint,
    m1: &Checkpoint,
    m2: &Checkpoint,
) -> Result<MergeOutcome> {
    base.check_arch(spec)?;
    m1.check_arch(spec)?;
    m2.check_arch(spec)?;
    let c1 = changed_layer_indices(dag, spec, base, m1)?;
    let c2 = changed_layer_indices(dag, spec, base, m2)?;

    // 1) Same layer changed by both users → conflict.
    let s1: BTreeSet<usize> = c1.iter().copied().collect();
    let overlapping: Vec<String> = c2
        .iter()
        .filter(|li| s1.contains(li))
        .map(|&li| dag.layers[li].id.clone())
        .collect();
    if !overlapping.is_empty() {
        return Ok(MergeOutcome::Conflict { overlapping });
    }

    // Merge = base + m1's layers + m2's layers (disjoint by construction).
    let mut merged = base.clone();
    apply_layers(dag, spec, &mut merged, m1, &c1)?;
    apply_layers(dag, spec, &mut merged, m2, &c2)?;

    // 2) Dependency between a layer changed by one user and a layer
    //    changed by the other → possible conflict (verify with tests).
    let mut dependent_pairs = Vec::new();
    for &x in &c1 {
        for &y in &c2 {
            let dep = dag.reaches(x, y)
                || dag.reaches(y, x)
                || (0..dag.layers.len()).any(|j| dag.reaches(x, j) && dag.reaches(y, j));
            if dep {
                dependent_pairs.push((dag.layers[x].id.clone(), dag.layers[y].id.clone()));
            }
        }
    }
    if !dependent_pairs.is_empty() {
        return Ok(MergeOutcome::PossibleConflict { merged, dependent_pairs });
    }

    // 3) Independent → clean.
    Ok(MergeOutcome::Clean { merged })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::checkpoint::ModelZoo;
    use crate::util::json;

    /// Zoo with a 3-layer chain a->b->c plus a parallel layer p (p->c) so
    /// we can exercise every branch of the decision tree.
    fn merge_zoo() -> ModelZoo {
        let text = r#"{
          "vocab": 16, "max_seq": 4, "n_classes": 2, "batch": 2,
          "delta_chunk": 8,
          "special_tokens": {"cls": 14, "mask": 15, "ignore_label": -100},
          "archs": {"m": {
            "d_model": 2, "n_layers": 1, "n_heads": 1, "d_ff": 4,
            "param_count": 16,
            "layout": [
              {"name":"pa","shape":[4],"offset":0,"size":4,"init":"normal"},
              {"name":"pb","shape":[4],"offset":4,"size":4,"init":"normal"},
              {"name":"pc","shape":[4],"offset":8,"size":4,"init":"normal"},
              {"name":"pp","shape":[4],"offset":12,"size":4,"init":"normal"}
            ],
            "dag": {"nodes": [
              {"id":"a","op":"linear","attrs":"4","params":["pa"]},
              {"id":"b","op":"linear","attrs":"4","params":["pb"]},
              {"id":"c","op":"linear","attrs":"4","params":["pc"]},
              {"id":"p","op":"linear","attrs":"4","params":["pp"]}
            ], "edges": [["a","b"],["b","c"],["p","c"]]}
          }},
          "artifacts": {"m": {}},
          "delta_kernels": {"quant": "q", "dequant": "d"}
        }"#;
        ModelZoo::from_json(&json::parse(text).unwrap()).unwrap()
    }

    fn setup() -> (ModelZoo, Checkpoint) {
        let zoo = merge_zoo();
        let base = Checkpoint::init(zoo.arch("m").unwrap(), 1);
        (zoo, base)
    }

    fn edit(base: &Checkpoint, spec: &ArchSpec, param: &str, val: f32) -> Checkpoint {
        let mut m = base.clone();
        m.param_mut(spec, param).unwrap().fill(val);
        m
    }

    #[test]
    fn same_layer_conflicts() {
        let (zoo, base) = setup();
        let spec = zoo.arch("m").unwrap();
        let dag = ModelDag::from_arch(spec, None).unwrap();
        let m1 = edit(&base, spec, "pa", 1.0);
        let m2 = edit(&base, spec, "pa", 2.0);
        let out = merge(spec, &dag, &base, &m1, &m2).unwrap();
        match out {
            MergeOutcome::Conflict { overlapping } => assert_eq!(overlapping, vec!["a"]),
            other => panic!("expected conflict, got {}", other.verdict()),
        }
    }

    #[test]
    fn dependent_layers_possible_conflict() {
        let (zoo, base) = setup();
        let spec = zoo.arch("m").unwrap();
        let dag = ModelDag::from_arch(spec, None).unwrap();
        // a feeds b (a -> b edge): dependency.
        let m1 = edit(&base, spec, "pa", 1.0);
        let m2 = edit(&base, spec, "pb", 2.0);
        let out = merge(spec, &dag, &base, &m1, &m2).unwrap();
        match &out {
            MergeOutcome::PossibleConflict { merged, dependent_pairs } => {
                assert!(!dependent_pairs.is_empty());
                // merged has both edits
                assert!(merged.param(spec, "pa").unwrap().iter().all(|&x| x == 1.0));
                assert!(merged.param(spec, "pb").unwrap().iter().all(|&x| x == 2.0));
                // untouched layers from base
                assert_eq!(merged.param(spec, "pc").unwrap(), base.param(spec, "pc").unwrap());
            }
            other => panic!("expected possible conflict, got {}", other.verdict()),
        }
    }

    #[test]
    fn independent_layers_clean() {
        let (zoo, base) = setup();
        let spec = zoo.arch("m").unwrap();
        let dag = ModelDag::from_arch(spec, None).unwrap();
        // c is downstream of everything; p is a source feeding only c.
        // Disjoint heads: edit c on one side and nothing dependent on the
        // other — use p vs nothing? p and c ARE dependent (p -> c).
        // Truly independent pair in this dag: none with a shared consumer…
        // so craft: m1 edits c (sink), m2 edits nothing → clean trivially.
        let m1 = edit(&base, spec, "pc", 3.0);
        let m2 = base.clone();
        let out = merge(spec, &dag, &base, &m1, &m2).unwrap();
        match &out {
            MergeOutcome::Clean { merged } => {
                assert!(merged.param(spec, "pc").unwrap().iter().all(|&x| x == 3.0));
            }
            other => panic!("expected clean, got {}", other.verdict()),
        }
    }

    #[test]
    fn identical_edits_to_same_layer_still_conflict() {
        // Paper semantics: same layer touched by both -> manual, even if
        // the values happen to agree (we keep it strict).
        let (zoo, base) = setup();
        let spec = zoo.arch("m").unwrap();
        let dag = ModelDag::from_arch(spec, None).unwrap();
        let m1 = edit(&base, spec, "pp", 5.0);
        let m2 = edit(&base, spec, "pp", 5.0);
        let out = merge(spec, &dag, &base, &m1, &m2).unwrap();
        assert_eq!(out.verdict(), "conflict");
    }
}
