//! # MGit — a model versioning and management system
//!
//! Reproduction of *MGit: A Model Versioning and Management System*
//! (ICML 2024) as a three-layer Rust + JAX + Pallas stack:
//!
//! * **L3 (this crate)** — the paper's contribution: the [`lineage`] graph,
//!   the content-addressed [`store`] with [`delta`] compression
//!   (Algorithm 1), the structural/contextual [`diff`] primitive
//!   (Algorithm 3), [`autoconstruct`]-ed graphs (§3.2), the [`merge`]
//!   decision tree (Figure 2), test/creation-function [`registry`]
//!   machinery, and the [`update`]/[`cascade`] execution tier
//!   (Algorithm 2, planned + wavefront-scheduled + journaled).
//! * **L2/L1 (build-time Python, `python/compile/`)** — the transformer
//!   model family and Pallas kernels, AOT-lowered to HLO text artifacts
//!   that the [`runtime`] executes through the PJRT CPU client. Python is
//!   never on the request path.
//!
//! Supporting substrates (everything the paper depends on, built here):
//! synthetic [`data`] tasks, [`train`]-ing creation functions, a federated
//! learning controller ([`fl`]), model [`workloads`] G1–G5,
//! dependency-free [`util`] (JSON, PRNG, CLI parsing, property testing),
//! and lock-free process metrics ([`obs`]: counters/gauges/histograms,
//! exposed by `mgit serve` as `GET /metrics`).
//!
//! The public entry point is the typed operations API in [`ops`]: every
//! repository operation is a request struct returning a serializable
//! report, executed against an open [`ops::Repo`] session. [`cli`] is a
//! thin argv shell over it, and [`ops::serve`] exposes the read path
//! over HTTP (see `docs/API.md`).

pub mod autoconstruct;
pub mod cascade;
pub mod checkpoint;
pub mod cli;
pub mod data;
pub mod delta;
pub mod diff;
pub mod fl;
pub mod lineage;
pub mod merge;
pub mod modeldag;
pub mod obs;
pub mod ops;
pub mod registry;
pub mod runtime;
pub mod store;
pub mod tensor;
pub mod train;
pub mod update;
pub mod util;
pub mod workloads;

/// Crate-wide result type.
pub type Result<T> = anyhow::Result<T>;
