//! Workload builders for the paper's five lineage graphs (Table 3) and
//! the persistence pass that feeds Table 4.
//!
//! Builders *train real models* through the PJRT runtime and return the
//! lineage graph (with creation specs + metadata) plus every checkpoint
//! in memory; [`persist`] then stores them under a given compression
//! configuration — separating the two lets the Table-4 bench compress one
//! build under five configurations.
//!
//! | Graph | Paper                       | Here                               |
//! |-------|-----------------------------|------------------------------------|
//! | G1    | 23 HuggingFace NLP models   | transformer zoo: 10 "pretrained" roots + 13 finetuned/frozen children, gold parent map |
//! | G2    | RoBERTa + 9 GLUE tasks × 10 perturbed versions (91/171) | MLM root + n tasks × (1 + versions) |
//! | G3    | ResNet-50 FL, 40 silos, 10 rounds, 5 sampled (60/95)    | [`crate::fl`] controller           |
//! | G4    | 3 pruned vision models      | 3 archs × progressive sparsities    |
//! | G5    | MTL RoBERTa, 10/9           | MTL group with shared backbone      |

use std::collections::HashMap;

use anyhow::{anyhow, Result};

use crate::autoconstruct::{self, AutoConfig, PoolModel};
use crate::checkpoint::Checkpoint;
use crate::delta::{self, CompressConfig, CompressReport, DeltaKernel, StoredModel};
use crate::fl::{run_federated, FlConfig};
use crate::lineage::{traversal, LineageGraph, NodeIdx};
use crate::modeldag::ModelDag;
use crate::registry::{CreationSpec, FreezeSpec, Objective, PerturbSpec};
use crate::runtime::Runtime;
use crate::store::Store;
use crate::train::{CasCheckpointStore, Trainer};
use crate::update::{CheckpointStore, CreationExecutor};
use crate::data;

/// A built workload: lineage graph (stored=None) + in-memory checkpoints.
pub struct Workload {
    pub name: String,
    pub graph: LineageGraph,
    pub checkpoints: HashMap<String, Checkpoint>,
}

impl Workload {
    pub fn ck(&self, name: &str) -> Result<&Checkpoint> {
        self.checkpoints
            .get(name)
            .ok_or_else(|| anyhow!("workload has no checkpoint for `{name}`"))
    }
}

/// Scale knobs (paper-shape vs test-size).
#[derive(Debug, Clone)]
pub struct Scale {
    pub n_tasks: usize,
    pub task_steps: usize,
    pub versions_per_task: usize,
    pub version_steps: usize,
    pub pretrain_steps: usize,
    pub lr: f32,
    pub fl: FlConfig,
    pub sparsities: Vec<f32>,
    pub prune_recover_steps: usize,
    pub mtl_steps: usize,
    pub g1_child_steps: usize,
}

impl Scale {
    /// Paper-shaped (node/edge counts match Table 3; step counts sized
    /// for a single-core CPU testbed).
    pub fn paper() -> Scale {
        Scale {
            n_tasks: 9,
            task_steps: 60,
            versions_per_task: 9, // + the original = 10 versions
            version_steps: 20,
            pretrain_steps: 60,
            lr: 0.02,
            fl: FlConfig {
                n_silos: 40,
                workers_per_round: 5,
                rounds: 10,
                local_steps: 3,
                ..FlConfig::default()
            },
            sparsities: vec![0.5, 0.7, 0.9],
            prune_recover_steps: 15,
            mtl_steps: 40,
            g1_child_steps: 30,
        }
    }

    /// Small (CI-sized) variant.
    pub fn small() -> Scale {
        Scale {
            n_tasks: 3,
            task_steps: 10,
            versions_per_task: 2,
            version_steps: 5,
            pretrain_steps: 10,
            lr: 0.02,
            fl: FlConfig {
                n_silos: 8,
                workers_per_round: 3,
                rounds: 2,
                local_steps: 2,
                ..FlConfig::default()
            },
            sparsities: vec![0.5, 0.8],
            prune_recover_steps: 4,
            mtl_steps: 8,
            g1_child_steps: 8,
        }
    }
}

fn task_name(i: usize) -> String {
    format!("task{}", i + 1)
}

// ---------------------------------------------------------------------------
// G2 — adaptation (MLM root -> task models -> perturbed versions)
// ---------------------------------------------------------------------------
pub fn build_g2(rt: &Runtime, scale: &Scale) -> Result<Workload> {
    let arch = "tx-tiny";
    let mut g = LineageGraph::new();
    let mut cks = HashMap::new();
    let trainer = Trainer::new(rt);

    // Root: MLM-pretrained base model.
    let root_spec = CreationSpec::Pretrain {
        corpus_seed: 42,
        steps: scale.pretrain_steps,
        lr: scale.lr,
    };
    let root_ck = trainer.execute(&root_spec, arch, &[])?;
    let root = g.add_node("g2/base-mlm", arch)?;
    g.register_creation_function(root, root_spec)?;
    cks.insert("g2/base-mlm".to_string(), root_ck.clone());

    for t in 0..scale.n_tasks {
        let task = task_name(t);
        let spec = CreationSpec::Finetune {
            task: task.clone(),
            objective: Objective::Cls,
            steps: scale.task_steps,
            lr: scale.lr,
            seed: 100 + t as u64,
            freeze: FreezeSpec::None,
            perturb: None,
        };
        let ck = trainer.execute(&spec, arch, &[root_ck.clone()])?;
        let name = format!("g2/{task}");
        let node = g.add_node(&name, arch)?;
        g.register_creation_function(node, spec)?;
        g.add_edge(root, node)?;
        cks.insert(name.clone(), ck.clone());

        // Versions: finetune the previous version on perturbed data.
        let mut prev_node = node;
        let mut prev_ck = ck;
        for v in 0..scale.versions_per_task {
            let kind = data::PERTURBATIONS[v % data::PERTURBATIONS.len()];
            let spec = CreationSpec::Finetune {
                task: task.clone(),
                objective: Objective::Cls,
                steps: scale.version_steps,
                lr: scale.lr,
                seed: 1000 + (t * 100 + v) as u64,
                freeze: FreezeSpec::None,
                perturb: Some(PerturbSpec { kind: kind.into(), strength: 0.3 }),
            };
            let vck = trainer.execute(&spec, arch, &[prev_ck.clone()])?;
            let vname = format!("g2/{task}@v{}", v + 2);
            let vnode = g.add_node(&vname, arch)?;
            g.register_creation_function(vnode, spec)?;
            // Both provenance and versioning edges (paper Fig. 1b).
            g.add_edge(prev_node, vnode)?;
            g.add_version_edge(prev_node, vnode)?;
            cks.insert(vname, vck.clone());
            prev_node = vnode;
            prev_ck = vck;
        }
    }
    Ok(Workload { name: "G2".into(), graph: g, checkpoints: cks })
}

// ---------------------------------------------------------------------------
// G3 — federated learning
// ---------------------------------------------------------------------------
pub fn build_g3(rt: &Runtime, scale: &Scale) -> Result<Workload> {
    // FL registers lineage itself; capture checkpoints through a
    // collecting CheckpointStore (mutexed: the trait is `&self`).
    struct Collect<'a> {
        inner: CasCheckpointStore<'a>,
        seen: std::sync::Mutex<Vec<(StoredModel, Checkpoint)>>,
    }
    impl<'a> CheckpointStore for Collect<'a> {
        fn load(&self, sm: &StoredModel) -> Result<Checkpoint> {
            self.inner.load(sm)
        }
        fn save(
            &self,
            ck: &Checkpoint,
            prev: Option<(&StoredModel, &Checkpoint)>,
        ) -> Result<StoredModel> {
            let sm = self.inner.save(ck, prev)?;
            self.seen.lock().unwrap().push((sm.clone(), ck.clone()));
            Ok(sm)
        }
    }
    let scratch = Store::in_memory();
    let collect = Collect {
        inner: CasCheckpointStore {
            store: &scratch,
            zoo: rt.zoo(),
            kernel: &crate::delta::NativeKernel,
            compress: None,
            cache: None,
        },
        seen: std::sync::Mutex::new(Vec::new()),
    };
    let mut g = LineageGraph::new();
    let cfg = FlConfig { ..scale.fl.clone() };
    run_federated(rt, &mut g, &collect, &cfg)?;
    // Map stored models back to node names.
    let mut cks = HashMap::new();
    let by_params: HashMap<String, Checkpoint> = collect
        .seen
        .into_inner()
        .unwrap()
        .iter()
        .map(|(sm, ck)| (sm.to_json().to_string_compact(), ck.clone()))
        .collect();
    for node in &g.nodes {
        if let Some(sm) = &node.stored {
            if let Some(ck) = by_params.get(&sm.to_json().to_string_compact()) {
                cks.insert(node.name.clone(), ck.clone());
            }
        }
    }
    // Strip stored pointers (persist() will re-store under each config).
    for node in g.nodes.iter_mut() {
        node.stored = None;
    }
    Ok(Workload { name: "G3".into(), graph: g, checkpoints: cks })
}

// ---------------------------------------------------------------------------
// G4 — edge specialization (progressive magnitude pruning, 3 archs)
// ---------------------------------------------------------------------------
pub fn build_g4(rt: &Runtime, scale: &Scale) -> Result<Workload> {
    let mut g = LineageGraph::new();
    let mut cks = HashMap::new();
    let trainer = Trainer::new(rt);
    // The 3 architectures stand in for ResNet-50 / DenseNet121 / MobileNet.
    for (ai, arch) in ["tx-tiny", "tx-small", "tx-base"].into_iter().enumerate() {
        let task = task_name(ai % scale.n_tasks.max(1));
        let root_spec = CreationSpec::Finetune {
            task: task.clone(),
            objective: Objective::Cls,
            steps: scale.task_steps,
            lr: scale.lr,
            seed: 7 + ai as u64,
            freeze: FreezeSpec::None,
            perturb: None,
        };
        let spec = rt.zoo().arch(arch)?;
        let base = Checkpoint::init(spec, 7 + ai as u64);
        let root_ck = trainer.execute(&root_spec, arch, &[base])?;
        let root_name = format!("g4/{arch}/dense");
        let root = g.add_node(&root_name, arch)?;
        g.register_creation_function(root, root_spec)?;
        cks.insert(root_name, root_ck.clone());

        let mut prev_node = root;
        let mut prev_ck = root_ck;
        for &s in &scale.sparsities {
            let spec = CreationSpec::Prune {
                sparsity: s,
                task: task.clone(),
                recover_steps: scale.prune_recover_steps,
                lr: scale.lr,
                seed: 50 + ai as u64,
            };
            let ck = trainer.execute(&spec, arch, &[prev_ck.clone()])?;
            let name = format!("g4/{arch}/sparse{:.0}", s * 100.0);
            let node = g.add_node(&name, arch)?;
            g.register_creation_function(node, spec)?;
            g.add_edge(prev_node, node)?;
            cks.insert(name, ck.clone());
            prev_node = node;
            prev_ck = ck;
        }
    }
    Ok(Workload { name: "G4".into(), graph: g, checkpoints: cks })
}

// ---------------------------------------------------------------------------
// G5 — multi-task learning (shared backbone)
// ---------------------------------------------------------------------------
pub fn build_g5(rt: &Runtime, scale: &Scale) -> Result<Workload> {
    let arch = "tx-tiny";
    let mut g = LineageGraph::new();
    let mut cks = HashMap::new();
    let trainer = Trainer::new(rt);

    let root_spec = CreationSpec::Pretrain {
        corpus_seed: 5,
        steps: scale.pretrain_steps,
        lr: scale.lr,
    };
    let root_ck = trainer.execute(&root_spec, arch, &[])?;
    let root = g.add_node("g5/base-mlm", arch)?;
    g.register_creation_function(root, root_spec)?;
    cks.insert("g5/base-mlm".to_string(), root_ck.clone());

    let group: Vec<String> = (0..scale.n_tasks).map(task_name).collect();
    let specs: Vec<CreationSpec> = group
        .iter()
        .map(|task| CreationSpec::Mtl {
            task: task.clone(),
            group: group.clone(),
            steps: scale.mtl_steps,
            lr: scale.lr,
            seed: 3,
        })
        .collect();
    let spec_refs: Vec<&CreationSpec> = specs.iter().collect();
    let outs = trainer.execute_mtl_group(&spec_refs, arch, &[root_ck])?;
    for (task, (spec, ck)) in group.iter().zip(specs.iter().zip(outs)) {
        let name = format!("g5/mtl-{task}");
        let node = g.add_node(&name, arch)?;
        g.register_creation_function(node, spec.clone())?;
        g.add_edge(root, node)?;
        cks.insert(name, ck);
    }
    Ok(Workload { name: "G5".into(), graph: g, checkpoints: cks })
}

// ---------------------------------------------------------------------------
// G1 — model-hub zoo + automated construction
// ---------------------------------------------------------------------------
/// The 23-model zoo with its gold parent map (None = root). Mirrors the
/// paper's HuggingFace list: 10 independently "pretrained" roots and 13
/// derived models, including frozen-backbone children.
pub fn g1_gold() -> Vec<(&'static str, &'static str, Option<&'static str>)> {
    // (name, arch, gold parent)
    vec![
        ("bert-base-cased", "tx-small", None),
        ("bert-base-uncased", "tx-small", None),
        ("bert-base-mnli", "tx-small", Some("bert-base-cased")),
        ("bert-base-uncased-squad-frozen", "tx-small", Some("bert-base-uncased")),
        ("bert-base-uncased-squad2", "tx-small", Some("bert-base-uncased")),
        ("bert-large-uncased", "tx-base", None),
        ("bert-large-cased", "tx-base", None),
        ("bert-large-mnli", "tx-base", Some("bert-large-uncased")),
        ("roberta-base", "tx-small", None),
        ("roberta-base-squad2", "tx-small", Some("roberta-base")),
        ("roberta-base-mnli", "tx-small", Some("roberta-base")),
        ("roberta-large", "tx-base", None),
        ("roberta-large-mnli", "tx-base", Some("roberta-large")),
        ("roberta-large-squad2", "tx-base", Some("roberta-large")),
        ("albert-base-v2", "tx-tiny", None),
        ("albert-base-v2-squad2", "tx-tiny", Some("albert-base-v2")),
        ("albert-base-v2-mnli", "tx-tiny", Some("albert-base-v2")),
        ("distilbert-base-uncased", "tx-tiny", None),
        ("distilbert-base-cased", "tx-tiny", None),
        ("distilbert-base-uncased-squad2", "tx-tiny", Some("distilbert-base-uncased")),
        ("distilbert-base-uncased-squad-frozen", "tx-tiny", Some("distilbert-base-uncased")),
        ("electra-small-generator", "tx-tiny", None),
        ("electra-small-mnli", "tx-tiny", Some("electra-small-generator")),
    ]
}

/// Build the G1 zoo by actually pretraining roots and finetuning children.
/// Tasks: "mnli" → task1, "squad" → task2 analogs; "frozen" children use
/// FreezeSpec::Backbone (the paper's frozen-weight models).
pub fn build_g1(rt: &Runtime, scale: &Scale) -> Result<Workload> {
    let gold = g1_gold();
    let mut g = LineageGraph::new();
    let mut cks: HashMap<String, Checkpoint> = HashMap::new();
    let trainer = Trainer::new(rt);

    for (i, (name, arch, parent)) in gold.iter().enumerate() {
        let (ck, spec) = match parent {
            None => {
                let spec = CreationSpec::Pretrain {
                    corpus_seed: 1000 + i as u64,
                    steps: scale.pretrain_steps,
                    lr: scale.lr,
                };
                (trainer.execute(&spec, arch, &[])?, spec)
            }
            Some(p) => {
                let task = if name.contains("mnli") { "task1" } else { "task2" };
                let freeze = if name.contains("frozen") {
                    FreezeSpec::Backbone
                } else {
                    FreezeSpec::None
                };
                let spec = CreationSpec::Finetune {
                    task: task.into(),
                    objective: Objective::Cls,
                    steps: scale.g1_child_steps,
                    lr: scale.lr,
                    seed: 2000 + i as u64,
                    freeze,
                    perturb: None,
                };
                let pck = cks
                    .get(*p)
                    .ok_or_else(|| anyhow!("gold parent {p} not built yet"))?
                    .clone();
                (trainer.execute(&spec, arch, &[pck])?, spec)
            }
        };
        let node = g.add_node(name, arch)?;
        g.register_creation_function(node, spec)?;
        if let Some(p) = parent {
            let pidx = g.idx(p)?;
            g.add_edge(pidx, node)?;
        }
        cks.insert(name.to_string(), ck);
    }
    Ok(Workload { name: "G1".into(), graph: g, checkpoints: cks })
}

/// §3.2 automated construction over a G1-style pool: insert models one by
/// one, scoring against everything already inserted. Returns
/// (constructed graph, #correct parent choices, per-model insert seconds).
pub fn auto_construct(
    rt: &Runtime,
    store: &Store,
    pool_order: &[(String, String, Option<String>)],
    checkpoints: &HashMap<String, Checkpoint>,
    cfg: &AutoConfig,
) -> Result<(LineageGraph, usize, Vec<f64>)> {
    let zoo = rt.zoo();
    let mut g = LineageGraph::new();
    let mut inserted: Vec<PoolModel<'_>> = Vec::new();
    let mut correct = 0;
    let mut times = Vec::new();

    for (name, arch, gold_parent) in pool_order {
        let spec = zoo.arch(arch)?;
        let ck = checkpoints
            .get(name)
            .ok_or_else(|| anyhow!("missing checkpoint {name}"))?
            .clone();
        let (sm, _) = delta::store_raw(store, spec, &ck)?;
        let pm = PoolModel {
            name: name.clone(),
            spec,
            dag: ModelDag::from_arch(spec, Some(&sm))?,
            ck,
        };
        let timer = crate::util::timing::Timer::start();
        let choice = autoconstruct::choose_parent(&inserted, &pm, cfg)?;
        times.push(timer.elapsed_secs());
        let node = g.add_node(name, arch)?;
        let chosen = match choice {
            Some((pi, _)) => {
                let pname = inserted[pi].name.clone();
                let pidx = g.idx(&pname)?;
                g.add_edge(pidx, node)?;
                Some(pname)
            }
            None => None,
        };
        if chosen.as_deref() == gold_parent.as_deref() {
            correct += 1;
        }
        inserted.push(pm);
    }
    Ok((g, correct, times))
}

// ---------------------------------------------------------------------------
// Persistence (feeds Table 4)
// ---------------------------------------------------------------------------
/// How a workload is persisted.
#[derive(Debug, Clone, Copy)]
pub enum PersistMode {
    /// Content hashing only (paper "MGit (Hash)").
    HashOnly,
    /// Hash + delta compression (paper "MGit (`<codec>` + Hash)").
    Delta(CompressConfig),
}

/// Aggregate result of persisting one workload.
#[derive(Debug, Clone, Default)]
pub struct PersistReport {
    pub raw_bytes: u64,
    pub stored_bytes: u64,
    pub n_models: usize,
    pub per_model: Vec<(String, CompressReport)>,
}

impl PersistReport {
    pub fn ratio(&self) -> f64 {
        if self.stored_bytes == 0 {
            return 0.0;
        }
        self.raw_bytes as f64 / self.stored_bytes as f64
    }
}

/// Store every checkpoint of a workload, parents before children;
/// children delta-compress against their version parent (preferred) or
/// first provenance parent. Updates `graph` nodes' `stored` pointers.
/// `check` (node name, reconstructed ck) gates lossy acceptance.
pub fn persist(
    wl: &mut Workload,
    store: &Store,
    zoo: &crate::checkpoint::ModelZoo,
    kernel: &dyn DeltaKernel,
    mode: PersistMode,
    mut check: impl FnMut(&str, &Checkpoint) -> Result<bool>,
) -> Result<PersistReport> {
    let mut report = PersistReport::default();
    // Persisted (possibly reconstructed) checkpoints by node index.
    let mut stored_cks: HashMap<NodeIdx, Checkpoint> = HashMap::new();

    // Roots-first order over provenance edges; version edges follow
    // provenance structure in all our workloads.
    let order = {
        let g = &wl.graph;
        let mut indeg: Vec<usize> =
            g.nodes.iter().map(|n| n.prov_parents.len()).collect();
        let mut queue: std::collections::VecDeque<NodeIdx> =
            (0..g.len()).filter(|&i| indeg[i] == 0).collect();
        let mut order = Vec::with_capacity(g.len());
        while let Some(i) = queue.pop_front() {
            order.push(i);
            for &c in &g.nodes[i].prov_children {
                indeg[c] -= 1;
                if indeg[c] == 0 {
                    queue.push_back(c);
                }
            }
        }
        order
    };

    for idx in order {
        let name = wl.graph.node(idx).name.clone();
        let mut ck = wl
            .checkpoints
            .get(&name)
            .ok_or_else(|| anyhow!("no checkpoint for node {name}"))?
            .clone();
        // G4 mode: quantize parameters to the grid BEFORE deltas, roots
        // included, so exact zeros survive the whole chain (paper §6.3).
        if let PersistMode::Delta(cfg) = mode {
            if cfg.prequantize {
                let grid = crate::delta::quant::step(cfg.eps);
                for x in ck.flat.iter_mut() {
                    if *x != 0.0 {
                        *x = (*x / grid + 0.5).floor() * grid;
                    }
                }
            }
        }
        let spec = zoo.arch(&ck.arch)?;
        report.n_models += 1;

        // Pick the compression parent.
        let parent_idx = wl
            .graph
            .node(idx)
            .ver_parents
            .first()
            .or_else(|| wl.graph.node(idx).prov_parents.first())
            .copied();

        let (sm, final_ck, rep) = match (mode, parent_idx) {
            (PersistMode::Delta(cfg), Some(p)) if wl.graph.node(p).stored.is_some() => {
                let pck = stored_cks
                    .get(&p)
                    .ok_or_else(|| anyhow!("parent checkpoint missing"))?;
                if pck.arch == ck.arch {
                    let pm = wl.graph.node(p).stored.clone().unwrap();
                    let pspec = zoo.arch(&pck.arch)?;
                    let (sm, final_ck, rep, _accepted) = delta::delta_compress_checked(
                        store, spec, &ck, pspec, pck, &pm, cfg, kernel,
                        |rec| check(&name, rec),
                    )?;
                    (sm, final_ck, rep)
                } else {
                    let (sm, rep) = delta::store_raw(store, spec, &ck)?;
                    (sm, ck.clone(), rep)
                }
            }
            _ => {
                let (sm, rep) = delta::store_raw(store, spec, &ck)?;
                (sm, ck.clone(), rep)
            }
        };
        report.raw_bytes += rep.raw_bytes;
        report.stored_bytes += rep.stored_bytes;
        report.per_model.push((name.clone(), rep));
        wl.graph.node_mut(idx).stored = Some(sm);
        stored_cks.insert(idx, final_ck);
    }
    Ok(report)
}
