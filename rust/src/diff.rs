//! The `diff` primitive (paper §3.2 + Appendix A, Algorithm 3).
//!
//! Computes the node/edge additions and deletions that transform model A
//! into model B, via hash-table bucketed greedy matching:
//!
//! 1. bucket both models' layers and edges by key hash (structural key =
//!    op/attrs; contextual key additionally includes parameter content
//!    hashes);
//! 2. greedily match edges bucket-by-bucket, committing a pair only when
//!    both endpoints' matched-status is consistent (a node may match at
//!    most one node);
//! 3. match leftover nodes by node-hash buckets in order;
//! 4. sort matches by A's topological order and drop *inverse* matches
//!    (pairs that go backwards in B's order), keeping a monotone matching;
//! 5. report unmatched nodes/edges of B as additions and of A as
//!    deletions.
//!
//! The divergence scores of §3.2 are `|edge diff| / (|E_A| + |E_B|)` under
//! the structural and contextual key respectively; [`value_distance`]
//! refines the contextual signal with a normalized parameter distance
//! (hash equality is too coarse for fully-finetuned children, which share
//! structure but no exact tensor values).

use std::collections::HashMap;

use anyhow::Result;

use crate::checkpoint::{ArchSpec, Checkpoint};
use crate::modeldag::ModelDag;

/// Which key the matching uses.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DiffMode {
    Structural,
    Contextual,
}

/// Output of `module_diff`: everything needed to turn A into B.
#[derive(Debug, Clone, Default)]
pub struct DiffResult {
    /// Matched layer pairs (index in A, index in B).
    pub matched_nodes: Vec<(usize, usize)>,
    /// Matched edge pairs (edge index in A, edge index in B).
    pub matched_edges: Vec<(usize, usize)>,
    /// Layer indices of B not present in A.
    pub add_nodes: Vec<usize>,
    /// Layer indices of A not present in B.
    pub del_nodes: Vec<usize>,
    /// Edge indices of B to add.
    pub add_edges: Vec<usize>,
    /// Edge indices of A to delete.
    pub del_edges: Vec<usize>,
}

impl DiffResult {
    pub fn is_empty(&self) -> bool {
        self.add_nodes.is_empty()
            && self.del_nodes.is_empty()
            && self.add_edges.is_empty()
            && self.del_edges.is_empty()
    }

    /// §3.2 divergence score: |edge diff| / (|E_A| + |E_B|).
    pub fn divergence(&self, a: &ModelDag, b: &ModelDag) -> f64 {
        let denom = (a.n_edges() + b.n_edges()) as f64;
        if denom == 0.0 {
            return 0.0;
        }
        (self.add_edges.len() + self.del_edges.len()) as f64 / denom
    }
}

/// Algorithm 3.
pub fn module_diff(a: &ModelDag, b: &ModelDag, mode: DiffMode) -> DiffResult {
    let contextual = mode == DiffMode::Contextual;
    let akeys: Vec<u64> = a.layers.iter().map(|l| l.key_hash(contextual)).collect();
    let bkeys: Vec<u64> = b.layers.iter().map(|l| l.key_hash(contextual)).collect();

    // Edge hash = (key of src, key of dst).
    let edge_key = |keys: &[u64], (s, d): (usize, usize)| -> (u64, u64) { (keys[s], keys[d]) };

    // Bucket B's edges by hash (value: edge indices, topological order —
    // edges are emitted in topo order by construction).
    let mut b_edge_buckets: HashMap<(u64, u64), Vec<usize>> = HashMap::new();
    for (ei, &e) in b.edges.iter().enumerate() {
        b_edge_buckets.entry(edge_key(&bkeys, e)).or_default().push(ei);
    }

    // matched_a[i] = Some(j) when A.layer i is matched to B.layer j.
    let mut matched_a: Vec<Option<usize>> = vec![None; a.n_layers()];
    let mut matched_b: Vec<Option<usize>> = vec![None; b.n_layers()];
    let mut matched_edges: Vec<(usize, usize)> = Vec::new();

    // Pass 1: greedy edge matching.
    for (aei, &ae) in a.edges.iter().enumerate() {
        let key = edge_key(&akeys, ae);
        let Some(bucket) = b_edge_buckets.get_mut(&key) else { continue };
        let mut chosen: Option<usize> = None;
        for (slot, &bei) in bucket.iter().enumerate() {
            let be = b.edges[bei];
            // check(e1, e2): endpoints must have consistent matched status.
            let src_ok = match matched_a[ae.0] {
                Some(j) => j == be.0,
                None => matched_b[be.0].is_none(),
            };
            let dst_ok = match matched_a[ae.1] {
                Some(j) => j == be.1,
                None => matched_b[be.1].is_none(),
            };
            if src_ok && dst_ok {
                chosen = Some(slot);
                break;
            }
        }
        if let Some(slot) = chosen {
            let bei = bucket.remove(slot);
            let be = b.edges[bei];
            matched_a[ae.0] = Some(be.0);
            matched_b[be.0] = Some(ae.0);
            matched_a[ae.1] = Some(be.1);
            matched_b[be.1] = Some(ae.1);
            matched_edges.push((aei, bei));
        }
    }

    // Pass 2: match leftover nodes by node-key buckets, in order.
    let mut b_node_buckets: HashMap<u64, Vec<usize>> = HashMap::new();
    for (j, &k) in bkeys.iter().enumerate() {
        if matched_b[j].is_none() {
            b_node_buckets.entry(k).or_default().push(j);
        }
    }
    for (i, &k) in akeys.iter().enumerate() {
        if matched_a[i].is_some() {
            continue;
        }
        if let Some(bucket) = b_node_buckets.get_mut(&k) {
            if let Some(j) = bucket.first().copied() {
                bucket.remove(0);
                matched_a[i] = Some(j);
                matched_b[j] = Some(i);
            }
        }
    }

    // Pass 3: drop inverse matches — keep node pairs monotone in B when
    // scanned in A's topological order (A-B-A-C vs A-B-C-A example).
    let mut last_b: isize = -1;
    let mut kept_nodes: Vec<(usize, usize)> = Vec::new();
    for i in 0..a.n_layers() {
        if let Some(j) = matched_a[i] {
            if (j as isize) > last_b {
                kept_nodes.push((i, j));
                last_b = j as isize;
            } else {
                matched_a[i] = None;
                matched_b[j] = None;
            }
        }
    }
    // Re-filter edge matches whose endpoints got dropped.
    matched_edges.retain(|&(aei, bei)| {
        let ae = a.edges[aei];
        let be = b.edges[bei];
        matched_a[ae.0] == Some(be.0) && matched_a[ae.1] == Some(be.1)
    });

    // Matched edge set for add/del computation: an A-edge survives if both
    // endpoints map and the corresponding B edge exists.
    let b_edge_set: HashMap<(usize, usize), usize> = b
        .edges
        .iter()
        .enumerate()
        .map(|(ei, &e)| (e, ei))
        .collect();
    let mut b_edge_matched = vec![false; b.edges.len()];
    let mut del_edges = Vec::new();
    for (aei, &(s, d)) in a.edges.iter().enumerate() {
        let mapped = match (matched_a[s], matched_a[d]) {
            (Some(ms), Some(md)) => b_edge_set.get(&(ms, md)).copied(),
            _ => None,
        };
        match mapped {
            Some(bei) => b_edge_matched[bei] = true,
            None => del_edges.push(aei),
        }
    }
    let add_edges: Vec<usize> =
        (0..b.edges.len()).filter(|&ei| !b_edge_matched[ei]).collect();

    DiffResult {
        add_nodes: (0..b.n_layers()).filter(|&j| matched_b[j].is_none()).collect(),
        del_nodes: (0..a.n_layers()).filter(|&i| matched_a[i].is_none()).collect(),
        add_edges,
        del_edges,
        matched_nodes: kept_nodes,
        matched_edges,
    }
}

/// Both §3.2 divergence scores at once.
pub fn divergence_scores(a: &ModelDag, b: &ModelDag) -> (f64, f64) {
    let ds = module_diff(a, b, DiffMode::Structural).divergence(a, b);
    let dc = module_diff(a, b, DiffMode::Contextual).divergence(a, b);
    (ds, dc)
}

/// Normalized parameter-value distance over structurally matched layers:
/// `||A − B|| / (||A|| + ||B||)` summed over matched, shape-equal tensors
/// (1.0 when nothing matches). ≈0 for finetuned children, ≈0.7 for
/// independently initialized models of the same architecture.
pub fn value_distance(
    a_dag: &ModelDag,
    a_spec: &ArchSpec,
    a_ck: &Checkpoint,
    b_dag: &ModelDag,
    b_spec: &ArchSpec,
    b_ck: &Checkpoint,
) -> Result<f64> {
    let diff = module_diff(a_dag, b_dag, DiffMode::Structural);
    let mut num = 0.0f64;
    let (mut na, mut nb) = (0.0f64, 0.0f64);
    let mut any = false;
    for &(i, j) in &diff.matched_nodes {
        let la = &a_dag.layers[i];
        let lb = &b_dag.layers[j];
        for (pa, pb) in la.params.iter().zip(&lb.params) {
            let (ea, eb) = (a_spec.entry(pa)?, b_spec.entry(pb)?);
            if ea.shape != eb.shape {
                continue;
            }
            let va = &a_ck.flat[ea.offset..ea.offset + ea.size];
            let vb = &b_ck.flat[eb.offset..eb.offset + eb.size];
            for (x, y) in va.iter().zip(vb) {
                let (x, y) = (*x as f64, *y as f64);
                num += (x - y) * (x - y);
                na += x * x;
                nb += y * y;
            }
            any = true;
        }
    }
    if !any {
        return Ok(1.0);
    }
    let denom = na.sqrt() + nb.sqrt();
    if denom == 0.0 {
        return Ok(0.0);
    }
    Ok((num.sqrt() / denom).min(1.0))
}

/// Layers of `other` whose parameters differ from `base` despite matching
/// structurally — the "changed layers" input of the merge decision tree.
pub fn changed_layers(base: &ModelDag, other: &ModelDag) -> Vec<usize> {
    let diff = module_diff(base, other, DiffMode::Structural);
    let mut changed: Vec<usize> = diff
        .matched_nodes
        .iter()
        .filter(|&&(i, j)| base.layers[i].contextual_key() != other.layers[j].contextual_key())
        .map(|&(i, _)| i)
        .collect();
    // Structurally new layers count as changed too (indices in base space
    // don't exist; report via sentinel usize::MAX offsets appended after).
    changed.sort_unstable();
    changed
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::checkpoint::testutil::tiny_zoo;
    use crate::checkpoint::Checkpoint;
    use crate::delta::store_raw;
    use crate::store::Store;

    fn dag_of(seed: u64, arch: &str) -> (ModelDag, Checkpoint) {
        let zoo = tiny_zoo();
        let spec = zoo.arch(arch).unwrap();
        let store = Store::in_memory();
        let ck = Checkpoint::init(spec, seed);
        let (sm, _) = store_raw(&store, spec, &ck).unwrap();
        (ModelDag::from_arch(spec, Some(&sm)).unwrap(), ck)
    }

    #[test]
    fn diff_self_is_empty() {
        let (dag, _) = dag_of(1, "t0");
        for mode in [DiffMode::Structural, DiffMode::Contextual] {
            let d = module_diff(&dag, &dag, mode);
            assert!(d.is_empty(), "mode {mode:?}: {d:?}");
            assert_eq!(d.matched_nodes.len(), dag.n_layers());
            assert_eq!(d.divergence(&dag, &dag), 0.0);
        }
    }

    #[test]
    fn same_arch_different_values() {
        let (a, _) = dag_of(1, "t0");
        let (b, _) = dag_of(2, "t0");
        // Structurally identical…
        let ds = module_diff(&a, &b, DiffMode::Structural);
        assert!(ds.is_empty());
        // …contextually disjoint (no shared tensors).
        let dc = module_diff(&a, &b, DiffMode::Contextual);
        assert!(!dc.is_empty());
        assert_eq!(dc.divergence(&a, &b), 1.0);
    }

    #[test]
    fn cross_arch_structural_overlap() {
        let (a, _) = dag_of(1, "t0"); // linear + bias layers
        let (b, _) = dag_of(1, "t1"); // linear + linear (different attrs)
        let ds = module_diff(&a, &b, DiffMode::Structural);
        // The shared `linear 2x3` layer matches; the others don't.
        assert_eq!(ds.matched_nodes.len(), 1);
        let d = ds.divergence(&a, &b);
        assert!(d > 0.0 && d <= 1.0, "d={d}");
    }

    #[test]
    fn divergence_scores_ordering() {
        // finetuned-like pair: same structure, one tensor changed.
        let zoo = crate::checkpoint::testutil::normal_zoo();
        let spec = zoo.arch("n0").unwrap();
        let store = Store::in_memory();
        let parent = Checkpoint::init(spec, 1);
        let mut child = parent.clone();
        child.param_mut(spec, "w.head").unwrap()[0] = 42.0;
        let (pm, _) = store_raw(&store, spec, &parent).unwrap();
        let (cm, _) = store_raw(&store, spec, &child).unwrap();
        let pd = ModelDag::from_arch(spec, Some(&pm)).unwrap();
        let cd = ModelDag::from_arch(spec, Some(&cm)).unwrap();
        let (ds, dc) = divergence_scores(&pd, &cd);
        assert_eq!(ds, 0.0);
        assert!(dc > 0.0 && dc < 1.0, "dc={dc}");
    }

    #[test]
    fn value_distance_separates_finetune_from_reinit() {
        let zoo = crate::checkpoint::testutil::normal_zoo();
        let spec = zoo.arch("n0").unwrap();
        let parent = Checkpoint::init(spec, 1);
        let mut finetuned = parent.clone();
        for x in finetuned.flat.iter_mut() {
            *x += 0.001;
        }
        let reinit = Checkpoint::init(spec, 99);
        let dag = ModelDag::from_arch(spec, None).unwrap();
        let d_ft =
            value_distance(&dag, spec, &parent, &dag, spec, &finetuned).unwrap();
        let d_re = value_distance(&dag, spec, &parent, &dag, spec, &reinit).unwrap();
        assert!(d_ft < 0.1, "finetune distance {d_ft}");
        assert!(d_re > 0.4, "reinit distance {d_re}");
        assert!(d_ft < d_re);
    }

    #[test]
    fn changed_layers_detects_edits() {
        let zoo = tiny_zoo();
        let spec = zoo.arch("t0").unwrap();
        let store = Store::in_memory();
        let base = Checkpoint::init(spec, 1);
        let mut edited = base.clone();
        edited.param_mut(spec, "w.a").unwrap()[0] += 1.0;
        let (bm, _) = store_raw(&store, spec, &base).unwrap();
        let (em, _) = store_raw(&store, spec, &edited).unwrap();
        let bd = ModelDag::from_arch(spec, Some(&bm)).unwrap();
        let ed = ModelDag::from_arch(spec, Some(&em)).unwrap();
        let changed = changed_layers(&bd, &ed);
        assert_eq!(changed, vec![bd.layer_index("a").unwrap()]);
    }
}
