//! Dense tensors (f32 / i32) with the small set of operations MGit's
//! storage and diagnostics paths need: byte (de)serialization, norms,
//! sparsity accounting and magnitude masking (for the pruning creation
//! functions of G4).

use anyhow::{bail, Result};

/// Element type of a stored tensor.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DType {
    F32,
    I32,
}

impl DType {
    pub fn code(self) -> u8 {
        match self {
            DType::F32 => 0,
            DType::I32 => 1,
        }
    }

    pub fn from_code(c: u8) -> Result<DType> {
        match c {
            0 => Ok(DType::F32),
            1 => Ok(DType::I32),
            _ => bail!("unknown dtype code {c}"),
        }
    }

    pub fn size_of(self) -> usize {
        4
    }
}

/// Tensor payload.
#[derive(Debug, Clone, PartialEq)]
pub enum TensorData {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

/// A dense tensor: shape + data.
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: TensorData,
}

impl Tensor {
    pub fn f32(shape: Vec<usize>, data: Vec<f32>) -> Tensor {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        Tensor { shape, data: TensorData::F32(data) }
    }

    pub fn i32(shape: Vec<usize>, data: Vec<i32>) -> Tensor {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        Tensor { shape, data: TensorData::I32(data) }
    }

    pub fn zeros_f32(shape: Vec<usize>) -> Tensor {
        let n = shape.iter().product();
        Tensor::f32(shape, vec![0.0; n])
    }

    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }

    pub fn dtype(&self) -> DType {
        match self.data {
            TensorData::F32(_) => DType::F32,
            TensorData::I32(_) => DType::I32,
        }
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match &self.data {
            TensorData::F32(v) => Ok(v),
            _ => bail!("tensor is not f32"),
        }
    }

    pub fn as_i32(&self) -> Result<&[i32]> {
        match &self.data {
            TensorData::I32(v) => Ok(v),
            _ => bail!("tensor is not i32"),
        }
    }

    pub fn as_f32_mut(&mut self) -> Result<&mut Vec<f32>> {
        match &mut self.data {
            TensorData::F32(v) => Ok(v),
            _ => bail!("tensor is not f32"),
        }
    }

    // ------------------------------------------------------------------
    // Byte serialization (little-endian, matching PJRT host layout)
    // ------------------------------------------------------------------
    pub fn payload_bytes(&self) -> Vec<u8> {
        match &self.data {
            TensorData::F32(v) => f32_to_bytes(v),
            TensorData::I32(v) => i32_to_bytes(v),
        }
    }

    pub fn from_payload(dtype: DType, shape: Vec<usize>, bytes: &[u8]) -> Result<Tensor> {
        let n: usize = shape.iter().product();
        if bytes.len() != n * dtype.size_of() {
            bail!(
                "payload size mismatch: shape {:?} wants {} bytes, got {}",
                shape,
                n * dtype.size_of(),
                bytes.len()
            );
        }
        Ok(match dtype {
            DType::F32 => Tensor::f32(shape, bytes_to_f32(bytes)),
            DType::I32 => Tensor::i32(shape, bytes_to_i32(bytes)),
        })
    }

    // ------------------------------------------------------------------
    // Diagnostics / math
    // ------------------------------------------------------------------
    pub fn l2_norm(&self) -> f64 {
        match &self.data {
            TensorData::F32(v) => v.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>().sqrt(),
            TensorData::I32(v) => v.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>().sqrt(),
        }
    }

    pub fn max_abs_diff(&self, other: &Tensor) -> Result<f64> {
        let (a, b) = (self.as_f32()?, other.as_f32()?);
        if a.len() != b.len() {
            bail!("shape mismatch in max_abs_diff");
        }
        Ok(a.iter()
            .zip(b)
            .map(|(&x, &y)| ((x - y).abs()) as f64)
            .fold(0.0, f64::max))
    }

    /// Fraction of exactly-zero elements.
    pub fn sparsity(&self) -> f64 {
        let n = self.numel();
        if n == 0 {
            return 0.0;
        }
        let zeros = match &self.data {
            TensorData::F32(v) => v.iter().filter(|&&x| x == 0.0).count(),
            TensorData::I32(v) => v.iter().filter(|&&x| x == 0).count(),
        };
        zeros as f64 / n as f64
    }
}

// ---------------------------------------------------------------------------
// Flat slice helpers (the runtime works on flat f32 vectors)
// ---------------------------------------------------------------------------
pub fn f32_to_bytes(v: &[f32]) -> Vec<u8> {
    let mut out = Vec::with_capacity(v.len() * 4);
    for x in v {
        out.extend_from_slice(&x.to_le_bytes());
    }
    out
}

pub fn bytes_to_f32(b: &[u8]) -> Vec<f32> {
    b.chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect()
}

pub fn i32_to_bytes(v: &[i32]) -> Vec<u8> {
    let mut out = Vec::with_capacity(v.len() * 4);
    for x in v {
        out.extend_from_slice(&x.to_le_bytes());
    }
    out
}

pub fn bytes_to_i32(b: &[u8]) -> Vec<i32> {
    b.chunks_exact(4)
        .map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect()
}

/// Indices of the `k` smallest-magnitude *non-zero* elements (G4's L1
/// magnitude pruning step).
pub fn smallest_magnitude_nonzero(v: &[f32], k: usize) -> Vec<usize> {
    let mut idx: Vec<usize> = (0..v.len()).filter(|&i| v[i] != 0.0).collect();
    idx.sort_by(|&a, &b| v[a].abs().partial_cmp(&v[b].abs()).unwrap());
    idx.truncate(k);
    idx
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn payload_roundtrip_f32() {
        let t = Tensor::f32(vec![2, 3], vec![1.0, -2.5, 0.0, 3.25, f32::MIN, f32::MAX]);
        let bytes = t.payload_bytes();
        let back = Tensor::from_payload(DType::F32, vec![2, 3], &bytes).unwrap();
        assert_eq!(t, back);
    }

    #[test]
    fn payload_roundtrip_i32() {
        let t = Tensor::i32(vec![4], vec![i32::MIN, -1, 0, i32::MAX]);
        let back = Tensor::from_payload(DType::I32, vec![4], &t.payload_bytes()).unwrap();
        assert_eq!(t, back);
    }

    #[test]
    fn payload_size_checked() {
        assert!(Tensor::from_payload(DType::F32, vec![3], &[0u8; 8]).is_err());
    }

    #[test]
    fn norms_and_sparsity() {
        let t = Tensor::f32(vec![4], vec![3.0, 0.0, 4.0, 0.0]);
        assert!((t.l2_norm() - 5.0).abs() < 1e-12);
        assert!((t.sparsity() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn magnitude_selection_skips_zeros() {
        let v = vec![0.0, -0.1, 5.0, 0.01, 0.0, -2.0];
        let idx = smallest_magnitude_nonzero(&v, 2);
        assert_eq!(idx, vec![3, 1]);
    }

    #[test]
    fn max_abs_diff_works() {
        let a = Tensor::f32(vec![3], vec![1.0, 2.0, 3.0]);
        let b = Tensor::f32(vec![3], vec![1.0, 2.5, 2.0]);
        assert!((a.max_abs_diff(&b).unwrap() - 1.0).abs() < 1e-12);
    }
}
