//! Creation-function execution (the paper's `cr` callables, §3.1.2) over
//! the PJRT runtime: finetuning (full / frozen-backbone / BitFit), MLM
//! pretraining, magnitude pruning with sparsity-preserving recovery (G4),
//! federated/plain averaging, and joint MTL training with a shared
//! backbone (G5). Also the CAS-backed [`CheckpointStore`] used by the
//! update cascade.

use std::sync::Mutex;

use anyhow::{anyhow, bail, Result};

use crate::checkpoint::{ArchSpec, Checkpoint};
use crate::data;
use crate::delta::{self, CompressConfig, DeltaKernel, ResolveCache, StoredModel};
use crate::registry::{CreationSpec, FreezeSpec, Objective};
use crate::runtime::Runtime;
use crate::store::Store;
use crate::tensor::smallest_magnitude_nonzero;
use crate::update::{CheckpointStore, CreationExecutor};

/// Training hyper-defaults shared by workloads.
pub const DEFAULT_LR: f32 = 0.05;

/// Loss trace of one creation (logged by the e2e example).
#[derive(Debug, Clone, Default)]
pub struct TrainTrace {
    pub losses: Vec<f32>,
}

/// Executes creation specs against the runtime.
///
/// [`CreationExecutor`] is `&self + Send + Sync` (cascade workers share
/// one trainer), so the diagnostic loss traces live behind a mutex —
/// the lock is taken once per finished creation, never inside the
/// training loop.
pub struct Trainer<'a> {
    pub rt: &'a Runtime,
    /// Loss traces per executed creation, in completion order
    /// (diagnostics; drain with [`Trainer::take_traces`]).
    pub traces: Mutex<Vec<(String, TrainTrace)>>,
}

impl<'a> Trainer<'a> {
    pub fn new(rt: &'a Runtime) -> Trainer<'a> {
        Trainer { rt, traces: Mutex::new(Vec::new()) }
    }

    /// Drain the accumulated loss traces.
    pub fn take_traces(&self) -> Vec<(String, TrainTrace)> {
        std::mem::take(&mut *self.traces.lock().unwrap())
    }

    fn spec_of(&self, arch: &str) -> Result<&ArchSpec> {
        self.rt.zoo().arch(arch)
    }

    /// Indices (offset ranges) frozen under a freeze policy.
    fn frozen_ranges(&self, spec: &ArchSpec, freeze: FreezeSpec) -> Vec<(usize, usize)> {
        let is_head = |name: &str| name.starts_with("mlm_head") || name.starts_with("cls_head");
        spec.layout
            .iter()
            .filter(|e| match freeze {
                FreezeSpec::None => false,
                FreezeSpec::Backbone => !is_head(&e.name),
                FreezeSpec::BiasOnly => {
                    // BitFit: train biases/LN vectors + heads; freeze
                    // everything else (the 2-D weight matrices).
                    !is_head(&e.name) && e.shape.len() > 1
                }
            })
            .map(|e| (e.offset, e.offset + e.size))
            .collect()
    }

    /// Core training loop with optional freezing and pruning masks.
    #[allow(clippy::too_many_arguments)]
    fn train_loop(
        &self,
        label: &str,
        arch: &str,
        obj: Objective,
        init: &Checkpoint,
        task_or_corpus: &str,
        steps: usize,
        lr: f32,
        seed: u64,
        perturb: Option<(&str, f64)>,
        frozen: &[(usize, usize)],
        zero_mask: Option<&[bool]>,
    ) -> Result<Checkpoint> {
        let zoo = self.rt.zoo();
        let spec = self.spec_of(arch)?;
        init.check_arch(spec)?;
        let mut params = init.flat.clone();
        let mut mom = vec![0f32; params.len()];
        let frozen_copy: Vec<Vec<f32>> = frozen
            .iter()
            .map(|&(a, b)| params[a..b].to_vec())
            .collect();
        let mut trace = TrainTrace::default();
        for step in 0..steps {
            let batch = match obj {
                Objective::Cls => data::cls_batch(
                    task_or_corpus,
                    zoo.batch,
                    zoo.max_seq,
                    seed,
                    step as u64,
                    perturb,
                )?,
                Objective::Mlm => data::mlm_batch(
                    seed,
                    zoo.batch,
                    zoo.max_seq,
                    step as u64,
                    perturb,
                )?,
            };
            let loss = self.rt.train_step(arch, obj, &mut params, &mut mom, &batch, lr)?;
            // Re-impose freeze / sparsity invariants after the step.
            for (&(a, b), orig) in frozen.iter().zip(&frozen_copy) {
                params[a..b].copy_from_slice(orig);
                mom[a..b].fill(0.0);
            }
            if let Some(mask) = zero_mask {
                for (p, &z) in params.iter_mut().zip(mask) {
                    if z {
                        *p = 0.0;
                    }
                }
                for (m, &z) in mom.iter_mut().zip(mask) {
                    if z {
                        *m = 0.0;
                    }
                }
            }
            trace.losses.push(loss);
        }
        self.traces.lock().unwrap().push((label.to_string(), trace));
        Ok(Checkpoint { arch: arch.to_string(), flat: params })
    }

    /// Magnitude-prune to `sparsity` (fraction of *all* weight params
    /// zeroed, lowest |value| first, per G4's two-step process), returning
    /// the mask of zeroed positions.
    fn prune_mask(&self, spec: &ArchSpec, ck: &Checkpoint, sparsity: f32) -> Vec<bool> {
        let mut mask = vec![false; ck.flat.len()];
        // Prune only the >=2-D weight tensors (biases/LN stay dense).
        for e in &spec.layout {
            if e.shape.len() < 2 {
                continue;
            }
            let slice = &ck.flat[e.offset..e.offset + e.size];
            let nonzero = slice.iter().filter(|&&x| x != 0.0).count();
            let target = (e.size as f64 * sparsity as f64) as usize;
            let already = e.size - nonzero;
            if target <= already {
                continue;
            }
            let k = target - already;
            for idx in smallest_magnitude_nonzero(slice, k) {
                mask[e.offset + idx] = true;
            }
        }
        mask
    }

    pub fn average(&self, arch: &str, parents: &[Checkpoint]) -> Result<Checkpoint> {
        average_checkpoints(arch, parents)
    }
}

/// Uniform parameter average (FedAvg with equal weights).
pub fn average_checkpoints(arch: &str, parents: &[Checkpoint]) -> Result<Checkpoint> {
    if parents.is_empty() {
        bail!("average needs at least one parent");
    }
    let n = parents[0].flat.len();
    for p in parents {
        if p.arch != arch || p.flat.len() != n {
            bail!("average: parent arch/shape mismatch");
        }
    }
    let mut flat = vec![0f32; n];
    for p in parents {
        for (o, &x) in flat.iter_mut().zip(&p.flat) {
            *o += x;
        }
    }
    let inv = 1.0 / parents.len() as f32;
    for o in flat.iter_mut() {
        *o *= inv;
    }
    Ok(Checkpoint { arch: arch.to_string(), flat })
}

impl<'a> CreationExecutor for Trainer<'a> {
    fn execute(
        &self,
        spec: &CreationSpec,
        arch: &str,
        parents: &[Checkpoint],
    ) -> Result<Checkpoint> {
        match spec {
            CreationSpec::Finetune { task, objective, steps, lr, seed, freeze, perturb } => {
                let parent = parents
                    .first()
                    .ok_or_else(|| anyhow!("finetune needs a parent"))?;
                let aspec = self.spec_of(arch)?;
                let frozen = self.frozen_ranges(aspec, *freeze);
                let p = perturb.as_ref().map(|p| (p.kind.as_str(), p.strength));
                self.train_loop(
                    &format!("finetune/{task}"),
                    arch,
                    *objective,
                    parent,
                    task,
                    *steps,
                    *lr,
                    *seed,
                    p,
                    &frozen,
                    None,
                )
            }
            CreationSpec::Pretrain { corpus_seed, steps, lr } => {
                let aspec = self.spec_of(arch)?;
                let init = match parents.first() {
                    Some(p) => p.clone(),
                    None => Checkpoint::init(aspec, *corpus_seed),
                };
                self.train_loop(
                    "pretrain",
                    arch,
                    Objective::Mlm,
                    &init,
                    "corpus",
                    *steps,
                    *lr,
                    *corpus_seed,
                    None,
                    &[],
                    None,
                )
            }
            CreationSpec::Prune { sparsity, task, recover_steps, lr, seed } => {
                let parent = parents
                    .first()
                    .ok_or_else(|| anyhow!("prune needs a parent"))?;
                let aspec = self.spec_of(arch)?;
                let mask = self.prune_mask(aspec, parent, *sparsity);
                let mut pruned = parent.clone();
                for (p, &z) in pruned.flat.iter_mut().zip(&mask) {
                    if z {
                        *p = 0.0;
                    }
                }
                if *recover_steps == 0 {
                    return Ok(pruned);
                }
                self.train_loop(
                    &format!("prune{sparsity}/{task}"),
                    arch,
                    Objective::Cls,
                    &pruned,
                    task,
                    *recover_steps,
                    *lr,
                    *seed,
                    None,
                    &[],
                    Some(&mask),
                )
            }
            CreationSpec::FedAvg | CreationSpec::Average => self.average(arch, parents),
            CreationSpec::Mtl { .. } => {
                // Single-member fallback: treated as a group of one.
                let group = self.execute_mtl_group(&[spec], arch, parents)?;
                Ok(group.into_iter().next().unwrap())
            }
        }
    }

    /// Joint MTL training (the merged cr' of §5): one shared backbone,
    /// per-task heads, round-robin task steps. Returned checkpoints share
    /// every non-head tensor bit-exactly — content hashing then stores the
    /// backbone once (the paper's 98% sharing for G5).
    fn execute_mtl_group(
        &self,
        specs: &[&CreationSpec],
        arch: &str,
        parents: &[Checkpoint],
    ) -> Result<Vec<Checkpoint>> {
        let aspec = self.spec_of(arch)?;
        let parent = parents
            .first()
            .ok_or_else(|| anyhow!("mtl needs a parent"))?;
        parent.check_arch(aspec)?;
        let zoo = self.rt.zoo();

        struct Member {
            task: String,
            steps: usize,
            lr: f32,
            seed: u64,
            head: Vec<f32>,
        }
        let head_entries: Vec<(usize, usize)> = aspec
            .layout
            .iter()
            .filter(|e| e.name.starts_with("cls_head"))
            .map(|e| (e.offset, e.offset + e.size))
            .collect();
        let mut members = Vec::new();
        for s in specs {
            let CreationSpec::Mtl { task, steps, lr, seed, .. } = s else {
                bail!("execute_mtl_group got non-MTL spec {}", s.kind());
            };
            let head = head_entries
                .iter()
                .flat_map(|&(a, b)| parent.flat[a..b].to_vec())
                .collect();
            members.push(Member {
                task: task.clone(),
                steps: *steps,
                lr: *lr,
                seed: *seed,
                head,
            });
        }
        let mut params = parent.flat.clone();
        let mut mom = vec![0f32; params.len()];
        let max_steps = members.iter().map(|m| m.steps).max().unwrap_or(0);
        let mut trace = TrainTrace::default();
        for step in 0..max_steps {
            for mi in 0..members.len() {
                if step >= members[mi].steps {
                    continue;
                }
                // Swap in this task's head.
                let mut off = 0;
                for &(a, b) in &head_entries {
                    params[a..b].copy_from_slice(&members[mi].head[off..off + (b - a)]);
                    off += b - a;
                }
                let batch = data::cls_batch(
                    &members[mi].task,
                    zoo.batch,
                    zoo.max_seq,
                    members[mi].seed,
                    step as u64,
                    None,
                )?;
                let loss = self.rt.train_step(
                    arch,
                    Objective::Cls,
                    &mut params,
                    &mut mom,
                    &batch,
                    members[mi].lr,
                )?;
                trace.losses.push(loss);
                // Save the task's updated head back.
                let mut off = 0;
                for &(a, b) in &head_entries {
                    members[mi].head[off..off + (b - a)].copy_from_slice(&params[a..b]);
                    off += b - a;
                }
            }
        }
        self.traces.lock().unwrap().push(("mtl_group".to_string(), trace));
        // Materialize per-member checkpoints: shared backbone + own head.
        let out = members
            .iter()
            .map(|m| {
                let mut flat = params.clone();
                let mut off = 0;
                for &(a, b) in &head_entries {
                    flat[a..b].copy_from_slice(&m.head[off..off + (b - a)]);
                    off += b - a;
                }
                Checkpoint { arch: arch.to_string(), flat }
            })
            .collect();
        Ok(out)
    }
}

// ---------------------------------------------------------------------------
// CAS-backed checkpoint store (delta-compresses against previous versions)
// ---------------------------------------------------------------------------
/// [`CheckpointStore`] over the content-addressed [`Store`]. `Send +
/// Sync` by composition (every field is a shared reference to a
/// thread-safe value), so one instance serves all cascade workers.
pub struct CasCheckpointStore<'a> {
    pub store: &'a Store,
    pub zoo: &'a crate::checkpoint::ModelZoo,
    pub kernel: &'a (dyn DeltaKernel + Sync),
    /// None => raw storage (hash-dedup only).
    pub compress: Option<CompressConfig>,
    /// Shared resolved-tensor cache: concurrent loads reuse each other's
    /// materialized delta-chain ancestors instead of re-decoding them.
    pub cache: Option<&'a ResolveCache>,
}

impl<'a> CheckpointStore for CasCheckpointStore<'a> {
    fn load(&self, stored: &StoredModel) -> Result<Checkpoint> {
        match self.cache {
            Some(cache) => {
                delta::load_with_cache(self.store, self.zoo, stored, self.kernel, cache)
            }
            None => delta::load(self.store, self.zoo, stored, self.kernel),
        }
    }

    fn save(
        &self,
        ck: &Checkpoint,
        prev: Option<(&StoredModel, &Checkpoint)>,
    ) -> Result<StoredModel> {
        let spec = self.zoo.arch(&ck.arch)?;
        match (self.compress, prev) {
            (Some(cfg), Some((pm, pck))) if pck.arch == ck.arch => {
                let cand = delta::prepare_delta(
                    self.store, spec, ck, spec, pck, pm, cfg, self.kernel,
                )?;
                if cand.report.stored_bytes < cand.report.raw_bytes {
                    delta::commit(self.store, &cand)?;
                    return Ok(cand.model);
                }
                Ok(delta::store_raw(self.store, spec, ck)?.0)
            }
            _ => Ok(delta::store_raw(self.store, spec, ck)?.0),
        }
    }
}

#[cfg(test)]
mod tests {
    // Trainer requires compiled artifacts; end-to-end coverage lives in
    // rust/tests/ (integration) — here we test the pure helpers.
    use super::*;
    use crate::checkpoint::testutil::tiny_zoo;

    #[test]
    fn average_checks_arity_and_arch() {
        let zoo = tiny_zoo();
        let spec = zoo.arch("t0").unwrap();
        let a = Checkpoint::init(spec, 1);
        let b = Checkpoint::init(spec, 2);
        let avg = average_checkpoints("t0", &[a.clone(), b.clone()]).unwrap();
        for i in 0..avg.flat.len() {
            assert!((avg.flat[i] - (a.flat[i] + b.flat[i]) / 2.0).abs() < 1e-7);
        }
        assert!(average_checkpoints("t0", &[]).is_err());
        let other = Checkpoint { arch: "x".into(), flat: a.flat.clone() };
        assert!(average_checkpoints("t0", &[a, other]).is_err());
    }
}
