//! The typed operations API — MGit as a library first.
//!
//! Every repository operation is a **request struct** (its parameters)
//! executed against an open [`Repo`] session, returning a **typed,
//! serializable report** — never printed text:
//!
//! | request                  | report                    | needs                |
//! |--------------------------|---------------------------|----------------------|
//! | [`InitRequest`]          | [`InitReport`]            | —                    |
//! | [`LogRequest`]           | [`LogReport`]             | `&Repo`              |
//! | [`LogPageRequest`]       | [`LogPageReport`]         | `&Repo`              |
//! | [`ShowRequest`]          | [`ShowReport`]            | `&Repo`              |
//! | [`SynthGraphRequest`]    | [`SynthGraphReport`]      | —                    |
//! | [`StatsRequest`]         | [`StatsReport`]           | `&Repo`              |
//! | [`FsckRequest`]          | [`FsckReport`]            | `&Repo`              |
//! | [`VerifyPackRequest`]    | [`VerifyPackReport`]      | `&Repo`              |
//! | [`GcRequest`]            | [`GcReport`]              | `&Repo`              |
//! | [`RepackRequest`]        | [`RepackReport`]          | `&mut Repo`          |
//! | [`CompressRequest`]      | [`CompressReport`]        | `&mut Repo` + zoo    |
//! | [`DiffRequest`]          | [`DiffReport`]            | `&Repo` + zoo        |
//! | [`MergeRequest`]         | [`MergeReport`]           | `&mut Repo` + zoo    |
//! | [`BuildRequest`]         | [`BuildReport`]           | `&mut Repo` + runtime|
//! | [`TestRequest`]          | [`TestReport`]            | `&Repo` + backend    |
//! | [`CascadeRequest`]       | [`CascadeReport`]         | repo root + runtime  |
//! | [`AutoInsertRequest`]    | [`AutoInsertReport`]      | `&Repo` + runtime    |
//! | [`GraphPackRequest`]     | [`GraphPackReport`]       | `&Repo`              |
//! | [`RemoteSetRequest`]     | [`RemoteSetReport`]       | repo root            |
//! | [`RemoteGetRequest`]     | [`RemoteGetReport`]       | repo root            |
//! | [`FetchRequest`]         | [`FetchReport`]           | `&mut Repo`          |
//! | [`PushRequest`]          | [`PushReport`]            | `&Repo`              |
//! | [`serve::Server`]        | [`serve::ServeReport`]    | `Repo` (owned)       |
//!
//! Reports implement [`Report`]: `to_json()` for machine consumers (the
//! CLI's `--json`, the [`serve`] HTTP tier, golden tests) and `Display`
//! ([`render`]) for humans. Operation *logic* lives here;
//! [`crate::cli`] only parses argv, builds a request, runs it, and
//! renders the report — so every command is equally reachable from
//! Rust code, the command line, and HTTP.

pub mod exec;
pub mod integrity;
pub mod maintain;
pub mod model;
pub mod query;
pub mod remote;
pub mod render;
mod repo;
pub mod serve;
pub mod synth;

pub use exec::{
    merge_graphs, AutoInsertReport, AutoInsertRequest, BuildReport, BuildRequest,
    CascadeReport, CascadeRequest, TestReport, TestRequest, TestResult,
};
pub use integrity::{
    FsckProblem, FsckReport, FsckRequest, GcReport, GcRequest, PackCheck, VerifyPackReport,
    VerifyPackRequest,
};
pub use maintain::{
    CompressReport, CompressRequest, GraphPackReport, GraphPackRequest, RepackReport,
    RepackRequest,
};
pub use model::{DiffReport, DiffRequest, MergeReport, MergeRequest};
pub use query::{
    LogNode, LogPageReport, LogPageRequest, LogReport, LogRequest, PackGeneration, ShowReport,
    ShowRequest, StatsReport, StatsRequest, TierInfo,
};
pub use remote::{
    FetchReport, FetchRequest, PushReport, PushRequest, RemoteGetReport, RemoteGetRequest,
    RemoteSetReport, RemoteSetRequest,
};
pub use repo::{InitReport, InitRequest, Repo};
pub use synth::{SynthGraphReport, SynthGraphRequest};

use crate::util::json::Json;

/// Implemented by every operation report: a machine-consumable JSON
/// form plus human rendering (via `Display`, see [`render`]).
pub trait Report: std::fmt::Display {
    /// Serialize the report (stable field order; golden-testable).
    fn to_json(&self) -> Json;

    /// When the operation *ran* but found problems that must fail the
    /// process (fsck corruption, failing tests, bad packs), the message
    /// to exit nonzero with. `None` = success.
    fn failure(&self) -> Option<String> {
        None
    }
}
