//! Read-path query operations: `log`, `show`, `stats`.

use anyhow::Result;

use crate::lineage::{GraphStore, LineageGraph, Node};
use crate::store::{ObjectId, Store};
use crate::util::json::Json;

use super::{Report, Repo};

// ---------------------------------------------------------------------------
// log
// ---------------------------------------------------------------------------

/// `mgit log`: list every node with its edges and versions.
pub struct LogRequest;

/// One node row in a [`LogReport`].
pub struct LogNode {
    pub name: String,
    pub model_type: String,
    /// Whether the node has a stored checkpoint in the CAS.
    pub stored: bool,
    /// Creation-function kind (`pretrain`, `finetune`, …), if registered.
    pub creation: Option<String>,
    /// Provenance parents, by name.
    pub prov_parents: Vec<String>,
}

/// Typed result of [`LogRequest`].
pub struct LogReport {
    pub nodes: Vec<LogNode>,
    pub prov_edges: usize,
    pub ver_edges: usize,
}

impl LogRequest {
    pub fn run(&self, repo: &Repo) -> Result<LogReport> {
        self.run_graph(&repo.graph)
    }

    /// Graph-level entry point: the serving tier runs `log` against an
    /// immutable snapshot graph rather than a whole [`Repo`] session.
    pub fn run_graph(&self, graph: &LineageGraph) -> Result<LogReport> {
        let (prov, ver) = graph.edge_counts();
        let nodes = graph
            .nodes
            .iter()
            .map(|node| LogNode {
                name: node.name.clone(),
                model_type: node.model_type.clone(),
                stored: node.stored.is_some(),
                creation: node.creation.as_ref().map(|c| c.kind().to_string()),
                prov_parents: node
                    .prov_parents
                    .iter()
                    .map(|&p| graph.node(p).name.clone())
                    .collect(),
            })
            .collect();
        Ok(LogReport { nodes, prov_edges: prov, ver_edges: ver })
    }
}

impl LogNode {
    /// One row from a decoded node, resolving parent names through the
    /// seam (one body decode per parent on a mapped graph).
    fn from_node(graph: &GraphStore, node: &Node) -> Result<LogNode> {
        Ok(LogNode {
            name: node.name.clone(),
            model_type: node.model_type.clone(),
            stored: node.stored.is_some(),
            creation: node.creation.as_ref().map(|c| c.kind().to_string()),
            prov_parents: node
                .prov_parents
                .iter()
                .map(|&p| graph.name_of(p))
                .collect::<Result<_>>()?,
        })
    }

    /// The per-node JSON shape. Shared by [`LogReport`] and
    /// [`LogPageReport`] so paginated pages are byte-identical to the
    /// corresponding full-log slices.
    fn to_json(&self) -> Json {
        Json::obj()
            .set("name", self.name.as_str())
            .set("model_type", self.model_type.as_str())
            .set("stored", self.stored)
            .set(
                "creation",
                self.creation.as_deref().map(Json::from).unwrap_or(Json::Null),
            )
            .set(
                "prov_parents",
                Json::Arr(
                    self.prov_parents.iter().map(|p| Json::from(p.as_str())).collect(),
                ),
            )
    }
}

impl Report for LogReport {
    fn to_json(&self) -> Json {
        let nodes: Vec<Json> = self.nodes.iter().map(LogNode::to_json).collect();
        Json::obj()
            .set("nodes", Json::Arr(nodes))
            .set("prov_edges", self.prov_edges)
            .set("ver_edges", self.ver_edges)
    }
}

// ---------------------------------------------------------------------------
// log (paginated)
// ---------------------------------------------------------------------------

/// `mgit log --limit N [--after NAME] [--type T]` and HTTP
/// `/log?limit&after&type`: one page of the log, walking the graph
/// index without materializing the full node set — page latency is
/// independent of total node count on a binary (mapped) graph.
pub struct LogPageRequest {
    /// Maximum rows in the page (clamped to at least 1).
    pub limit: usize,
    /// Resume cursor: the last node name of the previous page; the
    /// page starts at the node after it. Errors if the name is absent.
    pub after: Option<String>,
    /// Only include nodes of this model type.
    pub model_type: Option<String>,
}

/// Typed result of [`LogPageRequest`].
pub struct LogPageReport {
    pub nodes: Vec<LogNode>,
    /// Total node count (all pages, unfiltered).
    pub total: usize,
    /// Cursor for the next page; `None` when this page reached the end
    /// of the graph.
    pub next_after: Option<String>,
}

impl LogPageRequest {
    pub fn run(&self, repo: &Repo) -> Result<LogPageReport> {
        self.run_store(&repo.graph)
    }

    /// Seam-level entry point: on a mapped binary graph this decodes
    /// only the visited nodes (plus one name per parent edge).
    pub fn run_store(&self, graph: &GraphStore) -> Result<LogPageReport> {
        let total = graph.len();
        let limit = self.limit.max(1);
        let mut i = match &self.after {
            Some(name) => graph.idx(name)? + 1,
            None => 0,
        };
        let mut nodes = Vec::new();
        while i < total && nodes.len() < limit {
            let node = graph.node_owned(i)?;
            if self
                .model_type
                .as_deref()
                .is_none_or(|t| t == node.model_type)
            {
                nodes.push(LogNode::from_node(graph, &node)?);
            }
            i += 1;
        }
        // The page filled before the end: the last collected row is at
        // index i-1, so resuming after it continues exactly at i.
        let next_after =
            if i < total { nodes.last().map(|n| n.name.clone()) } else { None };
        Ok(LogPageReport { nodes, total, next_after })
    }
}

impl Report for LogPageReport {
    fn to_json(&self) -> Json {
        let nodes: Vec<Json> = self.nodes.iter().map(LogNode::to_json).collect();
        Json::obj()
            .set("nodes", Json::Arr(nodes))
            .set("total", self.total)
            .set(
                "next_after",
                self.next_after.as_deref().map(Json::from).unwrap_or(Json::Null),
            )
    }
}

// ---------------------------------------------------------------------------
// show
// ---------------------------------------------------------------------------

/// `mgit show <node>`: one node's details.
pub struct ShowRequest {
    pub node: String,
}

/// Typed result of [`ShowRequest`].
pub struct ShowReport {
    pub name: String,
    pub model_type: String,
    /// The serialized creation spec, if registered.
    pub creation: Option<Json>,
    /// Free-form node metadata.
    pub metadata: Json,
    /// (parameter name, full content-id hex) pairs, layout order.
    pub params: Vec<(String, String)>,
}

impl ShowRequest {
    pub fn run(&self, repo: &Repo) -> Result<ShowReport> {
        self.run_store(&repo.graph)
    }

    /// Seam-level entry point: one node decode, no materialization on
    /// a mapped binary graph.
    pub fn run_store(&self, graph: &GraphStore) -> Result<ShowReport> {
        Ok(Self::report_for(&graph.node_by_name(&self.node)?))
    }

    /// Graph-level entry point (see [`LogRequest::run_graph`]).
    pub fn run_graph(&self, graph: &LineageGraph) -> Result<ShowReport> {
        Ok(Self::report_for(graph.by_name(&self.node)?))
    }

    fn report_for(node: &Node) -> ShowReport {
        let params = node
            .stored
            .as_ref()
            .map(|sm| {
                sm.params
                    .iter()
                    .map(|(name, id)| (name.clone(), id.hex()))
                    .collect()
            })
            .unwrap_or_default();
        ShowReport {
            name: node.name.clone(),
            model_type: node.model_type.clone(),
            creation: node.creation.as_ref().map(|c| c.to_json()),
            metadata: node.metadata.clone(),
            params,
        }
    }
}

impl Report for ShowReport {
    fn to_json(&self) -> Json {
        Json::obj()
            .set("name", self.name.as_str())
            .set("model_type", self.model_type.as_str())
            .set("creation", self.creation.clone().unwrap_or(Json::Null))
            .set("metadata", self.metadata.clone())
            .set(
                "params",
                Json::Arr(
                    self.params
                        .iter()
                        .map(|(n, id)| {
                            Json::obj().set("name", n.as_str()).set("id", id.as_str())
                        })
                        .collect(),
                ),
            )
    }
}

// ---------------------------------------------------------------------------
// stats
// ---------------------------------------------------------------------------

/// `mgit stats`: object-store statistics.
pub struct StatsRequest;

/// One pack generation in a [`StatsReport`] (mtime-ordered; gen 0 is the
/// oldest).
pub struct PackGeneration {
    pub generation: usize,
    pub objects: usize,
    pub bytes: u64,
    /// Pack format version (1 = legacy, 2 = framed + index metadata).
    pub version: u8,
    /// Outer framing (`raw`/`zstd`).
    pub framing: &'static str,
    /// Deepest delta chain recorded in this pack's index metadata at
    /// pack time (`None` for v1 packs, which persist none) — read
    /// straight from the index, no pack bytes touched. A high value on
    /// an old generation is a hint that `repack --full` would shorten
    /// chains.
    pub max_depth: Option<u32>,
    pub name: String,
}

/// Remote-tier state in a [`StatsReport`] (present when the store reads
/// through a configured origin; see `store::tiered`).
pub struct TierInfo {
    /// Origin endpoint from `.mgit/remote`.
    pub url: String,
    /// Byte budget for evictable read-through fills (`None` = unbounded).
    pub hot_budget: Option<u64>,
    /// Whether cold fills prefetch the delta-parent chain.
    pub prefetch: bool,
    /// Bytes currently held by evictable fills (this process's view).
    pub fill_resident_bytes: u64,
}

/// Typed result of [`StatsRequest`].
pub struct StatsReport {
    pub objects: usize,
    pub loose: usize,
    pub packed: usize,
    /// Pack reader implementation (`mmap`, `pread`, …); None if no packs.
    pub reader_kind: Option<&'static str>,
    pub packs: Vec<PackGeneration>,
    pub delta_objects: usize,
    pub stored_bytes: u64,
    pub logical_bytes: u64,
    /// Cumulative counters persisted across invocations.
    pub puts: u64,
    pub dedup_hits: u64,
    pub bytes_written: u64,
    pub chain_max: usize,
    pub chain_mean: f64,
    /// (bucket label, object count), non-empty buckets only.
    pub depth_buckets: Vec<(String, usize)>,
    /// Objects whose metadata required reading object bytes (loose
    /// objects, plus packed entries whose index predates persisted
    /// numel). 0 means the whole report came from pack indexes alone.
    pub meta_fallback: usize,
    /// Remote-tier state; `None` for purely local repositories.
    pub tier: Option<TierInfo>,
}

impl StatsRequest {
    pub fn run(&self, repo: &Repo) -> Result<StatsReport> {
        self.run_on(&repo.root, &repo.store)
    }

    /// Store-level entry point: `stats` never reads the graph, so the
    /// serving tier can run it against a snapshot's shared store (plus
    /// the repo root, for the persisted cumulative counters).
    pub fn run_on(&self, root: &std::path::Path, store: &Store) -> Result<StatsReport> {
        let objects = store.list()?;
        let bytes = store.stored_bytes()?;
        let mut raw_bytes: u64 = 0;
        let mut delta_objs = 0usize;
        let mut meta_fallback = 0usize;
        // One metadata pass feeds both the byte accounting and (via the
        // parent map) the chain-depth histogram below. v3 pack indexes
        // persist each tensor's numel, so packed objects are answered from
        // pure index metadata — zero object reads, zero payload decodes.
        // Only loose objects and v2-index entries (which predate persisted
        // numel) fall back to reading bytes for a header parse; those are
        // counted in `meta_fallback`.
        let mut parents: std::collections::HashMap<ObjectId, Option<ObjectId>> =
            Default::default();
        for id in &objects {
            let meta = store.object_meta(id)?;
            if !meta.from_index {
                meta_fallback += 1; // loose: header parse read the bytes
            }
            let numel = match meta.numel {
                Some(n) => Some(n),
                None if meta.from_index
                    && meta.kind != crate::store::format::ObjectKind::Opaque =>
                {
                    // v2 index entry (kind/parent but no numel persisted):
                    // one header parse of the object bytes.
                    meta_fallback += 1;
                    crate::store::format::TensorObject::decode_meta(&store.get(id)?)
                        .numel
                }
                None => None, // opaque blob: no logical tensor bytes
            };
            if let Some(n) = numel {
                raw_bytes += n * 4;
            }
            if meta.kind == crate::store::format::ObjectKind::Delta {
                delta_objs += 1;
            }
            parents.insert(*id, meta.parent);
        }
        let (loose, packed) = match store.as_packed() {
            Some(ps) => ps.counts()?,
            None => (objects.len(), 0),
        };
        // Per-pack generation info: incremental repacks append packs over
        // time; sort by file mtime so "gen 0" is the oldest.
        let mut reader_kind = None;
        let mut packs = Vec::new();
        if let Some(ps) = store.as_packed() {
            if !ps.packs().is_empty() {
                let mut gens: Vec<_> = ps
                    .packs()
                    .iter()
                    .map(|p| {
                        let mtime = std::fs::metadata(&p.path)
                            .and_then(|m| m.modified())
                            .unwrap_or(std::time::SystemTime::UNIX_EPOCH);
                        (mtime, p)
                    })
                    .collect();
                gens.sort_by_key(|(t, _)| *t);
                reader_kind = Some(gens[0].1.reader_kind());
                for (generation, (_, p)) in gens.iter().enumerate() {
                    let name = p
                        .path
                        .file_name()
                        .map(|n| n.to_string_lossy().into_owned())
                        .unwrap_or_else(|| p.path.display().to_string());
                    // v2+ indexes carry a depth per entry; v1 carry none.
                    let max_depth = (p.index.version
                        >= crate::store::pack::IDX_VERSION_2)
                        .then(|| {
                            p.index
                                .entries
                                .iter()
                                .filter_map(|e| e.meta.map(|m| m.depth))
                                .max()
                                .unwrap_or(0)
                        });
                    packs.push(PackGeneration {
                        generation,
                        objects: p.object_count(),
                        bytes: p.size_bytes(),
                        version: p.version,
                        framing: p.framing.name(),
                        max_depth,
                        name,
                    });
                }
            }
        }
        // Cumulative dedup counters (persisted across invocations).
        let (puts, dedup, written) = Repo::load_stats(root);
        // Delta-chain depths (reconstruction cost driver; docs/STORAGE.md).
        let depths = crate::store::pack::chain_depths_from_parents(&parents)?;
        let chain_max = depths.values().copied().max().unwrap_or(0);
        let chain_lens: Vec<usize> = depths.values().copied().filter(|&d| d > 0).collect();
        let chain_mean = if chain_lens.is_empty() {
            0.0
        } else {
            chain_lens.iter().sum::<usize>() as f64 / chain_lens.len() as f64
        };
        let buckets: [(usize, usize, &str); 6] = [
            (0, 0, "0 (base)"),
            (1, 2, "1-2"),
            (3, 4, "3-4"),
            (5, 8, "5-8"),
            (9, 16, "9-16"),
            (17, usize::MAX, "17+"),
        ];
        let mut depth_buckets = Vec::new();
        for (lo, hi, label) in buckets {
            let n = depths.values().filter(|&&d| d >= lo && d <= hi).count();
            if n > 0 {
                depth_buckets.push((label.to_string(), n));
            }
        }
        // Tier state (a tiered store's `list`/`stored_bytes` above are
        // hot-tier-only, so everything in this report is local — the
        // tier block says where misses would read through to).
        let tier = store.as_tiered().map(|t| TierInfo {
            url: t.remote().url().to_string(),
            hot_budget: t.hot_budget(),
            prefetch: t.prefetch_enabled(),
            fill_resident_bytes: t.fill_resident_bytes(),
        });
        Ok(StatsReport {
            objects: objects.len(),
            loose,
            packed,
            reader_kind,
            packs,
            delta_objects: delta_objs,
            stored_bytes: bytes,
            logical_bytes: raw_bytes,
            puts,
            dedup_hits: dedup,
            bytes_written: written,
            chain_max,
            chain_mean,
            depth_buckets,
            meta_fallback,
            tier,
        })
    }
}

impl StatsReport {
    /// `logical / stored` (0.0 when nothing is stored).
    pub fn compression_ratio(&self) -> f64 {
        if self.stored_bytes > 0 {
            self.logical_bytes as f64 / self.stored_bytes as f64
        } else {
            0.0
        }
    }

    /// Dedup hit rate in percent (0.0 with no puts).
    pub fn dedup_hit_rate(&self) -> f64 {
        if self.puts > 0 {
            100.0 * self.dedup_hits as f64 / self.puts as f64
        } else {
            0.0
        }
    }
}

impl Report for StatsReport {
    fn to_json(&self) -> Json {
        let packs: Vec<Json> = self
            .packs
            .iter()
            .map(|p| {
                Json::obj()
                    .set("generation", p.generation)
                    .set("objects", p.objects)
                    .set("bytes", p.bytes)
                    .set("version", p.version as usize)
                    .set("framing", p.framing)
                    .set(
                        "max_depth",
                        p.max_depth.map(|d| Json::from(d as usize)).unwrap_or(Json::Null),
                    )
                    .set("name", p.name.as_str())
            })
            .collect();
        Json::obj()
            .set("objects", self.objects)
            .set("loose", self.loose)
            .set("packed", self.packed)
            .set(
                "reader_kind",
                self.reader_kind.map(Json::from).unwrap_or(Json::Null),
            )
            .set("packs", Json::Arr(packs))
            .set("delta_objects", self.delta_objects)
            .set("stored_bytes", self.stored_bytes)
            .set("logical_bytes", self.logical_bytes)
            .set("compression_ratio", self.compression_ratio())
            .set("puts", self.puts)
            .set("dedup_hits", self.dedup_hits)
            .set("bytes_written", self.bytes_written)
            .set("chain_max", self.chain_max)
            .set("chain_mean", self.chain_mean)
            .set("meta_fallback", self.meta_fallback)
            .set(
                "tier",
                self.tier
                    .as_ref()
                    .map(|t| {
                        Json::obj()
                            .set("url", t.url.as_str())
                            .set(
                                "hot_budget",
                                t.hot_budget.map(Json::from).unwrap_or(Json::Null),
                            )
                            .set("prefetch", t.prefetch)
                            .set("fill_resident_bytes", t.fill_resident_bytes)
                    })
                    .unwrap_or(Json::Null),
            )
            .set(
                "depth_buckets",
                Json::Arr(
                    self.depth_buckets
                        .iter()
                        .map(|(label, n)| {
                            Json::obj().set("depth", label.as_str()).set("objects", *n)
                        })
                        .collect(),
                ),
            )
    }
}
