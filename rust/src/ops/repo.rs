//! The on-disk repository session every operation executes against.
//!
//! A repository is a directory containing `.mgit/graph.json` (lineage
//! graph + test registry, re-serialized after every mutating operation,
//! matching §3.1) and `.mgit/objects/` (the content-addressed store:
//! loose staging fan-out plus `pack/*.pack` pack files — see
//! `docs/STORAGE.md`). [`Repo`] bundles the two behind open/save
//! bookkeeping; the typed operations in [`crate::ops`] take a `&Repo`
//! (read path) or `&mut Repo` (mutating path).

use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Result};

use crate::checkpoint::Checkpoint;
use crate::delta::{self, DeltaKernel};
use crate::lineage::{GraphStore, LineageGraph};
use crate::store::{wal, ObjectId, Store};
use crate::util::json::Json;

use super::Report;

/// An on-disk MGit repository.
///
/// `graph` is a [`GraphStore`]: the v0 `graph.json` is parsed eagerly
/// as before, while a binary `graph.bin` (MGGI) repo is only *mapped*
/// here — node bodies and adjacency are decoded on demand, and the
/// full in-memory graph materializes on first whole-graph access
/// (auto-deref keeps every `repo.graph.…` call site working).
pub struct Repo {
    pub root: PathBuf,
    pub graph: GraphStore,
    pub store: Store,
}

impl Repo {
    pub fn mgit_dir(root: &Path) -> PathBuf {
        root.join(".mgit")
    }

    pub fn graph_path(root: &Path) -> PathBuf {
        Self::mgit_dir(root).join("graph.json")
    }

    /// The binary (MGGI) graph index. When present it is authoritative
    /// and `graph.json` is ignored.
    pub fn graph_bin_path(root: &Path) -> PathBuf {
        Self::mgit_dir(root).join("graph.bin")
    }

    fn stats_path(root: &Path) -> PathBuf {
        Self::mgit_dir(root).join("stats.json")
    }

    pub fn init(root: &Path) -> Result<Repo> {
        let dir = Self::mgit_dir(root);
        if Self::graph_path(root).exists() {
            bail!("repository already initialized at {}", dir.display());
        }
        std::fs::create_dir_all(&dir)?;
        let store = Store::open_packed(&dir.join("objects"))?;
        let graph = LineageGraph::new();
        graph.save(&Self::graph_path(root))?;
        Ok(Repo { root: root.to_path_buf(), graph: GraphStore::from_graph(graph), store })
    }

    /// De-serialize at the start of an operation (paper §3.1). The store
    /// is pack-capable: loose staging first, then pack indexes. When
    /// `.mgit/remote` is configured (`mgit remote set <url>`), the store
    /// opens *tiered* instead: same local layout as the hot tier, with
    /// misses read through to the configured origin
    /// (see `store::tiered`). Opening never dials the origin, so a repo
    /// whose origin is down still serves everything it holds hot.
    ///
    /// If a writable server left a write-ahead log behind (crash, or
    /// simply commits since the last checkpoint), its durable prefix is
    /// replayed here: WAL-carried objects are re-put (dedup makes this
    /// write-free after the first materialization) and commit records
    /// are re-applied to the in-memory graph (idempotent). The log file
    /// itself is never modified on open — only a writable server
    /// truncates it, after folding it into `graph.json`. A torn tail is
    /// warned about here and diagnosed as a problem by `mgit fsck`.
    pub fn open(root: &Path) -> Result<Repo> {
        let mgit = Self::mgit_dir(root);
        let mut graph = GraphStore::open(&mgit)?;
        let objects = mgit.join("objects");
        let store = match crate::store::remote::RemoteConfig::load(&mgit)? {
            Some(cfg) => Store::open_tiered(&objects, &cfg)?,
            None => Store::open_packed(&objects)?,
        };
        let wal_file = wal::wal_path(root);
        if wal_file.exists() {
            let scan = wal::scan(&wal_file)?;
            if let Some(t) = &scan.torn {
                eprintln!(
                    "warning: {} has a torn tail at byte {} ({}); recovering the durable prefix ({} commits)",
                    wal_file.display(),
                    t.offset,
                    t.reason,
                    scan.commits
                );
            }
            let mut replayed = 0u64;
            for rec in &scan.records {
                match rec {
                    wal::WalRecord::Put { id, bytes } => {
                        store.put(*id, bytes)?;
                    }
                    wal::WalRecord::Commit { op } => {
                        // Materializes a mapped graph on the first
                        // commit record — replay needs the full image.
                        graph.full_mut()?.apply_commit(op)?;
                    }
                }
                replayed += 1;
            }
            wal::WAL_REPLAYS.add(replayed);
        }
        Ok(Repo { root: root.to_path_buf(), graph, store })
    }

    /// Serialize at the end of every operation (paper §3.1); also folds
    /// this process's store counters into the persistent cumulative
    /// stats that `mgit stats` reports.
    pub fn save(&self) -> Result<()> {
        self.graph.persist(&Self::mgit_dir(&self.root))?;
        self.persist_stats()
    }

    /// Cumulative (puts, dedup_hits, bytes_written) since `init`.
    ///
    /// A missing `stats.json` is a fresh repository: zeros. A *corrupt*
    /// one is never silently discarded — the unreadable file is
    /// preserved as `stats.json.corrupt`, a warning goes to stderr, and
    /// counting restarts from zero (the next `persist_stats` writes a
    /// fresh file).
    pub fn load_stats(root: &Path) -> (u64, u64, u64) {
        let path = Self::stats_path(root);
        if !path.exists() {
            return (0, 0, 0);
        }
        let read = || -> Result<(u64, u64, u64)> {
            let text = std::fs::read_to_string(&path)?;
            let j = crate::util::json::parse(&text)?;
            Ok((
                j.req_usize("puts")? as u64,
                j.req_usize("dedup_hits")? as u64,
                j.req_usize("bytes_written")? as u64,
            ))
        };
        match read() {
            Ok(t) => t,
            Err(e) => {
                let corrupt = path.with_extension("json.corrupt");
                let kept = std::fs::rename(&path, &corrupt).is_ok();
                eprintln!(
                    "warning: {} is unreadable ({e:#}); cumulative dedup counters reset{}",
                    path.display(),
                    if kept {
                        format!(" (old file preserved as {})", corrupt.display())
                    } else {
                        String::new()
                    }
                );
                (0, 0, 0)
            }
        }
    }

    /// Drain the in-process store counters into `.mgit/stats.json`.
    /// Single-writer, like `graph.json`: operations are per-invocation.
    pub fn persist_stats(&self) -> Result<()> {
        let (puts, dedup, written) = self.store.stats.take();
        if puts == 0 && dedup == 0 && written == 0 {
            return Ok(());
        }
        let (p0, d0, w0) = Self::load_stats(&self.root);
        let j = Json::obj()
            .set("puts", (p0 + puts) as usize)
            .set("dedup_hits", (d0 + dedup) as usize)
            .set("bytes_written", (w0 + written) as usize);
        let path = Self::stats_path(&self.root);
        let write = || -> Result<()> {
            let tmp = path.with_extension("json.tmp");
            std::fs::write(&tmp, j.to_string_pretty())?;
            std::fs::rename(&tmp, &path)?;
            Ok(())
        };
        let res = write();
        if res.is_err() {
            // Don't lose the drained counts on a failed write; they'll
            // ride along with the next successful persist.
            use std::sync::atomic::Ordering;
            self.store.stats.puts.fetch_add(puts, Ordering::Relaxed);
            self.store.stats.dedup_hits.fetch_add(dedup, Ordering::Relaxed);
            self.store.stats.bytes_written.fetch_add(written, Ordering::Relaxed);
        }
        res
    }

    pub fn load_checkpoint(
        &self,
        node: &str,
        kernel: &dyn DeltaKernel,
        zoo: &crate::checkpoint::ModelZoo,
    ) -> Result<Checkpoint> {
        // One lazy node decode — loading a checkpoint from a mapped
        // graph never materializes the node set.
        let n = self.graph.node_by_name(node)?;
        let sm = n
            .stored
            .as_ref()
            .ok_or_else(|| anyhow!("node {node} has no stored checkpoint"))?;
        delta::load(&self.store, zoo, sm, kernel)
    }

    /// GC roots: every stored model referenced by the graph. Delta-parent
    /// references are strong and walked transitively; GC aborts rather
    /// than sweep if a live object is unreadable.
    pub fn gc(&self) -> Result<Vec<ObjectId>> {
        // Streamed through the seam: one node resident at a time on a
        // mapped graph.
        let mut roots = Vec::new();
        self.graph.each_node(&mut |_, n| {
            if let Some(sm) = &n.stored {
                roots.extend(sm.refs());
            }
            Ok(())
        })?;
        self.store.gc(&roots, |bytes| {
            crate::store::format::TensorObject::decode(bytes)
                .map(|o| o.refs())
                .unwrap_or_default()
        })
    }
}

/// `mgit init`: create an empty repository.
pub struct InitRequest;

/// Outcome of [`InitRequest`].
pub struct InitReport {
    /// The `.mgit` directory that was created.
    pub mgit_dir: String,
}

impl InitRequest {
    pub fn run(&self, root: &Path) -> Result<InitReport> {
        Repo::init(root)?;
        Ok(InitReport { mgit_dir: Repo::mgit_dir(root).display().to_string() })
    }
}

impl Report for InitReport {
    fn to_json(&self) -> Json {
        Json::obj().set("initialized", self.mgit_dir.as_str())
    }
}
