//! Store-maintenance operations: `repack`, `compress`, `graph pack`.

use anyhow::Result;

use crate::checkpoint::{Checkpoint, ModelZoo};
use crate::delta::{self, CompressConfig, DeltaKernel, NativeKernel};
use crate::lineage::traversal;
use crate::store::pack::{PackFraming, RepackConfig, RepackMode};
use crate::util::json::Json;
use crate::util::timing::Timer;

use super::{Report, Repo};

// ---------------------------------------------------------------------------
// repack
// ---------------------------------------------------------------------------

/// `mgit repack`: migrate loose objects into packs (incrementally by
/// default; [`RepackMode::Full`] rewrites every pack), re-basing long
/// delta chains onto nearer ancestors.
pub struct RepackRequest {
    pub max_chain_depth: usize,
    /// Drop unreachable objects while repacking.
    pub prune: bool,
    pub mode: RepackMode,
    /// Promote an incremental run to a full rewrite past this many pack
    /// generations (None disables).
    pub max_generations: Option<usize>,
    /// Promote an incremental run to a full rewrite once this fraction
    /// of sealed pack bytes is dead (None disables; needs `prune`).
    pub max_dead_ratio: Option<f64>,
    /// Outer framing of the pack this run writes (`--framing raw|zstd`;
    /// zstd needs the feature-gated dependency).
    pub framing: PackFraming,
    /// Keep loose copies of newly packed objects (`--keep-loose`). The
    /// writable serving tier repacks live with this on so readers still
    /// holding a pre-repack store snapshot keep resolving.
    pub keep_loose: bool,
    /// Similarity-driven delta base selection threshold
    /// (`--similarity <t>`, None disables; see `docs/COMPRESSION.md`).
    pub similarity: Option<f64>,
    /// Minimum fractional saving a delta must achieve over raw bytes
    /// (`--min-savings`, only consulted with `similarity` on).
    pub min_savings: f64,
    /// Write the new pack in chunked v3 format with cross-object chunk
    /// dedup (`--chunk-dedup`; implied by `--similarity`).
    pub chunk_dedup: bool,
}

impl Default for RepackRequest {
    fn default() -> Self {
        RepackRequest {
            max_chain_depth: 8,
            prune: false,
            mode: RepackMode::Incremental,
            max_generations: Some(16),
            max_dead_ratio: Some(0.5),
            framing: PackFraming::Raw,
            keep_loose: false,
            similarity: None,
            min_savings: 0.1,
            chunk_dedup: false,
        }
    }
}

/// Typed result of [`RepackRequest`]: the storage-layer report plus the
/// effective mode and wall-clock time.
pub struct RepackReport {
    pub pack: crate::store::pack::RepackReport,
    /// `full`, `incremental`, or `incremental -> full: <reason>`.
    pub mode_label: String,
    pub elapsed_secs: f64,
}

impl RepackRequest {
    pub fn run(&self, repo: &mut Repo) -> Result<RepackReport> {
        let cfg = RepackConfig {
            max_chain_depth: self.max_chain_depth,
            prune: self.prune,
            mode: self.mode,
            max_generations: self.max_generations,
            max_dead_ratio: self.max_dead_ratio,
            framing: self.framing,
            keep_loose: self.keep_loose,
            similarity: self.similarity,
            min_savings: self.min_savings,
            chunk_dedup: self.chunk_dedup,
            ..RepackConfig::default()
        };
        let roots = repo.graph.object_roots();
        let t = Timer::start();
        // NativeKernel is the bit-compatible oracle of the Pallas kernel,
        // so re-based encodings agree across runtime backends.
        let report = crate::store::pack::repack(&mut repo.store, &roots, &cfg, &NativeKernel)?;
        repo.save()?;
        let mode_label = match (self.mode, &report.escalated) {
            (RepackMode::Full, _) => "full".to_string(),
            (RepackMode::Incremental, None) => "incremental".to_string(),
            (RepackMode::Incremental, Some(reason)) => {
                format!("incremental -> full: {reason}")
            }
        };
        Ok(RepackReport { pack: report, mode_label, elapsed_secs: t.elapsed_secs() })
    }
}

impl Report for RepackReport {
    fn to_json(&self) -> Json {
        let p = &self.pack;
        Json::obj()
            .set("mode", self.mode_label.as_str())
            .set("framing", p.framing.name())
            .set("packed", p.packed)
            .set("retained_packed", p.retained_packed)
            .set("carried_dead", p.carried_dead)
            .set("dead_ratio", p.dead_ratio)
            .set("mark_payload_decodes", p.mark_payload_decodes)
            .set("mark_meta_fallback", p.mark_meta_fallback)
            .set("packs_before", p.packs_before)
            .set("packs_after", p.packs_after)
            .set("max_depth_before", p.max_depth_before)
            .set("max_depth_after", p.max_depth_after)
            .set("rebased_delta", p.rebased_delta)
            .set("new_bases", p.new_bases)
            .set("base_rewrites", p.base_rewrites)
            .set("delta_skipped", p.delta_skipped)
            .set("chunks_shared", p.chunks_shared)
            .set("chunk_bytes_saved", p.chunk_bytes_saved)
            .set("recipes", p.recipes)
            .set("bytes_before", p.bytes_before)
            .set("bytes_after", p.bytes_after)
            .set("loose_demoted", p.loose_demoted)
            .set("pruned_loose", p.pruned_loose)
            .set(
                "pack_path",
                p.pack_path
                    .as_ref()
                    .map(|path| Json::from(path.display().to_string()))
                    .unwrap_or(Json::Null),
            )
            .set("elapsed_secs", self.elapsed_secs)
    }
}

// ---------------------------------------------------------------------------
// compress
// ---------------------------------------------------------------------------

/// `mgit compress`: re-store every model with delta compression against
/// its parent (roots-first, so parents are already re-stored when their
/// children are encoded).
pub struct CompressRequest {
    pub config: CompressConfig,
}

/// Typed result of [`CompressRequest`].
pub struct CompressReport {
    /// Raw f32 payload bytes across all re-stored models.
    pub raw_bytes: u64,
    /// Bytes of objects newly written.
    pub stored_bytes: u64,
    /// Objects swept by the post-compress GC.
    pub swept: usize,
    pub elapsed_secs: f64,
}

impl CompressRequest {
    pub fn run(
        &self,
        repo: &mut Repo,
        zoo: &ModelZoo,
        kernel: &dyn DeltaKernel,
    ) -> Result<CompressReport> {
        let cfg = self.config;
        let t = Timer::start();
        let mut raw = 0u64;
        let mut stored = 0u64;
        // Roots-first over provenance edges.
        let order: Vec<usize> = {
            let roots = repo.graph.roots();
            let mut out = Vec::new();
            for r in roots {
                out.extend(traversal::bfs(
                    &repo.graph,
                    r,
                    traversal::EdgeFilter::Both,
                    |_, _| false,
                    |_, _| false,
                ));
            }
            out
        };
        let mut rec_cache: std::collections::HashMap<usize, Checkpoint> = Default::default();
        for idx in order {
            let Some(sm) = repo.graph.node(idx).stored.clone() else { continue };
            let ck = delta::load(&repo.store, zoo, &sm, kernel)?;
            let spec = zoo.arch(&ck.arch)?;
            let parent = repo
                .graph
                .node(idx)
                .ver_parents
                .first()
                .or_else(|| repo.graph.node(idx).prov_parents.first())
                .copied();
            match parent.and_then(|p| repo.graph.node(p).stored.clone().map(|s| (p, s))) {
                Some((p, psm)) if repo.graph.node(p).model_type == ck.arch => {
                    let pck = match rec_cache.get(&p) {
                        Some(c) => c.clone(),
                        None => delta::load(&repo.store, zoo, &psm, kernel)?,
                    };
                    let (sm2, final_ck, rep, _) = delta::delta_compress_checked(
                        &repo.store,
                        spec,
                        &ck,
                        zoo.arch(&pck.arch)?,
                        &pck,
                        &psm,
                        cfg,
                        kernel,
                        |_| Ok(true),
                    )?;
                    raw += rep.raw_bytes;
                    stored += rep.stored_bytes;
                    repo.graph.node_mut(idx).stored = Some(sm2);
                    rec_cache.insert(idx, final_ck);
                }
                _ => {
                    let (sm2, rep) = delta::store_raw(&repo.store, spec, &ck)?;
                    raw += rep.raw_bytes;
                    stored += rep.stored_bytes;
                    repo.graph.node_mut(idx).stored = Some(sm2);
                    rec_cache.insert(idx, ck);
                }
            }
        }
        repo.save()?;
        let swept = repo.gc()?;
        Ok(CompressReport {
            raw_bytes: raw,
            stored_bytes: stored,
            swept: swept.len(),
            elapsed_secs: t.elapsed_secs(),
        })
    }
}

impl CompressReport {
    /// `raw / stored` (0.0 when nothing was written).
    pub fn ratio(&self) -> f64 {
        if self.stored_bytes > 0 {
            self.raw_bytes as f64 / self.stored_bytes as f64
        } else {
            0.0
        }
    }
}

impl Report for CompressReport {
    fn to_json(&self) -> Json {
        Json::obj()
            .set("raw_bytes", self.raw_bytes)
            .set("stored_bytes", self.stored_bytes)
            .set("ratio", self.ratio())
            .set("swept", self.swept)
            .set("elapsed_secs", self.elapsed_secs)
    }
}

// ---------------------------------------------------------------------------
// graph pack
// ---------------------------------------------------------------------------

/// `mgit graph pack`: explicitly convert a JSON-graph repository to the
/// binary MGGI index (`graph.bin`). Until now the binary format was
/// only produced by synthesis or the serving tier's fold path; this
/// makes the conversion a first-class, reportable operation. Running it
/// on an already-binary repo is a no-op (reported, not an error).
pub struct GraphPackRequest;

/// Typed result of [`GraphPackRequest`].
pub struct GraphPackReport {
    pub nodes: usize,
    pub prov_edges: usize,
    pub ver_edges: usize,
    /// Path of the binary index (`.mgit/graph.bin`).
    pub path: String,
    /// Size of the binary index on disk.
    pub bytes: u64,
    /// The repo was already binary; nothing was written.
    pub already_binary: bool,
    pub elapsed_secs: f64,
}

impl GraphPackRequest {
    pub fn run(&self, repo: &Repo) -> Result<GraphPackReport> {
        let t = Timer::start();
        let bin = Repo::graph_bin_path(&repo.root);
        let already_binary = repo.graph.format() == "binary" || bin.exists();
        let g = repo.graph.full()?;
        if !already_binary {
            // graph.json is left in place as a readable backup; once
            // graph.bin exists it is authoritative (see Repo::open).
            crate::lineage::binfmt::write_binary(g, &bin)?;
        }
        let (prov, ver) = g.edge_counts();
        Ok(GraphPackReport {
            nodes: g.len(),
            prov_edges: prov,
            ver_edges: ver,
            path: bin.display().to_string(),
            bytes: std::fs::metadata(&bin)?.len(),
            already_binary,
            elapsed_secs: t.elapsed_secs(),
        })
    }
}

impl Report for GraphPackReport {
    fn to_json(&self) -> Json {
        Json::obj()
            .set("nodes", self.nodes)
            .set("prov_edges", self.prov_edges)
            .set("ver_edges", self.ver_edges)
            .set("path", self.path.as_str())
            .set("bytes", self.bytes)
            .set("already_binary", self.already_binary)
            .set("elapsed_secs", self.elapsed_secs)
    }
}
