//! Remote-tier operations: `remote set/get`, `fetch`, `push`.
//!
//! These are the fleet seam over [`crate::store::tiered`]: `remote set`
//! writes `.mgit/remote` (after which every `Repo::open` reads through
//! the origin), `fetch <node>` pins a node's checkpoint subtree hot so
//! it serves offline, and `push <node>` uploads a locally-committed
//! node — object closure first, then the graph commit — to a
//! `--writable` origin. Like every operation here, each is a request
//! struct returning a typed report (see [`super`]).

use std::collections::HashSet;
use std::path::Path;

use anyhow::{anyhow, bail, Result};

use crate::delta::StoredModel;
use crate::store::remote::{CommitOutcome, RemoteConfig, RemoteError, RemoteStore};
use crate::store::ObjectId;
use crate::util::json::Json;

use super::{Report, Repo};

// ---------------------------------------------------------------------------
// remote set / get
// ---------------------------------------------------------------------------

/// `mgit remote set <url>`: configure the origin this repository reads
/// through. Takes effect on the next `Repo::open`.
pub struct RemoteSetRequest {
    pub url: String,
    pub auth_token: Option<String>,
    /// Byte budget for evictable read-through fills (`--hot-bytes`).
    pub hot_bytes: Option<u64>,
    /// Delta-parent chain prefetch on cold fills (`--no-prefetch` off).
    pub prefetch: bool,
}

/// Typed result of [`RemoteSetRequest`].
pub struct RemoteSetReport {
    pub url: String,
    /// Where the config was written (`.mgit/remote`).
    pub path: String,
}

impl RemoteSetRequest {
    pub fn run(&self, root: &Path) -> Result<RemoteSetReport> {
        if !Repo::graph_path(root).exists() && !Repo::graph_bin_path(root).exists() {
            bail!("no repository at {} (run `mgit init` first)", root.display());
        }
        let cfg = RemoteConfig {
            url: self.url.clone(),
            auth_token: self.auth_token.clone(),
            hot_bytes: self.hot_bytes,
            prefetch: self.prefetch,
        };
        // Validate the URL eagerly — a malformed endpoint would otherwise
        // break every later `Repo::open`. (No dial: the origin may be
        // offline right now and that's fine.)
        RemoteStore::connect(&cfg)?;
        let mgit = Repo::mgit_dir(root);
        cfg.save(&mgit)?;
        Ok(RemoteSetReport {
            url: cfg.url,
            path: RemoteConfig::path(&mgit).display().to_string(),
        })
    }
}

impl Report for RemoteSetReport {
    fn to_json(&self) -> Json {
        Json::obj()
            .set("url", self.url.as_str())
            .set("path", self.path.as_str())
    }
}

/// `mgit remote get`: show the configured origin, if any.
pub struct RemoteGetRequest;

/// Typed result of [`RemoteGetRequest`]. `url == None` means no remote
/// is configured (not a failure).
pub struct RemoteGetReport {
    pub url: Option<String>,
    pub hot_bytes: Option<u64>,
    pub prefetch: bool,
    /// Whether an auth token is configured (the token itself is never
    /// echoed).
    pub auth: bool,
}

impl RemoteGetRequest {
    pub fn run(&self, root: &Path) -> Result<RemoteGetReport> {
        Ok(match RemoteConfig::load(&Repo::mgit_dir(root))? {
            Some(cfg) => RemoteGetReport {
                url: Some(cfg.url),
                hot_bytes: cfg.hot_bytes,
                prefetch: cfg.prefetch,
                auth: cfg.auth_token.is_some(),
            },
            None => RemoteGetReport {
                url: None,
                hot_bytes: None,
                prefetch: true,
                auth: false,
            },
        })
    }
}

impl Report for RemoteGetReport {
    fn to_json(&self) -> Json {
        Json::obj()
            .set(
                "url",
                self.url.as_deref().map(Json::from).unwrap_or(Json::Null),
            )
            .set(
                "hot_bytes",
                self.hot_bytes.map(Json::from).unwrap_or(Json::Null),
            )
            .set("prefetch", self.prefetch)
            .set("auth", self.auth)
    }
}

// ---------------------------------------------------------------------------
// fetch
// ---------------------------------------------------------------------------

/// `mgit fetch <node>`: pin a node's checkpoint subtree into the hot
/// tier. If the local graph has never seen the node, its metadata is
/// pulled from the origin's `/show` endpoint and committed locally
/// first, so a *fresh* repo with only `.mgit/remote` configured can
/// fetch and then serve the node entirely offline.
pub struct FetchRequest {
    pub node: String,
}

/// Typed result of [`FetchRequest`].
pub struct FetchReport {
    pub node: String,
    /// The node was unknown locally and was created from origin metadata.
    pub created_node: bool,
    /// Parameters in the node's stored checkpoint.
    pub params: usize,
    /// Objects pulled from the origin (params + delta-chain ancestors).
    pub objects_fetched: usize,
    /// Payload bytes transferred for those objects.
    pub bytes_fetched: u64,
    /// Chain objects that were already hot.
    pub already_hot: usize,
}

impl FetchRequest {
    pub fn run(&self, repo: &mut Repo) -> Result<FetchReport> {
        if repo.store.as_tiered().is_none() {
            bail!("no remote configured (run `mgit remote set <url>` first)");
        }
        let (sm, created) = self.resolve_model(repo)?;
        let tiered = repo.store.as_tiered().expect("checked above");
        let mut fetched = 0usize;
        let mut bytes = 0u64;
        let mut already = 0usize;
        for (_, id) in &sm.params {
            let pin = tiered.pin_chain(id)?;
            fetched += pin.fetched;
            bytes += pin.bytes;
            already += pin.already_hot;
        }
        if created {
            repo.save()?;
        }
        Ok(FetchReport {
            node: self.node.clone(),
            created_node: created,
            params: sm.params.len(),
            objects_fetched: fetched,
            bytes_fetched: bytes,
            already_hot: already,
        })
    }

    /// The node's stored model: from the local graph when known, else
    /// from the origin's `/show` (committing the node locally so later
    /// offline opens still resolve it).
    fn resolve_model(&self, repo: &mut Repo) -> Result<(StoredModel, bool)> {
        if let Ok(node) = repo.graph.node_by_name(&self.node) {
            let sm = node.stored.ok_or_else(|| {
                anyhow!("node `{}` has no stored checkpoint to fetch", self.node)
            })?;
            return Ok((sm, false));
        }
        let show = repo
            .store
            .as_tiered()
            .expect("caller checked")
            .remote()
            .fetch_show(&self.node)
            .map_err(anyhow::Error::new)?;
        let model_type = show.req_str("model_type")?.to_string();
        let mut params = Vec::new();
        for p in show.req_arr("params")? {
            params.push((
                p.req_str("name")?.to_string(),
                ObjectId::from_hex(p.req_str("id")?)?,
            ));
        }
        if params.is_empty() {
            bail!(
                "origin node `{}` has no stored checkpoint to fetch",
                self.node
            );
        }
        let sm = StoredModel { arch: model_type.clone(), params };
        // Commit the node locally (no lineage edges: the origin's graph
        // context is not replicated — `fetch` pins content, not history).
        let op = Json::obj()
            .set("name", self.node.as_str())
            .set("model_type", model_type.as_str())
            .set("stored", sm.to_json());
        repo.graph.full_mut()?.apply_commit(&op)?;
        Ok((sm, true))
    }
}

impl Report for FetchReport {
    fn to_json(&self) -> Json {
        Json::obj()
            .set("node", self.node.as_str())
            .set("created_node", self.created_node)
            .set("params", self.params)
            .set("objects_fetched", self.objects_fetched)
            .set("bytes_fetched", self.bytes_fetched)
            .set("already_hot", self.already_hot)
    }
}

// ---------------------------------------------------------------------------
// push
// ---------------------------------------------------------------------------

/// `mgit push <node>`: upload a node to a `--writable` origin — the full
/// object closure first (delta-chain bases before the deltas that need
/// them, so the origin never holds a dangling parent pointer), then the
/// graph commit. Pushing an already-present node is idempotent.
pub struct PushRequest {
    pub node: String,
}

/// Typed result of [`PushRequest`].
pub struct PushReport {
    pub node: String,
    /// Objects newly uploaded.
    pub objects_pushed: usize,
    /// Payload bytes those uploads transferred.
    pub bytes_pushed: u64,
    /// Closure objects the origin already had (dedup).
    pub already_remote: usize,
    /// `true` when the origin created the node; `false` when it already
    /// had one of that name (409 — treated as success).
    pub committed: bool,
    /// Version-parent name sent with the commit, when the origin knew it.
    pub ver_parent: Option<String>,
}

impl PushRequest {
    pub fn run(&self, repo: &Repo) -> Result<PushReport> {
        let Some(tiered) = repo.store.as_tiered() else {
            bail!("no remote configured (run `mgit remote set <url>` first)");
        };
        let remote = tiered.remote();
        let node = repo.graph.node_by_name(&self.node)?;
        let sm = node.stored.as_ref().ok_or_else(|| {
            anyhow!("node `{}` has no stored checkpoint to push", self.node)
        })?;

        // Full object closure: params plus transitive delta parents.
        let mut closure: Vec<ObjectId> = Vec::new();
        let mut seen: HashSet<ObjectId> = HashSet::new();
        for (_, id) in &sm.params {
            let mut cursor = Some(*id);
            while let Some(id) = cursor {
                if !seen.insert(id) {
                    break;
                }
                closure.push(id);
                cursor = repo.store.object_meta(&id)?.parent;
            }
        }
        // Reverse order pushes each chain's base before its deltas.
        let mut pushed = 0usize;
        let mut bytes = 0u64;
        let mut already = 0usize;
        for id in closure.iter().rev() {
            let payload = repo.store.get(id)?;
            let new = remote
                .put_remote(*id, &payload)
                .map_err(|e| anyhow::Error::new(e).context(format!("pushing object {}", id.short())))?;
            if new {
                pushed += 1;
                bytes += payload.len() as u64;
            } else {
                already += 1;
            }
        }

        // Commit on the origin. Carry the local version parent when we
        // have one; if the origin does not know that node (400), commit
        // without lineage rather than fail the push.
        let base_op = Json::obj()
            .set("name", node.name.as_str())
            .set("model_type", node.model_type.as_str())
            .set("stored", sm.to_json())
            .set("metadata", node.metadata.clone());
        let ver_parent = match node.ver_parents.first() {
            Some(&idx) => Some(repo.graph.name_of(idx)?),
            None => None,
        };
        let (outcome, sent_parent) = match &ver_parent {
            Some(vname) => {
                let op = base_op.clone().set("ver_parent", vname.as_str());
                match remote.commit(&op) {
                    Ok(o) => (o, Some(vname.clone())),
                    Err(RemoteError::Status { status: 400, .. }) => {
                        (remote.commit(&base_op).map_err(anyhow::Error::new)?, None)
                    }
                    Err(e) => return Err(anyhow::Error::new(e)),
                }
            }
            None => (remote.commit(&base_op).map_err(anyhow::Error::new)?, None),
        };
        Ok(PushReport {
            node: self.node.clone(),
            objects_pushed: pushed,
            bytes_pushed: bytes,
            already_remote: already,
            committed: outcome == CommitOutcome::Created,
            ver_parent: sent_parent,
        })
    }
}

impl Report for PushReport {
    fn to_json(&self) -> Json {
        Json::obj()
            .set("node", self.node.as_str())
            .set("objects_pushed", self.objects_pushed)
            .set("bytes_pushed", self.bytes_pushed)
            .set("already_remote", self.already_remote)
            .set("committed", self.committed)
            .set(
                "ver_parent",
                self.ver_parent
                    .as_deref()
                    .map(Json::from)
                    .unwrap_or(Json::Null),
            )
    }
}
