//! `mgit synth-graph`: deterministic synthetic lineage graphs for the
//! graph-scale benchmarks and tests.
//!
//! Three shapes cover the traversal patterns that matter at scale:
//! `chain` (one long version chain — deep versioning), `tree` (a
//! binary provenance tree — wide derivation), and `mtl` (the paper's
//! multi-task shape: one shared base, task heads hanging off it, each
//! head a short version chain). Generation is pure and seed-free —
//! the same `--nodes`/`--shape` always produce the same graph.

use std::path::Path;

use anyhow::{bail, Result};

use crate::lineage::{binfmt, LineageGraph};
use crate::util::json::Json;

use super::{Report, Repo};

/// Length of each task head's version chain in the `mtl` shape (the
/// head itself plus seven updates).
const MTL_GROUP: usize = 8;

/// Build a synthetic graph in memory. Node names are `n0000000`,
/// `n0000001`, … in index order; every node carries one small metadata
/// field so bodies are realistic but compact.
pub fn build_graph(nodes: usize, shape: &str) -> Result<LineageGraph> {
    let mut g = LineageGraph::new();
    match shape {
        // One version chain: n0 -> n1 -> … (versioning edges).
        "chain" => {
            for i in 0..nodes {
                let idx = g.add_node(&format!("n{i:07}"), "tx")?;
                g.nodes[idx].metadata = Json::obj().set("seed", i);
                if i > 0 {
                    g.add_version_edge(idx - 1, idx)?;
                }
            }
        }
        // Binary provenance tree: parent of node i is (i-1)/2.
        "tree" => {
            for i in 0..nodes {
                let idx = g.add_node(&format!("n{i:07}"), "tx")?;
                g.nodes[idx].metadata = Json::obj().set("seed", i);
                if i > 0 {
                    g.add_edge((i - 1) / 2, idx)?;
                }
            }
        }
        // Multi-task: n0 is the shared base; every group of MTL_GROUP
        // nodes is a task head (provenance child of the base) followed
        // by its version chain.
        "mtl" => {
            for i in 0..nodes {
                let idx = g.add_node(&format!("n{i:07}"), "tx")?;
                g.nodes[idx].metadata = Json::obj().set("seed", i);
                if i == 0 {
                    continue;
                }
                if (i - 1) % MTL_GROUP == 0 {
                    g.add_edge(0, idx)?;
                } else {
                    g.add_version_edge(idx - 1, idx)?;
                }
            }
        }
        other => bail!("unknown shape `{other}` (expected chain|tree|mtl)"),
    }
    Ok(g)
}

/// `mgit synth-graph --nodes N [--shape S] [--format json|bin]`.
pub struct SynthGraphRequest {
    pub nodes: usize,
    /// `chain`, `tree`, or `mtl`.
    pub shape: String,
    /// `json` (v0 `graph.json`) or `bin` (MGGI `graph.bin`).
    pub format: String,
}

/// Typed result of [`SynthGraphRequest`].
pub struct SynthGraphReport {
    pub nodes: usize,
    pub prov_edges: usize,
    pub ver_edges: usize,
    pub shape: String,
    pub format: String,
    /// The graph file that was written.
    pub path: String,
    pub elapsed_secs: f64,
}

impl SynthGraphRequest {
    /// Initialize `root` if needed and write the synthetic graph in
    /// the requested format. Refuses to clobber a non-empty repo.
    pub fn run(&self, root: &Path) -> Result<SynthGraphReport> {
        if !matches!(self.format.as_str(), "json" | "bin") {
            bail!("unknown format `{}` (expected json|bin)", self.format);
        }
        let t = std::time::Instant::now();
        let g = build_graph(self.nodes, &self.shape)?;
        if Repo::graph_path(root).exists() || Repo::graph_bin_path(root).exists() {
            let existing = Repo::open(root)?;
            if !existing.graph.is_empty() {
                bail!(
                    "repository at {} already has {} nodes; refusing to overwrite",
                    root.display(),
                    existing.graph.len()
                );
            }
        } else {
            Repo::init(root)?;
        }
        let path = match self.format.as_str() {
            "json" => {
                let p = Repo::graph_path(root);
                g.save(&p)?;
                p
            }
            _ => {
                let p = Repo::graph_bin_path(root);
                binfmt::write_binary(&g, &p)?;
                p
            }
        };
        let (prov_edges, ver_edges) = g.edge_counts();
        Ok(SynthGraphReport {
            nodes: g.len(),
            prov_edges,
            ver_edges,
            shape: self.shape.clone(),
            format: self.format.clone(),
            path: path.display().to_string(),
            elapsed_secs: t.elapsed().as_secs_f64(),
        })
    }
}

impl Report for SynthGraphReport {
    fn to_json(&self) -> Json {
        Json::obj()
            .set("nodes", self.nodes)
            .set("prov_edges", self.prov_edges)
            .set("ver_edges", self.ver_edges)
            .set("shape", self.shape.as_str())
            .set("format", self.format.as_str())
            .set("path", self.path.as_str())
            .set("elapsed_secs", self.elapsed_secs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes_are_valid_graphs() {
        for shape in ["chain", "tree", "mtl"] {
            let g = build_graph(100, shape).unwrap();
            assert_eq!(g.len(), 100, "{shape}");
            g.integrity_check().unwrap();
            let (prov, ver) = g.edge_counts();
            assert_eq!(prov + ver, 99, "{shape}: every non-root has one in-edge");
        }
        assert!(build_graph(10, "blob").is_err());
    }

    #[test]
    fn mtl_shape_structure() {
        let g = build_graph(18, "mtl").unwrap();
        // Heads: n1 and n9 hang off the base; everything else chains.
        let base = g.idx("n0000000").unwrap();
        assert_eq!(g.node(g.idx("n0000001").unwrap()).prov_parents, vec![base]);
        assert_eq!(g.node(g.idx("n0000009").unwrap()).prov_parents, vec![base]);
        let (prov, ver) = g.edge_counts();
        assert_eq!((prov, ver), (3, 14));
    }
}
