//! `mgit serve`: a dependency-free HTTP/1.1 front-end over the
//! concurrent read tier.
//!
//! The server owns one read-only [`Repo`] snapshot (graph loaded once at
//! bind time) and shares the `Send + Sync` [`crate::store::Store`] plus
//! one bounded [`ResolveCache`] across a fixed pool of worker threads —
//! exactly the concurrency contract the storage tier guarantees (mmap'd
//! lock-free pack reads; see `docs/STORAGE.md`). Endpoints:
//!
//! | method+path              | response                                         |
//! |--------------------------|--------------------------------------------------|
//! | `GET /log`               | [`super::LogReport`] JSON                        |
//! | `GET /stats`             | [`super::StatsReport`] JSON                      |
//! | `GET /show/<node>`       | [`super::ShowReport`] JSON                       |
//! | `GET /diff/<a>/<b>`      | [`super::DiffReport`] JSON (needs the manifest)  |
//! | `GET /checkpoint/<node>` | raw little-endian f32 tensor stream (flat layout |
//! |                          | order), delta chains resolved through the shared |
//! |                          | cache — bit-exact with [`crate::delta::load`]    |
//! | `GET /object/<hex-id>`   | the stored object's exact bytes (`Store::get`)   |
//! | `GET /metrics`           | live metrics: per-server request counters and    |
//! |                          | latency histograms plus the process registry     |
//! |                          | (JSON; `?format=prom` for Prometheus text)       |
//! | `GET /healthz`           | `{"ok": true}`                                   |
//!
//! Node names may contain `/` (e.g. `g5/base-mlm`): `show` and
//! `checkpoint` treat the whole remaining path as the name, and any
//! segment may percent-encode reserved characters (`%2F`). The protocol
//! surface is deliberately tiny — `GET`-only (anything else gets a `405`
//! with an `Allow: GET` header) — so it needs no external HTTP crate,
//! matching the repo's no-new-deps style.
//!
//! ## Keep-alive
//!
//! Connections are HTTP/1.1 persistent by default: a worker serves up to
//! [`MAX_REQUESTS_PER_CONN`] requests per connection, closing early on
//! `Connection: close`, an HTTP/1.0 request line, or ~5 s of idleness
//! between requests (the first request gets a longer 10 s grace). Load
//! clients amortize the TCP handshake across a whole request stream,
//! which is what `benches/serve_load.rs` measures.
//!
//! ## Observability
//!
//! Every server owns a *per-instance* [`Registry`] (concurrent servers
//! in one process — tests — must not bleed request counts into each
//! other): request/byte counters, per-endpoint and per-status counters,
//! an in-flight gauge, and a request-latency histogram. `GET /metrics`
//! renders that registry alongside the process-global one
//! ([`crate::obs::global`]: store reads, payload decodes, cascade
//! timings). Metrics for a request are recorded *before* its first
//! response byte is written, so once a client has read a response, a
//! subsequent `/metrics` fetch is guaranteed to include it — the
//! property the integration tests pin down. `--log-requests` adds a
//! one-line JSON record per request on stderr.

use std::io::Write;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use crate::checkpoint::ModelZoo;
use crate::delta::{self, NativeKernel, ResolveCache};
use crate::obs::{Counter, Gauge, Histogram, Registry};
use crate::store::ObjectId;
use crate::tensor::f32_to_bytes;
use crate::util::json::Json;

use super::{Report, Repo};

/// Hard cap on requests served over one persistent connection: bounds
/// how long a single client can monopolize a pool worker.
pub const MAX_REQUESTS_PER_CONN: u64 = 1000;

/// Summary returned when a server shuts down.
pub struct ServeReport {
    pub requests: u64,
    pub errors: u64,
    pub pool: usize,
}

impl Report for ServeReport {
    fn to_json(&self) -> Json {
        Json::obj()
            .set("requests", self.requests)
            .set("errors", self.errors)
            .set("pool", self.pool)
    }
}

// ---------------------------------------------------------------------------
// Per-server metrics
// ---------------------------------------------------------------------------

/// Endpoint labels for per-endpoint request counters. `other` absorbs
/// unmatched paths (404s on unknown routes).
const ENDPOINTS: [&str; 9] = [
    "checkpoint",
    "diff",
    "healthz",
    "log",
    "metrics",
    "object",
    "other",
    "show",
    "stats",
];

/// Status codes with dedicated counters; anything else lands in
/// `status.other`.
const STATUSES: [u16; 6] = [200, 400, 404, 405, 500, 503];

/// One server's request metrics: a private [`Registry`] plus handles
/// resolved once at bind time, so the per-request path is pure relaxed
/// atomics (the registry mutex is never taken while serving).
struct ServeMetrics {
    registry: Registry,
    requests_total: Arc<Counter>,
    bytes_sent: Arc<Counter>,
    request_micros: Arc<Histogram>,
    inflight: Arc<Gauge>,
    connections: Arc<Counter>,
    endpoints: Vec<(&'static str, Arc<Counter>)>,
    statuses: Vec<(u16, Arc<Counter>)>,
    status_other: Arc<Counter>,
    // Mirrors of the shared ResolveCache's own atomics, refreshed at
    // /metrics scrape time (the cache is the source of truth; mirroring
    // keeps the hot cache paths free of registry coupling).
    cache_hits: Arc<Counter>,
    cache_misses: Arc<Counter>,
    cache_evictions: Arc<Counter>,
    cache_resident: Arc<Gauge>,
}

impl ServeMetrics {
    fn new() -> ServeMetrics {
        let registry = Registry::new();
        let requests_total = registry.counter("requests_total");
        let bytes_sent = registry.counter("bytes_sent_total");
        let request_micros = registry.histogram("request_micros");
        let inflight = registry.gauge("inflight");
        let connections = registry.counter("connections_total");
        let endpoints = ENDPOINTS
            .iter()
            .map(|e| (*e, registry.counter(&format!("endpoint.{e}"))))
            .collect();
        let statuses = STATUSES
            .iter()
            .map(|c| (*c, registry.counter(&format!("status.{c}"))))
            .collect();
        let status_other = registry.counter("status.other");
        let cache_hits = registry.counter("cache.hits");
        let cache_misses = registry.counter("cache.misses");
        let cache_evictions = registry.counter("cache.evictions");
        let cache_resident = registry.gauge("cache.resident_bytes");
        ServeMetrics {
            registry,
            requests_total,
            bytes_sent,
            request_micros,
            inflight,
            connections,
            endpoints,
            statuses,
            status_other,
            cache_hits,
            cache_misses,
            cache_evictions,
            cache_resident,
        }
    }

    fn endpoint(&self, name: &str) -> &Counter {
        self.endpoints
            .iter()
            .find(|(n, _)| *n == name)
            .or_else(|| self.endpoints.iter().find(|(n, _)| *n == "other"))
            .map(|(_, c)| c.as_ref())
            .expect("`other` endpoint counter always registered")
    }

    fn status(&self, code: u16) -> &Counter {
        self.statuses
            .iter()
            .find(|(c, _)| *c == code)
            .map(|(_, c)| c.as_ref())
            .unwrap_or(self.status_other.as_ref())
    }

    /// Refresh the ResolveCache mirror metrics (scrape-time only).
    fn sync_cache(&self, cache: &ResolveCache) {
        let (hits, misses) = cache.counters();
        self.cache_hits.store(hits);
        self.cache_misses.store(misses);
        self.cache_evictions.store(cache.evictions());
        self.cache_resident.set(cache.resident_bytes() as i64);
    }
}

/// RAII in-flight marker: decrements the gauge however the request
/// handler exits (including error paths).
struct InflightGuard<'a>(&'a Gauge);

impl<'a> InflightGuard<'a> {
    fn new(g: &'a Gauge) -> InflightGuard<'a> {
        g.inc();
        InflightGuard(g)
    }
}

impl Drop for InflightGuard<'_> {
    fn drop(&mut self) {
        self.0.dec();
    }
}

// ---------------------------------------------------------------------------
// Server
// ---------------------------------------------------------------------------

/// Shared, read-only serving state (one per server).
struct ServeState {
    repo: Repo,
    /// `/stats` response, computed once at bind time: the report walks
    /// every object in the store, and the server's repo snapshot is
    /// immutable for its lifetime — recomputing per request would let a
    /// few concurrent `/stats` hits pin every pool worker on large
    /// stores.
    stats: Json,
    /// Arch specs for `/diff` and `/checkpoint`; None when no artifacts
    /// manifest was found (those endpoints answer 503).
    zoo: Option<ModelZoo>,
    /// Shared across workers so concurrent chain walks reuse resolved
    /// ancestors (PR 2's bounded LRU).
    cache: ResolveCache,
    metrics: ServeMetrics,
    /// Emit a one-line JSON record per request on stderr.
    log_requests: AtomicBool,
    stop: AtomicBool,
    requests: AtomicU64,
    errors: AtomicU64,
}

/// A bound-but-not-yet-serving HTTP server.
pub struct Server {
    listener: TcpListener,
    state: Arc<ServeState>,
    pool: usize,
}

/// Cloneable handle used to stop a running [`Server`] (tests, signal
/// handlers).
#[derive(Clone)]
pub struct ServerHandle {
    state: Arc<ServeState>,
    addr: SocketAddr,
}

impl ServerHandle {
    /// Ask the accept loop to exit. Safe to call more than once.
    pub fn shutdown(&self) {
        self.state.stop.store(true, Ordering::SeqCst);
        // Unblock the blocking `accept` with one throwaway connection.
        let _ = TcpStream::connect(self.addr);
    }

    pub fn addr(&self) -> SocketAddr {
        self.addr
    }
}

impl Server {
    /// Bind `127.0.0.1:port` (`port = 0` picks an ephemeral port) over
    /// an opened repository. `pool` worker threads serve requests
    /// (clamped to ≥ 1); size it with [`crate::util::auto_jobs`].
    pub fn bind(repo: Repo, zoo: Option<ModelZoo>, port: u16, pool: usize) -> Result<Server> {
        let listener = TcpListener::bind(("127.0.0.1", port))
            .with_context(|| format!("binding 127.0.0.1:{port}"))?;
        let stats = super::StatsRequest.run(&repo)?.to_json();
        let state = Arc::new(ServeState {
            repo,
            stats,
            zoo,
            cache: ResolveCache::with_max_bytes(128, 256 << 20),
            metrics: ServeMetrics::new(),
            log_requests: AtomicBool::new(false),
            stop: AtomicBool::new(false),
            requests: AtomicU64::new(0),
            errors: AtomicU64::new(0),
        });
        Ok(Server { listener, state, pool: pool.max(1) })
    }

    /// Toggle per-request stderr logging (`mgit serve --log-requests`).
    pub fn with_log_requests(self, on: bool) -> Server {
        self.state.log_requests.store(on, Ordering::Relaxed);
        self
    }

    pub fn local_addr(&self) -> Result<SocketAddr> {
        Ok(self.listener.local_addr()?)
    }

    pub fn pool(&self) -> usize {
        self.pool
    }

    pub fn handle(&self) -> Result<ServerHandle> {
        Ok(ServerHandle { state: Arc::clone(&self.state), addr: self.local_addr()? })
    }

    /// Accept connections until [`ServerHandle::shutdown`], dispatching
    /// them to the bounded worker pool. Blocks the calling thread.
    pub fn serve(self) -> Result<ServeReport> {
        // Bounded hand-off: when every worker is busy and the queue is
        // full, the accept loop blocks in `send`, which backpressures to
        // the kernel listen queue instead of buffering unboundedly.
        let (tx, rx) = mpsc::sync_channel::<TcpStream>(self.pool * 2);
        let rx = Arc::new(Mutex::new(rx));
        std::thread::scope(|scope| {
            for _ in 0..self.pool {
                let rx = Arc::clone(&rx);
                let state = Arc::clone(&self.state);
                scope.spawn(move || loop {
                    // Hold the receiver lock only for the dequeue.
                    let conn = rx.lock().unwrap().recv();
                    match conn {
                        Ok(stream) => handle_connection(&state, stream),
                        Err(_) => break, // accept loop ended
                    }
                });
            }
            for conn in self.listener.incoming() {
                if self.state.stop.load(Ordering::SeqCst) {
                    break;
                }
                let Ok(stream) = conn else { continue };
                if tx.send(stream).is_err() {
                    break;
                }
            }
            drop(tx); // workers drain the queue, then exit
        });
        Ok(ServeReport {
            requests: self.state.requests.load(Ordering::Relaxed),
            errors: self.state.errors.load(Ordering::Relaxed),
            pool: self.pool,
        })
    }
}

// ---------------------------------------------------------------------------
// Per-connection handling
// ---------------------------------------------------------------------------

fn handle_connection(state: &ServeState, stream: TcpStream) {
    state.metrics.connections.inc();
    let _ = stream.set_read_timeout(Some(Duration::from_secs(10)));
    let _ = stream.set_write_timeout(Some(Duration::from_secs(60)));
    if handle_http(state, stream).is_err() {
        state.errors.fetch_add(1, Ordering::Relaxed);
    }
}

/// One in-flight request's response side: writes the head exactly once
/// and records the request's metrics (status/endpoint/latency/bytes —
/// plus the optional stderr log line) *immediately before* the head
/// bytes go out. By the time a client has a response, its request is in
/// the metrics, so `/metrics` reads are deterministic for settled
/// traffic; the `/metrics` handler itself snapshots before its own head
/// and is therefore excluded from its own output.
struct ResponseWriter<'a> {
    stream: &'a mut TcpStream,
    metrics: &'a ServeMetrics,
    log_requests: bool,
    keep_alive: bool,
    method: &'a str,
    path: &'a str,
    endpoint: &'static str,
    start: Instant,
    recorded: bool,
}

impl ResponseWriter<'_> {
    fn record(&mut self, code: u16, body_len: usize) {
        if self.recorded {
            return;
        }
        self.recorded = true;
        let micros = self.start.elapsed().as_micros() as u64;
        self.metrics.requests_total.inc();
        self.metrics.status(code).inc();
        self.metrics.endpoint(self.endpoint).inc();
        self.metrics.bytes_sent.add(body_len as u64);
        self.metrics.request_micros.observe(micros);
        if self.log_requests {
            let line = Json::obj()
                .set("method", self.method)
                .set("path", self.path)
                .set("status", code as usize)
                .set("bytes", body_len)
                .set("micros", micros)
                .to_string_compact();
            eprintln!("{line}");
        }
    }

    fn write_head(&mut self, code: u16, content_type: &str, len: usize) -> Result<()> {
        self.write_head_with(code, content_type, len, &[])
    }

    fn write_head_with(
        &mut self,
        code: u16,
        content_type: &str,
        len: usize,
        extra: &[(&str, &str)],
    ) -> Result<()> {
        self.record(code, len);
        write!(
            self.stream,
            "HTTP/1.1 {code} {}\r\nContent-Type: {content_type}\r\nContent-Length: {len}\r\n",
            status_reason(code)
        )?;
        for (k, v) in extra {
            write!(self.stream, "{k}: {v}\r\n")?;
        }
        let conn = if self.keep_alive { "keep-alive" } else { "close" };
        write!(self.stream, "Connection: {conn}\r\n\r\n")?;
        Ok(())
    }

    fn respond_json(&mut self, code: u16, body: &Json) -> Result<()> {
        self.respond_json_with(code, body, &[])
    }

    fn respond_json_with(
        &mut self,
        code: u16,
        body: &Json,
        extra: &[(&str, &str)],
    ) -> Result<()> {
        let text = body.to_string_pretty();
        self.write_head_with(code, "application/json", text.len(), extra)?;
        self.stream.write_all(text.as_bytes())?;
        self.stream.flush()?;
        Ok(())
    }
}

/// Serve one connection's request stream (HTTP/1.1 keep-alive).
fn handle_http(state: &ServeState, mut stream: TcpStream) -> Result<()> {
    use std::io::{BufRead, BufReader, Read};
    // Bound how much request-line + header data one request can make us
    // buffer: `read_line` grows its String until a newline arrives, so an
    // un-capped reader would let a newline-free byte stream grow a
    // worker's memory without ever tripping the per-read timeout. The cap
    // is re-armed per request.
    let mut reader = BufReader::new(stream.try_clone()?.take(16 * 1024));
    let mut served = 0u64;
    loop {
        reader.get_mut().set_limit(16 * 1024);
        let mut line = String::new();
        match reader.read_line(&mut line) {
            Ok(0) => return Ok(()), // clean EOF (client closed)
            Ok(_) => {}
            Err(e)
                if served > 0
                    && matches!(
                        e.kind(),
                        std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                    ) =>
            {
                // Idle keep-alive connection timed out: a clean close,
                // not a served-request error.
                return Ok(());
            }
            Err(e) => return Err(e.into()),
        }
        if line.trim().is_empty() {
            // No request line: the shutdown wake-up connection (or a
            // client that sent a bare newline and went away).
            return Ok(());
        }
        let mut parts = line.split_whitespace();
        let method = parts.next().unwrap_or("").to_string();
        let target = parts.next().unwrap_or("").to_string();
        let version = parts.next().unwrap_or("HTTP/1.1");
        // HTTP/1.0 defaults to close; 1.1 to keep-alive. An explicit
        // `Connection:` header wins either way.
        let mut close = version == "HTTP/1.0";
        loop {
            let mut h = String::new();
            if reader.read_line(&mut h)? == 0 || h == "\r\n" || h == "\n" {
                break;
            }
            let lower = h.trim().to_ascii_lowercase();
            if let Some(v) = lower.strip_prefix("connection:") {
                match v.trim() {
                    "close" => close = true,
                    "keep-alive" => close = false,
                    _ => {}
                }
            }
        }
        served += 1;
        let keep_alive = !close && served < MAX_REQUESTS_PER_CONN;
        let _inflight = InflightGuard::new(&state.metrics.inflight);
        let (path, query) = match target.split_once('?') {
            Some((p, q)) => (p.to_string(), q.to_string()),
            None => (target.clone(), String::new()),
        };
        let mut rw = ResponseWriter {
            stream: &mut stream,
            metrics: &state.metrics,
            log_requests: state.log_requests.load(Ordering::Relaxed),
            keep_alive,
            method: &method,
            path: &path,
            endpoint: "other",
            start: Instant::now(),
            recorded: false,
        };
        if method != "GET" {
            rw.respond_json_with(
                405,
                &err_json("only GET is supported"),
                &[("Allow", "GET")],
            )?;
        } else if let Err(e) = route(state, &mut rw, &path, &query) {
            // Route handlers answer their own 4xx; anything that
            // *escapes* is an internal error. Best-effort 500 unless a
            // head already went out (the client may be gone either way).
            if !rw.recorded {
                let _ = rw.respond_json(500, &err_json(&format!("{e:#}")));
            }
            bail!("internal error serving {path}: {e:#}");
        }
        state.requests.fetch_add(1, Ordering::Relaxed);
        if !keep_alive {
            return Ok(());
        }
        // Idle budget between keep-alive requests is tighter than the
        // first-request grace: a parked connection frees its worker fast.
        let _ = stream.set_read_timeout(Some(Duration::from_secs(5)));
    }
}

fn route(state: &ServeState, rw: &mut ResponseWriter, path: &str, query: &str) -> Result<()> {
    match path {
        "/log" => {
            rw.endpoint = "log";
            let report = super::LogRequest.run(&state.repo)?;
            return rw.respond_json(200, &report.to_json());
        }
        "/stats" => {
            rw.endpoint = "stats";
            return rw.respond_json(200, &state.stats);
        }
        "/metrics" => {
            rw.endpoint = "metrics";
            return serve_metrics(state, rw, query);
        }
        "/healthz" => {
            rw.endpoint = "healthz";
            return rw.respond_json(200, &Json::obj().set("ok", true));
        }
        _ => {}
    }
    if let Some(rest) = path.strip_prefix("/show/") {
        rw.endpoint = "show";
        let node = percent_decode(rest);
        if state.repo.graph.idx(&node).is_err() {
            return rw.respond_json(404, &err_json(&format!("no node named `{node}`")));
        }
        let report = super::ShowRequest { node }.run(&state.repo)?;
        return rw.respond_json(200, &report.to_json());
    }
    if let Some(rest) = path.strip_prefix("/checkpoint/") {
        rw.endpoint = "checkpoint";
        return serve_checkpoint(state, rw, &percent_decode(rest));
    }
    if let Some(rest) = path.strip_prefix("/object/") {
        rw.endpoint = "object";
        return serve_object(state, rw, rest);
    }
    if let Some(rest) = path.strip_prefix("/diff/") {
        rw.endpoint = "diff";
        let segs: Vec<&str> = rest.split('/').collect();
        if segs.len() != 2 {
            return rw.respond_json(
                400,
                &err_json("diff wants exactly /diff/<a>/<b> (percent-encode `/` in names)"),
            );
        }
        let (a, b) = (percent_decode(segs[0]), percent_decode(segs[1]));
        let Some(zoo) = &state.zoo else {
            return rw.respond_json(503, &err_json(NO_MANIFEST));
        };
        if state.repo.graph.idx(&a).is_err() || state.repo.graph.idx(&b).is_err() {
            return rw.respond_json(404, &err_json("no such node"));
        }
        let report = super::DiffRequest { a, b }.run(&state.repo, zoo, &NativeKernel)?;
        return rw.respond_json(200, &report.to_json());
    }
    rw.respond_json(404, &err_json(&format!("no route for `{path}`")))
}

const NO_MANIFEST: &str =
    "server started without an artifacts manifest; arch-dependent endpoints are disabled";

/// `GET /metrics`: both registries — this server's request metrics plus
/// the process-global layer telemetry. The snapshot is taken *before*
/// this response's own head is written, so a `/metrics` response never
/// includes itself (keeping "histogram count == requests the client has
/// completed" exact for tests and cross-checking load harnesses).
fn serve_metrics(state: &ServeState, rw: &mut ResponseWriter, query: &str) -> Result<()> {
    state.metrics.sync_cache(&state.cache);
    if query.split('&').any(|kv| kv == "format=prom") {
        let mut out = String::new();
        state.metrics.registry.render_prometheus("mgit_serve_", &mut out);
        crate::obs::global().render_prometheus("mgit_", &mut out);
        rw.write_head(200, "text/plain; version=0.0.4", out.len())?;
        rw.stream.write_all(out.as_bytes())?;
        rw.stream.flush()?;
        return Ok(());
    }
    let body = Json::obj()
        .set("server", state.metrics.registry.snapshot())
        .set("process", crate::obs::global().snapshot());
    rw.respond_json(200, &body)
}

/// Stream a node's resolved checkpoint: the flat f32 parameter vector in
/// layout order, little-endian — bit-exact with what `delta::load`
/// reconstructs. Delta chains resolve through the server's shared cache,
/// so concurrent readers of sibling models reuse common ancestors.
fn serve_checkpoint(state: &ServeState, rw: &mut ResponseWriter, node: &str) -> Result<()> {
    let Ok(n) = state.repo.graph.by_name(node) else {
        return rw.respond_json(404, &err_json(&format!("no node named `{node}`")));
    };
    let Some(sm) = &n.stored else {
        return rw.respond_json(
            404,
            &err_json(&format!("node `{node}` has no stored checkpoint")),
        );
    };
    let Some(zoo) = &state.zoo else {
        return rw.respond_json(503, &err_json(NO_MANIFEST));
    };
    let ck = delta::load_with_cache(&state.repo.store, zoo, sm, &NativeKernel, &state.cache)?;
    let body_len = ck.flat.len() * 4;
    rw.write_head(200, "application/octet-stream", body_len)?;
    // Stream in bounded chunks rather than materializing one giant byte
    // buffer next to the checkpoint.
    const CHUNK: usize = 1 << 20; // 1 Mi f32 values (4 MiB) per write
    for values in ck.flat.chunks(CHUNK) {
        rw.stream.write_all(&f32_to_bytes(values))?;
    }
    rw.stream.flush()?;
    Ok(())
}

/// Serve one stored object's exact bytes — byte-identical to
/// `Store::get`, whichever pack or loose file holds it.
fn serve_object(state: &ServeState, rw: &mut ResponseWriter, hex: &str) -> Result<()> {
    let Ok(id) = ObjectId::from_hex(hex) else {
        return rw.respond_json(400, &err_json("object id must be 64 hex chars"));
    };
    if !state.repo.store.has(&id) {
        return rw.respond_json(404, &err_json(&format!("object {hex} not found")));
    }
    let bytes = state.repo.store.get(&id)?;
    rw.write_head(200, "application/octet-stream", bytes.len())?;
    rw.stream.write_all(&bytes)?;
    rw.stream.flush()?;
    Ok(())
}

// ---------------------------------------------------------------------------
// HTTP plumbing
// ---------------------------------------------------------------------------

fn status_reason(code: u16) -> &'static str {
    match code {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        503 => "Service Unavailable",
        _ => "Internal Server Error",
    }
}

fn err_json(msg: &str) -> Json {
    Json::obj().set("error", msg)
}

/// Minimal percent-decoding (`%2F` → `/`, `+` is *not* special — node
/// names may legitimately contain it). Invalid escapes pass through
/// verbatim.
fn percent_decode(s: &str) -> String {
    let bytes = s.as_bytes();
    let mut out = Vec::with_capacity(bytes.len());
    let mut i = 0;
    while i < bytes.len() {
        if bytes[i] == b'%' {
            if let Some(hex) = s.get(i + 1..i + 3) {
                if let Ok(b) = u8::from_str_radix(hex, 16) {
                    out.push(b);
                    i += 3;
                    continue;
                }
            }
        }
        out.push(bytes[i]);
        i += 1;
    }
    String::from_utf8_lossy(&out).into_owned()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percent_decoding() {
        assert_eq!(percent_decode("g5%2Fbase-mlm"), "g5/base-mlm");
        assert_eq!(percent_decode("plain"), "plain");
        assert_eq!(percent_decode("a+b"), "a+b");
        assert_eq!(percent_decode("bad%zz"), "bad%zz");
        assert_eq!(percent_decode("trail%2"), "trail%2");
        assert_eq!(percent_decode("%41%42"), "AB");
    }

    #[test]
    fn serve_metrics_labels_and_mirrors() {
        let m = ServeMetrics::new();
        m.endpoint("stats").inc();
        m.endpoint("stats").inc();
        m.endpoint("no-such-endpoint").inc(); // falls into `other`
        m.status(200).inc();
        m.status(418).inc(); // falls into `status.other`
        let snap = m.registry.snapshot();
        let counters = snap.get("counters").unwrap();
        assert_eq!(counters.req_usize("endpoint.stats").unwrap(), 2);
        assert_eq!(counters.req_usize("endpoint.other").unwrap(), 1);
        assert_eq!(counters.req_usize("status.200").unwrap(), 1);
        assert_eq!(counters.req_usize("status.other").unwrap(), 1);

        let cache = ResolveCache::new(2);
        cache.insert(crate::store::hash_bytes(b"a"), vec![0.0; 4]);
        assert!(cache.get(&crate::store::hash_bytes(b"a")).is_some());
        assert!(cache.get(&crate::store::hash_bytes(b"b")).is_none());
        m.sync_cache(&cache);
        let snap = m.registry.snapshot();
        let counters = snap.get("counters").unwrap();
        assert_eq!(counters.req_usize("cache.hits").unwrap(), 1);
        assert_eq!(counters.req_usize("cache.misses").unwrap(), 1);
        assert_eq!(
            snap.get("gauges").unwrap().req_usize("cache.resident_bytes").unwrap(),
            16
        );
    }
}
