//! `mgit serve`: a dependency-free HTTP/1.1 front-end over the
//! concurrent read tier.
//!
//! The server owns one read-only [`Repo`] snapshot (graph loaded once at
//! bind time) and shares the `Send + Sync` [`crate::store::Store`] plus
//! one bounded [`ResolveCache`] across a fixed pool of worker threads —
//! exactly the concurrency contract the storage tier guarantees (mmap'd
//! lock-free pack reads; see `docs/STORAGE.md`). Endpoints:
//!
//! | method+path              | response                                         |
//! |--------------------------|--------------------------------------------------|
//! | `GET /log`               | [`super::LogReport`] JSON                        |
//! | `GET /stats`             | [`super::StatsReport`] JSON                      |
//! | `GET /show/<node>`       | [`super::ShowReport`] JSON                       |
//! | `GET /diff/<a>/<b>`      | [`super::DiffReport`] JSON (needs the manifest)  |
//! | `GET /checkpoint/<node>` | raw little-endian f32 tensor stream (flat layout |
//! |                          | order), delta chains resolved through the shared |
//! |                          | cache — bit-exact with [`crate::delta::load`]    |
//! | `GET /object/<hex-id>`   | the stored object's exact bytes (`Store::get`)   |
//! | `GET /healthz`           | `{"ok": true}`                                   |
//!
//! Node names may contain `/` (e.g. `g5/base-mlm`): `show` and
//! `checkpoint` treat the whole remaining path as the name, and any
//! segment may percent-encode reserved characters (`%2F`). The protocol
//! surface is deliberately tiny — `GET`-only, `Connection: close` — so
//! it needs no external HTTP crate, matching the repo's no-new-deps
//! style.

use std::io::Write;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::Duration;

use anyhow::{Context, Result};

use crate::checkpoint::ModelZoo;
use crate::delta::{self, NativeKernel, ResolveCache};
use crate::store::ObjectId;
use crate::tensor::f32_to_bytes;
use crate::util::json::Json;

use super::{Report, Repo};

/// Summary returned when a server shuts down.
pub struct ServeReport {
    pub requests: u64,
    pub errors: u64,
    pub pool: usize,
}

impl Report for ServeReport {
    fn to_json(&self) -> Json {
        Json::obj()
            .set("requests", self.requests)
            .set("errors", self.errors)
            .set("pool", self.pool)
    }
}

/// Shared, read-only serving state (one per server).
struct ServeState {
    repo: Repo,
    /// `/stats` response, computed once at bind time: the report walks
    /// every object in the store, and the server's repo snapshot is
    /// immutable for its lifetime — recomputing per request would let a
    /// few concurrent `/stats` hits pin every pool worker on large
    /// stores.
    stats: Json,
    /// Arch specs for `/diff` and `/checkpoint`; None when no artifacts
    /// manifest was found (those endpoints answer 503).
    zoo: Option<ModelZoo>,
    /// Shared across workers so concurrent chain walks reuse resolved
    /// ancestors (PR 2's bounded LRU).
    cache: ResolveCache,
    stop: AtomicBool,
    requests: AtomicU64,
    errors: AtomicU64,
}

/// A bound-but-not-yet-serving HTTP server.
pub struct Server {
    listener: TcpListener,
    state: Arc<ServeState>,
    pool: usize,
}

/// Cloneable handle used to stop a running [`Server`] (tests, signal
/// handlers).
#[derive(Clone)]
pub struct ServerHandle {
    state: Arc<ServeState>,
    addr: SocketAddr,
}

impl ServerHandle {
    /// Ask the accept loop to exit. Safe to call more than once.
    pub fn shutdown(&self) {
        self.state.stop.store(true, Ordering::SeqCst);
        // Unblock the blocking `accept` with one throwaway connection.
        let _ = TcpStream::connect(self.addr);
    }

    pub fn addr(&self) -> SocketAddr {
        self.addr
    }
}

impl Server {
    /// Bind `127.0.0.1:port` (`port = 0` picks an ephemeral port) over
    /// an opened repository. `pool` worker threads serve requests
    /// (clamped to ≥ 1); size it with [`crate::util::auto_jobs`].
    pub fn bind(repo: Repo, zoo: Option<ModelZoo>, port: u16, pool: usize) -> Result<Server> {
        let listener = TcpListener::bind(("127.0.0.1", port))
            .with_context(|| format!("binding 127.0.0.1:{port}"))?;
        let stats = super::StatsRequest.run(&repo)?.to_json();
        let state = Arc::new(ServeState {
            repo,
            stats,
            zoo,
            cache: ResolveCache::with_max_bytes(128, 256 << 20),
            stop: AtomicBool::new(false),
            requests: AtomicU64::new(0),
            errors: AtomicU64::new(0),
        });
        Ok(Server { listener, state, pool: pool.max(1) })
    }

    pub fn local_addr(&self) -> Result<SocketAddr> {
        Ok(self.listener.local_addr()?)
    }

    pub fn pool(&self) -> usize {
        self.pool
    }

    pub fn handle(&self) -> Result<ServerHandle> {
        Ok(ServerHandle { state: Arc::clone(&self.state), addr: self.local_addr()? })
    }

    /// Accept connections until [`ServerHandle::shutdown`], dispatching
    /// them to the bounded worker pool. Blocks the calling thread.
    pub fn serve(self) -> Result<ServeReport> {
        // Bounded hand-off: when every worker is busy and the queue is
        // full, the accept loop blocks in `send`, which backpressures to
        // the kernel listen queue instead of buffering unboundedly.
        let (tx, rx) = mpsc::sync_channel::<TcpStream>(self.pool * 2);
        let rx = Arc::new(Mutex::new(rx));
        std::thread::scope(|scope| {
            for _ in 0..self.pool {
                let rx = Arc::clone(&rx);
                let state = Arc::clone(&self.state);
                scope.spawn(move || loop {
                    // Hold the receiver lock only for the dequeue.
                    let conn = rx.lock().unwrap().recv();
                    match conn {
                        Ok(stream) => handle_connection(&state, stream),
                        Err(_) => break, // accept loop ended
                    }
                });
            }
            for conn in self.listener.incoming() {
                if self.state.stop.load(Ordering::SeqCst) {
                    break;
                }
                let Ok(stream) = conn else { continue };
                if tx.send(stream).is_err() {
                    break;
                }
            }
            drop(tx); // workers drain the queue, then exit
        });
        Ok(ServeReport {
            requests: self.state.requests.load(Ordering::Relaxed),
            errors: self.state.errors.load(Ordering::Relaxed),
            pool: self.pool,
        })
    }
}

// ---------------------------------------------------------------------------
// Per-connection handling
// ---------------------------------------------------------------------------

fn handle_connection(state: &ServeState, stream: TcpStream) {
    let _ = stream.set_read_timeout(Some(Duration::from_secs(10)));
    let _ = stream.set_write_timeout(Some(Duration::from_secs(60)));
    match handle_http(state, stream) {
        Ok(served) => {
            if served {
                state.requests.fetch_add(1, Ordering::Relaxed);
            }
        }
        Err(_) => {
            state.errors.fetch_add(1, Ordering::Relaxed);
        }
    }
}

/// Parse one request and answer it. Returns `false` for connections that
/// never sent a request line (e.g. the shutdown wake-up connection).
fn handle_http(state: &ServeState, mut stream: TcpStream) -> Result<bool> {
    use std::io::{BufRead, BufReader, Read};
    // Bound how much request-line + header data one connection can make
    // us buffer: `read_line` grows its String until a newline arrives,
    // so an un-capped reader would let a newline-free byte stream grow a
    // worker's memory without ever tripping the per-read timeout.
    let mut reader = BufReader::new(stream.try_clone()?.take(16 * 1024));
    let mut line = String::new();
    reader.read_line(&mut line)?;
    if line.trim().is_empty() {
        return Ok(false);
    }
    let mut parts = line.split_whitespace();
    let method = parts.next().unwrap_or("").to_string();
    let target = parts.next().unwrap_or("").to_string();
    // Drain (and ignore) the request headers.
    loop {
        let mut h = String::new();
        if reader.read_line(&mut h)? == 0 || h == "\r\n" || h == "\n" {
            break;
        }
    }
    if method != "GET" {
        respond_json(&mut stream, 405, &err_json("only GET is supported"))?;
        return Ok(true);
    }
    let path = target.split('?').next().unwrap_or("").to_string();
    if let Err(e) = route(state, &mut stream, &path) {
        // Route handlers answer their own 4xx; anything that *escapes* is
        // an internal error. Best-effort 500 (the client may be gone).
        let _ = respond_json(&mut stream, 500, &err_json(&format!("{e:#}")));
        anyhow::bail!("internal error serving {path}: {e:#}");
    }
    Ok(true)
}

fn route(state: &ServeState, stream: &mut TcpStream, path: &str) -> Result<()> {
    match path {
        "/log" => {
            let report = super::LogRequest.run(&state.repo)?;
            return respond_json(stream, 200, &report.to_json());
        }
        "/stats" => return respond_json(stream, 200, &state.stats),
        "/healthz" => return respond_json(stream, 200, &Json::obj().set("ok", true)),
        _ => {}
    }
    if let Some(rest) = path.strip_prefix("/show/") {
        let node = percent_decode(rest);
        if state.repo.graph.idx(&node).is_err() {
            return respond_json(stream, 404, &err_json(&format!("no node named `{node}`")));
        }
        let report = super::ShowRequest { node }.run(&state.repo)?;
        return respond_json(stream, 200, &report.to_json());
    }
    if let Some(rest) = path.strip_prefix("/checkpoint/") {
        return serve_checkpoint(state, stream, &percent_decode(rest));
    }
    if let Some(rest) = path.strip_prefix("/object/") {
        return serve_object(state, stream, rest);
    }
    if let Some(rest) = path.strip_prefix("/diff/") {
        let segs: Vec<&str> = rest.split('/').collect();
        if segs.len() != 2 {
            return respond_json(
                stream,
                400,
                &err_json("diff wants exactly /diff/<a>/<b> (percent-encode `/` in names)"),
            );
        }
        let (a, b) = (percent_decode(segs[0]), percent_decode(segs[1]));
        let Some(zoo) = &state.zoo else {
            return respond_json(stream, 503, &err_json(NO_MANIFEST));
        };
        if state.repo.graph.idx(&a).is_err() || state.repo.graph.idx(&b).is_err() {
            return respond_json(stream, 404, &err_json("no such node"));
        }
        let report = super::DiffRequest { a, b }.run(&state.repo, zoo, &NativeKernel)?;
        return respond_json(stream, 200, &report.to_json());
    }
    respond_json(stream, 404, &err_json(&format!("no route for `{path}`")))
}

const NO_MANIFEST: &str =
    "server started without an artifacts manifest; arch-dependent endpoints are disabled";

/// Stream a node's resolved checkpoint: the flat f32 parameter vector in
/// layout order, little-endian — bit-exact with what `delta::load`
/// reconstructs. Delta chains resolve through the server's shared cache,
/// so concurrent readers of sibling models reuse common ancestors.
fn serve_checkpoint(state: &ServeState, stream: &mut TcpStream, node: &str) -> Result<()> {
    let Ok(n) = state.repo.graph.by_name(node) else {
        return respond_json(stream, 404, &err_json(&format!("no node named `{node}`")));
    };
    let Some(sm) = &n.stored else {
        return respond_json(
            stream,
            404,
            &err_json(&format!("node `{node}` has no stored checkpoint")),
        );
    };
    let Some(zoo) = &state.zoo else {
        return respond_json(stream, 503, &err_json(NO_MANIFEST));
    };
    let ck = delta::load_with_cache(&state.repo.store, zoo, sm, &NativeKernel, &state.cache)?;
    let body_len = ck.flat.len() * 4;
    write_head(stream, 200, "application/octet-stream", body_len)?;
    // Stream in bounded chunks rather than materializing one giant byte
    // buffer next to the checkpoint.
    const CHUNK: usize = 1 << 20; // 1 Mi f32 values (4 MiB) per write
    for values in ck.flat.chunks(CHUNK) {
        stream.write_all(&f32_to_bytes(values))?;
    }
    stream.flush()?;
    Ok(())
}

/// Serve one stored object's exact bytes — byte-identical to
/// `Store::get`, whichever pack or loose file holds it.
fn serve_object(state: &ServeState, stream: &mut TcpStream, hex: &str) -> Result<()> {
    let Ok(id) = ObjectId::from_hex(hex) else {
        return respond_json(stream, 400, &err_json("object id must be 64 hex chars"));
    };
    if !state.repo.store.has(&id) {
        return respond_json(stream, 404, &err_json(&format!("object {hex} not found")));
    }
    let bytes = state.repo.store.get(&id)?;
    write_head(stream, 200, "application/octet-stream", bytes.len())?;
    stream.write_all(&bytes)?;
    stream.flush()?;
    Ok(())
}

// ---------------------------------------------------------------------------
// HTTP plumbing
// ---------------------------------------------------------------------------

fn status_reason(code: u16) -> &'static str {
    match code {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        503 => "Service Unavailable",
        _ => "Internal Server Error",
    }
}

fn write_head(
    stream: &mut TcpStream,
    code: u16,
    content_type: &str,
    content_length: usize,
) -> Result<()> {
    write!(
        stream,
        "HTTP/1.1 {code} {}\r\nContent-Type: {content_type}\r\n\
         Content-Length: {content_length}\r\nConnection: close\r\n\r\n",
        status_reason(code)
    )?;
    Ok(())
}

fn respond_json(stream: &mut TcpStream, code: u16, body: &Json) -> Result<()> {
    let text = body.to_string_pretty();
    write_head(stream, code, "application/json", text.len())?;
    stream.write_all(text.as_bytes())?;
    stream.flush()?;
    Ok(())
}

fn err_json(msg: &str) -> Json {
    Json::obj().set("error", msg)
}

/// Minimal percent-decoding (`%2F` → `/`, `+` is *not* special — node
/// names may legitimately contain it). Invalid escapes pass through
/// verbatim.
fn percent_decode(s: &str) -> String {
    let bytes = s.as_bytes();
    let mut out = Vec::with_capacity(bytes.len());
    let mut i = 0;
    while i < bytes.len() {
        if bytes[i] == b'%' {
            if let Some(hex) = s.get(i + 1..i + 3) {
                if let Ok(b) = u8::from_str_radix(hex, 16) {
                    out.push(b);
                    i += 3;
                    continue;
                }
            }
        }
        out.push(bytes[i]);
        i += 1;
    }
    String::from_utf8_lossy(&out).into_owned()
}

#[cfg(test)]
mod tests {
    use super::percent_decode;

    #[test]
    fn percent_decoding() {
        assert_eq!(percent_decode("g5%2Fbase-mlm"), "g5/base-mlm");
        assert_eq!(percent_decode("plain"), "plain");
        assert_eq!(percent_decode("a+b"), "a+b");
        assert_eq!(percent_decode("bad%zz"), "bad%zz");
        assert_eq!(percent_decode("trail%2"), "trail%2");
        assert_eq!(percent_decode("%41%42"), "AB");
    }
}
