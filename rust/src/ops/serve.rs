//! `mgit serve`: a dependency-free HTTP/1.1 front-end over the
//! concurrent read tier, optionally write-capable.
//!
//! ## Snapshots
//!
//! Every read request is pinned to one immutable [`Snapshot`] — an
//! `Arc`'d (graph, store, epoch) triple held behind an `RwLock` slot.
//! Readers clone the `Arc` once at dispatch and never observe a torn
//! graph: the writer builds the next snapshot off to the side and swaps
//! the slot atomically after each committed batch, so `/log` and
//! `/checkpoint` reflect new commits without a restart. The shared
//! `Send + Sync` [`crate::store::Store`] and one bounded
//! [`ResolveCache`] span all snapshots (objects are content-addressed
//! and immutable, so the cache is epoch-agnostic). Endpoints:
//!
//! | method+path               | response                                         |
//! |---------------------------|--------------------------------------------------|
//! | `GET /log`                | [`super::LogReport`] JSON                        |
//! | `GET /stats`              | [`super::StatsReport`] JSON (lazy per snapshot)  |
//! | `GET /show/<node>`        | [`super::ShowReport`] JSON                       |
//! | `GET /diff/<a>/<b>`       | [`super::DiffReport`] JSON (needs the manifest)  |
//! | `GET /checkpoint/<node>`  | raw little-endian f32 tensor stream (flat layout |
//! |                           | order), delta chains resolved through the shared |
//! |                           | cache — bit-exact with [`crate::delta::load`];   |
//! |                           | honors single-range `Range: bytes=…` (206/416)   |
//! | `GET /object/<hex-id>`    | the stored object's exact bytes (`Store::get`);  |
//! |                           | honors single-range `Range: bytes=…` (206/416)   |
//! | `HEAD /object/<hex-id>`   | existence + `Content-Length`, no body — what the |
//! |                           | remote tier's `contains` probe rides on          |
//! | `GET /metrics`            | live metrics: per-server request counters and    |
//! |                           | latency histograms plus the process registry     |
//! |                           | (JSON; `?format=prom` for Prometheus text)       |
//! | `GET /healthz`            | `{"ok": true}`                                   |
//! | `POST /object/<hex-id>`   | stage one encoded object ahead of a commit       |
//! | `POST /commit`            | apply one commit-op JSON body                    |
//! | `POST /checkpoint/<node>` | store a raw f32 body (`?arch=<name>&prev=<node>` |
//! |                           | delta-compresses against `prev`) and commit it   |
//! | `POST /admin/repack`      | checkpoint the WAL, repack live, swap snapshots  |
//!
//! Node names may contain `/` (e.g. `g5/base-mlm`): `show` and
//! `checkpoint` treat the whole remaining path as the name, and any
//! segment may percent-encode reserved characters (`%2F`). Method
//! dispatch is route-aware: a known route answers `405` with its own
//! `Allow` header (`GET, HEAD, POST` on `/object/…`, `GET, POST` on
//! `/checkpoint/…`, `POST` on `/commit` and `/admin/repack`, `GET`
//! elsewhere); unknown routes are `404` for every method. A `HEAD`
//! response carries the full head (status, `Content-Length`) and no
//! body. No external HTTP crate, matching
//! the repo's no-new-deps style.
//!
//! ## Write tier
//!
//! Mutating routes exist only when the server was bound with
//! [`Server::bind_writable`] (`mgit serve --writable`); otherwise they
//! answer `403`. Writes are single-writer: one [`WriteState`] mutex
//! owns the authoritative graph and the append-only WAL at
//! `.mgit/wal/wal.log` (see [`crate::store::wal`] for the byte format).
//! Commit durability order is: object put records, then the commit
//! record, then **one fsync**, then in-memory apply, then the snapshot
//! swap — a crash at any byte boundary recovers to exactly the last
//! durable commit ([`super::Repo::open`] replays the log). Every
//! `--fold-every` commits ([`CHECKPOINT_EVERY`] by default, and at
//! shutdown) the log is folded down and truncated: a JSON repo
//! re-serializes the whole `graph.json`, while a binary (MGGI) repo
//! *appends* the folded commit ops to `graph.bin`'s segment tail —
//! O(batch), not O(graph) — compacting the tail into the base image
//! only at shutdown, on admin repack, or once it exceeds
//! [`MAX_TAIL_SEGMENT`] records. Optional guards on the
//! write path: a bearer token (`--auth-token`, else `401`) and a
//! token-bucket rate limit (`--write-rate`, else `429`).
//!
//! ## Keep-alive
//!
//! Connections are HTTP/1.1 persistent by default: a worker serves up to
//! [`MAX_REQUESTS_PER_CONN`] requests per connection, closing early on
//! `Connection: close`, an HTTP/1.0 request line, or ~5 s of idleness
//! between requests (the first request gets a longer 10 s grace). Load
//! clients amortize the TCP handshake across a whole request stream,
//! which is what `benches/serve_load.rs` measures.
//!
//! ## Observability
//!
//! Every server owns a *per-instance* [`Registry`] (concurrent servers
//! in one process — tests — must not bleed request counts into each
//! other): request/byte counters, per-endpoint and per-status counters,
//! an in-flight gauge, request- and write-latency histograms, and a
//! `snapshot.swaps` counter. `GET /metrics` renders that registry
//! alongside the process-global one ([`crate::obs::global`]: store
//! reads, payload decodes, WAL appends/replays, cascade timings).
//! Metrics for a request are recorded *before* its first response byte
//! is written, so once a client has read a response, a subsequent
//! `/metrics` fetch is guaranteed to include it — the property the
//! integration tests pin down. `--log-requests` adds a one-line JSON
//! record per request on stderr.

use std::io::Write;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex, OnceLock, RwLock};
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use crate::checkpoint::{Checkpoint, ModelZoo};
use crate::delta::{self, CompressConfig, NativeKernel, ResolveCache, StoredModel};
use crate::lineage::store::{GRAPH_FOLDS, GRAPH_FOLD_MICROS};
use crate::lineage::{binfmt, GraphStore, LineageGraph};
use crate::obs::{Counter, Gauge, Histogram, Registry};
use crate::store::pack::RepackMode;
use crate::store::{wal, ObjectId, Store};
use crate::tensor::{bytes_to_f32, f32_to_bytes};
use crate::util::json::{self, Json};

use super::{Report, Repo};

/// Hard cap on requests served over one persistent connection: bounds
/// how long a single client can monopolize a pool worker.
pub const MAX_REQUESTS_PER_CONN: u64 = 1000;

/// Default for `--fold-every`: fold the WAL into the on-disk graph
/// (and truncate the log) every this many commits; also happens at
/// shutdown. Bounds replay work after a crash without putting graph
/// serialization on every commit.
pub const CHECKPOINT_EVERY: u64 = 64;

/// A binary graph folds by *appending* records to `graph.bin`'s
/// segment tail; once the tail would exceed this many records it is
/// compacted into the base image instead (bounds tail replay work at
/// `Repo::open` time).
pub const MAX_TAIL_SEGMENT: u64 = 1024;

/// Largest request body accepted (matches the WAL's own record cap).
pub const MAX_BODY: usize = 1 << 30;

/// Summary returned when a server shuts down.
pub struct ServeReport {
    pub requests: u64,
    pub errors: u64,
    pub pool: usize,
    /// Whether the server accepted writes.
    pub writable: bool,
    /// Commits applied over the server's lifetime.
    pub commits: u64,
    /// Snapshot epochs published (commits + admin repacks).
    pub snapshot_swaps: u64,
}

impl Report for ServeReport {
    fn to_json(&self) -> Json {
        Json::obj()
            .set("requests", self.requests)
            .set("errors", self.errors)
            .set("pool", self.pool)
            .set("writable", self.writable)
            .set("commits", self.commits)
            .set("snapshot_swaps", self.snapshot_swaps)
    }
}

// ---------------------------------------------------------------------------
// Per-server metrics
// ---------------------------------------------------------------------------

/// Endpoint labels for per-endpoint request counters. `other` absorbs
/// unmatched paths (404s on unknown routes).
const ENDPOINTS: [&str; 11] = [
    "admin",
    "checkpoint",
    "commit",
    "diff",
    "healthz",
    "log",
    "metrics",
    "object",
    "other",
    "show",
    "stats",
];

/// Status codes with dedicated counters; anything else lands in
/// `status.other`.
const STATUSES: [u16; 13] =
    [200, 206, 400, 401, 403, 404, 405, 409, 413, 416, 429, 500, 503];

/// One server's request metrics: a private [`Registry`] plus handles
/// resolved once at bind time, so the per-request path is pure relaxed
/// atomics (the registry mutex is never taken while serving).
struct ServeMetrics {
    registry: Registry,
    requests_total: Arc<Counter>,
    bytes_sent: Arc<Counter>,
    request_micros: Arc<Histogram>,
    /// Write-route handler latency (commit/object/checkpoint/repack).
    write_micros: Arc<Histogram>,
    /// Snapshot epochs published by the write tier.
    snapshot_swaps: Arc<Counter>,
    inflight: Arc<Gauge>,
    connections: Arc<Counter>,
    endpoints: Vec<(&'static str, Arc<Counter>)>,
    statuses: Vec<(u16, Arc<Counter>)>,
    status_other: Arc<Counter>,
    // Mirrors of the shared ResolveCache's own atomics, refreshed at
    // /metrics scrape time (the cache is the source of truth; mirroring
    // keeps the hot cache paths free of registry coupling).
    cache_hits: Arc<Counter>,
    cache_misses: Arc<Counter>,
    cache_evictions: Arc<Counter>,
    cache_resident: Arc<Gauge>,
}

impl ServeMetrics {
    fn new() -> ServeMetrics {
        let registry = Registry::new();
        let requests_total = registry.counter("requests_total");
        let bytes_sent = registry.counter("bytes_sent_total");
        let request_micros = registry.histogram("request_micros");
        let write_micros = registry.histogram("write_micros");
        let snapshot_swaps = registry.counter("snapshot.swaps");
        let inflight = registry.gauge("inflight");
        let connections = registry.counter("connections_total");
        let endpoints = ENDPOINTS
            .iter()
            .map(|e| (*e, registry.counter(&format!("endpoint.{e}"))))
            .collect();
        let statuses = STATUSES
            .iter()
            .map(|c| (*c, registry.counter(&format!("status.{c}"))))
            .collect();
        let status_other = registry.counter("status.other");
        let cache_hits = registry.counter("cache.hits");
        let cache_misses = registry.counter("cache.misses");
        let cache_evictions = registry.counter("cache.evictions");
        let cache_resident = registry.gauge("cache.resident_bytes");
        ServeMetrics {
            registry,
            requests_total,
            bytes_sent,
            request_micros,
            write_micros,
            snapshot_swaps,
            inflight,
            connections,
            endpoints,
            statuses,
            status_other,
            cache_hits,
            cache_misses,
            cache_evictions,
            cache_resident,
        }
    }

    fn endpoint(&self, name: &str) -> &Counter {
        self.endpoints
            .iter()
            .find(|(n, _)| *n == name)
            .or_else(|| self.endpoints.iter().find(|(n, _)| *n == "other"))
            .map(|(_, c)| c.as_ref())
            .expect("`other` endpoint counter always registered")
    }

    fn status(&self, code: u16) -> &Counter {
        self.statuses
            .iter()
            .find(|(c, _)| *c == code)
            .map(|(_, c)| c.as_ref())
            .unwrap_or(self.status_other.as_ref())
    }

    /// Refresh the ResolveCache mirror metrics (scrape-time only).
    fn sync_cache(&self, cache: &ResolveCache) {
        let (hits, misses) = cache.counters();
        self.cache_hits.store(hits);
        self.cache_misses.store(misses);
        self.cache_evictions.store(cache.evictions());
        self.cache_resident.set(cache.resident_bytes() as i64);
    }
}

/// RAII in-flight marker: decrements the gauge however the request
/// handler exits (including error paths).
struct InflightGuard<'a>(&'a Gauge);

impl<'a> InflightGuard<'a> {
    fn new(g: &'a Gauge) -> InflightGuard<'a> {
        g.inc();
        InflightGuard(g)
    }
}

impl Drop for InflightGuard<'_> {
    fn drop(&mut self) {
        self.0.dec();
    }
}

// ---------------------------------------------------------------------------
// Snapshots and the write state
// ---------------------------------------------------------------------------

/// One immutable published view of the repository. Readers clone the
/// `Arc` at dispatch time and keep it for the whole request, so a
/// concurrent commit (which swaps the slot, never mutates a published
/// snapshot) can't tear a response.
struct Snapshot {
    /// A [`GraphStore`] so a mapped binary repo serves paged `/log`
    /// and `/show` without ever materializing the full node set; the
    /// whole-graph routes reach the eager image through auto-deref.
    graph: Arc<GraphStore>,
    /// Shared across snapshots except after an admin repack, which
    /// publishes a freshly opened store (old `Arc`s keep resolving:
    /// live repacks retain loose copies and never delete sealed packs).
    store: Arc<Store>,
    /// Monotonic publish counter, starting at 1 for the bind snapshot.
    epoch: u64,
    /// `/stats` response, computed lazily on first request against this
    /// snapshot (it walks every object; commits would invalidate it, so
    /// the old bind-time precompute is now per-epoch).
    stats: OnceLock<Json>,
}

/// The single-writer side: authoritative graph plus the open WAL. All
/// mutating routes funnel through this mutex.
struct WriteState {
    graph: LineageGraph,
    wal: wal::Wal,
    /// Commits since the WAL was last folded into the on-disk graph.
    since_checkpoint: u64,
    /// Commit ops accumulated since the last fold, in apply order — a
    /// binary graph folds by appending exactly these to its tail.
    pending_ops: Vec<Json>,
    /// Whether the on-disk graph is the binary (MGGI) `graph.bin`.
    binary: bool,
    /// Records currently in `graph.bin`'s segment tail.
    tail_records: u64,
}

/// Options for [`Server::bind_writable`].
pub struct WriteConfig {
    /// Require `Authorization: Bearer <token>` on mutating routes.
    pub auth_token: Option<String>,
    /// Token-bucket rate limit on mutating requests (per second;
    /// `None`/0 disables).
    pub rate_per_sec: Option<u64>,
    /// Fold the WAL into the on-disk graph every this many commits
    /// (`--fold-every`; clamped to ≥ 1). [`CHECKPOINT_EVERY`] is the
    /// conventional default.
    pub fold_every: u64,
}

/// Minimal token bucket: refills continuously at `per_sec`, holds at
/// most one second's burst.
struct TokenBucket {
    tokens: f64,
    last: Instant,
    per_sec: f64,
}

impl TokenBucket {
    fn new(per_sec: u64) -> TokenBucket {
        let per_sec = per_sec.max(1) as f64;
        TokenBucket { tokens: per_sec, last: Instant::now(), per_sec }
    }

    fn take(&mut self) -> bool {
        let now = Instant::now();
        let dt = now.duration_since(self.last).as_secs_f64();
        self.last = now;
        self.tokens = (self.tokens + dt * self.per_sec).min(self.per_sec);
        if self.tokens >= 1.0 {
            self.tokens -= 1.0;
            true
        } else {
            false
        }
    }
}

// ---------------------------------------------------------------------------
// Server
// ---------------------------------------------------------------------------

/// Shared serving state (one per server).
struct ServeState {
    root: PathBuf,
    /// The published snapshot slot; see [`Snapshot`].
    snapshot: RwLock<Arc<Snapshot>>,
    /// Arch specs for `/diff` and `/checkpoint`; None when no artifacts
    /// manifest was found (those endpoints answer 503).
    zoo: Option<ModelZoo>,
    /// Shared across workers so concurrent chain walks reuse resolved
    /// ancestors (PR 2's bounded LRU).
    cache: ResolveCache,
    metrics: ServeMetrics,
    /// Present iff the server accepts writes.
    writer: Option<Mutex<WriteState>>,
    auth_token: Option<String>,
    rate: Option<Mutex<TokenBucket>>,
    /// Commits between WAL folds (from [`WriteConfig::fold_every`]).
    fold_every: u64,
    epoch: AtomicU64,
    commits: AtomicU64,
    /// Emit a one-line JSON record per request on stderr.
    log_requests: AtomicBool,
    stop: AtomicBool,
    requests: AtomicU64,
    errors: AtomicU64,
}

/// A bound-but-not-yet-serving HTTP server.
pub struct Server {
    listener: TcpListener,
    state: Arc<ServeState>,
    pool: usize,
}

/// Cloneable handle used to stop a running [`Server`] (tests, signal
/// handlers).
#[derive(Clone)]
pub struct ServerHandle {
    state: Arc<ServeState>,
    addr: SocketAddr,
}

impl ServerHandle {
    /// Ask the accept loop to exit. Safe to call more than once.
    pub fn shutdown(&self) {
        self.state.stop.store(true, Ordering::SeqCst);
        // Unblock the blocking `accept` with one throwaway connection.
        let _ = TcpStream::connect(self.addr);
    }

    pub fn addr(&self) -> SocketAddr {
        self.addr
    }
}

impl Server {
    /// Bind `127.0.0.1:port` (`port = 0` picks an ephemeral port) over
    /// an opened repository, read-only. `pool` worker threads serve
    /// requests (clamped to ≥ 1); size it with [`crate::util::auto_jobs`].
    pub fn bind(repo: Repo, zoo: Option<ModelZoo>, port: u16, pool: usize) -> Result<Server> {
        Self::bind_inner(repo, zoo, port, pool, None)
    }

    /// Bind a write-capable server (`mgit serve --writable`): folds any
    /// replayed WAL into `graph.json`, opens a fresh log, and enables
    /// the POST routes guarded by `cfg`.
    pub fn bind_writable(
        repo: Repo,
        zoo: Option<ModelZoo>,
        port: u16,
        pool: usize,
        cfg: WriteConfig,
    ) -> Result<Server> {
        Self::bind_inner(repo, zoo, port, pool, Some(cfg))
    }

    fn bind_inner(
        repo: Repo,
        zoo: Option<ModelZoo>,
        port: u16,
        pool: usize,
        write: Option<WriteConfig>,
    ) -> Result<Server> {
        let listener = TcpListener::bind(("127.0.0.1", port))
            .with_context(|| format!("binding 127.0.0.1:{port}"))?;
        let Repo { root, graph, store } = repo;
        let writer = match &write {
            None => None,
            Some(_) => {
                // `Repo::open` already replayed any leftover WAL into
                // `graph`; persist that (a binary repo compacts its
                // tail) and start from an empty log so the bind
                // snapshot and the log agree.
                graph.persist(&Repo::mgit_dir(&root))?;
                let mut wal = wal::Wal::open_append(&root)?;
                wal.truncate()?;
                Some(Mutex::new(WriteState {
                    graph: graph.clone_full()?,
                    wal,
                    since_checkpoint: 0,
                    pending_ops: Vec::new(),
                    binary: Repo::graph_bin_path(&root).exists(),
                    tail_records: 0,
                }))
            }
        };
        let fold_every =
            write.as_ref().map_or(CHECKPOINT_EVERY, |cfg| cfg.fold_every.max(1));
        let (auth_token, rate) = match write {
            None => (None, None),
            Some(cfg) => (
                cfg.auth_token,
                cfg.rate_per_sec
                    .filter(|r| *r > 0)
                    .map(|r| Mutex::new(TokenBucket::new(r))),
            ),
        };
        let snapshot = Snapshot {
            graph: Arc::new(graph),
            store: Arc::new(store),
            epoch: 1,
            stats: OnceLock::new(),
        };
        let state = Arc::new(ServeState {
            root,
            snapshot: RwLock::new(Arc::new(snapshot)),
            zoo,
            cache: ResolveCache::with_max_bytes(128, 256 << 20),
            metrics: ServeMetrics::new(),
            writer,
            auth_token,
            rate,
            fold_every,
            epoch: AtomicU64::new(1),
            commits: AtomicU64::new(0),
            log_requests: AtomicBool::new(false),
            stop: AtomicBool::new(false),
            requests: AtomicU64::new(0),
            errors: AtomicU64::new(0),
        });
        Ok(Server { listener, state, pool: pool.max(1) })
    }

    /// Toggle per-request stderr logging (`mgit serve --log-requests`).
    pub fn with_log_requests(self, on: bool) -> Server {
        self.state.log_requests.store(on, Ordering::Relaxed);
        self
    }

    pub fn local_addr(&self) -> Result<SocketAddr> {
        Ok(self.listener.local_addr()?)
    }

    pub fn pool(&self) -> usize {
        self.pool
    }

    pub fn handle(&self) -> Result<ServerHandle> {
        Ok(ServerHandle { state: Arc::clone(&self.state), addr: self.local_addr()? })
    }

    /// Accept connections until [`ServerHandle::shutdown`], dispatching
    /// them to the bounded worker pool. Blocks the calling thread. A
    /// writable server checkpoints its WAL into `graph.json` on the way
    /// out, so a clean shutdown leaves an empty log.
    pub fn serve(self) -> Result<ServeReport> {
        // Bounded hand-off: when every worker is busy and the queue is
        // full, the accept loop blocks in `send`, which backpressures to
        // the kernel listen queue instead of buffering unboundedly.
        let (tx, rx) = mpsc::sync_channel::<TcpStream>(self.pool * 2);
        let rx = Arc::new(Mutex::new(rx));
        std::thread::scope(|scope| {
            for _ in 0..self.pool {
                let rx = Arc::clone(&rx);
                let state = Arc::clone(&self.state);
                scope.spawn(move || loop {
                    // Hold the receiver lock only for the dequeue.
                    let conn = rx.lock().unwrap().recv();
                    match conn {
                        Ok(stream) => handle_connection(&state, stream),
                        Err(_) => break, // accept loop ended
                    }
                });
            }
            for conn in self.listener.incoming() {
                if self.state.stop.load(Ordering::SeqCst) {
                    break;
                }
                let Ok(stream) = conn else { continue };
                if tx.send(stream).is_err() {
                    break;
                }
            }
            drop(tx); // workers drain the queue, then exit
        });
        if let Some(wm) = &self.state.writer {
            let mut ws = wm.lock().unwrap();
            if let Err(e) = checkpoint_writer(&self.state, &mut ws, true) {
                eprintln!("warning: final WAL checkpoint failed: {e:#}");
            }
        }
        Ok(ServeReport {
            requests: self.state.requests.load(Ordering::Relaxed),
            errors: self.state.errors.load(Ordering::Relaxed),
            pool: self.pool,
            writable: self.state.writer.is_some(),
            commits: self.state.commits.load(Ordering::Relaxed),
            snapshot_swaps: self.state.metrics.snapshot_swaps.get(),
        })
    }
}

/// Fold the writer's pending commits into the on-disk graph, then
/// truncate the WAL. Crash-safe in that order: a crash between the two
/// replays the log against an already-updated graph, which
/// `apply_commit` treats as a no-op per record.
///
/// A JSON repo re-serializes the whole `graph.json` (O(graph)). A
/// binary (MGGI) repo appends the pending ops to `graph.bin`'s segment
/// tail (O(batch)) — unless `compact` is forced (shutdown, admin
/// repack) or the tail would outgrow [`MAX_TAIL_SEGMENT`], in which
/// case the base image is rewritten and the tail emptied.
fn checkpoint_writer(state: &ServeState, ws: &mut WriteState, compact: bool) -> Result<()> {
    let t = Instant::now();
    if ws.binary {
        let path = Repo::graph_bin_path(&state.root);
        if compact || ws.tail_records + ws.pending_ops.len() as u64 > MAX_TAIL_SEGMENT {
            binfmt::write_binary(&ws.graph, &path)?;
            ws.tail_records = 0;
        } else {
            binfmt::append_commits(&path, &ws.pending_ops)?;
            ws.tail_records += ws.pending_ops.len() as u64;
        }
    } else {
        ws.graph.save(&Repo::graph_path(&state.root))?;
    }
    ws.wal.truncate()?;
    ws.pending_ops.clear();
    ws.since_checkpoint = 0;
    GRAPH_FOLDS.inc();
    GRAPH_FOLD_MICROS.observe(t.elapsed().as_micros() as u64);
    Ok(())
}

/// Publish a new immutable snapshot (epoch bump + atomic slot swap).
fn publish_snapshot(state: &ServeState, graph: &LineageGraph, store: Arc<Store>) -> u64 {
    let epoch = state.epoch.fetch_add(1, Ordering::SeqCst) + 1;
    let snap = Arc::new(Snapshot {
        graph: Arc::new(GraphStore::from_graph(graph.clone())),
        store,
        epoch,
        stats: OnceLock::new(),
    });
    *state.snapshot.write().unwrap() = snap;
    state.metrics.snapshot_swaps.inc();
    epoch
}

/// `/stats` for one snapshot, computed on first request (a benign race
/// may compute it twice; `OnceLock` keeps one).
fn snapshot_stats(state: &ServeState, snap: &Snapshot) -> Result<Json> {
    if let Some(j) = snap.stats.get() {
        return Ok(j.clone());
    }
    let j = super::StatsRequest.run_on(&state.root, &snap.store)?.to_json();
    let _ = snap.stats.set(j.clone());
    Ok(j)
}

// ---------------------------------------------------------------------------
// The single-writer commit path
// ---------------------------------------------------------------------------

/// A write-path failure: either a client error with a status code, or
/// an internal error that escapes to the generic 500 handler.
enum WriteError {
    Reject(u16, String),
    Internal(anyhow::Error),
}

impl From<anyhow::Error> for WriteError {
    fn from(e: anyhow::Error) -> WriteError {
        WriteError::Internal(e)
    }
}

fn reject(code: u16, msg: impl Into<String>) -> WriteError {
    WriteError::Reject(code, msg.into())
}

struct CommitDone {
    epoch: u64,
    new_objects: usize,
    nodes: usize,
}

/// Apply one commit under the writer lock: validate against the
/// authoritative graph, WAL the object puts and the commit record,
/// fsync once, apply in memory, maybe checkpoint, and publish the new
/// snapshot. `objects` are puts batched with this commit (the commit
/// may also reference objects staged earlier via `POST /object`).
fn writer_commit(
    state: &ServeState,
    objects: &[(ObjectId, Vec<u8>)],
    op: &Json,
) -> Result<CommitDone, WriteError> {
    let wm = state.writer.as_ref().expect("dispatch gates writes on state.writer");
    let mut ws = wm.lock().unwrap();
    let name = op.req_str("name").map_err(|e| reject(400, format!("{e:#}")))?;
    if name.is_empty() {
        return Err(reject(400, "node name must be non-empty"));
    }
    if ws.graph.idx(name).is_ok() {
        return Err(reject(409, format!("node `{name}` already exists")));
    }
    let model_type = op
        .req_str("model_type")
        .map_err(|e| reject(400, format!("{e:#}")))?
        .to_string();
    let store = Arc::clone(&state.snapshot.read().unwrap().store);
    match op.get("stored") {
        None | Some(Json::Null) => {}
        Some(j) => {
            let sm = StoredModel::from_json(j)
                .map_err(|e| reject(400, format!("invalid stored model: {e:#}")))?;
            for (pname, id) in &sm.params {
                if !store.has(id) && !objects.iter().any(|(oid, _)| oid == id) {
                    return Err(reject(
                        409,
                        format!(
                            "param `{pname}` references object {} that is neither \
                             stored nor in this batch; POST /object first",
                            id.hex()
                        ),
                    ));
                }
            }
        }
    }
    if let Some(parents) = op.get("prov_parents") {
        let arr = parents
            .as_arr()
            .ok_or_else(|| reject(400, "prov_parents must be an array"))?;
        for p in arr {
            let pname = p
                .as_str()
                .ok_or_else(|| reject(400, "prov_parents entries must be strings"))?;
            if ws.graph.idx(pname).is_err() {
                return Err(reject(400, format!("unknown prov parent `{pname}`")));
            }
        }
    }
    match op.get("ver_parent") {
        None | Some(Json::Null) => {}
        Some(v) => {
            let vname = v
                .as_str()
                .ok_or_else(|| reject(400, "ver_parent must be a string"))?;
            let vn = ws
                .graph
                .by_name(vname)
                .map_err(|_| reject(400, format!("unknown ver parent `{vname}`")))?;
            if vn.model_type != model_type {
                return Err(reject(
                    400,
                    format!(
                        "ver parent `{vname}` has model type `{}`, commit says `{model_type}`",
                        vn.model_type
                    ),
                ));
            }
        }
    }
    // Durability order: puts, commit record, one fsync. Only after the
    // batch is durable does it become visible (graph apply + swap).
    let mut new_objects = 0usize;
    for (id, bytes) in objects {
        if store.put_via_wal(&mut ws.wal, *id, bytes)? {
            new_objects += 1;
        }
    }
    ws.wal.append(&wal::WalRecord::Commit { op: op.clone() })?;
    ws.wal.sync()?;
    let applied = ws.graph.apply_commit(op)?;
    debug_assert!(applied, "validated commit must apply");
    ws.pending_ops.push(op.clone());
    ws.since_checkpoint += 1;
    if ws.since_checkpoint >= state.fold_every {
        checkpoint_writer(state, &mut ws, false)?;
    }
    let epoch = publish_snapshot(state, &ws.graph, store);
    state.commits.fetch_add(1, Ordering::Relaxed);
    Ok(CommitDone { epoch, new_objects, nodes: ws.graph.len() })
}

// ---------------------------------------------------------------------------
// Per-connection handling
// ---------------------------------------------------------------------------

fn handle_connection(state: &ServeState, stream: TcpStream) {
    state.metrics.connections.inc();
    let _ = stream.set_read_timeout(Some(Duration::from_secs(10)));
    let _ = stream.set_write_timeout(Some(Duration::from_secs(60)));
    if handle_http(state, stream).is_err() {
        state.errors.fetch_add(1, Ordering::Relaxed);
    }
}

/// One in-flight request's response side: writes the head exactly once
/// and records the request's metrics (status/endpoint/latency/bytes —
/// plus the optional stderr log line) *immediately before* the head
/// bytes go out. By the time a client has a response, its request is in
/// the metrics, so `/metrics` reads are deterministic for settled
/// traffic; the `/metrics` handler itself snapshots before its own head
/// and is therefore excluded from its own output.
struct ResponseWriter<'a> {
    stream: &'a mut TcpStream,
    metrics: &'a ServeMetrics,
    log_requests: bool,
    keep_alive: bool,
    method: &'a str,
    path: &'a str,
    endpoint: &'static str,
    start: Instant,
    recorded: bool,
    /// `HEAD` request: write every head (status, `Content-Length`,
    /// extra headers) exactly as `GET` would, but no body bytes.
    head_only: bool,
}

impl ResponseWriter<'_> {
    fn record(&mut self, code: u16, body_len: usize) {
        if self.recorded {
            return;
        }
        self.recorded = true;
        let micros = self.start.elapsed().as_micros() as u64;
        self.metrics.requests_total.inc();
        self.metrics.status(code).inc();
        self.metrics.endpoint(self.endpoint).inc();
        self.metrics.bytes_sent.add(body_len as u64);
        self.metrics.request_micros.observe(micros);
        if self.log_requests {
            let line = Json::obj()
                .set("method", self.method)
                .set("path", self.path)
                .set("status", code as usize)
                .set("bytes", body_len)
                .set("micros", micros)
                .to_string_compact();
            eprintln!("{line}");
        }
    }

    fn write_head(&mut self, code: u16, content_type: &str, len: usize) -> Result<()> {
        self.write_head_with(code, content_type, len, &[])
    }

    fn write_head_with(
        &mut self,
        code: u16,
        content_type: &str,
        len: usize,
        extra: &[(&str, &str)],
    ) -> Result<()> {
        self.record(code, len);
        write!(
            self.stream,
            "HTTP/1.1 {code} {}\r\nContent-Type: {content_type}\r\nContent-Length: {len}\r\n",
            status_reason(code)
        )?;
        for (k, v) in extra {
            write!(self.stream, "{k}: {v}\r\n")?;
        }
        let conn = if self.keep_alive { "keep-alive" } else { "close" };
        write!(self.stream, "Connection: {conn}\r\n\r\n")?;
        Ok(())
    }

    fn respond_json(&mut self, code: u16, body: &Json) -> Result<()> {
        self.respond_json_with(code, body, &[])
    }

    fn respond_json_with(
        &mut self,
        code: u16,
        body: &Json,
        extra: &[(&str, &str)],
    ) -> Result<()> {
        let text = body.to_string_pretty();
        self.write_head_with(code, "application/json", text.len(), extra)?;
        self.write_body(text.as_bytes())
    }

    /// Write a response body — skipped (head already advertised the
    /// length) on a `HEAD` request.
    fn write_body(&mut self, bytes: &[u8]) -> Result<()> {
        if !self.head_only {
            self.stream.write_all(bytes)?;
        }
        self.stream.flush()?;
        Ok(())
    }
}

/// One parsed request, body already read (framing is handled before
/// dispatch so keep-alive survives error responses).
struct Request<'a> {
    method: &'a str,
    path: &'a str,
    query: &'a str,
    body: &'a [u8],
    /// `Authorization: Bearer <token>` value, when present.
    auth: Option<&'a str>,
    /// Raw `Range:` header value, when present.
    range: Option<&'a str>,
}

/// Serve one connection's request stream (HTTP/1.1 keep-alive).
fn handle_http(state: &ServeState, mut stream: TcpStream) -> Result<()> {
    use std::io::{BufRead, BufReader, Read};
    // Bound how much request-line + header data one request can make us
    // buffer: `read_line` grows its String until a newline arrives, so an
    // un-capped reader would let a newline-free byte stream grow a
    // worker's memory without ever tripping the per-read timeout. The cap
    // is re-armed per request.
    let mut reader = BufReader::new(stream.try_clone()?.take(16 * 1024));
    let mut served = 0u64;
    loop {
        reader.get_mut().set_limit(16 * 1024);
        let mut line = String::new();
        match reader.read_line(&mut line) {
            Ok(0) => return Ok(()), // clean EOF (client closed)
            Ok(_) => {}
            Err(e)
                if served > 0
                    && matches!(
                        e.kind(),
                        std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                    ) =>
            {
                // Idle keep-alive connection timed out: a clean close,
                // not a served-request error.
                return Ok(());
            }
            Err(e) => return Err(e.into()),
        }
        if line.trim().is_empty() {
            // No request line: the shutdown wake-up connection (or a
            // client that sent a bare newline and went away).
            return Ok(());
        }
        let mut parts = line.split_whitespace();
        let method = parts.next().unwrap_or("").to_string();
        let target = parts.next().unwrap_or("").to_string();
        let version = parts.next().unwrap_or("HTTP/1.1");
        // HTTP/1.0 defaults to close; 1.1 to keep-alive. An explicit
        // `Connection:` header wins either way.
        let mut close = version == "HTTP/1.0";
        let mut content_length = 0usize;
        let mut bad_content_length = false;
        let mut chunked = false;
        let mut auth_bearer: Option<String> = None;
        let mut range_header: Option<String> = None;
        loop {
            let mut h = String::new();
            if reader.read_line(&mut h)? == 0 || h == "\r\n" || h == "\n" {
                break;
            }
            // Only the header *name* is case-insensitive; values (bearer
            // tokens in particular) must pass through untouched.
            let Some((hname, hval)) = h.split_once(':') else { continue };
            let hname = hname.trim().to_ascii_lowercase();
            let hval = hval.trim();
            match hname.as_str() {
                "connection" => match hval.to_ascii_lowercase().as_str() {
                    "close" => close = true,
                    "keep-alive" => close = false,
                    _ => {}
                },
                "content-length" => match hval.parse::<usize>() {
                    Ok(n) => content_length = n,
                    Err(_) => bad_content_length = true,
                },
                "transfer-encoding" => {
                    if hval.to_ascii_lowercase().contains("chunked") {
                        chunked = true;
                    }
                }
                "authorization" => {
                    if let Some(tok) = hval
                        .strip_prefix("Bearer ")
                        .or_else(|| hval.strip_prefix("bearer "))
                    {
                        auth_bearer = Some(tok.trim().to_string());
                    }
                }
                "range" => range_header = Some(hval.to_string()),
                _ => {}
            }
        }
        served += 1;
        let keep_alive = !close && served < MAX_REQUESTS_PER_CONN;
        let _inflight = InflightGuard::new(&state.metrics.inflight);
        let (path, query) = match target.split_once('?') {
            Some((p, q)) => (p.to_string(), q.to_string()),
            None => (target.clone(), String::new()),
        };
        let mut rw = ResponseWriter {
            stream: &mut stream,
            metrics: &state.metrics,
            log_requests: state.log_requests.load(Ordering::Relaxed),
            keep_alive,
            method: &method,
            path: &path,
            endpoint: "other",
            start: Instant::now(),
            recorded: false,
            head_only: method == "HEAD",
        };
        // Framing errors close the connection: we can't locate the next
        // request boundary without a trustworthy body length.
        if bad_content_length || chunked {
            rw.keep_alive = false;
            let msg = if chunked {
                "chunked request bodies are not supported; send Content-Length"
            } else {
                "invalid Content-Length"
            };
            rw.respond_json(400, &err_json(msg))?;
            state.requests.fetch_add(1, Ordering::Relaxed);
            return Ok(());
        }
        if content_length > MAX_BODY {
            rw.keep_alive = false;
            rw.respond_json(
                413,
                &err_json(&format!("request body exceeds {MAX_BODY} bytes")),
            )?;
            state.requests.fetch_add(1, Ordering::Relaxed);
            return Ok(());
        }
        // Read the body on every method (even ones we'll reject) so the
        // keep-alive stream stays framed.
        let mut body = vec![0u8; content_length];
        if content_length > 0 {
            reader.get_mut().set_limit(content_length as u64);
            reader.read_exact(&mut body)?;
        }
        let req = Request {
            method: &method,
            path: &path,
            query: &query,
            body: &body,
            auth: auth_bearer.as_deref(),
            range: range_header.as_deref(),
        };
        if let Err(e) = dispatch(state, &mut rw, &req) {
            // Route handlers answer their own 4xx; anything that
            // *escapes* is an internal error. Best-effort 500 unless a
            // head already went out (the client may be gone either way).
            if !rw.recorded {
                let _ = rw.respond_json(500, &err_json(&format!("{e:#}")));
            }
            bail!("internal error serving {path}: {e:#}");
        }
        state.requests.fetch_add(1, Ordering::Relaxed);
        if !keep_alive {
            return Ok(());
        }
        // Idle budget between keep-alive requests is tighter than the
        // first-request grace: a parked connection frees its worker fast.
        let _ = stream.set_read_timeout(Some(Duration::from_secs(5)));
    }
}

// ---------------------------------------------------------------------------
// Routing
// ---------------------------------------------------------------------------

enum Route<'a> {
    Log,
    Stats,
    Metrics,
    Healthz,
    Show(&'a str),
    Diff(&'a str),
    Checkpoint(&'a str),
    Object(&'a str),
    Commit,
    AdminRepack,
    Unknown,
}

fn parse_route(path: &str) -> Route<'_> {
    match path {
        "/log" => Route::Log,
        "/stats" => Route::Stats,
        "/metrics" => Route::Metrics,
        "/healthz" => Route::Healthz,
        "/commit" => Route::Commit,
        "/admin/repack" => Route::AdminRepack,
        _ => {
            if let Some(r) = path.strip_prefix("/show/") {
                Route::Show(r)
            } else if let Some(r) = path.strip_prefix("/checkpoint/") {
                Route::Checkpoint(r)
            } else if let Some(r) = path.strip_prefix("/object/") {
                Route::Object(r)
            } else if let Some(r) = path.strip_prefix("/diff/") {
                Route::Diff(r)
            } else {
                Route::Unknown
            }
        }
    }
}

impl Route<'_> {
    fn endpoint(&self) -> &'static str {
        match self {
            Route::Log => "log",
            Route::Stats => "stats",
            Route::Metrics => "metrics",
            Route::Healthz => "healthz",
            Route::Show(_) => "show",
            Route::Diff(_) => "diff",
            Route::Checkpoint(_) => "checkpoint",
            Route::Object(_) => "object",
            Route::Commit => "commit",
            Route::AdminRepack => "admin",
            Route::Unknown => "other",
        }
    }

    /// The `Allow:` header this route advertises on a 405.
    fn allow(&self) -> &'static str {
        match self {
            Route::Object(_) => "GET, HEAD, POST",
            Route::Checkpoint(_) => "GET, POST",
            Route::Commit | Route::AdminRepack => "POST",
            _ => "GET",
        }
    }

    fn allows(&self, method: &str) -> bool {
        match self {
            Route::Object(_) => method == "GET" || method == "HEAD" || method == "POST",
            Route::Checkpoint(_) => method == "GET" || method == "POST",
            Route::Commit | Route::AdminRepack => method == "POST",
            _ => method == "GET",
        }
    }
}

fn dispatch(state: &ServeState, rw: &mut ResponseWriter, req: &Request) -> Result<()> {
    let route = parse_route(req.path);
    rw.endpoint = route.endpoint();
    if matches!(route, Route::Unknown) {
        return rw.respond_json(404, &err_json(&format!("no route for `{}`", req.path)));
    }
    if !route.allows(req.method) {
        return rw.respond_json_with(
            405,
            &err_json(&format!(
                "method {} not allowed here; allowed: {}",
                req.method,
                route.allow()
            )),
            &[("Allow", route.allow())],
        );
    }
    if req.method == "POST" {
        // Write gating, in order: capability, auth, rate.
        if state.writer.is_none() {
            return rw.respond_json(
                403,
                &err_json("server is read-only (start with --writable)"),
            );
        }
        if let Some(expect) = &state.auth_token {
            if req.auth != Some(expect.as_str()) {
                return rw.respond_json_with(
                    401,
                    &err_json("missing or invalid bearer token"),
                    &[("WWW-Authenticate", "Bearer")],
                );
            }
        }
        if let Some(rate) = &state.rate {
            if !rate.lock().unwrap().take() {
                return rw.respond_json(429, &err_json("write rate limit exceeded"));
            }
        }
        let t = Instant::now();
        let res = match route {
            Route::Commit => post_commit(state, rw, req.body),
            Route::AdminRepack => admin_repack(state, rw),
            Route::Object(hex) => post_object(state, rw, hex, req.body),
            Route::Checkpoint(rest) => {
                post_checkpoint(state, rw, &percent_decode(rest), req.query, req.body)
            }
            _ => unreachable!("allows() admits POST only on write routes"),
        };
        state
            .metrics
            .write_micros
            .observe(t.elapsed().as_micros() as u64);
        return res;
    }
    // Read path: pin the whole request to one immutable snapshot.
    let snap = state.snapshot.read().unwrap().clone();
    match route {
        Route::Log => {
            // Bare `/log` keeps its exact historical shape (and bytes);
            // `?limit=<n>[&after=<node>][&type=<t>]` pages through the
            // graph instead, decoding only the visited nodes on a
            // mapped binary repo.
            if req.query.is_empty() {
                let report = super::LogRequest.run_graph(&snap.graph)?;
                return rw.respond_json(200, &report.to_json());
            }
            let mut limit = None;
            let mut after = None;
            let mut model_type = None;
            for kv in req.query.split('&').filter(|kv| !kv.is_empty()) {
                match kv.split_once('=') {
                    Some(("limit", v)) => match v.parse::<usize>() {
                        Ok(n) if n > 0 => limit = Some(n),
                        _ => {
                            return rw.respond_json(
                                400,
                                &err_json("limit must be a positive integer"),
                            )
                        }
                    },
                    Some(("after", v)) => after = Some(percent_decode(v)),
                    Some(("type", v)) => model_type = Some(percent_decode(v)),
                    _ => {
                        return rw.respond_json(
                            400,
                            &err_json(&format!(
                                "unknown /log query parameter `{kv}` \
                                 (want limit, after, type)"
                            )),
                        )
                    }
                }
            }
            let Some(limit) = limit else {
                return rw.respond_json(
                    400,
                    &err_json("paged /log wants ?limit=<n>[&after=<node>][&type=<t>]"),
                );
            };
            let page = super::LogPageRequest { limit, after, model_type };
            match page.run_store(&snap.graph) {
                Ok(report) => rw.respond_json(200, &report.to_json()),
                // The only client-reachable failure is a bad cursor.
                Err(e) => rw.respond_json(404, &err_json(&format!("{e:#}"))),
            }
        }
        Route::Stats => {
            let stats = snapshot_stats(state, &snap)?;
            rw.respond_json(200, &stats)
        }
        Route::Metrics => serve_metrics(state, rw, req.query),
        Route::Healthz => rw.respond_json(200, &Json::obj().set("ok", true)),
        Route::Show(rest) => {
            let node = percent_decode(rest);
            if snap.graph.idx(&node).is_err() {
                return rw.respond_json(404, &err_json(&format!("no node named `{node}`")));
            }
            // One lazy node decode on a mapped binary graph.
            let report = super::ShowRequest { node }.run_store(&snap.graph)?;
            rw.respond_json(200, &report.to_json())
        }
        Route::Checkpoint(rest) => {
            serve_checkpoint(state, &snap, rw, &percent_decode(rest), req.range)
        }
        Route::Object(hex) => serve_object(&snap, rw, hex, req.range),
        Route::Diff(rest) => {
            let segs: Vec<&str> = rest.split('/').collect();
            if segs.len() != 2 {
                return rw.respond_json(
                    400,
                    &err_json("diff wants exactly /diff/<a>/<b> (percent-encode `/` in names)"),
                );
            }
            let (a, b) = (percent_decode(segs[0]), percent_decode(segs[1]));
            let Some(zoo) = &state.zoo else {
                return rw.respond_json(503, &err_json(NO_MANIFEST));
            };
            if snap.graph.idx(&a).is_err() || snap.graph.idx(&b).is_err() {
                return rw.respond_json(404, &err_json("no such node"));
            }
            let report =
                super::DiffRequest { a, b }.run_on(&snap.graph, &snap.store, zoo, &NativeKernel)?;
            rw.respond_json(200, &report.to_json())
        }
        Route::Commit | Route::AdminRepack | Route::Unknown => {
            unreachable!("handled before the read path")
        }
    }
}

const NO_MANIFEST: &str =
    "server started without an artifacts manifest; arch-dependent endpoints are disabled";

// ---------------------------------------------------------------------------
// Read handlers
// ---------------------------------------------------------------------------

/// `GET /metrics`: both registries — this server's request metrics plus
/// the process-global layer telemetry. The snapshot is taken *before*
/// this response's own head is written, so a `/metrics` response never
/// includes itself (keeping "histogram count == requests the client has
/// completed" exact for tests and cross-checking load harnesses).
fn serve_metrics(state: &ServeState, rw: &mut ResponseWriter, query: &str) -> Result<()> {
    state.metrics.sync_cache(&state.cache);
    if query.split('&').any(|kv| kv == "format=prom") {
        let mut out = String::new();
        state.metrics.registry.render_prometheus("mgit_serve_", &mut out);
        crate::obs::global().render_prometheus("mgit_", &mut out);
        rw.write_head(200, "text/plain; version=0.0.4", out.len())?;
        rw.stream.write_all(out.as_bytes())?;
        rw.stream.flush()?;
        return Ok(());
    }
    let body = Json::obj()
        .set("server", state.metrics.registry.snapshot())
        .set("process", crate::obs::global().snapshot());
    rw.respond_json(200, &body)
}

/// Outcome of parsing a `Range:` header against a known body length.
enum RangeParse {
    /// No usable single byte-range: serve the full 200 response.
    Ignore,
    /// Syntactically valid but empty/out-of-bounds: 416.
    Unsatisfiable,
    /// Half-open byte window `[start, end)` within the body.
    Bytes(usize, usize),
}

/// Parse a single-range `bytes=` header (RFC 9110 subset). Multi-range
/// and malformed specs fall back to `Ignore` — a full 200 is always a
/// valid response to a Range request.
fn parse_range(header: &str, total: usize) -> RangeParse {
    let Some(spec) = header.trim().strip_prefix("bytes=") else {
        return RangeParse::Ignore;
    };
    if spec.contains(',') {
        return RangeParse::Ignore;
    }
    let Some((a, b)) = spec.split_once('-') else {
        return RangeParse::Ignore;
    };
    let (a, b) = (a.trim(), b.trim());
    match (a.is_empty(), b.is_empty()) {
        (false, false) => match (a.parse::<usize>(), b.parse::<usize>()) {
            (Ok(start), Ok(last)) => {
                if start > last {
                    RangeParse::Ignore
                } else if start >= total {
                    RangeParse::Unsatisfiable
                } else {
                    RangeParse::Bytes(start, (last + 1).min(total))
                }
            }
            _ => RangeParse::Ignore,
        },
        (false, true) => match a.parse::<usize>() {
            Ok(start) if start < total => RangeParse::Bytes(start, total),
            Ok(_) => RangeParse::Unsatisfiable,
            Err(_) => RangeParse::Ignore,
        },
        (true, false) => match b.parse::<usize>() {
            Ok(0) => RangeParse::Unsatisfiable,
            Ok(n) if total > 0 => RangeParse::Bytes(total.saturating_sub(n), total),
            Ok(_) => RangeParse::Unsatisfiable,
            Err(_) => RangeParse::Ignore,
        },
        (true, true) => RangeParse::Ignore,
    }
}

/// Stream a node's resolved checkpoint: the flat f32 parameter vector in
/// layout order, little-endian — bit-exact with what `delta::load`
/// reconstructs. Delta chains resolve through the server's shared cache,
/// so concurrent readers of sibling models reuse common ancestors. A
/// single `Range: bytes=…` header yields a 206 byte window (416 when
/// unsatisfiable); resumable pulls of multi-GB checkpoints ride on this.
fn serve_checkpoint(
    state: &ServeState,
    snap: &Snapshot,
    rw: &mut ResponseWriter,
    node: &str,
    range: Option<&str>,
) -> Result<()> {
    let Ok(n) = snap.graph.node_by_name(node) else {
        return rw.respond_json(404, &err_json(&format!("no node named `{node}`")));
    };
    let Some(sm) = &n.stored else {
        return rw.respond_json(
            404,
            &err_json(&format!("node `{node}` has no stored checkpoint")),
        );
    };
    let Some(zoo) = &state.zoo else {
        return rw.respond_json(503, &err_json(NO_MANIFEST));
    };
    let ck = delta::load_with_cache(&snap.store, zoo, sm, &NativeKernel, &state.cache)?;
    let total = ck.flat.len() * 4;
    if let Some(header) = range {
        match parse_range(header, total) {
            RangeParse::Ignore => {}
            RangeParse::Unsatisfiable => {
                let content_range = format!("bytes */{total}");
                return rw.respond_json_with(
                    416,
                    &err_json("range not satisfiable"),
                    &[("Content-Range", content_range.as_str())],
                );
            }
            RangeParse::Bytes(start, end) => {
                // Serialize just the f32 window covering [start, end),
                // then trim to the exact byte edges.
                let i0 = start / 4;
                let i1 = (end + 3) / 4;
                let window = f32_to_bytes(&ck.flat[i0..i1]);
                let slice = &window[start - i0 * 4..][..end - start];
                let content_range = format!("bytes {}-{}/{}", start, end - 1, total);
                rw.write_head_with(
                    206,
                    "application/octet-stream",
                    slice.len(),
                    &[("Content-Range", content_range.as_str()), ("Accept-Ranges", "bytes")],
                )?;
                rw.stream.write_all(slice)?;
                rw.stream.flush()?;
                return Ok(());
            }
        }
    }
    rw.write_head_with(200, "application/octet-stream", total, &[("Accept-Ranges", "bytes")])?;
    // Stream in bounded chunks rather than materializing one giant byte
    // buffer next to the checkpoint.
    const CHUNK: usize = 1 << 20; // 1 Mi f32 values (4 MiB) per write
    for values in ck.flat.chunks(CHUNK) {
        rw.stream.write_all(&f32_to_bytes(values))?;
    }
    rw.stream.flush()?;
    Ok(())
}

/// Serve one stored object's exact bytes — byte-identical to
/// `Store::get`, whichever pack or loose file holds it. `HEAD` answers
/// the same heads with no body (the remote tier's cheap existence
/// probe), and a single `Range: bytes=…` header yields a 206 window
/// (416 when unsatisfiable) — resumable cold fills ride on this.
fn serve_object(
    snap: &Snapshot,
    rw: &mut ResponseWriter,
    hex: &str,
    range: Option<&str>,
) -> Result<()> {
    let Ok(id) = ObjectId::from_hex(hex) else {
        return rw.respond_json(400, &err_json("object id must be 64 hex chars"));
    };
    if !snap.store.has(&id) {
        return rw.respond_json(404, &err_json(&format!("object {hex} not found")));
    }
    let bytes = snap.store.get(&id)?;
    if let Some(header) = range {
        match parse_range(header, bytes.len()) {
            RangeParse::Ignore => {}
            RangeParse::Unsatisfiable => {
                let content_range = format!("bytes */{}", bytes.len());
                return rw.respond_json_with(
                    416,
                    &err_json("range not satisfiable"),
                    &[("Content-Range", content_range.as_str())],
                );
            }
            RangeParse::Bytes(start, end) => {
                let content_range = format!("bytes {}-{}/{}", start, end - 1, bytes.len());
                rw.write_head_with(
                    206,
                    "application/octet-stream",
                    end - start,
                    &[("Content-Range", content_range.as_str()), ("Accept-Ranges", "bytes")],
                )?;
                return rw.write_body(&bytes[start..end]);
            }
        }
    }
    rw.write_head_with(
        200,
        "application/octet-stream",
        bytes.len(),
        &[("Accept-Ranges", "bytes")],
    )?;
    rw.write_body(&bytes)
}

// ---------------------------------------------------------------------------
// Write handlers
// ---------------------------------------------------------------------------

/// `POST /object/<hex-id>`: stage one encoded object (WAL-journaled put)
/// ahead of a commit that references it. Idempotent: an already-stored
/// id answers `"new": false` without touching the log.
fn post_object(state: &ServeState, rw: &mut ResponseWriter, hex: &str, body: &[u8]) -> Result<()> {
    let Ok(id) = ObjectId::from_hex(hex) else {
        return rw.respond_json(400, &err_json("object id must be 64 hex chars"));
    };
    let wm = state.writer.as_ref().expect("dispatch gates writes on state.writer");
    let mut ws = wm.lock().unwrap();
    let store = Arc::clone(&state.snapshot.read().unwrap().store);
    let new = store.put_via_wal(&mut ws.wal, id, body)?;
    if new {
        ws.wal.sync()?;
    }
    rw.respond_json(
        200,
        &Json::obj().set("id", hex).set("new", new).set("bytes", body.len()),
    )
}

/// `POST /commit`: apply one commit-op JSON body (see
/// [`LineageGraph::apply_commit`] for the schema).
fn post_commit(state: &ServeState, rw: &mut ResponseWriter, body: &[u8]) -> Result<()> {
    let text = match std::str::from_utf8(body) {
        Ok(t) => t,
        Err(_) => return rw.respond_json(400, &err_json("commit body must be UTF-8 JSON")),
    };
    let op = match json::parse(text) {
        Ok(j) => j,
        Err(e) => {
            return rw.respond_json(400, &err_json(&format!("invalid commit JSON: {e:#}")))
        }
    };
    match writer_commit(state, &[], &op) {
        Ok(done) => rw.respond_json(
            200,
            &Json::obj()
                .set("committed", true)
                .set("epoch", done.epoch)
                .set("nodes", done.nodes)
                .set("new_objects", done.new_objects),
        ),
        Err(WriteError::Reject(code, msg)) => rw.respond_json(code, &err_json(&msg)),
        Err(WriteError::Internal(e)) => Err(e),
    }
}

/// `POST /checkpoint/<node>?arch=<name>[&prev=<node>]`: store a raw
/// little-endian f32 body as `<node>`'s checkpoint and commit it in one
/// round trip. With `prev`, the body is delta-compressed against that
/// node's checkpoint and linked to it with a version edge.
fn post_checkpoint(
    state: &ServeState,
    rw: &mut ResponseWriter,
    node: &str,
    query: &str,
    body: &[u8],
) -> Result<()> {
    let Some(zoo) = &state.zoo else {
        return rw.respond_json(503, &err_json(NO_MANIFEST));
    };
    if node.is_empty() {
        return rw.respond_json(400, &err_json("checkpoint wants POST /checkpoint/<node>"));
    }
    let mut arch = None;
    let mut prev = None;
    for kv in query.split('&') {
        match kv.split_once('=') {
            Some(("arch", v)) => arch = Some(percent_decode(v)),
            Some(("prev", v)) => prev = Some(percent_decode(v)),
            _ => {}
        }
    }
    let Some(arch) = arch else {
        return rw.respond_json(400, &err_json("POST /checkpoint/<node>?arch=<name> is required"));
    };
    let spec = match zoo.arch(&arch) {
        Ok(s) => s,
        Err(_) => {
            return rw.respond_json(400, &err_json(&format!("unknown architecture `{arch}`")))
        }
    };
    if body.len() != spec.param_count * 4 {
        return rw.respond_json(
            400,
            &err_json(&format!(
                "arch `{arch}` wants {} bytes of little-endian f32 ({} params); body has {}",
                spec.param_count * 4,
                spec.param_count,
                body.len()
            )),
        );
    }
    let ck = Checkpoint { arch: spec.name.clone(), flat: bytes_to_f32(body) };
    let snap = state.snapshot.read().unwrap().clone();
    let (sm, objects, delta_params) = match &prev {
        Some(pname) => {
            let pn = match snap.graph.node_by_name(pname) {
                Ok(n) => n,
                Err(_) => {
                    return rw
                        .respond_json(400, &err_json(&format!("unknown prev node `{pname}`")))
                }
            };
            let Some(psm) = &pn.stored else {
                return rw.respond_json(
                    400,
                    &err_json(&format!("prev node `{pname}` has no stored checkpoint")),
                );
            };
            if pn.model_type != spec.name {
                return rw.respond_json(
                    400,
                    &err_json(&format!(
                        "prev node `{pname}` has model type `{}`, not `{}`",
                        pn.model_type, spec.name
                    )),
                );
            }
            let pck = delta::load_with_cache(&snap.store, zoo, psm, &NativeKernel, &state.cache)?;
            let cand = delta::prepare_delta(
                &snap.store,
                spec,
                &ck,
                spec,
                &pck,
                psm,
                CompressConfig::default(),
                &NativeKernel,
            )?;
            (cand.model, cand.objects, cand.report.n_delta)
        }
        None => {
            // Encode into a scratch in-memory store, then ship the
            // objects through the WAL'd commit like any other batch.
            let mem = Store::in_memory();
            let (sm, _) = delta::store_raw(&mem, spec, &ck)?;
            let mut seen = std::collections::HashSet::new();
            let mut objects = Vec::new();
            for (_, id) in &sm.params {
                if seen.insert(*id) {
                    objects.push((*id, mem.get(id)?));
                }
            }
            (sm, objects, 0)
        }
    };
    let mut op = Json::obj()
        .set("name", node)
        .set("model_type", spec.name.as_str())
        .set("stored", sm.to_json());
    if let Some(pname) = &prev {
        op = op.set("ver_parent", pname.as_str());
    }
    match writer_commit(state, &objects, &op) {
        Ok(done) => rw.respond_json(
            200,
            &Json::obj()
                .set("node", node)
                .set("arch", spec.name.as_str())
                .set("delta_params", delta_params)
                .set("new_objects", done.new_objects)
                .set("epoch", done.epoch),
        ),
        Err(WriteError::Reject(code, msg)) => rw.respond_json(code, &err_json(&msg)),
        Err(WriteError::Internal(e)) => Err(e),
    }
}

/// `POST /admin/repack`: checkpoint the WAL, repack the store live
/// (incremental, escalation off, loose copies kept so readers holding a
/// pre-repack snapshot keep resolving), and publish a new snapshot over
/// the repacked store.
fn admin_repack(state: &ServeState, rw: &mut ResponseWriter) -> Result<()> {
    let wm = state.writer.as_ref().expect("dispatch gates writes on state.writer");
    let mut ws = wm.lock().unwrap();
    // Fold outstanding commits into the on-disk graph (compacting a
    // binary tail) so the fresh Repo below sees them without replay.
    checkpoint_writer(state, &mut ws, true)?;
    let mut repo = Repo::open(&state.root)?;
    let request = super::RepackRequest {
        mode: RepackMode::Incremental,
        prune: false,
        keep_loose: true,
        // Escalation to a full rewrite deletes old packs, which would
        // break readers still on a pre-repack store snapshot.
        max_generations: None,
        max_dead_ratio: None,
        ..Default::default()
    };
    let report = request.run(&mut repo)?;
    let epoch = publish_snapshot(state, &ws.graph, Arc::new(repo.store));
    rw.respond_json(200, &report.to_json().set("epoch", epoch))
}

// ---------------------------------------------------------------------------
// HTTP plumbing
// ---------------------------------------------------------------------------

fn status_reason(code: u16) -> &'static str {
    match code {
        200 => "OK",
        206 => "Partial Content",
        400 => "Bad Request",
        401 => "Unauthorized",
        403 => "Forbidden",
        404 => "Not Found",
        405 => "Method Not Allowed",
        409 => "Conflict",
        413 => "Payload Too Large",
        416 => "Range Not Satisfiable",
        429 => "Too Many Requests",
        503 => "Service Unavailable",
        _ => "Internal Server Error",
    }
}

fn err_json(msg: &str) -> Json {
    Json::obj().set("error", msg)
}

/// Minimal percent-decoding (`%2F` → `/`, `+` is *not* special — node
/// names may legitimately contain it). Invalid escapes pass through
/// verbatim.
fn percent_decode(s: &str) -> String {
    let bytes = s.as_bytes();
    let mut out = Vec::with_capacity(bytes.len());
    let mut i = 0;
    while i < bytes.len() {
        if bytes[i] == b'%' {
            if let Some(hex) = s.get(i + 1..i + 3) {
                if let Ok(b) = u8::from_str_radix(hex, 16) {
                    out.push(b);
                    i += 3;
                    continue;
                }
            }
        }
        out.push(bytes[i]);
        i += 1;
    }
    String::from_utf8_lossy(&out).into_owned()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percent_decoding() {
        assert_eq!(percent_decode("g5%2Fbase-mlm"), "g5/base-mlm");
        assert_eq!(percent_decode("plain"), "plain");
        assert_eq!(percent_decode("a+b"), "a+b");
        assert_eq!(percent_decode("bad%zz"), "bad%zz");
        assert_eq!(percent_decode("trail%2"), "trail%2");
        assert_eq!(percent_decode("%41%42"), "AB");
    }

    #[test]
    fn serve_metrics_labels_and_mirrors() {
        let m = ServeMetrics::new();
        m.endpoint("stats").inc();
        m.endpoint("stats").inc();
        m.endpoint("no-such-endpoint").inc(); // falls into `other`
        m.status(200).inc();
        m.status(418).inc(); // falls into `status.other`
        let snap = m.registry.snapshot();
        let counters = snap.get("counters").unwrap();
        assert_eq!(counters.req_usize("endpoint.stats").unwrap(), 2);
        assert_eq!(counters.req_usize("endpoint.other").unwrap(), 1);
        assert_eq!(counters.req_usize("status.200").unwrap(), 1);
        assert_eq!(counters.req_usize("status.other").unwrap(), 1);
        // The write-tier labels exist from bind time so scrapes are
        // shape-stable whether or not a write ever happened.
        assert_eq!(counters.req_usize("endpoint.commit").unwrap(), 0);
        assert_eq!(counters.req_usize("endpoint.admin").unwrap(), 0);
        assert_eq!(counters.req_usize("status.401").unwrap(), 0);
        assert_eq!(counters.req_usize("snapshot.swaps").unwrap(), 0);

        let cache = ResolveCache::new(2);
        cache.insert(crate::store::hash_bytes(b"a"), vec![0.0; 4]);
        assert!(cache.get(&crate::store::hash_bytes(b"a")).is_some());
        assert!(cache.get(&crate::store::hash_bytes(b"b")).is_none());
        m.sync_cache(&cache);
        let snap = m.registry.snapshot();
        let counters = snap.get("counters").unwrap();
        assert_eq!(counters.req_usize("cache.hits").unwrap(), 1);
        assert_eq!(counters.req_usize("cache.misses").unwrap(), 1);
        assert_eq!(
            snap.get("gauges").unwrap().req_usize("cache.resident_bytes").unwrap(),
            16
        );
    }

    #[test]
    fn range_parsing() {
        // Full-form, open-ended, and suffix ranges on a 100-byte body.
        assert!(matches!(parse_range("bytes=0-9", 100), RangeParse::Bytes(0, 10)));
        assert!(matches!(parse_range("bytes=90-199", 100), RangeParse::Bytes(90, 100)));
        assert!(matches!(parse_range("bytes=40-", 100), RangeParse::Bytes(40, 100)));
        assert!(matches!(parse_range("bytes=-25", 100), RangeParse::Bytes(75, 100)));
        assert!(matches!(parse_range("bytes=-500", 100), RangeParse::Bytes(0, 100)));
        // Unsatisfiable: start past the end, empty suffix, empty body.
        assert!(matches!(parse_range("bytes=100-", 100), RangeParse::Unsatisfiable));
        assert!(matches!(parse_range("bytes=200-300", 100), RangeParse::Unsatisfiable));
        assert!(matches!(parse_range("bytes=-0", 100), RangeParse::Unsatisfiable));
        assert!(matches!(parse_range("bytes=-5", 0), RangeParse::Unsatisfiable));
        // Ignored (→ full 200): other units, multi-range, garbage.
        assert!(matches!(parse_range("items=0-4", 100), RangeParse::Ignore));
        assert!(matches!(parse_range("bytes=0-4,10-14", 100), RangeParse::Ignore));
        assert!(matches!(parse_range("bytes=x-y", 100), RangeParse::Ignore));
        assert!(matches!(parse_range("bytes=9-2", 100), RangeParse::Ignore));
        assert!(matches!(parse_range("bytes=-", 100), RangeParse::Ignore));
    }

    #[test]
    fn token_bucket_refills() {
        let mut tb = TokenBucket::new(2);
        // Full burst up front, then dry.
        assert!(tb.take());
        assert!(tb.take());
        assert!(!tb.take());
        // Simulate the passage of time by back-dating the last refill.
        tb.last = Instant::now() - Duration::from_secs(1);
        assert!(tb.take()); // ~2 tokens refilled
        assert!(tb.take());
        assert!(!tb.take());
    }

    #[test]
    fn snapshot_swap_is_atomic_for_held_readers() {
        let mut g1 = LineageGraph::new();
        g1.add_node("a", "t").unwrap();
        let store = Arc::new(Store::in_memory());
        let slot = RwLock::new(Arc::new(Snapshot {
            graph: Arc::new(GraphStore::from_graph(g1.clone())),
            store: Arc::clone(&store),
            epoch: 1,
            stats: OnceLock::new(),
        }));

        // A reader pins the epoch-1 snapshot...
        let held = slot.read().unwrap().clone();

        // ...while the writer publishes epoch 2 with one more node.
        let mut g2 = g1.clone();
        g2.add_node("b", "t").unwrap();
        *slot.write().unwrap() = Arc::new(Snapshot {
            graph: Arc::new(GraphStore::from_graph(g2)),
            store,
            epoch: 2,
            stats: OnceLock::new(),
        });

        // The held snapshot is frozen; the slot serves the new epoch.
        assert_eq!(held.epoch, 1);
        assert_eq!(held.graph.len(), 1);
        assert!(held.graph.idx("b").is_err());
        let current = slot.read().unwrap().clone();
        assert_eq!(current.epoch, 2);
        assert_eq!(current.graph.len(), 2);
        assert!(current.graph.idx("b").is_ok());
    }
}
