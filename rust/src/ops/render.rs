//! Human-readable rendering for every operation report.
//!
//! The typed reports in [`crate::ops`] carry data only; this module is
//! the presentation layer the CLI uses when `--json` is absent. Each
//! `Display` impl produces the full multi-line text *without* a
//! trailing newline (the CLI adds it).

use std::fmt;

use crate::util::{human_bytes, human_secs};

use super::exec::{AutoInsertReport, BuildReport, CascadeReport, TestReport};
use super::integrity::{FsckReport, GcReport, VerifyPackReport};
use super::maintain::{CompressReport, GraphPackReport, RepackReport};
use super::model::{DiffReport, MergeReport};
use super::query::{LogPageReport, LogReport, ShowReport, StatsReport};
use super::remote::{FetchReport, PushReport, RemoteGetReport, RemoteSetReport};
use super::repo::InitReport;
use super::serve::ServeReport;
use super::synth::SynthGraphReport;

fn join(f: &mut fmt::Formatter<'_>, lines: &[String]) -> fmt::Result {
    write!(f, "{}", lines.join("\n"))
}

impl fmt::Display for InitReport {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "initialized empty MGit repository in {}", self.mgit_dir)
    }
}

impl fmt::Display for LogReport {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let mut lines = vec![format!(
            "{} nodes / {} provenance edges / {} version edges",
            self.nodes.len(),
            self.prov_edges,
            self.ver_edges
        )];
        for node in &self.nodes {
            let stored = if node.stored { "" } else { " (no ckpt)" };
            let cr = node
                .creation
                .as_ref()
                .map(|c| format!(" cr={c}"))
                .unwrap_or_default();
            lines.push(format!(
                "  {:<40} [{}]{}{} <- {:?}",
                node.name, node.model_type, stored, cr, node.prov_parents
            ));
        }
        join(f, &lines)
    }
}

impl fmt::Display for LogPageReport {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let more = match &self.next_after {
            Some(cursor) => format!(" (more: --after {cursor})"),
            None => " (end)".to_string(),
        };
        let mut lines =
            vec![format!("{} of {} nodes{}", self.nodes.len(), self.total, more)];
        for node in &self.nodes {
            let stored = if node.stored { "" } else { " (no ckpt)" };
            let cr = node
                .creation
                .as_ref()
                .map(|c| format!(" cr={c}"))
                .unwrap_or_default();
            lines.push(format!(
                "  {:<40} [{}]{}{} <- {:?}",
                node.name, node.model_type, stored, cr, node.prov_parents
            ));
        }
        join(f, &lines)
    }
}

impl fmt::Display for ShowReport {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let mut lines = vec![
            format!("name:  {}", self.name),
            format!("type:  {}", self.model_type),
        ];
        if let Some(cr) = &self.creation {
            lines.push(format!("cr:    {}", cr.to_string_compact()));
        }
        lines.push(format!("meta:  {}", self.metadata.to_string_compact()));
        if !self.params.is_empty() {
            lines.push(format!("params ({}):", self.params.len()));
            for (name, id) in self.params.iter().take(8) {
                lines.push(format!("  {:<24} {}", name, &id[..12.min(id.len())]));
            }
            if self.params.len() > 8 {
                lines.push(format!("  … {} more", self.params.len() - 8));
            }
        }
        join(f, &lines)
    }
}

impl fmt::Display for FsckReport {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let mut lines = Vec::new();
        for p in &self.problems {
            // `BAD_PACK` is a machine tag; humans read "BAD PACK".
            lines.push(format!("{} {}", p.kind.replace('_', " "), p.detail));
        }
        if !self.orphaned.is_empty() {
            lines.push(format!("orphaned delta parents ({}):", self.orphaned.len()));
            for (parent, children) in &self.orphaned {
                let refs: Vec<&str> =
                    children.iter().map(|c| &c[..12.min(c.len())]).collect();
                lines.push(format!("  {} <- [{}]", parent, refs.join(", ")));
            }
        }
        if let Some((loose, packed, packs)) = self.pack_counts {
            lines.push(format!("objects: {loose} loose / {packed} packed in {packs} packs"));
        }
        lines.push(format!(
            "chain scan: {} via index metadata, {} via header reads",
            self.meta_scanned, self.byte_scanned
        ));
        if self.problems.is_empty() {
            lines.push(format!(
                "ok: {} nodes, all invariants hold, all objects present",
                self.nodes
            ));
        }
        join(f, &lines)
    }
}

impl fmt::Display for StatsReport {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let mut lines = vec![format!(
            "objects:        {} ({} loose, {} packed)",
            self.objects, self.loose, self.packed
        )];
        if !self.packs.is_empty() {
            lines.push(format!(
                "packs:          {} ({} reads)",
                self.packs.len(),
                self.reader_kind.unwrap_or("unknown")
            ));
            for p in &self.packs {
                let depth = p
                    .max_depth
                    .map(|d| format!("depth<={d}"))
                    .unwrap_or_else(|| "depth=?".to_string());
                lines.push(format!(
                    "  gen {:<3} {:<6} objects  {:>10}  v{} {:<5} {:<10} {}",
                    p.generation,
                    p.objects,
                    human_bytes(p.bytes),
                    p.version,
                    p.framing,
                    depth,
                    p.name
                ));
            }
        }
        lines.push(format!("delta-encoded:  {}", self.delta_objects));
        lines.push(format!("stored bytes:   {}", human_bytes(self.stored_bytes)));
        lines.push(format!("logical bytes:  {}", human_bytes(self.logical_bytes)));
        if self.stored_bytes > 0 {
            lines.push(format!(
                "object-level compression ratio: {:.2}x",
                self.compression_ratio()
            ));
        }
        lines.push(format!(
            "puts:           {} total, {} dedup hits ({:.1}% hit rate)",
            self.puts,
            self.dedup_hits,
            self.dedup_hit_rate()
        ));
        lines.push(format!("bytes written:  {}", human_bytes(self.bytes_written)));
        lines.push(format!(
            "chain depth:    max {}, mean {:.2} (over delta objects)",
            self.chain_max, self.chain_mean
        ));
        lines.push(format!(
            "meta fallback:  {} object(s) needed a header read",
            self.meta_fallback
        ));
        for (label, n) in &self.depth_buckets {
            lines.push(format!("  depth {label:<9} {n}"));
        }
        if let Some(t) = &self.tier {
            let budget = t
                .hot_budget
                .map(human_bytes)
                .unwrap_or_else(|| "unbounded".to_string());
            lines.push(format!(
                "remote tier:    {} (hot budget {}, prefetch {})",
                t.url,
                budget,
                if t.prefetch { "on" } else { "off" }
            ));
            lines.push(format!(
                "  evictable fills resident: {}",
                human_bytes(t.fill_resident_bytes)
            ));
        }
        join(f, &lines)
    }
}

impl fmt::Display for VerifyPackReport {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if self.packs.is_empty() {
            return write!(f, "no packs to verify");
        }
        let mut lines = Vec::new();
        for p in &self.packs {
            match &p.error {
                None => lines.push(format!(
                    "pack {}: {} objects, v{} {}, structure ok",
                    p.path, p.objects, p.version, p.framing
                )),
                Some(e) => lines.push(format!("BAD PACK {}: {e}", p.path)),
            }
        }
        for m in &self.object_problems {
            lines.push(format!("BAD OBJECT {m}"));
        }
        if self.all_problems().is_empty() {
            lines.push(format!(
                "verify-pack ok: {} objects in {} packs, {} content hashes verified, \
                 {} opaque blobs",
                self.total_objects,
                self.packs.len(),
                self.checked,
                self.opaque
            ));
        }
        join(f, &lines)
    }
}

impl fmt::Display for GcReport {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "swept {} unreachable objects", self.swept.len())
    }
}

impl fmt::Display for RepackReport {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let p = &self.pack;
        let mut lines = vec![format!(
            "repacked {} objects ({} retained in old packs, {} carried dead) in {} [{}, \
             {} framing]",
            p.packed,
            p.retained_packed,
            p.carried_dead,
            human_secs(self.elapsed_secs),
            self.mode_label,
            p.framing.name()
        )];
        lines.push(format!(
            "mark:   {} payload decodes, {} metadata fallbacks (byte reads)",
            p.mark_payload_decodes, p.mark_meta_fallback
        ));
        if p.dead_ratio > 0.0 {
            lines.push(format!(
                "garbage: {:.1}% of sealed pack bytes are unreachable",
                p.dead_ratio * 100.0
            ));
        }
        lines.push(format!("packs:  {} -> {}", p.packs_before, p.packs_after));
        lines.push(format!(
            "chains: max depth {} -> {} ({} re-based onto nearer ancestors, {} new bases)",
            p.max_depth_before, p.max_depth_after, p.rebased_delta, p.new_bases
        ));
        if p.base_rewrites > 0 || p.delta_skipped > 0 {
            lines.push(format!(
                "bases:  {} re-based onto similar non-parents, {} deltas dropped (below \
                 min-savings)",
                p.base_rewrites, p.delta_skipped
            ));
        }
        if p.recipes > 0 {
            lines.push(format!(
                "dedup:  {} chunk recipes ({} shared chunks, {} saved)",
                p.recipes,
                p.chunks_shared,
                human_bytes(p.chunk_bytes_saved)
            ));
        }
        lines.push(format!(
            "store:  {} -> {} ({} loose demoted, {} pruned)",
            human_bytes(p.bytes_before),
            human_bytes(p.bytes_after),
            p.loose_demoted,
            p.pruned_loose
        ));
        if let Some(path) = &p.pack_path {
            lines.push(format!("pack:   {}", path.display()));
        }
        join(f, &lines)
    }
}

impl fmt::Display for CompressReport {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "compressed: {} raw -> {} new bytes ({:.2}x vs raw), {} objects swept, took {}",
            human_bytes(self.raw_bytes),
            human_bytes(self.stored_bytes),
            self.ratio(),
            self.swept,
            human_secs(self.elapsed_secs)
        )
    }
}

impl fmt::Display for GraphPackReport {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if self.already_binary {
            return write!(
                f,
                "graph already binary: {} nodes / {} prov + {} ver edges in {} ({})",
                self.nodes,
                self.prov_edges,
                self.ver_edges,
                self.path,
                human_bytes(self.bytes)
            );
        }
        write!(
            f,
            "packed graph: {} nodes / {} prov + {} ver edges -> {} ({}) in {}",
            self.nodes,
            self.prov_edges,
            self.ver_edges,
            self.path,
            human_bytes(self.bytes),
            human_secs(self.elapsed_secs)
        )
    }
}

impl fmt::Display for RemoteSetReport {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "remote origin set to {} ({})", self.url, self.path)
    }
}

impl fmt::Display for RemoteGetReport {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let Some(url) = &self.url else {
            return write!(f, "no remote configured");
        };
        let budget = self
            .hot_bytes
            .map(human_bytes)
            .unwrap_or_else(|| "unbounded".to_string());
        write!(
            f,
            "remote: {url} (hot budget {budget}, prefetch {}, auth {})",
            if self.prefetch { "on" } else { "off" },
            if self.auth { "token" } else { "none" }
        )
    }
}

impl fmt::Display for FetchReport {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let mut lines = vec![format!(
            "fetched {}: {} objects ({}) pulled, {} already hot, across {} params",
            self.node,
            self.objects_fetched,
            human_bytes(self.bytes_fetched),
            self.already_hot,
            self.params
        )];
        if self.created_node {
            lines.push(format!(
                "  node `{}` created locally from origin metadata",
                self.node
            ));
        }
        join(f, &lines)
    }
}

impl fmt::Display for PushReport {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let commit = if self.committed {
            "committed on origin"
        } else {
            "origin already had the node"
        };
        let lineage = match &self.ver_parent {
            Some(p) => format!(" (version of `{p}`)"),
            None => String::new(),
        };
        write!(
            f,
            "pushed {}: {} objects ({}) uploaded, {} already on origin; {}{}",
            self.node,
            self.objects_pushed,
            human_bytes(self.bytes_pushed),
            self.already_remote,
            commit,
            lineage
        )
    }
}

impl fmt::Display for DiffReport {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let mut lines = vec![
            format!("structural divergence: {:.4}", self.structural),
            format!("contextual divergence: {:.4}", self.contextual),
        ];
        if let Some(dv) = self.value_distance {
            lines.push(format!("value distance:        {dv:.4}"));
        }
        join(f, &lines)
    }
}

impl fmt::Display for MergeReport {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let mut lines = vec![format!("merge verdict: {}", self.verdict)];
        if !self.overlapping.is_empty() {
            lines.push(format!("layers changed by both sides: {:?}", self.overlapping));
            lines.push("manual resolution required".to_string());
        }
        if !self.dependent_pairs.is_empty() {
            lines.push(format!("dependent changed-layer pairs: {:?}", self.dependent_pairs));
            lines.push("run `mgit test` on the merged model before accepting".to_string());
        }
        if let Some(name) = &self.stored_as {
            lines.push(format!("stored merged model as `{name}`"));
        }
        join(f, &lines)
    }
}

impl fmt::Display for BuildReport {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "built {}: {} nodes / {} prov + {} ver edges in {}",
            self.name,
            self.nodes,
            self.prov_edges,
            self.ver_edges,
            human_secs(self.elapsed_secs)
        )
    }
}

impl fmt::Display for TestReport {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let mut lines = Vec::with_capacity(self.results.len() + 1);
        for r in &self.results {
            lines.push(format!(
                "{} {:<36} {:<24} metric={:.4}",
                if r.passed { "PASS" } else { "FAIL" },
                r.node,
                r.test,
                r.metric
            ));
        }
        lines.push(format!("{} tests run, {} failed", self.ran, self.failed));
        join(f, &lines)
    }
}

impl fmt::Display for CascadeReport {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let mut lines = Vec::new();
        match &self.origin {
            Some((node, new)) => lines.push(format!(
                "cascade from {node} -> {new} ({} jobs): {} new versions, \
                 {} skipped (no cr)",
                self.jobs,
                self.new_versions.len(),
                self.skipped_no_cr
            )),
            None => lines.push(format!(
                "resumed cascade: {} new versions ({} tasks replayed from the journal), \
                 {} skipped (no cr)",
                self.new_versions.len(),
                self.resumed_tasks,
                self.skipped_no_cr
            )),
        }
        for (old, new) in &self.new_versions {
            lines.push(format!("  {old} -> {new}"));
        }
        join(f, &lines)
    }
}

impl fmt::Display for AutoInsertReport {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let mut lines = vec![format!("auto-constructed {} nodes:", self.nodes.len())];
        for (name, parents) in &self.nodes {
            lines.push(format!("  {name:<40} <- {parents:?}"));
        }
        lines.push(format!("avg per-model insertion time: {}", human_secs(self.avg_secs)));
        join(f, &lines)
    }
}

impl fmt::Display for SynthGraphReport {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "synthesized {} `{}` graph: {} nodes / {} prov + {} ver edges -> {} in {}",
            self.format,
            self.shape,
            self.nodes,
            self.prov_edges,
            self.ver_edges,
            self.path,
            human_secs(self.elapsed_secs)
        )
    }
}

impl fmt::Display for ServeReport {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "serve: handled {} requests ({} errors) across {} workers",
            self.requests, self.errors, self.pool
        )?;
        if self.writable {
            write!(
                f,
                "; writable: {} commits, {} snapshot swaps",
                self.commits, self.snapshot_swaps
            )?;
        }
        Ok(())
    }
}
