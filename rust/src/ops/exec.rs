//! Execution-tier operations: `build`, `test`, `cascade`, `auto-insert`.

use std::path::Path;

use anyhow::{bail, Result};
use regex::Regex;

use crate::autoconstruct::AutoConfig;
use crate::cascade;
use crate::checkpoint::ModelZoo;
use crate::delta::{self, CompressConfig, DeltaKernel, NativeKernel};
use crate::lineage::LineageGraph;
use crate::registry::{run_test, CreationSpec, EvalBackend};
use crate::runtime::Runtime;
use crate::store::Store;
use crate::train::{CasCheckpointStore, Trainer};
use crate::update;
use crate::util::json::Json;
use crate::util::timing::Timer;
use crate::workloads::{self, PersistMode, Scale};

use super::{Report, Repo};

// ---------------------------------------------------------------------------
// build
// ---------------------------------------------------------------------------

/// `mgit build <g1..g5>`: train + register one of the paper's workload
/// graphs, then import it into the repository graph.
pub struct BuildRequest {
    /// Workload name: `g1` … `g5`.
    pub which: String,
    /// Use the fast small-scale configuration instead of paper scale.
    pub small: bool,
}

/// Typed result of [`BuildRequest`].
pub struct BuildReport {
    pub name: String,
    pub nodes: usize,
    pub prov_edges: usize,
    pub ver_edges: usize,
    pub elapsed_secs: f64,
}

impl BuildRequest {
    pub fn run(&self, repo: &mut Repo, rt: &Runtime) -> Result<BuildReport> {
        let scale = if self.small { Scale::small() } else { Scale::paper() };
        let t = Timer::start();
        let mut wl = match self.which.as_str() {
            "g1" => workloads::build_g1(rt, &scale)?,
            "g2" => workloads::build_g2(rt, &scale)?,
            "g3" => workloads::build_g3(rt, &scale)?,
            "g4" => workloads::build_g4(rt, &scale)?,
            "g5" => workloads::build_g5(rt, &scale)?,
            other => bail!("unknown workload `{other}`"),
        };
        workloads::persist(&mut wl, &repo.store, rt.zoo(), rt, PersistMode::HashOnly, |_, _| {
            Ok(true)
        })?;
        // Merge the workload graph into the repo graph.
        merge_graphs(&mut repo.graph, &wl.graph)?;
        repo.save()?;
        let (prov, ver) = wl.graph.edge_counts();
        Ok(BuildReport {
            name: wl.name.clone(),
            nodes: wl.graph.len(),
            prov_edges: prov,
            ver_edges: ver,
            elapsed_secs: t.elapsed_secs(),
        })
    }
}

/// Import `src` into `dst` (names must be disjoint).
pub fn merge_graphs(dst: &mut LineageGraph, src: &LineageGraph) -> Result<()> {
    let mut map = Vec::with_capacity(src.len());
    for node in &src.nodes {
        let idx = dst.add_node(&node.name, &node.model_type)?;
        dst.node_mut(idx).stored = node.stored.clone();
        dst.node_mut(idx).creation = node.creation.clone();
        dst.node_mut(idx).metadata = node.metadata.clone();
        map.push(idx);
    }
    for (i, node) in src.nodes.iter().enumerate() {
        for &p in &node.prov_parents {
            dst.add_edge(map[p], map[i])?;
        }
        for &p in &node.ver_parents {
            dst.add_version_edge(map[p], map[i])?;
        }
    }
    for t in &src.tests.tests {
        let _ = dst.tests.register(&t.name, t.scope.clone(), t.spec.clone());
    }
    Ok(())
}

impl Report for BuildReport {
    fn to_json(&self) -> Json {
        Json::obj()
            .set("name", self.name.as_str())
            .set("nodes", self.nodes)
            .set("prov_edges", self.prov_edges)
            .set("ver_edges", self.ver_edges)
            .set("elapsed_secs", self.elapsed_secs)
    }
}

// ---------------------------------------------------------------------------
// test
// ---------------------------------------------------------------------------

/// `mgit test [--re REGEX]`: run every registered test whose name
/// matches against every node it applies to.
pub struct TestRequest {
    /// Optional test-name filter.
    pub pattern: Option<String>,
}

/// One executed test in a [`TestReport`].
pub struct TestResult {
    pub node: String,
    pub test: String,
    pub passed: bool,
    pub metric: f64,
}

/// Typed result of [`TestRequest`]. A nonzero `failed` makes the CLI
/// exit nonzero (see [`Report::failure`]).
pub struct TestReport {
    pub results: Vec<TestResult>,
    pub ran: usize,
    pub failed: usize,
}

impl TestRequest {
    pub fn run(
        &self,
        repo: &Repo,
        zoo: &ModelZoo,
        kernel: &dyn DeltaKernel,
        backend: &dyn EvalBackend,
    ) -> Result<TestReport> {
        let re = match &self.pattern {
            Some(r) => Some(Regex::new(r)?),
            None => None,
        };
        let mut results = Vec::new();
        let mut failed = 0usize;
        for node in &repo.graph.nodes {
            let tests: Vec<_> = repo
                .graph
                .tests
                .matching(&node.name, &node.model_type, re.as_ref())
                .cloned()
                .collect();
            if tests.is_empty() || node.stored.is_none() {
                continue;
            }
            let ck = delta::load(&repo.store, zoo, node.stored.as_ref().unwrap(), kernel)?;
            for t in tests {
                let (pass, metric) = run_test(&t.spec, &ck, backend)?;
                if !pass {
                    failed += 1;
                }
                results.push(TestResult {
                    node: node.name.clone(),
                    test: t.name.clone(),
                    passed: pass,
                    metric,
                });
            }
        }
        Ok(TestReport { ran: results.len(), failed, results })
    }
}

impl Report for TestReport {
    fn to_json(&self) -> Json {
        Json::obj()
            .set(
                "results",
                Json::Arr(
                    self.results
                        .iter()
                        .map(|r| {
                            Json::obj()
                                .set("node", r.node.as_str())
                                .set("test", r.test.as_str())
                                .set("passed", r.passed)
                                .set("metric", r.metric)
                        })
                        .collect(),
                ),
            )
            .set("ran", self.ran)
            .set("failed", self.failed)
    }

    fn failure(&self) -> Option<String> {
        if self.failed == 0 {
            None
        } else {
            Some(format!("{} test failures", self.failed))
        }
    }
}

// ---------------------------------------------------------------------------
// cascade
// ---------------------------------------------------------------------------

/// `mgit cascade <node>` / `mgit cascade --resume`: retrain the root on
/// perturbed data, then run the Algorithm-2 update cascade over its
/// descendants on a wavefront scheduler (journaled, resumable).
pub struct CascadeRequest {
    /// Root node to update; `None` means resume the journaled cascade.
    pub node: Option<String>,
    /// Retraining steps for the root update.
    pub steps: usize,
    /// Scheduler worker threads (1 = serial).
    pub jobs: usize,
}

/// Typed result of [`CascadeRequest`].
pub struct CascadeReport {
    pub resumed: bool,
    pub jobs: usize,
    /// `(old root, new root version)` for fresh runs.
    pub origin: Option<(String, String)>,
    /// `(old, new)` node names, plan order.
    pub new_versions: Vec<(String, String)>,
    pub skipped_no_cr: usize,
    /// Tasks replayed from the journal instead of re-executed.
    pub resumed_tasks: usize,
}

impl CascadeRequest {
    pub fn run(&self, root: &Path, artifacts: &Path) -> Result<CascadeReport> {
        use crate::update::{CheckpointStore as _, CreationExecutor as _};

        let jobs = self.jobs;
        let jdir = cascade::journal_dir(&Repo::mgit_dir(root));
        let resume = self.node.is_none();

        // Cheap precondition checks first: a missing/stale journal should
        // produce its actionable message without paying runtime startup
        // (and without runtime-init errors masking it).
        if resume && !cascade::journal_exists(&jdir) {
            bail!("no interrupted cascade to resume (no journal at {})", jdir.display());
        }
        if !resume && cascade::journal_exists(&jdir) {
            bail!(
                "an interrupted cascade journal exists at {}; run `mgit cascade --resume` \
                 to finish it (or delete the directory to abandon it)",
                jdir.display()
            );
        }

        // Shared execution substrate: one trainer + one CAS-backed store
        // serve every scheduler worker; parent checkpoints resolve
        // through a shared bounded cache so concurrent loads reuse
        // ancestors.
        let rt = Runtime::new(artifacts)?;
        let zoo = rt.zoo().clone();
        let trainer = Trainer::new(&rt);
        let cache = delta::ResolveCache::with_max_bytes(128, 256 << 20);

        if resume {
            let mut repo = Repo::open(root)?;
            let ckstore = CasCheckpointStore {
                store: &repo.store,
                zoo: &zoo,
                kernel: &NativeKernel,
                compress: Some(CompressConfig::default()),
                cache: Some(&cache),
            };
            let report = cascade::resume(&mut repo.graph, &ckstore, &trainer, &jdir, jobs)
                .map_err(|e| {
                    e.context(format!(
                        "resuming the cascade journaled at {} (a plan that no longer \
                         binds to the graph means the original run died before the \
                         graph was saved — delete the journal directory and re-run \
                         the cascade)",
                        jdir.display()
                    ))
                })?;
            repo.save()?;
            cascade::remove_journal(&jdir)?;
            return Ok(CascadeReport {
                resumed: true,
                jobs: jobs.max(1),
                origin: None,
                new_versions: name_pairs(&repo.graph, &report.new_versions),
                skipped_no_cr: report.skipped_no_cr.len(),
                resumed_tasks: report.resumed_tasks,
            });
        }

        let mut repo = Repo::open(root)?;
        let node_name = self.node.clone().expect("checked above");

        let m = repo.graph.idx(&node_name)?;
        let arch = repo.graph.node(m).model_type.clone();
        let ck = repo.load_checkpoint(&node_name, &rt, &zoo)?;

        // Retrain the root on perturbed data -> m'.
        let spec = CreationSpec::Pretrain { corpus_seed: 777, steps: self.steps, lr: 0.02 };
        let new_ck = trainer.execute(&spec, &arch, &[ck.clone()])?;
        let ckstore = CasCheckpointStore {
            store: &repo.store,
            zoo: &zoo,
            kernel: &NativeKernel,
            compress: Some(CompressConfig::default()),
            cache: Some(&cache),
        };
        let sm = ckstore.save(&new_ck, None)?;
        let new_name = update::next_version_name(&repo.graph, &node_name);
        let m_new = repo.graph.add_node(&new_name, &arch)?;
        repo.graph.node_mut(m_new).stored = Some(sm);
        repo.graph.add_version_edge(m, m_new)?;

        // Plan (all graph mutation), journal the plan, then persist the
        // graph so a crash during execution is resumable. Journal-first:
        // if we die between the two writes, graph.json is still
        // pre-cascade — `--resume` then fails to re-bind the plan (its
        // nodes were never saved) and tells the user to delete the
        // journal, which is strictly better than the graph accumulating
        // orphaned, never-stored next-version nodes.
        let plan =
            cascade::plan_cascade(&mut repo.graph, m, m_new, |_, _| false, |_, _| false)?;
        let journal = cascade::CascadeJournal::create(&jdir, &plan, &repo.graph)?;
        repo.save()?;
        let opts = cascade::CascadeOptions { jobs, journal: Some(&journal) };
        let report = match cascade::execute_and_apply(
            &mut repo.graph,
            &plan,
            &ckstore,
            &trainer,
            &opts,
            &cascade::DoneTasks::new(),
        ) {
            Ok(r) => r,
            Err(e) => {
                eprintln!(
                    "cascade interrupted; finished models are journaled — \
                     run `mgit cascade --resume` to continue"
                );
                return Err(e);
            }
        };
        repo.save()?;
        drop(journal);
        cascade::remove_journal(&jdir)?;
        Ok(CascadeReport {
            resumed: false,
            jobs: jobs.max(1),
            origin: Some((node_name, new_name)),
            new_versions: name_pairs(&repo.graph, &report.new_versions),
            skipped_no_cr: report.skipped_no_cr.len(),
            resumed_tasks: report.resumed_tasks,
        })
    }
}

fn name_pairs(
    g: &LineageGraph,
    pairs: &[(crate::lineage::NodeIdx, crate::lineage::NodeIdx)],
) -> Vec<(String, String)> {
    pairs
        .iter()
        .map(|&(old, new)| (g.node(old).name.clone(), g.node(new).name.clone()))
        .collect()
}

impl Report for CascadeReport {
    fn to_json(&self) -> Json {
        let versions: Vec<Json> = self
            .new_versions
            .iter()
            .map(|(old, new)| Json::obj().set("old", old.as_str()).set("new", new.as_str()))
            .collect();
        let origin = match &self.origin {
            Some((node, new)) => {
                Json::obj().set("node", node.as_str()).set("new", new.as_str())
            }
            None => Json::Null,
        };
        Json::obj()
            .set("resumed", self.resumed)
            .set("jobs", self.jobs)
            .set("origin", origin)
            .set("new_versions", Json::Arr(versions))
            .set("skipped_no_cr", self.skipped_no_cr)
            .set("resumed_tasks", self.resumed_tasks)
    }
}

// ---------------------------------------------------------------------------
// auto-insert
// ---------------------------------------------------------------------------

/// `mgit auto-insert`: rebuild provenance edges automatically (§3.2) for
/// every stored node, in insertion order.
pub struct AutoInsertRequest;

/// Typed result of [`AutoInsertRequest`].
pub struct AutoInsertReport {
    /// (node name, inferred provenance parents).
    pub nodes: Vec<(String, Vec<String>)>,
    /// Mean per-model insertion time.
    pub avg_secs: f64,
}

impl AutoInsertRequest {
    pub fn run(&self, repo: &Repo, rt: &Runtime) -> Result<AutoInsertReport> {
        let zoo = rt.zoo();
        let cfg = AutoConfig::default();
        // Re-derive provenance edges for all stored nodes, in insertion
        // order.
        let mut order = Vec::new();
        let mut cks = std::collections::HashMap::new();
        for node in &repo.graph.nodes {
            if node.stored.is_some() {
                let ck = repo.load_checkpoint(&node.name, rt, zoo)?;
                cks.insert(node.name.clone(), ck);
                order.push((node.name.clone(), node.model_type.clone(), None));
            }
        }
        let scratch = Store::in_memory();
        let (g, _, times) = workloads::auto_construct(rt, &scratch, &order, &cks, &cfg)?;
        let nodes = g
            .nodes
            .iter()
            .map(|node| {
                (
                    node.name.clone(),
                    node.prov_parents.iter().map(|&p| g.node(p).name.clone()).collect(),
                )
            })
            .collect();
        let avg = times.iter().sum::<f64>() / times.len().max(1) as f64;
        Ok(AutoInsertReport { nodes, avg_secs: avg })
    }
}

impl Report for AutoInsertReport {
    fn to_json(&self) -> Json {
        Json::obj()
            .set(
                "nodes",
                Json::Arr(
                    self.nodes
                        .iter()
                        .map(|(name, parents)| {
                            Json::obj().set("name", name.as_str()).set(
                                "prov_parents",
                                Json::Arr(
                                    parents.iter().map(|p| Json::from(p.as_str())).collect(),
                                ),
                            )
                        })
                        .collect(),
                ),
            )
            .set("avg_insertion_secs", self.avg_secs)
    }
}
