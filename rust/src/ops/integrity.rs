//! Integrity operations: `fsck`, `verify-pack`, `gc`.
//!
//! These walk the graph/store looking for corruption and report every
//! problem they find rather than dying on the first one (a repair pass
//! needs the full set). The CLI maps a non-empty problem list to a
//! nonzero process exit through [`Report::failure`].

use anyhow::{bail, Result};

use crate::delta::{self, NativeKernel};
use crate::store::ObjectId;
use crate::util::json::Json;

use super::{Report, Repo};

// ---------------------------------------------------------------------------
// fsck
// ---------------------------------------------------------------------------

/// `mgit fsck`: graph invariants + object presence + cross-pack
/// delta-chain integrity.
pub struct FsckRequest;

/// One fsck finding. `kind` is a stable machine tag (`MISSING`,
/// `UNREADABLE`, `DANGLING`, `BAD_PACK`, `TORN_WAL`,
/// `TORN_GRAPH_TAIL`).
pub struct FsckProblem {
    pub kind: &'static str,
    pub detail: String,
}

/// Typed result of [`FsckRequest`].
pub struct FsckReport {
    /// Lineage-graph node count.
    pub nodes: usize,
    pub problems: Vec<FsckProblem>,
    /// Orphaned delta parents: (parent hex, referencing-object hexes).
    pub orphaned: Vec<(String, Vec<String>)>,
    /// (loose, packed, pack count) when the store is pack-capable.
    pub pack_counts: Option<(usize, usize, usize)>,
    /// Objects whose delta-parent edges were answered from pack-index v2
    /// metadata — zero object reads, zero payload decodes.
    pub meta_scanned: usize,
    /// Objects that needed a byte read + header parse (loose staging
    /// copies, v1-pack copies).
    pub byte_scanned: usize,
}

impl FsckRequest {
    pub fn run(&self, repo: &Repo) -> Result<FsckReport> {
        repo.graph.integrity_check()?;
        let mut problems = Vec::new();
        // A binary graph with a torn segment tail lost the record(s)
        // past the valid prefix — `Repo::open` already recovered what
        // was durable; fsck must surface the loss.
        if let Some((offset, reason)) = repo.graph.tail_status() {
            problems.push(FsckProblem {
                kind: "TORN_GRAPH_TAIL",
                detail: format!("graph.bin segment tail torn at byte {offset}: {reason}"),
            });
        }
        // Every model parameter must be present (loose or packed).
        // Streamed through the graph seam: one node resident at a time
        // on a mapped binary graph.
        repo.graph.each_node(&mut |_, node| {
            if let Some(sm) = &node.stored {
                for (pname, id) in &sm.params {
                    if !repo.store.has(id) {
                        problems.push(FsckProblem {
                            kind: "MISSING",
                            detail: format!(
                                "object {} ({}:{})",
                                id.short(),
                                node.name,
                                pname
                            ),
                        });
                    }
                }
            }
            Ok(())
        })?;
        // Cross-pack delta-chain integrity: every delta parent must
        // resolve somewhere in the store, whichever pack (or loose file)
        // holds it. The scan is metadata-only: objects sealed in v2
        // packs contribute their parent edge straight from the index
        // (no object read — `verify-pack`/`BAD_PACK` below cross-checks
        // that the index metadata matches the stored headers); loose and
        // v1-packed objects cost a header parse, never a payload decode.
        // Unreadable objects are recorded and the scan continues — fsck
        // must report corruption, not die on it. Orphaned parents are
        // also collected together so a repair pass has the full set in
        // one place. Ids are scanned in sorted order so the report is
        // deterministic.
        let mut ids = repo.store.list()?;
        ids.sort();
        let mut orphaned: std::collections::BTreeMap<ObjectId, Vec<ObjectId>> =
            Default::default();
        let mut meta_scanned = 0usize;
        let mut byte_scanned = 0usize;
        for id in ids {
            let meta = match repo.store.object_meta(&id) {
                Ok(m) => m,
                Err(e) => {
                    problems.push(FsckProblem {
                        kind: "UNREADABLE",
                        detail: format!("object {}: {e:#}", id.short()),
                    });
                    continue;
                }
            };
            if meta.from_index {
                meta_scanned += 1;
            } else {
                byte_scanned += 1;
            }
            if let Some(parent) = meta.parent {
                if !repo.store.has(&parent) {
                    problems.push(FsckProblem {
                        kind: "DANGLING",
                        detail: format!(
                            "delta parent {} (referenced by {})",
                            parent.short(),
                            id.short()
                        ),
                    });
                    orphaned.entry(parent).or_default().push(id);
                }
            }
        }
        // Write-ahead log: a torn tail means a writable server crashed
        // mid-append. The durable prefix was already replayed by
        // `Repo::open`; the tail past it is unrecoverable and must fail
        // fsck so operators notice the lost (never-acknowledged) write.
        let wal_file = crate::store::wal::wal_path(&repo.root);
        if wal_file.exists() {
            match crate::store::wal::scan(&wal_file) {
                Ok(scan) => {
                    if let Some(t) = scan.torn {
                        problems.push(FsckProblem {
                            kind: "TORN_WAL",
                            detail: format!(
                                "{} torn at byte {}: {} ({} durable commits precede it)",
                                wal_file.display(),
                                t.offset,
                                t.reason,
                                scan.commits
                            ),
                        });
                    }
                }
                Err(e) => problems.push(FsckProblem {
                    kind: "UNREADABLE",
                    detail: format!("{}: {e:#}", wal_file.display()),
                }),
            }
        }
        // Pack structure (checksums, index/offset agreement).
        let mut pack_counts = None;
        if let Some(ps) = repo.store.as_packed() {
            for p in ps.packs() {
                if let Err(e) = p.verify() {
                    problems.push(FsckProblem {
                        kind: "BAD_PACK",
                        detail: format!("{}: {e:#}", p.path.display()),
                    });
                }
            }
            let (loose, packed) = ps.counts()?;
            pack_counts = Some((loose, packed, ps.packs().len()));
        }
        let orphaned = orphaned
            .into_iter()
            .map(|(parent, children)| {
                (parent.hex(), children.iter().map(|c| c.hex()).collect())
            })
            .collect();
        Ok(FsckReport {
            nodes: repo.graph.len(),
            problems,
            orphaned,
            pack_counts,
            meta_scanned,
            byte_scanned,
        })
    }
}

impl Report for FsckReport {
    fn to_json(&self) -> Json {
        let problems: Vec<Json> = self
            .problems
            .iter()
            .map(|p| Json::obj().set("kind", p.kind).set("detail", p.detail.as_str()))
            .collect();
        let orphaned: Vec<Json> = self
            .orphaned
            .iter()
            .map(|(parent, children)| {
                Json::obj().set("parent", parent.as_str()).set(
                    "referenced_by",
                    Json::Arr(children.iter().map(|c| Json::from(c.as_str())).collect()),
                )
            })
            .collect();
        let mut j = Json::obj()
            .set("nodes", self.nodes)
            .set("problems", Json::Arr(problems))
            .set("orphaned_delta_parents", Json::Arr(orphaned))
            .set("meta_scanned", self.meta_scanned)
            .set("byte_scanned", self.byte_scanned)
            .set("ok", self.problems.is_empty());
        if let Some((loose, packed, packs)) = self.pack_counts {
            j = j.set("loose", loose).set("packed", packed).set("pack_count", packs);
        }
        j
    }

    fn failure(&self) -> Option<String> {
        if self.problems.is_empty() {
            None
        } else {
            Some(format!("{} fsck problems", self.problems.len()))
        }
    }
}

// ---------------------------------------------------------------------------
// verify-pack
// ---------------------------------------------------------------------------

/// `mgit verify-pack`: pack checksums + per-object content hashes.
pub struct VerifyPackRequest;

/// Per-pack structural verification outcome.
pub struct PackCheck {
    pub path: String,
    pub objects: usize,
    /// Pack format version (1 = legacy, 2 = framed + index metadata,
    /// 3 = chunked with `MGCR` recipes).
    pub version: u8,
    /// Outer framing (`raw`/`zstd`).
    pub framing: &'static str,
    pub structure_ok: bool,
    pub error: Option<String>,
}

/// Typed result of [`VerifyPackRequest`].
pub struct VerifyPackReport {
    pub packs: Vec<PackCheck>,
    /// Per-object content-verification failures (bad hashes, unreadable
    /// entries, unresolvable chains) across all structurally-ok packs.
    pub object_problems: Vec<String>,
    /// Objects counted across structurally-ok packs.
    pub total_objects: usize,
    /// Content hashes verified.
    pub checked: usize,
    /// Non-MGTF blobs (structure-only verification).
    pub opaque: usize,
}

impl VerifyPackRequest {
    pub fn run(&self, repo: &Repo) -> Result<VerifyPackReport> {
        let Some(ps) = repo.store.as_packed() else {
            bail!("object store is not pack-capable");
        };
        // Structure first: checksums, counts, offset/length agreement. A
        // bad pack is recorded (with the failing pack named and, for
        // entry-level problems, the offending offset) and the scan
        // continues, so one corrupt pack doesn't mask others.
        let mut total = 0usize;
        let mut packs = Vec::with_capacity(ps.packs().len());
        for p in ps.packs() {
            match p.verify() {
                Ok(()) => {
                    total += p.object_count();
                    packs.push(PackCheck {
                        path: p.path.display().to_string(),
                        objects: p.object_count(),
                        version: p.version,
                        framing: p.framing.name(),
                        structure_ok: true,
                        error: None,
                    });
                }
                Err(e) => {
                    packs.push(PackCheck {
                        path: p.path.display().to_string(),
                        objects: p.object_count(),
                        version: p.version,
                        framing: p.framing.name(),
                        structure_ok: false,
                        error: Some(format!("{e:#}")),
                    });
                }
            }
        }
        // Content second: each pack's *own copy* of every object (ids may
        // be duplicated across packs after a crash) must still hash to
        // its id once its delta chain — possibly crossing packs / loose
        // staging — is resolved. Structurally bad packs are skipped
        // (their offsets can't be trusted), and per-object errors are
        // recorded rather than aborting, so one corruption never masks
        // another.
        let mut object_problems: Vec<String> = Vec::new();
        let mut cache: std::collections::HashMap<ObjectId, Vec<f32>> = Default::default();
        let mut checked = 0usize;
        let mut opaque = 0usize;
        for (p, check) in ps.packs().iter().zip(&packs) {
            if !check.structure_ok {
                continue;
            }
            for id in p.index.ids().collect::<Vec<_>>() {
                let offset = p.index.lookup(&id).map(|(o, _)| o).unwrap_or(0);
                let bytes = match p.get(&id) {
                    Ok(Some(b)) => b,
                    Ok(None) => {
                        object_problems.push(format!(
                            "index lists {} but pack {} lacks it",
                            id.short(),
                            p.path.display()
                        ));
                        continue;
                    }
                    Err(e) => {
                        object_problems.push(format!(
                            "object {} at offset {offset} in pack {} unreadable: {e:#}",
                            id.short(),
                            p.path.display()
                        ));
                        continue;
                    }
                };
                let obj = match crate::store::format::TensorObject::decode(&bytes) {
                    Ok(o) => o,
                    Err(_) => {
                        opaque += 1; // non-MGTF blob: structure-only
                        continue;
                    }
                };
                let shape = obj.shape().to_vec();
                let want = match &obj {
                    crate::store::format::TensorObject::Raw { dtype, payload, .. } => {
                        crate::store::hash_tensor(*dtype, &shape, payload)
                    }
                    crate::store::format::TensorObject::Delta { .. } => {
                        match delta::resolve_object(
                            &repo.store,
                            &obj,
                            &NativeKernel,
                            &mut cache,
                            0,
                        ) {
                            Ok(values) => crate::store::hash_tensor(
                                crate::tensor::DType::F32,
                                &shape,
                                &crate::tensor::f32_to_bytes(&values),
                            ),
                            Err(e) => {
                                object_problems.push(format!(
                                    "object {} at offset {offset} in pack {} has an \
                                     unresolvable delta chain: {e:#}",
                                    id.short(),
                                    p.path.display()
                                ));
                                continue;
                            }
                        }
                    }
                };
                if want != id {
                    object_problems.push(format!(
                        "object {} at offset {offset} in pack {} does not hash to its id",
                        id.short(),
                        p.path.display()
                    ));
                    continue;
                }
                checked += 1;
                // Ancestor values only help while verifying nearby chain
                // links; keep peak memory bounded on huge stores.
                if cache.len() > 4096 {
                    cache.clear();
                }
            }
        }
        Ok(VerifyPackReport {
            packs,
            object_problems,
            total_objects: total,
            checked,
            opaque,
        })
    }
}

impl VerifyPackReport {
    /// Every problem (structural pack failures + per-object failures),
    /// in report order.
    pub fn all_problems(&self) -> Vec<String> {
        let mut out: Vec<String> = self
            .packs
            .iter()
            .filter_map(|p| p.error.as_ref().map(|e| format!("{}: {e}", p.path)))
            .collect();
        out.extend(self.object_problems.iter().cloned());
        out
    }
}

impl Report for VerifyPackReport {
    fn to_json(&self) -> Json {
        let packs: Vec<Json> = self
            .packs
            .iter()
            .map(|p| {
                Json::obj()
                    .set("path", p.path.as_str())
                    .set("objects", p.objects)
                    .set("version", p.version as usize)
                    .set("framing", p.framing)
                    .set("structure_ok", p.structure_ok)
                    .set(
                        "error",
                        p.error.as_deref().map(Json::from).unwrap_or(Json::Null),
                    )
            })
            .collect();
        Json::obj()
            .set("packs", Json::Arr(packs))
            .set(
                "object_problems",
                Json::Arr(
                    self.object_problems
                        .iter()
                        .map(|m| Json::from(m.as_str()))
                        .collect(),
                ),
            )
            .set("total_objects", self.total_objects)
            .set("checked", self.checked)
            .set("opaque", self.opaque)
            .set("ok", self.all_problems().is_empty())
    }

    fn failure(&self) -> Option<String> {
        let problems = self.all_problems();
        if problems.is_empty() {
            None
        } else {
            Some(format!(
                "verify-pack found {} problems:\n  {}",
                problems.len(),
                problems.join("\n  ")
            ))
        }
    }
}

// ---------------------------------------------------------------------------
// gc
// ---------------------------------------------------------------------------

/// `mgit gc`: sweep unreachable loose objects.
pub struct GcRequest;

/// Typed result of [`GcRequest`].
pub struct GcReport {
    /// Hex ids of swept objects, sorted.
    pub swept: Vec<String>,
}

impl GcRequest {
    pub fn run(&self, repo: &Repo) -> Result<GcReport> {
        let mut swept: Vec<String> = repo.gc()?.iter().map(|id| id.hex()).collect();
        swept.sort();
        Ok(GcReport { swept })
    }
}

impl Report for GcReport {
    fn to_json(&self) -> Json {
        Json::obj().set("swept", self.swept.len()).set(
            "swept_objects",
            Json::Arr(self.swept.iter().map(|s| Json::from(s.as_str())).collect()),
        )
    }
}
