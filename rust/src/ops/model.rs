//! Model-to-model operations: `diff`, `merge`.
//!
//! Both take `repo.graph` through [`crate::lineage::GraphStore`]'s
//! auto-deref: they need whole-graph access (node pairs, mutation), so
//! on a mapped binary repo the first such access materializes the full
//! in-memory graph — the lazy read seam is for the traversal-shaped
//! paths (`log`/`show`/fsck/gc), not these.

use anyhow::Result;

use crate::checkpoint::ModelZoo;
use crate::delta::{self, DeltaKernel};
use crate::diff::{divergence_scores, value_distance};
use crate::merge::{merge, MergeOutcome};
use crate::modeldag::ModelDag;
use crate::util::json::Json;

use super::{Report, Repo};

// ---------------------------------------------------------------------------
// diff
// ---------------------------------------------------------------------------

/// `mgit diff <a> <b>`: structural/contextual divergence (Algorithm 3)
/// plus parameter-value distance when both nodes have checkpoints.
pub struct DiffRequest {
    pub a: String,
    pub b: String,
}

/// Typed result of [`DiffRequest`].
pub struct DiffReport {
    pub a: String,
    pub b: String,
    pub structural: f64,
    pub contextual: f64,
    /// Present only when both nodes have stored checkpoints.
    pub value_distance: Option<f64>,
}

impl DiffRequest {
    pub fn run(
        &self,
        repo: &Repo,
        zoo: &ModelZoo,
        kernel: &dyn DeltaKernel,
    ) -> Result<DiffReport> {
        self.run_on(&repo.graph, &repo.store, zoo, kernel)
    }

    /// Snapshot-level entry point: the serving tier diffs against an
    /// immutable (graph, store) pair rather than a [`Repo`] session.
    pub fn run_on(
        &self,
        graph: &crate::lineage::LineageGraph,
        store: &crate::store::Store,
        zoo: &ModelZoo,
        kernel: &dyn DeltaKernel,
    ) -> Result<DiffReport> {
        let na = graph.by_name(&self.a)?;
        let nb = graph.by_name(&self.b)?;
        let (sa, sb) = (zoo.arch(&na.model_type)?, zoo.arch(&nb.model_type)?);
        let da = ModelDag::from_arch(sa, na.stored.as_ref())?;
        let db = ModelDag::from_arch(sb, nb.stored.as_ref())?;
        let (structural, contextual) = divergence_scores(&da, &db);
        let value = match (&na.stored, &nb.stored) {
            (Some(sma), Some(smb)) => {
                let cka = delta::load(store, zoo, sma, kernel)?;
                let ckb = delta::load(store, zoo, smb, kernel)?;
                Some(value_distance(&da, sa, &cka, &db, sb, &ckb)?)
            }
            _ => None,
        };
        Ok(DiffReport {
            a: self.a.clone(),
            b: self.b.clone(),
            structural,
            contextual,
            value_distance: value,
        })
    }
}

impl Report for DiffReport {
    fn to_json(&self) -> Json {
        Json::obj()
            .set("a", self.a.as_str())
            .set("b", self.b.as_str())
            .set("structural_divergence", self.structural)
            .set("contextual_divergence", self.contextual)
            .set(
                "value_distance",
                self.value_distance.map(Json::from).unwrap_or(Json::Null),
            )
    }
}

// ---------------------------------------------------------------------------
// merge
// ---------------------------------------------------------------------------

/// `mgit merge <base> <m1> <m2>`: the Figure-2 merge decision tree; a
/// mergeable result is stored as a new node with provenance edges from
/// both sides.
pub struct MergeRequest {
    pub base: String,
    pub m1: String,
    pub m2: String,
    /// Name for the merged node (default `merged`).
    pub out: Option<String>,
}

/// Typed result of [`MergeRequest`].
pub struct MergeReport {
    /// `conflict`, `possible-conflict`, or `no-conflict`.
    pub verdict: String,
    /// Layers changed by both sides (conflict case).
    pub overlapping: Vec<String>,
    /// Dependent changed-layer pairs (possible-conflict case).
    pub dependent_pairs: Vec<(String, String)>,
    /// Name the merged model was stored under, when one was produced.
    pub stored_as: Option<String>,
}

impl MergeRequest {
    pub fn run(
        &self,
        repo: &mut Repo,
        zoo: &ModelZoo,
        kernel: &dyn DeltaKernel,
    ) -> Result<MergeReport> {
        let arch = repo.graph.by_name(&self.base)?.model_type.clone();
        let spec = zoo.arch(&arch)?;
        let dag = ModelDag::from_arch(spec, None)?;
        let b = repo.load_checkpoint(&self.base, kernel, zoo)?;
        let c1 = repo.load_checkpoint(&self.m1, kernel, zoo)?;
        let c2 = repo.load_checkpoint(&self.m2, kernel, zoo)?;
        let out = merge(spec, &dag, &b, &c1, &c2)?;
        let mut report = MergeReport {
            verdict: out.verdict().to_string(),
            overlapping: Vec::new(),
            dependent_pairs: Vec::new(),
            stored_as: None,
        };
        match &out {
            MergeOutcome::Conflict { overlapping } => {
                report.overlapping = overlapping.clone();
            }
            MergeOutcome::PossibleConflict { dependent_pairs, .. } => {
                report.dependent_pairs = dependent_pairs.clone();
            }
            MergeOutcome::Clean { .. } => {}
        }
        if let Some(merged) = out.merged() {
            let name = self.out.as_deref().unwrap_or("merged").to_string();
            let (sm, _) = delta::store_raw(&repo.store, spec, merged)?;
            let idx = repo.graph.add_node(&name, &arch)?;
            repo.graph.node_mut(idx).stored = Some(sm);
            let b1 = repo.graph.idx(&self.m1)?;
            let b2 = repo.graph.idx(&self.m2)?;
            repo.graph.add_edge(b1, idx)?;
            repo.graph.add_edge(b2, idx)?;
            repo.save()?;
            report.stored_as = Some(name);
        }
        Ok(report)
    }
}

impl Report for MergeReport {
    fn to_json(&self) -> Json {
        Json::obj()
            .set("verdict", self.verdict.as_str())
            .set(
                "overlapping",
                Json::Arr(self.overlapping.iter().map(|s| Json::from(s.as_str())).collect()),
            )
            .set(
                "dependent_pairs",
                Json::Arr(
                    self.dependent_pairs
                        .iter()
                        .map(|(a, b)| {
                            Json::Arr(vec![Json::from(a.as_str()), Json::from(b.as_str())])
                        })
                        .collect(),
                ),
            )
            .set(
                "stored_as",
                self.stored_as.as_deref().map(Json::from).unwrap_or(Json::Null),
            )
    }
}
