//! Creation functions and test functions (paper §3.1.2–§3.1.3).
//!
//! The paper registers arbitrary Python callables per node. In an AOT
//! world there is no Python on the request path, so MGit's creation and
//! test functions are *declarative specs* interpreted by the Rust
//! coordinator against the compiled artifacts: a [`CreationSpec`] says how
//! to produce a model from its provenance parents (finetune N steps on
//! task T, prune to sparsity s, federated-average, …) and a [`TestSpec`]
//! says how to score a model. This is exactly what makes the update
//! cascade (Algorithm 2) replayable: specs are stored in the lineage graph
//! and re-executed with *new* parents when an upstream model changes.
//!
//! Execution of the specs lives in [`crate::train`] (creation) and in
//! [`run_test`] below against an [`EvalBackend`] (implemented by the PJRT
//! runtime, and by mocks in tests).

use anyhow::{anyhow, bail, Result};
use regex::Regex;

use crate::checkpoint::Checkpoint;
use crate::util::json::Json;

/// Training objective, selecting which head/artifact is used.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Objective {
    Mlm,
    Cls,
}

impl Objective {
    pub fn name(self) -> &'static str {
        match self {
            Objective::Mlm => "mlm",
            Objective::Cls => "cls",
        }
    }

    pub fn parse(s: &str) -> Result<Objective> {
        match s {
            "mlm" => Ok(Objective::Mlm),
            "cls" => Ok(Objective::Cls),
            other => Err(anyhow!("unknown objective `{other}`")),
        }
    }
}

/// Which parameters a finetune updates (full / frozen-backbone / BitFit).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FreezeSpec {
    /// Update everything.
    None,
    /// Freeze the backbone, train only the heads (adapter-style children
    /// share backbone tensors with their parent — big dedup wins).
    Backbone,
    /// BitFit: train bias/LN vectors + heads only.
    BiasOnly,
}

impl FreezeSpec {
    pub fn name(self) -> &'static str {
        match self {
            FreezeSpec::None => "none",
            FreezeSpec::Backbone => "backbone",
            FreezeSpec::BiasOnly => "bias_only",
        }
    }

    pub fn parse(s: &str) -> Result<FreezeSpec> {
        match s {
            "none" => Ok(FreezeSpec::None),
            "backbone" => Ok(FreezeSpec::Backbone),
            "bias_only" => Ok(FreezeSpec::BiasOnly),
            other => Err(anyhow!("unknown freeze spec `{other}`")),
        }
    }
}

/// A perturbation family applied to training data (G2's "perturbed data";
/// the Moradi & Samwald analog — see `data::perturb`).
#[derive(Debug, Clone, PartialEq)]
pub struct PerturbSpec {
    pub kind: String,
    pub strength: f64,
}

/// How a model is created from its provenance parents.
#[derive(Debug, Clone, PartialEq)]
pub enum CreationSpec {
    /// Initialize from parents[0], train `steps` on `task`.
    Finetune {
        task: String,
        objective: Objective,
        steps: usize,
        lr: f32,
        seed: u64,
        freeze: FreezeSpec,
        perturb: Option<PerturbSpec>,
    },
    /// MLM pretraining from scratch-initialized or parent weights.
    Pretrain { corpus_seed: u64, steps: usize, lr: f32 },
    /// Magnitude-prune parents[0] to `sparsity`, then recover-finetune.
    Prune {
        sparsity: f32,
        task: String,
        recover_steps: usize,
        lr: f32,
        seed: u64,
    },
    /// Federated averaging of all parents (same arch).
    FedAvg,
    /// Plain parameter average of all parents.
    Average,
    /// Multi-task group member: trained jointly with siblings, sharing the
    /// backbone (heads are task-local). `group` lists all member tasks.
    Mtl {
        task: String,
        group: Vec<String>,
        steps: usize,
        lr: f32,
        seed: u64,
    },
}

impl CreationSpec {
    pub fn kind(&self) -> &'static str {
        match self {
            CreationSpec::Finetune { .. } => "finetune",
            CreationSpec::Pretrain { .. } => "pretrain",
            CreationSpec::Prune { .. } => "prune",
            CreationSpec::FedAvg => "fedavg",
            CreationSpec::Average => "average",
            CreationSpec::Mtl { .. } => "mtl",
        }
    }

    pub fn to_json(&self) -> Json {
        match self {
            CreationSpec::Finetune { task, objective, steps, lr, seed, freeze, perturb } => {
                let mut j = Json::obj()
                    .set("kind", "finetune")
                    .set("task", task.as_str())
                    .set("objective", objective.name())
                    .set("steps", *steps)
                    .set("lr", *lr as f64)
                    .set("seed", *seed)
                    .set("freeze", freeze.name());
                if let Some(p) = perturb {
                    j = j.set(
                        "perturb",
                        Json::obj().set("kind", p.kind.as_str()).set("strength", p.strength),
                    );
                }
                j
            }
            CreationSpec::Pretrain { corpus_seed, steps, lr } => Json::obj()
                .set("kind", "pretrain")
                .set("corpus_seed", *corpus_seed)
                .set("steps", *steps)
                .set("lr", *lr as f64),
            CreationSpec::Prune { sparsity, task, recover_steps, lr, seed } => Json::obj()
                .set("kind", "prune")
                .set("sparsity", *sparsity as f64)
                .set("task", task.as_str())
                .set("recover_steps", *recover_steps)
                .set("lr", *lr as f64)
                .set("seed", *seed),
            CreationSpec::FedAvg => Json::obj().set("kind", "fedavg"),
            CreationSpec::Average => Json::obj().set("kind", "average"),
            CreationSpec::Mtl { task, group, steps, lr, seed } => Json::obj()
                .set("kind", "mtl")
                .set("task", task.as_str())
                .set("group", group.iter().map(|s| s.as_str()).collect::<Vec<_>>())
                .set("steps", *steps)
                .set("lr", *lr as f64)
                .set("seed", *seed),
        }
    }

    pub fn from_json(j: &Json) -> Result<CreationSpec> {
        Ok(match j.req_str("kind")? {
            "finetune" => CreationSpec::Finetune {
                task: j.req_str("task")?.to_string(),
                objective: Objective::parse(j.req_str("objective")?)?,
                steps: j.req_usize("steps")?,
                lr: j.req_f64("lr")? as f32,
                seed: j.req_f64("seed")? as u64,
                freeze: FreezeSpec::parse(j.req_str("freeze")?)?,
                perturb: match j.get("perturb") {
                    None | Some(Json::Null) => None,
                    Some(p) => Some(PerturbSpec {
                        kind: p.req_str("kind")?.to_string(),
                        strength: p.req_f64("strength")?,
                    }),
                },
            },
            "pretrain" => CreationSpec::Pretrain {
                corpus_seed: j.req_f64("corpus_seed")? as u64,
                steps: j.req_usize("steps")?,
                lr: j.req_f64("lr")? as f32,
            },
            "prune" => CreationSpec::Prune {
                sparsity: j.req_f64("sparsity")? as f32,
                task: j.req_str("task")?.to_string(),
                recover_steps: j.req_usize("recover_steps")?,
                lr: j.req_f64("lr")? as f32,
                seed: j.req_f64("seed")? as u64,
            },
            "fedavg" => CreationSpec::FedAvg,
            "average" => CreationSpec::Average,
            "mtl" => CreationSpec::Mtl {
                task: j.req_str("task")?.to_string(),
                group: j
                    .req_arr("group")?
                    .iter()
                    .map(|g| g.as_str().unwrap_or_default().to_string())
                    .collect(),
                steps: j.req_usize("steps")?,
                lr: j.req_f64("lr")? as f32,
                seed: j.req_f64("seed")? as u64,
            },
            other => bail!("unknown creation kind `{other}`"),
        })
    }
}

/// A test over one model.
#[derive(Debug, Clone, PartialEq)]
pub enum TestSpec {
    /// Evaluate accuracy on a task's held-out split; pass iff >= min_acc.
    EvalAccuracy {
        task: String,
        objective: Objective,
        batches: usize,
        split_seed: u64,
        min_acc: f32,
    },
    /// Pass iff the parameter L2 norm is <= max (explosion detector).
    ParamNormBelow { max: f64 },
    /// Pass iff overall sparsity >= min (pruning invariant).
    SparsityAtLeast { min: f64 },
    /// Pass iff all parameters are finite.
    FiniteParams,
}

impl TestSpec {
    pub fn to_json(&self) -> Json {
        match self {
            TestSpec::EvalAccuracy { task, objective, batches, split_seed, min_acc } => {
                Json::obj()
                    .set("kind", "eval_accuracy")
                    .set("task", task.as_str())
                    .set("objective", objective.name())
                    .set("batches", *batches)
                    .set("split_seed", *split_seed)
                    .set("min_acc", *min_acc as f64)
            }
            TestSpec::ParamNormBelow { max } => {
                Json::obj().set("kind", "param_norm_below").set("max", *max)
            }
            TestSpec::SparsityAtLeast { min } => {
                Json::obj().set("kind", "sparsity_at_least").set("min", *min)
            }
            TestSpec::FiniteParams => Json::obj().set("kind", "finite_params"),
        }
    }

    pub fn from_json(j: &Json) -> Result<TestSpec> {
        Ok(match j.req_str("kind")? {
            "eval_accuracy" => TestSpec::EvalAccuracy {
                task: j.req_str("task")?.to_string(),
                objective: Objective::parse(j.req_str("objective")?)?,
                batches: j.req_usize("batches")?,
                split_seed: j.req_f64("split_seed")? as u64,
                min_acc: j.req_f64("min_acc")? as f32,
            },
            "param_norm_below" => TestSpec::ParamNormBelow { max: j.req_f64("max")? },
            "sparsity_at_least" => TestSpec::SparsityAtLeast { min: j.req_f64("min")? },
            "finite_params" => TestSpec::FiniteParams,
            other => bail!("unknown test kind `{other}`"),
        })
    }
}

/// What a registered test applies to (paper: a node, or all of a type).
#[derive(Debug, Clone, PartialEq)]
pub enum TestScope {
    Node(String),
    ModelType(String),
}

#[derive(Debug, Clone, PartialEq)]
pub struct RegisteredTest {
    pub name: String,
    pub scope: TestScope,
    pub spec: TestSpec,
}

/// Accuracy evaluation backend: the PJRT runtime in production, mocks in
/// unit tests.
pub trait EvalBackend {
    /// Returns (loss, accuracy) of `ck` on `batches` batches of `task`.
    fn eval(
        &self,
        ck: &Checkpoint,
        task: &str,
        objective: Objective,
        batches: usize,
        split_seed: u64,
    ) -> Result<(f32, f32)>;
}

/// Result of one test run.
#[derive(Debug, Clone)]
pub struct TestOutcome {
    pub test_name: String,
    pub node: String,
    pub passed: bool,
    /// Primary metric (accuracy, norm, sparsity…), for diagnostics.
    pub metric: f64,
}

/// Execute one test spec against a checkpoint.
pub fn run_test(
    spec: &TestSpec,
    ck: &Checkpoint,
    backend: &dyn EvalBackend,
) -> Result<(bool, f64)> {
    Ok(match spec {
        TestSpec::EvalAccuracy { task, objective, batches, split_seed, min_acc } => {
            let (_loss, acc) = backend.eval(ck, task, *objective, *batches, *split_seed)?;
            (acc >= *min_acc, acc as f64)
        }
        TestSpec::ParamNormBelow { max } => {
            let norm = ck.l2_norm();
            (norm <= *max, norm)
        }
        TestSpec::SparsityAtLeast { min } => {
            let s = ck.sparsity();
            (s >= *min, s)
        }
        TestSpec::FiniteParams => {
            let ok = ck.flat.iter().all(|x| x.is_finite());
            (ok, if ok { 1.0 } else { 0.0 })
        }
    })
}

/// The test registry: register / deregister / select by node + regex
/// (paper API: `register_test_function`, `deregister_test_function`,
/// `run_tests(i, re)`).
#[derive(Debug, Clone, Default)]
pub struct TestRegistry {
    pub tests: Vec<RegisteredTest>,
}

impl TestRegistry {
    pub fn register(&mut self, name: &str, scope: TestScope, spec: TestSpec) -> Result<()> {
        if self.tests.iter().any(|t| t.name == name && t.scope == scope) {
            bail!("test `{name}` already registered for this scope");
        }
        self.tests.push(RegisteredTest { name: name.to_string(), scope, spec });
        Ok(())
    }

    pub fn deregister(&mut self, name: &str, scope: Option<&TestScope>) -> usize {
        let before = self.tests.len();
        self.tests.retain(|t| {
            !(t.name == name && scope.map(|s| *s == t.scope).unwrap_or(true))
        });
        before - self.tests.len()
    }

    /// Tests applying to a node of the given name/type whose test-name
    /// matches `re` (None = all).
    pub fn matching<'a>(
        &'a self,
        node_name: &'a str,
        model_type: &'a str,
        re: Option<&'a Regex>,
    ) -> impl Iterator<Item = &'a RegisteredTest> {
        self.tests.iter().filter(move |t| {
            let scope_ok = match &t.scope {
                TestScope::Node(n) => n == node_name,
                TestScope::ModelType(mt) => mt == model_type,
            };
            scope_ok && re.map(|r| r.is_match(&t.name)).unwrap_or(true)
        })
    }

    pub fn to_json(&self) -> Json {
        Json::Arr(
            self.tests
                .iter()
                .map(|t| {
                    let (scope_kind, scope_val) = match &t.scope {
                        TestScope::Node(n) => ("node", n.as_str()),
                        TestScope::ModelType(m) => ("type", m.as_str()),
                    };
                    Json::obj()
                        .set("name", t.name.as_str())
                        .set("scope_kind", scope_kind)
                        .set("scope", scope_val)
                        .set("spec", t.spec.to_json())
                })
                .collect(),
        )
    }

    pub fn from_json(j: &Json) -> Result<TestRegistry> {
        let mut reg = TestRegistry::default();
        for t in j.as_arr().unwrap_or(&[]) {
            let scope = match t.req_str("scope_kind")? {
                "node" => TestScope::Node(t.req_str("scope")?.to_string()),
                "type" => TestScope::ModelType(t.req_str("scope")?.to_string()),
                other => bail!("bad scope kind `{other}`"),
            };
            reg.tests.push(RegisteredTest {
                name: t.req_str("name")?.to_string(),
                scope,
                spec: TestSpec::from_json(t.req("spec")?)?,
            });
        }
        Ok(reg)
    }
}

#[cfg(test)]
pub mod testutil {
    use super::*;
    use std::collections::HashMap;

    /// Mock backend with per-task fixed accuracies.
    pub struct MockEval {
        pub acc: HashMap<String, f32>,
    }

    impl EvalBackend for MockEval {
        fn eval(
            &self,
            _ck: &Checkpoint,
            task: &str,
            _obj: Objective,
            _batches: usize,
            _seed: u64,
        ) -> Result<(f32, f32)> {
            Ok((0.0, *self.acc.get(task).unwrap_or(&0.0)))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::HashMap;

    fn specs() -> Vec<CreationSpec> {
        vec![
            CreationSpec::Finetune {
                task: "task3".into(),
                objective: Objective::Cls,
                steps: 100,
                lr: 0.05,
                seed: 7,
                freeze: FreezeSpec::Backbone,
                perturb: Some(PerturbSpec { kind: "swap".into(), strength: 0.1 }),
            },
            CreationSpec::Pretrain { corpus_seed: 1, steps: 500, lr: 0.1 },
            CreationSpec::Prune {
                sparsity: 0.5,
                task: "task1".into(),
                recover_steps: 50,
                lr: 0.01,
                seed: 3,
            },
            CreationSpec::FedAvg,
            CreationSpec::Average,
            CreationSpec::Mtl {
                task: "task2".into(),
                group: vec!["task1".into(), "task2".into()],
                steps: 10,
                lr: 0.1,
                seed: 0,
            },
        ]
    }

    #[test]
    fn creation_spec_json_roundtrip() {
        for spec in specs() {
            let back = CreationSpec::from_json(&spec.to_json()).unwrap();
            assert_eq!(spec, back, "spec kind {}", spec.kind());
        }
    }

    #[test]
    fn test_spec_json_roundtrip() {
        let all = vec![
            TestSpec::EvalAccuracy {
                task: "t".into(),
                objective: Objective::Cls,
                batches: 4,
                split_seed: 9,
                min_acc: 0.7,
            },
            TestSpec::ParamNormBelow { max: 100.0 },
            TestSpec::SparsityAtLeast { min: 0.5 },
            TestSpec::FiniteParams,
        ];
        for spec in all {
            assert_eq!(TestSpec::from_json(&spec.to_json()).unwrap(), spec);
        }
    }

    #[test]
    fn registry_register_matching_deregister() {
        let mut reg = TestRegistry::default();
        reg.register("acc/task1", TestScope::Node("m1".into()), TestSpec::FiniteParams)
            .unwrap();
        reg.register(
            "acc/all",
            TestScope::ModelType("tx-tiny".into()),
            TestSpec::FiniteParams,
        )
        .unwrap();
        // duplicate rejected
        assert!(reg
            .register("acc/task1", TestScope::Node("m1".into()), TestSpec::FiniteParams)
            .is_err());
        let re = Regex::new("^acc/").unwrap();
        let got: Vec<_> =
            reg.matching("m1", "tx-tiny", Some(&re)).map(|t| t.name.clone()).collect();
        assert_eq!(got, vec!["acc/task1", "acc/all"]);
        let got: Vec<_> =
            reg.matching("m2", "tx-tiny", None).map(|t| t.name.clone()).collect();
        assert_eq!(got, vec!["acc/all"]);
        assert_eq!(reg.deregister("acc/all", None), 1);
        assert!(reg.matching("m2", "tx-tiny", None).next().is_none());
    }

    #[test]
    fn registry_json_roundtrip() {
        let mut reg = TestRegistry::default();
        reg.register(
            "a",
            TestScope::Node("n".into()),
            TestSpec::ParamNormBelow { max: 5.0 },
        )
        .unwrap();
        reg.register(
            "b",
            TestScope::ModelType("t".into()),
            TestSpec::SparsityAtLeast { min: 0.9 },
        )
        .unwrap();
        let back = TestRegistry::from_json(&reg.to_json()).unwrap();
        assert_eq!(reg.tests, back.tests);
    }

    #[test]
    fn run_test_variants() {
        let zoo = crate::checkpoint::testutil::tiny_zoo();
        let spec = zoo.arch("t0").unwrap();
        let ck = crate::checkpoint::Checkpoint::init(spec, 0);
        let backend = testutil::MockEval {
            acc: HashMap::from([("task1".to_string(), 0.9f32)]),
        };
        let (pass, metric) = run_test(
            &TestSpec::EvalAccuracy {
                task: "task1".into(),
                objective: Objective::Cls,
                batches: 1,
                split_seed: 0,
                min_acc: 0.8,
            },
            &ck,
            &backend,
        )
        .unwrap();
        assert!(pass && (metric - 0.9).abs() < 1e-6);
        let (pass, _) = run_test(&TestSpec::ParamNormBelow { max: 1e9 }, &ck, &backend).unwrap();
        assert!(pass);
        let (pass, _) =
            run_test(&TestSpec::SparsityAtLeast { min: 0.99 }, &ck, &backend).unwrap();
        assert!(!pass);
        let (pass, _) = run_test(&TestSpec::FiniteParams, &ck, &backend).unwrap();
        assert!(pass);
    }
}
