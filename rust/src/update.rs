//! Automated model updating — `run_update_cascade` (paper §5, Algorithm 2).
//!
//! Given an update `m → m'` (the user registered a new version `m'` of
//! model `m`), create new versions of every provenance descendant of `m`
//! and re-execute their creation functions against the updated parents:
//!
//! * **Phase A** — BFS over `m`'s descendants (respecting skip/terminate):
//!   for each node `x`, create an empty node `x'`, link provenance edges
//!   from the *next versions* of `x`'s parents (falling back to current
//!   versions for parents outside the cascade), add the version edge
//!   `x → x'`, and copy the creation function.
//! * **Phase B** — all-parents-first traversal from `m'`: materialize each
//!   `x'` by running its creation spec with its parents' checkpoints. MTL
//!   groups are gathered and executed once per group through
//!   [`CreationExecutor::execute_mtl_group`] (the merged `cr'`).
//!
//! MGit never overwrites existing models: the old versions stay loadable,
//! and the storage layer delta-compresses `x'` against `x`.

use std::collections::{HashMap, HashSet};

use anyhow::{anyhow, bail, Result};

use crate::checkpoint::Checkpoint;
use crate::delta::StoredModel;
use crate::lineage::{traversal, LineageGraph, NodeIdx};
use crate::registry::CreationSpec;

/// Executes creation specs (implemented over the PJRT runtime in
/// [`crate::train`], mocked in tests).
pub trait CreationExecutor {
    /// Create a model from its parents per `spec`. `arch` is the target
    /// node's architecture (model_type).
    fn execute(
        &mut self,
        spec: &CreationSpec,
        arch: &str,
        parents: &[Checkpoint],
    ) -> Result<Checkpoint>;

    /// Merged-cr execution of an MTL group (paper §5): all members are
    /// trained jointly with shared backbone weights. Returns one
    /// checkpoint per member, in `specs` order.
    fn execute_mtl_group(
        &mut self,
        specs: &[&CreationSpec],
        arch: &str,
        parents: &[Checkpoint],
    ) -> Result<Vec<Checkpoint>>;
}

/// Persists checkpoints into the CAS (with delta compression against the
/// previous version when available).
pub trait CheckpointStore {
    fn load(&self, stored: &StoredModel) -> Result<Checkpoint>;
    /// `prev` is the node's previous version (delta-compression parent).
    fn save(
        &mut self,
        ck: &Checkpoint,
        prev: Option<(&StoredModel, &Checkpoint)>,
    ) -> Result<StoredModel>;
}

/// Next-version name: `foo` → `foo@v2`, `foo@v2` → `foo@v3`; appends a
/// disambiguating suffix if the name is somehow taken.
pub fn next_version_name(g: &LineageGraph, name: &str) -> String {
    let (stem, n) = match name.rsplit_once("@v") {
        Some((stem, v)) => match v.parse::<u64>() {
            Ok(k) => (stem.to_string(), k + 1),
            Err(_) => (name.to_string(), 2),
        },
        None => (name.to_string(), 2),
    };
    let mut k = n;
    loop {
        let cand = format!("{stem}@v{k}");
        if g.idx(&cand).is_err() {
            return cand;
        }
        k += 1;
    }
}

/// Outcome of one cascade.
#[derive(Debug, Default)]
pub struct CascadeReport {
    /// (old node, new node) pairs, in creation order.
    pub new_versions: Vec<(NodeIdx, NodeIdx)>,
    /// Nodes skipped because they had no creation function.
    pub skipped_no_cr: Vec<NodeIdx>,
}

/// Algorithm 2. `m` is the updated model's old version, `m_new` the user's
/// new version (already a node, with `stored` populated and a version edge
/// m → m_new in place — the CLI's `cascade` command does that setup).
pub fn run_update_cascade(
    g: &mut LineageGraph,
    ckstore: &mut dyn CheckpointStore,
    exec: &mut dyn CreationExecutor,
    m: NodeIdx,
    m_new: NodeIdx,
    skip: impl Fn(&LineageGraph, NodeIdx) -> bool,
    terminate: impl Fn(&LineageGraph, NodeIdx) -> bool,
) -> Result<CascadeReport> {
    if g.next_version(m) != Some(m_new) {
        bail!("m' must be the registered next version of m");
    }
    let mut report = CascadeReport::default();

    // ---------------- Phase A: create empty next versions ----------------
    let descendants = traversal::bfs(
        g,
        m,
        traversal::EdgeFilter::Provenance,
        |g2, i| i == m || skip(g2, i),
        &terminate,
    );
    let mut next_of: HashMap<NodeIdx, NodeIdx> = HashMap::from([(m, m_new)]);
    for &x in &descendants {
        if g.node(x).creation.is_none() {
            report.skipped_no_cr.push(x);
            continue;
        }
        let name = next_version_name(g, &g.node(x).name);
        let model_type = g.node(x).model_type.clone();
        let x_new = g.add_node(&name, &model_type)?;
        g.node_mut(x_new).creation = g.node(x).creation.clone();
        g.node_mut(x_new).metadata = g.node(x).metadata.clone();
        g.add_version_edge(x, x_new)?;
        next_of.insert(x, x_new);
    }
    // Provenance edges: from next version of each parent if it exists,
    // otherwise from the current parent.
    for (&x, &x_new) in next_of.iter() {
        if x == m {
            continue;
        }
        let parents = g.node(x).prov_parents.clone();
        for p in parents {
            let p_eff = next_of.get(&p).copied().unwrap_or(p);
            g.add_edge(p_eff, x_new)?;
        }
    }

    // ---------------- Phase B: train in all-parents-first order ----------
    // Order the *created* nodes so each trains only after every created
    // parent is materialized (parents outside the created set — including
    // skipped nodes' old versions — are already stored). This is the
    // traversal_all_parents_first of Algorithm 2 restricted to the new
    // version set, which also covers children whose path from m' was cut
    // by a skip.
    let created: HashSet<NodeIdx> =
        next_of.values().copied().filter(|&i| i != m_new).collect();
    let mut indeg: HashMap<NodeIdx, usize> = created
        .iter()
        .map(|&i| {
            let d = g
                .node(i)
                .prov_parents
                .iter()
                .filter(|p| created.contains(p))
                .count();
            (i, d)
        })
        .collect();
    let mut queue: std::collections::VecDeque<NodeIdx> = {
        let mut q: Vec<NodeIdx> = created
            .iter()
            .copied()
            .filter(|i| indeg[i] == 0)
            .collect();
        q.sort_unstable();
        q.into()
    };
    let mut order = Vec::with_capacity(created.len());
    while let Some(i) = queue.pop_front() {
        order.push(i);
        for &c in &g.node(i).prov_children {
            if let Some(d) = indeg.get_mut(&c) {
                *d -= 1;
                if *d == 0 {
                    queue.push_back(c);
                }
            }
        }
    }
    let mut done: HashSet<NodeIdx> = HashSet::new();
    for x_new in order {
        if done.contains(&x_new) || g.node(x_new).stored.is_some() {
            continue;
        }
        let Some(spec) = g.node(x_new).creation.clone() else { continue };

        // Gather parents' checkpoints.
        let load_parents = |g: &LineageGraph, idx: NodeIdx| -> Result<Vec<Checkpoint>> {
            g.node(idx)
                .prov_parents
                .iter()
                .map(|&p| {
                    let sm = g
                        .node(p)
                        .stored
                        .as_ref()
                        .ok_or_else(|| anyhow!("parent {} has no checkpoint", g.node(p).name))?;
                    ckstore.load(sm)
                })
                .collect()
        };

        if let CreationSpec::Mtl { group, .. } = &spec {
            // Gather the whole group among pending new versions.
            let group_tasks: HashSet<&String> = group.iter().collect();
            let mut members: Vec<NodeIdx> = vec![x_new];
            for (&_old, &cand) in next_of.iter() {
                if cand == x_new || done.contains(&cand) {
                    continue;
                }
                if let Some(CreationSpec::Mtl { task, .. }) = &g.node(cand).creation {
                    if group_tasks.contains(task) {
                        members.push(cand);
                    }
                }
            }
            members.sort_by_key(|&i| g.node(i).name.clone());
            let parents = load_parents(g, x_new)?;
            let specs: Vec<CreationSpec> = members
                .iter()
                .map(|&i| g.node(i).creation.clone().unwrap())
                .collect();
            let spec_refs: Vec<&CreationSpec> = specs.iter().collect();
            let arch = g.node(x_new).model_type.clone();
            let cks = exec.execute_mtl_group(&spec_refs, &arch, &parents)?;
            if cks.len() != members.len() {
                bail!("MTL executor returned {} models for {} members", cks.len(), members.len());
            }
            for (&member, ck) in members.iter().zip(&cks) {
                let prev = g.prev_version(member);
                let prev_data = match prev {
                    Some(p) => {
                        let sm = g.node(p).stored.clone();
                        match sm {
                            Some(sm) => Some((sm.clone(), ckstore.load(&sm)?)),
                            None => None,
                        }
                    }
                    None => None,
                };
                let stored = ckstore
                    .save(ck, prev_data.as_ref().map(|(s, c)| (s, c)))?;
                g.node_mut(member).stored = Some(stored);
                done.insert(member);
                if let Some(p) = prev {
                    report.new_versions.push((p, member));
                }
            }
        } else {
            let parents = load_parents(g, x_new)?;
            let arch = g.node(x_new).model_type.clone();
            let ck = exec.execute(&spec, &arch, &parents)?;
            let prev = g.prev_version(x_new);
            let prev_data = match prev {
                Some(p) => match g.node(p).stored.clone() {
                    Some(sm) => Some((sm.clone(), ckstore.load(&sm)?)),
                    None => None,
                },
                None => None,
            };
            let stored = ckstore.save(&ck, prev_data.as_ref().map(|(s, c)| (s, c)))?;
            g.node_mut(x_new).stored = Some(stored);
            done.insert(x_new);
            if let Some(p) = prev {
                report.new_versions.push((p, x_new));
            }
        }
    }
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::registry::{FreezeSpec, Objective};

    /// Executor that records calls and returns parents[0] + 1.0.
    struct MockExec {
        calls: Vec<String>,
    }

    impl CreationExecutor for MockExec {
        fn execute(
            &mut self,
            spec: &CreationSpec,
            _arch: &str,
            parents: &[Checkpoint],
        ) -> Result<Checkpoint> {
            self.calls.push(format!("{}", spec.kind()));
            let mut ck = parents[0].clone();
            for x in ck.flat.iter_mut() {
                *x += 1.0;
            }
            Ok(ck)
        }

        fn execute_mtl_group(
            &mut self,
            specs: &[&CreationSpec],
            _arch: &str,
            parents: &[Checkpoint],
        ) -> Result<Vec<Checkpoint>> {
            self.calls.push(format!("mtl_group x{}", specs.len()));
            Ok(specs.iter().map(|_| parents[0].clone()).collect())
        }
    }

    /// In-memory checkpoint "store" that just clones.
    struct MockStore {
        saved: Vec<Checkpoint>,
    }

    impl CheckpointStore for MockStore {
        fn load(&self, stored: &StoredModel) -> Result<Checkpoint> {
            // Index is smuggled through the arch field suffix.
            let idx: usize = stored.arch.rsplit('#').next().unwrap().parse()?;
            Ok(self.saved[idx].clone())
        }

        fn save(
            &mut self,
            ck: &Checkpoint,
            _prev: Option<(&StoredModel, &Checkpoint)>,
        ) -> Result<StoredModel> {
            self.saved.push(ck.clone());
            Ok(StoredModel {
                arch: format!("{}#{}", ck.arch, self.saved.len() - 1),
                params: vec![],
            })
        }
    }

    fn ck(v: f32) -> Checkpoint {
        Checkpoint { arch: "t".into(), flat: vec![v; 4] }
    }

    fn finetune_spec(task: &str) -> CreationSpec {
        CreationSpec::Finetune {
            task: task.into(),
            objective: Objective::Cls,
            steps: 1,
            lr: 0.1,
            seed: 0,
            freeze: FreezeSpec::None,
            perturb: None,
        }
    }

    /// root(m) -> a -> b ; root -> c(no cr)
    fn setup() -> (LineageGraph, MockStore) {
        let mut g = LineageGraph::new();
        let mut st = MockStore { saved: vec![] };
        let m = g.add_node("m", "t").unwrap();
        let a = g.add_node("a", "t").unwrap();
        let b = g.add_node("b", "t").unwrap();
        let c = g.add_node("c", "t").unwrap();
        g.add_edge(m, a).unwrap();
        g.add_edge(a, b).unwrap();
        g.add_edge(m, c).unwrap();
        for (i, idx) in [m, a, b, c].into_iter().enumerate() {
            let stored = st.save(&ck(i as f32), None).unwrap();
            g.node_mut(idx).stored = Some(stored);
        }
        g.register_creation_function(a, finetune_spec("t1")).unwrap();
        g.register_creation_function(b, finetune_spec("t2")).unwrap();
        // c intentionally has no creation function.
        (g, st)
    }

    fn register_update(g: &mut LineageGraph, st: &mut MockStore, m: NodeIdx) -> NodeIdx {
        let name = next_version_name(g, &g.node(m).name);
        let mt = g.node(m).model_type.clone();
        let m2 = g.add_node(&name, &mt).unwrap();
        let stored = st.save(&ck(100.0), None).unwrap();
        g.node_mut(m2).stored = Some(stored);
        g.add_version_edge(m, m2).unwrap();
        m2
    }

    #[test]
    fn cascade_creates_and_trains_descendants() {
        let (mut g, mut st) = setup();
        let m = g.idx("m").unwrap();
        let m2 = register_update(&mut g, &mut st, m);
        let mut exec = MockExec { calls: vec![] };
        let report = run_update_cascade(
            &mut g, &mut st, &mut exec, m, m2,
            |_, _| false, |_, _| false,
        )
        .unwrap();
        // a and b get new versions; c skipped (no cr).
        assert_eq!(report.new_versions.len(), 2);
        assert_eq!(report.skipped_no_cr.len(), 1);
        let a2 = g.idx("a@v2").unwrap();
        let b2 = g.idx("b@v2").unwrap();
        // a@v2's parent is m@v2; b@v2's parent is a@v2.
        assert_eq!(g.node(a2).prov_parents, vec![m2]);
        assert_eq!(g.node(b2).prov_parents, vec![a2]);
        // Trained values flow: m2=100 -> a2=101 -> b2=102.
        let a2_ck = st.load(g.node(a2).stored.as_ref().unwrap()).unwrap();
        assert_eq!(a2_ck.flat[0], 101.0);
        let b2_ck = st.load(g.node(b2).stored.as_ref().unwrap()).unwrap();
        assert_eq!(b2_ck.flat[0], 102.0);
        g.integrity_check().unwrap();
        // Old versions untouched.
        assert!(g.node(g.idx("a").unwrap()).stored.is_some());
    }

    #[test]
    fn cascade_respects_skip() {
        let (mut g, mut st) = setup();
        let m = g.idx("m").unwrap();
        let a = g.idx("a").unwrap();
        let m2 = register_update(&mut g, &mut st, m);
        let mut exec = MockExec { calls: vec![] };
        // Skip a: only b would remain, but its parent a has no new version,
        // so b@v2 trains against the OLD a (parent fallback).
        let report = run_update_cascade(
            &mut g, &mut st, &mut exec, m, m2,
            move |_, i| i == a, |_, _| false,
        )
        .unwrap();
        assert!(g.idx("a@v2").is_err());
        assert!(g.idx("b@v2").is_ok());
        assert_eq!(report.new_versions.len(), 1);
        let b2 = g.idx("b@v2").unwrap();
        let b2_ck = st.load(g.node(b2).stored.as_ref().unwrap()).unwrap();
        assert_eq!(b2_ck.flat[0], 2.0); // old a (=1.0) + 1
    }

    #[test]
    fn cascade_requires_version_edge() {
        let (mut g, mut st) = setup();
        let m = g.idx("m").unwrap();
        let a = g.idx("a").unwrap();
        let mut exec = MockExec { calls: vec![] };
        assert!(run_update_cascade(
            &mut g, &mut st, &mut exec, m, a,
            |_, _| false, |_, _| false
        )
        .is_err());
    }

    #[test]
    fn version_names_increment() {
        let mut g = LineageGraph::new();
        g.add_node("x", "t").unwrap();
        assert_eq!(next_version_name(&g, "x"), "x@v2");
        g.add_node("x@v2", "t").unwrap();
        assert_eq!(next_version_name(&g, "x@v2"), "x@v3");
        g.add_node("x@v3", "t").unwrap();
        assert_eq!(next_version_name(&g, "x@v2"), "x@v4");
    }

    #[test]
    fn mtl_group_trains_once() {
        let mut g = LineageGraph::new();
        let mut st = MockStore { saved: vec![] };
        let m = g.add_node("m", "t").unwrap();
        let t1 = g.add_node("t1", "t").unwrap();
        let t2 = g.add_node("t2", "t").unwrap();
        g.add_edge(m, t1).unwrap();
        g.add_edge(m, t2).unwrap();
        for idx in [m, t1, t2] {
            let s = st.save(&ck(0.0), None).unwrap();
            g.node_mut(idx).stored = Some(s);
        }
        let mtl = |task: &str| CreationSpec::Mtl {
            task: task.into(),
            group: vec!["t1".into(), "t2".into()],
            steps: 1,
            lr: 0.1,
            seed: 0,
        };
        g.register_creation_function(t1, mtl("t1")).unwrap();
        g.register_creation_function(t2, mtl("t2")).unwrap();
        let m2 = register_update(&mut g, &mut st, m);
        let mut exec = MockExec { calls: vec![] };
        let report = run_update_cascade(
            &mut g, &mut st, &mut exec, m, m2,
            |_, _| false, |_, _| false,
        )
        .unwrap();
        assert_eq!(report.new_versions.len(), 2);
        // The group executed exactly once.
        assert_eq!(
            exec.calls.iter().filter(|c| c.starts_with("mtl_group")).count(),
            1
        );
    }
}
