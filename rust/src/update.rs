//! Automated model updating (paper §5, Algorithm 2): the execution-tier
//! traits and the serial convenience wrapper.
//!
//! Given an update `m → m'` (the user registered a new version `m'` of
//! model `m`), a cascade creates new versions of every provenance
//! descendant of `m` and re-executes their creation functions against
//! the updated parents. The implementation lives in [`crate::cascade`]
//! as three layers — planning, wavefront scheduling, journaling —
//! [`run_update_cascade`] here is the one-call serial (`jobs = 1`) form
//! kept for library users, tests and benches.
//!
//! The two traits below are the contract between the cascade engine and
//! its substrate. Both are **`&self + Send + Sync`**: one executor and
//! one checkpoint store are shared by reference across the scheduler's
//! worker threads, so implementations keep any internal mutability
//! behind their own synchronization (see [`crate::train::Trainer`]'s
//! mutexed loss traces).
//!
//! MGit never overwrites existing models: the old versions stay
//! loadable, and the storage layer delta-compresses `x'` against `x`.

use anyhow::Result;

use crate::checkpoint::Checkpoint;
use crate::delta::StoredModel;
use crate::lineage::{LineageGraph, NodeIdx};
use crate::registry::CreationSpec;

/// Executes creation specs (implemented over the runtime in
/// [`crate::train`], mocked in tests). Shared across scheduler workers —
/// implementations must be thread-safe.
pub trait CreationExecutor: Send + Sync {
    /// Create a model from its parents per `spec`. `arch` is the target
    /// node's architecture (model_type).
    fn execute(
        &self,
        spec: &CreationSpec,
        arch: &str,
        parents: &[Checkpoint],
    ) -> Result<Checkpoint>;

    /// Merged-cr execution of an MTL group (paper §5): all members are
    /// trained jointly with shared backbone weights. Returns one
    /// checkpoint per member, in `specs` order.
    fn execute_mtl_group(
        &self,
        specs: &[&CreationSpec],
        arch: &str,
        parents: &[Checkpoint],
    ) -> Result<Vec<Checkpoint>>;
}

/// Persists checkpoints into the CAS (with delta compression against the
/// previous version when available). Shared across scheduler workers —
/// implementations must be thread-safe (the [`crate::store::Store`]
/// facade already is).
pub trait CheckpointStore: Send + Sync {
    fn load(&self, stored: &StoredModel) -> Result<Checkpoint>;
    /// `prev` is the node's previous version (delta-compression parent).
    fn save(
        &self,
        ck: &Checkpoint,
        prev: Option<(&StoredModel, &Checkpoint)>,
    ) -> Result<StoredModel>;
}

/// Next-version name: `foo` → `foo@v2`, `foo@v2` → `foo@v3`; appends a
/// disambiguating suffix if the name is somehow taken.
pub fn next_version_name(g: &LineageGraph, name: &str) -> String {
    let (stem, n) = match name.rsplit_once("@v") {
        Some((stem, v)) => match v.parse::<u64>() {
            Ok(k) => (stem.to_string(), k + 1),
            Err(_) => (name.to_string(), 2),
        },
        None => (name.to_string(), 2),
    };
    let mut k = n;
    loop {
        let cand = format!("{stem}@v{k}");
        if g.idx(&cand).is_err() {
            return cand;
        }
        k += 1;
    }
}

/// Outcome of one cascade.
#[derive(Debug, Default)]
pub struct CascadeReport {
    /// (old node, new node) pairs, in plan order.
    pub new_versions: Vec<(NodeIdx, NodeIdx)>,
    /// Nodes skipped because they had no creation function.
    pub skipped_no_cr: Vec<NodeIdx>,
    /// Tasks replayed from a journal instead of re-executed (resume).
    pub resumed_tasks: usize,
}

/// Algorithm 2, serial form. `m` is the updated model's old version,
/// `m_new` the user's new version (already a node, with `stored`
/// populated and a version edge m → m_new in place — the CLI's `cascade`
/// command does that setup). Equivalent to [`crate::cascade::run`] with
/// default options; use that directly for multi-threaded (`jobs > 1`) or
/// journaled execution.
pub fn run_update_cascade(
    g: &mut LineageGraph,
    ckstore: &dyn CheckpointStore,
    exec: &dyn CreationExecutor,
    m: NodeIdx,
    m_new: NodeIdx,
    skip: impl Fn(&LineageGraph, NodeIdx) -> bool,
    terminate: impl Fn(&LineageGraph, NodeIdx) -> bool,
) -> Result<CascadeReport> {
    crate::cascade::run(
        g,
        ckstore,
        exec,
        m,
        m_new,
        skip,
        terminate,
        &crate::cascade::CascadeOptions::default(),
    )
}

#[cfg(test)]
pub(crate) mod testutil {
    //! Mock executor/store shared by the update and cascade test suites.
    use std::sync::Mutex;

    use super::*;
    use crate::registry::{FreezeSpec, Objective};

    /// Executor that records calls and returns parents[0] + 1.0.
    pub struct MockExec {
        pub calls: Mutex<Vec<String>>,
    }

    impl Default for MockExec {
        fn default() -> Self {
            Self::new()
        }
    }

    impl MockExec {
        pub fn new() -> MockExec {
            MockExec { calls: Mutex::new(Vec::new()) }
        }

        pub fn calls(&self) -> Vec<String> {
            self.calls.lock().unwrap().clone()
        }
    }

    impl CreationExecutor for MockExec {
        fn execute(
            &self,
            spec: &CreationSpec,
            _arch: &str,
            parents: &[Checkpoint],
        ) -> Result<Checkpoint> {
            self.calls.lock().unwrap().push(spec.kind().to_string());
            let mut ck = parents[0].clone();
            for x in ck.flat.iter_mut() {
                *x += 1.0;
            }
            Ok(ck)
        }

        fn execute_mtl_group(
            &self,
            specs: &[&CreationSpec],
            _arch: &str,
            parents: &[Checkpoint],
        ) -> Result<Vec<Checkpoint>> {
            self.calls.lock().unwrap().push(format!("mtl_group x{}", specs.len()));
            Ok(specs.iter().map(|_| parents[0].clone()).collect())
        }
    }

    /// In-memory checkpoint "store" that just clones; the slot index is
    /// smuggled through the arch field suffix.
    pub struct MockStore {
        pub saved: Mutex<Vec<Checkpoint>>,
    }

    impl Default for MockStore {
        fn default() -> Self {
            Self::new()
        }
    }

    impl MockStore {
        pub fn new() -> MockStore {
            MockStore { saved: Mutex::new(Vec::new()) }
        }
    }

    impl CheckpointStore for MockStore {
        fn load(&self, stored: &StoredModel) -> Result<Checkpoint> {
            let idx: usize = stored.arch.rsplit('#').next().unwrap().parse()?;
            Ok(self.saved.lock().unwrap()[idx].clone())
        }

        fn save(
            &self,
            ck: &Checkpoint,
            _prev: Option<(&StoredModel, &Checkpoint)>,
        ) -> Result<StoredModel> {
            let mut saved = self.saved.lock().unwrap();
            saved.push(ck.clone());
            Ok(StoredModel {
                arch: format!("{}#{}", ck.arch, saved.len() - 1),
                params: vec![],
            })
        }
    }

    pub fn ck(v: f32) -> Checkpoint {
        Checkpoint { arch: "t".into(), flat: vec![v; 4] }
    }

    pub fn finetune_spec(task: &str) -> CreationSpec {
        CreationSpec::Finetune {
            task: task.into(),
            objective: Objective::Cls,
            steps: 1,
            lr: 0.1,
            seed: 0,
            freeze: FreezeSpec::None,
            perturb: None,
        }
    }

    /// Register `m2` as a stored next version of `m` (what the CLI does
    /// before invoking the cascade).
    pub fn register_update(g: &mut LineageGraph, st: &MockStore, m: NodeIdx) -> NodeIdx {
        let name = next_version_name(g, &g.node(m).name);
        let mt = g.node(m).model_type.clone();
        let m2 = g.add_node(&name, &mt).unwrap();
        let stored = st.save(&ck(100.0), None).unwrap();
        g.node_mut(m2).stored = Some(stored);
        g.add_version_edge(m, m2).unwrap();
        m2
    }
}

#[cfg(test)]
mod tests {
    use super::testutil::*;
    use super::*;

    /// root(m) -> a -> b ; root -> c(no cr)
    fn setup() -> (LineageGraph, MockStore) {
        let mut g = LineageGraph::new();
        let st = MockStore::new();
        let m = g.add_node("m", "t").unwrap();
        let a = g.add_node("a", "t").unwrap();
        let b = g.add_node("b", "t").unwrap();
        let c = g.add_node("c", "t").unwrap();
        g.add_edge(m, a).unwrap();
        g.add_edge(a, b).unwrap();
        g.add_edge(m, c).unwrap();
        for (i, idx) in [m, a, b, c].into_iter().enumerate() {
            let stored = st.save(&ck(i as f32), None).unwrap();
            g.node_mut(idx).stored = Some(stored);
        }
        g.register_creation_function(a, finetune_spec("t1")).unwrap();
        g.register_creation_function(b, finetune_spec("t2")).unwrap();
        // c intentionally has no creation function.
        (g, st)
    }

    #[test]
    fn cascade_creates_and_trains_descendants() {
        let (mut g, st) = setup();
        let m = g.idx("m").unwrap();
        let m2 = register_update(&mut g, &st, m);
        let exec = MockExec::new();
        let report =
            run_update_cascade(&mut g, &st, &exec, m, m2, |_, _| false, |_, _| false)
                .unwrap();
        // a and b get new versions; c skipped (no cr).
        assert_eq!(report.new_versions.len(), 2);
        assert_eq!(report.skipped_no_cr.len(), 1);
        let a2 = g.idx("a@v2").unwrap();
        let b2 = g.idx("b@v2").unwrap();
        // a@v2's parent is m@v2; b@v2's parent is a@v2.
        assert_eq!(g.node(a2).prov_parents, vec![m2]);
        assert_eq!(g.node(b2).prov_parents, vec![a2]);
        // Trained values flow: m2=100 -> a2=101 -> b2=102.
        let a2_ck = st.load(g.node(a2).stored.as_ref().unwrap()).unwrap();
        assert_eq!(a2_ck.flat[0], 101.0);
        let b2_ck = st.load(g.node(b2).stored.as_ref().unwrap()).unwrap();
        assert_eq!(b2_ck.flat[0], 102.0);
        g.integrity_check().unwrap();
        // Old versions untouched.
        assert!(g.node(g.idx("a").unwrap()).stored.is_some());
    }

    #[test]
    fn cascade_respects_skip() {
        let (mut g, st) = setup();
        let m = g.idx("m").unwrap();
        let a = g.idx("a").unwrap();
        let m2 = register_update(&mut g, &st, m);
        let exec = MockExec::new();
        // Skip a: only b would remain, but its parent a has no new version,
        // so b@v2 trains against the OLD a (parent fallback).
        let report =
            run_update_cascade(&mut g, &st, &exec, m, m2, move |_, i| i == a, |_, _| false)
                .unwrap();
        assert!(g.idx("a@v2").is_err());
        assert!(g.idx("b@v2").is_ok());
        assert_eq!(report.new_versions.len(), 1);
        let b2 = g.idx("b@v2").unwrap();
        let b2_ck = st.load(g.node(b2).stored.as_ref().unwrap()).unwrap();
        assert_eq!(b2_ck.flat[0], 2.0); // old a (=1.0) + 1
    }

    #[test]
    fn cascade_requires_version_edge() {
        let (mut g, st) = setup();
        let m = g.idx("m").unwrap();
        let a = g.idx("a").unwrap();
        let exec = MockExec::new();
        assert!(
            run_update_cascade(&mut g, &st, &exec, m, a, |_, _| false, |_, _| false)
                .is_err()
        );
    }

    #[test]
    fn version_names_increment() {
        let mut g = LineageGraph::new();
        g.add_node("x", "t").unwrap();
        assert_eq!(next_version_name(&g, "x"), "x@v2");
        g.add_node("x@v2", "t").unwrap();
        assert_eq!(next_version_name(&g, "x@v2"), "x@v3");
        g.add_node("x@v3", "t").unwrap();
        assert_eq!(next_version_name(&g, "x@v2"), "x@v4");
    }

    #[test]
    fn mtl_group_trains_once() {
        let mut g = LineageGraph::new();
        let st = MockStore::new();
        let m = g.add_node("m", "t").unwrap();
        let t1 = g.add_node("t1", "t").unwrap();
        let t2 = g.add_node("t2", "t").unwrap();
        g.add_edge(m, t1).unwrap();
        g.add_edge(m, t2).unwrap();
        for idx in [m, t1, t2] {
            let s = st.save(&ck(0.0), None).unwrap();
            g.node_mut(idx).stored = Some(s);
        }
        let mtl = |task: &str| CreationSpec::Mtl {
            task: task.into(),
            group: vec!["t1".into(), "t2".into()],
            steps: 1,
            lr: 0.1,
            seed: 0,
        };
        g.register_creation_function(t1, mtl("t1")).unwrap();
        g.register_creation_function(t2, mtl("t2")).unwrap();
        let m2 = register_update(&mut g, &st, m);
        let exec = MockExec::new();
        let report =
            run_update_cascade(&mut g, &st, &exec, m, m2, |_, _| false, |_, _| false)
                .unwrap();
        assert_eq!(report.new_versions.len(), 2);
        // The group executed exactly once.
        assert_eq!(
            exec.calls().iter().filter(|c| c.starts_with("mtl_group")).count(),
            1
        );
    }
}
