//! The `mgit` command-line interface (paper §3.1: "analogous to git's
//! command-line interface") and the on-disk repository wrapper.
//!
//! A repository is a directory containing `.mgit/graph.json` (lineage
//! graph + test registry, re-serialized after every mutating operation,
//! matching §3.1) and `.mgit/objects/` (the content-addressed store:
//! loose staging fan-out plus `pack/*.pack` pack files — see
//! `docs/STORAGE.md`).
//!
//! Commands:
//! ```text
//! mgit init [--dir D]
//! mgit log                       # nodes, edges, versions
//! mgit show <node>
//! mgit fsck                      # graph + object + cross-pack integrity
//! mgit diff <a> <b>              # structural/contextual divergence
//! mgit merge <base> <m1> <m2> [--out name]
//! mgit gc                        # sweep unreachable loose objects
//! mgit repack [--max-chain-depth N] [--prune] [--full|--incremental]
//!                                # pack new loose objects (incremental,
//!                                # the default) or rewrite every pack
//! mgit verify-pack               # pack checksums + content hashes
//! mgit build <g1|g2|g3|g4|g5>    # train + register a workload graph
//! mgit compress --codec <rle|lzma|zstd> [--eps E]  # re-store with deltas
//! mgit test [--re REGEX]         # run registered tests over the graph
//! mgit cascade <node> [--steps N] [--jobs N]
//!                                # perturb-retrain node, cascade children
//!                                # (wavefront-parallel over N workers)
//! mgit cascade --resume [--jobs N] # finish an interrupted cascade
//! mgit stats                     # store/dedup/chain-depth statistics
//! ```

use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};
use regex::Regex;

use crate::autoconstruct::AutoConfig;
use crate::cascade;
use crate::checkpoint::Checkpoint;
use crate::delta::{self, Codec, CompressConfig, DeltaKernel, NativeKernel};
use crate::diff::{divergence_scores, value_distance};
use crate::lineage::{traversal, LineageGraph};
use crate::merge::{merge, MergeOutcome};
use crate::modeldag::ModelDag;
use crate::registry::{run_test, CreationSpec, Objective, PerturbSpec, TestScope, TestSpec};
use crate::runtime::Runtime;
use crate::store::{ObjectId, Store};
use crate::train::{CasCheckpointStore, Trainer};
use crate::update;
use crate::util::argparse::Args;
use crate::util::{human_bytes, human_secs};
use crate::workloads::{self, PersistMode, Scale};

/// An on-disk MGit repository.
pub struct Repo {
    pub root: PathBuf,
    pub graph: LineageGraph,
    pub store: Store,
}

impl Repo {
    pub fn mgit_dir(root: &Path) -> PathBuf {
        root.join(".mgit")
    }

    pub fn graph_path(root: &Path) -> PathBuf {
        Self::mgit_dir(root).join("graph.json")
    }

    fn stats_path(root: &Path) -> PathBuf {
        Self::mgit_dir(root).join("stats.json")
    }

    pub fn init(root: &Path) -> Result<Repo> {
        let dir = Self::mgit_dir(root);
        if Self::graph_path(root).exists() {
            bail!("repository already initialized at {}", dir.display());
        }
        std::fs::create_dir_all(&dir)?;
        let store = Store::open_packed(&dir.join("objects"))?;
        let graph = LineageGraph::new();
        graph.save(&Self::graph_path(root))?;
        Ok(Repo { root: root.to_path_buf(), graph, store })
    }

    /// De-serialize at the start of an operation (paper §3.1). The store
    /// is pack-capable: loose staging first, then pack indexes.
    pub fn open(root: &Path) -> Result<Repo> {
        let graph = LineageGraph::load(&Self::graph_path(root))?;
        let store = Store::open_packed(&Self::mgit_dir(root).join("objects"))?;
        Ok(Repo { root: root.to_path_buf(), graph, store })
    }

    /// Serialize at the end of every operation (paper §3.1); also folds
    /// this process's store counters into the persistent cumulative
    /// stats that `mgit stats` reports.
    pub fn save(&self) -> Result<()> {
        self.graph.save(&Self::graph_path(&self.root))?;
        self.persist_stats()
    }

    /// Cumulative (puts, dedup_hits, bytes_written) since `init`.
    pub fn load_stats(root: &Path) -> (u64, u64, u64) {
        let read = || -> Result<(u64, u64, u64)> {
            let text = std::fs::read_to_string(Self::stats_path(root))?;
            let j = crate::util::json::parse(&text)?;
            Ok((
                j.req_usize("puts")? as u64,
                j.req_usize("dedup_hits")? as u64,
                j.req_usize("bytes_written")? as u64,
            ))
        };
        read().unwrap_or((0, 0, 0))
    }

    /// Drain the in-process store counters into `.mgit/stats.json`.
    /// Single-writer, like `graph.json`: operations are per-invocation.
    pub fn persist_stats(&self) -> Result<()> {
        let (puts, dedup, written) = self.store.stats.take();
        if puts == 0 && dedup == 0 && written == 0 {
            return Ok(());
        }
        let (p0, d0, w0) = Self::load_stats(&self.root);
        let j = crate::util::json::Json::obj()
            .set("puts", (p0 + puts) as usize)
            .set("dedup_hits", (d0 + dedup) as usize)
            .set("bytes_written", (w0 + written) as usize);
        let path = Self::stats_path(&self.root);
        let write = || -> Result<()> {
            let tmp = path.with_extension("json.tmp");
            std::fs::write(&tmp, j.to_string_pretty())?;
            std::fs::rename(&tmp, &path)?;
            Ok(())
        };
        let res = write();
        if res.is_err() {
            // Don't lose the drained counts on a failed write; they'll
            // ride along with the next successful persist.
            use std::sync::atomic::Ordering;
            self.store.stats.puts.fetch_add(puts, Ordering::Relaxed);
            self.store.stats.dedup_hits.fetch_add(dedup, Ordering::Relaxed);
            self.store.stats.bytes_written.fetch_add(written, Ordering::Relaxed);
        }
        res
    }

    pub fn load_checkpoint(
        &self,
        node: &str,
        kernel: &dyn DeltaKernel,
        zoo: &crate::checkpoint::ModelZoo,
    ) -> Result<Checkpoint> {
        let n = self.graph.by_name(node)?;
        let sm = n
            .stored
            .as_ref()
            .ok_or_else(|| anyhow!("node {node} has no stored checkpoint"))?;
        delta::load(&self.store, zoo, sm, kernel)
    }

    /// GC roots: every stored model referenced by the graph. Delta-parent
    /// references are strong and walked transitively; GC aborts rather
    /// than sweep if a live object is unreadable.
    pub fn gc(&self) -> Result<Vec<ObjectId>> {
        let roots = self.graph.object_roots();
        self.store.gc(&roots, |bytes| {
            crate::store::format::TensorObject::decode(bytes)
                .map(|o| o.refs())
                .unwrap_or_default()
        })
    }
}

/// Entry point used by `rust/src/main.rs`.
pub fn run(argv: Vec<String>) -> Result<()> {
    let args = Args::parse(argv)?;
    let root = PathBuf::from(args.flag_or("dir", "."));
    let artifacts = PathBuf::from(args.flag_or("artifacts", "artifacts"));
    match args.command.as_str() {
        "" | "help" => {
            print!("{}", HELP);
            Ok(())
        }
        "init" => {
            Repo::init(&root)?;
            println!("initialized empty MGit repository in {}", Repo::mgit_dir(&root).display());
            Ok(())
        }
        "log" => cmd_log(&root),
        "show" => cmd_show(&root, &args),
        "fsck" => cmd_fsck(&root),
        "stats" => cmd_stats(&root),
        "repack" => cmd_repack(&root, &args),
        "verify-pack" => cmd_verify_pack(&root),
        "gc" => {
            let repo = Repo::open(&root)?;
            let swept = repo.gc()?;
            println!("swept {} unreachable objects", swept.len());
            Ok(())
        }
        "diff" => cmd_diff(&root, &artifacts, &args),
        "merge" => cmd_merge(&root, &artifacts, &args),
        "build" => cmd_build(&root, &artifacts, &args),
        "compress" => cmd_compress(&root, &artifacts, &args),
        "test" => cmd_test(&root, &artifacts, &args),
        "cascade" => cmd_cascade(&root, &artifacts, &args),
        "auto-insert" => cmd_auto_insert(&root, &artifacts, &args),
        other => bail!("unknown command `{other}` (try `mgit help`)"),
    }
}

const HELP: &str = "\
mgit — model versioning and management (MGit, ICML 2024 reproduction)

usage: mgit <command> [args] [--flags]

  init                       create .mgit/ in --dir (default .)
  log                        list nodes with edges and versions
  show <node>                node details (type, creation fn, params)
  fsck                       check graph invariants, object presence and
                             cross-pack delta-chain integrity
  stats                      object store statistics (loose vs packed,
                             dedup counters, chain-depth histogram,
                             per-pack generations)
  gc                         sweep unreachable loose objects
  repack                     pack new loose objects into a fresh pack
                             (--incremental, the default; --full rewrites
                             every pack) [--max-chain-depth 8] [--prune]
                             [--auto-full-gens 16] [--auto-full-dead 0.5]
                             (incremental auto-promotes to a full rewrite
                             past either threshold; 0 disables; the dead-
                             byte trigger fires only with --prune)
  verify-pack                verify pack checksums + object content hashes
  diff <a> <b>               divergence scores between two models
  merge <base> <m1> <m2>     figure-2 merge (conflict detection)
  build <g1|g2|g3|g4|g5>     train + register a workload graph [--small]
  compress                   re-store all models with delta compression
                             [--codec rle|lzma|zstd] [--eps 1e-4]
  test [--re REGEX]          run registered tests over all nodes
  cascade <node>             retrain <node> on perturbed data, then run
                             the update cascade over its descendants
                             [--jobs N] (wavefront-parallel) — journaled:
                             `cascade --resume` finishes an interrupted run
  auto-insert                rebuild provenance edges automatically (§3.2)

global flags: --dir DIR  --artifacts DIR
";

fn cmd_log(root: &Path) -> Result<()> {
    let repo = Repo::open(root)?;
    let (prov, ver) = repo.graph.edge_counts();
    println!(
        "{} nodes / {} provenance edges / {} version edges",
        repo.graph.len(),
        prov,
        ver
    );
    for node in &repo.graph.nodes {
        let parents: Vec<&str> = node
            .prov_parents
            .iter()
            .map(|&p| repo.graph.node(p).name.as_str())
            .collect();
        let stored = if node.stored.is_some() { "" } else { " (no ckpt)" };
        let cr = node
            .creation
            .as_ref()
            .map(|c| format!(" cr={}", c.kind()))
            .unwrap_or_default();
        println!(
            "  {:<40} [{}]{}{} <- {:?}",
            node.name, node.model_type, stored, cr, parents
        );
    }
    Ok(())
}

fn cmd_show(root: &Path, args: &Args) -> Result<()> {
    let repo = Repo::open(root)?;
    let node = repo.graph.by_name(args.pos(0, "node")?)?;
    println!("name:  {}", node.name);
    println!("type:  {}", node.model_type);
    if let Some(cr) = &node.creation {
        println!("cr:    {}", cr.to_json().to_string_compact());
    }
    println!("meta:  {}", node.metadata.to_string_compact());
    if let Some(sm) = &node.stored {
        println!("params ({}):", sm.params.len());
        for (name, id) in sm.params.iter().take(8) {
            println!("  {:<24} {}", name, id.short());
        }
        if sm.params.len() > 8 {
            println!("  … {} more", sm.params.len() - 8);
        }
    }
    Ok(())
}

fn cmd_fsck(root: &Path) -> Result<()> {
    let repo = Repo::open(root)?;
    repo.graph.integrity_check()?;
    let mut problems = 0;
    // Every model parameter must be present (loose or packed).
    for node in &repo.graph.nodes {
        if let Some(sm) = &node.stored {
            for (pname, id) in &sm.params {
                if !repo.store.has(id) {
                    println!("MISSING object {} ({}:{})", id.short(), node.name, pname);
                    problems += 1;
                }
            }
        }
    }
    // Cross-pack delta-chain integrity: every delta parent must resolve
    // somewhere in the store, whichever pack (or loose file) holds it.
    // Unreadable objects are recorded and the scan continues — fsck must
    // report corruption, not die on it. Orphaned parents are also listed
    // together at the end so a repair pass has the full set in one place.
    let mut orphaned: std::collections::BTreeMap<ObjectId, Vec<ObjectId>> = Default::default();
    for id in repo.store.list()? {
        let bytes = match repo.store.get(&id) {
            Ok(b) => b,
            Err(e) => {
                println!("UNREADABLE object {}: {e:#}", id.short());
                problems += 1;
                continue;
            }
        };
        if let Ok(obj) = crate::store::format::TensorObject::decode(&bytes) {
            for parent in obj.refs() {
                if !repo.store.has(&parent) {
                    println!(
                        "DANGLING delta parent {} (referenced by {})",
                        parent.short(),
                        id.short()
                    );
                    orphaned.entry(parent).or_default().push(id);
                    problems += 1;
                }
            }
        }
    }
    if !orphaned.is_empty() {
        println!("orphaned delta parents ({}):", orphaned.len());
        for (parent, children) in &orphaned {
            let refs: Vec<String> = children.iter().map(|c| c.short()).collect();
            println!("  {} <- [{}]", parent.hex(), refs.join(", "));
        }
    }
    // Pack structure (checksums, index/offset agreement).
    if let Some(ps) = repo.store.as_packed() {
        for p in ps.packs() {
            if let Err(e) = p.verify() {
                println!("BAD PACK {}: {e:#}", p.path.display());
                problems += 1;
            }
        }
        let (loose, packed) = ps.counts()?;
        println!("objects: {loose} loose / {packed} packed in {} packs", ps.packs().len());
    }
    if problems == 0 {
        println!("ok: {} nodes, all invariants hold, all objects present", repo.graph.len());
        Ok(())
    } else {
        bail!("{problems} fsck problems")
    }
}

fn cmd_stats(root: &Path) -> Result<()> {
    let repo = Repo::open(root)?;
    let objects = repo.store.list()?;
    let bytes = repo.store.stored_bytes()?;
    let mut raw_bytes: u64 = 0;
    let mut delta_objs = 0usize;
    // One decode pass feeds both the byte accounting and (via the parent
    // map) the chain-depth histogram below.
    let mut parents: std::collections::HashMap<ObjectId, Option<ObjectId>> =
        Default::default();
    for id in &objects {
        let mut parent = None;
        if let Ok(obj) = crate::store::format::TensorObject::decode(&repo.store.get(id)?) {
            let numel: usize = obj.shape().iter().product();
            raw_bytes += (numel * 4) as u64;
            if let crate::store::format::TensorObject::Delta { parent: p, .. } = obj {
                delta_objs += 1;
                parent = Some(p);
            }
        }
        parents.insert(*id, parent);
    }
    let (loose, packed) = match repo.store.as_packed() {
        Some(ps) => ps.counts()?,
        None => (objects.len(), 0),
    };
    println!("objects:        {} ({loose} loose, {packed} packed)", objects.len());
    // Per-pack generation info: incremental repacks append packs over
    // time; sort by file mtime so "gen 0" is the oldest.
    if let Some(ps) = repo.store.as_packed() {
        if !ps.packs().is_empty() {
            let mut gens: Vec<_> = ps
                .packs()
                .iter()
                .map(|p| {
                    let mtime = std::fs::metadata(&p.path)
                        .and_then(|m| m.modified())
                        .unwrap_or(std::time::SystemTime::UNIX_EPOCH);
                    (mtime, p)
                })
                .collect();
            gens.sort_by_key(|(t, _)| *t);
            println!("packs:          {} ({} reads)", gens.len(), gens[0].1.reader_kind());
            for (generation, (_, p)) in gens.iter().enumerate() {
                let name = p
                    .path
                    .file_name()
                    .map(|n| n.to_string_lossy().into_owned())
                    .unwrap_or_else(|| p.path.display().to_string());
                println!(
                    "  gen {generation:<3} {:<6} objects  {:>10}  {}",
                    p.object_count(),
                    human_bytes(p.size_bytes()),
                    name
                );
            }
        }
    }
    println!("delta-encoded:  {delta_objs}");
    println!("stored bytes:   {}", human_bytes(bytes));
    println!("logical bytes:  {}", human_bytes(raw_bytes));
    if bytes > 0 {
        println!("object-level compression ratio: {:.2}x", raw_bytes as f64 / bytes as f64);
    }
    // Cumulative dedup counters (persisted across invocations).
    let (puts, dedup, written) = Repo::load_stats(root);
    println!(
        "puts:           {puts} total, {dedup} dedup hits ({:.1}% hit rate)",
        if puts > 0 { 100.0 * dedup as f64 / puts as f64 } else { 0.0 }
    );
    println!("bytes written:  {}", human_bytes(written));
    // Delta-chain depths (reconstruction cost driver; see docs/STORAGE.md).
    let depths = crate::store::pack::chain_depths_from_parents(&parents)?;
    let max_depth = depths.values().copied().max().unwrap_or(0);
    let chain_lens: Vec<usize> = depths.values().copied().filter(|&d| d > 0).collect();
    let mean_depth = if chain_lens.is_empty() {
        0.0
    } else {
        chain_lens.iter().sum::<usize>() as f64 / chain_lens.len() as f64
    };
    println!("chain depth:    max {max_depth}, mean {mean_depth:.2} (over delta objects)");
    let buckets: [(usize, usize, &str); 6] = [
        (0, 0, "0 (base)"),
        (1, 2, "1-2"),
        (3, 4, "3-4"),
        (5, 8, "5-8"),
        (9, 16, "9-16"),
        (17, usize::MAX, "17+"),
    ];
    for (lo, hi, label) in buckets {
        let n = depths.values().filter(|&&d| d >= lo && d <= hi).count();
        if n > 0 {
            println!("  depth {label:<9} {n}");
        }
    }
    Ok(())
}

fn cmd_repack(root: &Path, args: &Args) -> Result<()> {
    use crate::store::pack::RepackMode;
    let mut repo = Repo::open(root)?;
    if args.has("full") && args.has("incremental") {
        bail!("--full and --incremental are mutually exclusive");
    }
    let mode = if args.has("full") { RepackMode::Full } else { RepackMode::Incremental };
    // Generation-aware escalation defaults (ROADMAP follow-up): after 16
    // generations or once half the sealed pack bytes are garbage, an
    // incremental run is promoted to a full rewrite. `0` disables either.
    let max_generations = match args.flag_usize("auto-full-gens", 16)? {
        0 => None,
        n => Some(n),
    };
    let max_dead_ratio = {
        let r = args.flag_f64("auto-full-dead", 0.5)?;
        if r <= 0.0 {
            None
        } else {
            Some(r)
        }
    };
    let cfg = crate::store::pack::RepackConfig {
        max_chain_depth: args.flag_usize("max-chain-depth", 8)?,
        prune: args.has("prune"),
        mode,
        max_generations,
        max_dead_ratio,
    };
    let roots = repo.graph.object_roots();
    let t = crate::util::timing::Timer::start();
    // NativeKernel is the bit-compatible oracle of the Pallas kernel, so
    // re-based encodings agree across runtime backends.
    let report = crate::store::pack::repack(&mut repo.store, &roots, &cfg, &NativeKernel)?;
    repo.save()?;
    let mode_label = match (mode, &report.escalated) {
        (RepackMode::Full, _) => "full".to_string(),
        (RepackMode::Incremental, None) => "incremental".to_string(),
        (RepackMode::Incremental, Some(reason)) => {
            format!("incremental -> full: {reason}")
        }
    };
    println!(
        "repacked {} objects ({} retained in old packs, {} carried dead) in {} [{}]",
        report.packed,
        report.retained_packed,
        report.carried_dead,
        human_secs(t.elapsed_secs()),
        mode_label
    );
    if report.dead_ratio > 0.0 {
        println!("garbage: {:.1}% of sealed pack bytes are unreachable", report.dead_ratio * 100.0);
    }
    println!("packs:  {} -> {}", report.packs_before, report.packs_after);
    println!(
        "chains: max depth {} -> {} ({} re-based onto nearer ancestors, {} new bases)",
        report.max_depth_before,
        report.max_depth_after,
        report.rebased_delta,
        report.new_bases
    );
    println!(
        "store:  {} -> {} ({} loose demoted, {} pruned)",
        human_bytes(report.bytes_before),
        human_bytes(report.bytes_after),
        report.loose_demoted,
        report.pruned_loose
    );
    if let Some(p) = &report.pack_path {
        println!("pack:   {}", p.display());
    }
    Ok(())
}

fn cmd_verify_pack(root: &Path) -> Result<()> {
    let repo = Repo::open(root)?;
    let Some(ps) = repo.store.as_packed() else {
        bail!("object store is not pack-capable");
    };
    if ps.packs().is_empty() {
        println!("no packs to verify");
        return Ok(());
    }
    // Structure first: checksums, counts, offset/length agreement. A bad
    // pack is reported (with the failing pack named and, for entry-level
    // problems, the offending offset) and the scan continues, so one
    // corrupt pack doesn't mask others.
    let mut total = 0usize;
    let mut failures: Vec<String> = Vec::new();
    let mut structurally_ok: Vec<bool> = Vec::with_capacity(ps.packs().len());
    for p in ps.packs() {
        match p.verify() {
            Ok(()) => {
                total += p.object_count();
                println!(
                    "pack {}: {} objects, structure ok",
                    p.path.display(),
                    p.object_count()
                );
                structurally_ok.push(true);
            }
            Err(e) => {
                println!("BAD PACK {}: {e:#}", p.path.display());
                failures.push(format!("{}: {e:#}", p.path.display()));
                structurally_ok.push(false);
            }
        }
    }
    // Content second: each pack's *own copy* of every object (ids may be
    // duplicated across packs after a crash) must still hash to its id
    // once its delta chain — possibly crossing packs / loose staging —
    // is resolved. Structurally bad packs are skipped (their offsets
    // can't be trusted), and per-object errors are recorded rather than
    // aborting, so one corruption never masks another.
    let mut cache: std::collections::HashMap<ObjectId, Vec<f32>> = Default::default();
    let mut checked = 0usize;
    let mut opaque = 0usize;
    for (p, ok) in ps.packs().iter().zip(&structurally_ok) {
        if !ok {
            continue;
        }
        for id in p.index.ids().collect::<Vec<_>>() {
            let offset = p.index.lookup(&id).map(|(o, _)| o).unwrap_or(0);
            let bytes = match p.get(&id) {
                Ok(Some(b)) => b,
                Ok(None) => {
                    let msg = format!(
                        "index lists {} but pack {} lacks it",
                        id.short(),
                        p.path.display()
                    );
                    println!("BAD OBJECT {msg}");
                    failures.push(msg);
                    continue;
                }
                Err(e) => {
                    let msg = format!(
                        "object {} at offset {offset} in pack {} unreadable: {e:#}",
                        id.short(),
                        p.path.display()
                    );
                    println!("BAD OBJECT {msg}");
                    failures.push(msg);
                    continue;
                }
            };
            let obj = match crate::store::format::TensorObject::decode(&bytes) {
                Ok(o) => o,
                Err(_) => {
                    opaque += 1; // non-MGTF blob: structure-only
                    continue;
                }
            };
            let shape = obj.shape().to_vec();
            let want = match &obj {
                crate::store::format::TensorObject::Raw { dtype, payload, .. } => {
                    crate::store::hash_tensor(*dtype, &shape, payload)
                }
                crate::store::format::TensorObject::Delta { .. } => {
                    match delta::resolve_object(&repo.store, &obj, &NativeKernel, &mut cache, 0)
                    {
                        Ok(values) => crate::store::hash_tensor(
                            crate::tensor::DType::F32,
                            &shape,
                            &crate::tensor::f32_to_bytes(&values),
                        ),
                        Err(e) => {
                            let msg = format!(
                                "object {} at offset {offset} in pack {} has an \
                                 unresolvable delta chain: {e:#}",
                                id.short(),
                                p.path.display()
                            );
                            println!("BAD OBJECT {msg}");
                            failures.push(msg);
                            continue;
                        }
                    }
                }
            };
            if want != id {
                let msg = format!(
                    "object {} at offset {offset} in pack {} does not hash to its id",
                    id.short(),
                    p.path.display()
                );
                println!("BAD OBJECT {msg}");
                failures.push(msg);
                continue;
            }
            checked += 1;
            // Ancestor values only help while verifying nearby chain
            // links; keep peak memory bounded on huge stores.
            if cache.len() > 4096 {
                cache.clear();
            }
        }
    }
    if !failures.is_empty() {
        bail!("verify-pack found {} problems:\n  {}", failures.len(), failures.join("\n  "));
    }
    println!(
        "verify-pack ok: {total} objects in {} packs, {checked} content hashes verified, \
         {opaque} opaque blobs",
        ps.packs().len()
    );
    Ok(())
}

fn cmd_diff(root: &Path, artifacts: &Path, args: &Args) -> Result<()> {
    let repo = Repo::open(root)?;
    let rt = Runtime::new(artifacts)?;
    let zoo = rt.zoo();
    let (a, b) = (args.pos(0, "a")?, args.pos(1, "b")?);
    let na = repo.graph.by_name(a)?;
    let nb = repo.graph.by_name(b)?;
    let (sa, sb) = (zoo.arch(&na.model_type)?, zoo.arch(&nb.model_type)?);
    let da = ModelDag::from_arch(sa, na.stored.as_ref())?;
    let db = ModelDag::from_arch(sb, nb.stored.as_ref())?;
    let (ds, dc) = divergence_scores(&da, &db);
    println!("structural divergence: {ds:.4}");
    println!("contextual divergence: {dc:.4}");
    if na.stored.is_some() && nb.stored.is_some() {
        let cka = repo.load_checkpoint(a, &rt, zoo)?;
        let ckb = repo.load_checkpoint(b, &rt, zoo)?;
        let dv = value_distance(&da, sa, &cka, &db, sb, &ckb)?;
        println!("value distance:        {dv:.4}");
    }
    Ok(())
}

fn cmd_merge(root: &Path, artifacts: &Path, args: &Args) -> Result<()> {
    let mut repo = Repo::open(root)?;
    let rt = Runtime::new(artifacts)?;
    let zoo = rt.zoo();
    let (base, m1, m2) = (args.pos(0, "base")?, args.pos(1, "m1")?, args.pos(2, "m2")?);
    let arch = repo.graph.by_name(base)?.model_type.clone();
    let spec = zoo.arch(&arch)?;
    let dag = ModelDag::from_arch(spec, None)?;
    let b = repo.load_checkpoint(base, &rt, zoo)?;
    let c1 = repo.load_checkpoint(m1, &rt, zoo)?;
    let c2 = repo.load_checkpoint(m2, &rt, zoo)?;
    let out = merge(spec, &dag, &b, &c1, &c2)?;
    println!("merge verdict: {}", out.verdict());
    match &out {
        MergeOutcome::Conflict { overlapping } => {
            println!("layers changed by both sides: {overlapping:?}");
            println!("manual resolution required");
        }
        MergeOutcome::PossibleConflict { dependent_pairs, .. } => {
            println!("dependent changed-layer pairs: {dependent_pairs:?}");
            println!("run `mgit test` on the merged model before accepting");
        }
        MergeOutcome::Clean { .. } => {}
    }
    if let Some(merged) = out.merged() {
        let name = args.flag_or("out", "merged");
        let (sm, _) = delta::store_raw(&repo.store, spec, merged)?;
        let idx = repo.graph.add_node(name, &arch)?;
        repo.graph.node_mut(idx).stored = Some(sm);
        let b1 = repo.graph.idx(m1)?;
        let b2 = repo.graph.idx(m2)?;
        repo.graph.add_edge(b1, idx)?;
        repo.graph.add_edge(b2, idx)?;
        repo.save()?;
        println!("stored merged model as `{name}`");
    }
    Ok(())
}

fn scale_from(args: &Args) -> Scale {
    if args.has("small") {
        Scale::small()
    } else {
        Scale::paper()
    }
}

fn cmd_build(root: &Path, artifacts: &Path, args: &Args) -> Result<()> {
    let mut repo = Repo::open(root)?;
    let rt = Runtime::new(artifacts)?;
    let scale = scale_from(args);
    let which = args.pos(0, "graph")?;
    let t = crate::util::timing::Timer::start();
    let mut wl = match which {
        "g1" => workloads::build_g1(&rt, &scale)?,
        "g2" => workloads::build_g2(&rt, &scale)?,
        "g3" => workloads::build_g3(&rt, &scale)?,
        "g4" => workloads::build_g4(&rt, &scale)?,
        "g5" => workloads::build_g5(&rt, &scale)?,
        other => bail!("unknown workload `{other}`"),
    };
    workloads::persist(
        &mut wl,
        &repo.store,
        rt.zoo(),
        &rt,
        PersistMode::HashOnly,
        |_, _| Ok(true),
    )?;
    // Merge the workload graph into the repo graph.
    merge_graphs(&mut repo.graph, &wl.graph)?;
    repo.save()?;
    let (prov, ver) = wl.graph.edge_counts();
    println!(
        "built {}: {} nodes / {} prov + {} ver edges in {}",
        wl.name,
        wl.graph.len(),
        prov,
        ver,
        human_secs(t.elapsed_secs())
    );
    Ok(())
}

/// Import `src` into `dst` (names must be disjoint).
fn merge_graphs(dst: &mut LineageGraph, src: &LineageGraph) -> Result<()> {
    let mut map = Vec::with_capacity(src.len());
    for node in &src.nodes {
        let idx = dst.add_node(&node.name, &node.model_type)?;
        dst.node_mut(idx).stored = node.stored.clone();
        dst.node_mut(idx).creation = node.creation.clone();
        dst.node_mut(idx).metadata = node.metadata.clone();
        map.push(idx);
    }
    for (i, node) in src.nodes.iter().enumerate() {
        for &p in &node.prov_parents {
            dst.add_edge(map[p], map[i])?;
        }
        for &p in &node.ver_parents {
            dst.add_version_edge(map[p], map[i])?;
        }
    }
    for t in &src.tests.tests {
        let _ = dst.tests.register(&t.name, t.scope.clone(), t.spec.clone());
    }
    Ok(())
}

fn cmd_compress(root: &Path, artifacts: &Path, args: &Args) -> Result<()> {
    let mut repo = Repo::open(root)?;
    let rt = Runtime::new(artifacts)?;
    let zoo = rt.zoo();
    let cfg = CompressConfig {
        eps: args.flag_f64("eps", 1e-4)? as f32,
        codec: Codec::parse(args.flag_or("codec", "lzma"))?,
        prequantize: args.has("prequantize"),
    };
    let t = crate::util::timing::Timer::start();
    let mut raw = 0u64;
    let mut stored = 0u64;
    // Roots-first over provenance edges.
    let order: Vec<usize> = {
        let roots = repo.graph.roots();
        let mut out = Vec::new();
        for r in roots {
            out.extend(traversal::bfs(
                &repo.graph,
                r,
                traversal::EdgeFilter::Both,
                |_, _| false,
                |_, _| false,
            ));
        }
        out
    };
    let mut rec_cache: std::collections::HashMap<usize, Checkpoint> = Default::default();
    for idx in order {
        let Some(sm) = repo.graph.node(idx).stored.clone() else { continue };
        let ck = delta::load(&repo.store, zoo, &sm, &rt)?;
        let spec = zoo.arch(&ck.arch)?;
        let parent = repo.graph.node(idx)
            .ver_parents
            .first()
            .or_else(|| repo.graph.node(idx).prov_parents.first())
            .copied();
        match parent.and_then(|p| {
            repo.graph.node(p).stored.clone().map(|s| (p, s))
        }) {
            Some((p, psm)) if repo.graph.node(p).model_type == ck.arch => {
                let pck = match rec_cache.get(&p) {
                    Some(c) => c.clone(),
                    None => delta::load(&repo.store, zoo, &psm, &rt)?,
                };
                let (sm2, final_ck, rep, _) = delta::delta_compress_checked(
                    &repo.store, spec, &ck, zoo.arch(&pck.arch)?, &pck, &psm, cfg, &rt,
                    |_| Ok(true),
                )?;
                raw += rep.raw_bytes;
                stored += rep.stored_bytes;
                repo.graph.node_mut(idx).stored = Some(sm2);
                rec_cache.insert(idx, final_ck);
            }
            _ => {
                let (sm2, rep) = delta::store_raw(&repo.store, spec, &ck)?;
                raw += rep.raw_bytes;
                stored += rep.stored_bytes;
                repo.graph.node_mut(idx).stored = Some(sm2);
                rec_cache.insert(idx, ck);
            }
        }
    }
    repo.save()?;
    let swept = repo.gc()?;
    println!(
        "compressed: {} raw -> {} new bytes ({:.2}x vs raw), {} objects swept, took {}",
        human_bytes(raw),
        human_bytes(stored),
        if stored > 0 { raw as f64 / stored as f64 } else { 0.0 },
        swept.len(),
        human_secs(t.elapsed_secs())
    );
    Ok(())
}

fn cmd_test(root: &Path, artifacts: &Path, args: &Args) -> Result<()> {
    let repo = Repo::open(root)?;
    let rt = Runtime::new(artifacts)?;
    let zoo = rt.zoo();
    let re = match args.flag("re") {
        Some(r) => Some(Regex::new(r)?),
        None => None,
    };
    let mut ran = 0;
    let mut failed = 0;
    for node in &repo.graph.nodes {
        let tests: Vec<_> = repo
            .graph
            .tests
            .matching(&node.name, &node.model_type, re.as_ref())
            .cloned()
            .collect();
        if tests.is_empty() || node.stored.is_none() {
            continue;
        }
        let ck = delta::load(&repo.store, zoo, node.stored.as_ref().unwrap(), &rt)?;
        for t in tests {
            let (pass, metric) = run_test(&t.spec, &ck, &rt)?;
            ran += 1;
            if !pass {
                failed += 1;
            }
            println!(
                "{} {:<36} {:<24} metric={metric:.4}",
                if pass { "PASS" } else { "FAIL" },
                node.name,
                t.name
            );
        }
    }
    println!("{ran} tests run, {failed} failed");
    if failed > 0 {
        bail!("{failed} test failures");
    }
    Ok(())
}

fn cmd_cascade(root: &Path, artifacts: &Path, args: &Args) -> Result<()> {
    use crate::update::{CheckpointStore as _, CreationExecutor as _};

    let jobs = args.flag_usize("jobs", 1)?;
    let jdir = cascade::journal_dir(&Repo::mgit_dir(root));
    let resume = args.has("resume");

    // Cheap precondition checks first: a missing/stale journal should
    // produce its actionable message without paying runtime startup
    // (and without runtime-init errors masking it).
    if resume && !cascade::journal_exists(&jdir) {
        bail!("no interrupted cascade to resume (no journal at {})", jdir.display());
    }
    if !resume && cascade::journal_exists(&jdir) {
        bail!(
            "an interrupted cascade journal exists at {}; run `mgit cascade --resume` \
             to finish it (or delete the directory to abandon it)",
            jdir.display()
        );
    }

    // Shared execution substrate: one trainer + one CAS-backed store
    // serve every scheduler worker; parent checkpoints resolve through
    // a shared bounded cache so concurrent loads reuse ancestors.
    let rt = Runtime::new(artifacts)?;
    let zoo = rt.zoo().clone();
    let trainer = Trainer::new(&rt);
    let cache = delta::ResolveCache::with_max_bytes(128, 256 << 20);

    if resume {
        let mut repo = Repo::open(root)?;
        let ckstore = CasCheckpointStore {
            store: &repo.store,
            zoo: &zoo,
            kernel: &NativeKernel,
            compress: Some(CompressConfig::default()),
            cache: Some(&cache),
        };
        let report = cascade::resume(&mut repo.graph, &ckstore, &trainer, &jdir, jobs)
            .with_context(|| {
                format!(
                    "resuming the cascade journaled at {} (a plan that no longer \
                     binds to the graph means the original run died before the \
                     graph was saved — delete the journal directory and re-run \
                     the cascade)",
                    jdir.display()
                )
            })?;
        repo.save()?;
        cascade::remove_journal(&jdir)?;
        println!(
            "resumed cascade: {} new versions ({} tasks replayed from the journal), \
             {} skipped (no cr)",
            report.new_versions.len(),
            report.resumed_tasks,
            report.skipped_no_cr.len()
        );
        for (old, new) in report.new_versions {
            println!("  {} -> {}", repo.graph.node(old).name, repo.graph.node(new).name);
        }
        return Ok(());
    }

    let mut repo = Repo::open(root)?;
    let node_name = args.pos(0, "node")?.to_string();
    let steps = args.flag_usize("steps", 30)?;
    let perturb = args.flag_or("perturb", "swap").to_string();

    let m = repo.graph.idx(&node_name)?;
    let arch = repo.graph.node(m).model_type.clone();
    let ck = repo.load_checkpoint(&node_name, &rt, &zoo)?;

    // Retrain the root on perturbed data -> m'.
    let spec = CreationSpec::Pretrain { corpus_seed: 777, steps, lr: 0.02 };
    let _ = perturb; // root update here is a fresh pretrain continuation
    let new_ck = trainer.execute(&spec, &arch, &[ck.clone()])?;
    let ckstore = CasCheckpointStore {
        store: &repo.store,
        zoo: &zoo,
        kernel: &NativeKernel,
        compress: Some(CompressConfig::default()),
        cache: Some(&cache),
    };
    let sm = ckstore.save(&new_ck, None)?;
    let new_name = update::next_version_name(&repo.graph, &node_name);
    let m_new = repo.graph.add_node(&new_name, &arch)?;
    repo.graph.node_mut(m_new).stored = Some(sm);
    repo.graph.add_version_edge(m, m_new)?;

    // Plan (all graph mutation), journal the plan, then persist the
    // graph so a crash during execution is resumable. Journal-first: if
    // we die between the two writes, graph.json is still pre-cascade —
    // `--resume` then fails to re-bind the plan (its nodes were never
    // saved) and tells the user to delete the journal, which is strictly
    // better than the graph accumulating orphaned, never-stored
    // next-version nodes.
    let plan = cascade::plan_cascade(&mut repo.graph, m, m_new, |_, _| false, |_, _| false)?;
    let journal = cascade::CascadeJournal::create(&jdir, &plan, &repo.graph)?;
    repo.save()?;
    let opts = cascade::CascadeOptions { jobs, journal: Some(&journal) };
    let report = match cascade::execute_and_apply(
        &mut repo.graph,
        &plan,
        &ckstore,
        &trainer,
        &opts,
        &cascade::DoneTasks::new(),
    ) {
        Ok(r) => r,
        Err(e) => {
            eprintln!(
                "cascade interrupted; finished models are journaled — \
                 run `mgit cascade --resume` to continue"
            );
            return Err(e);
        }
    };
    repo.save()?;
    drop(journal);
    cascade::remove_journal(&jdir)?;
    println!(
        "cascade from {node_name} -> {new_name} ({} jobs): {} new versions, \
         {} skipped (no cr)",
        jobs.max(1),
        report.new_versions.len(),
        report.skipped_no_cr.len()
    );
    for (old, new) in report.new_versions {
        println!("  {} -> {}", repo.graph.node(old).name, repo.graph.node(new).name);
    }
    Ok(())
}

fn cmd_auto_insert(root: &Path, artifacts: &Path, args: &Args) -> Result<()> {
    let repo = Repo::open(root)?;
    let rt = Runtime::new(artifacts)?;
    let zoo = rt.zoo();
    let cfg = AutoConfig::default();
    let _ = args;
    // Re-derive provenance edges for all stored nodes, in insertion order.
    let mut order = Vec::new();
    let mut cks = std::collections::HashMap::new();
    for node in &repo.graph.nodes {
        if node.stored.is_some() {
            let ck = repo.load_checkpoint(&node.name, &rt, zoo)?;
            cks.insert(node.name.clone(), ck);
            order.push((node.name.clone(), node.model_type.clone(), None));
        }
    }
    let scratch = Store::in_memory();
    let (g, _, times) = workloads::auto_construct(&rt, &scratch, &order, &cks, &cfg)?;
    println!("auto-constructed {} nodes:", g.len());
    for node in &g.nodes {
        let parents: Vec<&str> =
            node.prov_parents.iter().map(|&p| g.node(p).name.as_str()).collect();
        println!("  {:<40} <- {:?}", node.name, parents);
    }
    let avg = times.iter().sum::<f64>() / times.len().max(1) as f64;
    println!("avg per-model insertion time: {}", human_secs(avg));
    Ok(())
}
