//! The `mgit` command-line interface (paper §3.1: "analogous to git's
//! command-line interface") — a thin shell over the typed operations
//! API in [`crate::ops`]: parse argv → build a request → execute →
//! render the report (human text, or JSON with `--json`). No operation
//! logic lives here.
//!
//! Commands (every one maps to an `ops` request/report pair and accepts
//! `--json`):
//! ```text
//! mgit init [--dir D]
//! mgit log [--limit N [--after NODE] [--type T]]
//!                                # nodes, edges, versions; --limit pages
//!                                # through big graphs without loading them
//! mgit show <node>
//! mgit fsck                      # graph + object + cross-pack integrity
//! mgit diff <a> <b>              # structural/contextual divergence
//! mgit merge <base> <m1> <m2> [--out name]
//! mgit gc                        # sweep unreachable loose objects
//! mgit repack [--max-chain-depth N] [--prune] [--full|--incremental]
//!             [--framing raw|zstd] [--similarity T] [--min-savings F]
//!             [--chunk-dedup]
//!                                # pack new loose objects (incremental,
//!                                # the default) or rewrite every pack;
//!                                # --similarity turns on similarity-driven
//!                                # base selection + chunk dedup
//! mgit verify-pack               # pack checksums + content hashes
//! mgit build <g1|g2|g3|g4|g5>    # train + register a workload graph
//! mgit compress --codec <rle|lzma|zstd> [--eps E]  # re-store with deltas
//! mgit test [--re REGEX]         # run registered tests over the graph
//! mgit cascade <node> [--steps N] [--jobs N|auto]
//!                                # perturb-retrain node, cascade children
//!                                # (wavefront-parallel over N workers)
//! mgit cascade --resume [--jobs N|auto]  # finish an interrupted cascade
//! mgit stats                     # store/dedup/chain-depth statistics
//! mgit synth-graph --nodes N [--shape chain|tree|mtl] [--format bin|json]
//!                                # deterministic synthetic lineage graph
//!                                # (graph-scale benchmarks)
//! mgit graph pack                # convert graph.json to the binary
//!                                # MGGI index (graph.bin)
//! mgit remote set <url> [--auth-token TOK] [--hot-bytes N] [--no-prefetch]
//! mgit remote get                # configured origin (token never echoed)
//! mgit fetch <node>              # pin a node's checkpoint subtree hot
//!                                # (then it serves entirely offline)
//! mgit push <node>               # upload object closure + commit to a
//!                                # --writable origin
//! mgit serve [--port N] [--pool N|auto] [--log-requests]
//!            [--writable [--auth-token TOK] [--write-rate N]
//!             [--fold-every N]]
//!                                # HTTP front-end on the concurrent
//!                                # read tier; --writable adds WAL-backed
//!                                # POST routes with live snapshot swap;
//!                                # /metrics for live counters/latency
//!                                # (docs/API.md)
//! ```
//!
//! Exit status: nonzero when the operation errors *or* when its report
//! carries problems ([`crate::ops::Report::failure`]) — `fsck` with
//! corruption, `test` with failing tests, `verify-pack` with bad packs.

use std::path::{Path, PathBuf};

use anyhow::{bail, Result};

use crate::delta::{Codec, CompressConfig};
use crate::ops::{self, Report};
use crate::runtime::Runtime;
use crate::util::argparse::Args;

pub use crate::ops::Repo;

/// Entry point used by `rust/src/main.rs`.
pub fn run(argv: Vec<String>) -> Result<()> {
    let args = Args::parse(argv)?;
    let root = PathBuf::from(args.flag_or("dir", "."));
    let artifacts = PathBuf::from(args.flag_or("artifacts", "artifacts"));
    let json = args.has("json");
    match args.command.as_str() {
        "" | "help" => {
            print!("{}", HELP);
            Ok(())
        }
        "init" => finish(json, &ops::InitRequest.run(&root)?),
        "log" => {
            let limit = args.flag_usize("limit", 0)?;
            if limit == 0 && (args.has("after") || args.has("type")) {
                bail!("--after/--type only make sense with --limit");
            }
            let repo = Repo::open(&root)?;
            if limit == 0 {
                finish(json, &ops::LogRequest.run(&repo)?)
            } else {
                let req = ops::LogPageRequest {
                    limit,
                    after: args.flag("after").map(String::from),
                    model_type: args.flag("type").map(String::from),
                };
                finish(json, &req.run(&repo)?)
            }
        }
        "show" => {
            let req = ops::ShowRequest { node: args.pos(0, "node")?.to_string() };
            finish(json, &req.run(&Repo::open(&root)?)?)
        }
        "fsck" => finish(json, &ops::FsckRequest.run(&Repo::open(&root)?)?),
        "stats" => finish(json, &ops::StatsRequest.run(&Repo::open(&root)?)?),
        "gc" => finish(json, &ops::GcRequest.run(&Repo::open(&root)?)?),
        "repack" => finish(json, &repack_request(&args)?.run(&mut Repo::open(&root)?)?),
        "verify-pack" => finish(json, &ops::VerifyPackRequest.run(&Repo::open(&root)?)?),
        "diff" => {
            let rt = Runtime::new(&artifacts)?;
            let req = ops::DiffRequest {
                a: args.pos(0, "a")?.to_string(),
                b: args.pos(1, "b")?.to_string(),
            };
            finish(json, &req.run(&Repo::open(&root)?, rt.zoo(), &rt)?)
        }
        "merge" => {
            let rt = Runtime::new(&artifacts)?;
            let req = ops::MergeRequest {
                base: args.pos(0, "base")?.to_string(),
                m1: args.pos(1, "m1")?.to_string(),
                m2: args.pos(2, "m2")?.to_string(),
                out: args.flag("out").map(String::from),
            };
            finish(json, &req.run(&mut Repo::open(&root)?, rt.zoo(), &rt)?)
        }
        "build" => {
            let rt = Runtime::new(&artifacts)?;
            let req = ops::BuildRequest {
                which: args.pos(0, "graph")?.to_string(),
                small: args.has("small"),
            };
            finish(json, &req.run(&mut Repo::open(&root)?, &rt)?)
        }
        "compress" => {
            let rt = Runtime::new(&artifacts)?;
            let req = ops::CompressRequest {
                config: CompressConfig {
                    eps: args.flag_f64("eps", 1e-4)? as f32,
                    codec: Codec::parse(args.flag_or("codec", "lzma"))?,
                    prequantize: args.has("prequantize"),
                },
            };
            finish(json, &req.run(&mut Repo::open(&root)?, rt.zoo(), &rt)?)
        }
        "test" => {
            let rt = Runtime::new(&artifacts)?;
            let req = ops::TestRequest { pattern: args.flag("re").map(String::from) };
            finish(json, &req.run(&Repo::open(&root)?, rt.zoo(), &rt, &rt)?)
        }
        "cascade" => {
            let req = ops::CascadeRequest {
                node: if args.has("resume") {
                    None
                } else {
                    Some(args.pos(0, "node")?.to_string())
                },
                steps: args.flag_usize("steps", 30)?,
                jobs: jobs_flag(&args, "jobs", 1)?,
            };
            finish(json, &req.run(&root, &artifacts)?)
        }
        "auto-insert" => {
            let rt = Runtime::new(&artifacts)?;
            finish(json, &ops::AutoInsertRequest.run(&Repo::open(&root)?, &rt)?)
        }
        "synth-graph" => {
            let nodes = args.flag_usize("nodes", 0)?;
            if nodes == 0 {
                bail!("synth-graph wants --nodes N (positive)");
            }
            let req = ops::SynthGraphRequest {
                nodes,
                shape: args.flag_or("shape", "chain").to_string(),
                format: args.flag_or("format", "bin").to_string(),
            };
            finish(json, &req.run(&root)?)
        }
        "graph" => match args.pos(0, "subcommand")? {
            "pack" => finish(json, &ops::GraphPackRequest.run(&Repo::open(&root)?)?),
            other => bail!("unknown graph subcommand `{other}` (try `mgit graph pack`)"),
        },
        "remote" => match args.pos(0, "subcommand")? {
            "set" => {
                let req = ops::RemoteSetRequest {
                    url: args.pos(1, "url")?.to_string(),
                    auth_token: args.flag("auth-token").map(String::from),
                    hot_bytes: match args.flag("hot-bytes") {
                        None => None,
                        Some(_) => Some(args.flag_u64("hot-bytes", 0)?),
                    },
                    prefetch: !args.has("no-prefetch"),
                };
                finish(json, &req.run(&root)?)
            }
            "get" => finish(json, &ops::RemoteGetRequest.run(&root)?),
            other => bail!("unknown remote subcommand `{other}` (try set|get)"),
        },
        "fetch" => {
            let req = ops::FetchRequest { node: args.pos(0, "node")?.to_string() };
            finish(json, &req.run(&mut Repo::open(&root)?)?)
        }
        "push" => {
            let req = ops::PushRequest { node: args.pos(0, "node")?.to_string() };
            finish(json, &req.run(&Repo::open(&root)?)?)
        }
        "serve" => cmd_serve(&root, &artifacts, &args, json),
        other => bail!("unknown command `{other}` (try `mgit help`)"),
    }
}

/// Render the report (JSON or human text), then map report-carried
/// problems to a nonzero exit.
fn finish(json: bool, report: &dyn Report) -> Result<()> {
    if json {
        println!("{}", report.to_json().to_string_pretty());
    } else {
        let text = report.to_string();
        if !text.is_empty() {
            println!("{text}");
        }
    }
    match report.failure() {
        None => Ok(()),
        Some(msg) => bail!("{msg}"),
    }
}

/// `--jobs N` / `--jobs auto` (ROADMAP follow-up): `auto` sizes from
/// [`crate::util::auto_jobs`].
fn jobs_flag(args: &Args, name: &str, default: usize) -> Result<usize> {
    match args.flag(name) {
        Some("auto") => Ok(crate::util::auto_jobs()),
        _ => args.flag_usize(name, default),
    }
}

fn repack_request(args: &Args) -> Result<ops::RepackRequest> {
    use crate::store::pack::{PackFraming, RepackMode};
    if args.has("full") && args.has("incremental") {
        bail!("--full and --incremental are mutually exclusive");
    }
    let mode = if args.has("full") { RepackMode::Full } else { RepackMode::Incremental };
    let framing = PackFraming::parse(args.flag_or("framing", "raw"))?;
    // Generation-aware escalation defaults (ROADMAP follow-up): after 16
    // generations or once half the sealed pack bytes are garbage, an
    // incremental run is promoted to a full rewrite. `0` disables either.
    let max_generations = match args.flag_usize("auto-full-gens", 16)? {
        0 => None,
        n => Some(n),
    };
    let max_dead_ratio = {
        let r = args.flag_f64("auto-full-dead", 0.5)?;
        if r <= 0.0 {
            None
        } else {
            Some(r)
        }
    };
    let similarity = match args.flag("similarity") {
        None => None,
        Some(_) => Some(args.flag_f64("similarity", 0.0)?),
    };
    Ok(ops::RepackRequest {
        max_chain_depth: args.flag_usize("max-chain-depth", 8)?,
        prune: args.has("prune"),
        mode,
        max_generations,
        max_dead_ratio,
        framing,
        keep_loose: args.has("keep-loose"),
        similarity,
        min_savings: args.flag_f64("min-savings", 0.1)?,
        // --similarity implies the chunked pack format: both halves of
        // the compression model ship together (docs/COMPRESSION.md).
        chunk_dedup: args.has("chunk-dedup") || similarity.is_some(),
    })
}

fn cmd_serve(root: &Path, artifacts: &Path, args: &Args, json: bool) -> Result<()> {
    let port = u16::try_from(args.flag_usize("port", 7421)?)
        .map_err(|_| anyhow::anyhow!("--port must be 0-65535"))?;
    // Pool sizing defaults to the machine's available parallelism.
    let pool = match args.flag("pool") {
        None | Some("auto") => crate::util::auto_jobs(),
        Some(_) => args.flag_usize("pool", 1)?.max(1),
    };
    let writable = args.has("writable");
    let auth_token = args.flag("auth-token").map(|t| t.to_string());
    let write_rate = match args.flag("write-rate") {
        None => None,
        Some(_) => Some(args.flag_usize("write-rate", 0)? as u64),
    };
    let fold_every = args.flag_u64("fold-every", ops::serve::CHECKPOINT_EVERY)?;
    if !writable && (auth_token.is_some() || write_rate.is_some() || args.has("fold-every")) {
        bail!("--auth-token/--write-rate/--fold-every only make sense with --writable");
    }
    if fold_every == 0 {
        bail!("--fold-every must be at least 1");
    }
    let repo = Repo::open(root)?;
    // Arch specs enable /diff and /checkpoint; the graph/store endpoints
    // work without them.
    let zoo = Runtime::new(artifacts).ok().map(|rt| rt.zoo().clone());
    if zoo.is_none() {
        eprintln!(
            "warning: no artifacts manifest under {}; /diff and /checkpoint are disabled",
            artifacts.display()
        );
    }
    let server = if writable {
        ops::serve::Server::bind_writable(
            repo,
            zoo,
            port,
            pool,
            ops::serve::WriteConfig { auth_token, rate_per_sec: write_rate, fold_every },
        )?
    } else {
        ops::serve::Server::bind(repo, zoo, port, pool)?
    }
    .with_log_requests(args.has("log-requests"));
    // Status chatter goes to stderr so stdout stays JSON-clean.
    eprintln!(
        "mgit serve: listening on http://{} ({} workers{})",
        server.local_addr()?,
        server.pool(),
        if writable { ", writable" } else { "" }
    );
    finish(json, &server.serve()?)
}

const HELP: &str = "\
mgit — model versioning and management (MGit, ICML 2024 reproduction)

usage: mgit <command> [args] [--flags]

  init                       create .mgit/ in --dir (default .)
  log                        list nodes with edges and versions
                             [--limit N] (page through a big graph without
                             loading it; repeat with --after <last name>)
                             [--after NODE] [--type T] (filter by model
                             type; both need --limit)
  show <node>                node details (type, creation fn, params)
  fsck                       check graph invariants, object presence and
                             cross-pack delta-chain integrity (exits
                             nonzero on corruption)
  stats                      object store statistics (loose vs packed,
                             dedup counters, chain-depth histogram,
                             per-pack generations)
  gc                         sweep unreachable loose objects
  repack                     pack new loose objects into a fresh pack
                             (--incremental, the default; --full rewrites
                             every pack and upgrades v1 packs to v2)
                             [--max-chain-depth 8] [--prune]
                             [--framing raw|zstd] (outer whole-pack
                             compression; zstd needs --features zstd)
                             [--auto-full-gens 16] [--auto-full-dead 0.5]
                             (incremental auto-promotes to a full rewrite
                             past either threshold; 0 disables; the dead-
                             byte trigger fires only with --prune)
                             [--keep-loose] (keep loose copies of newly
                             packed objects — live-server repacks)
                             [--similarity T] (similarity-driven delta
                             base selection: score candidate bases by
                             min-hash sketch, keep the smallest bit-exact
                             encoding, or none below --min-savings;
                             implies --chunk-dedup)
                             [--min-savings 0.1] (minimum fractional
                             saving a delta must achieve over raw bytes)
                             [--chunk-dedup] (write a chunked v3 pack:
                             byte ranges shared across objects are
                             stored once, replayed via MGCR recipes)
  verify-pack                verify pack checksums + object content hashes
                             (exits nonzero on bad packs)
  diff <a> <b>               divergence scores between two models
  merge <base> <m1> <m2>     figure-2 merge (conflict detection)
  build <g1|g2|g3|g4|g5>     train + register a workload graph [--small]
  compress                   re-store all models with delta compression
                             [--codec rle|lzma|zstd] [--eps 1e-4]
  test [--re REGEX]          run registered tests over all nodes (exits
                             nonzero on failures)
  cascade <node>             retrain <node> on perturbed data, then run
                             the update cascade over its descendants
                             [--jobs N|auto] (wavefront-parallel) —
                             journaled: `cascade --resume` finishes an
                             interrupted run
  auto-insert                rebuild provenance edges automatically (§3.2)
  synth-graph                write a deterministic synthetic lineage graph
                             into --dir (graph-scale benchmarks/tests)
                             --nodes N [--shape chain|tree|mtl]
                             [--format bin|json] (bin = MGGI graph.bin)
  graph pack                 convert a JSON-graph repo to the binary MGGI
                             index (graph.bin); no-op when already binary
  remote set <url>           configure the origin this repo reads through
                             (.mgit/remote; later opens become tiered)
                             [--auth-token TOK] (bearer token for pushes)
                             [--hot-bytes N] (evict read-through fills
                             past this byte budget) [--no-prefetch]
                             (disable delta-parent chain prefetch)
  remote get                 show the configured origin (token not echoed)
  fetch <node>               pin a node's checkpoint subtree into the hot
                             tier so it serves entirely offline; unknown
                             nodes are created from origin /show metadata
  push <node>                upload a node to a --writable origin: object
                             closure first (bases before deltas), then
                             the graph commit (409 = already there, ok)
  serve                      HTTP front-end on the concurrent read tier
                             [--port 7421] [--pool N|auto]
                             [--log-requests] (JSON request log, stderr)
                             [--writable] (WAL-backed POST /object
                             /commit /checkpoint/<node> /admin/repack
                             with live snapshot swap)
                             [--auth-token TOK] (bearer auth on writes)
                             [--write-rate N] (write requests/second)
                             [--fold-every N] (commits between WAL folds,
                             default 64; a binary graph.bin folds by
                             appending to its segment tail);
                             read endpoints /log /stats /show/<node>
                             /diff/<a>/<b> /checkpoint/<node>
                             /object/<id> /metrics (docs/API.md)

global flags: --dir DIR  --artifacts DIR  --json (machine-readable reports)
";
