//! Native fallback runtime backend (compiled without the `pjrt` feature).
//!
//! Presents the exact `Runtime` API of the PJRT backend so every caller
//! (trainer, workloads, CLI, benches) compiles unchanged:
//!
//! * the manifest/zoo loads identically;
//! * the delta kernels run the bit-compatible native oracle
//!   ([`NativeKernel`]), so storage, compression, repack and diff paths
//!   are fully functional and produce the same objects the PJRT build
//!   would (the quantizer formula is shared);
//! * `train_step`/`eval_step` cannot execute HLO without PJRT and return
//!   a descriptive error telling the user to rebuild with
//!   `--features pjrt` after `make artifacts`.

use std::path::Path;
use std::sync::atomic::Ordering;

use anyhow::{anyhow, Result};

use super::RuntimeStats;
use crate::checkpoint::{Checkpoint, ModelZoo};
use crate::data;
use crate::delta::quant::{DeltaKernel, NativeKernel};
use crate::registry::{EvalBackend, Objective};

pub struct Runtime {
    zoo: ModelZoo,
    pub stats: RuntimeStats,
}

impl Runtime {
    /// Load the manifest from `artifacts_dir`. No PJRT client is created;
    /// only the zoo metadata is needed for the storage/lineage paths.
    pub fn new(artifacts_dir: &Path) -> Result<Runtime> {
        let zoo = ModelZoo::load(&artifacts_dir.join("manifest.json"))?;
        Ok(Runtime { zoo, stats: RuntimeStats::default() })
    }

    pub fn zoo(&self) -> &ModelZoo {
        &self.zoo
    }

    fn needs_pjrt(&self, what: &str) -> anyhow::Error {
        anyhow!(
            "{what} needs the PJRT execution backend; this binary was built \
             without the `pjrt` feature (rebuild with `cargo build --features pjrt` \
             after `make artifacts`)"
        )
    }

    /// One SGD-momentum step (PJRT only).
    pub fn train_step(
        &self,
        _arch: &str,
        _obj: Objective,
        _params: &mut Vec<f32>,
        _mom: &mut Vec<f32>,
        _batch: &data::Batch,
        _lr: f32,
    ) -> Result<f32> {
        Err(self.needs_pjrt("train_step"))
    }

    /// Evaluate (loss, accuracy) on one batch (PJRT only).
    pub fn eval_step(
        &self,
        _arch: &str,
        _obj: Objective,
        _params: &[f32],
        _batch: &data::Batch,
    ) -> Result<(f32, f32)> {
        Err(self.needs_pjrt("eval_step"))
    }

    /// Averaged evaluation over `batches` held-out batches (PJRT only).
    pub fn eval_many(
        &self,
        arch: &str,
        obj: Objective,
        params: &[f32],
        task_or_corpus: &str,
        split_seed: u64,
        batches: usize,
    ) -> Result<(f32, f32)> {
        self.eval_many_perturbed(arch, obj, params, task_or_corpus, split_seed, batches, None)
    }

    /// Like [`Runtime::eval_many`] with an input perturbation (PJRT only).
    #[allow(clippy::too_many_arguments)]
    pub fn eval_many_perturbed(
        &self,
        _arch: &str,
        _obj: Objective,
        _params: &[f32],
        _task_or_corpus: &str,
        _split_seed: u64,
        _batches: usize,
        _perturb: Option<(&str, f64)>,
    ) -> Result<(f32, f32)> {
        Err(self.needs_pjrt("eval"))
    }
}

impl EvalBackend for Runtime {
    fn eval(
        &self,
        ck: &Checkpoint,
        task: &str,
        objective: Objective,
        batches: usize,
        split_seed: u64,
    ) -> Result<(f32, f32)> {
        self.eval_many(&ck.arch, objective, &ck.flat, task, split_seed, batches)
    }
}

// The delta kernels are pure arithmetic; the native oracle is
// bit-compatible with the Pallas kernel, so the storage path is identical
// across backends.
impl DeltaKernel for Runtime {
    fn quantize(&self, parent: &[f32], child: &[f32], eps: f32) -> Result<Vec<i32>> {
        self.stats.quant_calls.fetch_add(1, Ordering::Relaxed);
        NativeKernel.quantize(parent, child, eps)
    }

    fn dequantize(&self, parent: &[f32], q: &[i32], eps: f32) -> Result<Vec<f32>> {
        self.stats.dequant_calls.fetch_add(1, Ordering::Relaxed);
        NativeKernel.dequantize(parent, q, eps)
    }
}
