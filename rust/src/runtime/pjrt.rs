//! PJRT runtime backend: loads the AOT-compiled HLO artifacts (L2 model
//! steps + L1 Pallas delta kernels) and executes them on the CPU PJRT
//! client.
//!
//! * One `PjRtClient` per process; executables are compiled once per
//!   artifact file and cached.
//! * The ABI is the flat-parameter convention of `python/compile/model.py`
//!   (see the manifest loaded into [`ModelZoo`]).
//! * [`Runtime`] implements [`DeltaKernel`] by chunking flat vectors
//!   through the AOT `delta_quant`/`delta_dequant` kernels, so the
//!   storage path's hot loop runs the same compiled code the paper's
//!   GPU implementation would.

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::atomic::Ordering;
use std::sync::{Arc, Mutex};

use anyhow::{anyhow, bail, Result};

use super::RuntimeStats;
use crate::checkpoint::{Checkpoint, ModelZoo};
use crate::data;
use crate::delta::quant::DeltaKernel;
use crate::registry::{EvalBackend, Objective};

/// The PJRT client plus its lazily-compiled executable cache — the only
/// part of [`Runtime`] whose thread-safety the compiler cannot verify.
struct XlaHandles {
    client: xla::PjRtClient,
    exes: Mutex<HashMap<String, Arc<xla::PjRtLoadedExecutable>>>,
}

// SAFETY: the execution-tier contract (`CreationExecutor`/`DeltaKernel`
// implementations are `Send + Sync`) requires sharing one Runtime across
// cascade worker threads. XLA's PJRT CPU client and loaded executables
// are internally synchronized (PJRT documents Execute as thread-safe);
// the lazily-built executable cache is behind a `Mutex`. The `xla`
// bindings simply don't propagate the auto traits through their raw
// pointers, hence the explicit impls — scoped to this newtype so the
// compiler keeps checking every other Runtime field.
unsafe impl Send for XlaHandles {}
unsafe impl Sync for XlaHandles {}

pub struct Runtime {
    xla: XlaHandles,
    zoo: ModelZoo,
    dir: PathBuf,
    pub stats: RuntimeStats,
}

impl Runtime {
    /// Load the manifest from `artifacts_dir` and create a CPU client.
    /// Executables compile lazily on first use.
    pub fn new(artifacts_dir: &Path) -> Result<Runtime> {
        let zoo = ModelZoo::load(&artifacts_dir.join("manifest.json"))?;
        let client = xla::PjRtClient::cpu()
            .map_err(|e| anyhow!("creating PJRT CPU client: {e:?}"))?;
        Ok(Runtime {
            xla: XlaHandles { client, exes: Mutex::new(HashMap::new()) },
            zoo,
            dir: artifacts_dir.to_path_buf(),
            stats: RuntimeStats::default(),
        })
    }

    pub fn zoo(&self) -> &ModelZoo {
        &self.zoo
    }

    fn exe(&self, file: &str) -> Result<Arc<xla::PjRtLoadedExecutable>> {
        if let Some(e) = self.xla.exes.lock().unwrap().get(file) {
            return Ok(e.clone());
        }
        // Compile outside the lock (it can take a while); two threads
        // racing on the same artifact both compile once and the second
        // insert simply wins — executables are interchangeable.
        let path = self.dir.join(file);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("bad path"))?,
        )
        .map_err(|e| anyhow!("parsing HLO text {}: {e:?}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .xla
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compiling {}: {e:?}", path.display()))?;
        self.stats.compile_count.fetch_add(1, Ordering::Relaxed);
        let exe = Arc::new(exe);
        self.xla.exes.lock().unwrap().insert(file.to_string(), exe.clone());
        Ok(exe)
    }

    fn artifact(&self, arch: &str, kind: &str) -> Result<String> {
        self.zoo
            .artifacts
            .get(arch)
            .and_then(|m| m.get(kind))
            .cloned()
            .ok_or_else(|| anyhow!("no artifact `{kind}` for arch `{arch}`"))
    }

    // ------------------------------------------------------------------
    // Training / evaluation steps
    // ------------------------------------------------------------------
    fn check_batch(&self, arch: &str, obj: Objective, b: &data::Batch) -> Result<()> {
        let spec = self.zoo.arch(arch)?;
        if b.seq != self.zoo.max_seq || b.batch != self.zoo.batch {
            bail!(
                "batch shape ({}, {}) != compiled ({}, {})",
                b.batch,
                b.seq,
                self.zoo.batch,
                self.zoo.max_seq
            );
        }
        let want_labels = match obj {
            Objective::Mlm => b.batch * b.seq,
            Objective::Cls => b.batch,
        };
        if b.labels.len() != want_labels || b.tokens.len() != b.batch * b.seq {
            bail!("batch payload sizes wrong for {}", spec.name);
        }
        Ok(())
    }

    /// One SGD-momentum step; updates `params`/`mom` in place, returns loss.
    pub fn train_step(
        &self,
        arch: &str,
        obj: Objective,
        params: &mut Vec<f32>,
        mom: &mut Vec<f32>,
        batch: &data::Batch,
        lr: f32,
    ) -> Result<f32> {
        self.check_batch(arch, obj, batch)?;
        let spec = self.zoo.arch(arch)?;
        if params.len() != spec.param_count || mom.len() != spec.param_count {
            bail!("flat param length mismatch for {}", arch);
        }
        let file = self.artifact(arch, &format!("{}_train", obj.name()))?;
        let exe = self.exe(&file)?;

        let b = batch.batch as i64;
        let t = batch.seq as i64;
        let p_lit = xla::Literal::vec1(params.as_slice());
        let m_lit = xla::Literal::vec1(mom.as_slice());
        let tok_lit = xla::Literal::vec1(batch.tokens.as_slice()).reshape(&[b, t])
            .map_err(|e| anyhow!("tokens reshape: {e:?}"))?;
        let lab_lit = match obj {
            Objective::Mlm => xla::Literal::vec1(batch.labels.as_slice())
                .reshape(&[b, t])
                .map_err(|e| anyhow!("labels reshape: {e:?}"))?,
            Objective::Cls => xla::Literal::vec1(batch.labels.as_slice()),
        };
        let lr_lit = xla::Literal::from(lr);

        let result = exe
            .execute::<xla::Literal>(&[p_lit, m_lit, tok_lit, lab_lit, lr_lit])
            .map_err(|e| anyhow!("train step exec: {e:?}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetch result: {e:?}"))?;
        let parts = result.to_tuple().map_err(|e| anyhow!("tuple: {e:?}"))?;
        if parts.len() != 3 {
            bail!("train artifact returned {} outputs, want 3", parts.len());
        }
        parts[0]
            .copy_raw_to(params.as_mut_slice())
            .map_err(|e| anyhow!("params out: {e:?}"))?;
        parts[1]
            .copy_raw_to(mom.as_mut_slice())
            .map_err(|e| anyhow!("momentum out: {e:?}"))?;
        let loss = parts[2]
            .get_first_element::<f32>()
            .map_err(|e| anyhow!("loss out: {e:?}"))?;
        self.stats.train_steps.fetch_add(1, Ordering::Relaxed);
        Ok(loss)
    }

    /// Evaluate (loss, accuracy) on one batch.
    pub fn eval_step(
        &self,
        arch: &str,
        obj: Objective,
        params: &[f32],
        batch: &data::Batch,
    ) -> Result<(f32, f32)> {
        self.check_batch(arch, obj, batch)?;
        let spec = self.zoo.arch(arch)?;
        if params.len() != spec.param_count {
            bail!("flat param length mismatch for {}", arch);
        }
        let file = self.artifact(arch, &format!("{}_eval", obj.name()))?;
        let exe = self.exe(&file)?;
        let b = batch.batch as i64;
        let t = batch.seq as i64;
        let p_lit = xla::Literal::vec1(params);
        let tok_lit = xla::Literal::vec1(batch.tokens.as_slice())
            .reshape(&[b, t])
            .map_err(|e| anyhow!("tokens reshape: {e:?}"))?;
        let lab_lit = match obj {
            Objective::Mlm => xla::Literal::vec1(batch.labels.as_slice())
                .reshape(&[b, t])
                .map_err(|e| anyhow!("labels reshape: {e:?}"))?,
            Objective::Cls => xla::Literal::vec1(batch.labels.as_slice()),
        };
        let result = exe
            .execute::<xla::Literal>(&[p_lit, tok_lit, lab_lit])
            .map_err(|e| anyhow!("eval exec: {e:?}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetch result: {e:?}"))?;
        let (loss_l, acc_l) = result.to_tuple2().map_err(|e| anyhow!("tuple2: {e:?}"))?;
        let loss = loss_l.get_first_element::<f32>().map_err(|e| anyhow!("{e:?}"))?;
        let acc = acc_l.get_first_element::<f32>().map_err(|e| anyhow!("{e:?}"))?;
        self.stats.eval_steps.fetch_add(1, Ordering::Relaxed);
        Ok((loss, acc))
    }

    /// Averaged evaluation over `batches` held-out batches.
    pub fn eval_many(
        &self,
        arch: &str,
        obj: Objective,
        params: &[f32],
        task_or_corpus: &str,
        split_seed: u64,
        batches: usize,
    ) -> Result<(f32, f32)> {
        self.eval_many_perturbed(arch, obj, params, task_or_corpus, split_seed, batches, None)
    }

    /// Like [`Runtime::eval_many`] but with an input perturbation applied
    /// to the held-out batches (robustness evaluation — Figure 4).
    #[allow(clippy::too_many_arguments)]
    pub fn eval_many_perturbed(
        &self,
        arch: &str,
        obj: Objective,
        params: &[f32],
        task_or_corpus: &str,
        split_seed: u64,
        batches: usize,
        perturb: Option<(&str, f64)>,
    ) -> Result<(f32, f32)> {
        let (mut loss, mut acc) = (0f32, 0f32);
        for i in 0..batches {
            let batch = match obj {
                Objective::Cls => data::cls_batch(
                    task_or_corpus,
                    self.zoo.batch,
                    self.zoo.max_seq,
                    split_seed,
                    // held-out batches live in a high index range
                    1_000_000 + i as u64,
                    perturb,
                )?,
                Objective::Mlm => data::mlm_batch(
                    split_seed,
                    self.zoo.batch,
                    self.zoo.max_seq,
                    1_000_000 + i as u64,
                    perturb,
                )?,
            };
            let (l, a) = self.eval_step(arch, obj, params, &batch)?;
            loss += l;
            acc += a;
        }
        Ok((loss / batches as f32, acc / batches as f32))
    }
}

impl EvalBackend for Runtime {
    fn eval(
        &self,
        ck: &Checkpoint,
        task: &str,
        objective: Objective,
        batches: usize,
        split_seed: u64,
    ) -> Result<(f32, f32)> {
        self.eval_many(&ck.arch, objective, &ck.flat, task, split_seed, batches)
    }
}

// ---------------------------------------------------------------------------
// Delta kernels via PJRT (chunked)
// ---------------------------------------------------------------------------
impl DeltaKernel for Runtime {
    fn quantize(&self, parent: &[f32], child: &[f32], eps: f32) -> Result<Vec<i32>> {
        anyhow::ensure!(parent.len() == child.len(), "length mismatch");
        let chunk = self.zoo.delta_chunk;
        let exe = self.exe(&self.zoo.delta_quant_artifact.clone())?;
        let eps_lit = xla::Literal::vec1(&[eps]);
        let mut out = Vec::with_capacity(parent.len());
        let mut buf_a = vec![0f32; chunk];
        let mut buf_b = vec![0f32; chunk];
        for (pa, ch) in parent.chunks(chunk).zip(child.chunks(chunk)) {
            let (a_lit, b_lit) = if pa.len() == chunk {
                (xla::Literal::vec1(pa), xla::Literal::vec1(ch))
            } else {
                buf_a[..pa.len()].copy_from_slice(pa);
                buf_a[pa.len()..].fill(0.0);
                buf_b[..ch.len()].copy_from_slice(ch);
                buf_b[ch.len()..].fill(0.0);
                (xla::Literal::vec1(&buf_a), xla::Literal::vec1(&buf_b))
            };
            let result = exe
                .execute::<xla::Literal>(&[a_lit, b_lit, eps_lit.clone()])
                .map_err(|e| anyhow!("quant exec: {e:?}"))?[0][0]
                .to_literal_sync()
                .map_err(|e| anyhow!("{e:?}"))?;
            let q = result.to_tuple1().map_err(|e| anyhow!("{e:?}"))?;
            let v: Vec<i32> = q.to_vec().map_err(|e| anyhow!("{e:?}"))?;
            out.extend_from_slice(&v[..pa.len()]);
            self.stats.quant_calls.fetch_add(1, Ordering::Relaxed);
        }
        Ok(out)
    }

    fn dequantize(&self, parent: &[f32], q: &[i32], eps: f32) -> Result<Vec<f32>> {
        anyhow::ensure!(parent.len() == q.len(), "length mismatch");
        let chunk = self.zoo.delta_chunk;
        let exe = self.exe(&self.zoo.delta_dequant_artifact.clone())?;
        let eps_lit = xla::Literal::vec1(&[eps]);
        let mut out = Vec::with_capacity(parent.len());
        let mut buf_a = vec![0f32; chunk];
        let mut buf_q = vec![0i32; chunk];
        for (pa, qa) in parent.chunks(chunk).zip(q.chunks(chunk)) {
            let (a_lit, q_lit) = if pa.len() == chunk {
                (xla::Literal::vec1(pa), xla::Literal::vec1(qa))
            } else {
                buf_a[..pa.len()].copy_from_slice(pa);
                buf_a[pa.len()..].fill(0.0);
                buf_q[..qa.len()].copy_from_slice(qa);
                buf_q[qa.len()..].fill(0);
                (xla::Literal::vec1(&buf_a), xla::Literal::vec1(&buf_q))
            };
            let result = exe
                .execute::<xla::Literal>(&[a_lit, q_lit, eps_lit.clone()])
                .map_err(|e| anyhow!("dequant exec: {e:?}"))?[0][0]
                .to_literal_sync()
                .map_err(|e| anyhow!("{e:?}"))?;
            let b = result.to_tuple1().map_err(|e| anyhow!("{e:?}"))?;
            let v: Vec<f32> = b.to_vec().map_err(|e| anyhow!("{e:?}"))?;
            out.extend_from_slice(&v[..pa.len()]);
            self.stats.dequant_calls.fetch_add(1, Ordering::Relaxed);
        }
        Ok(out)
    }
}
