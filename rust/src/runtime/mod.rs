//! Model execution runtime.
//!
//! Two interchangeable backends expose the same `Runtime` API:
//!
//! * **PJRT** (`pjrt.rs`, behind the `pjrt` cargo feature) — loads the
//!   AOT-compiled HLO artifacts (L2 model steps + L1 Pallas delta
//!   kernels) and executes them on the CPU PJRT client via the `xla`
//!   bindings crate. This is the paper-faithful hot path; it needs
//!   `make artifacts` and libxla.
//! * **Native fallback** (`native.rs`, the default) — compiled when the
//!   `xla` crate is unavailable (the offline build). It loads the same
//!   manifest and implements the delta kernels with the bit-compatible
//!   native oracle ([`crate::delta::quant::NativeKernel`]), so every
//!   storage, lineage, diff, pack and CLI path works; train/eval steps
//!   return an error directing the user to the PJRT build.
//!
//! Python never runs here: artifacts are plain HLO text produced by
//! `make artifacts`.

use std::sync::atomic::AtomicU64;

#[cfg(feature = "pjrt")]
mod pjrt;
#[cfg(feature = "pjrt")]
pub use pjrt::Runtime;

#[cfg(not(feature = "pjrt"))]
mod native;
#[cfg(not(feature = "pjrt"))]
pub use native::Runtime;

/// Cumulative execution counters (perf diagnostics, EXPERIMENTS.md §Perf).
#[derive(Debug, Default)]
pub struct RuntimeStats {
    pub train_steps: AtomicU64,
    pub eval_steps: AtomicU64,
    pub quant_calls: AtomicU64,
    pub dequant_calls: AtomicU64,
    pub compile_count: AtomicU64,
}

/// Whether this build carries the PJRT execution backend (integration
/// tests that train/evaluate skip themselves when it is absent).
pub const HAS_PJRT: bool = cfg!(feature = "pjrt");

/// Wrapper for the quant/eps convention used by the "eps-bucketed" sweep
/// benches: expose the quantization step size here so benches don't reach
/// into delta::quant.
pub fn quant_step(eps: f32) -> f32 {
    crate::delta::quant::step(eps)
}
