//! Remote object backend: [`ObjectStore`] over the `mgit serve` wire.
//!
//! A [`RemoteStore`] speaks to an *origin* — another mgit process running
//! `mgit serve` — re-using the existing HTTP/1.1 surface instead of
//! inventing a transfer protocol:
//!
//! | method                  | used for                                  |
//! |-------------------------|-------------------------------------------|
//! | `GET  /object/<hex-id>` | [`RemoteStore::fetch`] (exact bytes)      |
//! | `HEAD /object/<hex-id>` | [`RemoteStore::contains_remote`]          |
//! | `POST /object/<hex-id>` | [`RemoteStore::put_remote`] (`--writable`)|
//! | `POST /commit`          | [`RemoteStore::commit`] (`--writable`)    |
//! | `GET  /show/<node>`     | [`RemoteStore::fetch_show`] (fetch seam)  |
//! | `GET  /healthz`         | [`RemoteStore::healthz`]                  |
//!
//! The client is dependency-free and blocking: std [`TcpStream`], a
//! keep-alive connection pool (dead pooled connections are replaced
//! transparently — an origin idle-closing a socket never surfaces as an
//! error), per-request read/write timeouts, and bounded retry with
//! exponential backoff + jitter. `429 Too Many Requests` answers wait a
//! backoff step like a transport failure would, so a rate-limited writer
//! spreads its attempts across the origin's token-refill window instead
//! of burning its whole retry budget in microseconds.
//!
//! Failures surface as typed [`RemoteError`]s (inspect with
//! `err.downcast_ref::<RemoteError>()` through `anyhow`): a read-only
//! origin's `403` carries the server's own explanation, `401`/`404`/
//! `429` and transport exhaustion are distinct variants — callers such
//! as [`super::tiered::TieredStore`] key caching decisions off them
//! (only a definitive `NotFound` may enter the negative cache).
//!
//! A `RemoteStore` holds no local state besides its socket pool; the
//! hot/cold layering, read-through fill and eviction policy live in
//! [`super::tiered`]. Configuration (`.mgit/remote`) is a tiny JSON file
//! managed by [`RemoteConfig`] and the `mgit remote set/get` commands.

use std::io::{BufRead, BufReader, Read, Write};
use std::net::TcpStream;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Context, Result};

use super::{ObjectId, ObjectStore};
use crate::util::json::{self, Json};

// Wire telemetry, served by `GET /metrics` on whichever process embeds
// this client (a tiered repo may itself be an origin for others).
static OBS_REQUESTS: crate::obs::LazyCounter =
    crate::obs::LazyCounter::new("remote.requests");
static OBS_RETRIES: crate::obs::LazyCounter =
    crate::obs::LazyCounter::new("remote.retries");
static OBS_FETCH_BYTES: crate::obs::LazyCounter =
    crate::obs::LazyCounter::new("remote.fetch_bytes");
static OBS_FETCH_MICROS: crate::obs::LazyHistogram =
    crate::obs::LazyHistogram::new("remote.fetch_micros");

/// Max idle keep-alive sockets retained per origin.
const MAX_IDLE_CONNS: usize = 4;

/// Contents of `.mgit/remote`: where this repository reads through to.
#[derive(Debug, Clone)]
pub struct RemoteConfig {
    /// Origin endpoint, `http://host:port` (the dependency-free client
    /// speaks plain HTTP/1.1 only).
    pub url: String,
    /// Bearer token forwarded on every request (origins started with
    /// `--auth-token` require it for writes).
    pub auth_token: Option<String>,
    /// Byte budget for evictable read-through fills in the hot tier;
    /// `None` = unbounded (every fill stays until repack/GC).
    pub hot_bytes: Option<u64>,
    /// Whether a cold fill also pulls the object's delta-parent chain
    /// (see `TieredStore::prefetch_parents`). Defaults on.
    pub prefetch: bool,
}

impl RemoteConfig {
    pub fn new(url: &str) -> RemoteConfig {
        RemoteConfig {
            url: url.to_string(),
            auth_token: None,
            hot_bytes: None,
            prefetch: true,
        }
    }

    /// `.mgit/remote` under the given `.mgit` directory.
    pub fn path(mgit_dir: &Path) -> PathBuf {
        mgit_dir.join("remote")
    }

    /// Load the remote config if one is present (`Ok(None)` = no remote
    /// configured; the repo opens as a plain packed store).
    pub fn load(mgit_dir: &Path) -> Result<Option<RemoteConfig>> {
        let path = Self::path(mgit_dir);
        if !path.exists() {
            return Ok(None);
        }
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        let j = json::parse(&text)
            .with_context(|| format!("parsing {}", path.display()))?;
        Ok(Some(RemoteConfig {
            url: j.req_str("url")?.to_string(),
            auth_token: j
                .get("auth_token")
                .and_then(|v| v.as_str())
                .map(String::from),
            hot_bytes: j.get("hot_bytes").and_then(|v| v.as_f64()).map(|n| n as u64),
            prefetch: j.get("prefetch").and_then(|v| v.as_bool()).unwrap_or(true),
        }))
    }

    /// Persist atomically (write-then-rename, like every other `.mgit`
    /// metadata file).
    pub fn save(&self, mgit_dir: &Path) -> Result<()> {
        let j = Json::obj()
            .set("url", self.url.as_str())
            .set(
                "auth_token",
                match &self.auth_token {
                    Some(t) => Json::from(t.as_str()),
                    None => Json::Null,
                },
            )
            .set(
                "hot_bytes",
                match self.hot_bytes {
                    Some(n) => Json::from(n),
                    None => Json::Null,
                },
            )
            .set("prefetch", self.prefetch);
        let path = Self::path(mgit_dir);
        let tmp = path.with_extension("tmp");
        std::fs::write(&tmp, j.to_string_pretty())
            .with_context(|| format!("writing {}", tmp.display()))?;
        std::fs::rename(&tmp, &path)?;
        Ok(())
    }
}

/// Typed failure modes of the remote client. Reaches callers wrapped in
/// `anyhow::Error`; recover the variant with `downcast_ref`.
#[derive(Debug)]
pub enum RemoteError {
    /// The origin refused a write with `403` — it was started without
    /// `--writable`. `server` is the origin's own error message.
    ReadOnly { url: String, server: String },
    /// `401`: the origin requires a Bearer token this client does not
    /// have (or has wrong).
    Unauthorized { url: String },
    /// The retry budget ran out while the origin kept answering `429`;
    /// every attempt honored a backoff delay first.
    RateLimited { url: String, attempts: u32 },
    /// Definitive `404`: the origin does not hold this object/node.
    NotFound { what: String, url: String },
    /// Transport failure (dial, timeout, connection reset) on every
    /// attempt — the origin is down or unreachable.
    Unreachable { url: String, attempts: u32, detail: String },
    /// Any other HTTP status.
    Status { url: String, status: u16, server: String },
}

impl std::fmt::Display for RemoteError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            RemoteError::ReadOnly { url, server } => {
                write!(f, "origin {url} refused the write (403 read-only): {server}")
            }
            RemoteError::Unauthorized { url } => {
                write!(
                    f,
                    "origin {url} requires Bearer auth (401); configure \
                     `mgit remote set {url} --auth-token <token>`"
                )
            }
            RemoteError::RateLimited { url, attempts } => {
                write!(
                    f,
                    "origin {url} still rate-limiting (429) after {attempts} \
                     backed-off attempts"
                )
            }
            RemoteError::NotFound { what, url } => {
                write!(f, "{what} not found on origin {url} (404)")
            }
            RemoteError::Unreachable { url, attempts, detail } => {
                write!(f, "origin {url} unreachable after {attempts} attempts: {detail}")
            }
            RemoteError::Status { url, status, server } => {
                write!(f, "origin {url} answered HTTP {status}: {server}")
            }
        }
    }
}

impl std::error::Error for RemoteError {}

/// One parsed origin response.
struct Response {
    status: u16,
    body: Vec<u8>,
    /// Origin asked to close the connection (don't pool it).
    close: bool,
}

/// Whether a node committed by [`RemoteStore::commit`] was new.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CommitOutcome {
    Created,
    /// The origin already has a node of that name (`409`) — idempotent
    /// pushes treat this as success.
    AlreadyExists,
}

/// Parse `http://host:port` into a dialable address.
fn parse_endpoint(url: &str) -> Result<(String, u16)> {
    let rest = url
        .strip_prefix("http://")
        .ok_or_else(|| anyhow!("remote url must start with http:// (got `{url}`)"))?;
    let rest = rest.trim_end_matches('/');
    if rest.contains('/') {
        bail!("remote url must be just http://host:port, no path (got `{url}`)");
    }
    let (host, port) = match rest.rsplit_once(':') {
        Some((h, p)) => (
            h.to_string(),
            p.parse::<u16>()
                .map_err(|_| anyhow!("bad port in remote url `{url}`"))?,
        ),
        None => (rest.to_string(), 80),
    };
    if host.is_empty() {
        bail!("empty host in remote url `{url}`");
    }
    Ok((host, port))
}

/// Percent-encode one path segment (node names may hold spaces etc.).
fn encode_segment(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for b in s.bytes() {
        match b {
            b'a'..=b'z' | b'A'..=b'Z' | b'0'..=b'9' | b'-' | b'_' | b'.' | b'~' => {
                out.push(b as char)
            }
            _ => out.push_str(&format!("%{b:02X}")),
        }
    }
    out
}

/// Backoff delay before retry `attempt` (1-based): exponential base with
/// half-range jitter, capped at ~2s. Jitter comes from a splitmix-style
/// atomic sequence — good enough to de-synchronize a fleet without an
/// RNG dependency.
fn backoff_delay(attempt: u32) -> Duration {
    static SEQ: AtomicU64 = AtomicU64::new(0x9E37_79B9_7F4A_7C15);
    let base_ms = 50u64.saturating_mul(1u64 << attempt.min(5));
    let mut x = SEQ.fetch_add(0x9E37_79B9_7F4A_7C15, Ordering::Relaxed);
    x ^= x >> 30;
    x = x.wrapping_mul(0xBF58_476D_1CE4_E5B9);
    x ^= x >> 27;
    let jitter_ms = x % (base_ms / 2 + 1);
    Duration::from_millis(base_ms / 2 + jitter_ms)
}

/// Best human-readable message from an origin error body (the serve
/// tier answers errors as `{"error": "..."}`).
fn body_message(body: &[u8]) -> String {
    if let Ok(text) = std::str::from_utf8(body) {
        if let Ok(j) = json::parse(text) {
            if let Some(msg) = j.get("error").and_then(|v| v.as_str()) {
                return msg.to_string();
            }
        }
        let trimmed = text.trim();
        if !trimmed.is_empty() {
            return trimmed.chars().take(200).collect();
        }
    }
    format!("{} body bytes", body.len())
}

/// Blocking HTTP client for one origin, implementing [`ObjectStore`].
///
/// Reads ([`fetch`](RemoteStore::fetch)) work against any origin; writes
/// need the origin started `--writable`. `list`/`stored_bytes` are
/// unsupported — the wire has no enumeration endpoint, and the tiered
/// layer answers both from the hot tier instead.
pub struct RemoteStore {
    url: String,
    host: String,
    port: u16,
    auth: Option<String>,
    timeout: Duration,
    /// Retries *after* the first attempt; each waits a backoff first.
    max_retries: u32,
    /// Idle keep-alive connections. Buffered so response read-ahead
    /// survives across requests on the same socket.
    pool: Mutex<Vec<BufReader<TcpStream>>>,
}

impl RemoteStore {
    /// Build a client for `cfg`. Validates the URL shape but does not
    /// dial — opening a repo whose origin is down must still work for
    /// hot-tier reads.
    pub fn connect(cfg: &RemoteConfig) -> Result<RemoteStore> {
        let (host, port) = parse_endpoint(&cfg.url)?;
        Ok(RemoteStore {
            url: cfg.url.trim_end_matches('/').to_string(),
            host,
            port,
            auth: cfg.auth_token.clone(),
            timeout: Duration::from_secs(10),
            max_retries: 5,
            pool: Mutex::new(Vec::new()),
        })
    }

    pub fn url(&self) -> &str {
        &self.url
    }

    /// Override the per-request timeout (tests, impatient tooling).
    pub fn set_timeout(&mut self, t: Duration) {
        self.timeout = t;
    }

    /// Override the retry budget (0 = single attempt).
    pub fn set_max_retries(&mut self, n: u32) {
        self.max_retries = n;
    }

    fn checkout(&self) -> Option<BufReader<TcpStream>> {
        self.pool.lock().unwrap().pop()
    }

    fn checkin(&self, conn: BufReader<TcpStream>) {
        let mut pool = self.pool.lock().unwrap();
        if pool.len() < MAX_IDLE_CONNS {
            pool.push(conn);
        }
    }

    fn dial(&self) -> std::io::Result<TcpStream> {
        let stream = TcpStream::connect((self.host.as_str(), self.port))?;
        stream.set_read_timeout(Some(self.timeout))?;
        stream.set_write_timeout(Some(self.timeout))?;
        let _ = stream.set_nodelay(true);
        Ok(stream)
    }

    /// One request/response over one connection.
    fn exchange(
        &self,
        conn: &mut BufReader<TcpStream>,
        method: &str,
        path: &str,
        body: Option<&[u8]>,
    ) -> std::io::Result<Response> {
        let mut head = format!(
            "{method} {path} HTTP/1.1\r\nHost: {}:{}\r\nConnection: keep-alive\r\n",
            self.host, self.port
        );
        if let Some(token) = &self.auth {
            head.push_str(&format!("Authorization: Bearer {token}\r\n"));
        }
        if let Some(b) = body {
            head.push_str(&format!(
                "Content-Type: application/octet-stream\r\nContent-Length: {}\r\n",
                b.len()
            ));
        }
        head.push_str("\r\n");
        let stream = conn.get_mut();
        stream.write_all(head.as_bytes())?;
        if let Some(b) = body {
            stream.write_all(b)?;
        }
        stream.flush()?;
        read_response(conn, method == "HEAD")
    }

    /// One attempt: prefer a pooled connection; a pooled socket failing
    /// mid-exchange is routine (origin idle-close) and falls through to
    /// one fresh dial without consuming the caller's retry budget.
    fn attempt(&self, method: &str, path: &str, body: Option<&[u8]>) -> std::io::Result<Response> {
        if let Some(mut conn) = self.checkout() {
            OBS_REQUESTS.inc();
            if let Ok(resp) = self.exchange(&mut conn, method, path, body) {
                if !resp.close && !(method == "HEAD" && resp.status == 405) {
                    self.checkin(conn);
                }
                return Ok(resp);
            }
        }
        let mut conn = BufReader::new(self.dial()?);
        OBS_REQUESTS.inc();
        let resp = self.exchange(&mut conn, method, path, body)?;
        if !resp.close && !(method == "HEAD" && resp.status == 405) {
            // Exception: an origin predating HEAD support answers a HEAD
            // with `405` *and* a JSON body we never read — its framing
            // can't be trusted, so that connection is not pooled.
            self.checkin(conn);
        }
        Ok(resp)
    }

    /// Issue a request with bounded retry. Transport errors and `429`
    /// responses retry after [`backoff_delay`]; any other HTTP status is
    /// returned to the caller for interpretation.
    fn request(
        &self,
        method: &str,
        path: &str,
        body: Option<&[u8]>,
    ) -> Result<Response, RemoteError> {
        let start = Instant::now();
        let mut rate_limited = false;
        let mut detail = String::new();
        for attempt in 0..=self.max_retries {
            if attempt > 0 {
                OBS_RETRIES.inc();
                std::thread::sleep(backoff_delay(attempt));
            }
            match self.attempt(method, path, body) {
                Ok(resp) if resp.status == 429 => {
                    rate_limited = true;
                    detail = body_message(&resp.body);
                }
                Ok(resp) => {
                    OBS_FETCH_MICROS.observe(start.elapsed().as_micros() as u64);
                    return Ok(resp);
                }
                Err(e) => {
                    rate_limited = false;
                    detail = e.to_string();
                }
            }
        }
        let attempts = self.max_retries + 1;
        Err(if rate_limited {
            RemoteError::RateLimited { url: self.url.clone(), attempts }
        } else {
            RemoteError::Unreachable { url: self.url.clone(), attempts, detail }
        })
    }

    /// `GET /healthz`.
    pub fn healthz(&self) -> Result<(), RemoteError> {
        let resp = self.request("GET", "/healthz", None)?;
        match resp.status {
            200 => Ok(()),
            s => Err(RemoteError::Status {
                url: self.url.clone(),
                status: s,
                server: body_message(&resp.body),
            }),
        }
    }

    /// Fetch the exact stored bytes of `id` from the origin.
    pub fn fetch(&self, id: &ObjectId) -> Result<Vec<u8>, RemoteError> {
        let resp = self.request("GET", &format!("/object/{}", id.hex()), None)?;
        match resp.status {
            200 => {
                OBS_FETCH_BYTES.add(resp.body.len() as u64);
                Ok(resp.body)
            }
            404 => Err(RemoteError::NotFound {
                what: format!("object {}", id.short()),
                url: self.url.clone(),
            }),
            s => Err(RemoteError::Status {
                url: self.url.clone(),
                status: s,
                server: body_message(&resp.body),
            }),
        }
    }

    /// Existence probe via `HEAD` (no payload transfer). Origins predating
    /// HEAD support answer `405`; fall back to a full GET for those.
    pub fn contains_remote(&self, id: &ObjectId) -> Result<bool, RemoteError> {
        let resp = self.request("HEAD", &format!("/object/{}", id.hex()), None)?;
        match resp.status {
            200 => Ok(true),
            404 => Ok(false),
            405 => match self.fetch(id) {
                Ok(_) => Ok(true),
                Err(RemoteError::NotFound { .. }) => Ok(false),
                Err(e) => Err(e),
            },
            s => Err(RemoteError::Status {
                url: self.url.clone(),
                status: s,
                server: body_message(&resp.body),
            }),
        }
    }

    /// Upload `bytes` as object `id` (`POST /object/<hex>`, origin must
    /// be `--writable`). `Ok(true)` = newly written, `Ok(false)` = the
    /// origin already had it (dedup).
    pub fn put_remote(&self, id: ObjectId, bytes: &[u8]) -> Result<bool, RemoteError> {
        let resp = self.request("POST", &format!("/object/{}", id.hex()), Some(bytes))?;
        match resp.status {
            200 => {
                let new = std::str::from_utf8(&resp.body)
                    .ok()
                    .and_then(|t| json::parse(t).ok())
                    .and_then(|j| j.get("new").and_then(|v| v.as_bool()))
                    .unwrap_or(true);
                Ok(new)
            }
            403 => Err(RemoteError::ReadOnly {
                url: self.url.clone(),
                server: body_message(&resp.body),
            }),
            401 => Err(RemoteError::Unauthorized { url: self.url.clone() }),
            s => Err(RemoteError::Status {
                url: self.url.clone(),
                status: s,
                server: body_message(&resp.body),
            }),
        }
    }

    /// Commit a node on the origin (`POST /commit`, JSON op body). A
    /// `409` (name already present) is reported as
    /// [`CommitOutcome::AlreadyExists`], not an error — pushes are
    /// idempotent.
    pub fn commit(&self, op: &Json) -> Result<CommitOutcome, RemoteError> {
        let body = op.to_string_compact();
        let resp = self.request("POST", "/commit", Some(body.as_bytes()))?;
        match resp.status {
            200 => Ok(CommitOutcome::Created),
            409 => Ok(CommitOutcome::AlreadyExists),
            403 => Err(RemoteError::ReadOnly {
                url: self.url.clone(),
                server: body_message(&resp.body),
            }),
            401 => Err(RemoteError::Unauthorized { url: self.url.clone() }),
            s => Err(RemoteError::Status {
                url: self.url.clone(),
                status: s,
                server: body_message(&resp.body),
            }),
        }
    }

    /// `GET /show/<node>`: the origin's node report (model type + stored
    /// parameter ids) — how `mgit fetch` learns what to pin when the
    /// local graph has never seen the node.
    pub fn fetch_show(&self, node: &str) -> Result<Json, RemoteError> {
        let resp = self.request("GET", &format!("/show/{}", encode_segment(node)), None)?;
        match resp.status {
            200 => std::str::from_utf8(&resp.body)
                .map_err(|_| ())
                .and_then(|t| json::parse(t).map_err(|_| ()))
                .map_err(|_| RemoteError::Status {
                    url: self.url.clone(),
                    status: 200,
                    server: "unparseable /show body".to_string(),
                }),
            404 => Err(RemoteError::NotFound {
                what: format!("node `{node}`"),
                url: self.url.clone(),
            }),
            s => Err(RemoteError::Status {
                url: self.url.clone(),
                status: s,
                server: body_message(&resp.body),
            }),
        }
    }
}

/// Parse one HTTP/1.1 response off `conn`. `head_only` skips the body
/// (HEAD responses advertise Content-Length without sending bytes).
fn read_response(
    conn: &mut BufReader<TcpStream>,
    head_only: bool,
) -> std::io::Result<Response> {
    use std::io::{Error, ErrorKind};
    let mut line = String::new();
    if conn.read_line(&mut line)? == 0 {
        return Err(Error::new(
            ErrorKind::UnexpectedEof,
            "connection closed before status line",
        ));
    }
    let status: u16 = line
        .split_whitespace()
        .nth(1)
        .and_then(|code| code.parse().ok())
        .ok_or_else(|| {
            Error::new(ErrorKind::InvalidData, format!("bad status line `{}`", line.trim()))
        })?;
    let mut content_len = 0usize;
    let mut close = false;
    loop {
        let mut header = String::new();
        if conn.read_line(&mut header)? == 0 {
            return Err(Error::new(ErrorKind::UnexpectedEof, "connection closed in headers"));
        }
        if header == "\r\n" || header == "\n" {
            break;
        }
        let lower = header.to_ascii_lowercase();
        if let Some(v) = lower.strip_prefix("content-length:") {
            content_len = v
                .trim()
                .parse()
                .map_err(|_| Error::new(ErrorKind::InvalidData, "bad Content-Length"))?;
        } else if let Some(v) = lower.strip_prefix("connection:") {
            close = v.trim() == "close";
        }
    }
    let mut body = Vec::new();
    if !head_only && content_len > 0 {
        body = vec![0u8; content_len];
        conn.read_exact(&mut body)?;
    }
    Ok(Response { status, body, close })
}

impl ObjectStore for RemoteStore {
    fn get(&self, id: &ObjectId) -> Result<Vec<u8>> {
        self.fetch(id).map_err(anyhow::Error::new)
    }

    fn put(&self, id: ObjectId, bytes: &[u8]) -> Result<bool> {
        self.put_remote(id, bytes).map_err(anyhow::Error::new)
    }

    fn contains(&self, id: &ObjectId) -> bool {
        self.contains_remote(id).unwrap_or(false)
    }

    fn list(&self) -> Result<Vec<ObjectId>> {
        bail!(
            "remote store {} does not enumerate objects (no wire endpoint); \
             list the hot tier instead",
            self.url
        )
    }

    fn remove(&self, _id: &ObjectId) -> Result<bool> {
        // Origins never delete over the wire; nothing mutable here.
        Ok(false)
    }

    fn stored_bytes(&self) -> Result<u64> {
        bail!("remote store {} does not report stored bytes", self.url)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn endpoint_parsing() {
        assert_eq!(
            parse_endpoint("http://127.0.0.1:7070").unwrap(),
            ("127.0.0.1".to_string(), 7070)
        );
        assert_eq!(
            parse_endpoint("http://origin.internal:80/").unwrap(),
            ("origin.internal".to_string(), 80)
        );
        assert_eq!(parse_endpoint("http://host").unwrap().1, 80);
        assert!(parse_endpoint("https://host:1").is_err());
        assert!(parse_endpoint("http://host:1/path").is_err());
        assert!(parse_endpoint("http://:7070").is_err());
    }

    #[test]
    fn segment_encoding() {
        assert_eq!(encode_segment("v1"), "v1");
        assert_eq!(encode_segment("a b/c"), "a%20b%2Fc");
    }

    #[test]
    fn backoff_grows_and_is_bounded() {
        for attempt in 1..=8 {
            let d = backoff_delay(attempt);
            let base = 50u64 * (1 << attempt.min(5));
            assert!(d.as_millis() as u64 >= base / 2);
            assert!(d.as_millis() as u64 <= base);
        }
    }

    #[test]
    fn config_roundtrip() {
        let dir = std::env::temp_dir().join(format!("mgit-remote-cfg-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        assert!(RemoteConfig::load(&dir).unwrap().is_none());
        let mut cfg = RemoteConfig::new("http://127.0.0.1:9999");
        cfg.hot_bytes = Some(1 << 20);
        cfg.auth_token = Some("sekrit".to_string());
        cfg.prefetch = false;
        cfg.save(&dir).unwrap();
        let back = RemoteConfig::load(&dir).unwrap().unwrap();
        assert_eq!(back.url, cfg.url);
        assert_eq!(back.auth_token.as_deref(), Some("sekrit"));
        assert_eq!(back.hot_bytes, Some(1 << 20));
        assert!(!back.prefetch);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn error_body_extraction() {
        assert_eq!(body_message(br#"{"error": "server is read-only"}"#), "server is read-only");
        assert_eq!(body_message(b"plain text"), "plain text");
    }
}
