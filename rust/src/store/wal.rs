//! Write-ahead log for the writable serving tier.
//!
//! A repository that accepts live commits (`mgit serve --writable`)
//! records every mutation in an append-only log under `.mgit/wal/`
//! *before* touching the store or the graph. The log is the sole
//! durability mechanism for a write: once the commit record is
//! fsync'd, a crash at any later point — including halfway through
//! materializing loose objects or saving `graph.json` — recovers to
//! exactly that commit, because [`Repo::open`](crate::ops::Repo::open)
//! replays the log non-destructively on every open.
//!
//! ## On-disk format
//!
//! One file, `.mgit/wal/wal.log`:
//!
//! ```text
//! offset  size  field
//! 0       4     magic "MGWL"
//! 4       4     format version, u32 LE (currently 1)
//! 8       ...   records, back to back
//! ```
//!
//! Each record:
//!
//! ```text
//! offset  size  field
//! +0      4     payload length N, u32 LE (1 ..= 1 GiB)
//! +4      4     CRC32 (IEEE) of the N payload bytes, u32 LE
//! +8      N     payload: [kind: u8] + body
//! ```
//!
//! Payload kinds:
//!
//! * `1` — **Put**: 32-byte object id followed by the exact object
//!   bytes. Carrying the bytes in the log (rather than trusting the
//!   loose-file write) is what makes a commit's referenced objects
//!   durable the moment the commit record is synced.
//! * `2` — **Commit**: a UTF-8 JSON commit operation, applied to the
//!   lineage graph by `LineageGraph::apply_commit`.
//!
//! ## Torn-tail policy
//!
//! A crash mid-append leaves a suffix that fails one of the checks
//! (short header, implausible length, truncated payload, checksum
//! mismatch, undecodable payload). [`scan`] stops at the **first**
//! invalid byte and never resynchronizes past it: everything before is
//! the durable prefix, everything after is the torn tail, reported via
//! [`WalScan::torn`] (and surfaced as an `fsck` problem). A writer
//! reopening the log ([`Wal::open_append`]) truncates the torn tail
//! before appending — records are only ever appended after a clean
//! scan, so valid data never follows garbage.
//!
//! The log is bounded by the writer's checkpoint cadence (the serving
//! tier folds it into `graph.json` and truncates every few dozen
//! commits), so [`scan`] reading the whole file into memory is fine.

use std::fs::{self, File, OpenOptions};
use std::io::{Seek, SeekFrom, Write};
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::obs::LazyCounter;
use crate::util::json::{self, Json};

use super::ObjectId;

/// Records appended across the process lifetime (all WAL instances).
pub static WAL_APPENDS: LazyCounter = LazyCounter::new("wal.appends");
/// Records replayed into a store/graph across the process lifetime.
pub static WAL_REPLAYS: LazyCounter = LazyCounter::new("wal.replays");

pub const WAL_MAGIC: &[u8; 4] = b"MGWL";
pub const WAL_VERSION: u32 = 1;
/// Bytes before the first record.
pub const WAL_HEADER_LEN: u64 = 8;
/// Upper bound on a single record's payload (sanity check on scan).
pub const MAX_RECORD_LEN: u32 = 1 << 30;

const KIND_PUT: u8 = 1;
const KIND_COMMIT: u8 = 2;

/// `<root>/.mgit/wal`.
pub fn wal_dir(root: &Path) -> PathBuf {
    root.join(".mgit").join("wal")
}

/// `<root>/.mgit/wal/wal.log`.
pub fn wal_path(root: &Path) -> PathBuf {
    wal_dir(root).join("wal.log")
}

/// One durable mutation.
#[derive(Debug, Clone, PartialEq)]
pub enum WalRecord {
    /// Object bytes, stored under a content id.
    Put { id: ObjectId, bytes: Vec<u8> },
    /// A lineage commit operation (see `LineageGraph::apply_commit`).
    Commit { op: Json },
}

impl WalRecord {
    /// The payload this record serializes to (kind byte + body).
    fn payload(&self) -> Vec<u8> {
        match self {
            WalRecord::Put { id, bytes } => {
                let mut out = Vec::with_capacity(1 + 32 + bytes.len());
                out.push(KIND_PUT);
                out.extend_from_slice(&id.0);
                out.extend_from_slice(bytes);
                out
            }
            WalRecord::Commit { op } => {
                let mut out = vec![KIND_COMMIT];
                out.extend_from_slice(op.to_string().as_bytes());
                out
            }
        }
    }

    fn decode(payload: &[u8]) -> Result<WalRecord> {
        match payload.first() {
            Some(&KIND_PUT) => {
                if payload.len() < 1 + 32 {
                    bail!("put record shorter than an object id");
                }
                let mut id = [0u8; 32];
                id.copy_from_slice(&payload[1..33]);
                Ok(WalRecord::Put { id: ObjectId(id), bytes: payload[33..].to_vec() })
            }
            Some(&KIND_COMMIT) => {
                let text = std::str::from_utf8(&payload[1..])
                    .context("commit record is not UTF-8")?;
                Ok(WalRecord::Commit { op: json::parse(text)? })
            }
            Some(k) => bail!("unknown record kind {k}"),
            None => bail!("empty record payload"),
        }
    }
}

/// Where and why a scan stopped before the end of the file.
#[derive(Debug, Clone)]
pub struct TornTail {
    /// File offset of the first invalid byte.
    pub offset: u64,
    pub reason: String,
}

/// Result of [`scan`]: the durable prefix plus any torn tail.
#[derive(Debug, Default)]
pub struct WalScan {
    /// Every complete, checksummed record, in append order.
    pub records: Vec<WalRecord>,
    /// File length of the durable prefix (header included).
    pub valid_len: u64,
    /// Commit records within `records`.
    pub commits: usize,
    /// Present when the file has bytes past the durable prefix that do
    /// not form a valid record.
    pub torn: Option<TornTail>,
}

/// Read and validate the log at `path`. A missing file is an empty
/// (clean) log. Never modifies the file.
pub fn scan(path: &Path) -> Result<WalScan> {
    let data = match fs::read(path) {
        Ok(d) => d,
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => {
            return Ok(WalScan { valid_len: WAL_HEADER_LEN, ..Default::default() })
        }
        Err(e) => return Err(e).context(format!("reading WAL {}", path.display())),
    };
    let mut out = WalScan::default();
    if data.len() < WAL_HEADER_LEN as usize {
        out.torn = Some(TornTail { offset: 0, reason: "short header".into() });
        return Ok(out);
    }
    if &data[..4] != WAL_MAGIC {
        out.torn = Some(TornTail { offset: 0, reason: "bad magic".into() });
        return Ok(out);
    }
    let version = u32::from_le_bytes([data[4], data[5], data[6], data[7]]);
    if version != WAL_VERSION {
        out.torn =
            Some(TornTail { offset: 4, reason: format!("unknown version {version}") });
        return Ok(out);
    }
    let mut off = WAL_HEADER_LEN as usize;
    let mut torn = |offset: usize, reason: String| -> Option<TornTail> {
        Some(TornTail { offset: offset as u64, reason })
    };
    while off < data.len() {
        if data.len() - off < 8 {
            out.torn = torn(off, "partial record header".into());
            break;
        }
        let len = u32::from_le_bytes([data[off], data[off + 1], data[off + 2], data[off + 3]]);
        let want_crc =
            u32::from_le_bytes([data[off + 4], data[off + 5], data[off + 6], data[off + 7]]);
        if len == 0 || len > MAX_RECORD_LEN {
            out.torn = torn(off, format!("implausible record length {len}"));
            break;
        }
        let body = off + 8;
        let end = body + len as usize;
        if end > data.len() {
            out.torn = torn(off, "record extends past end of file".into());
            break;
        }
        let payload = &data[body..end];
        if crc32(payload) != want_crc {
            out.torn = torn(off, "checksum mismatch".into());
            break;
        }
        match WalRecord::decode(payload) {
            Ok(rec) => {
                if matches!(rec, WalRecord::Commit { .. }) {
                    out.commits += 1;
                }
                out.records.push(rec);
            }
            Err(e) => {
                out.torn = torn(off, format!("undecodable payload: {e}"));
                break;
            }
        }
        off = end;
    }
    out.valid_len = if out.torn.as_ref().is_some_and(|t| t.offset < WAL_HEADER_LEN) {
        // Header itself is damaged: nothing in the file is trustworthy.
        WAL_HEADER_LEN
    } else {
        out.torn.as_ref().map(|t| t.offset).unwrap_or(data.len() as u64)
    };
    Ok(out)
}

/// Single-writer append handle. Creating one truncates any torn tail
/// (the only mutation recovery ever performs on the log itself), so
/// every append lands after a validated prefix.
pub struct Wal {
    path: PathBuf,
    file: File,
}

impl Wal {
    /// Open (creating if needed) the log for `root` and position at the
    /// end of the durable prefix.
    pub fn open_append(root: &Path) -> Result<Wal> {
        let dir = wal_dir(root);
        fs::create_dir_all(&dir)
            .with_context(|| format!("creating WAL dir {}", dir.display()))?;
        let path = wal_path(root);
        let prior = scan(&path)?;
        let mut file = OpenOptions::new()
            .read(true)
            .write(true)
            .create(true)
            .truncate(false)
            .open(&path)
            .with_context(|| format!("opening WAL {}", path.display()))?;
        if file.metadata()?.len() < WAL_HEADER_LEN || prior.valid_len == WAL_HEADER_LEN {
            // Fresh file, or a header-damaged one: (re)write the header.
            file.set_len(0)?;
            file.seek(SeekFrom::Start(0))?;
            file.write_all(WAL_MAGIC)?;
            file.write_all(&WAL_VERSION.to_le_bytes())?;
            file.sync_data()?;
        } else if prior.torn.is_some() {
            file.set_len(prior.valid_len)?;
            file.sync_data()?;
        }
        file.seek(SeekFrom::Start(prior.valid_len.max(WAL_HEADER_LEN)))?;
        Ok(Wal { path, file })
    }

    /// Append one record. Not durable until [`Wal::sync`] returns.
    pub fn append(&mut self, rec: &WalRecord) -> Result<()> {
        let payload = rec.payload();
        let mut frame = Vec::with_capacity(8 + payload.len());
        frame.extend_from_slice(&(payload.len() as u32).to_le_bytes());
        frame.extend_from_slice(&crc32(&payload).to_le_bytes());
        frame.extend_from_slice(&payload);
        self.file
            .write_all(&frame)
            .with_context(|| format!("appending to WAL {}", self.path.display()))?;
        WAL_APPENDS.inc();
        Ok(())
    }

    /// Make every appended record durable (fsync).
    pub fn sync(&mut self) -> Result<()> {
        self.file.sync_data().context("fsync WAL")
    }

    /// Drop every record (after the caller has checkpointed them into
    /// durable state elsewhere, e.g. `graph.json`).
    pub fn truncate(&mut self) -> Result<()> {
        self.file.set_len(WAL_HEADER_LEN)?;
        self.file.seek(SeekFrom::Start(WAL_HEADER_LEN))?;
        self.file.sync_data()?;
        Ok(())
    }

    /// Current file length (header + appended records).
    pub fn len(&self) -> Result<u64> {
        Ok(self.file.metadata()?.len())
    }

    pub fn is_empty(&self) -> Result<bool> {
        Ok(self.len()? <= WAL_HEADER_LEN)
    }

    pub fn path(&self) -> &Path {
        &self.path
    }
}

// ---------------------------------------------------------------------------
// CRC32 (IEEE 802.3), dependency-free
// ---------------------------------------------------------------------------

const fn crc32_table() -> [u32; 256] {
    let mut table = [0u32; 256];
    let mut i = 0;
    while i < 256 {
        let mut c = i as u32;
        let mut k = 0;
        while k < 8 {
            c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            k += 1;
        }
        table[i] = c;
        i += 1;
    }
    table
}

static CRC_TABLE: [u32; 256] = crc32_table();

/// CRC32 (IEEE) of `bytes`.
pub fn crc32(bytes: &[u8]) -> u32 {
    let mut c = 0xFFFF_FFFFu32;
    for &b in bytes {
        c = CRC_TABLE[((c ^ b as u32) & 0xFF) as usize] ^ (c >> 8);
    }
    c ^ 0xFFFF_FFFF
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn crc32_known_vectors() {
        // Standard test vector: CRC32("123456789") = 0xCBF43926.
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
    }

    fn tmp_root(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir()
            .join(format!("mgit-wal-{tag}-{}", std::process::id()));
        let _ = fs::remove_dir_all(&dir);
        fs::create_dir_all(&dir).unwrap();
        dir
    }

    #[test]
    fn append_scan_roundtrip() {
        let root = tmp_root("roundtrip");
        let recs = vec![
            WalRecord::Put { id: ObjectId([7u8; 32]), bytes: vec![1, 2, 3, 4, 5] },
            WalRecord::Commit {
                op: Json::obj().set("name", "m/v1").set("model_type", "t"),
            },
            WalRecord::Put { id: ObjectId([9u8; 32]), bytes: vec![] },
        ];
        {
            let mut wal = Wal::open_append(&root).unwrap();
            for r in &recs {
                wal.append(r).unwrap();
            }
            wal.sync().unwrap();
        }
        let scan = scan(&wal_path(&root)).unwrap();
        assert!(scan.torn.is_none());
        assert_eq!(scan.commits, 1);
        assert_eq!(scan.records, recs);
        fs::remove_dir_all(&root).unwrap();
    }

    #[test]
    fn torn_tail_detected_and_truncated_on_reopen() {
        let root = tmp_root("torn");
        {
            let mut wal = Wal::open_append(&root).unwrap();
            wal.append(&WalRecord::Put { id: ObjectId([1u8; 32]), bytes: vec![42; 16] })
                .unwrap();
            wal.sync().unwrap();
        }
        let path = wal_path(&root);
        let full = fs::read(&path).unwrap();
        // Simulate a crash mid-append: half a record header dangling.
        let mut cut = full.clone();
        cut.extend_from_slice(&[0xAB, 0xCD, 0xEF]);
        fs::write(&path, &cut).unwrap();
        let s = scan(&path).unwrap();
        assert_eq!(s.records.len(), 1);
        let torn = s.torn.expect("dangling bytes must be reported torn");
        assert_eq!(torn.offset, full.len() as u64);
        // Reopening for append truncates the tail.
        drop(Wal::open_append(&root).unwrap());
        assert_eq!(fs::read(&path).unwrap(), full);
        fs::remove_dir_all(&root).unwrap();
    }

    #[test]
    fn bit_flip_stops_replay_at_first_bad_record() {
        let root = tmp_root("flip");
        {
            let mut wal = Wal::open_append(&root).unwrap();
            for i in 0..4u8 {
                wal.append(&WalRecord::Put {
                    id: ObjectId([i; 32]),
                    bytes: vec![i; 8],
                })
                .unwrap();
            }
            wal.sync().unwrap();
        }
        let path = wal_path(&root);
        let mut data = fs::read(&path).unwrap();
        // Flip one payload bit in the third record.
        let rec_len = 8 + 1 + 32 + 8;
        let third_payload = WAL_HEADER_LEN as usize + 2 * rec_len + 8 + 5;
        data[third_payload] ^= 0x10;
        fs::write(&path, &data).unwrap();
        let s = scan(&path).unwrap();
        assert_eq!(s.records.len(), 2, "replay must stop before the flipped record");
        let torn = s.torn.unwrap();
        assert_eq!(torn.offset as usize, WAL_HEADER_LEN as usize + 2 * rec_len);
        assert!(torn.reason.contains("checksum"));
        fs::remove_dir_all(&root).unwrap();
    }
}
